"""Benchmark aggregator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Heavy search stages cache their
results under results/bench/; pass --force to individual modules to
re-derive, or --paper-scale for the full sample counts.
"""
from __future__ import annotations

import sys
import warnings


def main() -> None:
    warnings.filterwarnings("ignore")
    from . import (fig3_breakdown, fig5_latency, fig6_dse, fig7_ga,
                   fig8_taxonomy, perf_micro, rtl_gating, table2_nvdla)

    print("name,us_per_call,derived")
    for mod in (table2_nvdla, fig3_breakdown, fig5_latency, fig6_dse,
                fig7_ga, fig8_taxonomy, rtl_gating, perf_micro):
        for line in mod.main():
            print(line)
        sys.stdout.flush()


if __name__ == "__main__":
    main()
