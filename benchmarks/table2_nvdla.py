"""Paper Table 2: MOSAIC vs NVDLA on an INT8 64x64x64 GEMM at two design
points spanning 32x in MAC density (nv_small, nv_full).

Reports each metric, the MOSAIC/NVDLA ratio, and compares against the
ratios the paper itself reports (latency 1.08x/1.39x, energy 1.41x/1.19x,
area 1.77x/1.50x) — the external axis of the three-axis validation.
"""
from __future__ import annotations

from repro.core import compile_workload, simulate
from repro.core.calibrate.nvdla import NVDLA_FULL, NVDLA_SMALL, nvdla_chip
from repro.core.ir import OpNode, OpType, Precision, WorkloadGraph

from .common import csv_row, save_json, timed

PAPER_RATIOS = {  # (latency, energy, area) MOSAIC/NVDLA from Table 2
    "nv_small": (1.08, 1.41, 1.77),
    "nv_full": (1.39, 1.19, 1.50),
}


def gemm64() -> WorkloadGraph:
    g = WorkloadGraph("gemm64", model_precision=Precision.INT8)
    g.add(OpNode("gemm", OpType.MATMUL, m=64, k=64, n=64,
                 precision=Precision.INT8, splittable=False))
    return g


def run() -> dict:
    rows = []
    for point in (NVDLA_SMALL, NVDLA_FULL):
        chip = nvdla_chip(point)
        g = gemm64()
        (r, us) = timed(lambda: simulate(chip, compile_workload(g, chip)))
        ratios = {
            "latency": r.latency_s * 1e6 / point.latency_us,
            "energy": r.energy_pj * 1e-3 / point.energy_nj,
            "area": r.area_mm2 / point.area_mm2,
            "peak_tops": r.peak_tops / point.peak_tops,
        }
        rows.append({
            "point": point.name,
            "mosaic": {"latency_us": r.latency_s * 1e6,
                       "energy_nj": r.energy_pj * 1e-3,
                       "area_mm2": r.area_mm2,
                       "peak_tops": r.peak_tops,
                       "tops_per_w": r.tops_per_w},
            "nvdla": {"latency_us": point.latency_us,
                      "energy_nj": point.energy_nj,
                      "area_mm2": point.area_mm2,
                      "peak_tops": point.peak_tops,
                      "tops_per_w": point.tops_per_w},
            "ratio": ratios,
            "paper_ratio": dict(zip(("latency", "energy", "area"),
                                    PAPER_RATIOS[point.name])),
            "us_per_call": us,
        })
    save_json("table2_nvdla", rows)
    return rows


def main() -> list:
    rows = run()
    out = []
    for r in rows:
        m, ratio = r["mosaic"], r["ratio"]
        out.append(csv_row(
            f"table2_{r['point']}", r["us_per_call"],
            f"lat_ratio={ratio['latency']:.2f} en_ratio={ratio['energy']:.2f} "
            f"area_ratio={ratio['area']:.2f} peak_ratio={ratio['peak_tops']:.2f}"))
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
