"""Paper Fig. 7: GA-refined mean iso-area energy savings vs chip-area
budget {50, 100, 200, 400, 800} mm^2.

Paper: inverted-U peaking in the 100-400 mm^2 band
(+45.39 / +46.91 / +46.88 %), 800 mm^2 regresses to +42.69 %; Hetero-BLS
wins at every budget.  Reduced GA budget by default; --paper-scale
restores population 200 x 100 generations.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.dse.api import EngineConfig
from repro.core.dse.encoding import decode
from repro.core.dse.engine import EvalEngine
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.objective import AREA_BRACKETS
from repro.core.dse.sweep import run_sweep
from repro.core.workloads import workload_names

from .common import csv_row, load_json, save_json


def run(samples_per_stratum: int = 40, ga_cfg: GAConfig = None,
        force: bool = False, exact: bool = False) -> dict:
    """``exact=True`` runs the whole pipeline — sweep, every bracket's
    GA refinement, and the finalist numbers — on the exact search
    backend (one fused class-specialized map+execute scan per dispatch),
    so the GA selects on the same bits ``rescore()`` reports and the
    finalist re-score below is a cache formality."""
    cached = load_json("fig7_ga")
    if cached is not None and not force:
        return cached
    ga_cfg = ga_cfg or GAConfig(population=32, generations=10, seed_top_k=24,
                                early_stop=5)
    wls = workload_names()
    # one engine across the sweep and every bracket's GA: each GA's seed
    # population (top-k sweep individuals) is already memoized
    engine = EvalEngine(wls, config=EngineConfig(
        backend="exact" if exact else "scan"))
    sw = run_sweep(wls, samples_per_stratum=samples_per_stratum, seed=0,
                   verbose=True, engine=engine)
    rows = []
    for bracket in AREA_BRACKETS:
        res = run_ga(sw, bracket, ga_cfg, verbose=True, engine=engine)
        if res is None:
            continue
        chip = decode(res.best_genome)
        n_types = len(chip.tiles)
        has_sfu = any(t.sfu_mask for t, _ in chip.tiles)
        family = "Hetero-BLS" if has_sfu else (
            "Hetero-BL" if n_types > 1 else "Homo")
        # finalist re-scored through the exact batched plan backend
        exact = engine.rescore(res.best_genome[None, :])
        rows.append({
            "bracket_mm2": bracket,
            "mean_savings_pct": 100 * float(np.mean(res.best_savings_per_wl)),
            "fitness": res.best_fitness,
            "family": family,
            "evaluated": res.evaluated,
            "genome": res.best_genome.tolist(),
            "tops_per_w_mean": float(np.mean(res.best_metrics["tops_w"])),
            "tops_per_w_peak": float(np.max(res.best_metrics["tops_w"])),
            "exact_mean_latency_us": 1e6 * float(np.mean(exact["latency"])),
            "exact_mean_energy_uj": 1e-6 * float(np.mean(exact["energy"])),
            "rescore_backend": exact["meta"]["backend"],
        })
    payload = {"rows": rows, "samples": samples_per_stratum,
               "cache_hit_rate": engine.stats.hit_rate(),
               "evaluator_backend": engine.backend,
               "evaluator_throughput_cfg_wl_per_s": engine.stats.throughput()}
    save_json("fig7_ga", payload)
    return payload


def main() -> list:
    import warnings
    warnings.filterwarnings("ignore")
    p = run()
    out = []
    for r in p["rows"]:
        out.append(csv_row(
            f"fig7_ga_{int(r['bracket_mm2'])}mm2", 0.0,
            f"mean_savings={r['mean_savings_pct']:.1f}% family={r['family']} "
            f"mean_tops_w={r['tops_per_w_mean']:.2f}"))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--exact", action="store_true",
                    help="search on the exact fused-mapper backend "
                         "(search-time fitness == rescore bitwise)")
    a = ap.parse_args()
    if a.paper_scale:
        run(200, GAConfig(), force=True, exact=a.exact)
    elif a.force or a.exact:
        run(force=True, exact=a.exact)
    for line in main():
        print(line)
