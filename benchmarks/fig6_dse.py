"""Paper Fig. 6: per-workload best iso-area energy savings of the
DSE-selected heterogeneous design vs the iso-knob homogeneous baseline,
mean +- stdev across 3 random-sampling seeds.

Paper targets: ResNet-50 +60.10 +- 1.18 %; INT-quantized group 37-60 %;
FP16 transformer/SSM 16-34 %; spec-decode +0.28 %.

Offline CPU default is a reduced sample count; --paper-scale restores the
~980 K/seed sweep (DESIGN.md §2).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.dse.engine import EvalEngine
from repro.core.dse.sweep import run_sweep
from repro.core.workloads import workload_names

from .common import csv_row, load_json, save_json

DEFAULT_SAMPLES = 40  # per (bracket x family) stratum, per seed
SEEDS = (0, 1, 2)


def run(samples_per_stratum: int = DEFAULT_SAMPLES, seeds=SEEDS,
        workloads=None, force: bool = False) -> dict:
    cached = load_json("fig6_dse")
    if cached is not None and not force \
            and cached.get("samples") == samples_per_stratum:
        return cached
    workloads = workloads or workload_names()
    # one engine for all seeds: genomes re-sampled across seeds are free
    engine = EvalEngine(workloads)
    per_seed = []
    for seed in seeds:
        sw = run_sweep(workloads, samples_per_stratum=samples_per_stratum,
                       seed=seed, verbose=True, engine=engine)
        sav = sw.savings()
        hetero = (sw.family > 0)[:, None]
        best = np.nanmax(np.where(hetero, sav, np.nan), axis=0)
        per_seed.append(best)
    arr = np.asarray(per_seed)  # (seeds, W)
    payload = {
        "samples": samples_per_stratum,
        "seeds": list(seeds),
        "workloads": list(workloads),
        "mean": (100 * np.nanmean(arr, axis=0)).tolist(),
        "stdev": (100 * np.nanstd(arr, axis=0)).tolist(),
        "cache_hit_rate": engine.stats.hit_rate(),
        "evaluator_throughput_cfg_wl_per_s": engine.stats.throughput(),
    }
    save_json("fig6_dse", payload)
    return payload


def main() -> list:
    import warnings
    warnings.filterwarnings("ignore")
    p = run()
    out = []
    for w, m, s in zip(p["workloads"], p["mean"], p["stdev"]):
        out.append(csv_row(f"fig6_{w}", 0.0,
                           f"best_iso_area_savings={m:.1f}%+-{s:.1f}"))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=DEFAULT_SAMPLES)
    ap.add_argument("--paper-scale", action="store_true",
                    help="~65k samples/stratum (paper's ~980K/seed)")
    ap.add_argument("--force", action="store_true")
    a = ap.parse_args()
    n = 65333 if a.paper_scale else a.samples
    run(n, force=a.force or a.paper_scale)
    for line in main():
        print(line)
