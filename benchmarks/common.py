"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import statistics
import time
from typing import Callable, Dict, List, Sequence

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def save_repo_json(filename: str, payload) -> str:
    """Write a machine-readable benchmark payload at the repo root (the
    cross-PR perf trajectory files, e.g. BENCH_PR3.json)."""
    path = os.path.join(REPO_ROOT, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float, sort_keys=True)
        f.write("\n")
    return path


def median_s(samples: Sequence[float]) -> float:
    """Median seconds over benchmark repeats (the BENCH_PR*.json metric:
    robust to one-off scheduler noise, unlike min)."""
    return float(statistics.median(samples))


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def load_json(name: str):
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        return json.load(open(path))
    return None


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, us_per_call) with one warmup."""
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
