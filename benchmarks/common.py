"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def load_json(name: str):
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        return json.load(open(path))
    return None


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, us_per_call) with one warmup."""
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
