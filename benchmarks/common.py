"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import statistics
import time
from typing import Callable, Dict, List, Sequence

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def enable_persistent_compilation_cache() -> bool:
    """Point XLA's persistent compilation cache at
    ``$JAX_COMPILATION_CACHE_DIR`` when the env var is set (CI persists
    the directory via actions/cache keyed on the jax pin, so the fused
    mapper+executor's ~5-10 s per (calib, op-bucket) compiles are paid
    once per pin bump, not once per run).  No-op without the env var or
    on jax versions lacking a config knob; returns True when active.
    Mirrored by tests/conftest.py for the pytest jobs."""
    cache_dir = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    if not cache_dir:
        return False
    try:
        import jax
        jax.config.update("jax_compilation_cache_dir", cache_dir)
    except Exception:
        return False
    # cache even sub-second compiles: the sweep's cost is many medium
    # compiles, not one giant one (knobs exist on the pinned jax range;
    # tolerate their absence on other versions)
    for knob, val in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                      ("jax_persistent_cache_min_entry_size_bytes", 0)):
        try:
            jax.config.update(knob, val)
        except Exception:
            pass
    return True


_COMPILATION_CACHE_ACTIVE = enable_persistent_compilation_cache()


def save_repo_json(filename: str, payload) -> str:
    """Write a machine-readable benchmark payload at the repo root (the
    cross-PR perf trajectory files, e.g. BENCH_PR3.json)."""
    path = os.path.join(REPO_ROOT, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float, sort_keys=True)
        f.write("\n")
    return path


def median_s(samples: Sequence[float]) -> float:
    """Median seconds over benchmark repeats (the BENCH_PR*.json metric:
    robust to one-off scheduler noise, unlike min)."""
    return float(statistics.median(samples))


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def load_json(name: str):
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    if os.path.exists(path):
        return json.load(open(path))
    return None


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, us_per_call) with one warmup."""
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
