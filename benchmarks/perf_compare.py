"""Perf-trajectory comparison: fresh smoke numbers vs the committed
baseline.

Loads the just-written ``BENCH_PR5_smoke.json`` (produced by
``python -m benchmarks.perf_micro --smoke``; falls back to the legacy
``BENCH_PR3_smoke.json``) and the committed ``BENCH_PR5.json``
trajectory file (falling back to the PR-4 ``BENCH_PR3.json`` for
benchmarks recorded there — e.g. on the first run after a trajectory
file rename), and emits a markdown table of per-benchmark speedups with
the delta against the baseline's recorded speedup.  Benchmarks new in
the fresh file (``run_ga_exact_speedup``) show a baseline of "—" until
a full run commits them.  In CI the table is appended to
``$GITHUB_STEP_SUMMARY`` so the per-PR perf history is visible on the
workflow run page; locally it prints to stdout.

Smoke runs use a smaller population than the committed full-population
numbers, so the comparison is trajectory-shaped (is the speedup holding?)
rather than an apples-to-apples gate — the hard floors stay in
``perf_micro --smoke`` itself.

  PYTHONPATH=src python -m benchmarks.perf_compare
"""
from __future__ import annotations

import json
import os
import sys

# not benchmarks.common's REPO_ROOT: importing common would pull in jax
# (and mutate its config) just to diff two JSON files
REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

__all__ = ["compare", "render_markdown"]


def _load(filename: str):
    path = os.path.join(REPO_ROOT, filename)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def compare(fresh: dict, baseline: dict) -> list:
    """Per-benchmark rows: (name, fresh speedup, baseline speedup, delta).
    Benchmarks present on only one side get None for the missing value."""
    rows = []
    fb = fresh.get("benchmarks", {})
    bb = baseline.get("benchmarks", {})
    for name in sorted(set(fb) | set(bb)):
        f_spd = fb.get(name, {}).get("speedup")
        b_spd = bb.get(name, {}).get("speedup")
        delta = (f_spd - b_spd) if (f_spd is not None and b_spd is not None) \
            else None
        rows.append((name, f_spd, b_spd, delta))
    return rows


def render_markdown(rows: list, fresh: dict, baseline: dict) -> str:
    def fmt(v, suffix="x"):
        return f"{v:.2f}{suffix}" if v is not None else "—"

    lines = [
        "## Perf trajectory: smoke run vs committed BENCH_PR5/PR3 baseline",
        "",
        f"fresh: smoke={fresh.get('smoke')} · "
        f"baseline: pr={baseline.get('pr')} smoke={baseline.get('smoke')}",
        "",
        "| benchmark | fresh speedup | committed speedup | delta |",
        "|---|---|---|---|",
    ]
    for name, f_spd, b_spd, delta in rows:
        d = fmt(delta) if delta is None else f"{delta:+.2f}x"
        lines.append(f"| {name} | {fmt(f_spd)} | {fmt(b_spd)} | {d} |")
    lines.append("")
    lines.append("smoke populations are smaller than the committed "
                 "full-population run; deltas show trajectory, the hard "
                 "floor is enforced by `perf_micro --smoke`.")
    return "\n".join(lines) + "\n"


def _load_first(*filenames):
    for f in filenames:
        data = _load(f)
        if data is not None:
            return data
    return None


def _merged_baseline():
    """Committed baseline: BENCH_PR5.json, with BENCH_PR3.json filling
    in benchmarks the newer file doesn't carry (rename transition)."""
    new = _load("BENCH_PR5.json")
    old = _load("BENCH_PR3.json")
    if new is None:
        return old
    if old is not None:
        merged = dict(old.get("benchmarks", {}))
        merged.update(new.get("benchmarks", {}))
        new = dict(new)
        new["benchmarks"] = merged
    return new


def main() -> int:
    fresh = _load_first("BENCH_PR5_smoke.json", "BENCH_PR3_smoke.json")
    baseline = _merged_baseline()
    if fresh is None:
        print("perf_compare: BENCH_PR5_smoke.json missing — run "
              "`python -m benchmarks.perf_micro --smoke` first",
              file=sys.stderr)
        return 1
    if baseline is None:
        print("perf_compare: no committed BENCH_PR5.json / BENCH_PR3.json "
              "baseline", file=sys.stderr)
        return 1
    md = render_markdown(compare(fresh, baseline), fresh, baseline)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md)
    print(md)
    return 0


if __name__ == "__main__":
    sys.exit(main())
