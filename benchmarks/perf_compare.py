"""Perf-trajectory comparison: fresh smoke numbers vs the committed
baseline.

Scans the repo root for every ``BENCH_PR<N>.json`` trajectory file
(committed full runs) and ``BENCH_PR<N>_smoke.json`` (just written by
``python -m benchmarks.perf_micro --smoke`` / ``--service``), merges
each side newest-entry-per-benchmark — a benchmark recorded by several
PRs is taken from the highest-numbered file, while benchmarks that only
an older PR carries survive the merge — and emits a markdown table of
per-benchmark speedups with the delta against the baseline's recorded
speedup.  Benchmarks new in the fresh file show a baseline of "—" until
a full run commits them.  In CI the table is appended to
``$GITHUB_STEP_SUMMARY`` so the per-PR perf history is visible on the
workflow run page; locally it prints to stdout.

Smoke runs use a smaller population than the committed full-population
numbers, so the comparison is trajectory-shaped (is the speedup holding?)
rather than an apples-to-apples gate — the hard floors stay in
``perf_micro --smoke`` itself.

  PYTHONPATH=src python -m benchmarks.perf_compare
"""
from __future__ import annotations

import json
import os
import re
import sys

# not benchmarks.common's REPO_ROOT: importing common would pull in jax
# (and mutate its config) just to diff two JSON files
REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

__all__ = ["compare", "render_markdown", "merged_trajectory",
           "missing_named_benchmarks"]


def _load(filename: str):
    path = os.path.join(REPO_ROOT, filename)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _rank(data: dict, pr: int, name: str) -> tuple:
    """Merge precedence of one trajectory file: the run timestamp it
    records (``generated_unix``, stamped by ``perf_micro``'s writers —
    newer run wins), then PR number, then filename.  A total order over
    the candidate files, so two files carrying the same benchmark with
    equal (or missing) timestamps still merge deterministically — the
    higher-numbered PR wins — instead of depending on the directory
    listing order ``os.listdir`` happens to return."""
    ts = data.get("generated_unix")
    ts = float(ts) if isinstance(ts, (int, float)) else float("-inf")
    return (ts, pr, name)


def merged_trajectory(smoke: bool):
    """Merge every ``BENCH_PR<N>[_smoke].json`` in the repo root, newest
    entry winning per benchmark key (see ``_rank`` for what "newest"
    means and how ties break).  Returns None when no file matches."""
    suffix = "_smoke" if smoke else ""
    pat = re.compile(rf"^BENCH_PR(\d+){suffix}\.json$")
    hits = []
    for name in os.listdir(REPO_ROOT):
        m = pat.match(name)
        if m:
            data = _load(name) or {}
            hits.append((_rank(data, int(m.group(1)), name), name, data))
    if not hits:
        return None
    hits.sort(key=lambda h: h[0])  # ascending rank: newest overwrites
    merged: dict = {"benchmarks": {}}
    for _, name, data in hits:
        merged.update({k: v for k, v in data.items() if k != "benchmarks"})
        merged["benchmarks"].update(data.get("benchmarks", {}))
    merged["files"] = [name for _, name, _ in hits]
    return merged


def missing_named_benchmarks() -> list:
    """Full-run ``BENCH_PR<N>.json`` files that CHANGES.md names but the
    repo root does not contain.  A benchmark file named in the change
    log and then never committed silently vanishes from the merged
    baseline (the glob just doesn't see it), which is how PR 8's
    trajectory went missing — so ``main`` warns loudly instead."""
    changes = _load_text("CHANGES.md")
    if changes is None:
        return []
    named = set(re.findall(r"BENCH_PR\d+\.json", changes))
    return sorted(n for n in named
                  if not os.path.exists(os.path.join(REPO_ROOT, n)))


def _load_text(filename: str):
    path = os.path.join(REPO_ROOT, filename)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return f.read()


def compare(fresh: dict, baseline: dict) -> list:
    """Per-benchmark rows: (name, fresh speedup, baseline speedup, delta).
    Benchmarks present on only one side get None for the missing value."""
    rows = []
    fb = fresh.get("benchmarks", {})
    bb = baseline.get("benchmarks", {})
    for name in sorted(set(fb) | set(bb)):
        f_spd = fb.get(name, {}).get("speedup")
        b_spd = bb.get(name, {}).get("speedup")
        delta = (f_spd - b_spd) if (f_spd is not None and b_spd is not None) \
            else None
        rows.append((name, f_spd, b_spd, delta))
    return rows


def render_markdown(rows: list, fresh: dict, baseline: dict) -> str:
    def fmt(v, suffix="x"):
        return f"{v:.2f}{suffix}" if v is not None else "—"

    lines = [
        "## Perf trajectory: smoke run vs committed BENCH_PR* baseline",
        "",
        f"fresh: {', '.join(fresh.get('files', []))} · "
        f"baseline: {', '.join(baseline.get('files', []))}",
        "",
        "| benchmark | fresh speedup | committed speedup | delta |",
        "|---|---|---|---|",
    ]
    for name, f_spd, b_spd, delta in rows:
        d = fmt(delta) if delta is None else f"{delta:+.2f}x"
        lines.append(f"| {name} | {fmt(f_spd)} | {fmt(b_spd)} | {d} |")
    lines.append("")
    lines.append("smoke populations are smaller than the committed "
                 "full-population run; deltas show trajectory, the hard "
                 "floor is enforced by `perf_micro --smoke`.")
    return "\n".join(lines) + "\n"


def main() -> int:
    fresh = merged_trajectory(smoke=True)
    baseline = merged_trajectory(smoke=False)
    if fresh is None:
        print("perf_compare: no BENCH_PR*_smoke.json — run "
              "`python -m benchmarks.perf_micro --smoke` first",
              file=sys.stderr)
        return 1
    if baseline is None:
        print("perf_compare: no committed BENCH_PR*.json baseline",
              file=sys.stderr)
        return 1
    md = render_markdown(compare(fresh, baseline), fresh, baseline)
    missing = missing_named_benchmarks()
    if missing:
        for name in missing:
            print(f"perf_compare: WARNING: {name} is named in CHANGES.md "
                  "but absent from the repo root — its benchmarks are "
                  "MISSING from the committed baseline (regenerate via "
                  "`python -m benchmarks.perf_micro` and commit the file)",
                  file=sys.stderr)
        md += ("\n> **WARNING**: missing committed benchmark file(s) "
               f"named in CHANGES.md: {', '.join(missing)} — the baseline "
               "above silently excludes them.\n")
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md)
    print(md)
    return 0


if __name__ == "__main__":
    sys.exit(main())
