"""Paper Fig. 8 / §5.3: three-group workload taxonomy — best iso-area
savings vs arithmetic intensity for the 15 MAC/DSP-dominant workloads.

Paper: INT-quantized (+GNN-GAT) reach 37-60 %; FP16 transformer/SSM
16-34 %; bandwidth-bound spec-decode ~0.3 %.  Reads fig6's sweep output.
"""
from __future__ import annotations

import numpy as np

from repro.core.workloads import build
from repro.core.workloads.suite import GROUPS

from . import fig6_dse
from .common import csv_row, save_json


def run() -> list:
    p = fig6_dse.run()
    by_name = dict(zip(p["workloads"], p["mean"]))
    rows = []
    group_of = {}
    for gname, members in GROUPS.items():
        for m in members:
            group_of[m] = gname
    for name, sav in by_name.items():
        g = build(name)
        rows.append({"workload": name, "group": group_of.get(name, "?"),
                     "arithmetic_intensity": g.arithmetic_intensity(),
                     "best_savings_pct": sav})
    # group means (MAC/DSP-dominant groups only, as in the paper)
    summary = {}
    for gname in ("int_quantized", "fp16_transformer_ssm", "bandwidth_bound"):
        vals = [r["best_savings_pct"] for r in rows if r["group"] == gname]
        summary[gname] = {"mean": float(np.mean(vals)),
                          "min": float(np.min(vals)),
                          "max": float(np.max(vals))}
    payload = {"rows": rows, "group_summary": summary}
    save_json("fig8_taxonomy", payload)
    return payload


def main() -> list:
    p = run()
    out = []
    for gname, s in p["group_summary"].items():
        out.append(csv_row(f"fig8_group_{gname}", 0.0,
                           f"savings mean={s['mean']:.1f}% "
                           f"range=[{s['min']:.1f},{s['max']:.1f}]%"))
    # ordering check: the paper's taxonomy ordering
    g = p["group_summary"]
    ordered = (g["int_quantized"]["mean"] > g["fp16_transformer_ssm"]["mean"]
               > g["bandwidth_bound"]["mean"])
    out.append(csv_row("fig8_ordering", 0.0,
                       f"int>fp16>bandwidth={'OK' if ordered else 'VIOLATED'}"))
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
