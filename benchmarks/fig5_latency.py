"""Paper Fig. 5: single-batch inference latency of the GA-refined HPU
(Hetero-BLS, ~100 mm^2) vs synthesized NVDLA-large (nv_full: 2048-MAC
INT8+FP16, 512 KB CBUF) on every NVDLA-supported workload.

Paper: latency parity on ResNet-50 INT8 (NVDLA's design target), 1.5-2.4x
faster on INT8/SSM/ViT, 1.2-1.3x on FP16 dense-LLM decodes; the four
workloads NVDLA cannot execute (three INT4 LLMs + RT-2) are excluded.
"""
from __future__ import annotations

from repro.core import compile_workload, simulate
from repro.core.arch import (ChipConfig, Sparsity, TileTemplate, big_tile,
                             little_tile, special_tile)
from repro.core.calibrate.nvdla import NVDLA_FULL, nvdla_chip
from repro.core.ir import Precision
from repro.core.workloads import build, workload_names

from .common import csv_row, load_json, save_json

# NVDLA-large cannot execute INT4 weights or RT-2's action operators
UNSUPPORTED = {"llama7b_int4", "mixtral_int4", "nemotron_h_int4", "rt2"}


def ga_refined_100mm2() -> ChipConfig:
    """Representative GA-refined Hetero-BLS at ~100 mm^2 (fig7's winner
    family re-expressed as a canned config so this benchmark is
    deterministic; re-derive with benchmarks/fig7_ga.py --paper-scale)."""
    return ChipConfig(
        name="hpu-100mm2-bls",
        tiles=(
            (big_tile(rows=64, cols=64, sram_kb=2048), 1),
            (little_tile(rows=32, cols=32, sram_kb=1024,
                         sparsity=Sparsity.TWO_SIDED, clock_mhz=1200), 3),
            (special_tile(sram_kb=512), 1),
        ),
        dram_gbps=128.0,
    )


def run(force: bool = False) -> list:
    cached = load_json("fig5_latency")
    if cached is not None and not force:
        return cached
    hpu = ga_refined_100mm2()
    nvdla = nvdla_chip(NVDLA_FULL)
    rows = []
    for name in workload_names():
        if name in UNSUPPORTED:
            continue
        g = build(name)
        r_h = simulate(hpu, compile_workload(g, hpu))
        r_n = simulate(nvdla, compile_workload(g, nvdla))
        rows.append({
            "workload": name,
            "hpu_ms": r_h.latency_s * 1e3,
            "nvdla_ms": r_n.latency_s * 1e3,
            "speedup": r_n.latency_s / r_h.latency_s,
            "hpu_energy_ratio": r_h.energy_pj / r_n.energy_pj,
            "hpu_area_mm2": r_h.area_mm2,
        })
    save_json("fig5_latency", rows)
    return rows


def main() -> list:
    rows = run()
    out = []
    for r in rows:
        out.append(csv_row(
            f"fig5_{r['workload']}", 0.0,
            f"speedup={r['speedup']:.2f}x "
            f"energy_ratio={r['hpu_energy_ratio']:.2f}x"))
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
