"""Framework-performance microbenchmarks: the beyond-paper speedups.

* batch evaluator vs reference simulator throughput (the TPU-native
  re-think of the paper's 2.94 M-sample host loop);
* cache-aware ``EvalEngine`` vs the pre-refactor ``evaluate_genomes``
  host loop on a GA refinement run (population 64, 10 generations,
  4 workloads), reporting evaluator throughput (configs*workloads/s)
  and the GA cache-hit rate;
* the batched plan executor vs the per-candidate ChipSim walk on one
  GA-generation-sized population (64 candidates, plans precompiled for
  both sides — this isolates the simulator core, which ISSUE 2 targets
  at >= 5x);
* the compile-free exact path (fused batched Eq. 1-3 mapper + plan
  executor, ``compiler.batched_mapper.map_and_simulate``) vs the
  per-candidate ``compile_to_table`` path, end-to-end compile+simulate
  on a 64-genome x 6-workload population (ISSUE 3 targets >= 10x);
* the throughput-mode exact path (the same fused dispatch consuming the
  pipelined steady-state surface: II, per-inference energy) vs the
  latency-mode measurement — the II scan state rides in the same scan,
  so the ratio should hold near 1.0 (ISSUE 4 keeps it on the perf
  trajectory);
* the device GA generation loop on the exact search backend (jitted
  genetics + one class-specialized fused map+execute scan per workload
  per generation, ``run_ga`` defaults) vs the PR-4 host GA loop scoring
  the SAME exact (fused-mapper) metrics through ``backend="batched"``
  — iso-fidelity, so the measured win is pure framework (ISSUE 5
  targets >= 5x; the approximate-scan search time is recorded alongside
  for the fidelity-cost context);
* cross-tenant coalescing through the DSE evaluation service
  (``repro.serve.dse_service``): two concurrent GA tenants on one
  shared exact engine + persistent store vs the same tenants run
  sequentially on private local engines — wall clock, fused-dispatch
  reduction, and the warm persistent-store hit rate, with bitwise
  parity asserted (PR 6; ``python -m benchmarks.perf_micro --service``
  runs just this one and writes ``BENCH_PR6.json``);
* the fused §4 refinement path (device-resident memo + whole-GA-run
  dispatches, the ``run_pipeline`` Stage 2) vs the per-generation
  host-memo loop (``run_ga(loop="device")``) on the same seeded
  bracket sequence — bitwise-identical genome streams asserted, so the
  measured win is pure host-round-trip elimination (PR 7 targets >= 3x
  at population 4096; ``--pipeline`` runs just this one and writes
  ``BENCH_PR7.json``);
* the checkpointed §4 pipeline (per-stage durable records + memo
  drains, ``run_pipeline(checkpoint=...)``) vs the same study without,
  plus the resume-replay path over a completed checkpoint directory —
  informational (no floor): the overhead is stage-boundary I/O, the
  replay speedup is what a crash-resume saves (PR 8; writes
  ``BENCH_PR8.json``);
* the per-link NoC + per-channel DRAM fidelity tier
  (``fidelity="link"``) vs the aggregate tier on the exact throughput
  dispatch — identical mapping/latency/energy asserted, ``II(link) >=
  II(aggregate)`` pinned, overhead reported against a fail-soft 3.5x
  ceiling (PR 9; ``--link-fidelity`` runs just this one and writes
  ``BENCH_PR9.json``);
* coordinator dispatch-throughput scaling of the sharded worker cluster
  (``serve.cluster.DSECluster`` over 1-3 ``DSEService`` workers with
  emulated GIL-releasing worker service time — the CI box is
  single-core, so real compute cannot scale), plus the recovery
  overhead of a deterministic mid-stream ``worker_kill``, with bitwise
  parity asserted across every configuration (PR 10 targets >= 1.5x
  dispatch scaling at 3 workers; ``--cluster`` runs just this one and
  writes ``BENCH_PR10.json``).

Besides the per-run ``results/bench/perf_micro.json`` payload, ``run``
writes the machine-readable cross-PR trajectory files ``BENCH_PR5.json``
and ``BENCH_PR6.json`` at the repo root (``perf_compare`` merges every
``BENCH_PR*.json`` newest-entry-per-benchmark): per-benchmark median
seconds + speedup vs baseline.  ``python -m benchmarks.perf_micro
--smoke`` runs small-population exact-path + exact-GA + service checks
for CI (exit 1 when the exact path drops below its 5x floor or the
exact GA below its fail-soft 3x floor — the perf-smoke job is
non-blocking, so this fails soft).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import compile_workload, simulate
from repro.core.compiler.batched_mapper import map_and_simulate
from repro.core.compiler.mapper import UnmappableError
from repro.core.compiler.pipeline import compile_to_table, lower_plan
from repro.core.dse.batch_eval import (batch_evaluate, prepare_configs,
                                       prepare_workload)
from repro.core.dse.encoding import decode, random_genomes
from repro.core.dse.api import EngineConfig
from repro.core.dse.engine import (EngineStats, EvalEngine,
                                   genomes_to_configs, prepared_workload)
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.sweep import evaluate_genomes_reference, run_sweep
from repro.core.simulator.batched import (batch_simulate, stack_chip_configs,
                                          stack_plan_tables)
from repro.core.workloads import build

from .common import csv_row, median_s, save_json, save_repo_json

# one workload per family: CNN / ViT transformer / long-conv / GNN
GA_WORKLOADS = ["resnet50_int8", "vit_b16_int8", "hyena_1_3b", "gnn_gat"]
GA_CFG = GAConfig(population=64, generations=10, seed_top_k=32,
                  early_stop=10_000)  # fixed work: no early stop

# one per execution-path family (the golden-trace set): quantized CNN,
# FP16 ViT, INT4 LLM, SNN (LIF), FFT long-conv, polynomial (KAN)
EXACT_WORKLOADS = ["resnet50_int8", "vit_b16_fp16", "llama7b_int4",
                   "snn_vgg9", "hyena_1_3b", "kan"]


class _ReferenceEngine:
    """The verbatim pre-refactor hot path behind the engine interface:
    per-batch ``prepare_workload(build(w))``, per-genome ``decode``, no
    memoization, no prefilter."""

    def __init__(self, workloads):
        self.workloads = list(workloads)
        self.stats = EngineStats(workloads=len(self.workloads))

    def check_workloads(self, workloads, calib=None):
        assert list(workloads) == self.workloads
        return self

    def evaluate(self, genomes, keep=None):
        t0 = time.perf_counter()
        m = evaluate_genomes_reference(genomes, self.workloads)
        self.stats.requests += len(genomes)
        self.stats.misses += len(genomes)
        self.stats.eval_seconds += time.perf_counter() - t0
        return m


def _ga_run(engine, prefilter: bool, sweep, loop: str = "host",
            cfg: GAConfig = GA_CFG) -> tuple:
    """One GA refinement through ``engine``; returns (seconds, result)."""
    t0 = time.perf_counter()
    res = run_ga(sweep, 200.0, cfg, engine=engine, prefilter=prefilter,
                 loop=loop)
    return time.perf_counter() - t0, res


def run_ga_speedup(repeats: int = 3) -> dict:
    """Engine (cached + vectorized + prefiltered) vs the pre-refactor
    evaluate_genomes path (fresh decode / per-batch workload prep / no
    memoization) on the same seeded GA.  Both sides run the historical
    host generation loop (``loop="host"``) — this benchmark IS the PR-4
    ``ga_engine`` measurement, kept for trajectory continuity; the
    device-loop exact GA is measured by ``run_ga_exact_speedup``.  Each
    engine repeat uses a fresh engine (the sweep memoized untimed,
    mirroring the shared sweep→GA pattern).  Repeats are interleaved
    legacy/engine and min-reduced so both paths sample the same
    machine-load phases — the measured work itself is deterministic."""
    # pre-compile every batch shape either path can emit, so both timed
    # runs are steady-state (jit caches are process-global and one-time)
    setup = EvalEngine(GA_WORKLOADS)
    setup.warmup()
    sweep = run_sweep(GA_WORKLOADS, samples_per_stratum=8, seed=0,
                      brackets=(100.0, 200.0), engine=setup)

    t_leg_all, t_eng_all = [], []
    for _ in range(repeats):
        t, res_legacy = _ga_run(_ReferenceEngine(GA_WORKLOADS), False, sweep)
        t_leg_all.append(t)

        engine = EvalEngine(GA_WORKLOADS)
        engine.evaluate(sweep.genomes)      # untimed, as run_sweep did
        pre = dataclasses.replace(engine.stats)  # GA-only counter deltas
        t, res_engine = _ga_run(engine, True, sweep)
        t_eng_all.append(t)
    t_legacy, t_engine = min(t_leg_all), min(t_eng_all)
    st = engine.stats

    assert res_legacy.best_fitness == res_engine.best_fitness, \
        "cache-aware GA diverged from the reference path"
    hits = st.hits - pre.hits
    misses = st.misses - pre.misses
    requests = st.requests - pre.requests
    pairs = (hits + misses) * st.workloads
    return {
        "ga_population": GA_CFG.population,
        "ga_generations": GA_CFG.generations,
        "ga_workloads": GA_WORKLOADS,
        "legacy_s": t_legacy,
        "engine_s": t_engine,
        "legacy_median_s": median_s(t_leg_all),
        "engine_median_s": median_s(t_eng_all),
        "median_speedup": median_s(t_leg_all) / median_s(t_eng_all),
        "speedup": t_legacy / t_engine,
        "best_fitness": float(res_engine.best_fitness),
        "cache_hit_rate": hits / max(requests, 1),
        "cache_hits": hits,
        "skipped_out_of_bracket": st.skips - pre.skips,
        "simulated": misses,
        "throughput_cfg_wl_per_s":
            pairs / max(st.eval_seconds - pre.eval_seconds, 1e-12),
    }


def run_ga_exact_speedup(repeats: int = 3, population: int = 64,
                         generations: int = 10,
                         workloads=GA_WORKLOADS) -> dict:
    """Device GA loop + exact search backend vs the PR-4 GA path at
    iso-(exact)-fidelity, on the 64-genome benchmark config.

    Baseline: the PR-4 configuration for exact-search GA refinement —
    the host (numpy) generation loop with the engine's ``"batched"``
    exact backend scoring every generation through the two-scan
    ``map_and_simulate`` dispatch with full result materialization.
    New: ``run_ga`` defaults — the jitted device generation loop
    (genetics + canonicalization in one dispatch) scoring through
    ``backend="exact"``, the class-specialized single-scan search
    kernel.  Both sides score bitwise-identical exact (fused-mapper)
    metrics, so the speedup is pure framework.  The PR-4 *approximate*
    search time (host loop + scan backend, the ``ga_engine``
    configuration) is recorded alongside: it shows what the retired
    approximate-search-then-rescore trade used to buy.

    The device GA's exactness is asserted untimed: its best genome's
    search-time Eq. 8 fitness must equal the fitness recomputed from an
    exact ``rescore()`` bit-for-bit.
    """
    from repro.core.dse.ga_device import fitness_device

    cfg = GAConfig(population=population, generations=generations,
                   seed_top_k=min(32, population), early_stop=10_000)
    setup = EvalEngine(workloads)
    setup.warmup()
    sweep = run_sweep(workloads, samples_per_stratum=8, seed=0,
                      brackets=(100.0, 200.0), engine=setup)
    e_homo = sweep.homo_baseline()[200.0]

    def fresh(backend):
        eng = EvalEngine(workloads, config=EngineConfig(backend=backend))
        eng.evaluate(sweep.genomes)   # untimed memo warm (shared sweep→GA)
        return eng

    # untimed warm runs: compile the genetics kernel, the exact search
    # kernel, and every miss-batch shape either loop emits
    _ga_run(fresh("batched"), True, sweep, loop="host", cfg=cfg)
    _, res_dev = _ga_run(fresh("exact"), True, sweep, loop="device", cfg=cfg)

    m_search = EvalEngine(
        workloads, config=EngineConfig(backend="exact")).evaluate(
        res_dev.best_genome[None, :])
    m_rescore = EvalEngine(workloads).rescore(res_dev.best_genome[None, :])
    f_search = fitness_device(m_search, e_homo, 200.0)
    f_rescore = fitness_device(m_rescore, e_homo, 200.0)
    assert np.array_equal(f_search, f_rescore), \
        "exact-search fitness diverged from the exact rescore"

    t_base_all, t_dev_all, t_scan_all = [], [], []
    for _ in range(repeats):
        t, _ = _ga_run(fresh("batched"), True, sweep, loop="host", cfg=cfg)
        t_base_all.append(t)
        t, res_dev = _ga_run(fresh("exact"), True, sweep, loop="device",
                             cfg=cfg)
        t_dev_all.append(t)
        t, _ = _ga_run(fresh("scan"), True, sweep, loop="host", cfg=cfg)
        t_scan_all.append(t)

    med_base, med_dev = median_s(t_base_all), median_s(t_dev_all)
    return {
        "ga_population": population,
        "ga_generations": generations,
        "ga_workloads": list(workloads),
        "pr4_exact_s": min(t_base_all),
        "device_exact_s": min(t_dev_all),
        "pr4_exact_median_s": med_base,
        "device_exact_median_s": med_dev,
        "pr4_scan_median_s": median_s(t_scan_all),
        "median_speedup": med_base / med_dev,
        "speedup": min(t_base_all) / min(t_dev_all),
        "speedup_vs_scan_search": median_s(t_scan_all) / med_dev,
        "best_fitness": float(res_dev.best_fitness),
        "search_equals_rescore": True,   # asserted above
        "target_speedup": 5.0,
        "floor_speedup": 3.0,            # perf-smoke fail-soft floor
        "meets_target": med_base / med_dev >= 5.0,
    }


def run_population_sim_speedup(population: int = 64, repeats: int = 3,
                               workloads=GA_WORKLOADS) -> dict:
    """Batched plan executor vs per-candidate ChipSim on one GA generation.

    Plans are compiled once (outside the timed region — identical input
    for both sides): the timed work is exactly what a cache-missing
    population evaluation costs the simulator core.  Interleaved repeats,
    min-reduced; the batched path is warmed so both sides are
    steady-state."""
    rng = np.random.default_rng(1)
    chips = []
    for i, g in enumerate(random_genomes(rng, population * 2)):
        chips.append(decode(g, f"p{i}"))
        if len(chips) == population:
            break

    per_wl = {}
    compiled = {}
    for wname in workloads:
        g = build(wname)
        pairs = []
        for chip in chips:
            try:
                pairs.append((chip, compile_workload(g, chip)))
            except UnmappableError:
                continue
        if not pairs:
            continue
        tables = stack_plan_tables(
            [lower_plan(p, c.num_tiles) for c, p in pairs])
        cfgs = stack_chip_configs([c for c, _ in pairs])
        compiled[wname] = (pairs, tables, cfgs)
        batch_simulate(tables, cfgs)  # jit warmup, untimed

    for wname, (pairs, tables, cfgs) in compiled.items():
        ref_all, batch_all = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            for chip, plan in pairs:
                simulate(chip, plan)
            ref_all.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            batch_simulate(tables, cfgs)
            batch_all.append(time.perf_counter() - t0)
        per_wl[wname] = {"candidates": len(pairs),
                         "chipsim_s": min(ref_all),
                         "batched_s": min(batch_all),
                         "chipsim_median_s": median_s(ref_all),
                         "batched_median_s": median_s(batch_all),
                         "speedup": min(ref_all) / min(batch_all)}
    total_ref = sum(r["chipsim_s"] for r in per_wl.values())
    total_batch = sum(r["batched_s"] for r in per_wl.values())
    med_ref = sum(r["chipsim_median_s"] for r in per_wl.values())
    med_batch = sum(r["batched_median_s"] for r in per_wl.values())
    return {
        "population": population,
        "workloads": list(workloads),
        "per_workload": per_wl,
        "chipsim_s": total_ref,
        "batched_s": total_batch,
        "chipsim_median_s": med_ref,
        "batched_median_s": med_batch,
        "median_speedup": med_ref / med_batch,
        "speedup": total_ref / total_batch,
        "target_speedup": 5.0,
        # median-based, like exact_path and BENCH_PR3.json ("speedup"
        # stays the min-reduced best case for continuity with PR 2 logs)
        "meets_target": med_ref / med_batch >= 5.0,
    }


def run_exact_path_speedup(population: int = 64, repeats: int = 3,
                           workloads=EXACT_WORKLOADS) -> dict:
    """Compile-free exact path vs per-candidate compile, end-to-end.

    Baseline: the PR 2 exact path — ``compile_to_table`` (deepcopy +
    passes 1-2 + ``map_graph`` + ``lower_plan``) per (workload,
    candidate), stacked and executed by ``batch_simulate``.  New: one
    ``map_and_simulate`` dispatch per workload over the shared prepared
    workload (compile passes hoisted to once-per-workload) — the exact
    backend ``EvalEngine.rescore()`` runs.  Both sides warmed and
    interleaved; metrics asserted bitwise-equal on mappable rows
    (untimed), so the measured speedup is for identical numbers.
    """
    rng = np.random.default_rng(2)
    genomes = random_genomes(rng, population)
    chips = [decode(g, f"e{i}") for i, g in enumerate(genomes)]
    cfgs = genomes_to_configs(genomes)
    graphs = {w: build(w) for w in workloads}
    ws_all = {w: prepared_workload(w) for w in workloads}

    def run_baseline():
        out = {}
        for w in workloads:
            tables, sel = [], []
            for i, chip in enumerate(chips):
                try:
                    tables.append(compile_to_table(graphs[w], chip))
                    sel.append(i)
                except UnmappableError:
                    continue
            if sel:
                out[w] = (sel, batch_simulate(
                    stack_plan_tables(tables),
                    stack_chip_configs([chips[i] for i in sel])))
        return out

    def run_new():
        return {w: map_and_simulate(ws_all[w], cfgs) for w in workloads}

    base_res = run_baseline()   # warms the executor jit per op bucket
    new_res = run_new()         # warms the fused mapper+executor jit
    for w, (sel, ref) in base_res.items():
        ok = np.flatnonzero(new_res[w]["ok"])
        assert ok.tolist() == sel, (w, "mappable-set mismatch")
        assert np.array_equal(new_res[w]["latency_s"][ok],
                              ref["latency_s"]), w
        assert np.array_equal(new_res[w]["energy_pj"][ok],
                              ref["energy_pj"]), w

    base_all, new_all = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_baseline()
        base_all.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_new()
        new_all.append(time.perf_counter() - t0)
    med_base, med_new = median_s(base_all), median_s(new_all)
    return {
        "population": population,
        "workloads": list(workloads),
        "baseline_s": min(base_all),
        "exact_path_s": min(new_all),
        "baseline_median_s": med_base,
        "exact_path_median_s": med_new,
        "median_speedup": med_base / med_new,
        "speedup": min(base_all) / min(new_all),
        "target_speedup": 10.0,
        "meets_target": med_base / med_new >= 10.0,
    }


def run_throughput_exact(population: int = 64, repeats: int = 3,
                         workloads=EXACT_WORKLOADS) -> dict:
    """Throughput-mode exact path on the perf trajectory.

    The fused mapper+executor scan now carries the II state (per-tile
    busy times, DRAM-byte / NoC-second occupancy); this measures the
    dispatch in ``mode="throughput"`` (steady-state surface consumed) so
    a regression in the new scan state shows up as the reported time
    drifting above the latency-mode ``exact_path`` measurement it is
    benched against in BENCH_PR3.json (ratio ~1.0 when the II state is
    free, as intended).  The throughput invariant II <= fill makespan is
    asserted on every mappable row (untimed)."""
    rng = np.random.default_rng(2)  # same genomes as run_exact_path_speedup
    genomes = random_genomes(rng, population)
    cfgs = genomes_to_configs(genomes)
    ws_all = {w: prepared_workload(w) for w in workloads}

    def run_tp():
        return {w: map_and_simulate(ws_all[w], cfgs, mode="throughput")
                for w in workloads}

    res = run_tp()  # jit warmup (shared with the latency-mode dispatch)
    for w, r in res.items():
        ok = r["ok"]
        assert r["mode"] == "throughput"
        assert np.all(r["ii_s"][ok] <= r["latency_s"][ok] * (1 + 1e-12)), \
            (w, "II exceeded the fill makespan")
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_tp()
        times.append(time.perf_counter() - t0)
    return {
        "population": population,
        "workloads": list(workloads),
        "throughput_s": min(times),
        "throughput_median_s": median_s(times),
    }


def run_link_fidelity_overhead(population: int = 64, repeats: int = 3,
                               workloads=EXACT_WORKLOADS) -> dict:
    """What the per-link NoC + per-channel DRAM tier costs over the
    aggregate tier on the exact throughput path.

    Both sides run the identical fused mapper+executor dispatch in
    ``mode="throughput"``; only the II composition differs — the link
    tier folds XY-routed per-link occupancy and per-channel DRAM queues
    into the steady-state bound.  The tier is a jit-cache key, so each
    side is warmed separately (untimed), and the invariant ``II(link) >=
    II(aggregate)`` plus identical mappable sets / latency / energy are
    asserted on every row before timing starts.  Reported as an overhead
    multiplier with a fail-soft ceiling for the perf-smoke job: the link
    tier buys contention fidelity, it must not cost a regime change."""
    rng = np.random.default_rng(2)  # same genomes as run_exact_path_speedup
    genomes = random_genomes(rng, population)
    cfgs = genomes_to_configs(genomes)
    ws_all = {w: prepared_workload(w) for w in workloads}

    def run_fid(fid):
        return {w: map_and_simulate(ws_all[w], cfgs, mode="throughput",
                                    fidelity=fid)
                for w in workloads}

    agg = run_fid("aggregate")   # jit warmup, per fidelity tier
    link = run_fid("link")
    tighter = total = 0
    for w in workloads:
        ok = np.flatnonzero(agg[w]["ok"])
        assert np.array_equal(agg[w]["ok"], link[w]["ok"]), w
        assert np.array_equal(agg[w]["latency_s"][ok],
                              link[w]["latency_s"][ok]), \
            (w, "fidelity tier leaked into the latency surface")
        assert np.array_equal(agg[w]["energy_pj"][ok],
                              link[w]["energy_pj"][ok]), \
            (w, "fidelity tier leaked into the energy surface")
        assert np.all(link[w]["ii_s"][ok] >= agg[w]["ii_s"][ok]), \
            (w, "link-tier II fell below the aggregate bound")
        tighter += int(np.sum(link[w]["ii_s"][ok] > agg[w]["ii_s"][ok]))
        total += len(ok)

    t_agg, t_link = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_fid("aggregate")
        t_agg.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_fid("link")
        t_link.append(time.perf_counter() - t0)
    med_agg, med_link = median_s(t_agg), median_s(t_link)
    return {
        "population": population,
        "workloads": list(workloads),
        "aggregate_s": min(t_agg),
        "link_s": min(t_link),
        "aggregate_median_s": med_agg,
        "link_median_s": med_link,
        "overhead_x": med_link / max(med_agg, 1e-12),
        "frac_ii_tightened": tighter / max(total, 1),
        "ii_dominates": True,            # asserted above
        "max_overhead_x": 3.5,           # perf-smoke fail-soft ceiling
        "within_budget": med_link / max(med_agg, 1e-12) <= 3.5,
    }


def run_service_coalescing(population: int = 32, generations: int = 6,
                           workloads=("kan", "resnet50_int8"),
                           seeds=(0, 1), max_wait_ms: float = 100.0,
                           max_batch: int = 256) -> dict:
    """Cross-tenant coalescing through the DSE evaluation service vs the
    same tenants run back-to-back on private local exact engines.

    Baseline: each seed's GA refinement on its own fresh
    ``EvalEngine(backend="exact")``, sequential — wall times and engine
    dispatch counts summed.  Service side: one shared exact engine behind
    a ``DSEService`` (memory-LRU over a persistent sqlite store), the
    same seeds as concurrent client threads.  Identical seeds share their
    sweep-derived seed populations and the elites they converge to, so
    the continuous-batching loop both coalesces the tenants into fused
    micro-batches and serves repeats from the store — the win is
    dispatch elimination, measured alongside the wall-clock ratio.  A
    warm rerun against the same sqlite file reports the persistent-store
    hit rate a fresh service starts with.  Results are checked bitwise
    against the local baseline (the fused metrics are batch-composition
    independent, so coalescing is fidelity-free)."""
    import os
    import tempfile
    import threading

    from repro.core.dse.store import (MemoryLRUStore, SqliteStore,
                                      TieredStore)
    from repro.serve.dse_service import DSEClient, DSEService

    workloads = list(workloads)
    bracket = 200.0
    cfg = GAConfig(population=population, generations=generations,
                   seed_top_k=min(16, population), early_stop=10_000)
    sweep = run_sweep(workloads, samples_per_stratum=4, seed=0,
                      brackets=(100.0, bracket),
                      engine=EvalEngine(workloads,
                                        config=EngineConfig(backend="exact")))

    # ---- baseline: sequential tenants on private local engines ----------
    local, local_wall, local_dispatches = {}, 0.0, 0
    for s in seeds:
        eng = EvalEngine(workloads, config=EngineConfig(backend="exact"))
        t0 = time.perf_counter()
        local[s] = run_ga(sweep, bracket, cfg, seed=s, engine=eng)
        local_wall += time.perf_counter() - t0
        local_dispatches += eng.stats.dispatches

    # ---- service: concurrent tenants on one shared engine + store -------
    db = os.path.join(tempfile.mkdtemp(prefix="mosaic_bench_store_"),
                      "results.sqlite")

    def serve(run_seeds):
        eng = EvalEngine(workloads, config=EngineConfig(
            backend="exact", store=TieredStore(MemoryLRUStore(),
                                               SqliteStore(db))))
        svc = DSEService(eng, max_batch=max_batch, max_wait_ms=max_wait_ms)
        svc.start()
        try:
            out, errs = {}, []

            def tenant(s):
                try:
                    out[s] = run_ga(sweep, bracket, cfg, seed=s,
                                    engine=DSEClient(service=svc))
                except Exception as e:  # pragma: no cover - surfaced below
                    errs.append(e)

            threads = [threading.Thread(target=tenant, args=(s,))
                       for s in run_seeds]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
            if errs:
                raise errs[0]
            return out, wall, svc.stats.snapshot(max_batch)
        finally:
            svc.stop()

    served, service_wall, st = serve(seeds)
    parity = all(
        served[s].best_fitness == local[s].best_fitness
        and np.array_equal(served[s].best_genome, local[s].best_genome)
        for s in seeds)

    # warm rerun: a fresh service over the same sqlite file should answer
    # mostly from the persistent store
    warm, _, warm_st = serve(seeds[:1])
    warm_rate = warm_st["store_hits"] / max(warm_st["request_genomes"], 1)
    parity &= warm[seeds[0]].best_fitness == local[seeds[0]].best_fitness

    return {
        "population": population,
        "generations": generations,
        "workloads": workloads,
        "tenants": len(seeds),
        "local_wall_s": local_wall,
        "service_wall_s": service_wall,
        "local_dispatches": local_dispatches,
        "service_dispatches": st["engine_dispatches"],
        "dispatch_reduction": 1.0 - st["engine_dispatches"]
        / max(local_dispatches, 1),
        "batches": st["batches"],
        "coalesced_batches": st["coalesced_batches"],
        "batch_occupancy": st["batch_occupancy"],
        "mean_queue_ms": st["mean_queue_ms"],
        "store_hit_rate": st["store_hits"] / max(st["request_genomes"], 1),
        "warm_store_hit_rate": warm_rate,
        "bitwise_parity": bool(parity),
    }


def run_pipeline_speedup(population: int = 4096, generations: int = 6,
                         brackets=(100.0, 200.0), workloads=("kan",),
                         repeats: int = 3, seed: int = 0) -> dict:
    """The fused refinement path (device-resident memo, whole GA run as
    one dispatch — the §4 pipeline's Stage 2) vs the per-generation
    host-memo configuration at the same population.

    Baseline: ``run_ga(loop="device")`` on a ``backend="exact"`` engine
    — the PR-5 path, whose every generation round-trips the host store
    and scores the misses as a *data-dependent-shaped* batch: the padded
    miss-batch size differs nearly every generation and every seed, and
    each previously unseen shape recompiles the search kernel (~2 s), so
    a multi-seed study keeps paying a per-generation compile cascade
    that NEVER amortizes across seeds (measured: a fresh-seed study in a
    jit-warm process costs the same ~50 s as the first one).  New: the
    pipeline's refine stage — ``memo_from_store`` once, then one
    fixed-shape ``run_ga_fused`` dispatch per bracket threading the
    device memo, ``drain_to_store`` once (all timed); the fused kernel's
    shapes depend only on (P, W), so it compiles once per study shape,
    ever.

    Each timed repeat therefore runs BOTH sides at a seed this process
    has never executed — the §4 multi-seed pipeline's actual regime
    (stratified seeds -> per-seed refinement), not a same-seed replay
    that would credit the baseline with shape reuse it never gets in
    real use.  Both sides seed from the same sweep, share their memo
    state across brackets, and run a single island, so their genome
    streams are bitwise identical (asserted untimed at the warm-up
    seed)."""
    from repro.core.dse.device_memo import drain_to_store, memo_from_store
    from repro.core.dse.ga_device import run_ga_fused

    workloads = list(workloads)
    cfg = GAConfig(population=population, generations=generations,
                   seed_top_k=min(64, population), early_stop=10_000)
    setup = EvalEngine(workloads, config=EngineConfig(backend="exact"))
    sweep = run_sweep(workloads, samples_per_stratum=8, seed=seed,
                      brackets=tuple(brackets), engine=setup)

    def fresh():
        eng = EvalEngine(workloads, config=EngineConfig(backend="exact"))
        eng.evaluate(sweep.genomes)   # untimed memo warm (shared sweep->GA)
        return eng

    def run_baseline(eng, s):
        return [run_ga(sweep, b, cfg, seed=s, engine=eng, loop="device")
                for b in brackets]

    def run_fused(eng, s):
        memo = memo_from_store(eng, 1 << 17)
        out = []
        for b in brackets:
            f = run_ga_fused(sweep, b, cfg, seed=s, engine=eng,
                             islands=1, memo=memo, store_sync=False)
            memo = f.memo
            out.append(f.result)
        drain_to_store(memo, eng)
        return out

    # untimed warm runs at the base seed: compile the seed-independent
    # kernels (genetics, fused refinement, the baseline's first shapes),
    # and pin the bitwise invariant while we are at it
    res_base = run_baseline(fresh(), seed)
    res_fused = run_fused(fresh(), seed)
    parity = all(
        np.array_equal(a.best_genome, b.best_genome)
        and a.history == b.history
        for a, b in zip(res_base, res_fused))
    assert parity, "fused refinement diverged from the host-memo loop"

    t_base_all, t_fused_all = [], []
    for r in range(repeats):
        s = seed + 1 + r        # a seed this process has never run
        eng = fresh()
        t0 = time.perf_counter()
        run_baseline(eng, s)
        t_base_all.append(time.perf_counter() - t0)
        eng = fresh()
        t0 = time.perf_counter()
        run_fused(eng, s)
        t_fused_all.append(time.perf_counter() - t0)

    med_base, med_fused = median_s(t_base_all), median_s(t_fused_all)
    return {
        "population": population,
        "generations": generations,
        "brackets": list(brackets),
        "workloads": workloads,
        "host_memo_s": min(t_base_all),
        "fused_s": min(t_fused_all),
        "host_memo_median_s": med_base,
        "fused_median_s": med_fused,
        "median_speedup": med_base / med_fused,
        "speedup": min(t_base_all) / min(t_fused_all),
        "bitwise_parity": True,          # asserted above
        "target_speedup": 3.0,
        "floor_speedup": 1.5,            # perf-smoke fail-soft floor
        "meets_target": med_base / med_fused >= 3.0,
    }


def run_checkpoint_overhead(population: int = 256, generations: int = 4,
                            brackets=(100.0, 200.0), workloads=("kan",),
                            seeds=(0, 1), samples_per_stratum: int = 8,
                            repeats: int = 2) -> dict:
    """What durability costs: ``run_pipeline`` with per-stage checkpoints
    (atomic npz records + memo drain per stage, PR 8) vs the same study
    without, plus the resume-replay path (rerunning a *completed*
    checkpoint directory: every stage served from its record, no
    simulation).  Informational — no smoke floor: the overhead is pure
    stage-boundary I/O and should stay in the low single-digit percents,
    while the replay speedup shows what a crash-resume actually saves.

    Both sides get a fresh in-memory exact engine per run (the
    checkpointed side is NOT given the directory-backed sqlite store, so
    the measured delta is the checkpoint protocol itself, not a
    store-backend swap).  Bitwise parity between the plain and
    checkpointed studies is asserted untimed before timing starts."""
    import shutil
    import tempfile

    from repro.core.dse.pipeline import run_pipeline

    workloads = list(workloads)
    cfg = GAConfig(population=population, generations=generations,
                   seed_top_k=min(64, population), early_stop=10_000)
    kw = dict(seeds=tuple(seeds), brackets=tuple(brackets),
              samples_per_stratum=samples_per_stratum, cfg=cfg)

    def fresh():
        return EvalEngine(workloads, config=EngineConfig(backend="exact"))

    def run_plain():
        return run_pipeline(workloads, engine=fresh(), **kw)

    def run_ckpt(cdir):
        return run_pipeline(workloads, engine=fresh(), checkpoint=cdir, **kw)

    # untimed warm (compiles the study's kernels) + the parity invariant
    ref = run_plain()
    warm_dir = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        ck = run_ckpt(warm_dir)
        parity = (ref.front_points.tobytes() == ck.front_points.tobytes()
                  and ref.front_genomes.tobytes() == ck.front_genomes.tobytes()
                  and ref.evaluated == ck.evaluated)
        assert parity, "checkpointed pipeline diverged from the plain run"
    finally:
        shutil.rmtree(warm_dir, ignore_errors=True)

    t_plain, t_ckpt, t_replay = [], [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_plain()
        t_plain.append(time.perf_counter() - t0)
        cdir = tempfile.mkdtemp(prefix="bench_ckpt_")
        try:
            t0 = time.perf_counter()
            run_ckpt(cdir)
            t_ckpt.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            run_ckpt(cdir)       # completed dir: pure record replay
            t_replay.append(time.perf_counter() - t0)
        finally:
            shutil.rmtree(cdir, ignore_errors=True)

    med_plain = median_s(t_plain)
    med_ckpt = median_s(t_ckpt)
    med_replay = median_s(t_replay)
    return {
        "population": population,
        "generations": generations,
        "seeds": list(seeds),
        "brackets": list(brackets),
        "workloads": workloads,
        "plain_median_s": med_plain,
        "checkpointed_median_s": med_ckpt,
        "replay_median_s": med_replay,
        "overhead_frac": med_ckpt / max(med_plain, 1e-12) - 1.0,
        "replay_speedup": med_plain / max(med_replay, 1e-12),
        "bitwise_parity": True,          # asserted above
    }


def run_cluster_scaling(workers=(1, 2, 3), batches: int = 12,
                        population: int = 96,
                        worker_ms_per_genome: float = 1.0,
                        repeats: int = 3, kill_at_shard: int = 15,
                        workloads=("kan",)) -> dict:
    """Coordinator dispatch-throughput scaling across 1-N ``DSEService``
    workers behind a ``DSECluster`` (PR 10), plus the recovery overhead
    of losing a worker mid-stream.

    The CI container is single-core, so local simulation cannot speed up
    with more worker *processes or threads* — what this benchmark
    isolates is the coordinator: can ``DSECluster`` keep N workers busy
    concurrently?  Worker service time is therefore **emulated**: each
    worker engine sleeps ``worker_ms_per_genome`` per dispatched genome
    (a GIL-releasing stand-in for the compute a remote worker host would
    perform off-box) on top of its real simulation.  With one worker
    the emulated service times serialize; with N they overlap iff the
    coordinator shards, dispatches, and collects concurrently — so
    dispatch throughput (genomes/s over a stream of fresh micro-batches)
    scales with N exactly as a multi-host deployment's would, and the
    coordinator's own sharding/assembly cost is what bounds it.

    Recovery: the same 3-worker stream re-runs with a deterministic
    ``worker_kill`` mid-stream (the killed service stops for real); the
    wall-clock delta over the unfaulted 3-worker run is the recovery
    overhead.  Bitwise parity of every returned metric row across ALL
    configurations (1w / Nw / Nw-faulted) is asserted untimed —
    worker loss must never change the study's bytes."""
    from repro.core.dse.faults import FaultInjector
    from repro.serve.cluster import DSECluster
    from repro.serve.dse_service import DSEService

    workloads = list(workloads)
    n_workers = sorted(set(int(w) for w in workers))
    rng = np.random.default_rng(42)
    stream = [random_genomes(rng, population) for _ in range(batches)]

    def _laggy(engine):
        inner = engine._simulate

        def _simulate(cfgs, n, genomes=None, mode=None):
            time.sleep(worker_ms_per_genome * 1e-3 * n)
            return inner(cfgs, n, genomes=genomes, mode=mode)

        engine._simulate = _simulate
        return engine

    def run_once(n: int, injector=None):
        svcs = [DSEService(_laggy(EvalEngine(workloads)), max_batch=512,
                           max_wait_ms=2.0, worker_id=f"bench-w{i}").start()
                for i in range(n)]
        cluster = DSECluster(svcs, fault_injector=injector, backoff_s=0.01)
        try:
            cluster.reserve_shapes(population)   # compile untimed
            t0 = time.perf_counter()
            rows = [cluster.evaluate(g) for g in stream]
            wall = time.perf_counter() - t0
            lat = np.concatenate([r["latency"] for r in rows])
            return wall, lat.tobytes(), cluster.cluster_stats.snapshot()
        finally:
            cluster.close()
            for s in svcs:
                s.stop(drain=False)

    # untimed compile warm (the in-process JIT cache is shared) + parity ref
    _, ref_bytes, _ = run_once(1)

    walls: dict = {}
    parity = True
    for n in n_workers:
        times = []
        for _ in range(repeats):
            wall, got, _ = run_once(n)
            parity = parity and (got == ref_bytes)
            times.append(wall)
        walls[str(n)] = median_s(times)

    # recovery: kill one of 3 workers mid-stream (shard counter is
    # deterministic: 3 shards form per batch until the kill)
    n_rec = max(n_workers)
    rec_times, rec_stats = [], None
    for _ in range(repeats):
        inj = FaultInjector(seed=0, at={"worker_kill": (kill_at_shard,)})
        wall, got, rec_stats = run_once(n_rec, injector=inj)
        parity = parity and (got == ref_bytes)
        rec_times.append(wall)
    rec_wall = median_s(rec_times)

    assert parity, "cluster-served metrics diverged across worker counts"
    genomes_total = batches * population
    base = walls[str(n_workers[0])]
    top = walls[str(max(n_workers))]
    return {
        "workers": n_workers,
        "batches": batches,
        "population": population,
        "worker_ms_per_genome": worker_ms_per_genome,
        "emulated_workers": True,    # single-core CI: see docstring
        "workloads": workloads,
        "wall_s": walls,
        "throughput_genomes_s": {k: genomes_total / max(v, 1e-12)
                                 for k, v in walls.items()},
        "scaling_max_workers": base / max(top, 1e-12),
        "recovery_wall_s": rec_wall,
        "recovery_overhead_frac": rec_wall / max(top, 1e-12) - 1.0,
        "recovery_stats": rec_stats,
        "target_scaling": 1.5,
        "meets_target": base / max(top, 1e-12) >= 1.5,
        "bitwise_parity": True,      # asserted above
    }


def _bench_entry(median: float, baseline_median: float, **extra) -> dict:
    """One trajectory-file benchmark record: median seconds + speedup."""
    return {"median_s": median, "baseline_median_s": baseline_median,
            "speedup": baseline_median / max(median, 1e-12), **extra}


def write_bench_pr5(payload: dict, smoke: bool) -> str:
    """Distill the perf_micro payload into the cross-PR trajectory file
    ``BENCH_PR5.json`` at the repo root (the committed ``BENCH_PR3.json``
    stays as the PR-4 baseline ``perf_compare`` falls back to).  Smoke
    runs write ``BENCH_PR5_smoke.json`` instead (gitignored) so a local
    or CI smoke pass never clobbers the committed full-population
    numbers."""
    ep = payload["exact_path"]
    bench = {
        "pr": 5,
        "smoke": smoke,
        "generated_unix": time.time(),
        "benchmarks": {
            "exact_path": _bench_entry(
                ep["exact_path_median_s"], ep["baseline_median_s"],
                population=ep["population"],
                workloads=ep["workloads"],
                target_speedup=ep["target_speedup"],
                meets_target=ep["meets_target"]),
        },
    }
    if "exact_path_throughput" in payload:
        tp = payload["exact_path_throughput"]
        # baseline = the latency-mode fused dispatch: speedup ~1.0 means
        # the II scan state costs nothing on the exact path
        bench["benchmarks"]["exact_path_throughput"] = _bench_entry(
            tp["throughput_median_s"], ep["exact_path_median_s"],
            population=tp["population"], workloads=tp["workloads"],
            mode="throughput")
    if "population_sim" in payload:
        ps = payload["population_sim"]
        bench["benchmarks"]["population_sim"] = _bench_entry(
            ps["batched_median_s"], ps["chipsim_median_s"],
            population=ps["population"], target_speedup=ps["target_speedup"])
    if "ga_engine" in payload:
        ga = payload["ga_engine"]
        bench["benchmarks"]["ga_engine"] = _bench_entry(
            ga["engine_median_s"], ga["legacy_median_s"],
            cache_hit_rate=ga["cache_hit_rate"])
    if "ga_exact" in payload:
        gx = payload["ga_exact"]
        # baseline = the PR-4 exact-search configuration (host loop +
        # two-scan batched backend): iso-fidelity, pure framework win
        bench["benchmarks"]["run_ga_exact_speedup"] = _bench_entry(
            gx["device_exact_median_s"], gx["pr4_exact_median_s"],
            population=gx["ga_population"],
            generations=gx["ga_generations"],
            workloads=gx["ga_workloads"],
            pr4_scan_median_s=gx["pr4_scan_median_s"],
            speedup_vs_scan_search=gx["speedup_vs_scan_search"],
            search_equals_rescore=gx["search_equals_rescore"],
            target_speedup=gx["target_speedup"],
            floor_speedup=gx["floor_speedup"],
            meets_target=gx["meets_target"])
    if "batch_us_per_config" in payload:
        bench["benchmarks"]["batch_eval"] = _bench_entry(
            payload["batch_us_per_config"] * 1e-6,
            payload["reference_us_per_config"] * 1e-6,
            per="config")
    return save_repo_json(
        "BENCH_PR5_smoke.json" if smoke else "BENCH_PR5.json", bench)


def write_bench_pr6(payload: dict, smoke: bool) -> str:
    """Distill the service benchmark into the PR-6 trajectory file
    ``BENCH_PR6.json`` at the repo root (``perf_compare`` merges every
    ``BENCH_PR*.json`` newest-entry-per-benchmark, so the PR-5/PR-3
    files keep carrying the benchmarks this one doesn't).  Smoke runs
    write the gitignored ``BENCH_PR6_smoke.json`` instead."""
    sc = payload["service_coalescing"]
    bench = {
        "pr": 6,
        "smoke": smoke,
        "generated_unix": time.time(),
        "benchmarks": {
            # baseline = the same tenants run sequentially on private
            # local exact engines; the speedup is wall-clock, the
            # coalescing/dedup win shows up as the dispatch reduction
            "run_service_coalescing": _bench_entry(
                sc["service_wall_s"], sc["local_wall_s"],
                population=sc["population"],
                generations=sc["generations"],
                workloads=sc["workloads"],
                tenants=sc["tenants"],
                local_dispatches=sc["local_dispatches"],
                service_dispatches=sc["service_dispatches"],
                dispatch_reduction=sc["dispatch_reduction"],
                coalesced_batches=sc["coalesced_batches"],
                batch_occupancy=sc["batch_occupancy"],
                warm_store_hit_rate=sc["warm_store_hit_rate"],
                bitwise_parity=sc["bitwise_parity"]),
        },
    }
    return save_repo_json(
        "BENCH_PR6_smoke.json" if smoke else "BENCH_PR6.json", bench)


def write_bench_pr7(payload: dict, smoke: bool) -> str:
    """Distill the fused-pipeline benchmark into the PR-7 trajectory
    file ``BENCH_PR7.json`` at the repo root (``perf_compare`` keeps
    merging the earlier ``BENCH_PR*.json`` files for the benchmarks
    this one doesn't carry).  Smoke runs write the gitignored
    ``BENCH_PR7_smoke.json`` instead."""
    pp = payload["pipeline"]
    bench = {
        "pr": 7,
        "smoke": smoke,
        "generated_unix": time.time(),
        "benchmarks": {
            # baseline = the same refinement sequence through the
            # per-generation host-memo loop (run_ga loop="device");
            # bitwise-identical genome streams, so the speedup is pure
            # host-round-trip elimination
            "run_pipeline_speedup": _bench_entry(
                pp["fused_median_s"], pp["host_memo_median_s"],
                population=pp["population"],
                generations=pp["generations"],
                brackets=pp["brackets"],
                workloads=pp["workloads"],
                bitwise_parity=pp["bitwise_parity"],
                target_speedup=pp["target_speedup"],
                floor_speedup=pp["floor_speedup"],
                meets_target=pp["meets_target"]),
        },
    }
    return save_repo_json(
        "BENCH_PR7_smoke.json" if smoke else "BENCH_PR7.json", bench)


def write_bench_pr8(payload: dict, smoke: bool) -> str:
    """Distill the checkpoint-overhead benchmark into the PR-8
    trajectory file ``BENCH_PR8.json`` at the repo root (``perf_compare``
    keeps merging the earlier ``BENCH_PR*.json`` files for the
    benchmarks this one doesn't carry).  Smoke runs write the gitignored
    ``BENCH_PR8_smoke.json`` instead."""
    cp = payload["checkpoint"]
    bench = {
        "pr": 8,
        "smoke": smoke,
        "generated_unix": time.time(),
        "benchmarks": {
            # baseline = the same study without checkpoints; speedup
            # below 1.0 IS the durability overhead (informational — the
            # replay_speedup field records what a crash-resume saves)
            "run_checkpoint_overhead": _bench_entry(
                cp["checkpointed_median_s"], cp["plain_median_s"],
                population=cp["population"],
                generations=cp["generations"],
                seeds=cp["seeds"],
                brackets=cp["brackets"],
                workloads=cp["workloads"],
                overhead_frac=cp["overhead_frac"],
                replay_median_s=cp["replay_median_s"],
                replay_speedup=cp["replay_speedup"],
                bitwise_parity=cp["bitwise_parity"]),
        },
    }
    return save_repo_json(
        "BENCH_PR8_smoke.json" if smoke else "BENCH_PR8.json", bench)


def write_bench_pr10(payload: dict, smoke: bool) -> str:
    """Distill the cluster-scaling benchmark into the PR-10 trajectory
    file ``BENCH_PR10.json`` at the repo root (``perf_compare`` keeps
    merging the earlier ``BENCH_PR*.json`` files for the benchmarks this
    one doesn't carry).  Smoke runs write the gitignored
    ``BENCH_PR10_smoke.json`` instead."""
    cs = payload["cluster_scaling"]
    top = str(max(cs["workers"]))
    bench = {
        "pr": 10,
        "smoke": smoke,
        "generated_unix": time.time(),
        "benchmarks": {
            # baseline = the 1-worker cluster on the identical stream;
            # speedup IS the dispatch-throughput scaling at max workers
            # (worker service time emulated: single-core CI, see
            # run_cluster_scaling)
            "run_cluster_scaling": _bench_entry(
                cs["wall_s"][top], cs["wall_s"][str(cs["workers"][0])],
                workers=cs["workers"],
                batches=cs["batches"],
                population=cs["population"],
                workloads=cs["workloads"],
                worker_ms_per_genome=cs["worker_ms_per_genome"],
                emulated_workers=cs["emulated_workers"],
                throughput_genomes_s=cs["throughput_genomes_s"],
                recovery_overhead_frac=cs["recovery_overhead_frac"],
                target_scaling=cs["target_scaling"],
                meets_target=cs["meets_target"],
                bitwise_parity=cs["bitwise_parity"]),
        },
    }
    return save_repo_json(
        "BENCH_PR10_smoke.json" if smoke else "BENCH_PR10.json", bench)


def write_bench_pr9(payload: dict, smoke: bool) -> str:
    """Distill the link-fidelity benchmark into the PR-9 trajectory file
    ``BENCH_PR9.json`` at the repo root (``perf_compare`` keeps merging
    the earlier ``BENCH_PR*.json`` files for the benchmarks this one
    doesn't carry).  Smoke runs write the gitignored
    ``BENCH_PR9_smoke.json`` instead."""
    lf = payload["link_fidelity"]
    bench = {
        "pr": 9,
        "smoke": smoke,
        "generated_unix": time.time(),
        "benchmarks": {
            # baseline = the aggregate tier on the identical dispatch;
            # "speedup" below 1.0 IS the contention-fidelity overhead
            # (bounded fail-soft by max_overhead_x in perf-smoke)
            "run_link_fidelity_overhead": _bench_entry(
                lf["link_median_s"], lf["aggregate_median_s"],
                population=lf["population"],
                workloads=lf["workloads"],
                overhead_x=lf["overhead_x"],
                frac_ii_tightened=lf["frac_ii_tightened"],
                ii_dominates=lf["ii_dominates"],
                max_overhead_x=lf["max_overhead_x"],
                within_budget=lf["within_budget"]),
        },
    }
    return save_repo_json(
        "BENCH_PR9_smoke.json" if smoke else "BENCH_PR9.json", bench)


def run(smoke: bool = False) -> dict:
    """Full microbenchmark suite; ``smoke=True`` runs small-population
    exact-path + exact-GA checks (the non-blocking CI perf-smoke job:
    fails soft below the 5x exact-path / 3x exact-GA floors)."""
    if smoke:
        payload = {
            "exact_path": run_exact_path_speedup(
                population=16, repeats=2,
                workloads=["kan", "resnet50_int8"]),
            "exact_path_throughput": run_throughput_exact(
                population=16, repeats=2,
                workloads=["kan", "resnet50_int8"]),
            # population 16 is too small to smoke-test the device loop
            # (the pad-16 dispatch floor swallows both sides); 32 x 8
            # keeps the run CI-sized while the measured work dominates
            "ga_exact": run_ga_exact_speedup(
                repeats=3, population=32, generations=8,
                workloads=["kan", "resnet50_int8"]),
            "link_fidelity": run_link_fidelity_overhead(
                population=16, repeats=2,
                workloads=["kan", "resnet50_int8"]),
            "service_coalescing": run_service_coalescing(
                population=16, generations=4),
            # small population: the host loop's per-genome Python work
            # shrinks with P, so the smoke floor is the fail-soft 1.5x
            "pipeline": run_pipeline_speedup(
                population=256, generations=4, repeats=2),
            # informational: per-stage durability cost + replay win
            "checkpoint": run_checkpoint_overhead(
                population=128, generations=3, repeats=2),
            "cluster_scaling": run_cluster_scaling(
                batches=6, population=48, repeats=2),
        }
        write_bench_pr5(payload, smoke=True)
        write_bench_pr6(payload, smoke=True)
        write_bench_pr7(payload, smoke=True)
        write_bench_pr8(payload, smoke=True)
        write_bench_pr9(payload, smoke=True)
        write_bench_pr10(payload, smoke=True)
        save_json("perf_micro_smoke", payload)
        return payload

    rng = np.random.default_rng(0)
    chips = [decode(g, f"d{i}") for i, g in enumerate(random_genomes(rng, 256))]
    g = build("resnet50_int8")
    ws = prepare_workload(g)
    cfgs = prepare_configs(chips)
    batch_evaluate(ws, cfgs)  # compile
    t0 = time.perf_counter()
    batch_evaluate(ws, cfgs)
    t_batch = (time.perf_counter() - t0) / len(chips)

    t0 = time.perf_counter()
    n_ref = 8
    for chip in chips[:n_ref]:
        try:
            simulate(chip, compile_workload(g, chip))
        except Exception:
            pass
    t_ref = (time.perf_counter() - t0) / n_ref

    payload = {
        "batch_us_per_config": t_batch * 1e6,
        "reference_us_per_config": t_ref * 1e6,
        "speedup": t_ref / t_batch,
        "workload": "resnet50_int8",
        "batch_size": len(chips),
        # ga_exact runs before the legacy-path benchmarks: its baseline
        # is timing-sensitive to the jit/cache pressure they leave behind
        "ga_exact": run_ga_exact_speedup(repeats=5),
        "ga_engine": run_ga_speedup(),
        "population_sim": run_population_sim_speedup(),
        "exact_path": run_exact_path_speedup(),
        "exact_path_throughput": run_throughput_exact(),
        "link_fidelity": run_link_fidelity_overhead(),
        "service_coalescing": run_service_coalescing(),
        "pipeline": run_pipeline_speedup(),
        "checkpoint": run_checkpoint_overhead(),
        "cluster_scaling": run_cluster_scaling(),
    }
    save_json("perf_micro", payload)
    write_bench_pr5(payload, smoke=False)
    write_bench_pr6(payload, smoke=False)
    write_bench_pr7(payload, smoke=False)
    write_bench_pr8(payload, smoke=False)
    write_bench_pr9(payload, smoke=False)
    write_bench_pr10(payload, smoke=False)
    return payload


def main(smoke: bool = False) -> list:
    return _csv_rows(run(smoke=smoke), smoke)


def _csv_rows(p: dict, smoke: bool = False) -> list:
    ep = p["exact_path"]
    rows = [csv_row("perf_exact_path", ep["exact_path_s"],
                    f"vs_compile_per_candidate="
                    f"{ep['median_speedup']:.1f}x_faster "
                    f"pop={ep['population']} "
                    f"target_10x={'met' if ep['meets_target'] else 'MISSED'}")]
    if "exact_path_throughput" in p:
        tp = p["exact_path_throughput"]
        ratio = ep["exact_path_median_s"] / max(tp["throughput_median_s"],
                                                1e-12)
        rows.append(csv_row(
            "perf_exact_path_throughput", tp["throughput_s"],
            f"vs_latency_mode_dispatch={ratio:.2f}x "
            f"pop={tp['population']}"))
    if "ga_exact" in p:
        gx = p["ga_exact"]
        rows.append(csv_row(
            "perf_ga_exact", gx["device_exact_s"],
            f"vs_pr4_exact_search={gx['median_speedup']:.1f}x_faster "
            f"vs_pr4_approx_search={gx['speedup_vs_scan_search']:.1f}x "
            f"pop={gx['ga_population']} "
            f"target_5x={'met' if gx['meets_target'] else 'MISSED'}"))
    if "link_fidelity" in p:
        lf = p["link_fidelity"]
        rows.append(csv_row(
            "perf_link_fidelity", lf["link_s"],
            f"vs_aggregate_tier={lf['overhead_x']:.2f}x_cost "
            f"pop={lf['population']} "
            f"ii_tightened={lf['frac_ii_tightened']:.0%} "
            f"budget_3p5x={'met' if lf['within_budget'] else 'MISSED'}"))
    if "service_coalescing" in p:
        sc = p["service_coalescing"]
        rows.append(csv_row(
            "perf_service_coalescing", sc["service_wall_s"],
            f"vs_sequential_local="
            f"{sc['local_wall_s'] / max(sc['service_wall_s'], 1e-12):.2f}x "
            f"dispatches={sc['service_dispatches']}/"
            f"{sc['local_dispatches']} "
            f"warm_hit_rate={sc['warm_store_hit_rate']:.0%} "
            f"parity={'ok' if sc['bitwise_parity'] else 'BROKEN'}"))
    if "pipeline" in p:
        pp = p["pipeline"]
        rows.append(csv_row(
            "perf_pipeline", pp["fused_s"],
            f"vs_host_memo_loop={pp['median_speedup']:.1f}x_faster "
            f"pop={pp['population']} "
            f"parity={'ok' if pp['bitwise_parity'] else 'BROKEN'} "
            f"target_3x={'met' if pp['meets_target'] else 'MISSED'}"))
    if "checkpoint" in p:
        cp = p["checkpoint"]
        rows.append(csv_row(
            "perf_checkpoint_overhead", cp["checkpointed_median_s"],
            f"vs_plain_pipeline={100 * cp['overhead_frac']:+.1f}% "
            f"replay={cp['replay_speedup']:.1f}x_faster "
            f"pop={cp['population']} "
            f"parity={'ok' if cp['bitwise_parity'] else 'BROKEN'}"))
    if "cluster_scaling" in p:
        cs = p["cluster_scaling"]
        top = str(max(cs["workers"]))
        rows.append(csv_row(
            "perf_cluster_scaling", cs["wall_s"][top],
            f"dispatch_scaling_{top}w={cs['scaling_max_workers']:.2f}x "
            f"recovery_overhead={100 * cs['recovery_overhead_frac']:+.1f}% "
            f"pop={cs['population']}x{cs['batches']} "
            f"parity={'ok' if cs['bitwise_parity'] else 'BROKEN'} "
            f"target_1p5x={'met' if cs['meets_target'] else 'MISSED'}"))
    if smoke:
        return rows
    ga = p["ga_engine"]
    ps = p["population_sim"]
    return rows + [
        csv_row("perf_batch_eval", p["batch_us_per_config"],
                f"vs_reference={p['speedup']:.0f}x_faster"),
        csv_row("perf_reference_sim", p["reference_us_per_config"],
                "python_oracle"),
        csv_row("perf_ga_engine", ga["engine_s"],
                f"vs_legacy={ga['speedup']:.2f}x_faster "
                f"hit_rate={ga['cache_hit_rate']:.0%} "
                f"throughput={ga['throughput_cfg_wl_per_s']:.0f}cfg_wl_s"),
        csv_row("perf_population_sim", ps["batched_s"],
                f"vs_chipsim={ps['median_speedup']:.1f}x_faster "
                f"pop={ps['population']} "
                f"target_5x={'met' if ps['meets_target'] else 'MISSED'}")]


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small-population exact-path check only; exit 1 "
                         "when the speedup drops below 5x (CI fails soft)")
    ap.add_argument("--service", action="store_true",
                    help="run only the service-coalescing benchmark and "
                         "write BENCH_PR6.json (full-suite benchmarks stay "
                         "carried by the earlier BENCH_PR*.json files)")
    ap.add_argument("--link-fidelity", action="store_true",
                    help="run only the link-fidelity overhead benchmark "
                         "and write BENCH_PR9.json (full-suite benchmarks "
                         "stay carried by the earlier BENCH_PR*.json files)")
    ap.add_argument("--pipeline", action="store_true",
                    help="run only the fused-pipeline benchmark and write "
                         "BENCH_PR7.json (full-suite benchmarks stay "
                         "carried by the earlier BENCH_PR*.json files)")
    ap.add_argument("--checkpoint", action="store_true",
                    help="run only the checkpoint-overhead benchmark and "
                         "write BENCH_PR8.json (full-suite benchmarks stay "
                         "carried by the earlier BENCH_PR*.json files)")
    ap.add_argument("--cluster", action="store_true",
                    help="run only the cluster-scaling benchmark and write "
                         "BENCH_PR10.json (full-suite benchmarks stay "
                         "carried by the earlier BENCH_PR*.json files); "
                         "exit 1 below the 1.5x 3-worker scaling floor")
    args = ap.parse_args()
    if args.checkpoint:
        payload = {"checkpoint": run_checkpoint_overhead()}
        write_bench_pr8(payload, smoke=False)
        save_json("perf_checkpoint", payload)
        cp = payload["checkpoint"]
        print(csv_row(
            "perf_checkpoint_overhead", cp["checkpointed_median_s"],
            f"vs_plain_pipeline={100 * cp['overhead_frac']:+.1f}% "
            f"replay={cp['replay_speedup']:.1f}x_faster "
            f"pop={cp['population']} "
            f"parity={'ok' if cp['bitwise_parity'] else 'BROKEN'}"))
        sys.exit(0 if cp["bitwise_parity"] else 1)
    if args.cluster:
        payload = {"cluster_scaling": run_cluster_scaling()}
        write_bench_pr10(payload, smoke=False)
        save_json("perf_cluster", payload)
        cs = payload["cluster_scaling"]
        top = str(max(cs["workers"]))
        print(csv_row(
            "perf_cluster_scaling", cs["wall_s"][top],
            f"dispatch_scaling_{top}w={cs['scaling_max_workers']:.2f}x "
            f"recovery_overhead={100 * cs['recovery_overhead_frac']:+.1f}% "
            f"pop={cs['population']}x{cs['batches']} "
            f"parity={'ok' if cs['bitwise_parity'] else 'BROKEN'} "
            f"target_1p5x={'met' if cs['meets_target'] else 'MISSED'}"))
        sys.exit(0 if cs["meets_target"] and cs["bitwise_parity"] else 1)
    if args.link_fidelity:
        payload = {"link_fidelity": run_link_fidelity_overhead()}
        write_bench_pr9(payload, smoke=False)
        save_json("perf_link_fidelity", payload)
        lf = payload["link_fidelity"]
        print(csv_row(
            "perf_link_fidelity", lf["link_s"],
            f"vs_aggregate_tier={lf['overhead_x']:.2f}x_cost "
            f"pop={lf['population']} "
            f"ii_tightened={lf['frac_ii_tightened']:.0%} "
            f"budget_3p5x={'met' if lf['within_budget'] else 'MISSED'}"))
        sys.exit(0 if lf["within_budget"] and lf["ii_dominates"] else 1)
    if args.pipeline:
        payload = {"pipeline": run_pipeline_speedup()}
        write_bench_pr7(payload, smoke=False)
        save_json("perf_pipeline", payload)
        pp = payload["pipeline"]
        print(csv_row(
            "perf_pipeline", pp["fused_s"],
            f"vs_host_memo_loop={pp['median_speedup']:.1f}x_faster "
            f"pop={pp['population']} "
            f"parity={'ok' if pp['bitwise_parity'] else 'BROKEN'} "
            f"target_3x={'met' if pp['meets_target'] else 'MISSED'}"))
        sys.exit(0 if pp["bitwise_parity"] else 1)
    if args.service:
        payload = {"service_coalescing": run_service_coalescing()}
        write_bench_pr6(payload, smoke=False)
        save_json("perf_service", payload)
        sc = payload["service_coalescing"]
        print(csv_row(
            "perf_service_coalescing", sc["service_wall_s"],
            f"vs_sequential_local="
            f"{sc['local_wall_s'] / max(sc['service_wall_s'], 1e-12):.2f}x "
            f"dispatches={sc['service_dispatches']}/"
            f"{sc['local_dispatches']} "
            f"warm_hit_rate={sc['warm_store_hit_rate']:.0%} "
            f"parity={'ok' if sc['bitwise_parity'] else 'BROKEN'}"))
        sys.exit(0 if sc["bitwise_parity"] else 1)
    payload = run(smoke=args.smoke)
    for line in _csv_rows(payload, smoke=args.smoke):
        print(line)
    if args.smoke:
        # gate on the measured payload (BENCH_PR5.json is its distillate)
        failed = False
        spd = payload["exact_path"]["median_speedup"]
        if spd < 5.0:
            print(f"perf-smoke: exact-path speedup {spd:.2f}x < 5x "
                  f"floor", file=sys.stderr)
            failed = True
        else:
            print(f"perf-smoke: exact-path speedup {spd:.2f}x (floor 5x)")
        ga_spd = payload["ga_exact"]["median_speedup"]
        floor = payload["ga_exact"]["floor_speedup"]
        if ga_spd < floor:
            print(f"perf-smoke: exact-GA speedup {ga_spd:.2f}x < "
                  f"{floor:.0f}x floor", file=sys.stderr)
            failed = True
        else:
            print(f"perf-smoke: exact-GA speedup {ga_spd:.2f}x "
                  f"(floor {floor:.0f}x)")
        lf = payload["link_fidelity"]
        if not lf["within_budget"]:
            print(f"perf-smoke: link-fidelity overhead "
                  f"{lf['overhead_x']:.2f}x > "
                  f"{lf['max_overhead_x']:.0f}x ceiling", file=sys.stderr)
            failed = True
        else:
            print(f"perf-smoke: link-fidelity overhead "
                  f"{lf['overhead_x']:.2f}x "
                  f"(ceiling {lf['max_overhead_x']:.0f}x)")
        pp_spd = payload["pipeline"]["median_speedup"]
        pp_floor = payload["pipeline"]["floor_speedup"]
        if pp_spd < pp_floor:
            print(f"perf-smoke: fused-pipeline speedup {pp_spd:.2f}x < "
                  f"{pp_floor:.1f}x floor", file=sys.stderr)
            failed = True
        else:
            print(f"perf-smoke: fused-pipeline speedup {pp_spd:.2f}x "
                  f"(floor {pp_floor:.1f}x)")
        cs = payload["cluster_scaling"]
        # informational only: smoke-sized runs on a contended CI box are
        # too noisy to gate — the 1.5x floor is enforced by --cluster
        print(f"perf-smoke: cluster dispatch scaling "
              f"{cs['scaling_max_workers']:.2f}x at {max(cs['workers'])} "
              f"workers (1.5x floor gated by --cluster)")
        if failed:
            sys.exit(1)
