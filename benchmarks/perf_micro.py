"""Framework-performance microbenchmarks: the beyond-paper speedups.

* batch evaluator vs reference simulator throughput (the TPU-native
  re-think of the paper's 2.94 M-sample host loop);
* Pallas kernel interpret-mode validation timings (correctness proxy —
  TPU is the perf target).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import compile_workload, simulate
from repro.core.dse.batch_eval import (batch_evaluate, prepare_configs,
                                       prepare_workload)
from repro.core.dse.encoding import decode, random_genomes
from repro.core.workloads import build

from .common import csv_row, save_json


def run() -> dict:
    rng = np.random.default_rng(0)
    chips = [decode(g, f"d{i}") for i, g in enumerate(random_genomes(rng, 256))]
    g = build("resnet50_int8")
    ws = prepare_workload(g)
    cfgs = prepare_configs(chips)
    batch_evaluate(ws, cfgs)  # compile
    t0 = time.perf_counter()
    batch_evaluate(ws, cfgs)
    t_batch = (time.perf_counter() - t0) / len(chips)

    t0 = time.perf_counter()
    n_ref = 8
    for chip in chips[:n_ref]:
        try:
            simulate(chip, compile_workload(g, chip))
        except Exception:
            pass
    t_ref = (time.perf_counter() - t0) / n_ref

    payload = {
        "batch_us_per_config": t_batch * 1e6,
        "reference_us_per_config": t_ref * 1e6,
        "speedup": t_ref / t_batch,
        "workload": "resnet50_int8",
        "batch_size": len(chips),
    }
    save_json("perf_micro", payload)
    return payload


def main() -> list:
    p = run()
    return [csv_row("perf_batch_eval", p["batch_us_per_config"],
                    f"vs_reference={p['speedup']:.0f}x_faster"),
            csv_row("perf_reference_sim", p["reference_us_per_config"],
                    "python_oracle")]


if __name__ == "__main__":
    for line in main():
        print(line)
