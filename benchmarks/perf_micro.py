"""Framework-performance microbenchmarks: the beyond-paper speedups.

* batch evaluator vs reference simulator throughput (the TPU-native
  re-think of the paper's 2.94 M-sample host loop);
* cache-aware ``EvalEngine`` vs the pre-refactor ``evaluate_genomes``
  host loop on a GA refinement run (population 64, 10 generations,
  4 workloads), reporting evaluator throughput (configs*workloads/s)
  and the GA cache-hit rate;
* the batched plan executor vs the per-candidate ChipSim walk on one
  GA-generation-sized population (64 candidates, plans precompiled for
  both sides — this isolates the simulator core, which ISSUE 2 targets
  at >= 5x).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import compile_workload, simulate
from repro.core.compiler.mapper import UnmappableError
from repro.core.compiler.pipeline import lower_plan
from repro.core.dse.batch_eval import (batch_evaluate, prepare_configs,
                                       prepare_workload)
from repro.core.dse.encoding import decode, random_genomes
from repro.core.dse.engine import EngineStats, EvalEngine
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.sweep import evaluate_genomes_reference, run_sweep
from repro.core.simulator.batched import (batch_simulate, stack_chip_configs,
                                          stack_plan_tables)
from repro.core.workloads import build

from .common import csv_row, save_json

# one workload per family: CNN / ViT transformer / long-conv / GNN
GA_WORKLOADS = ["resnet50_int8", "vit_b16_int8", "hyena_1_3b", "gnn_gat"]
GA_CFG = GAConfig(population=64, generations=10, seed_top_k=32,
                  early_stop=10_000)  # fixed work: no early stop


class _ReferenceEngine:
    """The verbatim pre-refactor hot path behind the engine interface:
    per-batch ``prepare_workload(build(w))``, per-genome ``decode``, no
    memoization, no prefilter."""

    def __init__(self, workloads):
        self.workloads = list(workloads)
        self.stats = EngineStats(workloads=len(self.workloads))

    def check_workloads(self, workloads, calib=None):
        assert list(workloads) == self.workloads
        return self

    def evaluate(self, genomes, keep=None):
        t0 = time.perf_counter()
        m = evaluate_genomes_reference(genomes, self.workloads)
        self.stats.requests += len(genomes)
        self.stats.misses += len(genomes)
        self.stats.eval_seconds += time.perf_counter() - t0
        return m


def _ga_run(engine, prefilter: bool, sweep) -> tuple:
    """One GA refinement through ``engine``; returns (seconds, result)."""
    t0 = time.perf_counter()
    res = run_ga(sweep, 200.0, GA_CFG, engine=engine, prefilter=prefilter)
    return time.perf_counter() - t0, res


def run_ga_speedup(repeats: int = 3) -> dict:
    """Engine (cached + vectorized + prefiltered) vs the pre-refactor
    evaluate_genomes path (fresh decode / per-batch workload prep / no
    memoization) on the same seeded GA.  Each engine repeat uses a fresh
    engine (the sweep memoized untimed, mirroring the shared sweep→GA
    pattern).  Repeats are interleaved legacy/engine and min-reduced so
    both paths sample the same machine-load phases — the measured work
    itself is deterministic."""
    # pre-compile every batch shape either path can emit, so both timed
    # runs are steady-state (jit caches are process-global and one-time)
    setup = EvalEngine(GA_WORKLOADS)
    setup.warmup()
    sweep = run_sweep(GA_WORKLOADS, samples_per_stratum=8, seed=0,
                      brackets=(100.0, 200.0), engine=setup)

    t_legacy = t_engine = np.inf
    for _ in range(repeats):
        t, res_legacy = _ga_run(_ReferenceEngine(GA_WORKLOADS), False, sweep)
        t_legacy = min(t_legacy, t)

        engine = EvalEngine(GA_WORKLOADS)
        engine.evaluate(sweep.genomes)      # untimed, as run_sweep did
        pre = dataclasses.replace(engine.stats)  # GA-only counter deltas
        t, res_engine = _ga_run(engine, True, sweep)
        t_engine = min(t_engine, t)
    st = engine.stats

    assert res_legacy.best_fitness == res_engine.best_fitness, \
        "cache-aware GA diverged from the reference path"
    hits = st.hits - pre.hits
    misses = st.misses - pre.misses
    requests = st.requests - pre.requests
    pairs = (hits + misses) * st.workloads
    return {
        "ga_population": GA_CFG.population,
        "ga_generations": GA_CFG.generations,
        "ga_workloads": GA_WORKLOADS,
        "legacy_s": t_legacy,
        "engine_s": t_engine,
        "speedup": t_legacy / t_engine,
        "best_fitness": float(res_engine.best_fitness),
        "cache_hit_rate": hits / max(requests, 1),
        "cache_hits": hits,
        "skipped_out_of_bracket": st.skips - pre.skips,
        "simulated": misses,
        "throughput_cfg_wl_per_s":
            pairs / max(st.eval_seconds - pre.eval_seconds, 1e-12),
    }


def run_population_sim_speedup(population: int = 64, repeats: int = 3,
                               workloads=GA_WORKLOADS) -> dict:
    """Batched plan executor vs per-candidate ChipSim on one GA generation.

    Plans are compiled once (outside the timed region — identical input
    for both sides): the timed work is exactly what a cache-missing
    population evaluation costs the simulator core.  Interleaved repeats,
    min-reduced; the batched path is warmed so both sides are
    steady-state."""
    rng = np.random.default_rng(1)
    chips = []
    for i, g in enumerate(random_genomes(rng, population * 2)):
        chips.append(decode(g, f"p{i}"))
        if len(chips) == population:
            break

    per_wl = {}
    compiled = {}
    for wname in workloads:
        g = build(wname)
        pairs = []
        for chip in chips:
            try:
                pairs.append((chip, compile_workload(g, chip)))
            except UnmappableError:
                continue
        if not pairs:
            continue
        tables = stack_plan_tables(
            [lower_plan(p, c.num_tiles) for c, p in pairs])
        cfgs = stack_chip_configs([c for c, _ in pairs])
        compiled[wname] = (pairs, tables, cfgs)
        batch_simulate(tables, cfgs)  # jit warmup, untimed

    for wname, (pairs, tables, cfgs) in compiled.items():
        t_ref = t_batch = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            for chip, plan in pairs:
                simulate(chip, plan)
            t_ref = min(t_ref, time.perf_counter() - t0)
            t0 = time.perf_counter()
            batch_simulate(tables, cfgs)
            t_batch = min(t_batch, time.perf_counter() - t0)
        per_wl[wname] = {"candidates": len(pairs),
                         "chipsim_s": t_ref, "batched_s": t_batch,
                         "speedup": t_ref / t_batch}
    total_ref = sum(r["chipsim_s"] for r in per_wl.values())
    total_batch = sum(r["batched_s"] for r in per_wl.values())
    return {
        "population": population,
        "workloads": list(workloads),
        "per_workload": per_wl,
        "chipsim_s": total_ref,
        "batched_s": total_batch,
        "speedup": total_ref / total_batch,
        "target_speedup": 5.0,
        "meets_target": total_ref / total_batch >= 5.0,
    }


def run() -> dict:
    rng = np.random.default_rng(0)
    chips = [decode(g, f"d{i}") for i, g in enumerate(random_genomes(rng, 256))]
    g = build("resnet50_int8")
    ws = prepare_workload(g)
    cfgs = prepare_configs(chips)
    batch_evaluate(ws, cfgs)  # compile
    t0 = time.perf_counter()
    batch_evaluate(ws, cfgs)
    t_batch = (time.perf_counter() - t0) / len(chips)

    t0 = time.perf_counter()
    n_ref = 8
    for chip in chips[:n_ref]:
        try:
            simulate(chip, compile_workload(g, chip))
        except Exception:
            pass
    t_ref = (time.perf_counter() - t0) / n_ref

    payload = {
        "batch_us_per_config": t_batch * 1e6,
        "reference_us_per_config": t_ref * 1e6,
        "speedup": t_ref / t_batch,
        "workload": "resnet50_int8",
        "batch_size": len(chips),
        "ga_engine": run_ga_speedup(),
        "population_sim": run_population_sim_speedup(),
    }
    save_json("perf_micro", payload)
    return payload


def main() -> list:
    p = run()
    ga = p["ga_engine"]
    ps = p["population_sim"]
    return [csv_row("perf_batch_eval", p["batch_us_per_config"],
                    f"vs_reference={p['speedup']:.0f}x_faster"),
            csv_row("perf_reference_sim", p["reference_us_per_config"],
                    "python_oracle"),
            csv_row("perf_ga_engine", ga["engine_s"],
                    f"vs_legacy={ga['speedup']:.2f}x_faster "
                    f"hit_rate={ga['cache_hit_rate']:.0%} "
                    f"throughput={ga['throughput_cfg_wl_per_s']:.0f}cfg_wl_s"),
            csv_row("perf_population_sim", ps["batched_s"],
                    f"vs_chipsim={ps['speedup']:.1f}x_faster "
                    f"pop={ps['population']} "
                    f"target_5x={'met' if ps['meets_target'] else 'MISSED'}")]


if __name__ == "__main__":
    for line in main():
        print(line)
