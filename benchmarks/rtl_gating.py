"""Paper §5.1.3: system-level RTL gating study, analytical side.

The silicon study synthesized a homogeneous 2x 4x4 dual-datapath chip
(FP16 path clock-gated under INT8) against an iso-area heterogeneous
5x5 FP16+INT8 + 4x4 INT4+INT8 chip (idle tile power-gated): 93.6 % less
power, 28.1 % more MACs (41 vs 32), 8.3 % less area.  The paper's
analytical power-gating model (95 % leakage elimination) agreed within
6 %.  This benchmark reproduces the analytical side of that comparison
with our calibration tables.
"""
from __future__ import annotations

from repro.core.arch import Sparsity, TileTemplate
from repro.core.calibrate.asap7 import DEFAULT_CALIB
from repro.core.ir import Precision
from repro.core.simulator.area import tile_area

from .common import csv_row, save_json

PAPER = {"power_reduction_pct": 93.6, "mac_increase_pct": 28.1,
         "area_reduction_pct": 8.3, "analytical_leak_elim_pct": 95.0}


def run() -> dict:
    c = DEFAULT_CALIB
    # homogeneous: two 4x4 dual-datapath (FP16+INT8) tiles, FP16 clock-gated
    homo = TileTemplate(name="homo", rows=4, cols=4, sram_kb=64,
                        precisions=frozenset({Precision.INT8, Precision.FP16}),
                        dsp_count=0, clock_mhz=1000)
    # heterogeneous: 5x5 FP16+INT8 + 4x4 INT4+INT8, little tile power-gated
    big = TileTemplate(name="b", rows=5, cols=5, sram_kb=64,
                       precisions=frozenset({Precision.INT8, Precision.FP16}),
                       dsp_count=0, clock_mhz=1000)
    little = TileTemplate(name="l", rows=4, cols=4, sram_kb=64,
                          precisions=frozenset({Precision.INT4, Precision.INT8}),
                          dsp_count=0, clock_mhz=1000)

    a_homo = 2 * tile_area(homo, c)
    a_het = tile_area(big, c) + tile_area(little, c)
    macs_homo = 2 * homo.num_macs
    macs_het = big.num_macs + little.num_macs

    # idle-phase power: homogeneous clock-gates (leakage remains on the full
    # dual-datapath area); heterogeneous power-gates the idle INT4 tile to
    # the 5 % residual
    leak = c.leak_mw_per_mm2
    p_homo_idle = leak * a_homo                       # clock gating: full leak
    p_het_idle = leak * tile_area(big, c) \
        + leak * tile_area(little, c) * c.power_gate_residual
    # the study reports the INT8-only phase where the hetero design also
    # runs on the (cheaper) INT8 datapath vs homo's residual-toggling wide
    # path; dynamic part at equal throughput:
    e_homo_dyn = c.mac_energy(int(Precision.INT8), 0, int(Precision.FP16))
    e_het_dyn = c.mac_energy(int(Precision.INT8), 0, int(Precision.INT8))
    # idle-dominated comparison (the 93.6 % figure is reported at idle/gated
    # operation of the secondary tile)
    power_red = 100 * (1 - (p_het_idle - leak * tile_area(big, c))
                       / (p_homo_idle - leak * tile_area(homo, c)))
    leak_elim = 100 * (1 - c.power_gate_residual)

    payload = {
        "analytical": {
            "mac_increase_pct": 100 * (macs_het / macs_homo - 1),
            "area_delta_pct": 100 * (1 - a_het / a_homo),
            "gated_tile_power_reduction_pct": power_red,
            "leak_elimination_pct": leak_elim,
            "dyn_energy_reduction_pct": 100 * (1 - e_het_dyn / e_homo_dyn),
        },
        "paper_silicon": PAPER,
        "agreement": {
            "leak_model_vs_silicon_pct": abs(leak_elim - PAPER["power_reduction_pct"]),
        },
    }
    save_json("rtl_gating", payload)
    return payload


def main() -> list:
    p = run()
    a = p["analytical"]
    return [
        csv_row("rtl_gating_macs", 0.0,
                f"mac_increase={a['mac_increase_pct']:.1f}% (paper 28.1%)"),
        csv_row("rtl_gating_power", 0.0,
                f"leak_elim={a['leak_elimination_pct']:.1f}% "
                f"(paper silicon 93.6%, model 95%)"),
        csv_row("rtl_gating_dyn", 0.0,
                f"int8_dyn_energy_saving={a['dyn_energy_reduction_pct']:.1f}%"),
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
