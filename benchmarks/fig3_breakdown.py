"""Paper Fig. 3: per-operator inference latency breakdown (MAC / Vector /
Other) on the homogeneous LNL-class baseline for six representative
workloads.  Paper's finding: only ResNet-50 is MAC-bound; Hyena spends
~30 % in FFT, SNN-VGG9 ~47 % in LIF, KAN is entirely polynomial."""
from __future__ import annotations

from repro.core import compile_workload, homogeneous_baseline, simulate
from repro.core.ir import OpClass, OpType
from repro.core.workloads import build

from .common import csv_row, save_json, timed

WORKLOADS = ["resnet50_int8", "hyena_1_3b", "mixtral_fp16", "snn_vgg9",
             "kan", "gnn_gat"]

# op-type groups matching the paper's measurement buckets
_OTHER = {OpType.FFT, OpType.SNN_LIF, OpType.POLY, OpType.SSM_SCAN,
          OpType.GATHER, OpType.SCATTER}


def run() -> list:
    chip = homogeneous_baseline(6)
    rows = []
    for name in WORKLOADS:
        g = build(name)
        (r, us) = timed(lambda: simulate(chip, compile_workload(g, chip)),
                        repeats=1)
        shares = {"MAC": 0.0, "Vector": 0.0, "Other": 0.0}
        for opr in r.ops:
            nd = r  # op node lookup via plan graph
        plan_nodes = compile_workload(g, chip).graph.nodes
        for opr in r.ops:
            nd = plan_nodes[opr.op_index]
            if nd.op_type in _OTHER:
                shares["Other"] += opr.latency_s
            elif nd.op_cls == OpClass.MAC:
                shares["MAC"] += opr.latency_s
            else:
                shares["Vector"] += opr.latency_s
        tot = sum(shares.values()) or 1.0
        rows.append({"workload": name, "us_per_call": us,
                     "shares": {k: v / tot for k, v in shares.items()},
                     "latency_ms": r.latency_s * 1e3})
    save_json("fig3_breakdown", rows)
    return rows


def main() -> list:
    out = []
    for r in run():
        s = r["shares"]
        out.append(csv_row(
            f"fig3_{r['workload']}", r["us_per_call"],
            f"mac={s['MAC']:.2f} vector={s['Vector']:.2f} other={s['Other']:.2f}"))
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
