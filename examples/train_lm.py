"""End-to-end training driver: trains a reduced assigned architecture for a
few hundred steps on CPU with async checkpointing, then demonstrates the
fault-tolerance path (simulated device failure -> restore -> bit-identical
continuation).

  PYTHONPATH=src python examples/train_lm.py [--arch granite-20b] [--steps 200]
"""
import argparse
import shutil
import tempfile

from repro.models import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.fault import FaultInjector
from repro.train.loop import TrainLoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    ckpt = tempfile.mkdtemp(prefix="repro_train_")
    loop = TrainLoopConfig(steps=args.steps, ckpt_every=25, global_batch=8,
                           seq_len=64, ckpt_dir=ckpt)

    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) "
          f"for {args.steps} steps with a fault injected at step "
          f"{args.steps // 2} ...")
    out = train_loop(cfg, loop, AdamWConfig(lr=3e-3),
                     fault_injector=FaultInjector(fail_at={args.steps // 2}),
                     on_step=lambda s, m: print(
                         f"  step {s:4d}  loss {m['loss']:.4f}")
                     if s % 25 == 0 else None)
    print(f"\nfirst loss {out['losses'][0]:.4f} -> final "
          f"{out['final_loss']:.4f}  (restarts: {out['restarts']})")
    shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
