"""DSE-as-a-service demo: one shared evaluation service, many tenants.

Runs the §3.5 flow against ``repro.serve.dse_service`` instead of a
private engine: a stratified sweep seeds a persistent content-addressed
result store, two GA tenants then refine *concurrently* through the
service's coalescing queue (their per-generation populations fuse into
shared micro-batches, duplicates served from the store), and a third
search streams live Pareto-front updates as its generations complete.
Results are bitwise identical to a local ``EvalEngine(backend="exact")``
run — the fused metrics are batch-composition independent, so the
coalescing is fidelity-free.

  PYTHONPATH=src python examples/dse_serve.py [--samples 8] [--budget 200]
      [--store results.sqlite] [--tcp]

Rerun with ``--store`` pointing at the same file to watch the warm
persistent store answer most of the work without touching the engine.
"""
import argparse
import threading
import warnings

import numpy as np

from repro.core.dse.encoding import decode
from repro.core.dse.api import EngineConfig
from repro.core.dse.engine import EvalEngine
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.store import MemoryLRUStore, SqliteStore, TieredStore
from repro.core.dse.sweep import run_sweep
from repro.serve.dse_service import DSEClient, DSEService


def main():
    warnings.filterwarnings("ignore")
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=8)
    ap.add_argument("--budget", type=float, default=200.0)
    ap.add_argument("--workloads", nargs="*",
                    default=["resnet50_int8", "kan", "hyena_1_3b"])
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="persist results to this sqlite file (memory-LRU "
                         "front stays on regardless); rerun to start warm")
    ap.add_argument("--tcp", action="store_true",
                    help="tenants connect over the JSON-lines TCP front "
                         "instead of in-process (same bytes either way)")
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    args = ap.parse_args()

    store = (TieredStore(MemoryLRUStore(), SqliteStore(args.store))
             if args.store else None)
    engine = EvalEngine(args.workloads, config=EngineConfig(
        backend="exact", store=store))

    print(f"[1/4] stratified sweep ({args.samples}/stratum, warms the "
          f"store)...")
    sw = run_sweep(args.workloads, samples_per_stratum=args.samples, seed=0,
                   brackets=(100.0, args.budget), engine=engine)

    service = DSEService(engine, max_batch=256, max_wait_ms=args.max_wait_ms)
    service.start()
    try:
        if args.tcp:
            host, port = service.listen()
            print(f"      service on tcp://{host}:{port}")
            client = lambda: DSEClient(address=(host, port))  # noqa: E731
        else:
            client = lambda: DSEClient(service=service)      # noqa: E731

        print(f"\n[2/4] two GA tenants refine {args.budget:.0f} mm^2 "
              f"concurrently through the service ...")
        cfg = GAConfig(population=24, generations=8, seed_top_k=16,
                       early_stop=10_000)
        results = {}

        def tenant(seed):
            cl = client()
            results[seed] = run_ga(sw, args.budget, cfg, seed=seed,
                                   engine=cl)
            cl.close()

        threads = [threading.Thread(target=tenant, args=(s,)) for s in (0, 1)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for seed, ga in sorted(results.items()):
            chip = decode(ga.best_genome)
            print(f"      tenant seed={seed}: fitness {ga.best_fitness:+.3f}"
                  f" ({len(chip.tiles)} tile types)")

        print("\n[3/4] streamed server-side search (live Pareto front) ...")
        fit = sw.fitness(cfg.alpha)
        in_b = np.nonzero((sw.bracket == args.budget) & np.isfinite(fit))[0]
        seeds = sw.genomes[in_b[np.argsort(-fit[in_b])][:cfg.seed_top_k]]
        e_homo = sw.homo_baseline()[args.budget]
        cl = client()
        for ev in cl.search(seeds, args.budget, e_homo,
                            cfg={"population": 24, "generations": 6,
                                 "seed_top_k": 16, "early_stop": 10_000},
                            seed=2):
            if ev["event"] == "generation":
                print(f"      gen {ev['gen']:2d}: best "
                      f"{ev['best_fitness']:+.3f}, Pareto front "
                      f"{ev['front_size']} designs")
            elif ev["event"] == "done":
                r = ev["result"]
                print(f"      done: fitness {r['best_fitness']:+.3f} after "
                      f"{r['evaluated']} evaluations")
            else:
                raise RuntimeError(ev.get("error"))
        cl.close()

        print("\n[4/4] service counters ...")
        st = service.stats
        hit = st.store_hits / max(st.request_genomes, 1)
        print(f"      {st.requests} requests / {st.request_genomes} genomes "
              f"-> {st.batches} micro-batches "
              f"({st.coalesced_batches} coalesced across tenants)")
        print(f"      store served {hit:.0%} at admission, "
              f"{st.inflight_merged} merged in flight, "
              f"{st.engine_dispatches} fused engine dispatches")
        print(f"      mean queue {st.mean_queue_ms():.1f} ms, occupancy "
              f"{st.occupancy(service.max_batch):.1%}")
        if args.store:
            print(f"      persistent store: {len(engine.store)} rows in "
                  f"{args.store} (rerun --store to start warm)")
    finally:
        service.stop()


if __name__ == "__main__":
    main()
