"""DSE-as-a-service demo: one shared evaluation service, many tenants.

Runs the §3.5 flow against ``repro.serve.dse_service`` instead of a
private engine: a stratified sweep seeds a persistent content-addressed
result store, two GA tenants then refine *concurrently* through the
service's coalescing queue (their per-generation populations fuse into
shared micro-batches, duplicates served from the store), and a third
search streams live Pareto-front updates as its generations complete.
Results are bitwise identical to a local ``EvalEngine(backend="exact")``
run — the fused metrics are batch-composition independent, so the
coalescing is fidelity-free.

  PYTHONPATH=src python examples/dse_serve.py [--samples 8] [--budget 200]
      [--store results.sqlite] [--tcp] [--workers 3] [--kill-after 10]

Rerun with ``--store`` pointing at the same file to watch the warm
persistent store answer most of the work without touching the engine.

With ``--workers N`` (N > 1) the GA tenants refine through a sharded
``repro.serve.cluster.DSECluster`` over N worker services instead of
one; add ``--kill-after K`` to stop one worker for real while the Kth
shard forms and watch the survivors absorb its load — the results are
bitwise identical either way (that invariant is pinned by the ``-m
chaos`` suite, ``tests/test_cluster.py``).
"""
import argparse
import threading
import warnings

import numpy as np

from repro.core.dse.encoding import decode
from repro.core.dse.api import EngineConfig
from repro.core.dse.engine import EvalEngine
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.store import MemoryLRUStore, SqliteStore, TieredStore
from repro.core.dse.sweep import run_sweep
from repro.serve.dse_service import DSEClient, DSEService


def main():
    warnings.filterwarnings("ignore")
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=8)
    ap.add_argument("--budget", type=float, default=200.0)
    ap.add_argument("--workloads", nargs="*",
                    default=["resnet50_int8", "kan", "hyena_1_3b"])
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="persist results to this sqlite file (memory-LRU "
                         "front stays on regardless); rerun to start warm")
    ap.add_argument("--tcp", action="store_true",
                    help="tenants connect over the JSON-lines TCP front "
                         "instead of in-process (same bytes either way)")
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--workers", type=int, default=1, metavar="N",
                    help="refine through a DSECluster over N worker "
                         "services (default: one plain service)")
    ap.add_argument("--kill-after", type=int, default=None, metavar="K",
                    help="chaos demo: kill one worker while the Kth "
                         "cluster shard forms (requires --workers > 1)")
    args = ap.parse_args()
    if args.kill_after is not None and args.workers < 2:
        ap.error("--kill-after needs --workers > 1")

    store = (TieredStore(MemoryLRUStore(), SqliteStore(args.store))
             if args.store else None)
    engine = EvalEngine(args.workloads, config=EngineConfig(
        backend="exact", store=store))

    print(f"[1/4] stratified sweep ({args.samples}/stratum, warms the "
          f"store)...")
    sw = run_sweep(args.workloads, samples_per_stratum=args.samples, seed=0,
                   brackets=(100.0, args.budget), engine=engine)

    service = DSEService(engine, max_batch=256, max_wait_ms=args.max_wait_ms)
    service.start()
    try:
        if args.tcp:
            host, port = service.listen()
            print(f"      service on tcp://{host}:{port}")
            client = lambda: DSEClient(address=(host, port))  # noqa: E731
        else:
            client = lambda: DSEClient(service=service)      # noqa: E731

        cluster, workers = None, []
        if args.workers > 1:
            from repro.core.dse.faults import FaultInjector
            from repro.serve.cluster import DSECluster
            inj = None
            if args.kill_after is not None:
                inj = FaultInjector(seed=0,
                                    at={"worker_kill": (args.kill_after,)})
            workers = [DSEService(
                EvalEngine(args.workloads,
                           config=EngineConfig(backend="exact")),
                max_batch=256, max_wait_ms=args.max_wait_ms,
                worker_id=f"demo-w{i}").start()
                for i in range(args.workers)]
            cluster = DSECluster(workers, fault_injector=inj)
            kill = (f", killing one worker at shard {args.kill_after}"
                    if inj is not None else "")
            print(f"\n[2/4] two GA tenants refine {args.budget:.0f} mm^2 "
                  f"through a {args.workers}-worker cluster{kill} ...")
        else:
            print(f"\n[2/4] two GA tenants refine {args.budget:.0f} mm^2 "
                  f"concurrently through the service ...")
        cfg = GAConfig(population=24, generations=8, seed_top_k=16,
                       early_stop=10_000)
        results = {}

        def tenant(seed):
            if cluster is not None:
                results[seed] = run_ga(sw, args.budget, cfg, seed=seed,
                                       engine=cluster)
                return
            cl = client()
            results[seed] = run_ga(sw, args.budget, cfg, seed=seed,
                                   engine=cl)
            cl.close()

        threads = [threading.Thread(target=tenant, args=(s,)) for s in (0, 1)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for seed, ga in sorted(results.items()):
            chip = decode(ga.best_genome)
            print(f"      tenant seed={seed}: fitness {ga.best_fitness:+.3f}"
                  f" ({len(chip.tiles)} tile types)")
        if cluster is not None:
            cs = cluster.cluster_stats
            print(f"      cluster: {cs.shards} shards / {cs.dispatches} "
                  f"dispatches, {cs.retried_shards} retried, "
                  f"{cs.worker_failures} worker failures")
            for m in cluster.membership():
                print(f"        {m['name']}: {m['status']} "
                      f"(failures={m['failures']})")
            cluster.close()
            for w in workers:
                w.stop(drain=False)

        print("\n[3/4] streamed server-side search (live Pareto front) ...")
        fit = sw.fitness(cfg.alpha)
        in_b = np.nonzero((sw.bracket == args.budget) & np.isfinite(fit))[0]
        seeds = sw.genomes[in_b[np.argsort(-fit[in_b])][:cfg.seed_top_k]]
        e_homo = sw.homo_baseline()[args.budget]
        cl = client()
        for ev in cl.search(seeds, args.budget, e_homo,
                            cfg={"population": 24, "generations": 6,
                                 "seed_top_k": 16, "early_stop": 10_000},
                            seed=2):
            if ev["event"] == "generation":
                print(f"      gen {ev['gen']:2d}: best "
                      f"{ev['best_fitness']:+.3f}, Pareto front "
                      f"{ev['front_size']} designs")
            elif ev["event"] == "done":
                r = ev["result"]
                print(f"      done: fitness {r['best_fitness']:+.3f} after "
                      f"{r['evaluated']} evaluations")
            else:
                raise RuntimeError(ev.get("error"))
        cl.close()

        print("\n[4/4] service counters ...")
        st = service.stats
        hit = st.store_hits / max(st.request_genomes, 1)
        print(f"      {st.requests} requests / {st.request_genomes} genomes "
              f"-> {st.batches} micro-batches "
              f"({st.coalesced_batches} coalesced across tenants)")
        print(f"      store served {hit:.0%} at admission, "
              f"{st.inflight_merged} merged in flight, "
              f"{st.engine_dispatches} fused engine dispatches")
        print(f"      mean queue {st.mean_queue_ms():.1f} ms, occupancy "
              f"{st.occupancy(service.max_batch):.1%}")
        if args.store:
            print(f"      persistent store: {len(engine.store)} rows in "
                  f"{args.store} (rerun --store to start warm)")
    finally:
        service.stop()


if __name__ == "__main__":
    main()
