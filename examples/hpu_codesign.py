"""Beyond-paper loop closure: hardware/software co-design for the assigned
architectures.

1. Extract a JAX model (the same code that trains under pjit) into a
   MOSAIC operator DAG.
2. Search heterogeneous NPU compositions for it (the paper's DSE).
3. Search TPU mesh/sharding knobs for its training run with the same
   roofline methodology (repro.core.tpu_dse).

  PYTHONPATH=src python examples/hpu_codesign.py [--arch mamba2-780m]
"""
import argparse
import warnings

import numpy as np

from repro.core import compile_workload, hetero_bls, homogeneous_baseline, simulate
from repro.core.tpu_dse import search_mesh
from repro.core.workloads.extract import extract_model
from repro.models import get_config


def main():
    warnings.filterwarnings("ignore")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    args = ap.parse_args()
    cfg = get_config(args.arch)

    print(f"[1] extracting {cfg.name} into a MOSAIC DAG ...")
    g = extract_model(cfg, seq_len=256)
    print(f"    {len(g.nodes)} ops, {g.total_macs/1e9:.1f} GMACs, "
          f"AI={g.arithmetic_intensity():.1f}")

    print("[2] NPU composition comparison (single-batch inference):")
    for chip in (homogeneous_baseline(6), hetero_bls()):
        r = simulate(chip, compile_workload(g, chip))
        print(f"    {chip.name:22s} lat={r.latency_s*1e3:9.2f}ms "
              f"E={r.energy_pj*1e-6:9.1f}uJ TOPS/W={r.tops_per_w:.2f}")

    print("[3] TPU mesh DSE for training (256 chips, batch 256 x 4096):")
    ranked = search_mesh(cfg, chips=256, global_batch=256, seq_len=4096)
    for c in ranked[:5]:
        k = c.knobs
        print(f"    dp={k.dp:3d} tp={k.tp:2d} mb={k.microbatches} "
              f"remat={int(k.remat)}  step={c.step_s*1e3:7.1f}ms "
              f"(cmp {c.compute_s*1e3:.1f} / mem {c.memory_s*1e3:.1f} / "
              f"coll {c.collective_s*1e3:.1f})  hbm={c.hbm_gib:.1f}GiB "
              f"{'fits' if c.fits else 'OOM'}")


if __name__ == "__main__":
    main()
