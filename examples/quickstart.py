"""Quickstart: MOSAIC in a dozen lines.

Builds a workload, compiles it onto a homogeneous NPU and a
Big+Little+Special-Function HPU, and prints the PEA triple (paper §4.2).

  PYTHONPATH=src python examples/quickstart.py [workload]
"""
import sys

from repro.core import (compile_workload, hetero_bls, homogeneous_baseline,
                        simulate)
from repro.core.workloads import build, workload_names


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "resnet50_int8"
    g = build(name)
    print(f"workload: {name}  ops={len(g.nodes)}  "
          f"macs={g.total_macs/1e9:.2f}G  AI={g.arithmetic_intensity():.1f}")
    for chip in (homogeneous_baseline(6), hetero_bls(n_big=2, n_little=3,
                                                     n_special=1)):
        plan = compile_workload(g, chip)
        r = simulate(chip, plan)
        print(f"\n{chip.name}")
        print(f"  latency : {r.latency_s*1e3:9.3f} ms")
        print(f"  energy  : {r.energy_pj*1e-6:9.3f} uJ")
        print(f"  area    : {r.area_mm2:9.2f} mm^2")
        print(f"  TOPS/W  : {r.tops_per_w:9.3f}   power {r.avg_power_w:.2f} W")
        print(f"  util    : "
              + " ".join(f"{b.template}:{b.utilization(r.latency_s):.2f}"
                         for b in r.tiles))


if __name__ == "__main__":
    main()
