"""Design-space exploration (paper §3.5 flow): stratified sweep over the
12-knob space -> per-area-budget GA refinement -> Pareto front.

  PYTHONPATH=src python examples/dse_search.py [--samples 24] [--budget 200]

``--pipeline`` runs the §4 multi-seed study instead: per-seed stratified
sweeps feeding fused (device-memo, single-dispatch) island-GA
refinements across every area bracket, merged into one cumulative
Pareto front on device:

  PYTHONPATH=src python examples/dse_search.py --pipeline --seeds 0 1

``--checkpoint DIR`` (with ``--pipeline``) makes every completed stage
durable in DIR: kill the run at any point — SIGKILL included — and
rerunning the same command resumes where it left off, bitwise equal to
an uninterrupted run.  The directory also hosts the study's persistent
result store (``results.sqlite``).
"""
import argparse
import warnings

import numpy as np

from repro.core.dse.encoding import decode
from repro.core.dse.api import EngineConfig
from repro.core.dse.engine import EvalEngine
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.pareto import pareto_front
from repro.core.dse.sweep import run_sweep


def run_pipeline_demo(args):
    from repro.core.dse.pipeline import run_pipeline

    def stage(e):
        if e["stage"] == "sweep":
            print(f"   seed {e['seed']}: swept {e['configs']} configs "
                  f"({e['seconds']:.1f}s)")
        elif e["stage"] == "refine":
            print(f"   seed {e['seed']} @ {e['bracket']:5.0f} mm^2: "
                  f"fitness {e['best_fitness']:+.3f} "
                  f"({e['generations']} gens, {e['seconds']:.1f}s, "
                  f"front {len(e['front']['points'])})"
                  if not e.get("skipped") else
                  f"   seed {e['seed']} @ {e['bracket']:5.0f} mm^2: skipped "
                  f"(no homogeneous baseline)")
        elif e["stage"] == "seed_done":
            print(f"   seed {e['seed']}: drained {e['drained']} "
                  f"device-scored rows to the store")
        if e.get("resumed"):
            print("      ^ resumed from checkpoint (not recomputed)")

    print(f"pipeline: seeds {args.seeds}, "
          f"{args.samples}/stratum sweeps, population {args.population}"
          + (f", checkpoint {args.checkpoint}" if args.checkpoint else ""))
    res = run_pipeline(args.workloads, seeds=tuple(args.seeds),
                       samples_per_stratum=args.samples,
                       cfg=GAConfig(population=args.population,
                                    generations=8, early_stop=4),
                       checkpoint=args.checkpoint, on_stage=stage)
    print(f"\ncumulative Pareto front: {len(res.front_points)} points "
          f"({res.evaluated} genomes evaluated)")
    for pt, g in list(zip(res.front_points, res.front_genomes))[:8]:
        chip = decode(np.asarray(g))
        print(f"   E={pt[0]*1e-6:9.1f}uJ  A={pt[1]:6.1f}mm2  "
              f"L={pt[2]*1e3:8.2f}ms  ({len(chip.tiles)} tile types)")
    for b in res.brackets:
        best = res.best(b)
        if best is not None:
            print(f"   best @ {b:5.0f} mm^2: fitness "
                  f"{best.best_fitness:+.3f}")


def main():
    warnings.filterwarnings("ignore")
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=24)
    ap.add_argument("--budget", type=float, default=200.0)
    ap.add_argument("--workloads", nargs="*", default=[
        "resnet50_int8", "vit_b16_int8", "llama7b_int8", "hyena_1_3b",
        "kan", "spec_decode"])
    ap.add_argument("--exact", action="store_true",
                    help="search on the exact fused-mapper backend: the "
                         "sweep AND the GA score with bitwise-rescore-grade "
                         "metrics (no approximate/rescore gap)")
    ap.add_argument("--pipeline", action="store_true",
                    help="run the §4 multi-seed fused pipeline (implies "
                         "the exact backend) and print the cumulative "
                         "cross-seed Pareto front")
    ap.add_argument("--seeds", type=int, nargs="*", default=[0, 1],
                    help="pipeline sweep seeds (with --pipeline)")
    ap.add_argument("--population", type=int, default=64,
                    help="pipeline GA population (with --pipeline)")
    ap.add_argument("--checkpoint", default=None, metavar="DIR",
                    help="with --pipeline: durable per-stage checkpoints "
                         "in DIR — an interrupted run (even kill -9) "
                         "resumes bitwise-identically on rerun")
    args = ap.parse_args()
    if args.pipeline:
        run_pipeline_demo(args)
        return

    # one cache-aware engine end to end: the GA re-scores sweep genomes
    # (its seed population) and its own elites for free
    engine = EvalEngine(args.workloads, config=EngineConfig(
        backend="exact" if args.exact else "scan"))

    print(f"[1/3] stratified sweep ({args.samples}/stratum x 15 strata)...")
    sw = run_sweep(args.workloads, samples_per_stratum=args.samples, seed=0,
                   verbose=True, engine=engine)
    sav = sw.savings()
    best = np.nanmax(np.where((sw.family > 0)[:, None], sav, np.nan), axis=0)
    for w, s in zip(args.workloads, best):
        print(f"   best iso-area savings {w:16s}: {100*s:+6.1f} %")

    print(f"\n[2/3] GA refinement at {args.budget:.0f} mm^2 ...")
    ga = run_ga(sw, args.budget, GAConfig(population=24, generations=8,
                                          seed_top_k=16, early_stop=4),
                verbose=True, engine=engine)
    chip = decode(ga.best_genome)
    print(f"   winner: {len(chip.tiles)} tile types, "
          f"fitness {ga.best_fitness:+.3f}")
    for t, c in chip.tiles:
        kind = "SFU" if t.sfu_mask else f"{t.rows}x{t.cols}"
        print(f"     {c}x {kind:8s} {sorted(p.name for p in t.precisions)} "
              f"sram={t.sram_kb}KB {t.sparsity.name} @{t.clock_mhz}MHz")

    print("\n[3/3] Pareto front (energy, area, latency) over the sweep ...")
    valid = sw.valid_mask()
    pts = np.stack([sw.energy[valid].mean(1), sw.area[valid],
                    sw.latency[valid].mean(1)], axis=1)
    front = pareto_front(pts)
    print(f"   {len(front)} Pareto-optimal designs of {valid.sum()} valid")
    for i in front[:5]:
        print(f"     E={pts[i,0]*1e-6:9.1f}uJ  A={pts[i,1]:6.1f}mm2  "
              f"L={pts[i,2]*1e3:8.2f}ms")

    st = engine.stats
    print(f"\nengine: {st.misses} simulated / {st.hits} cache hits / "
          f"{st.skips} skipped ({st.hit_rate():.0%} hit rate, "
          f"{st.throughput():,.0f} cfg*wl/s)")


if __name__ == "__main__":
    main()
