"""Batched serving demo: continuous-batching engine over prefill/decode
steps with a KV cache.

  PYTHONPATH=src python examples/serve_lm.py [--arch starcoder2-15b]

Serving-mode DSE (--dse)
------------------------
Serving is a *throughput* deployment: successive inference batches
pipeline through the NPU, so the right hardware target is the
steady-state initiation interval (II) and the energy per inference at
that rate — not the one-batch latency the default DSE optimizes.  With
``--dse`` this example searches NPU designs for exactly that regime:

* an ``EvalEngine(..., mode="throughput")`` scores candidates on the
  pipelined steady state (the ``latency`` column is II seconds, the
  ``energy`` column per-inference pJ with leakage charged over II);
* ``objective.serving_fitness`` picks the lowest energy-per-inference
  design whose II meets ``--ii-target-us`` on every serving workload
  (designs that cannot sustain the request rate are infeasible);
* finalists are re-scored through the exact compile-free backend
  (``rescore(mode="throughput")``), so the reported II / energy are the
  ChipSim-parity numbers, not the in-scan search approximation.

  PYTHONPATH=src python examples/serve_lm.py --dse --ii-target-us 2e6

Engine knobs (see ROADMAP "backend x mode" matrix): ``backend`` selects
scan/batched/oracle, ``mode`` selects latency/throughput, and both
compose — every backend models both modes.
"""
import argparse

import numpy as np


def run_serving_demo(args):
    import jax

    from repro.models import get_config, init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=4, max_len=128)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 12),
                              dtype=np.int32)
        engine.submit(Request(rid, prompt, max_new_tokens=12))
    print(f"serving {args.requests} requests on {cfg.name} "
          f"(max_batch=4, greedy) ...")
    results = engine.run()
    for rid, toks in sorted(results.items()):
        print(f"  req {rid}: generated {toks}")


def run_serving_dse(args):
    """Throughput-mode NPU search for the serving deployment (see module
    docstring): sweep candidates at an II target, exact-rescore the best."""
    from repro.core.dse.encoding import random_genomes
    from repro.core.dse.api import EngineConfig
    from repro.core.dse.engine import EvalEngine
    from repro.core.dse.objective import serving_fitness

    workloads = ["llama7b_int4", "vit_b16_int8"]
    ii_target_s = args.ii_target_us * 1e-6
    engine = EvalEngine(workloads,
                        config=EngineConfig(mode="throughput"))
    rng = np.random.default_rng(args.seed)
    genomes = random_genomes(rng, args.samples)
    m = engine.evaluate(genomes)
    score = serving_fitness(m["energy"], m["latency"], ii_target_s)
    print(f"serving-mode DSE: {args.samples} candidates on {workloads}, "
          f"II target {args.ii_target_us:.0f} us "
          f"(mode={m['meta']['mode']}, backend={m['meta']['backend']})")
    feasible = np.isfinite(score)
    if not feasible.any():
        print("  no design sustains the II target; relax --ii-target-us")
        return
    order = np.argsort(-score)
    top = order[np.isfinite(score[order])][:4]
    exact = engine.rescore(genomes[top], mode="throughput")
    print(f"  {feasible.sum()}/{args.samples} designs meet the target; "
          f"top finalists exact-rescored "
          f"(mapper={exact['meta']['mapper']}):")
    for r in range(len(top)):
        ii_us = exact["latency"][r] * 1e6
        e_uj = exact["energy"][r] * 1e-6
        print(f"  #{r} (candidate {top[r]}): "
              f"area {exact['area'][r]:7.1f} mm^2  "
              f"II {np.max(ii_us):8.1f} us  "
              f"energy/inf {np.sum(e_uj):8.1f} uJ")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-15b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--dse", action="store_true",
                    help="run the serving-mode (throughput) NPU design "
                         "search instead of the token-serving demo")
    ap.add_argument("--ii-target-us", type=float, default=2e6,
                    help="steady-state initiation-interval target per "
                         "workload (microseconds)")
    ap.add_argument("--samples", type=int, default=48,
                    help="candidate designs to sweep in --dse mode")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.dse:
        run_serving_dse(args)
    else:
        run_serving_demo(args)


if __name__ == "__main__":
    main()
