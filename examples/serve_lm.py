"""Batched serving demo: continuous-batching engine over prefill/decode
steps with a KV cache.

  PYTHONPATH=src python examples/serve_lm.py [--arch starcoder2-15b]
"""
import argparse

import jax
import numpy as np

from repro.models import get_config, init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-15b")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=4, max_len=128)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 12),
                              dtype=np.int32)
        engine.submit(Request(rid, prompt, max_new_tokens=12))
    print(f"serving {args.requests} requests on {cfg.name} "
          f"(max_batch=4, greedy) ...")
    results = engine.run()
    for rid, toks in sorted(results.items()):
        print(f"  req {rid}: generated {toks}")


if __name__ == "__main__":
    main()
