"""Checkpoint/resume for the §4 pipeline (PR 8): a resumed run must be
*bitwise* equal to an uninterrupted one — merged Pareto front, per-seed
per-bracket results, and ``best()`` — with completed stages replayed
from their durable records (never recomputed), pinned both for an
in-process interrupt and (``-m slow``) a real SIGKILL mid-refinement.
"""
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core.dse.api import EngineConfig
from repro.core.dse.checkpoint import (CheckpointMismatch,
                                       PipelineCheckpoint, run_digest)
from repro.core.dse.engine import EvalEngine
from repro.core.dse.ga import GAConfig
from repro.core.dse.pipeline import run_pipeline

WLS = ["kan"]
CFG = GAConfig(population=16, generations=3, seed_top_k=8,
               early_stop=10_000)
KW = dict(seeds=(0, 1), brackets=(100.0, 200.0), samples_per_stratum=4,
          cfg=CFG)


def _engine():
    return EvalEngine(WLS, config=EngineConfig(backend="exact",
                                               nonfinite="skip"))


def _assert_same_study(ref, res):
    assert ref.front_points.tobytes() == res.front_points.tobytes()
    assert ref.front_genomes.tobytes() == res.front_genomes.tobytes()
    assert ref.evaluated == res.evaluated
    for s in KW["seeds"]:
        assert set(ref.results[s]) == set(res.results[s])
        for b, r in ref.results[s].items():
            q = res.results[s][b]
            assert r.best_fitness == q.best_fitness, (s, b)
            assert r.best_genome.tobytes() == q.best_genome.tobytes()
            assert r.history == q.history, (s, b)
            for k in ("latency", "energy", "tops_w"):
                assert np.asarray(r.best_metrics[k]).tobytes() == \
                    np.asarray(q.best_metrics[k]).tobytes(), (s, b, k)
    for b in KW["brackets"]:
        rb, qb = ref.best(b), res.best(b)
        assert (rb is None) == (qb is None)
        if rb is not None:
            assert rb.best_fitness == qb.best_fitness
            assert rb.best_genome.tobytes() == qb.best_genome.tobytes()


class _Interrupt(Exception):
    pass


def test_interrupted_resume_bitwise_equal(tmp_path):
    ref = run_pipeline(WLS, engine=_engine(), **KW)
    ck = str(tmp_path / "ck")

    seen = []

    def tripwire(ev):
        seen.append(ev["stage"])
        if len(seen) == 3:          # mid-study: after seed 0's 2nd stage
            raise _Interrupt

    with pytest.raises(_Interrupt):
        run_pipeline(WLS, checkpoint=ck, on_stage=tripwire, **KW)

    # no torn records: interrupted writes leave only final-name .npz
    assert not [f for f in os.listdir(ck) if f.endswith(".tmp")]

    events = []
    res = run_pipeline(WLS, checkpoint=ck,
                       on_stage=lambda ev: events.append(dict(ev)), **KW)
    # record-before-emit: every stage that reported before the interrupt
    # replays from its record, flagged resumed, and nothing re-runs
    resumed = [(e["stage"], e.get("seed"), e.get("bracket"))
               for e in events if e.get("resumed")]
    assert len(resumed) >= len(seen)
    assert [r[0] for r in resumed[:len(seen)]] == seen
    _assert_same_study(ref, res)

    # a fully-complete directory resumes everything: zero engine work
    eng = _engine()
    replay = run_pipeline(WLS, engine=eng, checkpoint=ck, **KW)
    assert eng.stats.dispatches == 0
    _assert_same_study(ref, replay)


def test_checkpoint_digest_guards_study_identity(tmp_path):
    ck = str(tmp_path / "ck")
    run_pipeline(WLS, checkpoint=ck, **KW)
    # same parameters: fine (resumes); different ones: refused
    run_pipeline(WLS, checkpoint=ck, **KW)
    with pytest.raises(CheckpointMismatch):
        run_pipeline(WLS, checkpoint=ck, **{**KW, "seeds": (0, 2)})
    with pytest.raises(CheckpointMismatch):
        run_pipeline(WLS, checkpoint=ck,
                     **{**KW, "cfg": GAConfig(population=32, generations=3,
                                              seed_top_k=8,
                                              early_stop=10_000)})


def test_checkpoint_record_load_roundtrip(tmp_path):
    ck = PipelineCheckpoint(str(tmp_path / "ck"))
    with pytest.raises(RuntimeError):
        ck.record("sweep:0", x=np.arange(3))    # open() must run first
    ck.open("digest-a")
    arr = np.array([5e-324, 1e308, -0.0, np.inf])
    ck.record("refine:0:100", vals=arr, n=np.int64(4))
    assert ck.has("refine:0:100") and not ck.has("sweep:0")
    # a second handle on the directory sees the same records, bitwise
    ck2 = PipelineCheckpoint(ck.path).open("digest-a")
    assert ck2.completed() == ["refine:0:100"]
    got = ck2.load("refine:0:100")
    assert got["vals"].tobytes() == arr.tobytes()
    assert int(got["n"]) == 4
    with pytest.raises(CheckpointMismatch):
        PipelineCheckpoint(ck.path).open("digest-b")


def test_run_digest_sensitivity():
    eng = _engine()
    base = run_digest(eng, (0, 1), (100.0,), 4, CFG, None, 5, 2)
    assert base == run_digest(eng, (0, 1), (100.0,), 4, CFG, None, 5, 2)
    assert base != run_digest(eng, (0, 2), (100.0,), 4, CFG, None, 5, 2)
    assert base != run_digest(eng, (0, 1), (200.0,), 4, CFG, None, 5, 2)
    assert base != run_digest(eng, (0, 1), (100.0,), 8, CFG, None, 5, 2)
    assert base != run_digest(eng, (0, 1), (100.0,), 4, CFG, 2, 5, 2)
    other = EvalEngine(["resnet50_int8"],
                       config=EngineConfig(backend="exact"))
    assert base != run_digest(other, (0, 1), (100.0,), 4, CFG, None, 5, 2)


_KILL_CHILD = textwrap.dedent("""
    import sys
    from repro.core.dse.engine import EvalEngine
    from repro.core.dse.ga import GAConfig
    from repro.core.dse.pipeline import run_pipeline

    def on_stage(ev):
        print(f"STAGE {ev['stage']}", flush=True)

    run_pipeline(["kan"], seeds=(0, 1), brackets=(100.0, 200.0),
                 samples_per_stratum=4,
                 cfg=GAConfig(population=16, generations=3, seed_top_k=8,
                              early_stop=10_000),
                 checkpoint=sys.argv[1], on_stage=on_stage)
    print("PIPELINE DONE", flush=True)
""")


@pytest.mark.slow
def test_sigkill_resume_bitwise_equal(tmp_path):
    """Kill -9 a checkpointed pipeline right after its first refinement
    reports, then resume: the study must equal an uninterrupted run
    bitwise, with the completed stages replayed (resumed events + zero
    dispatches for them) instead of recomputed."""
    ref = run_pipeline(WLS, engine=_engine(), **KW)
    ck = str(tmp_path / "ck")
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.Popen(
        [sys.executable, "-c", _KILL_CHILD, ck],
        stdout=subprocess.PIPE, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    stages = []
    try:
        for line in proc.stdout:       # SIGKILL mid-study, no warning
            if line.startswith("STAGE"):
                stages.append(line.split()[1])
            if len(stages) == 2:       # sweep + first refine reported
                proc.kill()            # SIGKILL: no atexit, no flush
                break
        assert proc.wait(timeout=60) == -signal.SIGKILL
    finally:
        if proc.poll() is None:        # pragma: no cover - cleanup
            proc.kill()
            proc.wait()
    assert stages == ["sweep", "refine"]

    # the records the child reported before dying are durable
    done = PipelineCheckpoint(ck).open(
        run_digest(_engine(), KW["seeds"], KW["brackets"],
                   KW["samples_per_stratum"], CFG, None, 5, 2)).completed()
    assert "sweep:0" in done

    events = []
    eng = _engine()
    res = run_pipeline(WLS, engine=eng, checkpoint=ck,
                       on_stage=lambda ev: events.append(dict(ev)), **KW)
    resumed = [(e["stage"], e.get("seed")) for e in events
               if e.get("resumed")]
    assert ("sweep", 0) in resumed     # skipped, not recomputed
    # resumed stages cost zero simulation: every dispatch the resumed
    # run made belongs to the stages the child never finished
    full = _engine()
    run_pipeline(WLS, engine=full, **KW)
    assert eng.stats.dispatches < full.stats.dispatches
    _assert_same_study(ref, res)
