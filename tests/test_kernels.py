"""Pallas kernels vs ref.py oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.dse_eval import dse_eval_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.horner import horner_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(1, 1, 128, 64), (2, 3, 256, 64),
                                   (1, 2, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(rng, shape, dtype, causal):
    B, H, S, D = shape
    q = jnp.asarray(rng.normal(size=shape), dtype)
    k = jnp.asarray(rng.normal(size=shape), dtype)
    v = jnp.asarray(rng.normal(size=shape), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_cross_lengths(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    ref = R.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(1, 64, 2, 16, 8), (2, 128, 3, 32, 16),
                                   (1, 256, 4, 64, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan(rng, shape, dtype):
    B, S, H, P, N = shape
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    al = jnp.asarray(rng.uniform(-1, 1, size=(H,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, N)), dtype)
    c = jnp.asarray(rng.normal(size=(B, S, N)), dtype)
    out = ssm_scan_pallas(x, dt, al, b, c, chunk=32, interpret=True)
    ref = R.ssm_scan_ref(x, dt, al, b, c, chunk=32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssm_scan_chunk_invariance(rng):
    """Different chunk sizes must give the same answer (state handoff)."""
    B, S, H, P, N = 1, 128, 2, 16, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    al = jnp.asarray(rng.uniform(-1, 1, size=(H,)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    o32 = ssm_scan_pallas(x, dt, al, b, c, chunk=32, interpret=True)
    o64 = ssm_scan_pallas(x, dt, al, b, c, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o32), np.asarray(o64),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n", [64, 1000, 8192])
@pytest.mark.parametrize("degree", [1, 3, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_horner(rng, n, degree, dtype):
    x = jnp.asarray(rng.normal(size=(n,)), dtype)
    cf = jnp.asarray(rng.normal(size=(degree + 1,)), jnp.float32)
    out = horner_pallas(x, cf, interpret=True)
    ref = R.horner_ref(x, cf)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
    assert out.shape == (n,)


@pytest.mark.parametrize("B,T,N", [(8, 8, 128), (16, 24, 256)])
def test_dse_eval(rng, B, T, N):
    tiles = rng.uniform(0.5, 2.0, size=(B, T, R.TILE_FIELDS)).astype(np.float32)
    tiles[..., 0] = (rng.random((B, T)) > 0.3)
    tiles[..., 1] = rng.integers(0, 4096, (B, T))
    tiles[..., 2] = 128.0
    tiles[..., 3] = 1e9
    tiles[..., 9] = 4e9
    ops = np.stack([
        rng.integers(0, 3, N), rng.integers(0, 10**6, N),
        rng.integers(1, 10**5, N), rng.integers(1, 10**6, N),
        np.ones(N), 2.0 ** rng.integers(0, 3, N), np.full(N, 512.0)],
        axis=1).astype(np.float32)
    out = np.asarray(dse_eval_pallas(jnp.asarray(tiles), jnp.asarray(ops),
                                     interpret=True))
    ref = np.asarray(R.dse_eval_ref(jnp.asarray(tiles), jnp.asarray(ops)))
    fin = np.isfinite(ref[..., 0])
    np.testing.assert_allclose(out[..., 0][fin], ref[..., 0][fin],
                               rtol=1e-5)
    np.testing.assert_allclose(out[..., 1], ref[..., 1], rtol=1e-4, atol=1.0)
    assert (np.isfinite(out[..., 0]) == fin).all()
