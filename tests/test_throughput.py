"""Throughput-mode (§3.2) dispatch and DSE surface.

Mode is a first-class scenario axis: ``emit_schedule`` stamps it,
``lower_plan`` carries it onto the plan table, every backend either
models it or raises a clear error, and the engine scores the pipelined
steady state (II / per-inference energy / steady-state TOPS/W) when asked
to.  Parity of the steady-state numbers themselves is pinned by
tests/test_golden_traces.py and tests/test_batched_parity.py.
"""
import numpy as np
import pytest

from repro.core import compile_workload, hetero_bls, simulate
from repro.core.compiler.batched_mapper import map_and_simulate
from repro.core.compiler.pipeline import lower_plan
from repro.core.compiler.schedule import SCHEDULE_MODES, emit_schedule
from repro.core.dse.api import EngineConfig
from repro.core.dse.encoding import random_genomes
from repro.core.dse.engine import EvalEngine, prepared_workload
from repro.core.dse.objective import serving_fitness
from repro.core.simulator.batched import (batch_simulate, simulate_plans,
                                          stack_chip_configs,
                                          stack_plan_tables)
from repro.core.simulator.orchestrator import ChipSim, ExecutionPlan
from repro.core.workloads import build

WORKLOAD = "kan"  # smallest golden-family workload: fast jit


def _plan(mode="throughput"):
    chip = hetero_bls()
    return chip, compile_workload(build(WORKLOAD), chip, mode=mode)


# ---------------------------------------------------------------- dispatch
def test_emit_schedule_rejects_unknown_mode():
    chip, plan = _plan("latency")
    with pytest.raises(ValueError, match="unknown schedule mode"):
        emit_schedule(plan.graph, plan.placements, mode="warp-speed")


def test_modes_change_results_not_just_tags():
    """The historical bug: both modes silently produced identical result
    surfaces.  Now the mode dispatches — throughput results carry the
    pipeline steady state, latency results do not."""
    chip, plan_t = _plan("throughput")
    r_t = simulate(chip, plan_t)
    r_l = simulate(chip, compile_workload(build(WORKLOAD), chip))
    assert r_l.mode == "latency" and r_l.pipeline is None
    assert r_t.mode == "throughput" and r_t.pipeline is not None
    assert r_t.pipeline["ii_s"] <= r_t.latency_s * (1 + 1e-12)
    assert r_l.ii_s == r_l.latency_s       # serial replay fallback
    assert r_t.ii_s == r_t.pipeline["ii_s"]


def test_chipsim_rejects_unknown_mode():
    chip, plan = _plan("latency")
    bad = ExecutionPlan(graph=plan.graph, placements=plan.placements,
                        mode="warp-speed")
    with pytest.raises(ValueError, match="cannot model schedule mode"):
        ChipSim(chip).run(bad)


def test_batched_executor_rejects_unknown_mode():
    chip, plan = _plan("latency")
    plans = stack_plan_tables([lower_plan(plan, chip.num_tiles)])
    cfgs = stack_chip_configs([chip])
    with pytest.raises(ValueError, match="cannot model schedule mode"):
        batch_simulate(plans, cfgs, mode="warp-speed")


def test_fused_mapper_rejects_unknown_mode():
    chip = hetero_bls()
    with pytest.raises(ValueError, match="cannot model schedule mode"):
        map_and_simulate(prepared_workload(WORKLOAD),
                         stack_chip_configs([chip]), mode="warp-speed")


def test_plan_table_carries_mode_and_mismatch_raises():
    chip, plan_t = _plan("throughput")
    _, plan_l = _plan("latency")
    t_t = lower_plan(plan_t, chip.num_tiles)
    t_l = lower_plan(plan_l, chip.num_tiles)
    assert (t_t.mode, t_l.mode) == ("throughput", "latency")
    with pytest.raises(ValueError, match="disagree on schedule mode"):
        stack_plan_tables([t_t, t_l])
    # stamped mode flows through to the executor without an explicit arg
    res = simulate_plans([chip], [t_t])
    assert res["mode"] == "throughput"


def test_chrome_trace_replays_batches_at_ii_offsets():
    chip, plan = _plan("throughput")
    r = simulate(chip, plan)
    import json
    ev = json.loads(r.chrome_trace(batches=3))["traceEvents"]
    per_batch = len(r.ops)
    assert len(ev) == 3 * per_batch
    ii_us = r.pipeline["ii_s"] * 1e6
    assert ev[2 * per_batch]["ts"] - ev[0]["ts"] == pytest.approx(
        2 * ii_us, rel=1e-9)
    r_l = simulate(chip, compile_workload(build(WORKLOAD), chip))
    with pytest.raises(ValueError, match="throughput-mode result"):
        r_l.chrome_trace(batches=2)


# ------------------------------------------------------------------ engine
def test_engine_mode_validation():
    with pytest.raises(ValueError, match="mode"):
        EvalEngine([WORKLOAD], config=EngineConfig(mode="warp-speed"))
    eng = EvalEngine([WORKLOAD])
    g = random_genomes(np.random.default_rng(0), 2)
    with pytest.raises(ValueError, match="mode"):
        eng.evaluate(g, mode="warp-speed")
    with pytest.raises(ValueError, match="mode"):
        eng.rescore(g, mode="warp-speed")
    assert set(SCHEDULE_MODES) == {"latency", "throughput"}


def test_engine_throughput_mode_scores_steady_state():
    """Scan-backend engine in throughput mode: latency column = II <=
    the latency-mode makespan; meta reports the mode; the per-mode memo
    keys keep the two modes from cross-contaminating."""
    g = random_genomes(np.random.default_rng(1), 6)
    eng = EvalEngine([WORKLOAD],
                     config=EngineConfig(mode="throughput"))
    m_t = eng.evaluate(g)
    assert m_t["meta"]["mode"] == "throughput"
    m_l = eng.evaluate(g, mode="latency")
    assert m_l["meta"]["mode"] == "latency"
    assert m_l["meta"]["hits"] == 0       # distinct memo namespace
    ok = np.isfinite(m_l["latency"])
    assert ok.any()
    assert np.all(m_t["latency"][ok] <= m_l["latency"][ok] * (1 + 1e-12))
    # memoized replay returns the mode-correct rows
    m_t2 = eng.evaluate(g)
    assert m_t2["meta"]["hits"] == len(g)
    np.testing.assert_array_equal(m_t2["latency"], m_t["latency"])


def test_engine_rescore_throughput_matches_oracle():
    """Exact rescore (fused batched mapper) vs the ChipSim oracle on the
    throughput surface — the tier-1 slice of the 0-rel-err acceptance
    bar (the full 20-workload sweep runs under -m slow)."""
    g = random_genomes(np.random.default_rng(2), 4)
    eng = EvalEngine([WORKLOAD],
                     config=EngineConfig(mode="throughput"))
    rb = eng.rescore(g)
    ro = eng.rescore(g, oracle=True)
    assert rb["meta"]["mode"] == ro["meta"]["mode"] == "throughput"
    ok = np.isfinite(ro["latency"])
    np.testing.assert_allclose(rb["latency"][ok], ro["latency"][ok],
                               rtol=1e-9)
    np.testing.assert_allclose(rb["energy"][ok], ro["energy"][ok],
                               rtol=1e-9)


# --------------------------------------------------------------- objective
def test_serving_fitness_ii_target():
    e = np.array([[1.0, 2.0], [0.5, 0.5], [3.0, 3.0]])
    ii = np.array([[1e-3, 2e-3], [1e-3, 5e-3], [1e-4, 1e-4]])
    s = serving_fitness(e, ii, 2e-3)
    assert s[1] == -np.inf                 # misses the rate target
    assert s[2] < s[0] < 0                 # lower energy wins among feasible
    # per-workload targets broadcast: relaxing workload 1's target makes
    # the previously infeasible row 1 feasible
    s2 = serving_fitness(e, ii, np.array([2e-3, 5e-3]))
    assert np.isfinite(s2[1])
    # infeasible/unmappable rows (inf energy) never win
    s3 = serving_fitness(np.array([[np.inf, np.inf]]),
                         np.array([[1e-9, 1e-9]]), 1.0)
    assert s3[0] == -np.inf
