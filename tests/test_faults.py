"""Deterministic fault-injection chaos suite (PR 8, ``-m chaos``).

Every fault class the harness injects is transient and value-preserving
(fail-then-retry, never wrong data), so the load-bearing assertion
throughout is *bitwise equality with a clean run* — resilience must not
cost determinism.  ``FAULT_SEED`` (CI matrixes over it) picks the
pseudorandom schedule; every schedule must pass.
"""
import sqlite3
import threading
import time

import numpy as np
import pytest

from repro.core.dse.api import EngineConfig
from repro.core.dse.encoding import random_genomes
from repro.core.dse.engine import EvalEngine, NonFiniteMetricsError
from repro.core.dse.faults import (FAULT_SITES, FaultInjector, FaultyStore,
                                   InjectedEngineError, InjectedStoreError,
                                   fault_seed_from_env,
                                   inject_engine_faults)
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.store import MemoryLRUStore, SqliteStore, TieredStore
from repro.core.dse.sweep import run_sweep
from repro.serve.dse_service import DSEClient, DSEService

pytestmark = pytest.mark.chaos

SEED = fault_seed_from_env()
WLS = ["kan"]


def _genomes(n=6, seed=3):
    return random_genomes(np.random.default_rng(seed), n)


# =============================================================================
# the injector itself
# =============================================================================

def test_injector_is_deterministic_and_order_independent():
    a = FaultInjector(seed=SEED, rates={s: 0.3 for s in FAULT_SITES})
    b = FaultInjector(seed=SEED, rates={s: 0.3 for s in FAULT_SITES})
    seq_a = [a.should_fire("store_put") for _ in range(64)]
    # interleaving other sites must not perturb store_put's schedule
    for i in range(64):
        b.should_fire("tcp_drop")
        assert b.should_fire("store_put") == seq_a[i]
    assert FaultInjector(seed=SEED + 1,
                         rates={"store_put": 0.3}) \
        .fired()["store_put"] == 0          # counters start untouched


def test_injector_exact_schedule_and_counters():
    inj = FaultInjector(seed=SEED, at={"sqlite_lock": (0, 2)})
    fires = [inj.should_fire("sqlite_lock") for _ in range(4)]
    assert fires == [True, False, True, False]
    assert inj.calls()["sqlite_lock"] == 4
    assert inj.fired()["sqlite_lock"] == 2
    with pytest.raises(ValueError):
        FaultInjector(rates={"bogus_site": 1.0})


def test_injector_thread_safety_counts_every_call():
    inj = FaultInjector(seed=SEED, rates={"store_get": 0.5})
    hits = []

    def spin():
        hits.append(sum(inj.should_fire("store_get") for _ in range(200)))

    ts = [threading.Thread(target=spin) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert inj.calls()["store_get"] == 800
    assert inj.fired()["store_get"] == sum(hits)


# =============================================================================
# store faults: sqlite lock retry + tiered LRU-only degradation
# =============================================================================

def test_sqlite_lock_retry_is_transparent(tmp_path):
    inj = FaultInjector(seed=SEED, at={"sqlite_lock": (0, 1)})
    st = SqliteStore(str(tmp_path / "r.sqlite"),
                     fault_injector=inj).bind(b"ctx")
    row = (np.arange(3.0), np.arange(3.0) * 2, np.arange(3.0) * 3)
    st.put(b"k", row)           # retried through two injected locks
    got = st.get(b"k")
    assert all(x.tobytes() == y.tobytes() for x, y in zip(got, row))
    assert inj.fired()["sqlite_lock"] >= 2
    st.close()


def test_sqlite_lock_exhaustion_raises(tmp_path):
    inj = FaultInjector(seed=SEED, rates={"sqlite_lock": 1.0})
    st = SqliteStore(str(tmp_path / "r.sqlite"), lock_retries=3,
                     fault_injector=inj).bind(b"ctx")
    with pytest.raises(sqlite3.OperationalError):
        st.put(b"k", (np.zeros(1), np.zeros(1), np.zeros(1)))


def test_tiered_degrades_to_lru_only_under_back_faults(tmp_path):
    inj = FaultInjector(seed=SEED, rates={"store_get": 1.0,
                                          "store_put": 1.0})
    back = FaultyStore(SqliteStore(str(tmp_path / "r.sqlite")), inj)
    st = TieredStore(MemoryLRUStore(), back).bind(b"ctx")
    row = (np.arange(3.0), np.arange(3.0) * 2, np.arange(3.0) * 3)
    with pytest.warns(RuntimeWarning, match="LRU-only"):
        st.put(b"k", row)       # back write fails -> front-only, warned
    st.put(b"k2", row)          # second failure: counted, NOT re-warned
    got = st.get(b"k")          # served from the front tier
    assert all(x.tobytes() == y.tobytes() for x, y in zip(got, row))
    assert st.stats.errors >= 2
    assert st.peek(b"k")


def test_engine_results_bitwise_equal_under_store_chaos(tmp_path):
    g = _genomes(8)
    clean = EvalEngine(WLS, config=EngineConfig(backend="exact")).evaluate(g)
    inj = FaultInjector(seed=SEED, rates={"store_get": 0.4,
                                          "store_put": 0.4})
    back = FaultyStore(SqliteStore(str(tmp_path / "r.sqlite")), inj)
    eng = EvalEngine(WLS, config=EngineConfig(
        backend="exact", store=TieredStore(MemoryLRUStore(), back)))
    with pytest.warns(RuntimeWarning):
        chaotic = eng.evaluate(g)
        again = eng.evaluate(g)
    for k in ("latency", "energy", "tops_w", "area"):
        assert clean[k].tobytes() == chaotic[k].tobytes(), k
        assert clean[k].tobytes() == again[k].tobytes(), k


# =============================================================================
# engine faults: exceptions + NaN poisoning
# =============================================================================

def test_injected_engine_exception_is_retryable_and_clean_on_retry():
    g = _genomes(5)
    clean = EvalEngine(WLS, config=EngineConfig(backend="exact")).evaluate(g)
    eng = inject_engine_faults(
        EvalEngine(WLS, config=EngineConfig(backend="exact")),
        FaultInjector(seed=SEED, at={"engine_exc": (0,)}))
    with pytest.raises(InjectedEngineError) as ei:
        eng.evaluate(g)
    assert ei.value.retryable
    retried = eng.evaluate(g)   # nothing memoized from the failed try
    for k in ("latency", "energy", "tops_w", "area"):
        assert clean[k].tobytes() == retried[k].tobytes(), k


def test_injected_nan_raises_then_retries_bitwise_clean():
    g = _genomes(5)
    clean = EvalEngine(WLS, config=EngineConfig(backend="exact")).evaluate(g)
    eng = inject_engine_faults(
        EvalEngine(WLS, config=EngineConfig(backend="exact")),
        FaultInjector(seed=SEED, at={"nan_metrics": (0,)}))
    with pytest.raises(NonFiniteMetricsError) as ei:
        eng.evaluate(g)
    assert ei.value.retryable
    assert ei.value.canon.shape == (g.shape[1],)    # names the genome
    retried = eng.evaluate(g)   # poisoned batch never reached the memo
    for k in ("latency", "energy", "tops_w", "area"):
        assert clean[k].tobytes() == retried[k].tobytes(), k


# =============================================================================
# service chaos: tenants stay bitwise-correct, nothing hangs
# =============================================================================

def _ga_setup():
    cfg = GAConfig(population=12, generations=3, seed_top_k=6,
                   early_stop=10_000)
    sweep = run_sweep(WLS, samples_per_stratum=4, seed=0,
                      brackets=(100.0, 200.0),
                      engine=EvalEngine(WLS, config=EngineConfig(backend="exact")))
    return cfg, sweep


def test_two_tenant_gas_bitwise_equal_under_service_chaos():
    """Two concurrent GA tenants against a service whose engine raises
    and NaN-poisons on an injected schedule: the batcher loop must
    survive, the clients' retries must converge, no future may hang,
    and both tenants' results must equal clean local runs bitwise."""
    cfg, sweep = _ga_setup()
    bracket = 200.0
    local = {s: run_ga(sweep, bracket, cfg, seed=s,
                       engine=EvalEngine(WLS, config=EngineConfig(backend="exact")))
             for s in (0, 1)}

    inj = FaultInjector(seed=SEED, at={"engine_exc": (1,),
                                       "nan_metrics": (3,)})
    eng = inject_engine_faults(EvalEngine(WLS, config=EngineConfig(backend="exact")), inj)
    svc = DSEService(eng, max_batch=256, max_wait_ms=50.0).start()
    served, errs = {}, []

    def tenant(s):
        try:
            served[s] = run_ga(sweep, bracket, cfg, seed=s,
                               engine=DSEClient(service=svc, retries=6,
                                                backoff_s=0.01))
        except BaseException as exc:    # pragma: no cover - surfaced below
            errs.append(exc)

    ts = [threading.Thread(target=tenant, args=(s,)) for s in (0, 1)]
    t0 = time.time()
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=300)
    assert not any(t.is_alive() for t in ts), "a tenant hung under chaos"
    assert not errs, errs
    assert time.time() - t0 < 300
    for s in (0, 1):
        assert served[s].best_fitness == local[s].best_fitness, s
        assert served[s].best_genome.tobytes() == \
            local[s].best_genome.tobytes(), s
        for k in ("latency", "energy", "tops_w"):
            assert np.asarray(served[s].best_metrics[k]).tobytes() == \
                np.asarray(local[s].best_metrics[k]).tobytes(), (s, k)
    assert not svc._inflight, "leaked in-flight futures"
    svc.stop()


def test_tcp_drops_are_survived_bitwise():
    """A TCP tenant whose connection the service keeps dropping must
    reconnect + idempotently retry to the same bytes a clean in-process
    evaluation returns."""
    g = _genomes(6)
    clean = EvalEngine(WLS, config=EngineConfig(backend="exact")).evaluate(g)
    inj = FaultInjector(seed=SEED, at={"tcp_drop": (1, 3)})
    svc = DSEService(EvalEngine(WLS, config=EngineConfig(backend="exact")),
                     fault_injector=inj).start()
    host, port = svc.listen()
    cli = DSEClient(address=(host, port), retries=6, backoff_s=0.01,
                    timeout=30.0)
    try:
        for _ in range(3):          # rides through both scheduled drops
            res = cli.evaluate(g)
            for k in ("latency", "energy", "tops_w", "area"):
                assert clean[k].tobytes() == res[k].tobytes(), k
        assert inj.fired()["tcp_drop"] == 2
    finally:
        cli.close()
        svc.stop()
    assert not svc._inflight
