"""Hypothesis property tests on system invariants."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.calibrate.asap7 import DEFAULT_CALIB
from repro.core.dse.pareto import pareto_mask
from repro.core.ir import OpNode, OpType, Precision, WorkloadGraph
from repro.core.simulator.tile import TileSim
from repro.core.arch import Sparsity, TileTemplate
from repro.kernels.ref import horner_ref
from repro.optim.schedule import warmup_cosine

SETTINGS = dict(max_examples=40, deadline=None)


@given(st.floats(0, 0.95), st.floats(0, 0.95),
       st.sampled_from(list(Sparsity)))
@settings(**SETTINGS)
def test_eta_bounds(act, w, mode):
    e = DEFAULT_CALIB.eta(int(mode), act, w)
    assert 1.0 <= e <= DEFAULT_CALIB.eta_cap


@given(st.integers(1, 512), st.integers(1, 512), st.integers(1, 512),
       st.sampled_from([Precision.INT8, Precision.FP16]))
@settings(**SETTINGS)
def test_execute_costs_positive_and_monotone_in_macs(m, k, n, prec):
    tile = TileSim(TileTemplate(name="t"))
    op = OpNode("mm", OpType.MATMUL, m=m, k=k, n=n, precision=prec).finalize()
    ex = tile.execute(op, 64.0, op.bytes_in + op.bytes_w, op.bytes_out)
    assert ex.cycles > 0 and ex.energy.total_pj > 0
    op2 = OpNode("mm2", OpType.MATMUL, m=m, k=k, n=2 * n,
                 precision=prec).finalize()
    ex2 = tile.execute(op2, 64.0, op2.bytes_in + op2.bytes_w, op2.bytes_out)
    assert ex2.energy.compute >= ex.energy.compute


@given(st.lists(st.lists(st.floats(0.0, 10.0), min_size=3, max_size=3),
                min_size=1, max_size=40))
@settings(**SETTINGS)
def test_pareto_mask_keeps_minima(points):
    pts = np.asarray(points)
    mask = pareto_mask(pts)
    assert mask.any()
    # per-axis minima are always non-dominated (first occurrence)
    for ax in range(3):
        i = int(np.argmin(pts[:, ax]))
        dominated = np.any(
            np.all(pts <= pts[i], axis=1) & np.any(pts < pts[i], axis=1))
        if not dominated:
            assert mask[i]


@given(st.integers(1, 64), st.integers(0, 8))
@settings(**SETTINGS)
def test_horner_ref_matches_numpy_polyval(n, degree):
    rng = np.random.default_rng(n * 31 + degree)
    x = rng.normal(size=n).astype(np.float32)
    cf = rng.normal(size=degree + 1).astype(np.float32)
    ours = np.asarray(horner_ref(jnp.asarray(x), jnp.asarray(cf)))
    ref = np.polyval(cf[::-1], x)
    np.testing.assert_allclose(ours, ref, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 20000))
@settings(**SETTINGS)
def test_schedule_bounded(step):
    s = float(warmup_cosine(step, warmup=200, total=10000))
    assert 0.0 <= s <= 1.0


@given(st.integers(1, 6), st.integers(2, 32))
@settings(**SETTINGS)
def test_workload_graph_ai_scales_with_reuse(layers, dim):
    """Adding MAC layers with the same operands raises total MACs
    monotonically; AI stays finite and positive."""
    g = WorkloadGraph("t", model_precision=Precision.INT8)
    prev = None
    for i in range(layers):
        prev = g.matmul(f"mm{i}", dim, dim, dim,
                        preds=[prev] if prev is not None else ())
    assert g.total_macs == layers * dim ** 3
    assert g.arithmetic_intensity() > 0


@given(st.floats(1e-6, 1.0), st.floats(1e-9, 1.0), st.floats(0.0, 1e9),
       st.floats(1.0, 256.0), st.floats(0.0, 1.0),
       st.lists(st.floats(0.0, 1e8), min_size=8, max_size=8),
       st.lists(st.floats(0.0, 0.5), min_size=16, max_size=16),
       st.integers(1, 8))
@settings(**SETTINGS)
def test_link_tier_ii_dominates_aggregate(makespan, tile_busy, dram_bytes,
                                          dram_gbps, noc_busy, chan,
                                          links, n_ch):
    """``pipeline_bounds`` with the link-tier occupancy vectors can only
    tighten the II: the aggregate bounds stay in the max, the channel and
    link bounds are added — so II(link) >= II(aggregate) for *any*
    occupancy split, and the shared aggregate keys are bitwise equal."""
    from repro.core.simulator.costs import MAX_DRAM_CHANNELS, MAX_LINKS
    chan_bytes = np.zeros(MAX_DRAM_CHANNELS)
    chan_bytes[:len(chan)] = chan
    link_busy = np.zeros(MAX_LINKS)
    link_busy[:len(links)] = links
    from repro.core.simulator.costs import pipeline_bounds
    agg = pipeline_bounds(np, makespan, tile_busy, dram_bytes, dram_gbps,
                          noc_busy)
    link = pipeline_bounds(np, makespan, tile_busy, dram_bytes, dram_gbps,
                           noc_busy, chan_bytes=chan_bytes,
                           dram_channels=float(n_ch), link_busy_s=link_busy)
    for k in ("ii_tile_bound_s", "ii_dram_bound_s", "ii_noc_bound_s"):
        assert float(link[k]) == float(agg[k]), k
    assert float(link["ii_s"]) >= float(agg["ii_s"])
    assert float(link["ii_s"]) <= makespan * (1 + 1e-12)
