"""CI guard: tier-1 internals are deprecation-clean.

Exercises every internal construction path — engine (all knob classes),
search frontends with default engines, and the service/client pair —
under ``-W error::DeprecationWarning``.  The legacy per-knob
``EvalEngine`` kwargs warn on purpose for *external* callers; this
script proves no in-repo caller still uses them (they must all go
through ``config=EngineConfig(...)``).

Run: ``PYTHONPATH=src python -W error::DeprecationWarning
tests/check_no_deprecations.py``
"""
import warnings

import numpy as np

from repro.core.dse.api import EngineConfig
from repro.core.dse.encoding import random_genomes
from repro.core.dse.engine import EvalEngine
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.sweep import run_sweep
from repro.serve.dse_service import DSEClient, DSEService

WLS = ["kan"]


def main():
    # the config path itself must be silent
    eng = EvalEngine(WLS, config=EngineConfig(backend="exact",
                                              fidelity="link"))
    g = random_genomes(np.random.default_rng(0), 8)
    eng.evaluate(g)
    eng.rescore(g[:2])
    eng.score_batch(g[:2])

    # search frontends constructing their own default engines
    sweep = run_sweep(WLS, samples_per_stratum=2, seed=0,
                      brackets=(200.0,))
    run_ga(sweep, 200.0, GAConfig(population=8, generations=2,
                                  seed_top_k=4, early_stop=100))

    # service + both client bindings
    svc = DSEService(EvalEngine(WLS)).start()
    try:
        cl = DSEClient(service=svc)
        cl.evaluate(g[:4])
        cl.context_key()
        host, port = svc.listen()
        tcp = DSEClient(address=(host, port))
        try:
            tcp.evaluate(g[:4])
        finally:
            tcp.close()
    finally:
        svc.stop()

    # and the shim still fires for legacy callers (sanity that the
    # guard would actually catch a regression)
    with warnings.catch_warnings():
        warnings.simplefilter("always")
        caught = warnings.catch_warnings(record=True)
        with caught as w:
            warnings.simplefilter("always")
            EvalEngine(WLS, backend="exact")
        assert any(issubclass(x.category, DeprecationWarning) for x in w), \
            "legacy-kwarg shim stopped warning"
    print("deprecation-clean: ok")


if __name__ == "__main__":
    main()
