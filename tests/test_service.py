"""DSE evaluation service (PR 6): bitwise parity of service-served
metrics vs the local engine (in-process and over the TCP front),
cross-request coalescing / in-flight dedup accounting, the client's
engine-interface contract (keep-prefilter, rescore, GA duck-typing),
and streamed search events."""
import threading

import numpy as np
import pytest

from repro.core.dse.encoding import random_genomes
from repro.core.dse.engine import EvalEngine, genome_areas
from repro.serve.dse_service import DSEClient, DSEService

WLS = ["kan"]
METRICS = ("latency", "energy", "tops_w", "area")


@pytest.fixture(scope="module")
def service():
    svc = DSEService(EvalEngine(WLS), max_batch=64, max_wait_ms=20.0)
    svc.start()
    yield svc
    svc.stop()


def _genomes(n=10, seed=5):
    return random_genomes(np.random.default_rng(seed), n)


def test_in_process_client_bitwise_parity(service):
    g = _genomes()
    local = EvalEngine(WLS).evaluate(g)
    cl = DSEClient(service=service)
    res = cl.evaluate(g)
    for k in METRICS:
        assert local[k].tobytes() == res[k].tobytes(), k
    meta = res["meta"]
    assert meta["requests"] == len(g)
    for key in ("queue_ms", "batch_occupancy", "store_hits", "hit_rate",
                "batches", "inflight_merged"):
        assert key in meta
    # repeat: everything served from the store, still bitwise identical
    again = cl.evaluate(g)
    assert again["meta"]["hit_rate"] == 1.0
    for k in METRICS:
        assert res[k].tobytes() == again[k].tobytes(), k


def test_tcp_client_bitwise_parity(service):
    g = _genomes(6, seed=6)
    host, port = service.listen()
    cl = DSEClient(address=(host, port))
    try:
        res = cl.evaluate(g)
        local = EvalEngine(WLS).evaluate(g)
        # JSON floats round-trip float64 exactly (shortest-repr), so the
        # wire adds no error: the TCP bytes equal the local computation
        for k in METRICS:
            assert local[k].tobytes() == res[k].tobytes(), k
        st = cl.service_stats()
        assert st["service"]["requests"] >= 1
    finally:
        cl.close()


def test_concurrent_tenants_share_dispatches(service):
    g = _genomes(16, seed=7)
    st = service.stats
    d0, merged0, hits0 = (st.engine_dispatches, st.inflight_merged,
                          st.store_hits)
    barrier = threading.Barrier(2)
    out, errs = {}, []

    def tenant(i):
        try:
            barrier.wait()
            out[i] = DSEClient(service=service).evaluate(g)
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=tenant, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    for k in METRICS:
        assert out[0][k].tobytes() == out[1][k].tobytes(), k
    # 16 unique genomes fit one engine chunk: exactly one fused dispatch
    # serves BOTH tenants — the duplicate request rides the in-flight
    # futures or the store, never the simulator
    assert st.engine_dispatches - d0 <= 1
    assert (st.inflight_merged - merged0) + (st.store_hits - hits0) >= len(g)


def test_client_keep_prefilter_matches_local(service):
    g = _genomes(12, seed=8)
    med = float(np.median(genome_areas(g)))

    def keep(areas):
        return areas <= med

    local = EvalEngine(WLS).evaluate(g, keep=keep)
    res = DSEClient(service=service).evaluate(g, keep=keep)
    for k in METRICS:
        assert local[k].tobytes() == res[k].tobytes(), k
    skipped = ~keep(genome_areas(g))
    assert np.all(np.isinf(res["latency"][skipped]))
    assert res["meta"]["requests"] == int((~skipped).sum())


def test_client_rescore_matches_local(service):
    g = _genomes(4, seed=9)
    local = EvalEngine(WLS).rescore(g)
    res = DSEClient(service=service).rescore(g)
    for k in ("latency", "energy", "tops_w"):
        assert local[k].tobytes() == res[k].tobytes(), k


def test_search_streams_generations(service):
    seeds = _genomes(8, seed=10)
    bracket = 200.0
    # a synthetic homogeneous baseline is enough to drive Eq. 8
    e_homo = np.full(len(WLS), 1e12)
    events = list(DSEClient(service=service).search(
        seeds, bracket, e_homo,
        cfg={"population": 8, "generations": 2, "seed_top_k": 4,
             "early_stop": 10_000}, seed=0))
    kinds = [e["event"] for e in events]
    assert kinds[-1] == "done" and kinds[:-1] == ["generation"] * 3
    for ev in events[:-1]:
        assert ev["front_size"] == len(ev["front"]["points"])
        assert all(len(p) == 3 for p in ev["front"]["points"])
    res = events[-1]["result"]
    assert res is None or "best_fitness" in res


def test_client_requires_exactly_one_transport(service):
    with pytest.raises(ValueError):
        DSEClient()
    with pytest.raises(ValueError):
        DSEClient(service=service, address=("127.0.0.1", 1))
