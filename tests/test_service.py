"""DSE evaluation service (PR 6): bitwise parity of service-served
metrics vs the local engine (in-process and over the TCP front),
cross-request coalescing / in-flight dedup accounting, the client's
engine-interface contract (keep-prefilter, rescore, GA duck-typing),
and streamed search events."""
import threading

import numpy as np
import pytest

from repro.core.dse.encoding import random_genomes
from repro.core.dse.engine import EvalEngine, genome_areas
from repro.serve.dse_service import DSEClient, DSEService

WLS = ["kan"]
METRICS = ("latency", "energy", "tops_w", "area")


@pytest.fixture(scope="module")
def service():
    svc = DSEService(EvalEngine(WLS), max_batch=64, max_wait_ms=20.0)
    svc.start()
    yield svc
    svc.stop()


def _genomes(n=10, seed=5):
    return random_genomes(np.random.default_rng(seed), n)


def test_in_process_client_bitwise_parity(service):
    g = _genomes()
    local = EvalEngine(WLS).evaluate(g)
    cl = DSEClient(service=service)
    res = cl.evaluate(g)
    for k in METRICS:
        assert local[k].tobytes() == res[k].tobytes(), k
    meta = res["meta"]
    assert meta["requests"] == len(g)
    for key in ("queue_ms", "batch_occupancy", "store_hits", "hit_rate",
                "batches", "inflight_merged"):
        assert key in meta
    # repeat: everything served from the store, still bitwise identical
    again = cl.evaluate(g)
    assert again["meta"]["hit_rate"] == 1.0
    for k in METRICS:
        assert res[k].tobytes() == again[k].tobytes(), k


def test_tcp_client_bitwise_parity(service):
    g = _genomes(6, seed=6)
    host, port = service.listen()
    cl = DSEClient(address=(host, port))
    try:
        res = cl.evaluate(g)
        local = EvalEngine(WLS).evaluate(g)
        # JSON floats round-trip float64 exactly (shortest-repr), so the
        # wire adds no error: the TCP bytes equal the local computation
        for k in METRICS:
            assert local[k].tobytes() == res[k].tobytes(), k
        st = cl.service_stats()
        assert st["service"]["requests"] >= 1
    finally:
        cl.close()


def test_concurrent_tenants_share_dispatches(service):
    g = _genomes(16, seed=7)
    st = service.stats
    d0, merged0, hits0 = (st.engine_dispatches, st.inflight_merged,
                          st.store_hits)
    barrier = threading.Barrier(2)
    out, errs = {}, []

    def tenant(i):
        try:
            barrier.wait()
            out[i] = DSEClient(service=service).evaluate(g)
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=tenant, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    for k in METRICS:
        assert out[0][k].tobytes() == out[1][k].tobytes(), k
    # 16 unique genomes fit one engine chunk: exactly one fused dispatch
    # serves BOTH tenants — the duplicate request rides the in-flight
    # futures or the store, never the simulator
    assert st.engine_dispatches - d0 <= 1
    assert (st.inflight_merged - merged0) + (st.store_hits - hits0) >= len(g)


def test_client_keep_prefilter_matches_local(service):
    g = _genomes(12, seed=8)
    med = float(np.median(genome_areas(g)))

    def keep(areas):
        return areas <= med

    local = EvalEngine(WLS).evaluate(g, keep=keep)
    res = DSEClient(service=service).evaluate(g, keep=keep)
    for k in METRICS:
        assert local[k].tobytes() == res[k].tobytes(), k
    skipped = ~keep(genome_areas(g))
    assert np.all(np.isinf(res["latency"][skipped]))
    assert res["meta"]["requests"] == int((~skipped).sum())


def test_client_rescore_matches_local(service):
    g = _genomes(4, seed=9)
    local = EvalEngine(WLS).rescore(g)
    res = DSEClient(service=service).rescore(g)
    for k in ("latency", "energy", "tops_w"):
        assert local[k].tobytes() == res[k].tobytes(), k


def test_search_streams_generations(service):
    seeds = _genomes(8, seed=10)
    bracket = 200.0
    # a synthetic homogeneous baseline is enough to drive Eq. 8
    e_homo = np.full(len(WLS), 1e12)
    events = list(DSEClient(service=service).search(
        seeds, bracket, e_homo,
        cfg={"population": 8, "generations": 2, "seed_top_k": 4,
             "early_stop": 10_000}, seed=0))
    kinds = [e["event"] for e in events]
    assert kinds[-1] == "done" and kinds[:-1] == ["generation"] * 3
    for ev in events[:-1]:
        assert ev["front_size"] == len(ev["front"]["points"])
        assert all(len(p) == 3 for p in ev["front"]["points"])
    res = events[-1]["result"]
    assert res is None or "best_fitness" in res


def test_client_requires_exactly_one_transport(service):
    with pytest.raises(ValueError):
        DSEClient()
    with pytest.raises(ValueError):
        DSEClient(service=service, address=("127.0.0.1", 1))


# =============================================================================
# resilience (PR 8): stop/health/backpressure/deadline/fail-fast
# =============================================================================

def test_stop_is_idempotent_and_close_is_an_alias():
    svc = DSEService(EvalEngine(WLS)).start()
    assert svc.health()["status"] == "ok"
    svc.stop()
    svc.stop()                  # second stop: silent no-op
    svc.close()                 # alias, also a no-op now
    assert svc.health()["status"] == "stopped"
    assert svc._loop is None and svc._thread is None


def test_health_in_process_and_over_the_wire(service):
    h = service.health()
    assert h["status"] == "ok" and h["uptime_s"] >= 0
    assert {"queue_depth", "max_queue", "inflight"} <= set(h)
    host, port = service.listen()
    cl = DSEClient(address=(host, port))
    try:
        hw = cl.health()
        assert hw["status"] == "ok"
    finally:
        cl.close()


def test_overload_rejects_with_retryable_error():
    from repro.serve.dse_service import OverloadedError
    svc = DSEService(EvalEngine(WLS), max_queue=1).start()
    try:
        with pytest.raises(OverloadedError) as ei:
            # 4 genomes > a 1-slot queue: rejected at admission, and the
            # client's retries see the same overload each time
            DSEClient(service=svc, retries=1,
                      backoff_s=0.01).evaluate(_genomes(4, seed=11))
        assert getattr(ei.value, "retryable", False)
        assert svc._queue.qsize() == 0        # nothing half-enqueued
    finally:
        svc.stop()


def test_deadline_bounds_the_wait_not_the_work():
    import asyncio

    from repro.serve.dse_service import DeadlineExceededError
    svc = DSEService(EvalEngine(WLS), max_wait_ms=1.0).start()
    g = _genomes(6, seed=12)
    try:
        with pytest.raises(DeadlineExceededError):
            asyncio.run_coroutine_threadsafe(
                svc.evaluate(g, deadline_s=1e-9), svc._loop).result()
        # the shared futures kept running: an unbounded follow-up gets
        # the full (bitwise-correct) answer
        out = asyncio.run_coroutine_threadsafe(
            svc.evaluate(g), svc._loop).result()
        local = EvalEngine(WLS).evaluate(g)
        for k in METRICS:
            assert local[k].tobytes() == out[k].tobytes(), k
    finally:
        svc.stop()


def test_dead_server_fails_fast_not_600s():
    import time
    svc = DSEService(EvalEngine(WLS)).start()
    host, port = svc.listen()
    cl = DSEClient(address=(host, port), retries=2, backoff_s=0.01)
    cl.evaluate(_genomes(3, seed=13))
    svc.stop()
    t0 = time.time()
    with pytest.raises((ConnectionError, OSError)):
        cl.evaluate(_genomes(3, seed=13))
    # EOF/refused surfaces through the bounded retry loop in seconds —
    # never a silent hang until the 600 s socket timeout
    assert time.time() - t0 < 30
    cl.close()


def test_client_deadline_caps_reconnect_storm():
    """Satellite (PR 10): a client with ``deadline_s`` never spends
    longer reconnecting than the request's remaining budget, and the
    failure surfaces as ``DeadlineExceededError`` — not a generic
    ``ConnectionError`` after the full retries x backoff storm."""
    import time

    from repro.serve.dse_service import DeadlineExceededError
    svc = DSEService(EvalEngine(WLS)).start()
    host, port = svc.listen()
    # a generous retry policy that would spend many seconds reconnecting
    # without the deadline: 8 retries, backoff up to 5 s per attempt
    cl = DSEClient(address=(host, port), retries=8, backoff_s=0.2,
                   backoff_max_s=5.0, deadline_s=0.5)
    g = _genomes(3, seed=14)
    res = cl.evaluate(g)                 # healthy round trip first
    assert res["latency"].shape == (3, len(WLS))
    svc.stop()
    t0 = time.time()
    with pytest.raises(DeadlineExceededError):
        cl.evaluate(_genomes(3, seed=15))
    # the 0.5 s budget bounds the whole storm (with margin for the
    # in-flight connect attempt), instead of ~8 x backoff
    assert time.time() - t0 < 3.0
    cl.close()


def test_client_without_deadline_keeps_connectionerror_contract():
    # no deadline_s: the pre-existing bounded-retry behaviour and error
    # class are unchanged
    svc = DSEService(EvalEngine(WLS)).start()
    host, port = svc.listen()
    cl = DSEClient(address=(host, port), retries=1, backoff_s=0.01)
    cl.evaluate(_genomes(2, seed=16))
    svc.stop()
    with pytest.raises((ConnectionError, OSError)):
        cl.evaluate(_genomes(2, seed=16))
    cl.close()


def test_stop_fails_undrained_futures_loudly():
    import time
    svc = DSEService(EvalEngine(WLS)).start()
    # park a future the batcher will never resolve (bypass the queue)
    fut = None

    def plant():
        nonlocal fut
        f = svc._loop.create_future()
        svc._inflight[b"orphan"] = f
        fut = f

    svc._loop.call_soon_threadsafe(plant)
    deadline = time.time() + 10
    while fut is None and time.time() < deadline:
        time.sleep(0.01)
    assert fut is not None
    svc.stop(drain=False)
    # nothing hangs forever: stop() failed the orphan with a clear error
    assert isinstance(fut.exception(), ConnectionError)
