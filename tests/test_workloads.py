"""Workload suite (paper Table 1): 20 workloads, coverage criteria §4.1."""
import numpy as np
import pytest

from repro.core.ir import OpClass, OpType, Precision
from repro.core.workloads import build, suite, workload_names
from repro.core.workloads.suite import GROUPS


def test_suite_has_20_workloads():
    assert len(workload_names()) == 20


def test_all_build_and_validate():
    for name, g in suite().items():
        g.validate()
        assert len(g.nodes) > 3


def test_all_23_op_types_exercised():
    seen = set()
    for g in suite().values():
        for nd in g.nodes:
            seen.add(int(nd.op_type))
    assert seen == set(range(23))


def test_all_three_paths_stressed():
    cls = {OpClass.MAC: 0, OpClass.DSP: 0, OpClass.SPECIAL: 0}
    for g in suite().values():
        for nd in g.nodes:
            cls[nd.op_cls] += 1
    assert all(v > 0 for v in cls.values())


def test_arithmetic_intensity_spans_orders_of_magnitude():
    ais = [g.arithmetic_intensity() for g in suite().values()
           if g.total_macs > 0]
    assert max(ais) / max(min(ais), 1e-9) > 50


def test_spec_decode_is_bandwidth_bound():
    ai = {name: g.arithmetic_intensity() for name, g in suite().items()
          if g.total_macs > 0}
    assert ai["spec_decode"] == min(ai.values())
    assert ai["spec_decode"] < 5  # paper: 2.4


def test_quantized_variants_ship_quantized():
    assert build("llama7b_int4").model_precision == Precision.INT4
    assert build("llama7b_int8").model_precision == Precision.INT8
    assert build("mixtral_int4").model_precision == Precision.INT4


def test_groups_partition_the_suite():
    names = set(workload_names())
    grouped = set(sum(GROUPS.values(), []))
    assert grouped == names


def test_non_mac_workloads_have_special_or_dominant_dsp():
    for name in GROUPS["non_mac"]:
        g = build(name)
        h = g.class_histogram()
        assert h["SPECIAL"] > 0 or h["DSP"] > h["MAC"], name


def test_hyena_fft_share():
    g = build("hyena_1_3b")
    fft_elems = sum(nd.elems for nd in g.nodes if nd.op_type == OpType.FFT)
    assert fft_elems > 0
