"""Simulator invariants: Eq. 4-7 behaviours, gating, orchestrator."""
import math

import pytest

from repro.core import compile_workload, hetero_bls, homogeneous_baseline, simulate
from repro.core.arch import (ChipConfig, Dataflow, Engine, Sparsity,
                             TileTemplate, big_tile, little_tile, special_tile)
from repro.core.calibrate.asap7 import DEFAULT_CALIB
from repro.core.calibrate.nvdla import NVDLA_FULL, NVDLA_SMALL, nvdla_chip
from repro.core.ir import OpNode, OpType, Precision, WorkloadGraph
from repro.core.simulator.area import area_breakdown, chip_area, tile_area
from repro.core.simulator.tile import TileSim


def _mm(m=512, k=512, n=512, prec=Precision.INT8, **kw):
    return OpNode("mm", OpType.MATMUL, m=m, k=k, n=n, precision=prec,
                  **kw).finalize()


def test_bigger_array_fewer_cycles():
    small = TileSim(TileTemplate(name="s", rows=16, cols=16))
    big = TileSim(TileTemplate(name="b", rows=64, cols=64))
    op = _mm()
    assert big.execute(op, 64, 1e6, 1e5).cycles \
        < small.execute(op, 64, 1e6, 1e5).cycles


def test_double_buffering_overlaps():
    t_db = TileSim(TileTemplate(name="db", double_buffer=True))
    t_nd = TileSim(TileTemplate(name="nd", double_buffer=False))
    op = _mm()
    assert t_db.execute(op, 64, 1e6, 1e5).cycles \
        < t_nd.execute(op, 64, 1e6, 1e5).cycles


def test_sparsity_speeds_up_and_saves_energy():
    dense = TileSim(TileTemplate(name="d", sparsity=Sparsity.NONE))
    sparse = TileSim(TileTemplate(name="s", sparsity=Sparsity.TWO_SIDED))
    op = _mm(act_sparsity=0.5, w_sparsity=0.5)
    ed = dense.execute(op, 64, 1e6, 1e5)
    es = sparse.execute(op, 64, 1e6, 1e5)
    assert es.energy.compute < ed.energy.compute
    # compute-bound op gets faster too
    assert es.cycles <= ed.cycles


def test_precision_energy_ordering():
    tile = TileSim(TileTemplate(
        name="t", precisions=frozenset({Precision.INT4, Precision.INT8,
                                        Precision.FP16})))
    e4 = tile.execute(_mm(prec=Precision.INT4), 64, 1e6, 1e5).energy.compute
    e8 = tile.execute(_mm(prec=Precision.INT8), 64, 1e6, 1e5).energy.compute
    e16 = tile.execute(_mm(prec=Precision.FP16), 64, 1e6, 1e5).energy.compute
    assert e4 < e8 < e16


def test_datapath_residual_charges_narrow_on_wide():
    wide = TileSim(TileTemplate(name="w", precisions=frozenset(
        {Precision.INT8, Precision.FP16})))
    narrow = TileSim(TileTemplate(name="n", precisions=frozenset(
        {Precision.INT8})))
    op = _mm(prec=Precision.INT8)
    assert wide.execute(op, 64, 1e6, 1e5).energy.compute \
        > narrow.execute(op, 64, 1e6, 1e5).energy.compute


def test_sfu_native_beats_lowering_on_energy():
    sfu = TileSim(special_tile())
    mac = TileSim(big_tile())
    fft = OpNode("fft", OpType.FFT, elems=8192, fft_n=512,
                 precision=Precision.FP16).finalize()
    e_sfu = sfu.execute(fft, 64, 1e5, 1e5).energy
    e_mac = mac.execute(fft, 64, 1e5, 1e5).energy
    assert e_sfu.special + e_sfu.dsp < (e_mac.compute) / 10  # ~100x asymptotic


def test_area_model_eq7_max_precision():
    t8 = TileTemplate(name="a", precisions=frozenset({Precision.INT8}))
    t16 = TileTemplate(name="b", precisions=frozenset({Precision.INT8,
                                                       Precision.FP16}))
    assert tile_area(t16) > tile_area(t8)
    bd = area_breakdown(t16)
    assert set(bd) == {"mac", "sram", "dsp", "special", "ports"}
    assert bd["special"] == 0.0


def test_nvdla_peak_tops_by_construction():
    for pt in (NVDLA_SMALL, NVDLA_FULL):
        chip = nvdla_chip(pt)
        tile = chip.instances()[0]
        tops = tile.num_macs * tile.clock_mhz * 1e6 / 1e12
        assert tops == pytest.approx(pt.peak_tops, rel=1e-6)


def test_power_gating_residual():
    # a chip where one tile type never runs anything leaks at 5 %
    g = WorkloadGraph("t", model_precision=Precision.INT8)
    g.matmul("mm", 64, 64, 64)
    chip = hetero_bls()
    r = simulate(chip, compile_workload(g, chip))
    gated = [b for b in r.tiles if b.power_gated]
    active = [b for b in r.tiles if not b.power_gated]
    assert gated and active
    for b in gated:
        tmpl = chip.instances()[b.tile_index]
        full = DEFAULT_CALIB.leak_mw_per_mm2 * tile_area(tmpl) \
            * r.latency_s * 1e9
        assert b.energy.leakage == pytest.approx(full * 0.05, rel=1e-6)


def test_makespan_at_least_per_tile_active():
    from repro.core.workloads import build
    g = build("vit_b16_fp16")
    chip = homogeneous_baseline(4)
    r = simulate(chip, compile_workload(g, chip))
    for b in r.tiles:
        assert b.active_s <= r.latency_s + 1e-12
    assert r.energy_pj > 0 and r.area_mm2 > 0


def test_chrome_trace_emits_events():
    import json
    from repro.core.workloads import build
    g = build("kan")
    chip = hetero_bls()
    r = simulate(chip, compile_workload(g, chip))
    trace = json.loads(r.chrome_trace())
    assert len(trace["traceEvents"]) >= 5
