"""Fused multi-seed pipeline (PR 7).

Pins the PR's load-bearing contracts:

* THE bitwise invariant — a seeded single-island ``run_ga_fused`` run
  (device-resident memo, whole refinement as one dispatch) equals the
  host-memo device loop ``run_ga(loop="device")`` genome-for-genome
  (best_genome + history + fitness), with warm memo state bitwise inert;
* the device config mirror — ``_chip_area_device``/``_configs_device``
  areas equal the host ``genome_areas`` bit-for-bit (the Eq. 8 band
  input; host-precomputed gather tables, no device mul->add chains);
* ``bracket_bounds`` NaN path — unknown brackets score every design
  -inf, known brackets reproduce ``area_bracket`` membership exactly;
* island-model determinism — same-seed island runs replay bitwise, on
  one device and (``-m slow``) under ``shard=True`` with the island
  axis sharded over forced host devices;
* ``run_pipeline`` — stage events, cumulative Pareto-front validity,
  cross-seed best() accounting.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.dse.api import EngineConfig
from repro.core.dse.engine import EvalEngine, genome_areas
from repro.core.dse.encoding import GENOME_LEN, random_genomes
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.ga_device import (bracket_bounds, fitness_device,
                                      run_ga_fused)
from repro.core.dse.objective import AREA_BRACKETS, area_bracket
from repro.core.dse.pareto import pareto_mask
from repro.core.dse.pipeline import run_pipeline
from repro.core.dse.sweep import run_sweep

WLS = ["kan"]
CFG = GAConfig(population=16, generations=3, seed_top_k=8, early_stop=100)


def _sweep():
    return run_sweep(WLS, samples_per_stratum=4, seed=0,
                     brackets=(100.0, 200.0))


def _exact():
    return EvalEngine(WLS, config=EngineConfig(backend="exact"))


def _same(a, b) -> bool:
    return (a is not None and b is not None
            and np.array_equal(a.best_genome, b.best_genome)
            and a.history == b.history
            and a.best_fitness == b.best_fitness)


# ---------------------------------------------------------------- invariant
def test_fused_bitwise_equals_host_memo_device_loop():
    sw = _sweep()
    dev = run_ga(sw, 200.0, CFG, seed=1, engine=_exact(), loop="device")
    fused = run_ga(sw, 200.0, CFG, seed=1, engine=_exact(), loop="fused")
    assert _same(dev, fused)
    assert dev.evaluated == fused.evaluated


def test_fused_warm_memo_is_bitwise_inert():
    """Replaying on an engine whose store already holds every row (and
    preloading it into the device memo) changes nothing: memo hits are
    served bitwise, all-hit generations skip the scan."""
    sw = _sweep()
    eng = _exact()
    cold = run_ga_fused(sw, 200.0, CFG, seed=2, engine=eng, islands=1)
    warm = run_ga_fused(sw, 200.0, CFG, seed=2, engine=eng, islands=1)
    assert _same(cold.result, warm.result)
    assert cold.generations_run == warm.generations_run
    assert np.array_equal(cold.population, warm.population)
    for k in cold.pop_metrics:
        assert np.array_equal(cold.pop_metrics[k], warm.pop_metrics[k])


def test_fused_frontend_validation():
    sw = _sweep()
    with pytest.raises(ValueError, match="fused"):
        run_ga(sw, 200.0, CFG, seed=0, loop="fused",
               on_generation=lambda **kw: None)
    with pytest.raises(ValueError, match="exact"):
        run_ga_fused(sw, 200.0, CFG, seed=0,
                     engine=EvalEngine(WLS, config=EngineConfig(backend="scan")))
    # a bracket with no homogeneous baseline returns None (run_ga
    # contract) — the baseline is cumulative over brackets, so only a
    # bracket BELOW every sampled homo design lacks one
    assert 50.0 not in sw.homo_baseline()
    assert run_ga_fused(sw, 50.0, CFG, seed=0, engine=_exact()) is None


def test_oversized_seed_set_truncates_to_population():
    """seed_top_k > population with enough in-bracket sweep survivors
    used to leave generation 0 over-populated: the host loop silently
    ran it at the wrong size and the fused while_loop crashed on the
    shape mismatch.  All loops must seed exactly ``population`` genomes
    — and still agree bitwise."""
    sw = run_sweep(WLS, samples_per_stratum=16, seed=0, brackets=(200.0,))
    cfg = GAConfig(population=8, generations=2, seed_top_k=50,
                   early_stop=100)
    fit = sw.fitness(cfg.alpha)
    assert ((sw.bracket == 200.0) & np.isfinite(fit)).sum() > cfg.population
    dev = run_ga(sw, 200.0, cfg, seed=1, engine=_exact(), loop="device")
    fused = run_ga(sw, 200.0, cfg, seed=1, engine=_exact(), loop="fused")
    assert _same(dev, fused)
    assert dev.evaluated == fused.evaluated


# ------------------------------------------------------------ device configs
def test_device_areas_bitwise_equal_host():
    from repro.core.dse.ga_device import _chip_area_device, _configs_device
    import jax
    from repro.core.calibrate.asap7 import DEFAULT_CALIB

    rng = np.random.default_rng(17)
    g = np.concatenate([random_genomes(rng, 32, family=f)
                        for f in (None, "homo", "hetero_bl", "hetero_bls")])
    host = genome_areas(g)
    area_only = np.asarray(jax.jit(
        lambda x: _chip_area_device(x, DEFAULT_CALIB))(g.astype(np.int32)))
    assert host.tobytes() == area_only.tobytes()
    _, _, full = jax.jit(
        lambda x: _configs_device(x, DEFAULT_CALIB))(g.astype(np.int32))
    assert host.tobytes() == np.asarray(full).tobytes()


# ------------------------------------------------------------- bracket band
def test_bracket_bounds_unknown_bracket_nan():
    lo, hi = bracket_bounds(123.0)
    assert np.isnan(lo) and np.isnan(hi)
    # host parity: area_bracket never assigns an unknown bracket, so the
    # device band must reject every area -> all fitness -inf
    metrics = {"latency": np.ones((4, 1)), "energy": np.ones((4, 1)),
               "tops_w": np.ones((4, 1)),
               "area": np.array([10.0, 100.0, 400.0, 1e6])}
    fit = fitness_device(metrics, np.ones(1), 123.0)
    assert np.all(fit == -np.inf)


def test_bracket_bounds_band_matches_area_bracket():
    areas = np.concatenate([np.asarray(AREA_BRACKETS),
                            np.asarray(AREA_BRACKETS) + 1e-9,
                            np.asarray(AREA_BRACKETS) - 1e-9,
                            [1e-3, 25.0, 1e5]])
    for b in AREA_BRACKETS:
        lo, hi = bracket_bounds(b)
        for a in areas:
            assert ((lo < a <= hi) == (area_bracket(float(a)) == b)), (b, a)


# ----------------------------------------------------------------- islands
def test_island_ga_seeded_determinism():
    sw = _sweep()
    r1 = run_ga_fused(sw, 200.0, CFG, seed=3, engine=_exact(), islands=2,
                      migrate_every=1, migrate_k=2)
    r2 = run_ga_fused(sw, 200.0, CFG, seed=3, engine=_exact(), islands=2,
                      migrate_every=1, migrate_k=2)
    assert _same(r1.result, r2.result)
    assert np.array_equal(r1.population, r2.population)
    # islands partition the population: a different trajectory from the
    # panmictic run is expected (not asserted), but validity must hold
    assert np.isfinite(r1.result.best_fitness)


def test_island_validation():
    sw = _sweep()
    with pytest.raises(ValueError, match="divisible"):
        run_ga_fused(sw, 200.0, CFG, seed=0, engine=_exact(), islands=3)
    tiny = GAConfig(population=4, generations=1, seed_top_k=2)
    with pytest.raises(ValueError, match="elites"):
        run_ga_fused(sw, 200.0, tiny, seed=0, engine=_exact(), islands=4)


@pytest.mark.slow
def test_island_ga_determinism_under_shard():
    """Under forced host devices with ``shard=True`` (island axis
    sharded over the device ring, migration lowered to a collective
    permute) the seeded island GA replays bitwise — and matches the
    single-device run of the identical configuration computed in the
    parent process."""
    ref = run_ga_fused(_sweep(), 200.0, CFG, seed=4, engine=_exact(),
                       islands=4, migrate_every=1, migrate_k=1)
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.core.dse.engine import EvalEngine
from repro.core.dse.ga import GAConfig
from repro.core.dse.ga_device import run_ga_fused
from repro.launch.mesh import island_sharding
from repro.core.dse.sweep import run_sweep
assert island_sharding(4) is not None
sw = run_sweep(["kan"], samples_per_stratum=4, seed=0,
               brackets=(100.0, 200.0))
cfg = GAConfig(population=16, generations=3, seed_top_k=8, early_stop=100)
runs = [run_ga_fused(sw, 200.0, cfg, seed=4,
                     engine=EvalEngine(["kan"], config=EngineConfig(
                         backend="exact", shard=True)),
                     islands=4, migrate_every=1, migrate_k=1)
        for _ in range(2)]
a, b = (r.result for r in runs)
assert np.array_equal(a.best_genome, b.best_genome)
assert a.history == b.history and a.best_fitness == b.best_fitness
print("GENOME", a.best_genome.tobytes().hex())
print("HIST", ",".join(repr(float(h)) for h in a.history))
"""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=env)
    assert "GENOME" in out.stdout, out.stderr[-2000:]
    lines = dict(l.split(" ", 1) for l in out.stdout.strip().splitlines()
                 if " " in l)
    assert lines["GENOME"] == ref.result.best_genome.tobytes().hex()
    assert lines["HIST"] == ",".join(repr(float(h))
                                     for h in ref.result.history)


# ---------------------------------------------------------------- pipeline
def test_run_pipeline_stages_and_front():
    events = []
    res = run_pipeline(WLS, seeds=(0, 1), brackets=(100.0, 200.0),
                       samples_per_stratum=4, cfg=CFG, engine=_exact(),
                       islands=1, on_stage=events.append)
    stages = [e["stage"] for e in events]
    assert stages.count("sweep") == 2 and stages.count("seed_done") == 2
    assert stages.count("refine") == 4
    # the cumulative front: sorted by mean energy, all points mutually
    # non-dominating, genomes aligned
    assert res.front_points.shape[1] == 3
    assert res.front_genomes.shape == (len(res.front_points), GENOME_LEN)
    assert np.all(np.diff(res.front_points[:, 0]) >= 0)
    assert pareto_mask(res.front_points).all()
    # refine events carry the cumulative front of their moment
    last_refine = [e for e in events if e["stage"] == "refine"][-1]
    assert np.array_equal(last_refine["front"]["points"], res.front_points)
    # cross-seed accounting
    for b in (100.0, 200.0):
        best = res.best(b)
        assert best is not None
        assert best.best_fitness == max(
            r[b].best_fitness for r in res.results.values() if b in r)
    assert res.evaluated == sum(r.evaluated for by_b in res.results.values()
                                for r in by_b.values())
    # seed boundaries drained device-computed rows back to the store
    assert events[-1]["stage"] == "seed_done"
    assert any(e["drained"] > 0 for e in events if e["stage"] == "seed_done")


def test_run_pipeline_validation():
    with pytest.raises(ValueError, match="exact"):
        run_pipeline(WLS, seeds=(0,), brackets=(200.0,),
                     samples_per_stratum=2,
                     engine=EvalEngine(WLS, config=EngineConfig(backend="scan")))
