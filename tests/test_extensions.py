"""Coverage for the extension layers: Bayesian DSE backend, TPU-mesh DSE,
ring collective-matmul (subprocess: needs >1 device), serve engine,
workload extraction."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.tpu_dse import MeshKnobs, predict_cost, search_mesh
from repro.models import get_config


@pytest.mark.slow
def test_bayes_backend_improves():
    from repro.core.dse.bayes import BayesConfig, run_bayes

    def objective(metrics):
        # minimize mean energy at fixed-ish area: maximize -E/area
        e = metrics["energy"].mean(axis=1)
        return -np.log(np.maximum(e, 1e-9))

    out = run_bayes(["kan", "resnet50_int8"], objective,
                    BayesConfig(init_samples=16, rounds=2, batch_per_round=8,
                                candidate_pool=256), seed=0)
    assert np.isfinite(out["best_score"])
    assert out["history"][-1] >= out["history"][0]


def test_tpu_dse_prefers_fitting_configs():
    cfg = get_config("granite-20b")
    ranked = search_mesh(cfg, chips=256, global_batch=256, seq_len=4096)
    assert ranked, "no mesh candidates"
    fits = [c for c in ranked if c.fits]
    assert fits, "nothing fits 16GiB HBM"
    assert ranked[0].fits
    # microbatching cuts live activation memory (FSDP shards the state
    # over BOTH mesh axes, so hbm is microbatch- not tp-sensitive)
    c1 = predict_cost(cfg, MeshKnobs(dp=128, tp=2, microbatches=1), 256, 4096)
    c4 = predict_cost(cfg, MeshKnobs(dp=128, tp=2, microbatches=4), 256, 4096)
    assert c4.hbm_gib < c1.hbm_gib


def test_tpu_dse_collective_term_grows_with_tp():
    cfg = get_config("starcoder2-15b")
    lo = predict_cost(cfg, MeshKnobs(dp=128, tp=2), 256, 4096)
    hi = predict_cost(cfg, MeshKnobs(dp=16, tp=16), 256, 4096)
    assert hi.collective_s > lo.collective_s


@pytest.mark.slow
def test_ring_allgather_matmul_subprocess():
    """Runs under 8 forced host devices in a fresh process."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.overlap import ring_allgather_matmul
from repro.launch.mesh import mesh_axis_kwargs
mesh = jax.make_mesh((8,), ("model",), **mesh_axis_kwargs(1))
x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 32)), jnp.float32)
w = jnp.asarray(np.random.default_rng(1).normal(size=(32, 48)), jnp.float32)
with mesh:
    y = ring_allgather_matmul(x, w, mesh)
assert float(jnp.abs(y - x @ w).max()) < 1e-4
print("OK")
"""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    # without an explicit platform, backend probing can hang in a bare env
    env["JAX_PLATFORMS"] = os.environ.get("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=240, env=env)
    assert "OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_serve_engine_continuous_batching():
    import jax
    from repro.models import init_params
    from repro.serve.engine import Request, ServeEngine

    cfg = get_config("starcoder2-15b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(5):
        eng.submit(Request(rid, rng.integers(0, cfg.vocab, 6, dtype=np.int32),
                           max_new_tokens=4))
    results = eng.run()
    assert set(results) == set(range(5))
    for toks in results.values():
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab for t in toks)
