"""Batched Eq. 1-3 mapper: bitwise placement parity with ``map_graph``.

The compile-free exact path stands on one claim: the jitted mapping scan
makes the *same placement decisions* as the Python mapper, bit for bit —
owner tile, split width, split axis, split membership — on any (graph,
chip) pair.  Pinned here three ways:

* a hypothesis property over random DAGs (split-friendly MAC shapes,
  SPECIAL ops, fused chains) x random genomes, compared row-by-row
  against ``lower_plan(emit_schedule(g, map_graph(g, chip)))``;
* the full 20-workload suite on the reference heterogeneous chips (the
  ISSUE-3 acceptance bar);
* golden-trace anchoring: the fused ``map_and_simulate`` dispatch
  reproduces the frozen oracle traces on the golden workloads, and the
  ``plan_from_arrays`` round-trip lets ``ChipSim`` replay batched-mapper
  placements directly.
"""
import copy

import numpy as np
import pytest

from repro.core import hetero_bl, hetero_bls, homogeneous_baseline
from repro.core.arch import MAX_TILES
from repro.core.compiler.batched_mapper import batched_map, map_and_simulate
from repro.core.compiler.fusion import fuse
from repro.core.compiler.mapper import UnmappableError, map_graph
from repro.core.compiler.pipeline import lower_plan, plan_from_arrays
from repro.core.compiler.precision import assign_precision
from repro.core.compiler.schedule import emit_schedule
from repro.core.dse.batch_eval import prepare_workload
from repro.core.dse.encoding import decode, random_genomes
from repro.core.ir import OpNode, OpType, Precision, WorkloadGraph
from repro.core.simulator.batched import stack_chip_configs
from repro.core.simulator.orchestrator import simulate
from repro.core.workloads import build, workload_names

REL = 1e-9


def _passes(g: WorkloadGraph) -> WorkloadGraph:
    """The config-independent compiler passes 1-2, as prepare_workload
    applies them (deepcopy so the caller's graph stays pristine)."""
    return fuse(assign_precision(copy.deepcopy(g)))


def _assert_rows_match(ws, out, b, g2, chip):
    """One candidate's batched placement rows == the lowered map_graph
    plan, bitwise."""
    tbl = lower_plan(emit_schedule(g2, map_graph(g2, chip)),
                     chip.num_tiles, max_ops=len(ws["op_type"]))
    nt = chip.num_tiles
    assert np.array_equal(out["owner"][b], tbl.owner)
    assert np.array_equal(out["n_split"][b], tbl.n_split)
    assert np.array_equal(out["split_axis"][b], tbl.split_axis)
    assert np.array_equal(out["split_mask"][b][:, :nt], tbl.split_mask)
    assert not out["split_mask"][b][:, nt:].any()
    return tbl


def _check_chips(g: WorkloadGraph, chips) -> dict:
    """Map ``g`` on every chip through both mappers and compare bitwise.
    Returns coverage counters so callers can assert the interesting
    branches actually fired."""
    g2 = _passes(g)
    ws = prepare_workload(g)
    out = batched_map(ws, stack_chip_configs(chips))
    cover = {"mappable": 0, "unmappable": 0, "splits": 0, "special": 0}
    for b, chip in enumerate(chips):
        try:
            placements = map_graph(g2, chip)
        except UnmappableError:
            assert not out["ok"][b], (b, "reference unmappable, batched ok")
            cover["unmappable"] += 1
            continue
        assert out["ok"][b], (b, "reference mappable, batched not ok")
        cover["mappable"] += 1
        _assert_rows_match(ws, out, b, g2, chip)
        cover["splits"] += sum(len(p.tiles) > 1
                               for p in placements.values())
        sfu_tiles = {i for i, t in enumerate(chip.instances()) if t.sfu_mask}
        cover["special"] += sum(p.tiles[0] in sfu_tiles
                                for p in placements.values())
    return cover


# =============================================================================
# deterministic branch-coverage cases
# =============================================================================

def _split_friendly_graph():
    """Bulk MAC work that the mapper partitions across Big+Little, plus a
    dependent chain exercising Eq. 1 cross-tile NoC delays."""
    g = WorkloadGraph("split", model_precision=Precision.INT8)
    a = g.matmul("mm0", 512, 512, 512)
    b = g.dsp("sm", OpType.SOFTMAX, elems=512 * 512, preds=[a])
    c = g.matmul("mm1", 512, 512, 1024, preds=[b])
    g.matmul("mm2", 64, 512, 64, preds=[a, c])
    return g


def test_split_decision_parity_and_coverage():
    cover = _check_chips(_split_friendly_graph(),
                         [hetero_bl(), hetero_bls(),
                          homogeneous_baseline(n_tiles=4)])
    assert cover["mappable"] == 3
    # the point of this case: the reference accepts Eq. 3 splits, and the
    # batched mapper reproduced every one of them bitwise
    assert cover["splits"] > 0


def test_special_routing_parity_and_coverage():
    g = WorkloadGraph("spec", model_precision=Precision.FP16)
    a = g.add(OpNode("fft", OpType.FFT, elems=8192, fft_n=256,
                     precision=Precision.FP16))
    b = g.add(OpNode("lif", OpType.SNN_LIF, elems=2048, snn_timesteps=4,
                     precision=Precision.FP16), preds=[a])
    g.add(OpNode("poly", OpType.POLY, elems=4096, poly_degree=3,
                 precision=Precision.FP16), preds=[b])
    cover = _check_chips(g, [hetero_bls(), hetero_bl()])
    assert cover["mappable"] == 2
    # on the BLS chip every special op must route to the SFU tile
    assert cover["special"] >= 3


def test_unmappable_candidate_flagged_not_raised():
    from repro.core.arch import ChipConfig, TileTemplate
    t = TileTemplate(name="macsonly", rows=8, cols=8, dsp_count=0,
                     precisions=frozenset({Precision.INT8}))
    chip = ChipConfig(name="nodsp", tiles=((t, 2),))
    g = WorkloadGraph("t", model_precision=Precision.INT8)
    g.dsp("softmax", OpType.SOFTMAX, elems=100)
    cover = _check_chips(g, [chip, hetero_bls()])
    assert cover["unmappable"] == 1 and cover["mappable"] == 1


# =============================================================================
# full 20-workload suite (ISSUE-3 acceptance bar) + golden anchoring
# (the hypothesis property lives in test_batched_mapper_props.py so this
# module still runs where hypothesis is absent)
# =============================================================================

@pytest.mark.parametrize("wname", workload_names())
def test_full_suite_placements_bitwise(wname):
    """Batched-mapper placements bitwise equal to map_graph for every
    stock workload on the reference heterogeneous chip."""
    cover = _check_chips(build(wname), [hetero_bls()])
    assert cover["mappable"] == 1


@pytest.mark.slow
@pytest.mark.parametrize("wname", workload_names())
def test_full_suite_placements_bitwise_more_chips(wname):
    _check_chips(build(wname),
                 [hetero_bl(), homogeneous_baseline(n_tiles=6),
                  decode(random_genomes(np.random.default_rng(11), 1)[0],
                         "rnd")])


GOLDEN_WORKLOADS = ["resnet50_int8", "vit_b16_fp16", "llama7b_int4",
                    "snn_vgg9", "hyena_1_3b", "kan"]


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_map_and_simulate_matches_oracle_on_golden_runs(wname):
    """The fused compile-free dispatch reproduces the oracle (and hence
    the frozen golden traces) on the golden workloads, and its placement
    arrays replay through ChipSim via plan_from_arrays."""
    chip = hetero_bls()
    g2 = _passes(build(wname))
    ws = prepare_workload(build(wname))
    res = map_and_simulate(ws, stack_chip_configs([chip]))
    assert bool(res["ok"][0])
    plan = plan_from_arrays(g2, res["owner"][0], res["n_split"][0],
                            res["split_axis"][0], res["split_mask"][0])
    r = simulate(chip, plan)
    assert res["latency_s"][0] == pytest.approx(r.latency_s, rel=REL)
    assert res["energy_pj"][0] == pytest.approx(r.energy_pj, rel=REL)
    assert res["achieved_tops"][0] == pytest.approx(r.achieved_tops, rel=REL)


@pytest.mark.parametrize("wname", ["kan", "hyena_1_3b"])
def test_map_and_simulate_matches_golden_trace(wname, golden):
    """Golden-trace run through the new exact path: the fused dispatch
    hits the frozen latency/energy of tests/golden/<wname>.json (no
    --regen here: a drift is a real regression, not a retune)."""
    import json
    import pathlib
    path = pathlib.Path(__file__).parent / "golden" / f"{wname}.json"
    ref = json.loads(path.read_text())
    chip = hetero_bls()
    ws = prepare_workload(build(wname))
    res = map_and_simulate(ws, stack_chip_configs([chip]))
    assert res["latency_s"][0] == pytest.approx(ref["latency_s"], rel=1e-6)
    assert res["energy_pj"][0] == pytest.approx(ref["energy_pj"], rel=1e-6)
    assert res["achieved_tops"][0] == pytest.approx(ref["achieved_tops"],
                                                    rel=1e-6)
