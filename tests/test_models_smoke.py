"""Per-architecture smoke tests (deliverable f): REDUCED config of the
same family, one forward/train step + one decode step on CPU, asserting
output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_config, init_params, list_archs, loss_fn, param_specs
from repro.models.model import decode_step, init_cache


def _batch(cfg, B=2, S=16):
    b = {"tokens": jnp.ones((B, S), jnp.int32),
         "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.encoder_layers:
        b["frames"] = jnp.ones((B, cfg.num_frontend_tokens, cfg.d_model),
                               jnp.float32)
    if cfg.frontend == "vision":
        b["vision_embeds"] = jnp.ones((B, cfg.num_frontend_tokens, cfg.d_model),
                                      jnp.float32)
    return b


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_train_step_and_decode(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch)))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    # decode
    B = 2
    caches = init_cache(cfg, B, 32)
    ctx = batch.get("frames", batch.get("vision_embeds"))
    logits, new_caches = jax.jit(
        lambda p, t, pos, c: decode_step(cfg, p, t, pos, c, ctx))(
        params, jnp.ones((B, 1), jnp.int32), jnp.zeros((B,), jnp.int32), caches)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(np.argmax(np.asarray(logits[0, 0], np.float32))) < cfg.vocab


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_structure_matches_params(arch):
    cfg = get_config(arch).reduced()
    params_struct = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = param_specs(cfg)
    t1 = jax.tree.structure(params_struct)
    t2 = jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert t1 == t2


@pytest.mark.parametrize("arch", ["llama4-maverick-400b-a17b", "granite-34b",
                                  "mamba2-780m"])
def test_full_config_param_counts(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    expect = {"llama4-maverick-400b-a17b": 400e9, "granite-34b": 34e9,
              "mamba2-780m": 0.78e9}[arch]
    assert abs(n - expect) / expect < 0.10


def test_decode_matches_prefill_logits():
    """Teacher-forced decode must reproduce forward() logits step by step
    (KV-cache correctness)."""
    from repro.models.model import forward
    cfg = get_config("starcoder2-15b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full = np.asarray(forward(cfg, params, toks), np.float32)
    caches = init_cache(cfg, B, S + 1)
    outs = []
    for t in range(S):
        logits, caches = decode_step(cfg, params, toks[:, t:t + 1],
                                     jnp.full((B,), t, jnp.int32), caches)
        outs.append(np.asarray(logits[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec[:, :, :cfg.vocab], full[:, :, :cfg.vocab],
                               rtol=2e-2, atol=2e-2)
