"""Cluster chaos suite (PR 10, ``-m chaos``): the sharded
``DSECluster`` coordinator under deterministic worker loss.

The invariant is the same one the rest of the chaos suite pins: every
injected fault is transient and value-preserving, so a 3-worker cluster
losing one or two workers mid-study must return results **bitwise
equal** to an unfaulted single-engine run — resilience must not cost
determinism.  ``FAULT_SEED`` (CI matrixes over it) seeds the injector;
the ``at=`` schedules used here are seed-independent, so every seed
must pass identically.

The chaos sites fire at deterministic points (``worker_kill`` and
``shard_timeout`` in ``_form_shards`` on the caller thread,
``heartbeat_drop`` in the sequential ``heartbeat()`` probe loop), which
is what makes "kill worker 0 while forming the 16th shard" a replayable
schedule rather than a race.
"""
import time

import numpy as np
import pytest

from repro.core.dse.api import EngineConfig
from repro.core.dse.encoding import random_genomes
from repro.core.dse.engine import EvalEngine
from repro.core.dse.faults import FaultInjector, fault_seed_from_env
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.pipeline import run_pipeline
from repro.core.dse.sweep import run_sweep
from repro.serve.cluster import ClusterError, DSECluster
from repro.serve.dse_service import DSEService

pytestmark = pytest.mark.chaos

SEED = fault_seed_from_env()
WLS = ["kan"]
METRICS = ("latency", "energy", "tops_w", "area")


def _genomes(n=8, seed=3):
    return random_genomes(np.random.default_rng(seed), n)


def _engine():
    return EvalEngine(WLS, config=EngineConfig(backend="exact"))


def _cluster(n=3, injector=None, **kw):
    svcs = [DSEService(_engine(), max_batch=256, max_wait_ms=5.0,
                       worker_id=f"chaos-w{i}").start() for i in range(n)]
    kw.setdefault("backoff_s", 0.01)
    kw.setdefault("rejoin_backoff_s", 0.01)
    return DSECluster(svcs, fault_injector=injector, **kw), svcs


def _stop(cluster, svcs):
    cluster.close()
    for s in svcs:
        s.stop(drain=False)


def _ga_setup():
    cfg = GAConfig(population=12, generations=3, seed_top_k=6,
                   early_stop=10_000)
    sweep = run_sweep(WLS, samples_per_stratum=4, seed=0,
                      brackets=(100.0, 200.0), engine=_engine())
    return cfg, sweep


# =============================================================================
# rendezvous sharding: deterministic, minimal movement
# =============================================================================

def test_rendezvous_ranking_is_stable_and_minimal_movement():
    """Each genome's worker ranking is a deterministic permutation, and
    ejecting a worker moves only the keys that worker owned — every
    other key keeps its owner (the HRW property failover relies on for
    per-worker store locality)."""
    cl, svcs = _cluster(3)
    try:
        keys = [b"latency:" + g.tobytes()
                for g in np.ascontiguousarray(_genomes(64), np.int64)]
        ranks = [cl._rank(k) for k in keys]
        assert ranks == [cl._rank(k) for k in keys]      # stable
        assert all(sorted(r) == [0, 1, 2] for r in ranks)
        owners = [r[0] for r in ranks]
        assert len(set(owners)) == 3                     # spread, not piled
        # drop worker 0: its keys fail over to their rank-2 worker,
        # everyone else's owner is untouched
        cl._workers[0].dead = True
        for k, r in zip(keys, ranks):
            w = cl._pick(cl._rank(k))
            assert w.index == (r[1] if r[0] == 0 else r[0])
    finally:
        _stop(cl, svcs)


def test_cluster_evaluate_bitwise_equal_to_local_engine():
    g = _genomes(24, seed=7)
    clean = _engine().evaluate(g)
    cl, svcs = _cluster(3)
    try:
        res = cl.evaluate(g)
        for k in METRICS:
            assert clean[k].tobytes() == res[k].tobytes(), k
        assert res["meta"]["shards"] >= 2       # genuinely sharded
        assert res["meta"]["requests"] == len(g)
    finally:
        _stop(cl, svcs)


# =============================================================================
# worker loss mid-GA: bitwise equality with the unfaulted run
# =============================================================================

def test_ga_bitwise_under_one_worker_kill():
    """A 3-worker cluster losing one worker mid-GA (the service stops
    for real) fails the dead worker's shards over to the survivors and
    finishes bitwise equal to a clean single-engine run."""
    cfg, sweep = _ga_setup()
    clean = run_ga(sweep, 200.0, cfg, seed=0, engine=_engine())

    inj = FaultInjector(seed=SEED, at={"worker_kill": (5,)})
    cl, svcs = _cluster(3, injector=inj)
    try:
        served = run_ga(sweep, 200.0, cfg, seed=0, engine=cl)
        assert inj.fired()["worker_kill"] == 1
        assert served.best_fitness == clean.best_fitness
        assert served.best_genome.tobytes() == clean.best_genome.tobytes()
        for k in ("latency", "energy", "tops_w"):
            assert np.asarray(served.best_metrics[k]).tobytes() == \
                np.asarray(clean.best_metrics[k]).tobytes(), k
        assert "dead" in {m["status"] for m in cl.membership()}
        assert not cl._inflight, "leaked in-flight futures"
    finally:
        _stop(cl, svcs)


def test_ga_bitwise_under_two_worker_kills_and_timeouts():
    """Losing two of three workers plus injected shard timeouts: the
    last survivor absorbs the whole study, retries are visible in the
    stats, and the bytes still match the clean run."""
    cfg, sweep = _ga_setup()
    clean = run_ga(sweep, 100.0, cfg, seed=1, engine=_engine())

    inj = FaultInjector(seed=SEED, at={"worker_kill": (2, 6),
                                       "shard_timeout": (3, 7)})
    cl, svcs = _cluster(3, injector=inj)
    try:
        served = run_ga(sweep, 100.0, cfg, seed=1, engine=cl)
        assert inj.fired()["worker_kill"] == 2
        assert inj.fired()["shard_timeout"] == 2
        assert cl.cluster_stats.retried_shards >= 2
        assert served.best_fitness == clean.best_fitness
        assert served.best_genome.tobytes() == clean.best_genome.tobytes()
        for k in ("latency", "energy", "tops_w"):
            assert np.asarray(served.best_metrics[k]).tobytes() == \
                np.asarray(clean.best_metrics[k]).tobytes(), k
        statuses = [m["status"] for m in cl.membership()]
        assert statuses.count("dead") == 2
        # the survivor still serves fresh work after the carnage
        g = _genomes(6, seed=8)
        res = cl.evaluate(g)
        ref = _engine().evaluate(g)
        for k in METRICS:
            assert ref[k].tobytes() == res[k].tobytes(), k
        assert not cl._inflight, "leaked in-flight futures"
    finally:
        _stop(cl, svcs)


def test_all_workers_dead_raises_cluster_error_fast():
    cl, svcs = _cluster(2, shard_retries=2)
    try:
        for w in cl._workers:
            cl._kill_worker(w)
        t0 = time.time()
        with pytest.raises(ClusterError):
            cl.evaluate(_genomes(4, seed=9))
        assert time.time() - t0 < 30        # terminal, not a hang
        assert not cl._inflight
    finally:
        _stop(cl, svcs)


# =============================================================================
# pipeline through the cluster (+ checkpoint composition)
# =============================================================================

def test_pipeline_through_faulted_cluster_bitwise(tmp_path):
    """``run_pipeline(cluster=...)`` under worker loss + checkpointing:
    the merged Pareto front, per-seed results, and the checkpoint's run
    digest are bitwise identical to a plain local run — worker loss
    never changes the study's bytes, and the checkpoint composes."""
    kw = dict(seeds=(0, 1), brackets=(100.0, 200.0),
              samples_per_stratum=4,
              cfg=GAConfig(population=12, generations=2, seed_top_k=6,
                           early_stop=10_000))
    ref = run_pipeline(WLS, engine=_engine(), **kw)

    inj = FaultInjector(seed=SEED, at={"worker_kill": (3,),
                                       "shard_timeout": (1,)})
    cl, svcs = _cluster(3, injector=inj)
    try:
        res = run_pipeline(WLS, engine=_engine(), cluster=cl,
                           checkpoint=str(tmp_path / "ck"), **kw)
    finally:
        _stop(cl, svcs)
    assert inj.fired()["worker_kill"] == 1
    assert ref.front_points.tobytes() == res.front_points.tobytes()
    assert ref.front_genomes.tobytes() == res.front_genomes.tobytes()
    assert ref.evaluated == res.evaluated
    for s in kw["seeds"]:
        for b, r in ref.results[s].items():
            q = res.results[s][b]
            assert r.best_fitness == q.best_fitness, (s, b)
            assert r.best_genome.tobytes() == q.best_genome.tobytes()


# =============================================================================
# health: heartbeat ejection + backoff-gated rejoin
# =============================================================================

def test_heartbeat_ejects_and_rejoins_deterministically():
    """Dropping worker 0's heartbeat ``eject_after`` times in a row
    ejects it; once the probes succeed again after the rejoin backoff,
    it rejoins and takes traffic."""
    # 3 workers probed in order each round: indices 0, 3, 6 are w0's
    # first three probes — exactly eject_after consecutive failures
    inj = FaultInjector(seed=SEED, at={"heartbeat_drop": (0, 3, 6)})
    cl, svcs = _cluster(3, injector=inj, eject_after=3)
    try:
        cl.heartbeat()
        cl.heartbeat()
        assert [m["status"] for m in cl.membership()] == ["ok"] * 3
        cl.heartbeat()                       # third drop: ejected
        assert inj.fired()["heartbeat_drop"] == 3
        assert cl.membership()[0]["status"] in ("ejected", "rejoining")
        assert cl.cluster_stats.ejections == 1
        # an ejected worker takes no traffic, the survivors do
        res = cl.evaluate(_genomes(12, seed=10))
        assert res["meta"]["workers"] == 2
        time.sleep(0.05)                     # rejoin backoff (0.01 s)
        cl.heartbeat()                       # clean probe: rejoined
        assert [m["status"] for m in cl.membership()] == ["ok"] * 3
        assert cl.cluster_stats.rejoins == 1
        ref = _engine().evaluate(_genomes(12, seed=10))
        res = cl.evaluate(_genomes(12, seed=10))
        for k in METRICS:
            assert ref[k].tobytes() == res[k].tobytes(), k
    finally:
        _stop(cl, svcs)


# =============================================================================
# TCP workers: same invariants over the wire
# =============================================================================

def test_tcp_worker_cluster_bitwise_under_faults():
    """A mixed cluster (one TCP worker, two in-process) with an
    injected shard timeout still returns local-engine bytes."""
    svcs = [DSEService(_engine(), max_batch=256, max_wait_ms=5.0,
                       worker_id=f"tcp-w{i}").start() for i in range(3)]
    workers = [svcs[0].listen(), svcs[1], svcs[2]]
    inj = FaultInjector(seed=SEED, at={"shard_timeout": (1,)})
    cl = DSECluster(workers, fault_injector=inj, backoff_s=0.01)
    g = _genomes(18, seed=11)
    try:
        ref = _engine().evaluate(g)
        res = cl.evaluate(g)
        for k in METRICS:
            assert ref[k].tobytes() == res[k].tobytes(), k
        assert inj.fired()["shard_timeout"] == 1
        assert cl.cluster_stats.retried_shards >= 1
        assert not cl._inflight
    finally:
        cl.close()
        for s in svcs:
            s.stop(drain=False)
