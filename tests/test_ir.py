"""IR invariants: the 23-op vocabulary, DAG validation, SoA conversion."""
import numpy as np
import pytest

from repro.core.ir import (MAX_PREDS, OpClass, OpNode, OpTensor, OpType,
                           Precision, WorkloadGraph, op_class, slice_op)


def test_vocabulary_is_23_ops_5_15_3():
    ops = list(OpType)
    assert len(ops) == 23
    counts = {OpClass.MAC: 0, OpClass.DSP: 0, OpClass.SPECIAL: 0}
    for t in ops:
        counts[op_class(t)] += 1
    assert counts[OpClass.MAC] == 5
    assert counts[OpClass.DSP] == 15
    assert counts[OpClass.SPECIAL] == 3


def test_graph_rejects_non_topological_preds():
    g = WorkloadGraph("t")
    g.matmul("a", 4, 4, 4)
    with pytest.raises(ValueError):
        g.add(OpNode("b", OpType.ADD, elems=4), preds=[5])


def test_finalize_fills_bytes_from_dims():
    n = OpNode("m", OpType.MATMUL, m=8, k=16, n=32,
               precision=Precision.INT8).finalize()
    assert n.bytes_in == 8 * 16
    assert n.bytes_w == 16 * 32
    assert n.bytes_out == 8 * 32
    assert n.macs == 8 * 16 * 32


def test_arithmetic_intensity_and_histogram():
    g = WorkloadGraph("t", model_precision=Precision.INT8)
    a = g.matmul("mm", 64, 64, 64)
    g.dsp("relu", OpType.RELU, elems=64 * 64, preds=[a])
    ai = g.arithmetic_intensity()
    assert ai > 0
    h = g.class_histogram()
    assert h == {"MAC": 1, "DSP": 1, "SPECIAL": 0}


def test_optensor_roundtrip_and_padding():
    g = WorkloadGraph("t")
    a = g.matmul("mm", 8, 8, 8)
    b = g.dsp("sm", OpType.SOFTMAX, elems=64, preds=[a])
    t = g.to_tensor(max_ops=10)
    assert t.num_ops == 2
    assert t.max_ops == 10
    assert t.arrays["valid"][:2].sum() == 2
    assert t.arrays["valid"][2:].sum() == 0
    assert t.preds[1, 0] == 0
    assert (t.preds[0] == -1).all()


def test_slice_op_axes():
    n = OpNode("m", OpType.MATMUL, m=8, k=16, n=32).finalize()
    oc = slice_op(n, "OC", 4)
    assert (oc.m, oc.k, oc.n) == (8, 16, 8)
    b = slice_op(n, "B", 4)
    assert (b.m, b.k, b.n) == (2, 16, 32)
    ic = slice_op(n, "IC", 4)
    assert (ic.m, ic.k, ic.n) == (8, 4, 32)
    # bytes: OC split shares inputs, splits weights+outputs
    assert oc.bytes_in == n.bytes_in
    assert oc.bytes_w == n.bytes_w // 4
