"""Golden-trace regression harness + oracle/batched backend agreement.

Freezes the reference simulator's full ``SimResult`` surface for a
Big+Little+Special-Function chip on six representative workloads
(tests/golden/*.json, regenerate with ``pytest --regen-golden``), and pins
the batched plan executor to the oracle on the same runs.  Throughput-mode
(§3.2 pipelined) runs freeze the steady-state pipeline section too, and
both the batched executor and the fused batched mapper+executor are
pinned to the oracle's II on the same runs.  The slow marker extends the
backend-agreement checks (both schedule modes) to the full 20-workload
suite (the ISSUE-2/ISSUE-4 acceptance bars).
"""
import numpy as np
import pytest

from repro.core import compile_workload, hetero_bls, simulate
from repro.core.compiler.batched_mapper import map_and_simulate
from repro.core.compiler.pipeline import lower_plan
from repro.core.dse.engine import prepared_workload
from repro.core.simulator.batched import simulate_plans, stack_chip_configs
from repro.core.workloads import build, workload_names

# throughput-mode steady-state surface every backend must agree on
PIPELINE_KEYS = ("ii_s", "ii_tile_bound_s", "ii_dram_bound_s",
                 "ii_noc_bound_s", "fill_latency_s", "energy_ss_pj",
                 "achieved_tops_ss", "pipeline_depth",
                 "dram_bytes_per_batch")

# one per execution-path family: quantized CNN, FP16 ViT, INT4 LLM,
# SNN (LIF), FFT long-conv, polynomial (KAN)
GOLDEN_WORKLOADS = ["resnet50_int8", "vit_b16_fp16", "llama7b_int4",
                    "snn_vgg9", "hyena_1_3b", "kan"]

REL_TOL = 1e-9  # oracle vs batched: same formulas, reduction order only


def _reference_chip():
    return hetero_bls()


def _run(wname):
    chip = _reference_chip()
    plan = compile_workload(build(wname), chip)
    return chip, plan, simulate(chip, plan)


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_golden_trace(wname, golden):
    _, _, r = _run(wname)
    golden(wname, r.golden_dict())


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_batched_matches_oracle_on_golden_runs(wname):
    chip, plan, r = _run(wname)
    res = simulate_plans([chip], [lower_plan(plan, chip.num_tiles)])
    assert res["latency_s"][0] == pytest.approx(r.latency_s, rel=REL_TOL)
    assert res["energy_pj"][0] == pytest.approx(r.energy_pj, rel=REL_TOL)
    assert res["achieved_tops"][0] == pytest.approx(r.achieved_tops,
                                                    rel=REL_TOL)
    # per-module energy agreement (leakage included)
    eb = r.energy_breakdown
    for mod in ("compute", "dram", "sram", "irf", "orf", "dsp", "special",
                "noc", "leakage", "fuse_savings"):
        got = float(res[f"energy_{mod}_pj"][0])
        want = getattr(eb, mod)
        assert got == pytest.approx(want, rel=REL_TOL, abs=1e-9), mod
    # per-tile op counts and power gating line up with the oracle trace
    n = len(r.tiles)
    assert res["tile_ops"][0][:n].tolist() == [b.ops for b in r.tiles]
    assert res["power_gated"][0][:n].tolist() == \
        [b.power_gated for b in r.tiles]
    np.testing.assert_allclose(res["tile_active_s"][0][:n],
                               [b.active_s for b in r.tiles], rtol=REL_TOL)


def _run_throughput(wname):
    chip = _reference_chip()
    plan = compile_workload(build(wname), chip, mode="throughput")
    return chip, plan, simulate(chip, plan)


def _assert_throughput_parity(wname, chip, plan, r):
    """Oracle II vs (a) the batched executor replaying the compiled plan,
    (b) the fused compile-free mapper+executor — the 0-rel-err bar."""
    assert r.mode == "throughput" and r.pipeline is not None
    table = lower_plan(plan, chip.num_tiles)
    assert table.mode == "throughput"
    res = simulate_plans([chip], [table])
    assert res["mode"] == "throughput"
    fused = map_and_simulate(prepared_workload(wname),
                             stack_chip_configs([chip]), mode="throughput")
    assert bool(fused["ok"][0]), wname
    for k in PIPELINE_KEYS:
        assert float(res[k][0]) == pytest.approx(r.pipeline[k],
                                                 rel=REL_TOL), (wname, k)
        assert float(fused[k][0]) == pytest.approx(r.pipeline[k],
                                                   rel=REL_TOL), (wname, k)
    # pipelining is never slower per batch than the serial replay
    assert r.pipeline["ii_s"] <= r.latency_s * (1 + 1e-12)


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_golden_trace_throughput(wname, golden):
    """Freeze the throughput-mode steady state (II + bounds + per-batch
    energy) for the hetero-BLS reference runs."""
    _, _, r = _run_throughput(wname)
    golden(f"{wname}_throughput", r.golden_dict())


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_throughput_backends_match_oracle_on_golden_runs(wname):
    chip, plan, r = _run_throughput(wname)
    _assert_throughput_parity(wname, chip, plan, r)


@pytest.mark.slow
def test_batched_matches_oracle_full_suite():
    """Acceptance bar: backend agreement across all 20 stock workloads on
    the fixed reference chip."""
    chip = _reference_chip()
    for wname in workload_names():
        plan = compile_workload(build(wname), chip)
        r = simulate(chip, plan)
        res = simulate_plans([chip], [lower_plan(plan, chip.num_tiles)])
        assert res["latency_s"][0] == pytest.approx(r.latency_s,
                                                    rel=REL_TOL), wname
        assert res["energy_pj"][0] == pytest.approx(r.energy_pj,
                                                    rel=REL_TOL), wname


@pytest.mark.slow
def test_throughput_backends_match_oracle_full_suite():
    """ISSUE-4 acceptance bar: throughput-mode II agreement (batched
    executor AND fused mapper+executor vs ChipSim) across all 20 stock
    workloads on the fixed reference chip."""
    for wname in workload_names():
        chip, plan, r = _run_throughput(wname)
        _assert_throughput_parity(wname, chip, plan, r)


# =============================================================================
# link-fidelity tier (per-link NoC + per-channel DRAM, PR 9)
# =============================================================================

# the aggregate steady-state surface plus the two link-tier bounds
LINK_PIPELINE_KEYS = PIPELINE_KEYS + ("ii_chan_bound_s", "ii_link_bound_s")


def _link_chip():
    """Topology-exercising reference chip: elongated torus grid, narrow
    NoC links, two interleaved DRAM channels — chosen so the link tier's
    extra bounds actually bite instead of hiding under the aggregate
    bottleneck."""
    import dataclasses
    return dataclasses.replace(
        hetero_bls(), name="heteroBLS-link", torus=True, grid_aspect=2.0,
        dram_channels=2, noc_bytes_per_cycle=32.0)


def _run_link(wname):
    chip = _link_chip()
    plan = compile_workload(build(wname), chip, mode="throughput")
    return chip, plan, simulate(chip, plan, fidelity="link")


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_golden_trace_link(wname, golden):
    """Freeze the link-tier steady state (II + per-channel / per-link
    bounds) for the topology-exercising reference runs."""
    _, _, r = _run_link(wname)
    assert "ii_chan_bound_s" in r.pipeline
    assert "ii_link_bound_s" in r.pipeline
    golden(f"{wname}_link", r.golden_dict())


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_link_backends_match_oracle_on_golden_runs(wname):
    """The link tier holds the same three-way backend agreement the
    aggregate tier always had: batched executor AND fused
    mapper+executor vs ChipSim, full link surface."""
    chip, plan, r = _run_link(wname)
    table = lower_plan(plan, chip.num_tiles)
    res = simulate_plans([chip], [table], fidelity="link")
    fused = map_and_simulate(prepared_workload(wname),
                             stack_chip_configs([chip]),
                             mode="throughput", fidelity="link")
    assert bool(fused["ok"][0]), wname
    for k in LINK_PIPELINE_KEYS:
        assert float(res[k][0]) == pytest.approx(r.pipeline[k],
                                                 rel=REL_TOL), (wname, k)
        assert float(fused[k][0]) == pytest.approx(r.pipeline[k],
                                                   rel=REL_TOL), (wname, k)


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_link_ii_dominates_aggregate(wname):
    """The link tier only *adds* occupancy lower bounds, so II(link) >=
    II(aggregate); the aggregate bound keys and the latency/energy
    surface keep their historical bits."""
    chip = _link_chip()
    plan = compile_workload(build(wname), chip, mode="throughput")
    r_agg = simulate(chip, plan)
    r_link = simulate(chip, plan, fidelity="link")
    for k in ("ii_tile_bound_s", "ii_dram_bound_s", "ii_noc_bound_s"):
        assert r_link.pipeline[k] == r_agg.pipeline[k], k
    assert r_link.pipeline["ii_s"] >= r_agg.pipeline["ii_s"]
    assert r_link.latency_s == r_agg.latency_s
    assert r_link.energy_pj == r_agg.energy_pj


def test_link_tier_population_parity():
    """Population-level bitwise agreement between the fused link-tier
    dispatch and the per-candidate oracle on random topology-bearing
    genomes (the search-time fidelity is the rescore fidelity)."""
    from repro.core.dse.encoding import decode, random_genomes
    from repro.core.dse.engine import genomes_to_configs

    rng = np.random.default_rng(9)
    genomes = random_genomes(rng, 24)
    cfgs = genomes_to_configs(genomes)
    for wname in ("kan", "resnet50_int8"):
        fused = map_and_simulate(prepared_workload(wname), cfgs,
                                 mode="throughput", fidelity="link")
        for i in np.flatnonzero(fused["ok"])[:6]:
            chip = decode(genomes[i], f"lk{i}")
            plan = compile_workload(build(wname), chip, mode="throughput")
            r = simulate(chip, plan, fidelity="link")
            assert float(fused["ii_s"][i]) == r.pipeline["ii_s"], (wname, i)
            assert float(fused["ii_link_bound_s"][i]) == \
                r.pipeline["ii_link_bound_s"], (wname, i)
            assert float(fused["ii_chan_bound_s"][i]) == \
                r.pipeline["ii_chan_bound_s"], (wname, i)
