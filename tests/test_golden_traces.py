"""Golden-trace regression harness + oracle/batched backend agreement.

Freezes the reference simulator's full ``SimResult`` surface for a
Big+Little+Special-Function chip on six representative workloads
(tests/golden/*.json, regenerate with ``pytest --regen-golden``), and pins
the batched plan executor to the oracle on the same runs.  Throughput-mode
(§3.2 pipelined) runs freeze the steady-state pipeline section too, and
both the batched executor and the fused batched mapper+executor are
pinned to the oracle's II on the same runs.  The slow marker extends the
backend-agreement checks (both schedule modes) to the full 20-workload
suite (the ISSUE-2/ISSUE-4 acceptance bars).
"""
import numpy as np
import pytest

from repro.core import compile_workload, hetero_bls, simulate
from repro.core.compiler.batched_mapper import map_and_simulate
from repro.core.compiler.pipeline import lower_plan
from repro.core.dse.engine import prepared_workload
from repro.core.simulator.batched import simulate_plans, stack_chip_configs
from repro.core.workloads import build, workload_names

# throughput-mode steady-state surface every backend must agree on
PIPELINE_KEYS = ("ii_s", "ii_tile_bound_s", "ii_dram_bound_s",
                 "ii_noc_bound_s", "fill_latency_s", "energy_ss_pj",
                 "achieved_tops_ss", "pipeline_depth",
                 "dram_bytes_per_batch")

# one per execution-path family: quantized CNN, FP16 ViT, INT4 LLM,
# SNN (LIF), FFT long-conv, polynomial (KAN)
GOLDEN_WORKLOADS = ["resnet50_int8", "vit_b16_fp16", "llama7b_int4",
                    "snn_vgg9", "hyena_1_3b", "kan"]

REL_TOL = 1e-9  # oracle vs batched: same formulas, reduction order only


def _reference_chip():
    return hetero_bls()


def _run(wname):
    chip = _reference_chip()
    plan = compile_workload(build(wname), chip)
    return chip, plan, simulate(chip, plan)


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_golden_trace(wname, golden):
    _, _, r = _run(wname)
    golden(wname, r.golden_dict())


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_batched_matches_oracle_on_golden_runs(wname):
    chip, plan, r = _run(wname)
    res = simulate_plans([chip], [lower_plan(plan, chip.num_tiles)])
    assert res["latency_s"][0] == pytest.approx(r.latency_s, rel=REL_TOL)
    assert res["energy_pj"][0] == pytest.approx(r.energy_pj, rel=REL_TOL)
    assert res["achieved_tops"][0] == pytest.approx(r.achieved_tops,
                                                    rel=REL_TOL)
    # per-module energy agreement (leakage included)
    eb = r.energy_breakdown
    for mod in ("compute", "dram", "sram", "irf", "orf", "dsp", "special",
                "noc", "leakage", "fuse_savings"):
        got = float(res[f"energy_{mod}_pj"][0])
        want = getattr(eb, mod)
        assert got == pytest.approx(want, rel=REL_TOL, abs=1e-9), mod
    # per-tile op counts and power gating line up with the oracle trace
    n = len(r.tiles)
    assert res["tile_ops"][0][:n].tolist() == [b.ops for b in r.tiles]
    assert res["power_gated"][0][:n].tolist() == \
        [b.power_gated for b in r.tiles]
    np.testing.assert_allclose(res["tile_active_s"][0][:n],
                               [b.active_s for b in r.tiles], rtol=REL_TOL)


def _run_throughput(wname):
    chip = _reference_chip()
    plan = compile_workload(build(wname), chip, mode="throughput")
    return chip, plan, simulate(chip, plan)


def _assert_throughput_parity(wname, chip, plan, r):
    """Oracle II vs (a) the batched executor replaying the compiled plan,
    (b) the fused compile-free mapper+executor — the 0-rel-err bar."""
    assert r.mode == "throughput" and r.pipeline is not None
    table = lower_plan(plan, chip.num_tiles)
    assert table.mode == "throughput"
    res = simulate_plans([chip], [table])
    assert res["mode"] == "throughput"
    fused = map_and_simulate(prepared_workload(wname),
                             stack_chip_configs([chip]), mode="throughput")
    assert bool(fused["ok"][0]), wname
    for k in PIPELINE_KEYS:
        assert float(res[k][0]) == pytest.approx(r.pipeline[k],
                                                 rel=REL_TOL), (wname, k)
        assert float(fused[k][0]) == pytest.approx(r.pipeline[k],
                                                   rel=REL_TOL), (wname, k)
    # pipelining is never slower per batch than the serial replay
    assert r.pipeline["ii_s"] <= r.latency_s * (1 + 1e-12)


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_golden_trace_throughput(wname, golden):
    """Freeze the throughput-mode steady state (II + bounds + per-batch
    energy) for the hetero-BLS reference runs."""
    _, _, r = _run_throughput(wname)
    golden(f"{wname}_throughput", r.golden_dict())


@pytest.mark.parametrize("wname", GOLDEN_WORKLOADS)
def test_throughput_backends_match_oracle_on_golden_runs(wname):
    chip, plan, r = _run_throughput(wname)
    _assert_throughput_parity(wname, chip, plan, r)


@pytest.mark.slow
def test_batched_matches_oracle_full_suite():
    """Acceptance bar: backend agreement across all 20 stock workloads on
    the fixed reference chip."""
    chip = _reference_chip()
    for wname in workload_names():
        plan = compile_workload(build(wname), chip)
        r = simulate(chip, plan)
        res = simulate_plans([chip], [lower_plan(plan, chip.num_tiles)])
        assert res["latency_s"][0] == pytest.approx(r.latency_s,
                                                    rel=REL_TOL), wname
        assert res["energy_pj"][0] == pytest.approx(r.energy_pj,
                                                    rel=REL_TOL), wname


@pytest.mark.slow
def test_throughput_backends_match_oracle_full_suite():
    """ISSUE-4 acceptance bar: throughput-mode II agreement (batched
    executor AND fused mapper+executor vs ChipSim) across all 20 stock
    workloads on the fixed reference chip."""
    for wname in workload_names():
        chip, plan, r = _run_throughput(wname)
        _assert_throughput_parity(wname, chip, plan, r)
