"""End-to-end behaviour tests for the paper's system: MOSAIC's qualitative
claims reproduce on the real pipeline."""
import numpy as np
import pytest

from repro.core import compile_workload, hetero_bls, homogeneous_baseline, simulate
from repro.core.arch import ChipConfig, Sparsity, TileTemplate
from repro.core.ir import Precision
from repro.core.workloads import build
from repro.core.workloads.extract import extract_model
from repro.models import get_config, list_archs


def _iso_pair():
    """Homogeneous FP16+INT8 chip vs a precision-matched heterogeneous chip
    in the same area bracket."""
    homo = homogeneous_baseline(8, 32, 32, sram_kb=2048)
    little = TileTemplate(
        name="little", rows=64, cols=64, sram_kb=4096,
        precisions=frozenset({Precision.INT4, Precision.INT8}),
        sparsity=Sparsity.TWO_SIDED, dsp_count=2, clock_mhz=1200)
    het = ChipConfig(name="int8-hpu", tiles=((little, 6),), dram_gbps=128.0)
    return homo, het


def test_heterogeneous_saves_energy_on_quantized_cnn():
    """The paper's core claim (Fig. 6 direction): a precision-matched
    heterogeneous chip beats the iso-knob homogeneous baseline on an INT8
    workload."""
    homo, het = _iso_pair()
    g = build("resnet50_int8")
    e_homo = simulate(homo, compile_workload(g, homo)).energy_pj
    e_het = simulate(het, compile_workload(g, het)).energy_pj
    assert (e_homo - e_het) / e_homo > 0.15


def test_special_function_tile_wins_fft_workload():
    """Hyena's FFT long-conv: the SFU changes the cost model asymptotically
    (paper §2.5)."""
    homo = homogeneous_baseline(6)
    bls = hetero_bls(n_big=2, n_little=2, n_special=2)
    g = build("hyena_1_3b")
    r_homo = simulate(homo, compile_workload(g, homo))
    r_bls = simulate(bls, compile_workload(g, bls))
    assert r_bls.latency_s < r_homo.latency_s
    assert r_bls.energy_pj < r_homo.energy_pj


def test_bandwidth_bound_workload_insensitive():
    """spec-decode (paper: +0.28 %): no MAC sizing helps a memory-starved
    workload — savings must be far below the quantized group's."""
    homo, het = _iso_pair()
    g = build("spec_decode")
    e_homo = simulate(homo, compile_workload(g, homo)).energy_pj
    e_het = simulate(het, compile_workload(g, het)).energy_pj
    spec_savings = (e_homo - e_het) / e_homo
    g2 = build("resnet50_int8")
    e_homo2 = simulate(homo, compile_workload(g2, homo)).energy_pj
    e_het2 = simulate(het, compile_workload(g2, het)).energy_pj
    r_savings = (e_homo2 - e_het2) / e_homo2
    assert spec_savings < r_savings


def test_extracted_archs_run_through_mosaic():
    """Every assigned architecture extracts into a MOSAIC DAG and simulates
    on a heterogeneous chip (DESIGN.md §2 loop closure)."""
    chip = hetero_bls()
    for arch in list_archs():
        cfg = get_config(arch)
        g = extract_model(cfg, seq_len=64)
        r = simulate(chip, compile_workload(g, chip))
        assert r.latency_s > 0 and np.isfinite(r.energy_pj), arch
