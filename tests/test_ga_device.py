"""Device GA generation loop + exact search backend (PR 5).

Pins the three contracts the device loop rides on:

* seeded determinism — two same-seed ``run_ga`` runs (device loop
  default) produce bitwise-identical ``best_genome`` and ``history``;
* exact-search/rescore parity — the Eq. 8 fitness a ``backend="exact"``
  search selects on equals the fitness recomputed from a post-hoc exact
  ``rescore()`` bit-for-bit (hypothesis-driven over random populations;
  the full 20-workload suite runs under ``-m slow``);
* the device genetics/canonicalization kernels — jnp canonicalization
  bitwise equal to ``engine.canonical_genomes``, children within
  ``genome_bounds``, elites preserved, Eq. 8 kernel equivalent to the
  host ``ga._fitness``.
"""
import numpy as np
import pytest

from repro.core.dse.api import EngineConfig
from repro.core.dse.encoding import GENOME_LEN, genome_bounds, random_genomes
from repro.core.dse.engine import EvalEngine, canonical_genomes
from repro.core.dse.ga import GAConfig, run_ga, _fitness
from repro.core.dse.ga_device import (MUT_GENES_MAX, _genetics_kernel,
                                      bracket_bounds,
                                      canonical_genomes_device,
                                      fitness_device)
from repro.core.dse.sweep import run_sweep
from repro.core.workloads import workload_names

WLS = ["kan", "resnet50_int8"]


def _sweep():
    return run_sweep(WLS, samples_per_stratum=4, seed=0,
                     brackets=(100.0, 200.0))


def test_canonical_device_bitwise_parity():
    rng = np.random.default_rng(11)
    g = np.concatenate([random_genomes(rng, 32, family=f)
                        for f in (None, "homo", "hetero_bl", "hetero_bls")])
    assert np.array_equal(canonical_genomes(g), canonical_genomes_device(g))


def test_run_ga_device_seeded_determinism():
    sw = _sweep()
    cfg = GAConfig(population=10, generations=3, seed_top_k=6, early_stop=30)
    r1 = run_ga(sw, 200.0, cfg, seed=1)
    r2 = run_ga(sw, 200.0, cfg, seed=1)
    assert r1 is not None and r2 is not None
    assert r1.best_fitness == r2.best_fitness
    assert np.array_equal(r1.best_genome, r2.best_genome)
    assert r1.history == r2.history
    assert r1.evaluated == r2.evaluated
    # a different seed explores a different trajectory (stream sanity)
    r3 = run_ga(sw, 200.0, cfg, seed=2)
    assert r3 is not None
    assert r3.history != r1.history or \
        not np.array_equal(r3.best_genome, r1.best_genome)


def test_run_ga_device_engine_invariance():
    """The device loop's result does not depend on which engine caches
    are warm — memoized vs fresh engines score bitwise identically."""
    sw = _sweep()
    cfg = GAConfig(population=8, generations=2, seed_top_k=4, early_stop=30)
    fresh = run_ga(sw, 200.0, cfg, seed=3,
                   engine=EvalEngine(WLS, config=EngineConfig(backend="exact")))
    warm_engine = EvalEngine(WLS, config=EngineConfig(backend="exact"))
    warm_engine.evaluate(sw.genomes)
    warm = run_ga(sw, 200.0, cfg, seed=3, engine=warm_engine)
    assert fresh.best_fitness == warm.best_fitness
    assert np.array_equal(fresh.best_genome, warm.best_genome)
    assert fresh.history == warm.history


def _parity_check(genomes, workloads, bracket=200.0):
    e_homo = np.ones(len(workloads))  # any positive baseline works
    eng = EvalEngine(workloads, config=EngineConfig(backend="exact"))
    m_search = eng.evaluate(genomes)
    m_rescore = EvalEngine(workloads).rescore(genomes)
    f_search = fitness_device(m_search, e_homo, bracket)
    f_rescore = fitness_device(m_rescore, e_homo, bracket)
    assert np.array_equal(f_search, f_rescore)
    for k in ("latency", "energy", "tops_w", "area"):
        assert np.array_equal(m_search[k], m_rescore[k]), k


def test_exact_search_equals_rescore_fast():
    g = random_genomes(np.random.default_rng(5), 12)
    _parity_check(g, WLS)


def test_exact_search_rescore_parity_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(0, 2 ** 31 - 1), st.integers(2, 10))
    @settings(max_examples=8, deadline=None)
    def prop(seed, n):
        g = random_genomes(np.random.default_rng(seed), n)
        _parity_check(g, ["kan"])

    prop()


@pytest.mark.slow
def test_exact_search_rescore_parity_full_suite():
    """Search-time exact fitness == post-hoc exact rescore across the
    full 20-workload suite."""
    wls = workload_names()
    g = random_genomes(np.random.default_rng(9), 16)
    _parity_check(g, wls)


def test_genetics_kernel_semantics():
    import jax

    rng = np.random.default_rng(21)
    population, tournament, n_elite = 12, 5, 2
    pop = random_genomes(rng, population).astype(np.int32)
    fit = rng.normal(size=population)
    gen_fn = _genetics_kernel(population, tournament, n_elite, 0.8, 0.2)
    children, canon = (np.asarray(a) for a in
                       gen_fn(pop, fit, jax.random.PRNGKey(0)))
    assert children.shape == (population, GENOME_LEN)
    # elites pass through unchanged, in fitness order
    elite_idx = np.argsort(-fit)[:n_elite]
    assert np.array_equal(children[:n_elite], pop[elite_idx])
    # every gene stays inside the knob-grid bounds
    bounds = genome_bounds()
    assert (children >= 0).all()
    assert (children < bounds[None, :]).all()
    # the same dispatch emits the engine's canonical memo keys
    assert np.array_equal(canon, canonical_genomes(children))
    # deterministic under the same key, different under another
    again, _ = gen_fn(pop, fit, jax.random.PRNGKey(0))
    assert np.array_equal(children, np.asarray(again))
    other, _ = gen_fn(pop, fit, jax.random.PRNGKey(1))
    assert not np.array_equal(children, np.asarray(other))


def test_fitness_kernel_matches_host():
    rng = np.random.default_rng(31)
    n, w = 16, 3
    en = rng.uniform(1.0, 5.0, (n, w))
    tw = rng.uniform(0.1, 2.0, (n, w))
    lat = rng.uniform(1e-4, 1e-2, (n, w))
    lat[0, 0] = np.inf            # invalid row
    area = rng.uniform(60.0, 380.0, n)
    e_homo = rng.uniform(2.0, 4.0, w)
    host = _fitness(en, tw, lat, area, 200.0, e_homo, 0.05)
    dev = fitness_device({"energy": en, "tops_w": tw, "latency": lat,
                          "area": area}, e_homo, 200.0, 0.05)
    assert np.array_equal(np.isneginf(host), np.isneginf(dev))
    finite = np.isfinite(host)
    np.testing.assert_allclose(dev[finite], host[finite], rtol=1e-12)


def test_bracket_bounds_match_area_bracket():
    from repro.core.dse.objective import AREA_BRACKETS, area_bracket
    areas = np.linspace(1.0, 1200.0, 257)
    for b in AREA_BRACKETS:
        lo, hi = bracket_bounds(b)
        ref = np.array([area_bracket(a) == b for a in areas])
        assert np.array_equal((areas > lo) & (areas <= hi), ref), b
    lo, hi = bracket_bounds(123.0)   # not a bracket: nothing matches
    assert not ((areas > lo) & (areas <= hi)).any()


def test_run_ga_device_respects_shared_scan_engine():
    """A shared approximate engine still works through the device loop
    (the caller owns the fidelity choice), and meta-backend flows."""
    sw = _sweep()
    cfg = GAConfig(population=8, generations=1, seed_top_k=4, early_stop=30)
    eng = EvalEngine(WLS)   # scan backend
    res = run_ga(sw, 200.0, cfg, seed=0, engine=eng)
    assert res is not None
    assert eng.stats.requests > 0
