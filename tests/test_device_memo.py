"""Device-resident memo table (PR 7): insert/lookup round trip,
put-if-absent + first-copy-wins duplicate semantics, graceful drop at
full load factor without corrupting live entries, and the seed-boundary
host sync (``engine.export_memo`` -> ``memo_from_store``,
``memo_insert`` -> ``drain_to_store``) round-tripping rows bitwise."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dse.api import EngineConfig
from repro.core.dse.device_memo import (PROBES, drain_to_store, memo_fill,
                                        memo_from_store, memo_init,
                                        memo_insert, memo_lookup)
from repro.core.dse.encoding import GENOME_LEN, random_genomes
from repro.core.dse.engine import EvalEngine, canonical_genomes

W = 2  # workload-row width for the synthetic tables


def _keys(n: int, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    g = rng.integers(0, 100, size=(n, GENOME_LEN)).astype(np.int32)
    g[:, 0] = np.arange(n)  # force distinct rows
    return jnp.asarray(g)


def _vals(n: int, seed: int = 1) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, 3, W)))


def _bitwise(a, b) -> bool:
    return np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_insert_lookup_roundtrip():
    keys, vals = _keys(32), _vals(32)
    memo = memo_insert(memo_init(128, W), keys, vals)
    assert memo_fill(memo) == 32
    hit, out = memo_lookup(memo, keys)
    assert bool(jnp.all(hit))
    assert _bitwise(out, vals)
    # unknown keys miss
    miss, _ = memo_lookup(memo, _keys(8, seed=99) + 1000)
    assert not bool(jnp.any(miss))


def test_put_if_absent_keeps_first_rows():
    keys = _keys(16)
    memo = memo_insert(memo_init(128, W), keys, _vals(16, seed=1))
    # re-offering the same keys with different values writes nothing
    memo2 = memo_insert(memo, keys, _vals(16, seed=2))
    _, out = memo_lookup(memo2, keys)
    assert _bitwise(out, _vals(16, seed=1))
    assert memo_fill(memo2) == 16


def test_in_batch_duplicates_first_copy_wins():
    keys = np.array(_keys(8))
    vals = np.array(_vals(8, seed=3))
    keys[5] = keys[2]          # rows 2 and 5 share a key...
    vals[5] += 1.0             # ...with different rows
    memo = memo_insert(memo_init(64, W), jnp.asarray(keys),
                       jnp.asarray(vals))
    assert memo_fill(memo) == 7
    _, out = memo_lookup(memo, jnp.asarray(keys[2:3]))
    assert _bitwise(out[0], vals[2])   # lowest row index won


def test_update_mask_gates_inserts():
    keys, vals = _keys(16), _vals(16)
    upd = jnp.arange(16) < 10
    memo = memo_insert(memo_init(128, W), keys, vals, update=upd)
    hit, _ = memo_lookup(memo, keys)
    assert bool(jnp.all(hit[:10])) and not bool(jnp.any(hit[10:]))


def test_full_load_factor_drops_without_corruption():
    """Offering far more keys than capacity fills the table and drops the
    overflow — no eviction, no corruption: every previously inserted key
    keeps returning its exact row, and every reported hit is bitwise the
    row that was inserted for that key."""
    cap = 8   # probe window covers the whole table (min(PROBES, cap))
    assert cap <= PROBES
    first_k, first_v = _keys(cap, seed=0), _vals(cap, seed=0)
    memo = memo_insert(memo_init(cap, W), first_k, first_v)
    assert memo_fill(memo) == cap          # full
    # a saturating second wave of distinct keys
    second_k = _keys(64, seed=7) + 1000
    memo2 = memo_insert(memo, second_k, _vals(64, seed=7))
    assert memo_fill(memo2) == cap         # nothing evicted, all dropped
    hit, out = memo_lookup(memo2, first_k)
    assert bool(jnp.all(hit))
    assert _bitwise(out, first_v)          # live entries untouched
    hit2, _ = memo_lookup(memo2, second_k)
    assert not bool(jnp.any(hit2))         # dropped, not half-written
    # determinism: the same saturating insert replays to the same table
    memo3 = memo_insert(memo, second_k, _vals(64, seed=7))
    for a, b in zip(memo2, memo3):
        assert _bitwise(a, b)


def test_engine_sync_roundtrip():
    """memo_from_store preloads exactly what the engine scored, bitwise;
    drained entries round-trip into a second engine's store and serve
    its evaluations without recomputation."""
    rng = np.random.default_rng(5)
    genomes = random_genomes(rng, 8)
    eng = EvalEngine(["kan"], config=EngineConfig(backend="exact"))
    m = eng.evaluate(genomes)

    memo = memo_from_store(eng, 64)
    canon = jnp.asarray(canonical_genomes(genomes), jnp.int32)
    hit, vals = memo_lookup(memo, canon)
    assert bool(jnp.all(hit))
    out = np.asarray(vals, np.float64)
    assert _bitwise(out[:, 0], m["latency"])
    assert _bitwise(out[:, 1], m["energy"])
    assert _bitwise(out[:, 2], m["tops_w"])
    # preloaded entries are not fresh: nothing drains back
    assert drain_to_store(memo, eng) == 0

    # fresh inserts DO drain — into a cold engine whose store then
    # serves the same genomes as pure hits, bitwise
    eng2 = EvalEngine(["kan"], config=EngineConfig(backend="exact"))
    memo2 = memo_insert(memo_init(64, 1), canon, vals)
    assert drain_to_store(memo2, eng2) == memo_fill(memo2)
    m2 = eng2.evaluate(genomes)
    assert m2["meta"]["hits"] == len(genomes)
    assert m2["meta"]["misses"] == 0
    for k in ("latency", "energy", "tops_w"):
        assert _bitwise(m2[k], m[k])
