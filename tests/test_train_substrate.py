"""Training substrate: data determinism, checkpoint durability, restart,
fault injection + elastic re-mesh, straggler detection, optimizer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.models import get_config
from repro.optim.adamw import AdamWConfig, apply_updates, init_opt_state
from repro.optim.compression import (compress_int8, decompress_int8,
                                     ef_compress_tree, init_error_state)
from repro.train.fault import ElasticMesh, FaultInjector, SimulatedDeviceFailure
from repro.train.loop import TrainLoopConfig, train_loop
from repro.train.straggler import StragglerDetector


# ---------------------------------------------------------------- data
def test_data_deterministic_and_shardable():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=7)
    p = SyntheticTokenPipeline(cfg)
    b1, b2 = p.batch(3), p.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # resharding yields per-shard streams independent of geometry history
    p2 = p.reshard(1, 2)
    b = p2.batch(5)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"], p2.batch(5)["tokens"])
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6).reshape(2, 3),
             "b": {"c": jnp.ones(4, jnp.bfloat16)},
             "step": jnp.asarray(5)}
    save_checkpoint(str(tmp_path), 5, state)
    like = jax.tree.map(jnp.zeros_like, state)
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(6).reshape(2, 3))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_commit(tmp_path):
    # a stale .tmp dir must never be visible as a checkpoint
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 3, {"x": jnp.zeros(2)})
    assert latest_step(str(tmp_path)) == 3


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, {"x": jnp.full(3, s)})
    mgr.wait()
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2 and kept[-1] == "step_00000004"


# ---------------------------------------------------------------- optim
def test_adamw_reduces_loss_quadratic():
    opt_cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params, opt_cfg)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, opt_cfg)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_int8_moments_track_fp32():
    """8-bit moments guarantee trend tracking, not coordinate equality:
    assert high update correlation + bounded worst-case deviation."""
    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (64,))}
    g = {"w": jax.random.normal(jax.random.fold_in(k, 1), (64,))}
    out = {}
    for md in ("fp32", "int8"):
        cfg = AdamWConfig(lr=1e-2, moments_dtype=md)
        p, s = dict(params), init_opt_state(params, cfg)
        for _ in range(10):
            p, s, _ = apply_updates(p, g, s, cfg)
        out[md] = np.asarray(p["w"])
    w0 = np.asarray(params["w"])
    upd_fp, upd_q = out["fp32"] - w0, out["int8"] - w0
    assert np.corrcoef(upd_fp, upd_q)[0, 1] > 0.9
    assert np.abs(upd_q - upd_fp).max() < 3.0 * np.abs(upd_fp).mean()


def test_gradient_compression_error_feedback_unbiased():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=256) * 1e-3)}
    err = init_error_state(g)
    acc = jnp.zeros(256)
    for _ in range(50):
        comp, err = ef_compress_tree(g, err)
        q, s = comp["w"]
        acc = acc + decompress_int8(q, s)
    mean_rel = float(jnp.abs(acc / 50 - g["w"]).mean()
                     / jnp.abs(g["w"]).mean())
    assert mean_rel < 0.05  # error feedback keeps compression unbiased


# ------------------------------------------------------------- straggler
def test_straggler_detector_flags_and_evicts():
    t = [0.0]

    def clock():
        return t[0]

    det = StragglerDetector(threshold=2.0, warmup_steps=2, trip_limit=2,
                            clock=clock)
    durs = [1.0, 1.0, 1.0, 1.0, 5.0, 5.0, 1.0]
    events = []
    for i, d in enumerate(durs):
        det.step_start()
        t[0] += d
        ev = det.step_end(i)
        if ev:
            events.append(ev)
        if i == 5:
            assert det.should_evict
    assert len(events) == 2
    assert events[0].ratio > 2.0
    assert not det.should_evict  # normal step reset the trip counter


# ------------------------------------------- fault injection + restart
@pytest.mark.slow
def test_train_loop_recovers_from_failures(tmp_path):
    cfg = get_config("granite-20b").reduced()
    loop = TrainLoopConfig(steps=40, ckpt_every=6, global_batch=4, seq_len=32,
                           ckpt_dir=str(tmp_path))
    inj = FaultInjector(fail_at={10, 17})
    out = train_loop(cfg, loop, AdamWConfig(lr=3e-3), fault_injector=inj)
    assert out["restarts"] == 2
    assert out["steps_run"] == 40
    # loss trend went down overall
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5])


@pytest.mark.slow
def test_restart_is_bit_identical(tmp_path):
    """A run interrupted + resumed must equal an uninterrupted run."""
    cfg = get_config("starcoder2-15b").reduced()

    def run(ckpt_dir, inj=None):
        loop = TrainLoopConfig(steps=12, ckpt_every=4, global_batch=2,
                               seq_len=16, ckpt_dir=ckpt_dir)
        return train_loop(cfg, loop, fault_injector=inj)

    clean = run(str(tmp_path / "a"))
    faulty = run(str(tmp_path / "b"), FaultInjector(fail_at={6}))
    np.testing.assert_allclose(clean["final_loss"], faulty["final_loss"],
                               rtol=1e-6)


def test_elastic_mesh_shrinks_data_axis():
    em = ElasticMesh(model_parallel=1)
    n0 = em.n_data
    assert n0 == len(jax.devices())
    if n0 > 1:
        em.fail(0)
        assert em.n_data == n0 - 1
    mesh = em.mesh()
    assert mesh.shape["model"] == 1
