"""The unified engine/evaluator API (PR 9): ``EngineConfig`` as the one
knob surface, the ``Evaluator`` protocol conformance suite shared by
every scoring surface (local ``EvalEngine``, in-process ``DSEClient``,
TCP ``DSEClient``, and the sharded ``DSECluster`` coordinator), the
legacy-kwarg deprecation shim, and the ``result["meta"]`` schema
stamp."""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core.dse.api import (BACKENDS, EngineConfig, Evaluator,
                                META_VERSION, context_digest)
from repro.core.dse.encoding import random_genomes
from repro.core.dse.engine import EvalEngine
from repro.serve.dse_service import DSEClient, DSEService

WLS = ["kan"]
METRICS = ("latency", "energy", "tops_w", "area")


@pytest.fixture(scope="module")
def service():
    svc = DSEService(EvalEngine(WLS), max_batch=64, max_wait_ms=20.0)
    svc.start()
    yield svc
    svc.stop()


@pytest.fixture(scope="module", params=["engine", "client", "tcp",
                                        "cluster"])
def evaluator(request, service):
    """One fixture per scoring surface; each must satisfy the full
    ``Evaluator`` contract below."""
    if request.param == "engine":
        yield EvalEngine(WLS, config=EngineConfig())
        return
    if request.param == "client":
        cl = DSEClient(service=service)
        yield cl
        cl.close()
        return
    if request.param == "cluster":
        from repro.serve.cluster import DSECluster
        svcs = [DSEService(EvalEngine(WLS), max_batch=64, max_wait_ms=20.0,
                           worker_id=f"api-w{i}").start() for i in range(3)]
        cl = DSECluster(svcs)
        yield cl
        cl.close()
        for svc in svcs:
            svc.stop()
        return
    host, port = service.listen()
    cl = DSEClient(address=(host, port))
    yield cl
    cl.close()


def _genomes(n=8, seed=11):
    return random_genomes(np.random.default_rng(seed), n)


# =============================================================================
# Evaluator protocol conformance (shared across all three surfaces)
# =============================================================================

def test_satisfies_protocol(evaluator):
    assert isinstance(evaluator, Evaluator)
    assert list(evaluator.workloads) == WLS
    assert evaluator.stats is not None


def test_evaluate_contract(evaluator):
    g = _genomes()
    res = evaluator.evaluate(g)
    for k in ("latency", "energy", "tops_w"):
        assert res[k].shape == (len(g), len(WLS)), k
        assert res[k].dtype == np.float64, k
    assert res["area"].shape == (len(g),)
    meta = res["meta"]
    assert meta["meta_version"] == META_VERSION
    assert meta["backend"] in BACKENDS
    assert meta["fidelity"] in ("aggregate", "link")
    assert meta["mode"] in ("latency", "throughput")
    assert meta["requests"] == len(g)


def test_rescore_contract(evaluator):
    res = evaluator.rescore(_genomes(4))
    for k in METRICS:
        assert k in res
    assert res["meta"]["meta_version"] == META_VERSION
    assert res["meta"]["fidelity"] in ("aggregate", "link")


def test_score_batch_matches_evaluate(evaluator):
    g = _genomes(6, seed=12)
    ref = evaluator.evaluate(g)
    got = evaluator.score_batch(g)
    assert set(got) == set(METRICS)   # metrics only, no meta
    for k in METRICS:
        assert got[k].tobytes() == ref[k].tobytes(), k


def test_context_key_matches_local_engine(evaluator):
    key = evaluator.context_key()
    assert isinstance(key, bytes) and len(key) == 32
    assert key == EvalEngine(WLS).context_key()


# =============================================================================
# EngineConfig: validation, digest coverage, immutability
# =============================================================================

def test_config_is_frozen_and_comparable():
    a, b = EngineConfig(backend="exact"), EngineConfig(backend="exact")
    assert a == b
    assert a != EngineConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.backend = "scan"
    # store is wiring, not identity: excluded from equality and repr
    assert EngineConfig(store=object()) == EngineConfig()
    assert "store" not in repr(EngineConfig())


@pytest.mark.parametrize("kw", [
    {"backend": "cuda"},
    {"mode": "speed"},
    {"fidelity": "cycle"},
    {"exact_mapper": "rust"},
    {"nonfinite": "ignore"},
    {"batch": 0},
    {"backend": "exact", "exact_mapper": "python"},
])
def test_config_rejects_invalid_knobs(kw):
    with pytest.raises(ValueError):
        EngineConfig(**kw)


def test_every_metric_knob_lands_in_the_digest():
    """The acceptance bar: all knobs flow through EngineConfig's context
    digest — fidelity and the compile flags change it, the exact-family
    backends share one digest class, scan gets its own."""
    from repro.core.calibrate.asap7 import DEFAULT_CALIB
    base = EngineConfig().context_digest(WLS, DEFAULT_CALIB)
    assert EngineConfig(fidelity="link").context_digest(
        WLS, DEFAULT_CALIB) != base
    assert EngineConfig(aggressive_int4=True).context_digest(
        WLS, DEFAULT_CALIB) != base
    assert EngineConfig(enable_fusion=False).context_digest(
        WLS, DEFAULT_CALIB) != base
    exact = EngineConfig(backend="exact").context_digest(WLS, DEFAULT_CALIB)
    assert exact != base                     # scan maps approximately
    for b in ("batched", "oracle"):
        assert EngineConfig(backend=b).context_digest(
            WLS, DEFAULT_CALIB) == exact     # one exact mapping class
    # non-metric knobs (batch size, memo sizing, store) don't invalidate
    assert EngineConfig(batch=7, memo_max=9,
                        memoize=False).context_digest(
        WLS, DEFAULT_CALIB) == base
    assert context_digest(WLS, DEFAULT_CALIB, False, True, "scan",
                          "aggregate") == base


def test_engine_context_key_delegates_to_config():
    from repro.core.calibrate.asap7 import DEFAULT_CALIB
    cfg = EngineConfig(backend="exact", fidelity="link")
    eng = EvalEngine(WLS, config=cfg)
    assert eng.context_key() == cfg.context_digest(WLS, DEFAULT_CALIB)
    assert eng.config == cfg
    assert eng.fidelity == "link"


# =============================================================================
# legacy-kwarg deprecation shim
# =============================================================================

def test_config_path_emits_no_warnings():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        EvalEngine(WLS, config=EngineConfig(backend="exact"))


def test_legacy_kwargs_warn_and_still_work():
    with pytest.warns(DeprecationWarning, match=r"backend.*nonfinite"):
        eng = EvalEngine(WLS, backend="exact", nonfinite="skip")
    assert eng.config == EngineConfig(backend="exact", nonfinite="skip")
    assert eng.backend == "exact"
    g = _genomes(3, seed=13)
    ref = EvalEngine(WLS, config=EngineConfig(backend="exact",
                                              nonfinite="skip")).evaluate(g)
    got = eng.evaluate(g)
    for k in METRICS:
        assert got[k].tobytes() == ref[k].tobytes(), k


def test_memo_limit_warns_specifically():
    # two warnings fire: the specific memo_limit-alias one, then the
    # aggregated legacy-kwargs one for the memo_max it maps to
    with pytest.warns(DeprecationWarning) as rec:
        eng = EvalEngine(WLS, memo_limit=2048)
    assert any("memo_limit" in str(w.message) for w in rec)
    assert eng.config.memo_max == 2048


def test_config_plus_legacy_kwargs_is_an_error():
    with pytest.raises(ValueError, match="config"):
        EvalEngine(WLS, backend="exact", config=EngineConfig())
