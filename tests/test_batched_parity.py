"""Property-based parity: ChipSim oracle vs the batched plan executor.

Random genomes x random small DAGs must agree to float tolerance (the two
backends share ``simulator.costs`` formulas, so any gap is an
orchestration bug), plus cost-model monotonicity properties:

* more DRAM bandwidth never increases latency — asserted on a single-tile
  chip, where it is a theorem of the per-tile model (with multiple tiles
  the dynamic N_active bandwidth share makes chip-level monotonicity a
  non-theorem: an earlier dependence edge can start an op inside a busier
  window);
* adding an idle tile never reduces energy below the power-gating floor
  (BUS interconnect, so hop counts don't change with the tile count).
"""
import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import assume, given, settings, strategies as st

from repro.core.arch import ChipConfig, Interconnect, TileTemplate, big_tile
from repro.core.calibrate.asap7 import DEFAULT_CALIB
from repro.core.compiler.mapper import UnmappableError
from repro.core.compiler.pipeline import compile_workload, lower_plan
from repro.core.dse.encoding import random_genomes, decode
from repro.core.ir import OpNode, OpType, Precision, WorkloadGraph
from repro.core.simulator.area import tile_area
from repro.core.simulator.batched import simulate_plans
from repro.core.simulator.orchestrator import ChipSim, simulate

SETTINGS = dict(max_examples=25, deadline=None)
REL = 1e-9

_OP_POOL = [OpType.MATMUL, OpType.FC, OpType.ADD, OpType.SOFTMAX,
            OpType.GELU, OpType.SSM_SCAN, OpType.FFT, OpType.SNN_LIF,
            OpType.POLY]


@st.composite
def small_graphs(draw):
    n_ops = draw(st.integers(3, 9))
    g = WorkloadGraph("prop", model_precision=Precision.INT8)
    for i in range(n_ops):
        ot = draw(st.sampled_from(_OP_POOL))
        preds = []
        if i > 0:
            k = draw(st.integers(0, min(2, i)))
            preds = sorted(set(draw(
                st.lists(st.integers(0, i - 1), min_size=k, max_size=k))))
        kw = dict(precision=draw(st.sampled_from(
            [Precision.INT8, Precision.FP16])))
        if ot in (OpType.MATMUL, OpType.FC):
            node = OpNode(f"op{i}", ot,
                          m=draw(st.integers(1, 96)),
                          k=draw(st.integers(1, 96)),
                          n=draw(st.integers(1, 96)),
                          act_sparsity=draw(st.sampled_from([0.0, 0.3, 0.6])),
                          w_sparsity=draw(st.sampled_from([0.0, 0.5])), **kw)
        elif ot == OpType.FFT:
            node = OpNode(f"op{i}", ot, elems=draw(st.integers(64, 4096)),
                          fft_n=draw(st.sampled_from([8, 32, 128])), **kw)
        elif ot == OpType.SNN_LIF:
            node = OpNode(f"op{i}", ot, elems=draw(st.integers(16, 2048)),
                          snn_timesteps=draw(st.integers(1, 8)), **kw)
        elif ot == OpType.POLY:
            node = OpNode(f"op{i}", ot, elems=draw(st.integers(16, 2048)),
                          poly_degree=draw(st.integers(1, 6)), **kw)
        elif ot == OpType.SSM_SCAN:
            node = OpNode(f"op{i}", ot, elems=draw(st.integers(64, 4096)),
                          seq_len=draw(st.sampled_from([1, 16, 64])), **kw)
        else:
            node = OpNode(f"op{i}", ot, elems=draw(st.integers(16, 8192)),
                          **kw)
        g.add(node, preds)
    return g


@given(small_graphs(), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_oracle_and_batched_agree_on_random_pairs(g, seed):
    chip = decode(random_genomes(np.random.default_rng(seed), 1)[0], "prop")
    try:
        plan = compile_workload(g, chip)
    except UnmappableError:
        assume(False)
    r = simulate(chip, plan)
    res = simulate_plans([chip], [lower_plan(plan, chip.num_tiles)])
    assert res["latency_s"][0] == pytest.approx(r.latency_s, rel=REL)
    assert res["energy_pj"][0] == pytest.approx(r.energy_pj, rel=REL)
    n = len(r.tiles)
    assert res["tile_ops"][0][:n].tolist() == [b.ops for b in r.tiles]
    assert res["power_gated"][0][:n].tolist() == \
        [b.power_gated for b in r.tiles]


@given(small_graphs(), st.sampled_from([8.0, 16.0, 64.0]),
       st.sampled_from([2.0, 4.0, 16.0]))
@settings(**SETTINGS)
def test_more_dram_bandwidth_never_slower_single_tile(g, bw, factor):
    """Per-tile model theorem: on one tile every op's DRAM stage scales
    down with bandwidth and nothing else changes, so the serialized
    makespan is monotone.  Both backends must agree on both points."""
    tile = big_tile()
    slow_chip = ChipConfig(name="slow", tiles=((tile, 1),), dram_gbps=bw)
    fast_chip = dataclasses.replace(slow_chip, name="fast",
                                    dram_gbps=bw * factor)
    try:
        plan = compile_workload(g, slow_chip)
    except UnmappableError:
        assume(False)
    r_slow = simulate(slow_chip, plan)
    r_fast = simulate(fast_chip, plan)
    assert r_fast.latency_s <= r_slow.latency_s * (1 + 1e-12)
    res = simulate_plans([slow_chip, fast_chip],
                         [lower_plan(plan, 1), lower_plan(plan, 1)])
    assert res["latency_s"][0] == pytest.approx(r_slow.latency_s, rel=REL)
    assert res["latency_s"][1] == pytest.approx(r_fast.latency_s, rel=REL)


@given(small_graphs(), st.integers(0, 3))
@settings(**SETTINGS)
def test_idle_tile_never_cuts_energy_below_gating_floor(g, sram_idx):
    """A tile the plan never touches adds exactly its power-gated leakage
    floor (BUS interconnect: hops independent of tile count), so total
    energy never drops below base + floor."""
    base_tile = big_tile()
    idle = TileTemplate(name="idle", rows=16, cols=16,
                        sram_kb=(64, 256, 1024, 2048)[sram_idx])
    chip1 = ChipConfig(name="c1", tiles=((base_tile, 1),),
                       interconnect=Interconnect.BUS)
    chip2 = ChipConfig(name="c2", tiles=((base_tile, 1), (idle, 1)),
                       interconnect=Interconnect.BUS)
    try:
        plan = compile_workload(g, chip1)
    except UnmappableError:
        assume(False)
    r1 = simulate(chip1, plan)
    r2 = ChipSim(chip2).run(plan)  # same plan: the idle tile gets no work
    assert r2.latency_s == pytest.approx(r1.latency_s, rel=REL)
    floor = DEFAULT_CALIB.leak_mw_per_mm2 * tile_area(idle) \
        * r1.latency_s * DEFAULT_CALIB.power_gate_residual * 1e9
    assert r2.energy_pj >= r1.energy_pj + floor * (1 - 1e-9)
    assert r2.tiles[1].power_gated
    # batched backend sees the identical floor
    res = simulate_plans([chip2], [lower_plan(plan, 2)])
    assert res["energy_pj"][0] == pytest.approx(r2.energy_pj, rel=REL)
    assert bool(res["power_gated"][0][1])


@given(small_graphs(), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_pipelining_never_slower_per_batch(g, seed):
    """Throughput-mode theorem: the steady-state initiation interval never
    exceeds the latency-mode makespan (serial replay — one batch per
    makespan — is always an admissible pipelined schedule), and the
    batched executor agrees with the oracle on the whole steady-state
    surface for random graph x chip pairs."""
    chip = decode(random_genomes(np.random.default_rng(seed), 1)[0], "prop")
    try:
        plan = compile_workload(g, chip, mode="throughput")
    except UnmappableError:
        assume(False)
    r = simulate(chip, plan)
    assert r.pipeline is not None
    assert r.pipeline["ii_s"] <= r.latency_s * (1 + 1e-12)
    # every resource bound is a lower bound on II up to the serial clamp
    assert r.pipeline["ii_s"] <= max(r.pipeline["ii_tile_bound_s"],
                                     r.pipeline["ii_dram_bound_s"],
                                     r.pipeline["ii_noc_bound_s"]) \
        * (1 + 1e-12) + 1e-30
    res = simulate_plans([chip], [lower_plan(plan, chip.num_tiles)])
    assert res["mode"] == "throughput"
    for k in ("ii_s", "ii_tile_bound_s", "ii_dram_bound_s",
              "ii_noc_bound_s", "energy_ss_pj"):
        assert float(res[k][0]) == pytest.approx(r.pipeline[k], rel=REL,
                                                 abs=1e-30), k


@pytest.mark.slow
@given(small_graphs(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=150, deadline=None)
def test_oracle_and_batched_agree_thorough(g, seed):
    """Wider-budget twin of the random-pair parity property (CI slow job
    runs it with HYPOTHESIS_PROFILE=thorough)."""
    chip = decode(random_genomes(np.random.default_rng(seed), 1)[0], "prop")
    try:
        plan = compile_workload(g, chip)
    except UnmappableError:
        assume(False)
    r = simulate(chip, plan)
    res = simulate_plans([chip], [lower_plan(plan, chip.num_tiles)])
    assert res["latency_s"][0] == pytest.approx(r.latency_s, rel=REL)
    assert res["energy_pj"][0] == pytest.approx(r.energy_pj, rel=REL)
