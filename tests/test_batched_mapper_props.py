"""Hypothesis property: batched-mapper placements bitwise vs map_graph.

Random DAGs (biased toward the mapper's decision branches: MAC shapes
large enough that Eq. 3 splits win sometimes, SPECIAL ops for SFU
routing, non-splittable ops, fusable MAC->DSP chains) x random genomes
must produce byte-identical ``owner`` / ``n_split`` / ``split_axis`` /
``split_mask`` rows through both mappers.  Deterministic branch-coverage
cases and the full 20-workload suite live in test_batched_mapper.py,
which runs even where hypothesis is absent.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import hetero_bls
from repro.core.dse.encoding import decode, random_genomes
from repro.core.ir import OpNode, OpType, Precision, WorkloadGraph

from test_batched_mapper import _check_chips

_OP_POOL = [OpType.MATMUL, OpType.FC, OpType.ADD, OpType.SOFTMAX,
            OpType.GELU, OpType.SSM_SCAN, OpType.FFT, OpType.SNN_LIF,
            OpType.POLY]


@st.composite
def mapper_graphs(draw):
    n_ops = draw(st.integers(3, 10))
    g = WorkloadGraph("prop", model_precision=draw(
        st.sampled_from([Precision.INT8, Precision.FP16])))
    for i in range(n_ops):
        ot = draw(st.sampled_from(_OP_POOL))
        preds = []
        if i > 0:
            k = draw(st.integers(0, min(2, i)))
            preds = sorted(set(draw(
                st.lists(st.integers(0, i - 1), min_size=k, max_size=k))))
        kw = dict(precision=draw(st.sampled_from(
            [Precision.INT8, Precision.FP16])))
        if ot in (OpType.MATMUL, OpType.FC):
            node = OpNode(f"op{i}", ot,
                          m=draw(st.sampled_from([1, 17, 96, 256, 512])),
                          k=draw(st.sampled_from([8, 96, 512])),
                          n=draw(st.sampled_from([1, 64, 512, 1024])),
                          splittable=draw(st.booleans()),
                          act_sparsity=draw(st.sampled_from([0.0, 0.5])),
                          **kw)
        elif ot == OpType.FFT:
            node = OpNode(f"op{i}", ot, elems=draw(st.integers(64, 4096)),
                          fft_n=draw(st.sampled_from([8, 32, 256])), **kw)
        elif ot == OpType.SNN_LIF:
            node = OpNode(f"op{i}", ot, elems=draw(st.integers(16, 2048)),
                          snn_timesteps=draw(st.integers(1, 8)), **kw)
        elif ot == OpType.POLY:
            node = OpNode(f"op{i}", ot, elems=draw(st.integers(16, 2048)),
                          poly_degree=draw(st.integers(1, 6)), **kw)
        elif ot == OpType.SSM_SCAN:
            node = OpNode(f"op{i}", ot, elems=draw(st.integers(64, 4096)),
                          seq_len=draw(st.sampled_from([1, 16, 64])), **kw)
        else:
            node = OpNode(f"op{i}", ot, elems=draw(st.integers(16, 8192)),
                          **kw)
        g.add(node, preds)
    return g


@given(mapper_graphs(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_batched_placements_bitwise_vs_map_graph(g, seed):
    rng = np.random.default_rng(seed)
    chips = [decode(x, f"p{i}")
             for i, x in enumerate(random_genomes(rng, 3))]
    chips.append(hetero_bls())
    _check_chips(g, chips)


@pytest.mark.slow
@given(mapper_graphs(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=150, deadline=None)
def test_batched_placements_bitwise_thorough(g, seed):
    rng = np.random.default_rng(seed)
    chips = [decode(x, f"p{i}")
             for i, x in enumerate(random_genomes(rng, 4))]
    _check_chips(g, chips)
