"""Direct unit coverage for ChipSim edge paths (ISSUE-2 satellite):
activation-cache FIFO eviction, cross-tile NoC DMA vs DRAM-miss
accounting, noc_hops per Interconnect topology, and Eq. 3 split-op
reduction including the degenerate single-tile placement."""
import math

import numpy as np
import pytest

from repro.core.arch import (ChipConfig, Interconnect, TileTemplate,
                             homogeneous_baseline)
from repro.core.calibrate.asap7 import DEFAULT_CALIB
from repro.core.ir import OpNode, OpType, Precision, WorkloadGraph
from repro.core.simulator.costs import (ACT_CACHE_SLOTS, CACHE_FRAC,
                                        ActivationCache)
from repro.core.simulator.orchestrator import (ChipSim, ExecutionPlan,
                                               Placement, noc_hops, simulate)

CAL = DEFAULT_CALIB


def _mm(name, out_bytes, preds=(), m=32, k=32, n=32):
    """Small INT8 matmul with an explicit output footprint."""
    return OpNode(name, OpType.MATMUL, m=m, k=k, n=n,
                  precision=Precision.INT8, bytes_out=int(out_bytes))


def _graph(*nodes_with_preds):
    g = WorkloadGraph("edges", model_precision=Precision.INT8)
    for node, preds in nodes_with_preds:
        g.add(node, preds)
    return g


def _plan(g, placements):
    return ExecutionPlan(graph=g, placements=placements)


# ---------------------------------------------------------------- noc_hops

def test_noc_hops_per_topology():
    for n in (1, 4, 9, 24):
        assert noc_hops(Interconnect.BUS, n) == 1
        assert noc_hops(Interconnect.NOC, n) == 2
        assert noc_hops(Interconnect.RING, n) == max(n // 4, 1)
        assert noc_hops(Interconnect.MESH, n) == max(math.ceil(math.sqrt(n)),
                                                     1)


# --------------------------------------------------- FIFO cache semantics

def test_activation_cache_byte_eviction_fifo_order():
    cached = {}
    c = ActivationCache(0, cap_bytes=100.0)
    c.insert(0, 60.0, cached)
    c.insert(1, 30.0, cached)
    assert cached == {0: 0, 1: 0}
    c.insert(2, 40.0, cached)         # 60+30+40 > 100: evict op 0 (oldest)
    assert cached == {1: 0, 2: 0}
    assert c.used == 70.0


def test_activation_cache_slot_bound_evicts_oldest():
    cached = {}
    c = ActivationCache(3, cap_bytes=1e9, slots=2)
    c.insert(0, 1.0, cached)
    c.insert(1, 1.0, cached)
    c.insert(2, 1.0, cached)          # slot bound: op 0 leaves first
    assert cached == {1: 3, 2: 3}
    assert len(c.entries) == 2


def test_activation_cache_oversized_output_never_inserted():
    cached = {0: 0}
    c = ActivationCache(0, cap_bytes=100.0)
    c.insert(0, 50.0, cached)
    c.insert(1, 200.0, cached)        # larger than the partition: spill
    assert 1 not in cached and cached[0] == 0
    assert c.used == 50.0


def test_jax_fifo_insert_matches_python_reference():
    """Randomized traffic through both FIFO implementations must leave
    identical cached_at maps — the array mirror is the parity-critical
    piece of the batched backends."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.core.simulator.batched import fifo_insert

    rng = np.random.default_rng(0)
    n_ops, cap = 64, 1000.0
    ref_cache = ActivationCache(0, cap)
    ref_map = {}
    fifo_ops = jnp.full((1, ACT_CACHE_SLOTS), -1, jnp.int32)
    fifo_bytes = jnp.zeros((1, ACT_CACHE_SLOTS), jnp.float64)
    cached_at = jnp.full(n_ops, -1, jnp.int32)
    for i in range(n_ops):
        nb = float(rng.choice([0.0, 90.0, 240.0, 510.0, 1200.0]))
        ref_cache.insert(i, nb, ref_map)
        fifo_ops, fifo_bytes, cached_at = fifo_insert(
            fifo_ops, fifo_bytes, cached_at, jnp.asarray(0, jnp.int32),
            jnp.asarray(i, jnp.int32), jnp.asarray(nb, jnp.float64),
            jnp.asarray(cap, jnp.float64), jnp.asarray(True))
        got = {j: int(t) for j, t in enumerate(np.asarray(cached_at))
               if t >= 0}
        assert got == ref_map, f"step {i}: {got} != {ref_map}"


# ------------------------------------------- FIFO eviction inside ChipSim

def _single_tile_chip(sram_kb=64):
    t = TileTemplate(name="one", rows=32, cols=32, sram_kb=sram_kb,
                     precisions=frozenset({Precision.INT8, Precision.FP16}))
    return ChipConfig(name="single", tiles=((t, 1),))


def test_chipsim_fifo_eviction_turns_hit_into_miss():
    chip = _single_tile_chip(sram_kb=64)          # cache cap = 16 KiB
    cap = 64 * 1024 * CACHE_FRAC
    big, small = int(cap * 0.6), 1000

    def run(mid_bytes):
        g = _graph((_mm("p0", big), ()),
                   (_mm("p1", mid_bytes), ()),
                   (_mm("c", small), ()))
        g.nodes[2].preds = [0]                    # c consumes p0
        plan = _plan(g, {i: Placement([0]) for i in range(3)})
        return simulate(chip, plan)

    evicted = run(int(cap * 0.6))                 # p1 pushes p0 out
    kept = run(1000)                              # p1 small: p0 survives
    assert kept.ops[2].cache == "hit"
    assert evicted.ops[2].cache == "miss"
    # the miss re-reads p0's activations from DRAM
    assert evicted.energy_breakdown.dram > kept.energy_breakdown.dram


# ---------------------------------- cross-tile NoC DMA vs DRAM-miss paths

def test_cross_tile_noc_dma_vs_dram_miss_accounting():
    chip = homogeneous_baseline(2, sram_kb=64)    # cap = 16 KiB per tile
    cap = 64 * 1024 * CACHE_FRAC

    def run(out_bytes):
        g = _graph((_mm("p", out_bytes), ()), (_mm("c", 1000), ()))
        g.nodes[1].preds = [0]
        plan = _plan(g, {0: Placement([0]), 1: Placement([1])})
        return simulate(chip, plan)

    dma = run(int(cap * 0.5))                     # fits: cross-tile DMA
    spill = run(int(cap * 2))                     # spills: DRAM round-trip
    assert dma.ops[1].cache == "noc"
    assert spill.ops[1].cache == "miss"
    # DMA charges NoC energy for exactly the consumed activation bytes
    sim = ChipSim(chip)
    consumed = _mm("c", 1000).finalize().bytes_in
    assert dma.energy_breakdown.noc == pytest.approx(
        sim.noc_energy_pj(consumed), rel=1e-12)
    assert spill.energy_breakdown.noc == 0.0
    # the spill pays the producer write-back plus the consumer re-read
    assert spill.energy_breakdown.dram > dma.energy_breakdown.dram


# ------------------------------------------------ Eq. 3 split-op paths

def test_degenerate_single_tile_placement_matches_plain():
    chip = _single_tile_chip()
    g = _graph((_mm("mm", 4096, m=64, k=64, n=64), ()))
    plain = simulate(chip, _plan(g, {0: Placement([0])}))
    degen = simulate(chip, _plan(g, {0: Placement([0], "OC")}))
    assert degen.latency_s == plain.latency_s
    assert degen.energy_pj == plain.energy_pj
    assert degen.ops[0].split_tiles == 1


def test_split_reduction_cost_eq3():
    chip = homogeneous_baseline(2)
    g = _graph((_mm("mm", 1 << 16, m=256, k=256, n=256), ()))
    r = simulate(chip, _plan(g, {0: Placement([0, 1], "OC")}))
    slices = [o for o in r.ops if o.op_index == 0]
    assert len(slices) == 2 and all(o.split_tiles == 2 for o in slices)
    sim = ChipSim(chip)
    reduce_s = sim.noc_seconds(g.nodes[0].bytes_out / 2)
    assert r.latency_s == pytest.approx(
        max(o.finish_s for o in slices) + reduce_s, rel=1e-12)
    # k-1 slice transfers hit the NoC (Eq. 3 reduce)
    assert r.energy_breakdown.noc == pytest.approx(
        sim.noc_energy_pj(g.nodes[0].bytes_out / 2), rel=1e-12)
