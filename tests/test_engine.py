"""Cache-aware DSE evaluation engine: bitwise parity of the vectorized
genome->SoA stacking against the reference decode() path, memoization
identity, canonicalization soundness, prefilter semantics, and GA
fixed-seed equivalence with the pre-refactor evaluation path."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.dse.api import EngineConfig
from repro.core.dse.batch_eval import (batch_evaluate, prepare_configs,
                                       prepare_workload)
from repro.core.dse.encoding import (FIELDS_PER_TILE, GENOME_LEN,
                                     _TILE_FIELDS, decode, random_genomes)
from repro.core.dse.engine import (EvalEngine, canonical_genomes,
                                   genome_areas, genomes_to_configs)
from repro.core.dse.sweep import evaluate_genomes_reference
from repro.core.workloads import build

WLS = ["kan", "resnet50_int8"]


def _mixed_genomes(n_per=32, seed=7):
    rng = np.random.default_rng(seed)
    return np.concatenate([random_genomes(rng, n_per, family=f)
                           for f in (None, "homo", "hetero_bl",
                                     "hetero_bls")])


def test_vectorized_stacking_bitwise_parity():
    g = _mixed_genomes()
    ref = prepare_configs([decode(x, f"g{i}") for i, x in enumerate(g)])
    vec = genomes_to_configs(g)
    for grp in ("tile", "chip"):
        assert set(ref[grp]) == set(vec[grp])
        for k in ref[grp]:
            assert np.array_equal(ref[grp][k], vec[grp][k]), (grp, k)


def test_genome_areas_match_reference():
    from repro.core.simulator.area import chip_area
    g = _mixed_genomes(8)
    areas = genome_areas(g)
    for i, x in enumerate(g):
        assert areas[i] == chip_area(decode(x))


def test_memoized_results_identical_to_fresh():
    rng = np.random.default_rng(1)
    g = random_genomes(rng, 20)
    eng = EvalEngine(WLS)
    fresh = evaluate_genomes_reference(g, WLS)
    first = eng.evaluate(g)
    for k in fresh:
        assert np.array_equal(fresh[k], first[k]), k
    assert first["meta"]["backend"] == "scan"
    assert first["meta"]["misses"] == len(g)
    # second pass: all hits, bitwise identical
    misses_before = eng.stats.misses
    again = eng.evaluate(g)
    assert eng.stats.misses == misses_before
    assert again["meta"]["hits"] == len(g)
    assert again["meta"]["hit_rate"] == 1.0
    metric_keys = [k for k in first if k != "meta"]
    for k in metric_keys:
        assert np.array_equal(first[k], again[k]), k
    # shuffled subset rides the memo and still matches
    idx = rng.permutation(len(g))[:9]
    sub = eng.evaluate(g[idx])
    for k in metric_keys:
        assert np.array_equal(first[k][idx], sub[k]), k
    assert eng.stats.misses == misses_before
    assert eng.stats.hit_rate() > 0


def test_duplicates_within_one_call_simulated_once():
    rng = np.random.default_rng(2)
    g = random_genomes(rng, 6)
    batch = np.concatenate([g, g[::-1]])
    eng = EvalEngine(["kan"])
    out = eng.evaluate(batch)
    assert eng.stats.misses == 6
    assert eng.stats.hits == 6
    for k in ("latency", "energy", "tops_w", "area"):
        assert np.array_equal(out[k][:6], out[k][6:][::-1]), k


def test_canonical_genomes_zero_inactive_blocks():
    rng = np.random.default_rng(3)
    g = random_genomes(rng, 64)
    c = canonical_genomes(g)
    for i, genome in enumerate(g):
        n_types = int(genome[0]) + 1
        for t in range(n_types, 3):
            sl = slice(1 + t * FIELDS_PER_TILE, 1 + (t + 1) * FIELDS_PER_TILE)
            assert (c[i, sl] == 0).all()
    # canonicalization never changes area or metrics
    assert np.array_equal(genome_areas(g), genome_areas(c))
    ws = prepare_workload(build("kan"))
    r1 = batch_evaluate(ws, prepare_configs([decode(x) for x in g]))
    r2 = batch_evaluate(ws, prepare_configs([decode(x) for x in c]))
    for k in ("latency_s", "energy_pj", "achieved_tops"):
        assert np.array_equal(r1[k], r2[k]), k


def test_special_tile_inert_genes():
    """Genes decode() ignores on Special-Function tiles (rows/cols and the
    MAC-path knobs) produce bitwise-identical metrics and area."""
    rng = np.random.default_rng(5)
    g = random_genomes(rng, 12, family="hetero_bls")
    g2 = g.copy()
    base = 1 + 2 * FIELDS_PER_TILE
    for f in ("rows", "cols", "engine", "prec", "sparsity", "dataflow",
              "asym", "pipe"):
        g2[:, base + _TILE_FIELDS.index(f)] = rng.integers(0, 3, len(g))
    assert np.array_equal(canonical_genomes(g), canonical_genomes(g2))
    ws = prepare_workload(build("kan"))
    r1 = batch_evaluate(ws, prepare_configs([decode(x) for x in g]))
    r2 = batch_evaluate(ws, prepare_configs([decode(x) for x in g2]))
    for k in ("latency_s", "energy_pj", "achieved_tops"):
        assert np.array_equal(r1[k], r2[k]), k


def test_asym_equivalence_classes():
    """asym_mac only acts through supports_precision; the canonical map
    collapses variants that cannot change any op's support."""
    rng = np.random.default_rng(6)
    g = random_genomes(rng, 24)
    g2 = g.copy()
    col = _TILE_FIELDS.index("asym")
    for t in range(3):
        g2[:, 1 + t * FIELDS_PER_TILE + col] = rng.integers(0, 4, len(g))
    same = np.all(canonical_genomes(g) == canonical_genomes(g2), axis=1)
    assert same.any()
    idx = np.nonzero(same)[0]
    chips1 = [decode(g[i]) for i in idx]
    chips2 = [decode(g2[i]) for i in idx]
    ws = prepare_workload(build("resnet50_int8"), aggressive_int4=True)
    r1 = batch_evaluate(ws, prepare_configs(chips1))
    r2 = batch_evaluate(ws, prepare_configs(chips2))
    for k in ("latency_s", "energy_pj", "achieved_tops"):
        assert np.array_equal(r1[k], r2[k]), k


def test_keep_prefilter_skips_without_poisoning_the_memo():
    rng = np.random.default_rng(4)
    g = random_genomes(rng, 12)
    eng = EvalEngine(["kan"])
    areas = eng.areas(g)
    cut = float(np.median(areas))
    out = eng.evaluate(g, keep=lambda a: a <= cut)
    skipped = areas > cut
    assert np.isinf(out["latency"][skipped]).all()
    assert np.isinf(out["energy"][skipped]).all()
    assert eng.stats.skips == int(skipped.sum())
    # areas are exact even for skipped genomes
    assert np.array_equal(out["area"], areas)
    assert out["meta"]["skips"] == int(skipped.sum())
    # an unfiltered follow-up simulates the skipped genomes for real
    full = eng.evaluate(g)
    fresh = EvalEngine(["kan"]).evaluate(g)
    for k in full:
        if k == "meta":
            continue
        assert np.array_equal(full[k], fresh[k]), k


def test_pad_size_rounds_to_mesh_multiple_after_bucket():
    """Sharded batch padding: the jit bucket is rounded up to a mesh-size
    multiple AFTER bucket rounding (an indivisible batch axis would fall
    back to whole-batch per-device replication), and shape reuse can
    never hand back a non-multiple."""
    class _Mesh:
        size = 8

    class _Sharding:
        mesh = _Mesh()

    eng = EvalEngine(["kan"])
    eng._sharding = _Sharding()
    for n in (1, 17, 18, 33, 63, 64, 65):
        p = eng._pad_size(n)
        assert p >= n and p % 8 == 0, (n, p)
    # a stale non-multiple shape in the reuse window is filtered out
    eng._shapes.add(42)
    p = eng._pad_size(28)   # bucket 28 -> mesh-rounded 32; window [32, 48]
    assert p % 8 == 0 and p != 42
    # unsharded engines keep plain bucket padding
    plain = EvalEngine(["kan"])
    assert plain._pad_size(17) == 20


def test_memo_lru_eviction_bounded_and_correct():
    """The canonical-genome memo is a bounded LRU: size never exceeds
    ``memo_max``, the oldest (least recently touched) entries are evicted
    first, and evicted genomes re-simulate to identical rows."""
    rng = np.random.default_rng(8)
    g = random_genomes(rng, 12)
    eng = EvalEngine(["kan"], config=EngineConfig(memo_max=8, batch=4))
    assert eng.memo_max == 8
    first = eng.evaluate(g)
    assert len(eng._memo) <= 8
    # the first rows were evicted -> re-scoring them is a miss, not a hit
    misses_before = eng.stats.misses
    again = eng.evaluate(g[:4])
    assert eng.stats.misses > misses_before
    for k in ("latency", "energy", "tops_w", "area"):
        assert np.array_equal(first[k][:4], again[k]), k
    # hits refresh recency: a touched entry survives newer insertions
    eng2 = EvalEngine(["kan"], config=EngineConfig(memo_max=8, batch=4))
    eng2.evaluate(g[:8])
    keep_key = b"latency:" + eng2._key(canonical_genomes(g[:1])[0])
    eng2.evaluate(g[:1])              # touch -> most recent
    eng2.evaluate(g[8:12])            # insert 4 more, evicting the LRU end
    assert keep_key in eng2._memo
    assert len(eng2._memo) <= 8
    # memo_limit stays accepted as the pre-PR-5 alias (it now warns)
    with pytest.warns(DeprecationWarning):
        assert EvalEngine(["kan"], memo_limit=9, batch=4).memo_max == 9


def test_exact_backend_evaluate_matches_rescore():
    """backend='exact' (the fused class-specialized search kernel) scores
    evaluate() bitwise identically to the exact rescore path, reports
    itself in meta, and memoizes like any other backend."""
    g = random_genomes(np.random.default_rng(9), 10)
    eng = EvalEngine(WLS, config=EngineConfig(backend="exact"))
    out = eng.evaluate(g)
    assert out["meta"]["backend"] == "exact"
    ref = EvalEngine(WLS).rescore(g)
    for k in ("latency", "energy", "tops_w", "area"):
        assert np.array_equal(out[k], ref[k]), k
    again = eng.evaluate(g)
    assert again["meta"]["hit_rate"] == 1.0
    for k in ("latency", "energy", "tops_w", "area"):
        assert np.array_equal(out[k], again[k]), k
    # throughput mode rides the same scan
    tp = eng.evaluate(g, mode="throughput")
    tp_ref = EvalEngine(WLS).rescore(g, mode="throughput")
    for k in ("latency", "energy", "tops_w", "area"):
        assert np.array_equal(tp[k], tp_ref[k]), k
    # the fused search kernel rejects the python per-candidate mapper
    with pytest.raises(ValueError):
        EvalEngine(WLS, config=EngineConfig(backend="exact",
                                            exact_mapper="python"))


def test_evaluate_accepts_precomputed_canonical():
    g = random_genomes(np.random.default_rng(10), 6)
    eng = EvalEngine(["kan"])
    a = eng.evaluate(g, canonical=canonical_genomes(g))
    b = EvalEngine(["kan"]).evaluate(g)
    for k in ("latency", "energy", "tops_w", "area"):
        assert np.array_equal(a[k], b[k]), k
    # memo keys line up: the same genomes are now all hits
    assert eng.evaluate(g)["meta"]["hit_rate"] == 1.0


def test_rescore_batched_mapper_matches_python_mapper():
    """The compile-free exact path (default) scores bitwise identically
    to the per-candidate map_graph + lower_plan pipeline."""
    g = random_genomes(np.random.default_rng(5), 6)
    rb = EvalEngine(["kan"]).rescore(g)
    rp = EvalEngine(["kan"],
                    config=EngineConfig(exact_mapper="python")).rescore(g)
    for k in ("latency", "energy", "tops_w", "area"):
        assert np.array_equal(rb[k], rp[k]), k
    assert rb["meta"]["mapper"] == "batched"
    assert rp["meta"]["mapper"] == "python"
    assert rb["meta"]["backend"] == rp["meta"]["backend"] == "batched"


def test_run_ga_fixed_seed_same_best_fitness():
    """The cache-aware engine (memo + vectorized stacking + bracket
    prefilter) reproduces the pre-refactor GA result bit-for-bit."""
    from repro.core.dse.ga import GAConfig, run_ga
    from repro.core.dse.sweep import run_sweep

    sw = run_sweep(WLS, samples_per_stratum=4, seed=0,
                   brackets=(100.0, 200.0))
    cfg = GAConfig(population=10, generations=3, seed_top_k=6, early_stop=3)
    legacy = run_ga(sw, 200.0, cfg, seed=1,
                    engine=EvalEngine(WLS, config=EngineConfig(
                        memoize=False, vectorized=False)),
                    prefilter=False)
    cached = run_ga(sw, 200.0, cfg, seed=1, engine=EvalEngine(WLS),
                    prefilter=True)
    assert legacy is not None and cached is not None
    assert legacy.best_fitness == cached.best_fitness
    assert np.array_equal(legacy.best_genome, cached.best_genome)
    assert legacy.history == cached.history


@pytest.mark.slow
def test_sharded_evaluation_matches_single_device():
    """Candidate-axis sharding over forced host devices is a pure layout
    change: results match the unsharded engine bitwise."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.core.dse.encoding import random_genomes
from repro.core.dse.api import EngineConfig
from repro.core.dse.engine import EvalEngine
g = random_genomes(np.random.default_rng(0), 16)
plain = EvalEngine(["kan"]).evaluate(g)
shard = EvalEngine(["kan"], config=EngineConfig(shard=True))
assert shard._sharding is not None
out = shard.evaluate(g)
for k in plain:
    assert np.array_equal(plain[k], out[k]), k
# the compile-free exact path shards too; 13 is deliberately uneven so
# _pad_size's mesh rounding is what keeps the batch divisible
g13 = g[:13]
pr = EvalEngine(["kan"]).rescore(g13)
sr = shard.rescore(g13)
for k in ("latency", "energy", "tops_w", "area"):
    assert np.array_equal(pr[k], sr[k]), k
print("OK")
"""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=240, env=env)
    assert "OK" in out.stdout, out.stderr[-2000:]


def test_memo_max_applies_to_caller_supplied_store():
    """An explicit ``memo_max`` used to be silently ignored whenever the
    caller passed ``store=`` — the cap must re-cap the store's in-memory
    LRU tier, or raise when there is no LRU tier to cap."""
    from repro.core.dse.store import (MemoryLRUStore, SqliteStore,
                                      TieredStore)

    st = MemoryLRUStore(max_entries=1000)
    eng = EvalEngine(["kan"],
                     config=EngineConfig(memo_max=8, batch=4, store=st))
    assert st.max_entries == 8 and eng.memo_max == 8
    g = random_genomes(np.random.default_rng(3), 12)
    eng.evaluate(g)
    assert len(st) <= 8

    # the resize evicts eagerly when the store already holds more
    big = MemoryLRUStore(max_entries=1000)
    warm = EvalEngine(["kan"],                       # no cap: untouched
                      config=EngineConfig(batch=4, store=big))
    warm.evaluate(g)
    assert big.max_entries == 1000 and len(big) > 8
    EvalEngine(["kan"], config=EngineConfig(memo_max=8, batch=4,
                                            store=big))
    assert big.max_entries == 8 and len(big) <= 8

    # tiered: the cap lands on the LRU front
    tiered = TieredStore(MemoryLRUStore(max_entries=500),
                         SqliteStore(":memory:"))
    EvalEngine(["kan"], config=EngineConfig(memo_max=16, batch=4,
                                            store=tiered))
    assert tiered.front.max_entries == 16

    # no LRU tier to cap -> error, not a silent no-op
    with pytest.raises(ValueError, match="memo_max"):
        EvalEngine(["kan"], config=EngineConfig(
            memo_max=8, batch=4, store=SqliteStore(":memory:")))
    # the default cap is NOT "explicit": plain stores pass through
    assert EvalEngine(["kan"], config=EngineConfig(
        store=MemoryLRUStore(max_entries=777))).store.max_entries == 777


def test_export_import_memo_roundtrip():
    """The seed-boundary sync surface: ``export_memo`` returns exactly
    the store's rows for one mode (canonical genomes + float64 rows),
    and ``import_memo`` makes a cold engine serve them as pure hits,
    bitwise."""
    g = random_genomes(np.random.default_rng(4), 6)
    eng = EvalEngine(["kan"], config=EngineConfig(backend="exact"))
    m = eng.evaluate(g)
    canon, rows = eng.export_memo()
    assert canon.shape[1:] == (GENOME_LEN,) and rows.shape[1:] == (3, 1)
    assert len(canon) == len(np.unique(canonical_genomes(g), axis=0))
    # rows match the evaluation bitwise (set comparison via sorting)
    key = np.lexsort(canon.T)
    want = {canonical_genomes(g)[i].tobytes():
            np.stack([m["latency"][i], m["energy"][i],
                      m["tops_w"][i]]).tobytes() for i in range(len(g))}
    got = {canon[i].tobytes(): rows[i].tobytes() for i in range(len(canon))}
    assert got == want
    # back-to-back exports over an unchanged store return the cached view
    c2, r2 = eng.export_memo()
    assert c2 is canon and r2 is rows

    cold = EvalEngine(["kan"], config=EngineConfig(backend="exact"))
    assert cold.import_memo(canon, rows) == len(canon)
    served = cold.evaluate(g)
    assert served["meta"]["hits"] == len(g)
    for k in ("latency", "energy", "tops_w"):
        assert np.array_equal(served[k], m[k]), k
    # shape and mode guards
    with pytest.raises(ValueError, match="mode"):
        eng.export_memo(mode="bogus")
    with pytest.raises(ValueError, match="shape"):
        eng.import_memo(canon, rows[:, :2])


# =============================================================================
# non-finite guard (PR 8)
# =============================================================================

def _poison_simulate(eng, cell=(0, 0)):
    """Make the engine's next simulation return one NaN latency cell."""
    inner = eng._simulate
    state = {"armed": True}

    def wrapped(cfgs, n, genomes=None, mode=None):
        lat, en, tw = inner(cfgs, n, genomes=genomes, mode=mode)
        if state["armed"]:
            state["armed"] = False
            lat = np.array(lat, np.float64, copy=True)
            lat[cell] = np.nan
        return lat, en, tw

    eng._simulate = wrapped
    return state


def test_nonfinite_default_raises_naming_the_genome():
    from repro.core.dse.engine import NonFiniteMetricsError
    g = random_genomes(np.random.default_rng(11), 5)
    eng = EvalEngine(["kan"], config=EngineConfig(backend="exact"))
    _poison_simulate(eng)
    with pytest.raises(NonFiniteMetricsError) as ei:
        eng.evaluate(g)
    err = ei.value
    assert err.retryable                     # transient by contract
    assert err.canon.shape == (GENOME_LEN,)  # the culprit, canonical
    assert str(err.canon.tolist()) in str(err)
    # the poisoned batch never reached the memo: a retry is bitwise clean
    clean = EvalEngine(["kan"], config=EngineConfig(backend="exact")).evaluate(g)
    retried = eng.evaluate(g)
    for k in ("latency", "energy", "tops_w"):
        assert clean[k].tobytes() == retried[k].tobytes(), k


def test_nonfinite_skip_scores_minus_inf_and_never_memoizes():
    g = random_genomes(np.random.default_rng(11), 5)
    eng = EvalEngine(["kan"], config=EngineConfig(backend="exact",
                                                  nonfinite="skip"))
    _poison_simulate(eng)
    res = eng.evaluate(g)
    assert res["meta"]["nonfinite"] == 1
    bad = np.isinf(res["latency"]).all(axis=1) & \
        np.isinf(res["energy"]).all(axis=1) & (res["tops_w"] == 0).all(axis=1)
    assert bad.sum() == 1                    # exactly the poisoned row
    # the skipped row was not memoized: re-evaluating recomputes it —
    # now un-poisoned — and the whole batch matches a clean engine
    again = eng.evaluate(g)
    assert again["meta"]["nonfinite"] == 0
    clean = EvalEngine(["kan"], config=EngineConfig(backend="exact")).evaluate(g)
    for k in ("latency", "energy", "tops_w"):
        assert clean[k].tobytes() == again[k].tobytes(), k


def test_nonfinite_ctor_validation():
    with pytest.raises(ValueError, match="nonfinite"):
        EvalEngine(["kan"], config=EngineConfig(nonfinite="bogus"))
    # legitimate unmappable rows (inf, inf, 0) are NOT corruption: the
    # skip path leaves genuinely-infinite sentinel rows alone
    eng = EvalEngine(["kan"], config=EngineConfig(backend="exact",
                                                  nonfinite="raise"))
    assert eng.nonfinite == "raise"
