"""Pluggable result stores (PR 6): LRU semantics, persistent sqlite
round-trips, concurrent writers, cost-model-version invalidation, tier
layering, and — the load-bearing property — bitwise identity of
store-served engine results vs freshly computed ones."""
import threading

import numpy as np
import pytest

from repro.core.dse.api import EngineConfig
from repro.core.dse.encoding import random_genomes
from repro.core.dse.engine import EvalEngine
from repro.core.dse.store import (COST_MODEL_VERSION, MemoryLRUStore,
                                  SqliteStore, TieredStore)

W = 3  # metric-row width (workload count) used by the synthetic rows


def _row(seed: int):
    rng = np.random.default_rng(seed)
    # adversarial float64 payloads: denormals, huge, negative zero, inf
    lat = rng.random(W) * np.array([5e-324, 1e308, -0.0])
    return (lat, rng.standard_normal(W), np.array([np.inf, 0.0, 1e-30]))


def _bitwise(a, b) -> bool:
    return all(x.tobytes() == y.tobytes() for x, y in zip(a, b))


def test_memory_lru_recency_and_eviction():
    st = MemoryLRUStore(max_entries=3)
    rows = {bytes([i]): _row(i) for i in range(4)}
    for k in list(rows)[:3]:
        st.put(k, rows[k])
    assert len(st) == 3
    assert st.get(b"\x00") is not None        # refresh: 0 is now newest
    st.put(b"\x03", rows[b"\x03"])            # evicts 1 (oldest), not 0
    assert st.peek(b"\x00") and not st.peek(b"\x01")
    assert st.stats.evictions == 1
    # peek has no stats side effects
    gets = st.stats.gets
    st.peek(b"\x00")
    assert st.stats.gets == gets
    # put-if-absent: re-putting an existing key changes nothing
    st.put(b"\x00", _row(99))
    assert _bitwise(st.get(b"\x00"), rows[b"\x00"])


def test_sqlite_round_trip_bitwise(tmp_path):
    path = str(tmp_path / "r.sqlite")
    st = SqliteStore(path).bind(b"ctx")
    rows = {f"k{i}".encode(): _row(i) for i in range(8)}
    for k, r in rows.items():
        st.put(k, r)
    st.close()
    # a second instance on the same file (fresh process in spirit)
    st2 = SqliteStore(path).bind(b"ctx")
    assert len(st2) == len(rows)
    for k, r in rows.items():
        assert st2.peek(k)
        assert _bitwise(st2.get(k), r)
    assert st2.stats.hit_rate() == 1.0
    st2.close()


def test_sqlite_context_partitions_the_file(tmp_path):
    path = str(tmp_path / "r.sqlite")
    a = SqliteStore(path).bind(b"engine-A")
    b = SqliteStore(path).bind(b"engine-B")
    a.put(b"k", _row(1))
    assert a.peek(b"k") and not b.peek(b"k")  # same short key, other context
    b.put(b"k", _row(2))
    assert _bitwise(a.get(b"k"), _row(1))
    assert _bitwise(b.get(b"k"), _row(2))
    with pytest.raises(ValueError):
        a.bind(b"engine-C")                   # one instance, one context
    a.close()
    b.close()


def test_sqlite_concurrent_writers(tmp_path):
    path = str(tmp_path / "r.sqlite")
    rows = {f"k{i}".encode(): _row(i) for i in range(32)}
    # 4 instances (separate connections, as separate processes would hold)
    # x 2 threads each, all racing over the same keys
    stores = [SqliteStore(path).bind(b"ctx") for _ in range(4)]
    errs = []

    def hammer(st):
        try:
            for k, r in rows.items():
                st.put(k, r)
                got = st.get(k)
                assert got is not None and _bitwise(got, rows[k])
        except Exception as e:  # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(s,))
               for s in stores for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(stores[0]) == len(rows)        # first-write-wins, no dupes
    for k, r in rows.items():
        assert _bitwise(stores[0].get(k), r)
    for s in stores:
        s.close()


def test_cost_model_version_invalidates(tmp_path):
    path = str(tmp_path / "r.sqlite")
    old = SqliteStore(path, version="0.test").bind(b"ctx")
    old.put(b"k", _row(1))
    assert old.peek(b"k")
    # a version bump re-addresses every key: stale rows can't be served
    new = SqliteStore(path, version="1.test").bind(b"ctx")
    assert not new.peek(b"k") and new.get(b"k") is None
    new.put(b"k", _row(2))
    assert _bitwise(new.get(b"k"), _row(2))
    assert new.version_counts() == {"0.test": 1, "1.test": 1}
    assert new.purge_stale() == 1             # reclaims the dead rows
    assert new.version_counts() == {"1.test": 1}
    assert not old.peek(b"k")
    old.close()
    new.close()
    assert SqliteStore(path).version == COST_MODEL_VERSION  # engine default


def test_tiered_layering_and_promotion(tmp_path):
    front = MemoryLRUStore(max_entries=2)
    back = SqliteStore(str(tmp_path / "r.sqlite"))
    st = TieredStore(front, back).bind(b"ctx")
    rows = {bytes([i]): _row(i) for i in range(5)}
    for k, r in rows.items():
        st.put(k, r)                          # write-through
    assert len(front) == 2 and len(back) == 5 and len(st) == 5
    # an entry the LRU evicted is still served — from the back tier —
    # and promoted into the front on the way out
    assert not front.peek(b"\x00") and st.peek(b"\x00")
    hits_back = back.stats.hits
    assert _bitwise(st.get(b"\x00"), rows[b"\x00"])
    assert back.stats.hits == hits_back + 1
    assert front.peek(b"\x00")                # promoted
    assert _bitwise(st.get(b"\x00"), rows[b"\x00"])
    assert back.stats.hits == hits_back + 1   # second get: front only
    assert st.stats.hit_rate() == 1.0
    # the engine's legacy memo view is the front tier's dict
    assert st.lru_dict() is front.data
    st.close()


def test_sqlite_close_is_idempotent_and_checkpoints_wal(tmp_path):
    import os
    path = str(tmp_path / "r.sqlite")
    st = SqliteStore(path).bind(b"ctx")
    for i in range(8):
        st.put(f"k{i}".encode(), _row(i))
    assert os.path.exists(path + "-wal")      # WAL mode is active
    st.close()
    st.close()                                # second close: no-op
    # close() checkpointed + truncated the WAL: nothing left to replay,
    # so a plain file copy of the .sqlite is a complete snapshot
    assert os.path.getsize(path + "-wal") == 0 \
        if os.path.exists(path + "-wal") else True
    st2 = SqliteStore(path).bind(b"ctx")
    assert len(st2) == 8
    assert _bitwise(st2.get(b"k3"), _row(3))
    st2.close()


def test_sqlite_retries_locked_database(tmp_path):
    from repro.core.dse.faults import FaultInjector
    inj = FaultInjector(seed=0, at={"sqlite_lock": (0,)})
    st = SqliteStore(str(tmp_path / "r.sqlite"),
                     fault_injector=inj).bind(b"ctx")
    st.put(b"k", _row(1))                     # first attempt "locked"
    assert _bitwise(st.get(b"k"), _row(1))
    assert inj.fired()["sqlite_lock"] == 1
    st.close()


class _DeadBack:
    """A back tier whose every data op fails — a full-disk / corrupted
    sqlite stand-in for the degradation test."""

    def __init__(self):
        from repro.core.dse.store import StoreStats
        self.stats = StoreStats()

    def bind(self, context):
        return self

    def get(self, key):
        raise OSError("disk on fire")

    def put(self, key, row):
        raise OSError("disk on fire")

    def peek(self, key):
        raise OSError("disk on fire")

    def __len__(self):
        raise OSError("disk on fire")

    def close(self):
        raise OSError("disk on fire")


def test_tiered_survives_back_tier_failure_lru_only():
    st = TieredStore(MemoryLRUStore(), _DeadBack()).bind(b"ctx")
    rows = {bytes([i]): _row(i) for i in range(3)}
    with pytest.warns(RuntimeWarning, match="LRU-only"):
        for k, r in rows.items():
            st.put(k, r)                      # warned once, not thrice
    for k, r in rows.items():                 # served from the LRU front
        assert _bitwise(st.get(k), r)
    assert st.peek(b"\x00")
    assert len(st) == 3                       # front count still works
    assert st.stats.errors >= 3
    st.close()                                # dead back close absorbed


def test_tiered_back_tier_recovery_resumes_writes(tmp_path):
    """Satellite (PR 10): degradation is not a one-way door.  After the
    back tier recovers (the injected fault schedule runs out), writes
    resume to sqlite automatically and ``stats.errors`` stops growing —
    and a re-put of a degraded-era key re-promotes it to persistence."""
    from repro.core.dse.faults import FaultInjector, FaultyStore

    sql = SqliteStore(str(tmp_path / "r.sqlite"))
    inj = FaultInjector(seed=0, at={"store_put": (0, 1)})  # fail, recover
    st = TieredStore(MemoryLRUStore(), FaultyStore(sql, inj)).bind(b"ctx")
    rows = {bytes([i]): _row(i) for i in range(4)}
    with pytest.warns(RuntimeWarning, match="LRU-only"):
        st.put(b"\x00", rows[b"\x00"])        # injected back failure
        st.put(b"\x01", rows[b"\x01"])        # injected back failure
    errs = st.stats.errors
    assert errs == 2
    assert not sql.peek(b"\x00") and not sql.peek(b"\x01")

    # the schedule is exhausted: the back tier has "recovered", so
    # write-through resumes with no state to reset and no new errors
    st.put(b"\x02", rows[b"\x02"])
    st.put(b"\x03", rows[b"\x03"])
    assert st.stats.errors == errs            # stopped growing
    assert sql.peek(b"\x02") and sql.peek(b"\x03")

    # degraded-era rows still serve from the front, bitwise
    assert _bitwise(st.get(b"\x00"), rows[b"\x00"])
    # and a re-put re-promotes one into the recovered sqlite tier
    st.put(b"\x00", rows[b"\x00"])
    assert sql.peek(b"\x00")
    assert st.stats.errors == errs
    st.close()


def test_engine_store_served_results_bitwise(tmp_path):
    path = str(tmp_path / "r.sqlite")
    rng = np.random.default_rng(3)
    g = random_genomes(rng, 12)
    wls = ["kan"]
    fresh = EvalEngine(wls).evaluate(g)

    cold = EvalEngine(wls, config=EngineConfig(
        store=TieredStore(MemoryLRUStore(), SqliteStore(path))))
    first = cold.evaluate(g)
    assert first["meta"]["dispatches"] >= 1

    # a brand-new engine over the same file starts warm: zero dispatches,
    # bitwise-identical metrics
    warm = EvalEngine(wls, config=EngineConfig(
        store=TieredStore(MemoryLRUStore(), SqliteStore(path))))
    served = warm.evaluate(g)
    assert served["meta"]["dispatches"] == 0
    assert served["meta"]["hit_rate"] == 1.0
    for k in ("latency", "energy", "tops_w", "area"):
        assert np.array_equal(fresh[k], served[k]), k
        assert fresh[k].tobytes() == served[k].tobytes(), k
    # a different engine context (other workload list) shares the file
    # but not the entries
    other = EvalEngine(["resnet50_int8"], config=EngineConfig(
        store=TieredStore(MemoryLRUStore(), SqliteStore(path))))
    res = other.evaluate(g[:4])
    assert res["meta"]["dispatches"] >= 1
