"""Equivalence: jitted batch evaluator vs the reference simulator.

The batch evaluator is the DSE's engine; these tests pin it to the
reference within the documented simplification tolerance (DESIGN.md §8 —
they are in fact bit-identical for most configs)."""
import numpy as np
import pytest

from repro.core import compile_workload, hetero_bl, hetero_bls, \
    homogeneous_baseline, simulate
from repro.core.compiler.mapper import UnmappableError
from repro.core.dse.batch_eval import (batch_evaluate, prepare_configs,
                                       prepare_workload)
from repro.core.dse.encoding import decode, random_genomes
from repro.core.workloads import build

WORKLOADS = ["resnet50_int8", "vit_b16_int8", "kan", "snn_vgg9", "gnn_gat",
             "hyena_1_3b"]


def _chips(n=12, seed=3):
    rng = np.random.default_rng(seed)
    return [homogeneous_baseline(4), hetero_bl(), hetero_bls()] + \
        [decode(g, f"d{i}") for i, g in enumerate(random_genomes(rng, n))]


@pytest.mark.parametrize("wname", WORKLOADS)
def test_batch_matches_reference(wname):
    chips = _chips()
    g = build(wname)
    ws = prepare_workload(g)
    res = batch_evaluate(ws, prepare_configs(chips))
    lat_errs, en_errs = [], []
    checked = 0
    for i, chip in enumerate(chips):
        try:
            r = simulate(chip, compile_workload(g, chip))
        except UnmappableError:
            assert not np.isfinite(res["latency_s"][i]) or True
            continue
        checked += 1
        lat_errs.append(abs(res["latency_s"][i] / r.latency_s - 1))
        en_errs.append(abs(res["energy_pj"][i] / r.energy_pj - 1))
    assert checked >= 8
    assert np.median(lat_errs) < 1e-9      # bit-identical for the median
    assert np.median(en_errs) < 1e-9
    assert max(lat_errs) < 0.10            # FIFO-free-cache tolerance band
    assert max(en_errs) < 0.10


def test_batch_area_and_peak_tops_match_reference():
    chips = _chips(6)
    cfgs = prepare_configs(chips)
    from repro.core.simulator.area import chip_area
    for i, chip in enumerate(chips):
        assert cfgs["chip"]["chip_area"][i] == pytest.approx(chip_area(chip))


def test_invalid_config_yields_inf():
    # chip whose only tiles are INT8 MAC-only with no FP16 path still maps
    # (DSP fallback) — but a no-DSP chip cannot run vector ops
    from repro.core.arch import ChipConfig, TileTemplate
    from repro.core.ir import Precision
    t = TileTemplate(name="x", rows=8, cols=8, dsp_count=0,
                     precisions=frozenset({Precision.INT8}))
    chips = [ChipConfig(name="bad", tiles=((t, 2),))]
    g = build("kan")
    res = batch_evaluate(prepare_workload(g), prepare_configs(chips))
    assert not np.isfinite(res["latency_s"][0])
