"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
1 CPU device; only launch/dryrun.py forces the 512-device host platform.
"""
import json
import os
import pathlib

import numpy as np
import pytest

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"

# Persistent XLA compilation cache (mirrors benchmarks/common.py): when CI
# sets JAX_COMPILATION_CACHE_DIR (persisted via actions/cache keyed on the
# jax pin), the jitted simulator/mapper compiles are restored across runs
# instead of re-paying ~5-10 s per (calib, op-bucket) pair.
if os.environ.get("JAX_COMPILATION_CACHE_DIR"):
    try:
        import jax

        jax.config.update("jax_compilation_cache_dir",
                          os.environ["JAX_COMPILATION_CACHE_DIR"])
        for _knob, _val in (
                ("jax_persistent_cache_min_compile_time_secs", 0.0),
                ("jax_persistent_cache_min_entry_size_bytes", 0)):
            try:
                jax.config.update(_knob, _val)
            except Exception:  # pragma: no cover - knob-less jax version
                pass
    except Exception:  # pragma: no cover - jax unavailable
        pass

# Hypothesis example budgets: the default profile keeps tier-1 fast; the
# CI "thorough" profile (non-blocking -m slow job) widens the search.
try:
    from hypothesis import settings

    settings.register_profile("default", deadline=None)
    settings.register_profile("thorough", max_examples=300, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:  # pragma: no cover - hypothesis is optional locally
    pass


def pytest_addoption(parser):
    parser.addoption(
        "--regen-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json from the current cost model "
             "instead of comparing against them")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _diff_nested(ref, new, rtol, atol, path, out):
    """Collect human-readable numeric diffs between two golden payloads."""
    if isinstance(ref, dict) or isinstance(new, dict):
        rk = set(ref) if isinstance(ref, dict) else set()
        nk = set(new) if isinstance(new, dict) else set()
        for k in sorted(rk | nk):
            if k not in rk:
                out.append(f"{path}.{k}: added")
            elif k not in nk:
                out.append(f"{path}.{k}: removed")
            else:
                _diff_nested(ref[k], new[k], rtol, atol, f"{path}.{k}", out)
    elif isinstance(ref, list) or isinstance(new, list):
        if not isinstance(ref, list) or not isinstance(new, list):
            out.append(f"{path}: {type(ref).__name__} -> {type(new).__name__}"
                       f" ({ref!r} -> {new!r})")
            return
        if len(ref) != len(new):
            out.append(f"{path}: length {len(ref)} -> {len(new)}")
            return
        for i, (r, n) in enumerate(zip(ref, new)):
            _diff_nested(r, n, rtol, atol, f"{path}[{i}]", out)
    elif isinstance(ref, bool) or isinstance(new, bool) \
            or isinstance(ref, str) or isinstance(new, str):
        if ref != new:
            out.append(f"{path}: {ref!r} -> {new!r}")
    elif isinstance(ref, (int, float)) and isinstance(new, (int, float)):
        if not np.isclose(ref, new, rtol=rtol, atol=atol, equal_nan=True):
            rel = abs(new - ref) / max(abs(ref), 1e-300)
            out.append(f"{path}: {ref!r} -> {new!r} (rel {rel:.3e})")
    elif ref != new:
        out.append(f"{path}: {ref!r} -> {new!r}")


@pytest.fixture
def golden(request):
    """Tolerance-aware golden-trace comparator.

    ``golden(name, payload)`` compares ``payload`` against
    ``tests/golden/<name>.json``; with ``--regen-golden`` it rewrites the
    file instead.  Failures list every diverging leaf with its relative
    error, so an intentional cost-model edit shows its numeric footprint.
    """
    def compare(name, payload, rtol=1e-6, atol=1e-12):
        path = GOLDEN_DIR / f"{name}.json"
        if request.config.getoption("--regen-golden"):
            GOLDEN_DIR.mkdir(exist_ok=True)
            path.write_text(json.dumps(payload, indent=1, sort_keys=True)
                            + "\n")
            return
        assert path.exists(), (
            f"missing golden trace {path}; run `pytest --regen-golden` "
            f"to freeze the current cost model")
        ref = json.loads(path.read_text())
        diffs = []
        _diff_nested(ref, payload, rtol, atol, name, diffs)
        assert not diffs, (
            "golden trace mismatch (regen with --regen-golden if the "
            "cost-model change is intentional):\n  " + "\n  ".join(diffs))

    return compare
