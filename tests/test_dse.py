"""DSE engine: encoding, stratified sampling, sweep, GA, Bayes, Pareto."""
import numpy as np
import pytest

from repro.core.dse.encoding import (FAMILIES, GENOME_LEN, decode,
                                     genome_bounds, random_genomes,
                                     sample_in_bracket)
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.objective import AREA_BRACKETS, area_bracket, fitness
from repro.core.dse.pareto import pareto_front, pareto_mask
from repro.core.dse.sweep import run_sweep
from repro.core.ir import Precision
from repro.core.simulator.area import chip_area

WLS = ["resnet50_int8", "kan", "spec_decode"]


def test_genome_decode_valid_chips(rng):
    for g in random_genomes(rng, 64):
        chip = decode(g)
        assert 1 <= len(chip.tiles) <= 3
        assert chip.num_tiles >= 1


def test_family_constraints(rng):
    homo = decode(random_genomes(rng, 1, family="homo")[0])
    assert len(homo.tiles) == 1
    t = homo.tiles[0][0]
    assert t.precisions == frozenset({Precision.INT8, Precision.FP16})
    assert t.sfu_mask == 0
    bls = decode(random_genomes(rng, 1, family="hetero_bls")[0])
    assert len(bls.tiles) == 3
    assert bls.tiles[2][0].sfu_mask > 0
    assert bls.tiles[2][0].is_special


def test_bracket_sampling(rng):
    def area_fn(g):
        return chip_area(decode(g))

    for b in (100.0, 200.0):
        gs = sample_in_bracket(rng, 8, "hetero_bl", b, area_fn)
        areas = [area_fn(g) for g in gs]
        assert all(a <= b for a in areas)
        assert np.mean([b / 2 < a <= b for a in areas]) >= 0.5


def test_area_bracket_assignment():
    assert area_bracket(30) == 50.0
    assert area_bracket(199) == 200.0
    assert area_bracket(1000) == 800.0


def test_pareto_properties(rng):
    pts = rng.random((64, 3))
    mask = pareto_mask(pts)
    assert mask.any()
    front = pts[mask]
    # no front point dominates another
    for i in range(len(front)):
        for j in range(len(front)):
            if i != j:
                assert not (np.all(front[i] <= front[j])
                            and np.any(front[i] < front[j]))
    # every dominated point is dominated by some front point
    dominated = pts[~mask]
    for d in dominated:
        assert np.any(np.all(front <= d, axis=1) & np.any(front < d, axis=1))


@pytest.mark.slow
def test_sweep_and_ga_smoke():
    sw = run_sweep(WLS, samples_per_stratum=8, seed=0,
                   brackets=(100.0, 200.0))
    assert sw.genomes.shape[0] == 8 * 2 * 3
    fit = sw.fitness()
    assert np.isfinite(fit).sum() > len(fit) * 0.5
    base = sw.homo_baseline()
    assert 200.0 in base
    ga = run_ga(sw, 200.0, GAConfig(population=12, generations=2,
                                    seed_top_k=8, early_stop=2))
    assert ga is not None
    assert np.isfinite(ga.best_fitness)
    assert ga.evaluated >= 24


def test_pareto_duplicate_rows_keep_first():
    """Bitwise-identical rows are mutually non-dominating, so without a
    dedupe every copy survived — cumulative fronts (streamed service
    updates, the pipeline's cross-seed merge) grew with each repeated
    candidate.  Only the FIRST copy may survive."""
    pts = np.array([[1.0, 2.0], [1.0, 2.0], [3.0, 1.0],
                    [1.0, 2.0], [3.0, 1.0]])
    mask = pareto_mask(pts)
    assert mask.tolist() == [True, False, True, False, False]
    # idempotence: feeding a front back in keeps exactly that front
    assert pareto_mask(pts[mask]).all()
    # dominated duplicates stay dominated
    pts2 = np.array([[0.5, 0.5], [9.0, 9.0], [9.0, 9.0]])
    assert pareto_mask(pts2).tolist() == [True, False, False]
    # front ordering survives the dedupe
    assert pareto_front(pts).tolist() == [0, 2]


def test_pareto_mask_device_matches_host(rng):
    from repro.core.dse.pareto import pareto_mask_device

    pts = rng.random((48, 3))
    dup = np.concatenate([pts, pts[::3], pts[:5]])   # inject duplicates
    host = pareto_mask(dup)
    dev = np.asarray(pareto_mask_device(dup))
    assert np.array_equal(host, dev)
    assert np.array_equal(pareto_mask(np.zeros((0, 3))),
                          np.asarray(pareto_mask_device(np.zeros((0, 3)))))
