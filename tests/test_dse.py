"""DSE engine: encoding, stratified sampling, sweep, GA, Bayes, Pareto."""
import numpy as np
import pytest

from repro.core.dse.encoding import (FAMILIES, GENOME_LEN, decode,
                                     genome_bounds, random_genomes,
                                     sample_in_bracket)
from repro.core.dse.ga import GAConfig, run_ga
from repro.core.dse.objective import AREA_BRACKETS, area_bracket, fitness
from repro.core.dse.pareto import pareto_front, pareto_mask
from repro.core.dse.sweep import run_sweep
from repro.core.ir import Precision
from repro.core.simulator.area import chip_area

WLS = ["resnet50_int8", "kan", "spec_decode"]


def test_genome_decode_valid_chips(rng):
    for g in random_genomes(rng, 64):
        chip = decode(g)
        assert 1 <= len(chip.tiles) <= 3
        assert chip.num_tiles >= 1


def test_family_constraints(rng):
    homo = decode(random_genomes(rng, 1, family="homo")[0])
    assert len(homo.tiles) == 1
    t = homo.tiles[0][0]
    assert t.precisions == frozenset({Precision.INT8, Precision.FP16})
    assert t.sfu_mask == 0
    bls = decode(random_genomes(rng, 1, family="hetero_bls")[0])
    assert len(bls.tiles) == 3
    assert bls.tiles[2][0].sfu_mask > 0
    assert bls.tiles[2][0].is_special


def test_bracket_sampling(rng):
    def area_fn(g):
        return chip_area(decode(g))

    for b in (100.0, 200.0):
        gs = sample_in_bracket(rng, 8, "hetero_bl", b, area_fn)
        areas = [area_fn(g) for g in gs]
        assert all(a <= b for a in areas)
        assert np.mean([b / 2 < a <= b for a in areas]) >= 0.5


def test_area_bracket_assignment():
    assert area_bracket(30) == 50.0
    assert area_bracket(199) == 200.0
    assert area_bracket(1000) == 800.0


def test_pareto_properties(rng):
    pts = rng.random((64, 3))
    mask = pareto_mask(pts)
    assert mask.any()
    front = pts[mask]
    # no front point dominates another
    for i in range(len(front)):
        for j in range(len(front)):
            if i != j:
                assert not (np.all(front[i] <= front[j])
                            and np.any(front[i] < front[j]))
    # every dominated point is dominated by some front point
    dominated = pts[~mask]
    for d in dominated:
        assert np.any(np.all(front <= d, axis=1) & np.any(front < d, axis=1))


@pytest.mark.slow
def test_sweep_and_ga_smoke():
    sw = run_sweep(WLS, samples_per_stratum=8, seed=0,
                   brackets=(100.0, 200.0))
    assert sw.genomes.shape[0] == 8 * 2 * 3
    fit = sw.fitness()
    assert np.isfinite(fit).sum() > len(fit) * 0.5
    base = sw.homo_baseline()
    assert 200.0 in base
    ga = run_ga(sw, 200.0, GAConfig(population=12, generations=2,
                                    seed_top_k=8, early_stop=2))
    assert ga is not None
    assert np.isfinite(ga.best_fitness)
    assert ga.evaluated >= 24


def test_pareto_duplicate_rows_keep_first():
    """Bitwise-identical rows are mutually non-dominating, so without a
    dedupe every copy survived — cumulative fronts (streamed service
    updates, the pipeline's cross-seed merge) grew with each repeated
    candidate.  Only the FIRST copy may survive."""
    pts = np.array([[1.0, 2.0], [1.0, 2.0], [3.0, 1.0],
                    [1.0, 2.0], [3.0, 1.0]])
    mask = pareto_mask(pts)
    assert mask.tolist() == [True, False, True, False, False]
    # idempotence: feeding a front back in keeps exactly that front
    assert pareto_mask(pts[mask]).all()
    # dominated duplicates stay dominated
    pts2 = np.array([[0.5, 0.5], [9.0, 9.0], [9.0, 9.0]])
    assert pareto_mask(pts2).tolist() == [True, False, False]
    # front ordering survives the dedupe
    assert pareto_front(pts).tolist() == [0, 2]


def test_pareto_mask_device_matches_host(rng):
    from repro.core.dse.pareto import pareto_mask_device

    pts = rng.random((48, 3))
    dup = np.concatenate([pts, pts[::3], pts[:5]])   # inject duplicates
    host = pareto_mask(dup)
    dev = np.asarray(pareto_mask_device(dup))
    assert np.array_equal(host, dev)
    assert np.array_equal(pareto_mask(np.zeros((0, 3))),
                          np.asarray(pareto_mask_device(np.zeros((0, 3)))))


# =============================================================================
# topology genes (mesh/torus, grid aspect, NoC width, DRAM channels; PR 9)
# =============================================================================

def test_topology_gene_roundtrip():
    """Every value of each interconnect gene decodes to the matching
    ChipConfig field, and the host decode agrees with the vectorized
    ``genomes_to_configs`` chip arrays gene-for-gene."""
    from repro.core.arch import KNOB_GRID
    from repro.core.dse.encoding import (IDX_ASPECT, IDX_DRAM_CH,
                                         IDX_NOC_BPC, IDX_TOPO)
    from repro.core.dse.engine import genomes_to_configs
    from repro.core.simulator.costs import grid_dims

    rng = np.random.default_rng(3)
    g = random_genomes(rng, 48)
    g[:, IDX_TOPO] = np.arange(48) % 2
    g[:, IDX_ASPECT] = np.arange(48) % 3
    g[:, IDX_NOC_BPC] = np.arange(48) % 4
    g[:, IDX_DRAM_CH] = np.arange(48) % 4
    cfgs = genomes_to_configs(g)
    chip_f = cfgs["chip"]
    for i in range(48):
        chip = decode(g[i])
        assert chip.torus == bool(KNOB_GRID["noc_topology"][i % 2])
        assert chip.grid_aspect == KNOB_GRID["grid_aspect"][i % 3]
        assert chip.noc_bytes_per_cycle == KNOB_GRID["noc_bpc"][i % 4]
        assert chip.dram_channels == KNOB_GRID["dram_channels"][i % 4]
        assert float(chip_f["torus"][i]) == float(chip.torus)
        assert float(chip_f["noc_bpc"][i]) == chip.noc_bytes_per_cycle
        assert float(chip_f["dram_channels"][i]) == chip.dram_channels
        gw, gh = grid_dims(np, float(chip.num_tiles), chip.grid_aspect)
        assert float(chip_f["grid_w"][i]) == float(gw)
        assert float(chip_f["grid_h"][i]) == float(gh)
        # area includes the NoC-width/torus scale + DRAM PHY term
        assert float(chip_f["chip_area"][i]) == chip_area(chip)


def test_homo_family_pins_interconnect_genes():
    """The §4.3 homogeneous baseline stays on the stock interconnect: its
    stratum pins the topology genes to the mesh/64B/1-channel defaults,
    so the iso-area comparison never credits the baseline with a torus."""
    from repro.core.dse.encoding import INTERCONNECT_GENE_DEFAULTS
    area_fn = lambda g: chip_area(decode(g))
    rng = np.random.default_rng(4)
    g = sample_in_bracket(rng, 64, "homo", 200.0, area_fn)
    for idx, v in INTERCONNECT_GENE_DEFAULTS.items():
        assert np.all(g[:, idx] == v), idx
    # hetero strata do explore the genes
    gh = sample_in_bracket(rng, 256, "hetero_bls", 200.0, area_fn)
    from repro.core.dse.encoding import IDX_TOPO
    assert len(np.unique(gh[:, IDX_TOPO])) > 1


def test_canonicalization_preserves_interconnect_genes():
    """Interconnect genes are never don't-care on multi-type chips:
    canonicalization must not collapse two designs that differ only in
    topology (their metrics differ on the link tier)."""
    from repro.core.dse.encoding import IDX_TOPO
    from repro.core.dse.engine import canonical_genomes
    rng = np.random.default_rng(5)
    g = random_genomes(rng, 16)
    g2 = g.copy()
    g2[:, IDX_TOPO] = 1 - (g2[:, IDX_TOPO] % 2)
    c, c2 = canonical_genomes(g), canonical_genomes(g2)
    assert np.all(c[:, IDX_TOPO] != c2[:, IDX_TOPO])
    # and the genes survive canonicalization verbatim
    assert np.array_equal(c[:, IDX_TOPO], g[:, IDX_TOPO] % 2) or \
        np.array_equal(c[:, IDX_TOPO], g[:, IDX_TOPO])
