"""``benchmarks/perf_compare.py`` trajectory merge: newest entry per
benchmark with a deterministic tie-break — two files carrying the same
benchmark at equal (or missing) ``generated_unix`` timestamps must merge
identically under every directory listing order ``os.listdir`` could
return (the merge used to be listing-order independent only by accident
of the PR-number sort; the rank makes the total order explicit)."""
import importlib.util
import itertools
import json
import pathlib

import pytest

_PC_PATH = (pathlib.Path(__file__).parent.parent / "benchmarks"
            / "perf_compare.py")
_spec = importlib.util.spec_from_file_location("perf_compare", _PC_PATH)
perf_compare = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(perf_compare)


def _write(root, name, pr, benchmarks, ts=None):
    data = {"pr": pr, "smoke": False, "benchmarks": benchmarks}
    if ts is not None:
        data["generated_unix"] = ts
    (root / name).write_text(json.dumps(data))


def _merge_under_listing_orders(monkeypatch, tmp_path):
    """Run merged_trajectory once per permutation of the listing order,
    returning the set of distinct results (json-canonicalized)."""
    names = sorted(p.name for p in tmp_path.iterdir())
    monkeypatch.setattr(perf_compare, "REPO_ROOT", str(tmp_path))
    outs = []
    for perm in itertools.permutations(names):
        monkeypatch.setattr(perf_compare.os, "listdir", lambda _p, _o=perm: list(_o))
        outs.append(perf_compare.merged_trajectory(smoke=False))
    uniq = {json.dumps(o, sort_keys=True) for o in outs}
    return outs, uniq


def test_equal_timestamps_tiebreak_deterministic(monkeypatch, tmp_path):
    # same benchmark, SAME timestamp in two files: higher PR number wins,
    # identically under all 3! = 6 listing orders
    _write(tmp_path, "BENCH_PR1.json", 1,
           {"b": {"speedup": 1.0}, "only_old": {"speedup": 9.0}}, ts=100.0)
    _write(tmp_path, "BENCH_PR2.json", 2, {"b": {"speedup": 2.0}}, ts=100.0)
    _write(tmp_path, "BENCH_PR3.json", 3, {"b": {"speedup": 3.0}}, ts=100.0)
    outs, uniq = _merge_under_listing_orders(monkeypatch, tmp_path)
    assert len(uniq) == 1
    merged = outs[0]
    assert merged["benchmarks"]["b"]["speedup"] == 3.0
    # benchmarks only an older PR carries survive the merge
    assert merged["benchmarks"]["only_old"]["speedup"] == 9.0
    assert merged["files"] == [
        "BENCH_PR1.json", "BENCH_PR2.json", "BENCH_PR3.json"]


def test_missing_timestamps_fall_back_to_pr_order(monkeypatch, tmp_path):
    # committed pre-PR-7 files carry no generated_unix at all
    _write(tmp_path, "BENCH_PR5.json", 5, {"b": {"speedup": 5.0}})
    _write(tmp_path, "BENCH_PR6.json", 6, {"b": {"speedup": 6.0}})
    outs, uniq = _merge_under_listing_orders(monkeypatch, tmp_path)
    assert len(uniq) == 1
    assert outs[0]["benchmarks"]["b"]["speedup"] == 6.0


def test_newer_run_outranks_higher_pr_number(monkeypatch, tmp_path):
    # a RE-RUN of an old PR's benchmark (newer timestamp) beats a
    # higher-numbered PR's stale entry: "newest" means the run, not the file
    _write(tmp_path, "BENCH_PR1.json", 1, {"b": {"speedup": 1.5}}, ts=200.0)
    _write(tmp_path, "BENCH_PR2.json", 2, {"b": {"speedup": 2.0}}, ts=100.0)
    outs, uniq = _merge_under_listing_orders(monkeypatch, tmp_path)
    assert len(uniq) == 1
    assert outs[0]["benchmarks"]["b"]["speedup"] == 1.5
    # timestamped files outrank timestamp-less ones regardless of PR number
    _write(tmp_path, "BENCH_PR9.json", 9, {"b": {"speedup": 9.0}})
    outs, uniq = _merge_under_listing_orders(monkeypatch, tmp_path)
    assert len(uniq) == 1
    assert outs[0]["benchmarks"]["b"]["speedup"] == 1.5


def test_smoke_and_full_do_not_mix(monkeypatch, tmp_path):
    _write(tmp_path, "BENCH_PR7.json", 7, {"b": {"speedup": 3.0}}, ts=100.0)
    _write(tmp_path, "BENCH_PR7_smoke.json", 7, {"b": {"speedup": 0.5}},
           ts=999.0)
    monkeypatch.setattr(perf_compare, "REPO_ROOT", str(tmp_path))
    full = perf_compare.merged_trajectory(smoke=False)
    smoke = perf_compare.merged_trajectory(smoke=True)
    assert full["benchmarks"]["b"]["speedup"] == 3.0
    assert smoke["benchmarks"]["b"]["speedup"] == 0.5
