"""Compiler passes: precision policy, fusion patterns, mapping rules."""
import pytest

from repro.core import (compile_workload, hetero_bls, homogeneous_baseline,
                        simulate)
from repro.core.arch import ChipConfig, TileTemplate, special_tile, SFU_FFT
from repro.core.compiler.fusion import fuse
from repro.core.compiler.mapper import UnmappableError, map_graph
from repro.core.compiler.precision import assign_precision
from repro.core.ir import OpNode, OpType, Precision, WorkloadGraph
from repro.core.workloads import build


def _toy(prec=Precision.INT8):
    g = WorkloadGraph("toy", model_precision=prec)
    a = g.matmul("conv", 128, 64, 64)
    b = g.dsp("relu", OpType.RELU, elems=128 * 64, preds=[a])
    c = g.matmul("lm_head", 1, 64, 1000, preds=[b])
    g.dsp("softmax", OpType.SOFTMAX, elems=1000, preds=[c])
    return g


def test_precision_default_policy_int8_model():
    g = assign_precision(_toy(Precision.INT8))
    assert g.nodes[0].precision == Precision.INT8          # conv -> INT8
    assert g.nodes[2].precision == Precision.FP16          # lm_head: sensitive
    assert g.nodes[3].precision == Precision.FP16          # softmax >= FP16


def test_precision_fp16_model_not_quantized():
    g = assign_precision(_toy(Precision.FP16))
    assert g.nodes[0].precision == Precision.FP16


def test_precision_aggressive_int4():
    g = assign_precision(_toy(Precision.INT8), aggressive_int4=True)
    assert g.nodes[0].precision == Precision.INT4
    assert g.nodes[2].precision == Precision.FP16          # override wins


def test_fusion_folds_single_consumer_posts():
    g = _toy()
    fuse(g)
    assert g.nodes[1].fused_into == 0
    assert g.nodes[0].fused_count == 1
    # softmax is NOT a PPM fusion pattern (paper §3.2 lists BN/Add/Act)
    assert g.nodes[3].fused_into == -1


def test_fusion_respects_multiple_consumers():
    g = WorkloadGraph("t")
    a = g.matmul("mm", 8, 8, 8)
    r = g.dsp("relu", OpType.RELU, elems=64, preds=[a])
    g.dsp("c1", OpType.ADD, elems=64, preds=[r])
    g.dsp("c2", OpType.ADD, elems=64, preds=[r])
    fuse(g)
    assert g.nodes[1].fused_into == 0   # relu fuses into mm (1 consumer)
    assert g.nodes[2].fused_into == -1  # adds have branching dependency


def test_mapper_routes_fft_to_special_function_tile():
    g = WorkloadGraph("t", model_precision=Precision.FP16)
    g.add(OpNode("fft", OpType.FFT, elems=4096, fft_n=512,
                 precision=Precision.FP16))
    chip = hetero_bls()
    plan = compile_workload(g, chip)
    sfu_idx = [i for i, t in enumerate(chip.instances()) if t.sfu_mask]
    assert plan.placements[0].tiles[0] in sfu_idx


def test_mapper_raises_on_unmappable():
    # a chip with no DSP anywhere cannot run vector ops
    t = TileTemplate(name="macsonly", rows=8, cols=8, dsp_count=0,
                     precisions=frozenset({Precision.INT8}))
    chip = ChipConfig(name="x", tiles=((t, 2),))
    g = WorkloadGraph("t")
    g.dsp("softmax", OpType.SOFTMAX, elems=100)
    with pytest.raises(UnmappableError):
        map_graph(g, chip)


def test_split_only_when_it_helps():
    # big matmul on a 2-big-tile chip should split; tiny one should not
    g = WorkloadGraph("t", model_precision=Precision.INT8)
    g.matmul("big", 4096, 4096, 4096)
    g.matmul("tiny", 8, 8, 8)
    chip = homogeneous_baseline(4)
    plan = compile_workload(g, chip)
    assert len(plan.placements[0].tiles) > 1
    assert len(plan.placements[1].tiles) == 1


def test_schedule_covers_all_unfused_ops():
    g = build("resnet50_int8")
    plan = compile_workload(g, homogeneous_baseline(4))
    for i, nd in enumerate(plan.graph.nodes):
        if nd.fused_into < 0:
            assert i in plan.placements
