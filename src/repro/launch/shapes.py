"""Assigned input-shape sets and ShapeDtypeStruct stand-ins (deliverable f).

Four shapes per LM architecture (seq_len x global_batch):
  train_4k     4,096 x 256   -> train_step
  prefill_32k  32,768 x 32   -> serve prefill (last-token logits + caches)
  decode_32k   32,768 x 128  -> serve_step: one new token, 32k KV cache
  long_500k    524,288 x 1   -> serve_step vs a 500k cache; ONLY for
                               sub-quadratic archs (SSM/hybrid) — full-
                               attention archs skip it (DESIGN.md §4)

``input_specs`` returns (ShapeDtypeStruct pytree, PartitionSpec pytree) —
weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig

__all__ = ["SHAPES", "ShapeCase", "input_specs", "cache_specs_physical",
           "runnable", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}


def runnable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True


def skip_reason(cfg: ModelConfig, shape: str) -> Optional[str]:
    if not runnable(cfg, shape):
        return ("pure full-attention architecture: 500k-token decode needs "
                "sub-quadratic sequence mixing (DESIGN.md §4)")
    return None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _ctx_specs(cfg: ModelConfig, B: int, batch_axes) -> Tuple[dict, dict]:
    """Frontend stubs: precomputed frame/patch embeddings (assignment)."""
    structs, specs = {}, {}
    if cfg.encoder_layers:
        structs["frames"] = _sds((B, cfg.num_frontend_tokens, cfg.d_model),
                                 jnp.float32)
        specs["frames"] = P(batch_axes, None, None)
    elif cfg.frontend == "vision":
        structs["vision_embeds"] = _sds((B, cfg.num_frontend_tokens, cfg.d_model),
                                        jnp.float32)
        specs["vision_embeds"] = P(batch_axes, None, None)
    return structs, specs


def input_specs(cfg: ModelConfig, shape: str, *, multi_pod: bool = False):
    """(structs, pspecs) for the given shape case."""
    case = SHAPES[shape]
    b_axes = ("pod", "data") if multi_pod else ("data",)
    n_dp = 32 if multi_pod else 16
    B = case.global_batch
    batch_axes = b_axes if B % n_dp == 0 else None  # tiny-batch decode: replicate
    if case.mode == "train":
        structs = {"tokens": _sds((B, case.seq_len), jnp.int32),
                   "labels": _sds((B, case.seq_len), jnp.int32)}
        specs = {"tokens": P(batch_axes, None), "labels": P(batch_axes, None)}
    elif case.mode == "prefill":
        structs = {"tokens": _sds((B, case.seq_len), jnp.int32)}
        specs = {"tokens": P(batch_axes, None)}
    else:  # decode
        structs = {"tokens": _sds((B, 1), jnp.int32),
                   "pos": _sds((B,), jnp.int32)}
        specs = {"tokens": P(batch_axes, None), "pos": P(batch_axes)}
    cs, cp = _ctx_specs(cfg, B, batch_axes)
    structs.update(cs)
    specs.update(cp)
    return structs, specs


def cache_structs(cfg: ModelConfig, B: int, T: int):
    """ShapeDtypeStructs for the decode cache (mirrors model.init_cache)."""
    from ..models.model import _init_layer_cache

    blocks = []
    for mk, fk in cfg.pattern():
        one = jax.eval_shape(lambda mk=mk: _init_layer_cache(cfg, mk, B, T))
        blocks.append(None if one is None else jax.tree.map(
            lambda s: _sds((cfg.n_repeats,) + s.shape, s.dtype), one))
    prefix = [jax.eval_shape(lambda mk=mk: _init_layer_cache(cfg, mk, B, T))
              for mk, fk in cfg.prefix_pattern()]
    return {"prefix": prefix, "blocks": blocks}


def cache_specs_physical(cfg: ModelConfig, B: int, model_axis: int = 16,
                         multi_pod: bool = False):
    """Decode-cache PartitionSpecs on the physical mesh.

    KV shards over heads when kv_heads divides the model axis; otherwise
    over the sequence axis (SP) — mandatory for MQA (granite kv=1) and the
    500k-token caches.  batch==1 (long_500k) leaves batch unsharded and
    spreads the sequence across every DP device too."""
    b_axes = ("pod", "data") if multi_pod else ("data",)
    n_dp = 32 if multi_pod else 16
    batch = b_axes if B % n_dp == 0 else None
    seq_axes = "model" if batch is not None else (b_axes + ("model",))

    def one(mk: str, stacked: bool):
        lead = (None,) if stacked else ()
        if mk == "mamba":
            return {"conv": P(*lead, batch, None, "model"),
                    "ssm": P(*lead, batch, "model", None, None)}
        if mk == "cross_attn":
            return None
        if cfg.mla:
            return {"ckv": P(*lead, batch, seq_axes, None),
                    "kr": P(*lead, batch, seq_axes, None),
                    "len": P(*lead, batch)}
        if cfg.n_kv_heads % model_axis == 0:
            kv = P(*lead, batch, None, "model", None)
        else:
            kv = P(*lead, batch, seq_axes, None, None)
        return {"k": kv, "v": kv, "len": P(*lead, batch)}

    return {"prefix": [one(mk, False) for mk, fk in cfg.prefix_pattern()],
            "blocks": [one(mk, True) for mk, fk in cfg.pattern()]}
