"""Perf-tuning knobs for the §Perf hillclimb (EXPERIMENTS.md).

Each knob is a module-level cell the launcher sets before lowering; the
dry-run cost pass then measures the effect on the roofline terms.  These
are the "candidate changes" of the hypothesis loop — sharding layout,
kernel block shape, microbatch count, precision of the MoE dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["PerfKnobs", "KNOBS", "set_knobs"]


@dataclasses.dataclass
class PerfKnobs:
    # residual-stream constraint between layer periods:
    #   "seq"    — P(batch, "model", None): sequence parallelism (baseline)
    #   "dmodel" — P(batch, None, "model"): shard d_model instead
    #   "batch"  — P(batch, None, None): batch-only (no SP)
    act_mode: str = "seq"
    # Mamba2 SSD chunk length (intra-chunk working set is O(chunk^2)).
    # Default 64 after the §Perf hillclimb: chunk 128 -> 64 cut mamba2
    # train_4k peak memory 21.6 -> 13.4 GiB (now fits HBM) and the memory
    # term by 26 %; 64 -> 32 was < 5 % further (stop rule).
    ssd_chunk: int = 64
    # MoE dispatch tensors in bf16 instead of f32
    moe_dispatch_bf16: bool = False
    # gradient-accumulation microbatches in the train step
    microbatches: int = 1


KNOBS = PerfKnobs()


def set_knobs(**kw) -> PerfKnobs:
    global KNOBS
    KNOBS = dataclasses.replace(PerfKnobs(), **kw)
    return KNOBS
