"""Roofline analysis (deliverable g): derive the three roofline terms per
(arch x shape x mesh) cell from the dry-run's compiled artifacts.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

cost_analysis() reports the per-device SPMD program, so per-device values
divide by per-chip rates directly (equivalently: global = per-device x
chips).  Hardware: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

  PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.models import get_config
from repro.launch.shapes import SHAPES

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link (ICI)

__all__ = ["analyze", "load_cells", "model_flops"]


def model_flops(arch: str, shape: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for training;
    2*N*D (fwd only) for prefill; 2*N_active per token for decode."""
    cfg = get_config(arch)
    case = SHAPES[shape]
    n_total = cfg.param_count()
    if cfg.n_experts:
        # active params: replace full expert banks by top_k (+shared)
        f = cfg.moe_d_ff or cfg.d_ff
        moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
        inactive = moe_layers * (cfg.n_experts - cfg.top_k) * 3 * cfg.d_model * f
        n_active = n_total - inactive
    else:
        n_active = n_total
    tokens = case.global_batch * (1 if case.mode == "decode" else case.seq_len)
    mult = 6.0 if case.mode == "train" else 2.0
    return mult * n_active * tokens


def load_cells(dirname: str) -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        cells.append(json.load(open(path)))
    return cells


def analyze(rec: dict) -> Optional[dict]:
    if "skipped" in rec or "error" in rec:
        return None
    chips = rec["n_devices"]
    fl = rec["flops_per_device"]
    by = rec["bytes_per_device"]
    coll = sum(v["bytes"] for v in rec.get("collectives", {}).values())
    t_c = fl / PEAK_FLOPS
    t_m = by / HBM_BW
    t_l = coll / LINK_BW
    dominant = max(("compute", t_c), ("memory", t_m), ("collective", t_l),
                   key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = fl * chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "bound_s": max(t_c, t_m, t_l),
        # roofline fraction: how much of the bound is useful compute
        "roofline_frac": (mf / chips / PEAK_FLOPS) / max(t_c, t_m, t_l)
        if max(t_c, t_m, t_l) > 0 else 0.0,
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
        "collectives": rec.get("collectives", {}),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", action="store_true", help="markdown table")
    args = ap.parse_args()

    rows = []
    for rec in load_cells(args.dir):
        if rec.get("mesh") != args.mesh:
            continue
        if "skipped" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skipped": rec["skipped"]})
            continue
        if "error" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "error": rec["error"][:80]})
            continue
        rows.append(analyze(rec))

    hdr = (f"{'arch':28s} {'shape':12s} {'cmp_ms':>8s} {'mem_ms':>8s} "
           f"{'coll_ms':>8s} {'dom':>10s} {'useful':>7s} {'roofl%':>7s} {'GiB':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r is None:
            continue
        if "skipped" in r:
            print(f"{r['arch']:28s} {r['shape']:12s} -- skipped: full attention")
            continue
        if "error" in r:
            print(f"{r['arch']:28s} {r['shape']:12s} !! {r['error']}")
            continue
        print(f"{r['arch']:28s} {r['shape']:12s} {r['compute_s']*1e3:8.2f} "
              f"{r['memory_s']*1e3:8.2f} {r['collective_s']*1e3:8.2f} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
              f"{r['roofline_frac']*100:6.1f}% {r['peak_gib']:6.2f}")


if __name__ == "__main__":
    main()
