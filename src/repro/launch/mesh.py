"""Production mesh definition (deliverable e).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state, so smoke tests see 1 CPU device while the
dry-run (which sets --xla_force_host_platform_device_count=512 before any
import) sees the full placeholder pod.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_for_devices"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for_devices(n_devices: int, model_parallel: int = 1):
    """Elastic mesh for whatever devices are healthy (train loop + tests):
    (n/model_parallel, model_parallel) over ("data", "model")."""
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by "
                         f"model_parallel={model_parallel}")
    return jax.make_mesh((n_devices // model_parallel, model_parallel),
                         ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
