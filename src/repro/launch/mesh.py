"""Production mesh definition (deliverable e).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state, so smoke tests see 1 CPU device while the
dry-run (which sets --xla_force_host_platform_device_count=512 before any
import) sees the full placeholder pod.

``mesh_axis_kwargs`` is the JAX-version compat shim shared by every mesh
construction site in the repo (train substrate, collective tests, and the
DSE evaluation engine's candidate-axis sharding): ``jax.sharding.AxisType``
only exists in newer JAX releases, and older ``jax.make_mesh`` rejects the
``axis_types`` keyword outright, so on old versions we simply build the
mesh without it (the default axis behaviour there is the same Auto mode).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_for_devices",
           "mesh_axis_kwargs", "candidate_sharding", "population_sharding",
           "island_sharding", "default_islands"]


def mesh_axis_kwargs(n_axes: int) -> dict:
    """kwargs for ``jax.make_mesh``: ``axis_types`` when supported, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def candidate_sharding():
    """``NamedSharding`` over the DSE candidate batch axis, or ``None``
    on a single device (where sharding is a no-op anyway).

    The one sharding every engine evaluation path uses — the in-scan
    ``batch_eval`` evaluator AND the compile-free batched mapper+executor
    place their (B, ...) config/placement arrays with it, so a sweep or
    GA population spans whatever devices exist.  Batch sizes must be a
    multiple of ``mesh.size`` (``EvalEngine._pad_size`` rounds up after
    bucket rounding) or XLA falls back to per-device replication.
    """
    devs = jax.devices()
    if len(devs) <= 1:
        return None
    mesh = jax.make_mesh((len(devs),), ("candidates",),
                         **mesh_axis_kwargs(1))
    return jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("candidates"))


def population_sharding():
    """The GA generation loop's sharding: the (P, GENOME_LEN) population
    and its per-generation genetics dispatch
    (``core.dse.ga_device``) shard over the same ``"candidates"`` axis
    the evaluation batches use, so one mesh covers the whole
    search loop — selection/crossover/mutation on device AND the fused
    exact scoring dispatches.  Same divisibility rule: the population
    must be a mesh-size multiple or the device loop falls back to a
    single-device placement (it checks before placing)."""
    return candidate_sharding()


def island_sharding(n_islands: int):
    """Sharding for the island-model GA (``core.dse.ga_device`` fused
    loop): the population is carried flat as (P, GENOME_LEN) but is
    logically (islands, P/islands, GENOME_LEN), and sharding the leading
    axis places one contiguous block of islands per device.  Inside the
    jitted refinement loop the ring migration is a ``jnp.roll`` over the
    island axis — XLA lowers a roll of a sharded leading axis to a
    collective permute around the device ring, so migrants move
    device-to-device without a host hop.  Returns ``None`` on a single
    device or when ``n_islands`` doesn't divide over the mesh (the
    caller falls back to single-device placement — same numbers, no
    collectives)."""
    devs = jax.devices()
    if len(devs) <= 1 or int(n_islands) % len(devs) != 0:
        return None
    mesh = jax.make_mesh((len(devs),), ("islands",), **mesh_axis_kwargs(1))
    return jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("islands"))


def default_islands(population: int) -> int:
    """Island count the fused GA defaults to: one island per local device
    when the population splits evenly, else a single panmictic island
    (which preserves the host-memo loop's exact genome stream)."""
    ndev = len(jax.devices())
    if ndev > 1 and population % ndev == 0 and population // ndev >= 2:
        return ndev
    return 1


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_kwargs(len(axes)))


def make_mesh_for_devices(n_devices: int, model_parallel: int = 1):
    """Elastic mesh for whatever devices are healthy (train loop + tests):
    (n/model_parallel, model_parallel) over ("data", "model")."""
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by "
                         f"model_parallel={model_parallel}")
    return jax.make_mesh((n_devices // model_parallel, model_parallel),
                         ("data", "model"), **mesh_axis_kwargs(2))
