"""Perf hillclimb drivers.

Cell mode (the original): lower ONE cell under a knob setting and report
the roofline terms + peak memory.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch mamba2-780m \
      --shape train_4k --set ssd_chunk=64 --tag chunk64

DSE mode: greedy single-gene hillclimb over the 12-knob genome space,
scored through the cache-aware ``EvalEngine``.  Hillclimbing revisits
neighbouring genomes constantly (every step re-proposes mutations of the
same incumbent), so the engine's genome memo does most of the work: only
never-seen neighbours are simulated.

  PYTHONPATH=src python -m repro.launch.hillclimb --dse \
      --workloads resnet50_int8 kan --budget 200 --steps 24
"""
import argparse
import json
import os


def dse_hillclimb(workloads, budget_mm2: float = 200.0, steps: int = 24,
                  neighbors: int = 32, seed: int = 0, engine=None,
                  verbose: bool = False) -> dict:
    """Greedy genome hillclimb under an area budget: minimize mean energy
    across workloads.  Returns the best genome, its metrics, and the
    engine cache stats."""
    import numpy as np

    from repro.core.dse.encoding import GENOME_LEN, genome_bounds, \
        random_genomes
    from repro.core.dse.api import EngineConfig
    from repro.core.dse.engine import EvalEngine

    if not workloads:
        raise ValueError("dse_hillclimb needs at least one workload")
    engine = (engine.check_workloads(workloads) if engine is not None
              else EvalEngine(workloads, config=EngineConfig()))
    rng = np.random.default_rng(seed)
    bounds = genome_bounds()

    def keep(areas):
        return areas <= budget_mm2

    def score(m):
        # mean energy over workloads; inf for unmappable / over-budget.
        # The explicit area guard matters with a shared engine: a genome
        # memoized by an earlier unfiltered search bypasses the keep
        # predicate and would otherwise return its real (finite) energy.
        e = m["energy"].mean(axis=1)
        return np.where(m["area"] <= budget_mm2, e, np.inf)

    starts = random_genomes(rng, max(neighbors, 8))
    m = engine.evaluate(starts, keep=keep)
    s = score(m)
    best_i = int(np.argmin(s))
    cur, cur_s = starts[best_i].copy(), float(s[best_i])

    for step in range(steps):
        cand = np.repeat(cur[None, :], neighbors, axis=0)
        genes = rng.integers(0, GENOME_LEN, neighbors)
        delta = rng.choice([-1, 1], neighbors)
        for r in range(neighbors):
            g = genes[r]
            cand[r, g] = np.clip(cand[r, g] + delta[r], 0, bounds[g] - 1)
        m = engine.evaluate(cand, keep=keep)
        s = score(m)
        i = int(np.argmin(s))
        if s[i] < cur_s:
            cur, cur_s = cand[i].copy(), float(s[i])
        if verbose:
            print(f"[dse-hillclimb] step {step}: best_energy={cur_s:.3e}pJ "
                  f"hit_rate={engine.stats.hit_rate():.0%}")
    if not np.isfinite(cur_s):
        raise ValueError(
            f"no mappable design found within budget {budget_mm2} mm^2 "
            f"after {steps} steps — raise the budget or the step count")
    final = engine.evaluate(cur[None, :])
    return {
        "best_genome": cur.tolist(),
        "mean_energy_pj": cur_s,
        "area_mm2": float(final["area"][0]),
        "per_workload_latency_s": final["latency"][0].tolist(),
        "workloads": list(workloads),
        "cache_hit_rate": engine.stats.hit_rate(),
        "evaluator_throughput_cfg_wl_per_s": engine.stats.throughput(),
    }


def _main_cell(args):
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    from repro.launch import tuning
    from repro.launch.dryrun import run_cell
    from repro.launch.roofline import analyze

    kw = {}
    for kv in args.set:
        k, v = kv.split("=")
        kw[k] = (v == "true") if v in ("true", "false") else \
            (int(v) if v.isdigit() else v)
    tuning.set_knobs(**kw)

    rec = run_cell(args.arch, args.shape, args.multi_pod)
    rec["knobs"] = kw
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}_{args.shape}_{args.tag}.json")
    json.dump(rec, open(path, "w"), indent=1)
    if "error" in rec:
        print("FAIL:", rec["error"][:300])
        raise SystemExit(1)
    a = analyze(rec)
    print(json.dumps({
        "tag": args.tag, "knobs": kw,
        "compute_ms": round(a["compute_s"] * 1e3, 2),
        "memory_ms": round(a["memory_s"] * 1e3, 2),
        "collective_ms": round(a["collective_s"] * 1e3, 2),
        "dominant": a["dominant"],
        "roofline_frac_pct": round(a["roofline_frac"] * 100, 2),
        "useful_ratio": round(a["useful_ratio"], 3),
        "peak_gib": round(a["peak_gib"], 2),
    }, indent=1))


def _main_dse(args):
    out = dse_hillclimb(args.workloads, budget_mm2=args.budget,
                        steps=args.steps, seed=args.seed, verbose=True)
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"dse_hillclimb_{args.tag}.json")
    json.dump(out, open(path, "w"), indent=1)
    print(json.dumps({k: out[k] for k in
                      ("mean_energy_pj", "area_mm2", "cache_hit_rate",
                       "evaluator_throughput_cfg_wl_per_s")}, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dse", action="store_true",
                    help="genome hillclimb through the DSE EvalEngine")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[],
                    help="knob=value pairs (act_mode, ssd_chunk, "
                         "moe_dispatch_bf16, microbatches)")
    ap.add_argument("--workloads", nargs="*",
                    default=["resnet50_int8", "kan"])
    ap.add_argument("--budget", type=float, default=200.0)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tag", default="variant")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    if args.dse:
        _main_dse(args)
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape are required without --dse")
        _main_cell(args)


if __name__ == "__main__":
    main()
