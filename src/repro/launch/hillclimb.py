"""§Perf hillclimb driver: lower ONE cell under a knob setting and report
the roofline terms + peak memory.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch mamba2-780m \
      --shape train_4k --set ssd_chunk=64 --tag chunk64
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

from repro.launch import tuning
from repro.launch.dryrun import run_cell
from repro.launch.roofline import analyze


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[],
                    help="knob=value pairs (act_mode, ssd_chunk, "
                         "moe_dispatch_bf16, microbatches)")
    ap.add_argument("--tag", default="variant")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    kw = {}
    for kv in args.set:
        k, v = kv.split("=")
        kw[k] = (v == "true") if v in ("true", "false") else \
            (int(v) if v.isdigit() else v)
    tuning.set_knobs(**kw)

    rec = run_cell(args.arch, args.shape, args.multi_pod)
    rec["knobs"] = kw
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"{args.arch}_{args.shape}_{args.tag}.json")
    json.dump(rec, open(path, "w"), indent=1)
    if "error" in rec:
        print("FAIL:", rec["error"][:300])
        raise SystemExit(1)
    a = analyze(rec)
    print(json.dumps({
        "tag": args.tag, "knobs": kw,
        "compute_ms": round(a["compute_s"] * 1e3, 2),
        "memory_ms": round(a["memory_s"] * 1e3, 2),
        "collective_ms": round(a["collective_s"] * 1e3, 2),
        "dominant": a["dominant"],
        "roofline_frac_pct": round(a["roofline_frac"] * 100, 2),
        "useful_ratio": round(a["useful_ratio"], 3),
        "peak_gib": round(a["peak_gib"], 2),
    }, indent=1))


if __name__ == "__main__":
    main()
