"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input-shape x mesh) cell on the
production meshes — (16,16) single-pod and (2,16,16) multi-pod — with
ShapeDtypeStruct inputs (no allocation), and records memory_analysis,
cost_analysis, and the HLO collective schedule for the roofline table.

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-34b --shape train_4k
"""
# The VERY FIRST lines, before ANY other import: jax locks the device
# count on first init.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import functools
import gc
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (AXIS_MAP_MULTI, AXIS_MAP_SINGLE,
                                        resolve_specs, set_axis_map)
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, cache_specs_physical, cache_structs,
                                 input_specs, runnable, skip_reason)
from repro.models import get_config, init_params, list_archs, param_specs
from repro.models.model import set_activation_spec, set_scan_unroll
from repro.optim.adamw import AdamWConfig
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import init_train_state, make_train_step

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

# optimized HLO prints untyped operands; parse the RESULT type of each
# collective and derive operand bytes from the op kind + replica-group size
_SHAPE_RE = re.compile(
    r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)"
    r"\[([0-9,]*)\]")


def _shape_bytes(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _group_size(spec: str) -> int:
    if not spec:
        return 1
    if spec.startswith("[{"):
        spec = spec[1:]
    if spec.startswith("{{"):
        first = spec[2:].split("}")[0]
        return first.count(",") + 1
    m = re.match(r"\[(\d+),(\d+)\]", spec)
    return int(m.group(2)) if m else 1


def collective_stats(hlo: str):
    """Per-device operand bytes of every collective in the HLO module.

    Result->operand conversion: all-gather R/g, all-reduce R,
    reduce-scatter R*g, all-to-all R, collective-permute R."""
    stats = {}
    for line in hlo.splitlines():
        m = re.search(
            r"=\s*(.*?)\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        restype, kind = m.group(1), m.group(2)
        rbytes = sum(_shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(restype))
        gm = re.search(r"replica_groups=(\{\{[^}]*\}|\[\d+,\d+\])", line)
        g = _group_size(gm.group(1)) if gm else 1
        if kind == "all-gather":
            nbytes = rbytes / max(g, 1)
        elif kind == "reduce-scatter":
            nbytes = rbytes * g
        else:
            nbytes = rbytes
        e = stats.setdefault(kind, {"count": 0, "bytes": 0})
        e["count"] += 1
        e["bytes"] += int(nbytes)
    return stats


def _moments_dtype(cfg) -> str:
    # int8 moments above 100B params (DESIGN.md §6 / optim.adamw)
    return "int8" if cfg.param_count() > 100e9 else "fp32"


def _opt_specs(pspecs, moments_dtype: str):
    def one(s):
        scale_spec = P(*(tuple(s)[:-1] + (None,))) if len(tuple(s)) else P()
        if moments_dtype == "int8":
            q = {"q": s, "scale": scale_spec}
            return {"m": q, "v": q}
        return {"m": s, "v": s}

    return jax.tree.map(one, pspecs, is_leaf=lambda x: isinstance(x, P))


def build_lowerable(arch: str, shape: str, multi_pod: bool):
    """Returns (jitted_fn, example_args_structs) for the cell."""
    cfg = get_config(arch)
    case = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axis_map = AXIS_MAP_MULTI if multi_pod else AXIS_MAP_SINGLE
    pspecs = resolve_specs(param_specs(cfg), axis_map)
    b_axes = ("pod", "data") if multi_pod else ("data",)
    n_dp = 32 if multi_pod else 16
    # residual-stream constraint between periods (tuning.act_mode):
    # "seq" = sequence parallelism (baseline), "dmodel", "batch"
    from repro.launch.tuning import KNOBS
    act_batch = b_axes if case.global_batch % n_dp == 0 else None
    act_spec = {"seq": P(act_batch, "model", None),
                "dmodel": P(act_batch, None, "model"),
                "batch": P(act_batch, None, None)}[KNOBS.act_mode]
    set_activation_spec(NamedSharding(mesh, act_spec))
    set_axis_map({"b": act_batch, "m": "model", "d": "data"})

    structs, in_pspecs = input_specs(cfg, shape, multi_pod=multi_pod)
    params_struct = jax.eval_shape(
        functools.partial(init_params, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))

    def shard(tree, specs):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))

    if case.mode == "train":
        opt_cfg = AdamWConfig(moments_dtype=_moments_dtype(cfg))
        state_struct = jax.eval_shape(
            lambda ps: init_train_state(cfg, ps, opt_cfg), params_struct)
        state_specs = {"params": pspecs,
                       "opt": {"mu": _opt_specs(pspecs, opt_cfg.moments_dtype),
                               "step": P()},
                       "step": P()}
        step = make_train_step(cfg, opt_cfg, microbatches=KNOBS.microbatches)
        fn = jax.jit(step,
                     in_shardings=(shard(None, state_specs), shard(None, in_pspecs)),
                     out_shardings=(shard(None, state_specs), None),
                     donate_argnums=(0,))
        args = (state_struct, structs)
    elif case.mode == "prefill":
        pf = make_prefill_step(cfg, case.seq_len)
        c_specs = cache_specs_physical(cfg, case.global_batch,
                                       multi_pod=multi_pod)
        ctx_keys = [k for k in structs if k != "tokens"]
        tok_sh = NamedSharding(mesh, in_pspecs["tokens"])
        ctx_sh = (NamedSharding(mesh, in_pspecs[ctx_keys[0]]),) if ctx_keys else ()
        fn = jax.jit(pf,
                     in_shardings=(shard(None, pspecs), tok_sh) + ctx_sh,
                     out_shardings=(None, shard(None, c_specs)))
        args = (params_struct, structs["tokens"]) + tuple(
            structs[k] for k in ctx_keys)
    else:  # decode
        dec = make_decode_step(cfg)
        c_struct = cache_structs(cfg, case.global_batch, case.seq_len)
        c_specs = cache_specs_physical(cfg, case.global_batch,
                                       multi_pod=multi_pod)
        ctx_keys = [k for k in structs if k not in ("tokens", "pos")]
        shardings = [shard(None, pspecs),
                     NamedSharding(mesh, in_pspecs["tokens"]),
                     NamedSharding(mesh, in_pspecs["pos"]),
                     shard(None, c_specs)]
        args = [params_struct, structs["tokens"], structs["pos"], c_struct]
        if ctx_keys:
            shardings.append(NamedSharding(mesh, in_pspecs[ctx_keys[0]]))
            args.append(structs[ctx_keys[0]])
        fn = jax.jit(dec, in_shardings=tuple(shardings),
                     out_shardings=(None, shard(None, c_specs)),
                     donate_argnums=(3,))
        args = tuple(args)
    return fn, args, mesh


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": 512 if multi_pod else 256}
    reason = skip_reason(cfg, shape)
    if reason:
        rec["skipped"] = reason
        return rec
    t0 = time.time()
    try:
        fn, args, mesh = build_lowerable(arch, shape, multi_pod)
        with mesh:
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
        rec.update({
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "flops_per_device": ca.get("flops", 0.0),
            "bytes_per_device": ca.get("bytes accessed", 0.0),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            "collectives": collective_stats(hlo),
            "hlo_chars": len(hlo),
        })
        del fn, lowered, compiled, hlo
        gc.collect()

        # ---- cost pass: XLA's cost analysis counts while-loop bodies once,
        # so the scanned block stack under-reports by n_repeats.  Cost-mode
        # lowering unrolls the small scans fully and the blocks scan by u;
        # cost is affine in u, so two lowerings (u=1, u=2) extrapolate the
        # true totals exactly: total = f1 + (R-1) * (f2 - f1). --------------
        try:
            R = cfg.n_repeats

            def cost_lower(u: int):
                set_scan_unroll(True, blocks_unroll=u)
                fnc, argsc, meshc = build_lowerable(arch, shape, multi_pod)
                with meshc:
                    comp = fnc.lower(*argsc).compile()
                    cac = comp.cost_analysis() or {}
                    stats = collective_stats(comp.as_text())
                out = {"flops": cac.get("flops", 0.0),
                       "bytes": cac.get("bytes accessed", 0.0),
                       "coll": stats}
                del fnc, comp
                gc.collect()
                return out

            c1 = cost_lower(1)
            if R > 1:
                c2 = cost_lower(2)

                def extrap(a, b):
                    return a + (R - 1) * (b - a)

                rec["flops_per_device"] = extrap(c1["flops"], c2["flops"])
                rec["bytes_per_device"] = extrap(c1["bytes"], c2["bytes"])
                coll = {}
                kinds = set(c1["coll"]) | set(c2["coll"])
                for k in kinds:
                    b1 = c1["coll"].get(k, {"count": 0, "bytes": 0})
                    b2 = c2["coll"].get(k, {"count": 0, "bytes": 0})
                    coll[k] = {"count": int(extrap(b1["count"], b2["count"])),
                               "bytes": int(extrap(b1["bytes"], b2["bytes"]))}
                rec["collectives"] = coll
            else:
                rec["flops_per_device"] = c1["flops"]
                rec["bytes_per_device"] = c1["bytes"]
                rec["collectives"] = c1["coll"]
            rec["cost_unrolled"] = True
        except Exception as e:
            rec["cost_unrolled"] = False
            rec["cost_pass_error"] = f"{type(e).__name__}: {e}"[:200]
        finally:
            set_scan_unroll(False)
    except Exception as e:  # record the failure — these are bugs to fix
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    finally:
        set_activation_spec(None)
        set_axis_map(None)
        gc.collect()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    ok = skip = fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{'multi' if mp else 'single'}_{arch}_{shape}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    rec = json.load(open(path))
                    if "error" not in rec:
                        print(f"[cached] {tag}")
                        ok += 0 if "skipped" in rec else 1
                        skip += 1 if "skipped" in rec else 0
                        continue
                rec = run_cell(arch, shape, mp)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if "skipped" in rec:
                    skip += 1
                    print(f"[skip]   {tag}: {rec['skipped'][:60]}")
                elif "error" in rec:
                    fail += 1
                    print(f"[FAIL]   {tag}: {rec['error'][:200]}")
                else:
                    ok += 1
                    peak_gb = rec["memory"]["peak_bytes"] / 2**30
                    print(f"[ok]     {tag}: compile={rec['compile_s']}s "
                          f"peak={peak_gb:.2f}GiB/dev "
                          f"flops/dev={rec['flops_per_device']:.3g}")
    print(f"\ndry-run: {ok} ok, {skip} skipped, {fail} FAILED")
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
