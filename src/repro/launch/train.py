"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

--smoke trains the reduced config for a few hundred steps on CPU (the
end-to-end example); full configs are exercised via the dry-run.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.models import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    loop = TrainLoopConfig(steps=args.steps, global_batch=args.batch,
                           seq_len=args.seq, ckpt_dir=args.ckpt_dir)
    t0 = time.time()
    out = train_loop(cfg, loop, AdamWConfig(lr=args.lr))
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name, "steps": out["steps_run"],
        "first_loss": out["losses"][0], "final_loss": out["final_loss"],
        "restarts": out["restarts"], "wall_s": round(dt, 1),
        "steps_per_s": round(out["steps_run"] / dt, 2),
    }, indent=1))


if __name__ == "__main__":
    main()
