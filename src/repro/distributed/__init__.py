"""Distribution layer: sharding-rule resolution, batch specs, and
compute/comm overlap helpers."""
from .sharding import (resolve_specs, named_shardings, batch_spec,
                       AXIS_MAP_SINGLE, AXIS_MAP_MULTI)

__all__ = ["resolve_specs", "named_shardings", "batch_spec",
           "AXIS_MAP_SINGLE", "AXIS_MAP_MULTI"]
