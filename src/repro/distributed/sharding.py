"""Sharding-rule resolution.

Model code annotates parameters with LOGICAL axes: "d" (FSDP over the
data axis) and "m" (tensor parallel over the model axis).  At launch time
these resolve against the physical mesh:

  single-pod (16,16) ("data","model"):   d -> "data",  m -> "model"
  multi-pod (2,16,16) ("pod","data","model"): batch over ("pod","data");
      params FSDP-shard over "data" only (each pod holds a replica of the
      FSDP shards, so the cross-pod axis carries only gradient reductions —
      the classic pod-level DP design).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["resolve_specs", "named_shardings", "batch_spec",
           "AXIS_MAP_SINGLE", "AXIS_MAP_MULTI", "set_axis_map",
           "logical_constraint"]

# Launcher-installed logical->physical axis map.  Model code calls
# ``logical_constraint(x, "b", None, "m", ...)`` and gets a
# with_sharding_constraint against the ambient mesh, or a no-op when no
# map is installed (single-device smoke tests).
_AXIS_MAP: Dict[str, Any] | None = None


def set_axis_map(axis_map: Optional[Dict[str, Any]]) -> None:
    global _AXIS_MAP
    _AXIS_MAP = axis_map


def logical_constraint(x, *axes):
    if _AXIS_MAP is None:
        return x
    spec = P(*[(_AXIS_MAP.get(a, a) if isinstance(a, str) else a)
               for a in axes])
    return jax.lax.with_sharding_constraint(x, spec)

AXIS_MAP_SINGLE: Dict[str, Any] = {"d": "data", "m": "model",
                                   "b": ("data",)}
AXIS_MAP_MULTI: Dict[str, Any] = {"d": "data", "m": "model",
                                  "b": ("pod", "data")}


def _resolve_one(spec: P, axis_map: Dict[str, Any]) -> P:
    out = []
    for part in spec:
        if part is None:
            out.append(None)
        elif isinstance(part, str):
            out.append(axis_map.get(part, part))
        else:  # tuple of logical axes
            resolved = []
            for q in part:
                r = axis_map.get(q, q)
                resolved.extend(r if isinstance(r, tuple) else (r,))
            out.append(tuple(resolved))
    return P(*out)


def resolve_specs(tree, axis_map: Dict[str, Any]):
    """Map logical-axis PartitionSpecs to physical mesh axes."""
    return jax.tree.map(lambda s: _resolve_one(s, axis_map), tree,
                        is_leaf=lambda x: isinstance(x, P))


def named_shardings(tree, mesh: Mesh):
    """Attach a mesh to a resolved spec tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, *trailing: Optional[str]) -> P:
    """Batch-leading PartitionSpec over all DP axes of ``mesh``."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    return P(dp, *trailing)
