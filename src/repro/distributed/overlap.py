"""Compute/communication overlap: ring collective-matmul via shard_map.

``ring_allgather_matmul`` decomposes x @ W (W column-sharded over the TP
axis, x row-gathered) into P steps: at step i each chip multiplies the
shard it holds while ``ppermute``-ing the next shard around the ring — XLA
overlaps the permute with the matmul, hiding the all-gather behind compute
(the classic collective-matmul; a distributed-optimization trick from
DESIGN.md §6 used by the §Perf hillclimb).

Equivalent semantics: jnp.einsum("sd,df->sf", all_gather(x), W_local).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["ring_allgather_matmul"]


def ring_allgather_matmul(x, w, mesh: Mesh, axis: str = "model"):
    """x: (S, D) row-sharded over ``axis``; w: (D, F) F-sharded over
    ``axis``.  Returns (S, F) F-sharded: equivalent to (allgather(x) @ w)
    but with the gather pipelined against P partial matmuls.
    """
    p = mesh.shape[axis]

    def body(x_blk, w_loc):
        # x_blk: (S/p, D) local rows; w_loc: (D, F/p)
        idx = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % p) for i in range(p)]
        s_blk = x_blk.shape[0]
        out = jnp.zeros((s_blk * p, w_loc.shape[1]), x_blk.dtype)
        cur = x_blk

        def step(i, carry):
            cur, out = carry
            # rows currently held came from rank (idx - i) mod p
            src = (idx - i) % p
            out = jax.lax.dynamic_update_slice(
                out, (cur @ w_loc).astype(out.dtype), (src * s_blk, 0))
            nxt = jax.lax.ppermute(cur, axis, perm)
            return (nxt, out)

        cur, out = jax.lax.fori_loop(0, p, step, (cur, out))
        return out

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis, None), P(None, axis)),
                   out_specs=P(None, axis), check_rep=False)
    return fn(x, w)
