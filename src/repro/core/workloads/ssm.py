"""SSM-family workloads: Mamba-370M, Hyena-1.3B, Nemotron-H (paper Table 1).

The SSM scan is a DSP-class op with a sequence-length sequential multiplier
(paper §3.3.1); Hyena's long convolutions run through FFT — lowered onto
the MAC array on homogeneous chips (~30 % of wall time, Fig. 3) but served
natively by a Special-Function tile.
"""
from __future__ import annotations

from ..ir import OpNode, OpType, Precision, WorkloadGraph
from .transformer import attention_block, mlp_block

__all__ = ["mamba_370m", "hyena_1_3b", "nemotron_h", "mamba_block"]


def mamba_block(g: WorkloadGraph, pre: str, x: int, s: int, d: int,
                d_state: int, prec: Precision, expand: int = 2) -> int:
    """Selective-SSM block: in_proj -> causal conv1d -> selective scan ->
    gated SiLU -> out_proj."""
    di = expand * d
    n1 = g.dsp(f"{pre}_norm", OpType.RMSNORM, elems=s * d, preds=[x])
    ip = g.add(OpNode(f"{pre}_in_proj", OpType.MATMUL, m=s, k=d, n=2 * di,
                      precision=prec), [n1])
    # causal conv over channels is depthwise (one filter per channel)
    cv = g.add(OpNode(f"{pre}_dwconv", OpType.DWCONV, m=s * di, k=4, n=1,
                      precision=prec), [ip])
    sc = g.add(OpNode(f"{pre}_ssm_scan", OpType.SSM_SCAN, elems=s * di * d_state,
                      seq_len=s, precision=Precision.FP16), [cv])
    gt = g.dsp(f"{pre}_gate_silu", OpType.SILU, elems=s * di, preds=[sc, ip])
    op = g.add(OpNode(f"{pre}_out_proj", OpType.MATMUL, m=s, k=di, n=d,
                      precision=prec), [gt])
    return g.dsp(f"{pre}_residual", OpType.ADD, elems=s * d, preds=[op, x])


def mamba_370m(s: int = 1024) -> WorkloadGraph:
    """Mamba-370M: 48 layers, d=1024, state 16."""
    g = WorkloadGraph("mamba_370m", model_precision=Precision.FP16,
                      family="ssm")
    x = g.dsp("embed_lookup", OpType.GATHER, elems=s * 1024,
              precision=Precision.FP16)
    for li in range(48):
        x = mamba_block(g, f"l{li}", x, s, 1024, 16, Precision.FP16)
    n = g.dsp("final_norm", OpType.RMSNORM, elems=s * 1024, preds=[x])
    g.add(OpNode("lm_head", OpType.MATMUL, m=1, k=1024, n=50280,
                 precision=Precision.FP16), [n])
    return g


def hyena_1_3b(s: int = 1024) -> WorkloadGraph:
    """Hyena-1.3B: long convolutions via FFT (order-2 operator): per layer
    three projections, an FFT long-conv per channel (length-2S padded), and
    multiplicative gating."""
    g = WorkloadGraph("hyena_1_3b", model_precision=Precision.FP16,
                      family="ssm")
    d, layers = 2048, 24
    fft_n = 2 * s  # zero-padded circular convolution
    x = g.dsp("embed_lookup", OpType.GATHER, elems=s * d,
              precision=Precision.FP16)
    for li in range(layers):
        pre = f"l{li}"
        n1 = g.dsp(f"{pre}_norm", OpType.LAYERNORM, elems=s * d, preds=[x])
        pr = g.add(OpNode(f"{pre}_projections", OpType.MATMUL, m=s, k=d,
                          n=3 * d, precision=Precision.FP16), [n1])
        sh = g.add(OpNode(f"{pre}_short_conv", OpType.CONV1D, m=s * 3 * d, k=3,
                          n=1, precision=Precision.FP16), [pr])
        # forward FFT over every channel, filter multiply, inverse FFT
        ff = g.add(OpNode(f"{pre}_fft_fwd", OpType.FFT, elems=d * fft_n,
                          fft_n=fft_n, precision=Precision.FP16), [sh])
        fm = g.dsp(f"{pre}_filter_mul", OpType.MUL, elems=d * fft_n, preds=[ff])
        fi = g.add(OpNode(f"{pre}_fft_inv", OpType.FFT, elems=d * fft_n,
                          fft_n=fft_n, precision=Precision.FP16), [fm])
        gt = g.dsp(f"{pre}_gate_mul", OpType.MUL, elems=s * d, preds=[fi, pr])
        op = g.add(OpNode(f"{pre}_out_proj", OpType.MATMUL, m=s, k=d, n=d,
                          precision=Precision.FP16), [gt])
        x = g.dsp(f"{pre}_residual", OpType.ADD, elems=s * d, preds=[op, x])
    n = g.dsp("final_norm", OpType.LAYERNORM, elems=s * d, preds=[x])
    g.add(OpNode("lm_head", OpType.MATMUL, m=1, k=d, n=50280,
                 precision=Precision.FP16), [n])
    return g


def nemotron_h(precision: Precision = Precision.FP16, s: int = 256) -> WorkloadGraph:
    """Nemotron-H-style hybrid attention/SSM LLM: 48 blocks, 4 attention +
    44 Mamba2 blocks interleaved (the across-layers heterogeneity scope of
    §2.3), d=4096."""
    g = WorkloadGraph(f"nemotron_h_{precision.name.lower()}",
                      model_precision=precision, family="hybrid")
    d = 4096
    x = g.dsp("embed_lookup", OpType.GATHER, elems=s * d,
              precision=Precision.FP16)
    for li in range(48):
        if li % 12 == 5:  # sparse attention interleave
            x = attention_block(g, f"l{li}", x, s, d, 32, 8, precision,
                                norm=OpType.RMSNORM, rope=True)
            x = mlp_block(g, f"l{li}", x, s, d, 14336, precision,
                          norm=OpType.RMSNORM)
        else:
            x = mamba_block(g, f"l{li}", x, s, d, 64, precision)
    n = g.dsp("final_norm", OpType.RMSNORM, elems=s * d, preds=[x])
    g.add(OpNode("lm_head", OpType.MATMUL, m=1, k=d, n=131072,
                 precision=precision), [n])
    return g
