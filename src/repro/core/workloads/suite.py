"""The 20-workload registry (paper Table 1): 14 base models + 6
post-training-quantized INT4/INT8 LLM variants.

Selection criteria (paper §4.1): exercise all 23 operator types, stress
every execution path (MAC / DSP / Special-Function), span five orders of
magnitude in arithmetic intensity, and cover production INT4/INT8
quantization.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List

from ..ir import Precision, WorkloadGraph
from .cnn import resnet50, snn_vgg9
from .misc import gnn_gat, kan
from .ssm import hyena_1_3b, mamba_370m, nemotron_h
from .transformer import lavish, llama7b, llava, mixtral, rt2, spec_decode, vit_b16

__all__ = ["SUITE_BUILDERS", "build", "suite", "workload_names", "GROUPS"]

SUITE_BUILDERS: Dict[str, Callable[[], WorkloadGraph]] = {
    # --- 14 base models (ten architectural families) ---
    "resnet50_int8": resnet50,
    "vit_b16_fp16": lambda: vit_b16(Precision.FP16),
    "llama7b_fp16": lambda: llama7b(Precision.FP16),
    "spec_decode": spec_decode,
    "mixtral_fp16": lambda: mixtral(Precision.FP16),
    "nemotron_h_fp16": lambda: nemotron_h(Precision.FP16),
    "mamba_370m": mamba_370m,
    "hyena_1_3b": hyena_1_3b,
    "kan": kan,
    "snn_vgg9": snn_vgg9,
    "lavish": lavish,
    "llava": llava,
    "rt2": rt2,
    "gnn_gat": gnn_gat,
    # --- 6 post-training-quantized variants ---
    "vit_b16_int8": lambda: vit_b16(Precision.INT8),
    "llama7b_int8": lambda: llama7b(Precision.INT8),
    "llama7b_int4": lambda: llama7b(Precision.INT4),
    "mixtral_int4": lambda: mixtral(Precision.INT4),
    "nemotron_h_int8": lambda: nemotron_h(Precision.INT8),
    "nemotron_h_int4": lambda: nemotron_h(Precision.INT4),
}

# Three-group taxonomy (paper §5.3) for the 15 MAC/DSP-dominant workloads,
# plus the five non-MAC workloads served by the Special-Function tile.
GROUPS = {
    "int_quantized": ["resnet50_int8", "vit_b16_int8", "llama7b_int8",
                      "llama7b_int4", "mixtral_int4", "nemotron_h_int8",
                      "nemotron_h_int4", "gnn_gat"],
    "fp16_transformer_ssm": ["vit_b16_fp16", "llama7b_fp16", "mixtral_fp16",
                             "nemotron_h_fp16", "mamba_370m", "llava"],
    "bandwidth_bound": ["spec_decode"],
    "non_mac": ["kan", "snn_vgg9", "hyena_1_3b", "lavish", "rt2"],
}


def workload_names() -> List[str]:
    return list(SUITE_BUILDERS)


@functools.lru_cache(maxsize=None)
def build(name: str) -> WorkloadGraph:
    try:
        g = SUITE_BUILDERS[name]()
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; have {workload_names()}")
    g.validate()
    return g


def suite() -> Dict[str, WorkloadGraph]:
    return {name: build(name) for name in SUITE_BUILDERS}
