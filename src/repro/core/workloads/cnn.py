"""CNN-family workloads: ResNet-50 (INT8) and SNN-VGG9 (paper Table 1)."""
from __future__ import annotations

from ..ir import OpNode, OpType, Precision, WorkloadGraph

__all__ = ["resnet50", "snn_vgg9"]

# (blocks, mid_channels, out_channels, spatial) per ResNet-50 stage
_R50_STAGES = (
    (3, 64, 256, 56),
    (4, 128, 512, 28),
    (6, 256, 1024, 14),
    (3, 512, 2048, 7),
)


def _conv(g, name, hw, cin, cout, k, preds, sparsity=0.5, stride=1,
          prec=Precision.INT8):
    out_hw = hw // stride
    i = g.add(OpNode(name, OpType.CONV2D, m=out_hw * out_hw, k=cin * k * k,
                     n=cout, precision=prec, act_sparsity=sparsity), preds)
    return i


def resnet50() -> WorkloadGraph:
    """ResNet-50, INT8 post-training quantized (the paper's headline
    per-workload DSE winner, +60.10 %).  BN folds into the convolutions at
    inference; residual adds and ReLUs are explicit DSP ops."""
    g = WorkloadGraph("resnet50_int8", model_precision=Precision.INT8,
                      family="cnn")
    c = _conv(g, "conv1", 224, 3, 64, 7, (), sparsity=0.0, stride=2)
    r = g.dsp("relu1", OpType.RELU, elems=112 * 112 * 64, preds=[c])
    p = g.dsp("maxpool", OpType.POOL, elems=56 * 56 * 64, preds=[r])
    x, cin = p, 64
    for s, (blocks, mid, cout, hw) in enumerate(_R50_STAGES):
        for b in range(blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            pre = f"s{s}b{b}"
            c1 = _conv(g, f"{pre}_conv1", hw * stride, cin, mid, 1, [x],
                       stride=stride)
            r1 = g.dsp(f"{pre}_relu1", OpType.RELU, elems=hw * hw * mid, preds=[c1])
            c2 = _conv(g, f"{pre}_conv2", hw, mid, mid, 3, [r1])
            r2 = g.dsp(f"{pre}_relu2", OpType.RELU, elems=hw * hw * mid, preds=[c2])
            c3 = _conv(g, f"{pre}_conv3", hw, mid, cout, 1, [r2])
            if b == 0:
                sc = _conv(g, f"{pre}_downsample", hw * stride, cin, cout, 1,
                           [x], stride=stride)
                a = g.dsp(f"{pre}_add", OpType.ADD, elems=hw * hw * cout,
                          preds=[c3, sc])
            else:
                a = g.dsp(f"{pre}_add", OpType.ADD, elems=hw * hw * cout,
                          preds=[c3, x])
            x = g.dsp(f"{pre}_relu3", OpType.RELU, elems=hw * hw * cout, preds=[a])
            cin = cout
    gp = g.dsp("avgpool", OpType.POOL, elems=7 * 7 * 2048, preds=[x])
    fc = g.add(OpNode("classifier_fc", OpType.FC, m=1, k=2048, n=1000,
                      precision=Precision.INT8), [gp])
    g.dsp("softmax", OpType.SOFTMAX, elems=1000, preds=[fc])
    return g


_VGG9 = (  # (cin, cout, hw) conv stack for the SNN-VGG9 of the SNN literature
    (3, 64, 32), (64, 64, 32),
    (64, 128, 16), (128, 128, 16),
    (128, 256, 8), (256, 256, 8), (256, 256, 8),
)


def snn_vgg9(timesteps: int = 4) -> WorkloadGraph:
    """Spiking VGG9: each conv integrates over T timesteps and feeds a
    leaky-integrate-and-fire (LIF) layer.  ~47 % of wall time is LIF
    integration on commercial NPUs (paper Fig. 3); spike trains are highly
    sparse (~90 % zeros) which two-sided-sparsity tiles exploit."""
    g = WorkloadGraph("snn_vgg9", model_precision=Precision.FP16, family="snn")
    x = None
    for li, (cin, cout, hw) in enumerate(_VGG9):
        preds = [x] if x is not None else ()
        c = g.add(OpNode(f"conv{li}", OpType.CONV2D, m=timesteps * hw * hw,
                         k=cin * 9, n=cout, precision=Precision.FP16,
                         act_sparsity=0.0 if li == 0 else 0.9), preds)
        x = g.add(OpNode(f"lif{li}", OpType.SNN_LIF, elems=hw * hw * cout,
                         snn_timesteps=timesteps, precision=Precision.FP16), [c])
    fc1 = g.add(OpNode("fc1", OpType.FC, m=timesteps, k=256 * 4 * 4, n=1024,
                       precision=Precision.FP16, act_sparsity=0.9), [x])
    l1 = g.add(OpNode("lif_fc1", OpType.SNN_LIF, elems=1024,
                      snn_timesteps=timesteps, precision=Precision.FP16), [fc1])
    fc2 = g.add(OpNode("classifier", OpType.FC, m=timesteps, k=1024, n=10,
                       precision=Precision.FP16, act_sparsity=0.9), [l1])
    g.dsp("rate_decode", OpType.REDUCE, elems=timesteps * 10, preds=[fc2])
    return g
