"""KAN and GNN-GAT workloads (paper Table 1)."""
from __future__ import annotations

from ..ir import OpNode, OpType, Precision, WorkloadGraph

__all__ = ["kan", "gnn_gat"]


def kan(widths=(784, 512, 512, 10), degree: int = 8) -> WorkloadGraph:
    """Kolmogorov-Arnold network: every edge evaluates a learnable
    polynomial basis — wall time is entirely polynomial evaluation on
    commercial NPUs (paper Fig. 3).  A Special-Function tile reduces each
    edge to a d-cycle Horner pipeline (paper §2.5)."""
    g = WorkloadGraph("kan", model_precision=Precision.FP16, family="kan")
    x = None
    for li, (w_in, w_out) in enumerate(zip(widths[:-1], widths[1:])):
        preds = [x] if x is not None else ()
        # per-edge basis evaluation: w_in*w_out polynomials of degree d
        p = g.add(OpNode(f"l{li}_edge_poly", OpType.POLY, elems=w_in * w_out,
                         poly_degree=degree, precision=Precision.FP16), preds)
        # node aggregation: sum over incoming edges
        x = g.dsp(f"l{li}_aggregate", OpType.REDUCE, elems=w_in * w_out,
                  preds=[p])
    g.dsp("softmax_out", OpType.SOFTMAX, elems=widths[-1], preds=[x])
    return g


def gnn_gat(nodes: int = 10000, edges: int = 100000, d: int = 256,
            layers: int = 3, heads: int = 4) -> WorkloadGraph:
    """Graph attention network: gather/scatter dominates (paper Fig. 3;
    MAC utilization < 10 % on commercial NPUs).  Feature transforms are
    INT8-compatible, which is why GNN-GAT clusters with the INT-quantized
    group in the taxonomy (§5.3)."""
    g = WorkloadGraph("gnn_gat", model_precision=Precision.INT8,
                      family="gnn")
    x = None
    for li in range(layers):
        preds = [x] if x is not None else ()
        w = g.add(OpNode(f"l{li}_feature_transform", OpType.MATMUL, m=nodes,
                         k=d, n=d, precision=Precision.INT8), preds)
        gth = g.dsp(f"l{li}_edge_gather", OpType.GATHER, elems=edges * d,
                    preds=[w])
        att = g.dsp(f"l{li}_edge_attention", OpType.MUL,
                    elems=edges * heads * 2, preds=[gth])
        sm = g.dsp(f"l{li}_edge_softmax", OpType.SOFTMAX, elems=edges * heads,
                   preds=[att])
        agg = g.dsp(f"l{li}_scatter_aggregate", OpType.SCATTER, elems=edges * d,
                    preds=[sm, gth])
        x = g.dsp(f"l{li}_relu", OpType.RELU, elems=nodes * d, preds=[agg])
    g.dsp("readout", OpType.REDUCE, elems=nodes * d, preds=[x])
    return g
