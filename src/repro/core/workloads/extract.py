"""Extraction: the 10 assigned JAX architectures -> MOSAIC workload DAGs.

The paper imports workloads from ONNX/PyTorch (§3.1); the JAX-native
equivalent walks a ``ModelConfig``'s layer pattern and emits the same
operator vocabulary the rest of MOSAIC consumes.  This closes the loop:
the models that train under pjit on the TPU mesh are also DSE inputs for
heterogeneous-NPU search (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Optional

from ...models.config import ModelConfig  # type: ignore
from ..ir import OpNode, OpType, Precision, WorkloadGraph

__all__ = ["extract_model"]


def _attn_ops(g, pre, x, s, d, heads, kv_heads, hd, prec, kv_len=None):
    kv_len = kv_len or s
    n1 = g.dsp(f"{pre}_norm", OpType.RMSNORM, elems=s * d, preds=[x])
    q = g.add(OpNode(f"{pre}_q_proj", OpType.MATMUL, m=s, k=d, n=heads * hd,
                     precision=prec), [n1])
    kk = g.add(OpNode(f"{pre}_k_proj", OpType.MATMUL, m=s, k=d,
                      n=kv_heads * hd, precision=prec), [n1])
    v = g.add(OpNode(f"{pre}_v_proj", OpType.MATMUL, m=s, k=d,
                     n=kv_heads * hd, precision=prec), [n1])
    r = g.dsp(f"{pre}_rope", OpType.ROPE, elems=s * heads * hd, preds=[q, kk])
    sc = g.add(OpNode(f"{pre}_scores", OpType.MATMUL, m=heads * s, k=hd,
                      n=kv_len, precision=Precision.FP16, splittable=False), [r, kk])
    sm = g.dsp(f"{pre}_softmax", OpType.SOFTMAX, elems=heads * s * kv_len,
               preds=[sc])
    av = g.add(OpNode(f"{pre}_attn_v", OpType.MATMUL, m=heads * s, k=kv_len,
                      n=hd, precision=Precision.FP16, splittable=False), [sm, v])
    o = g.add(OpNode(f"{pre}_o_proj", OpType.MATMUL, m=s, k=heads * hd, n=d,
                     precision=prec), [av])
    return g.dsp(f"{pre}_residual", OpType.ADD, elems=s * d, preds=[o, x])


def _mla_ops(g, pre, x, s, cfg, prec):
    d, h = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    n1 = g.dsp(f"{pre}_norm", OpType.RMSNORM, elems=s * d, preds=[x])
    q = g.add(OpNode(f"{pre}_q_proj", OpType.MATMUL, m=s, k=d,
                     n=h * (dn + dr), precision=prec), [n1])
    ck = g.add(OpNode(f"{pre}_kv_compress", OpType.MATMUL, m=s, k=d, n=r + dr,
                      precision=prec), [n1])
    uk = g.add(OpNode(f"{pre}_kv_decompress", OpType.MATMUL, m=s, k=r,
                      n=h * (dn + dv), precision=prec), [ck])
    sc = g.add(OpNode(f"{pre}_scores", OpType.MATMUL, m=h * s, k=dn + dr, n=s,
                      precision=Precision.FP16, splittable=False), [q, uk])
    sm = g.dsp(f"{pre}_softmax", OpType.SOFTMAX, elems=h * s * s, preds=[sc])
    av = g.add(OpNode(f"{pre}_attn_v", OpType.MATMUL, m=h * s, k=s, n=dv,
                      precision=Precision.FP16, splittable=False), [sm, uk])
    o = g.add(OpNode(f"{pre}_o_proj", OpType.MATMUL, m=s, k=h * dv, n=d,
                     precision=prec), [av])
    return g.dsp(f"{pre}_residual", OpType.ADD, elems=s * d, preds=[o, x])


def _mamba_ops(g, pre, x, s, cfg, prec):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    n1 = g.dsp(f"{pre}_norm", OpType.RMSNORM, elems=s * d, preds=[x])
    ip = g.add(OpNode(f"{pre}_in_proj", OpType.MATMUL, m=s, k=d,
                      n=2 * di + 2 * n + cfg.ssm_heads, precision=prec), [n1])
    cv = g.add(OpNode(f"{pre}_conv1d", OpType.CONV1D,
                      m=s * (di + 2 * n), k=cfg.ssm_conv_width, n=1,
                      precision=prec), [ip])
    sc = g.add(OpNode(f"{pre}_ssd_scan", OpType.SSM_SCAN,
                      elems=s * di * n, seq_len=s,
                      precision=Precision.FP16), [cv])
    gt = g.dsp(f"{pre}_gate_silu", OpType.SILU, elems=s * di, preds=[sc, ip])
    op = g.add(OpNode(f"{pre}_out_proj", OpType.MATMUL, m=s, k=di, n=d,
                      precision=prec), [gt])
    return g.dsp(f"{pre}_residual", OpType.ADD, elems=s * d, preds=[op, x])


def _ffn_ops(g, pre, x, s, cfg, kind, prec):
    d = cfg.d_model
    n2 = g.dsp(f"{pre}_norm2", OpType.RMSNORM, elems=s * d, preds=[x])
    if kind == "moe":
        e, k = cfg.n_experts, cfg.top_k
        f = cfg.moe_d_ff or cfg.d_ff
        router = g.add(OpNode(f"{pre}_router", OpType.FC, m=s, k=d, n=e,
                              precision=Precision.FP16), [n2])
        gate = g.dsp(f"{pre}_routing_softmax", OpType.SOFTMAX, elems=s * e,
                     preds=[router])
        disp = g.dsp(f"{pre}_dispatch", OpType.GATHER, elems=s * d,
                     preds=[gate, n2])
        tok = max(s * k // e, 1)
        outs = []
        for ei in range(min(e, 8)):  # representative expert slots
            up = g.add(OpNode(f"{pre}_e{ei}_gate_up", OpType.MATMUL,
                              m=tok * max(e // 8, 1), k=d, n=2 * f,
                              precision=prec), [disp])
            act = g.dsp(f"{pre}_e{ei}_silu", OpType.SILU,
                        elems=tok * max(e // 8, 1) * f, preds=[up])
            dn = g.add(OpNode(f"{pre}_e{ei}_down", OpType.MATMUL,
                              m=tok * max(e // 8, 1), k=f, n=d,
                              precision=prec), [act])
            outs.append(dn)
        comb = g.dsp(f"{pre}_combine", OpType.SCATTER, elems=s * k * d,
                     preds=outs[:3])
        last = comb
        if cfg.n_shared_experts:
            sh = g.add(OpNode(f"{pre}_shared_up", OpType.MATMUL, m=s, k=d,
                              n=2 * f * cfg.n_shared_experts, precision=prec), [n2])
            last = g.add(OpNode(f"{pre}_shared_down", OpType.MATMUL, m=s,
                                k=f * cfg.n_shared_experts, n=d,
                                precision=prec), [sh])
        return g.dsp(f"{pre}_residual2", OpType.ADD, elems=s * d,
                     preds=[last, x])
    if kind == "none":
        return x
    gated = cfg.act == "silu"
    up = g.add(OpNode(f"{pre}_ffn_up", OpType.MATMUL, m=s, k=d,
                      n=(2 if gated else 1) * cfg.d_ff, precision=prec), [n2])
    act = g.dsp(f"{pre}_act", OpType.SILU if gated else OpType.GELU,
                elems=s * cfg.d_ff, preds=[up])
    dn = g.add(OpNode(f"{pre}_ffn_down", OpType.MATMUL, m=s, k=cfg.d_ff, n=d,
                      precision=prec), [act])
    return g.dsp(f"{pre}_residual2", OpType.ADD, elems=s * d, preds=[dn, x])


def extract_model(cfg: ModelConfig, seq_len: int = 512,
                  precision: Precision = Precision.FP16) -> WorkloadGraph:
    """Emit the MOSAIC DAG of one single-batch inference pass of ``cfg``."""
    g = WorkloadGraph(f"{cfg.name}_s{seq_len}", model_precision=precision,
                      family=cfg.family)
    d, hd = cfg.d_model, cfg.head_dim
    x = g.dsp("embed_lookup", OpType.GATHER, elems=seq_len * d,
              precision=Precision.FP16)
    if cfg.encoder_layers:
        enc = g.dsp("audio_frontend_stub", OpType.GATHER,
                    elems=cfg.num_frontend_tokens * d)
        for li in range(cfg.encoder_layers):
            enc = _attn_ops(g, f"enc{li}", enc, cfg.num_frontend_tokens, d,
                            cfg.n_heads, cfg.n_kv_heads, hd, precision)
            enc = _ffn_ops(g, f"enc{li}", enc, cfg.num_frontend_tokens, cfg,
                           "dense", precision)
    layers = cfg.prefix_pattern() + cfg.pattern() * cfg.n_repeats
    for li, (mk, fk) in enumerate(layers):
        pre = f"l{li}"
        if mk == "mamba":
            x = _mamba_ops(g, pre, x, seq_len, cfg, precision)
        elif cfg.mla:
            x = _mla_ops(g, pre, x, seq_len, cfg, precision)
        elif mk == "cross_attn":
            x = _attn_ops(g, pre, x, seq_len, d, cfg.n_heads, cfg.n_kv_heads,
                          hd, precision, kv_len=cfg.num_frontend_tokens)
        else:
            x = _attn_ops(g, pre, x, seq_len, d, cfg.n_heads, cfg.n_kv_heads,
                          hd, precision)
        x = _ffn_ops(g, pre, x, seq_len, cfg, fk, precision)
    n = g.dsp("final_norm", OpType.RMSNORM, elems=seq_len * d, preds=[x])
    g.add(OpNode("lm_head", OpType.MATMUL, m=1, k=d, n=cfg.vocab,
                 precision=precision), [n])
    g.validate()
    return g
