"""Transformer-family workloads: ViT-B/16, LLaMA-7B, speculative decoding,
Mixtral, LLaVA, RT-2 and LAVISH (paper Table 1).

LLM workloads are prefill-style single-batch passes (S=256) — compute-bound,
past the roofline ridge, matching their Fig. 8 placement.  Speculative
decoding is the one bandwidth-bound workload (arithmetic intensity ~2.4):
a small-draft/large-verify step over a handful of tokens.
"""
from __future__ import annotations

from typing import Optional

from ..ir import OpNode, OpType, Precision, WorkloadGraph

__all__ = ["vit_b16", "llama7b", "spec_decode", "mixtral", "llava", "rt2",
           "lavish", "attention_block", "mlp_block"]


def attention_block(g: WorkloadGraph, pre: str, x: int, s: int, d: int,
                    heads: int, kv_heads: int, prec: Precision,
                    norm: OpType = OpType.LAYERNORM, rope: bool = False,
                    kv_len: Optional[int] = None, cross_from: Optional[int] = None) -> int:
    """Standard (self- or cross-) attention block; returns output op index.

    GQA: kv projections are sized by ``kv_heads``.  ``kv_len`` > s models
    decode against a KV cache; ``cross_from`` wires cross-attention."""
    hd = d // heads
    kv_len = kv_len or s
    n1 = g.dsp(f"{pre}_norm", norm, elems=s * d, preds=[x])
    q = g.add(OpNode(f"{pre}_q_proj", OpType.MATMUL, m=s, k=d, n=d, precision=prec), [n1])
    # K/V projections cover only the NEW tokens — the KV cache supplies the
    # history; kv_len enters the scores/AV dims below, not the projections.
    kv_src = cross_from if cross_from is not None else n1
    kv_new = kv_len if cross_from is not None else s
    kproj = g.add(OpNode(f"{pre}_k_proj", OpType.MATMUL, m=kv_new,
                         k=d, n=kv_heads * hd, precision=prec), [kv_src])
    vproj = g.add(OpNode(f"{pre}_v_proj", OpType.MATMUL, m=kv_new,
                         k=d, n=kv_heads * hd, precision=prec), [kv_src])
    if rope:
        q = g.dsp(f"{pre}_rope_q", OpType.ROPE, elems=s * d, preds=[q])
        kproj = g.dsp(f"{pre}_rope_k", OpType.ROPE, elems=kv_new * kv_heads * hd,
                      preds=[kproj])
    # scores: (heads*s) x hd x kv_len — attention math stays >= FP16
    sc = g.add(OpNode(f"{pre}_scores", OpType.MATMUL, m=heads * s, k=hd,
                      n=kv_len, precision=max(prec, Precision.FP16),
                      splittable=False), [q, kproj])
    sm = g.dsp(f"{pre}_softmax", OpType.SOFTMAX, elems=heads * s * kv_len, preds=[sc])
    av = g.add(OpNode(f"{pre}_attn_v", OpType.MATMUL, m=heads * s, k=kv_len,
                      n=hd, precision=max(prec, Precision.FP16),
                      splittable=False), [sm, vproj])
    o = g.add(OpNode(f"{pre}_o_proj", OpType.MATMUL, m=s, k=d, n=d, precision=prec), [av])
    return g.dsp(f"{pre}_residual", OpType.ADD, elems=s * d, preds=[o, x])


def mlp_block(g: WorkloadGraph, pre: str, x: int, s: int, d: int, d_ff: int,
              prec: Precision, gated: bool = True,
              norm: OpType = OpType.LAYERNORM) -> int:
    n2 = g.dsp(f"{pre}_norm2", norm, elems=s * d, preds=[x])
    if gated:
        up = g.add(OpNode(f"{pre}_gate_up", OpType.MATMUL, m=s, k=d,
                          n=2 * d_ff, precision=prec), [n2])
        act = g.dsp(f"{pre}_silu", OpType.SILU, elems=s * d_ff, preds=[up])
        h = g.dsp(f"{pre}_gate_mul", OpType.MUL, elems=s * d_ff, preds=[act])
    else:
        up = g.add(OpNode(f"{pre}_fc1", OpType.MATMUL, m=s, k=d, n=d_ff,
                          precision=prec), [n2])
        h = g.dsp(f"{pre}_gelu", OpType.GELU, elems=s * d_ff, preds=[up])
    down = g.add(OpNode(f"{pre}_fc2", OpType.MATMUL, m=s, k=d_ff, n=d,
                        precision=prec), [h])
    return g.dsp(f"{pre}_residual2", OpType.ADD, elems=s * d, preds=[down, x])


def _decoder_stack(g: WorkloadGraph, x: int, layers: int, s: int, d: int,
                   heads: int, kv_heads: int, d_ff: int, prec: Precision,
                   kv_len: Optional[int] = None, gated: bool = True) -> int:
    for li in range(layers):
        x = attention_block(g, f"l{li}", x, s, d, heads, kv_heads, prec,
                            norm=OpType.RMSNORM, rope=True, kv_len=kv_len)
        x = mlp_block(g, f"l{li}", x, s, d, d_ff, prec, gated=gated,
                      norm=OpType.RMSNORM)
    return x


def vit_b16(precision: Precision = Precision.FP16) -> WorkloadGraph:
    """ViT-B/16, 224x224 single image: 197 tokens, 12 blocks, d=768."""
    g = WorkloadGraph(f"vit_b16_{precision.name.lower()}",
                      model_precision=precision, family="vit")
    s, d, h, dff = 197, 768, 12, 3072
    x = g.add(OpNode("patch_embed", OpType.CONV2D, m=196, k=3 * 16 * 16, n=d,
                     precision=precision))
    for li in range(12):
        x = attention_block(g, f"b{li}", x, s, d, h, h, precision)
        x = mlp_block(g, f"b{li}", x, s, d, dff, precision, gated=False)
    n = g.dsp("final_norm", OpType.LAYERNORM, elems=s * d, preds=[x])
    c = g.add(OpNode("classifier", OpType.FC, m=1, k=d, n=1000,
                     precision=precision), [n])
    g.dsp("softmax_out", OpType.SOFTMAX, elems=1000, preds=[c])
    return g


def llama7b(precision: Precision = Precision.FP16, s: int = 256) -> WorkloadGraph:
    """LLaMA-7B prefill: 32 layers, d=4096, MHA-32, d_ff=11008."""
    g = WorkloadGraph(f"llama7b_{precision.name.lower()}",
                      model_precision=precision, family="llm")
    x = g.dsp("embed_lookup", OpType.GATHER, elems=s * 4096,
              precision=Precision.FP16)
    x = _decoder_stack(g, x, 32, s, 4096, 32, 32, 11008, precision)
    n = g.dsp("final_norm", OpType.RMSNORM, elems=s * 4096, preds=[x])
    g.add(OpNode("lm_head", OpType.MATMUL, m=1, k=4096, n=32000,
                 precision=precision), [n])
    return g


def spec_decode() -> WorkloadGraph:
    """Speculative decoding (paper: arithmetic intensity 2.4, the single
    bandwidth-bound workload): a 16-layer draft decodes 4 tokens one at a
    time, then the 7B target verifies all 5 in one pass."""
    g = WorkloadGraph("spec_decode", model_precision=Precision.FP16,
                      family="llm")
    x = g.dsp("embed_lookup", OpType.GATHER, elems=2048, precision=Precision.FP16)
    # draft: 4 sequential single-token decodes against a 256-token KV cache
    for t in range(4):
        x = _decoder_stack(g, x, 4, 1, 2048, 16, 16, 5504, Precision.FP16,
                           kv_len=256 + t)
    # target verify: 5 tokens in parallel through the 7B stack
    v = g.dsp("verify_embed", OpType.GATHER, elems=5 * 4096,
              precision=Precision.FP16, preds=[x])
    v = _decoder_stack(g, v, 32, 5, 4096, 32, 32, 11008, Precision.FP16,
                       kv_len=261)
    n = g.dsp("final_norm", OpType.RMSNORM, elems=5 * 4096, preds=[v])
    hd = g.add(OpNode("lm_head", OpType.MATMUL, m=5, k=4096, n=32000,
                      precision=Precision.FP16), [n])
    g.dsp("accept_reject", OpType.REDUCE, elems=5 * 32000, preds=[hd])
    return g


def mixtral(precision: Precision = Precision.FP16, s: int = 256) -> WorkloadGraph:
    """Mixtral 8x7B: GQA(32q/8kv), 8 experts top-2, d=4096, d_ff=14336."""
    g = WorkloadGraph(f"mixtral_{precision.name.lower()}",
                      model_precision=precision, family="moe")
    d, dff, n_exp, topk = 4096, 14336, 8, 2
    x = g.dsp("embed_lookup", OpType.GATHER, elems=s * d, precision=Precision.FP16)
    for li in range(32):
        x = attention_block(g, f"l{li}", x, s, d, 32, 8, precision,
                            norm=OpType.RMSNORM, rope=True)
        n2 = g.dsp(f"l{li}_norm2", OpType.RMSNORM, elems=s * d, preds=[x])
        router = g.add(OpNode(f"l{li}_router", OpType.FC, m=s, k=d, n=n_exp,
                              precision=Precision.FP16), [n2])
        gate = g.dsp(f"l{li}_routing_softmax", OpType.SOFTMAX, elems=s * n_exp,
                     preds=[router])
        disp = g.dsp(f"l{li}_dispatch", OpType.GATHER, elems=s * d, preds=[gate, n2])
        outs = []
        tok_per_exp = max(s * topk // n_exp, 1)
        for e in range(n_exp):
            up = g.add(OpNode(f"l{li}_e{e}_gate_up", OpType.MATMUL,
                              m=tok_per_exp, k=d, n=2 * dff, precision=precision), [disp])
            act = g.dsp(f"l{li}_e{e}_silu", OpType.SILU, elems=tok_per_exp * dff,
                        preds=[up])
            dn = g.add(OpNode(f"l{li}_e{e}_down", OpType.MATMUL, m=tok_per_exp,
                              k=dff, n=d, precision=precision), [act])
            outs.append(dn)
        comb = g.dsp(f"l{li}_combine", OpType.SCATTER, elems=s * topk * d,
                     preds=outs[:3])
        x = g.dsp(f"l{li}_residual2", OpType.ADD, elems=s * d, preds=[comb, x])
    n = g.dsp("final_norm", OpType.RMSNORM, elems=s * d, preds=[x])
    g.add(OpNode("lm_head", OpType.MATMUL, m=1, k=d, n=32000,
                 precision=precision), [n])
    return g


def llava(s_llm: int = 608) -> WorkloadGraph:
    """LLaVA: ViT-L/14 vision tower (24 blocks, 577 tokens) + projector +
    LLaMA-7B prefill over image+text tokens."""
    g = WorkloadGraph("llava", model_precision=Precision.FP16,
                      family="multimodal")
    sv, dv = 577, 1024
    x = g.add(OpNode("vision_patch_embed", OpType.CONV2D, m=576, k=3 * 14 * 14,
                     n=dv, precision=Precision.FP16))
    for li in range(24):
        x = attention_block(g, f"vis{li}", x, sv, dv, 16, 16, Precision.FP16)
        x = mlp_block(g, f"vis{li}", x, sv, dv, 4096, Precision.FP16, gated=False)
    p = g.add(OpNode("mm_projector", OpType.MATMUL, m=sv, k=dv, n=4096,
                     precision=Precision.FP16), [x])
    t = _decoder_stack(g, p, 32, s_llm, 4096, 32, 32, 11008, Precision.FP16)
    n = g.dsp("final_norm", OpType.RMSNORM, elems=s_llm * 4096, preds=[t])
    g.add(OpNode("lm_head", OpType.MATMUL, m=1, k=4096, n=32000,
                 precision=Precision.FP16), [n])
    return g


def rt2() -> WorkloadGraph:
    """RT-2 vision-language-action: ViT backbone + LLM + action
    de-tokenization (gather/scatter + polynomial trajectory smoothing) —
    the multimodal operator mix NVDLA cannot execute (paper §5.1.4)."""
    g = WorkloadGraph("rt2", model_precision=Precision.FP16,
                      family="multimodal")
    sv, dv = 256, 1024
    x = g.add(OpNode("vision_patch_embed", OpType.CONV2D, m=sv, k=3 * 16 * 16,
                     n=dv, precision=Precision.FP16))
    for li in range(12):
        x = attention_block(g, f"vis{li}", x, sv, dv, 16, 16, Precision.FP16)
        x = mlp_block(g, f"vis{li}", x, sv, dv, 4096, Precision.FP16, gated=False)
    t = _decoder_stack(g, x, 20, 288, 2048, 16, 16, 8192, Precision.FP16)
    act = g.dsp("action_gather", OpType.GATHER, elems=8 * 256, preds=[t])
    sm = g.dsp("action_softmax", OpType.SOFTMAX, elems=8 * 256, preds=[act])
    po = g.add(OpNode("trajectory_poly", OpType.POLY, elems=8 * 64,
                      poly_degree=5, precision=Precision.FP16), [sm])
    g.dsp("action_scatter", OpType.SCATTER, elems=8 * 64, preds=[po])
    return g


def lavish(timesteps_fft: int = 1) -> WorkloadGraph:
    """LAVISH audio-visual transformer: audio spectrogram FFT frontend,
    dual ViT-B streams with cross-modal adapters."""
    g = WorkloadGraph("lavish", model_precision=Precision.FP16,
                      family="multimodal")
    # audio frontend: 1 s of 16 kHz audio -> STFT frames (n_fft=512)
    fft = g.add(OpNode("audio_stft", OpType.FFT, elems=128 * 512, fft_n=512,
                       precision=Precision.FP16))
    a = g.add(OpNode("audio_patch_embed", OpType.CONV2D, m=128, k=512, n=768,
                     precision=Precision.FP16), [fft])
    v = g.add(OpNode("visual_patch_embed", OpType.CONV2D, m=196,
                     k=3 * 16 * 16, n=768, precision=Precision.FP16))
    for li in range(12):
        a = attention_block(g, f"aud{li}", a, 128, 768, 12, 12, Precision.FP16)
        v = attention_block(g, f"vis{li}", v, 197, 768, 12, 12, Precision.FP16)
        # LAVISH adapter: cross-modal token exchange with a sigmoid gate
        xa = attention_block(g, f"xmod{li}", v, 197, 768, 12, 12,
                             Precision.FP16, cross_from=a)
        xa = g.dsp(f"xmod{li}_gate_sigmoid", OpType.SIGMOID, elems=197 * 768,
                   preds=[xa])
        a = mlp_block(g, f"aud{li}", a, 128, 768, 3072, Precision.FP16, gated=False)
        v = mlp_block(g, f"vis{li}", xa, 197, 768, 3072, Precision.FP16, gated=False)
    fuse = g.dsp("av_fuse", OpType.ADD, elems=197 * 768, preds=[a, v])
    c = g.add(OpNode("classifier", OpType.FC, m=1, k=768, n=309,
                     precision=Precision.FP16), [fuse])
    g.dsp("softmax_out", OpType.SOFTMAX, elems=309, preds=[c])
    return g
