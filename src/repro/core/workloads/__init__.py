"""20-workload suite (paper Table 1, §4.1): 14 base models in ten
architectural families plus six post-training-quantized INT4/INT8 variants
of the transformer LLMs.

Workloads are expressed as parametric DAG builders (the offline stand-in
for the paper's ONNX/PyTorch importers) plus ``extract``, which converts
the 10 assigned JAX architectures of ``repro.models`` into the same IR.
"""
from .suite import SUITE_BUILDERS, build, suite, workload_names

__all__ = ["SUITE_BUILDERS", "build", "suite", "workload_names"]
