"""Chip-level orchestrator (paper §3.3.4): executes a compiled plan over a
heterogeneous tile mix with

* dynamic DRAM bandwidth sharing — only tiles whose previous operator has
  not finished count as active; per-tile bandwidth is BW_total / N_active;
* cross-tile activation caching — each tile's SRAM splits into a working
  set and a FIFO-evicted activation cache (byte- and slot-bounded, see
  ``costs.ActivationCache``); consumers see a local hit (no DRAM read), a
  cross-tile NoC DMA, or a full DRAM miss;
* clock gating (idle modules draw no dynamic energy — implicit in the
  per-module accounting) and power gating (tiles with no scheduled work
  leak at a 5 % residual);
* NoC transfer costs and split-op reductions (Eq. 3).

This is the *reference oracle*: the batched backend
(``simulator.batched``) re-expresses this per-operator loop as jittable
array ops over an SoA plan table and is pinned to it by golden traces and
the property-based parity suite.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..arch import ChipConfig, Interconnect, TileTemplate
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import OpClass, OpNode, WorkloadGraph, slice_op
from .area import chip_area, tile_area
from .costs import (ACT_CACHE_SLOTS, CACHE_FRAC, FIDELITIES,
                    MAX_DRAM_CHANNELS, MAX_LINKS, OP_COST_KEYS,
                    TILE_COST_KEYS, ActivationCache, cost_model,
                    dram_channel_one_hot, grid_dims,
                    noc_transfer_energy_pj, noc_transfer_seconds,
                    pipeline_bounds, steady_state_energy,
                    xy_route_link_mask)
from .modules import tile_cost_dict
from .outputs import EnergyBreakdown, OpResult, SimResult, TileBreakdown
from .tile import _PATH_NAME, _ROOFLINE_NAME, OpExec, TileSim, op_cost_dict

__all__ = ["Placement", "ExecutionPlan", "ChipSim", "simulate", "noc_hops",
           "CACHE_FRAC", "SCHEDULE_MODES"]

# The two §3.2 execution modes (re-exported by compiler.schedule, which
# owns the user-facing docs).  Lives here so the simulators can validate
# plans without importing the compiler package (schedule imports us).
SCHEDULE_MODES = ("latency", "throughput")


@dataclasses.dataclass
class Placement:
    tiles: List[int]
    axis: str = ""  # 'OC' | 'B' | 'IC' when split across len(tiles) > 1


@dataclasses.dataclass
class ExecutionPlan:
    """Compiler output: graph after passes 1-2 plus pass-3 placements."""

    graph: WorkloadGraph
    placements: Dict[int, Placement]
    mode: str = "latency"


def noc_hops(interconnect: Interconnect, num_tiles: int) -> int:
    """Average hop count by interconnect topology."""
    if interconnect == Interconnect.BUS:
        return 1
    if interconnect == Interconnect.RING:
        return max(num_tiles // 4, 1)
    if interconnect == Interconnect.NOC:
        return 2
    return max(int(math.ceil(math.sqrt(num_tiles))), 1)  # mesh


class ChipSim:
    """Event-free single-pass orchestrator.

    Ops are visited in topological order (the schedule emitted by compiler
    pass 4 preserves this); per-tile finish times provide the parallelism
    model: distinct-tile assignments overlap, same-tile ops serialize.
    """

    def __init__(self, chip: ChipConfig, calib: CalibrationTable = DEFAULT_CALIB,
                 fidelity: str = "aggregate"):
        if fidelity not in FIDELITIES:
            raise ValueError(
                f"unknown fidelity {fidelity!r}; supported: {FIDELITIES}")
        self.chip = chip
        self.calib = calib
        self.fidelity = fidelity
        self.templates = chip.instances()
        self.tiles = [TileSim(t, calib, CACHE_FRAC) for t in self.templates]
        self.hops = noc_hops(chip.interconnect, len(self.tiles))
        self.ref_clock_hz = chip.ref_clock_mhz * 1e6
        # link-fidelity topology: row-major tile grid + per-tile DRAM
        # channel interleave (precomputed — the walk only gathers)
        n = len(self.tiles)
        gw, gh = grid_dims(np, float(n), chip.grid_aspect)
        self.grid_w, self.grid_h = float(gw), float(gh)
        tidx = np.arange(n, dtype=np.float64)
        self._link_mask = xy_route_link_mask(
            np, tidx[:, None], tidx[None, :], self.grid_w, self.grid_h,
            float(chip.torus))  # (src, dst, MAX_LINKS)
        self._chan_onehot = dram_channel_one_hot(
            np, tidx, float(chip.dram_channels))  # (tile, MAX_DRAM_CHANNELS)
        # (n_tiles,) tile-field arrays for the vectorized static-cost
        # pre-pass (one CostModel query per plan instead of one scalar
        # query per op — the per-op walk only runs the DRAM combine)
        self._cm = cost_model(calib)
        dicts = [tile_cost_dict(t) for t in self.templates]
        self._T = {k: np.asarray([d[k] for d in dicts], np.float64)
                   for k in TILE_COST_KEYS}

    # ------------------------------------------------- vectorized static costs
    def _static_pass(self, plan: ExecutionPlan) -> Tuple[Dict[int, int], dict]:
        """Evaluate ``CostModel.execute_static`` for every (op, tile)
        execution of the plan in one vectorized call.

        Returns ``(rec_of, static)``: ``rec_of[i]`` is the first record
        index of op ``i`` (single placements own one record; a k-way split
        owns k consecutive records, one per placement tile in order), and
        ``static`` the dict of per-record arrays.  Values are bitwise
        identical to per-op scalar ``TileSim.execute`` internals — only
        the numpy dispatch overhead is amortized.
        """
        g = plan.graph
        rec_tiles: List[int] = []
        rec_ops: List[Dict[str, float]] = []
        rec_of: Dict[int, int] = {}
        for i, op in enumerate(g.nodes):
            if op.fused_into >= 0:
                continue
            pl = plan.placements[i]
            rec_of[i] = len(rec_tiles)
            if len(pl.tiles) == 1:
                rec_tiles.append(pl.tiles[0])
                rec_ops.append(op_cost_dict(op))
            else:
                sd = op_cost_dict(slice_op(op, pl.axis, len(pl.tiles)))
                for t in pl.tiles:
                    rec_tiles.append(t)
                    rec_ops.append(sd)
        if not rec_tiles:
            return rec_of, {}
        tsel = np.asarray(rec_tiles, np.int64)
        T_rec = {k: self._T[k][tsel] for k in TILE_COST_KEYS}
        op_rec = {k: np.asarray([d[k] for d in rec_ops], np.float64)
                  for k in OP_COST_KEYS}
        static = self._cm.execute_static(T_rec, op_rec, CACHE_FRAC)
        static["clock_hz"] = T_rec["clock_hz"]
        static["double_buffer"] = T_rec["double_buffer"]
        return rec_of, static

    def _exec_rec(self, static: dict, r: int, bw_gbps: float,
                  dram_rd: float, dram_wr: float) -> OpExec:
        """Scalar DRAM/Eq. 5 combine on pre-computed static record ``r``
        (the fast-path twin of ``TileSim.execute``)."""
        st = {k: static[k][r] for k in ("c_cmp", "c_mem", "e_compute",
                                        "e_dsp", "e_special", "e_sram",
                                        "e_irf", "e_orf", "e_static",
                                        "path")}
        T_row = {"clock_hz": static["clock_hz"][r],
                 "double_buffer": static["double_buffer"][r]}
        out = self._cm.execute_dynamic(st, T_row, float(bw_gbps),
                                       float(dram_rd), float(dram_wr))
        e = EnergyBreakdown(
            compute=float(out["e_compute"]),
            dram=float(out["e_dram"]),
            sram=float(out["e_sram"]),
            irf=float(out["e_irf"]),
            orf=float(out["e_orf"]),
            dsp=float(out["e_dsp"]),
            special=float(out["e_special"]),
        )
        return OpExec(cycles=float(out["cycles"]),
                      seconds=float(out["seconds"]), energy=e,
                      path=_PATH_NAME[int(out["path"])],
                      roofline=_ROOFLINE_NAME[int(out["roofline"])],
                      dram_rd=dram_rd, dram_wr=dram_wr,
                      dram_bytes=float(out["dram_bytes"]))

    # -------------------------------------------------------------- helpers
    def noc_seconds(self, bytes_: float) -> float:
        return float(noc_transfer_seconds(
            math, bytes_, self.chip.noc_bytes_per_cycle, self.hops,
            self.chip.noc_base_cycles, self.ref_clock_hz))

    def noc_energy_pj(self, bytes_: float) -> float:
        return float(noc_transfer_energy_pj(
            math, bytes_, self.calib.e_noc_pj_per_byte_hop, self.hops))

    def link_seconds(self, bytes_: float) -> float:
        """Store-and-forward occupancy of ONE grid link by a transfer of
        ``bytes_`` (hop count is per-link by construction)."""
        return float(noc_transfer_seconds(
            math, bytes_, self.chip.noc_bytes_per_cycle, 1.0,
            self.chip.noc_base_cycles, self.ref_clock_hz))

    # ------------------------------------------------------------------ run
    def run(self, plan: ExecutionPlan) -> SimResult:
        if plan.mode not in SCHEDULE_MODES:
            raise ValueError(
                f"ChipSim cannot model schedule mode {plan.mode!r}; "
                f"supported modes: {SCHEDULE_MODES}")
        g = plan.graph
        n_tiles = len(self.tiles)
        # one batched CostModel query for the whole plan (tile/op-only
        # costs); the walk below only runs the per-op DRAM combine
        rec_of, static = self._static_pass(plan)
        tile_finish = [0.0] * n_tiles
        op_finish: Dict[int, float] = {}
        op_tile: Dict[int, int] = {}
        # Activation cache (§3.3.4): each tile's cache partition is a FIFO
        # bounded in bytes (CACHE_FRAC of SRAM) and entries
        # (ACT_CACHE_SLOTS); inserting a new output evicts oldest-first
        # until it fits, and outputs larger than the partition spill.
        # Eviction re-writes are not charged (uniform-optimism
        # simplification shared with the batched backends).
        cache_cap = [t.sram_kb * 1024.0 * CACHE_FRAC for t in self.templates]
        caches = [ActivationCache(i, cap) for i, cap in enumerate(cache_cap)]
        cached_at: Dict[int, int] = {}  # op idx -> tile holding its output

        breakdowns = [TileBreakdown(i, self.templates[i].name) for i in range(n_tiles)]
        op_results: List[OpResult] = []
        chip_energy = EnergyBreakdown()
        total_macs = 0.0
        # per-batch shared-resource occupancy (throughput-mode II inputs):
        # burst-aligned DRAM bytes and NoC transfer seconds of one batch
        dram_bytes_total = 0.0
        noc_busy_s = 0.0
        # link-fidelity occupancy vectors: per-link XY-routed NoC seconds
        # and per-channel (tile-interleaved) DRAM bytes of one batch
        link = self.fidelity == "link"
        link_occ = np.zeros(MAX_LINKS, np.float64)
        chan_occ = np.zeros(MAX_DRAM_CHANNELS, np.float64)

        fused_map: Dict[int, List[int]] = {}
        for j, nd in enumerate(g.nodes):
            if nd.fused_into >= 0:
                fused_map.setdefault(nd.fused_into, []).append(j)

        def cache_insert(tidx: int, op_idx: int, nbytes: float) -> None:
            caches[tidx].insert(op_idx, nbytes, cached_at)

        for i, op in enumerate(g.nodes):
            if op.fused_into >= 0:
                # folded into the head's PPM: its vector energy rides along,
                # the SRAM round-trip is refunded via E_fuse (Eq. 6)
                continue
            pl = plan.placements[i]
            total_macs += op.macs

            # --- dependency-ready time + input acquisition -----------------
            t_dep = 0.0
            extra_noc_s = 0.0
            dram_rd = float(op.bytes_w)  # weights always stream from DRAM
            per_pred = op.bytes_in / max(len(op.preds), 1)
            cache_kind = "miss"
            tidx0 = pl.tiles[0]
            for p in op.preds:
                t_dep = max(t_dep, op_finish.get(p, 0.0))
                src = cached_at.get(p, -1)
                if src == -1:
                    dram_rd += per_pred            # miss: full DRAM load
                elif src == tidx0:
                    cache_kind = "hit"             # local hit: free
                else:
                    cache_kind = "noc"             # cross-tile DMA
                    extra_noc_s += self.noc_seconds(per_pred)
                    chip_energy.noc += self.noc_energy_pj(per_pred)
                    if link:
                        link_occ = link_occ + self._link_mask[src, tidx0] \
                            * self.link_seconds(per_pred)
            if not op.preds:
                dram_rd += float(op.bytes_in)      # graph input

            # write-back: outputs that fit the producer's activation cache
            # skip the DRAM round-trip entirely (§3.3.4); oversized outputs
            # spill.  Eviction re-writes are not charged (uniform-optimism
            # simplification shared with the batch evaluator — DESIGN.md).
            dram_wr = float(op.bytes_out) if op.bytes_out > cache_cap[tidx0] \
                else 0.0

            # --- dynamic DRAM bandwidth share ------------------------------
            t_start0 = max(tile_finish[tidx0], t_dep)
            n_active = sum(1 for f in tile_finish if f > t_start0)
            n_active = max(n_active, 1)
            bw_share = self.chip.dram_gbps / n_active

            noc_busy_s += extra_noc_s
            if len(pl.tiles) == 1:
                ex = self._exec_rec(static, rec_of[i], bw_share, dram_rd,
                                    dram_wr)
                t_start = t_start0 + extra_noc_s
                t_fin = t_start + ex.seconds
                tile_finish[tidx0] = t_fin
                dram_bytes_total += ex.dram_bytes
                if link:
                    chan_occ = chan_occ + self._chan_onehot[tidx0] \
                        * ex.dram_bytes
                self._account(breakdowns[tidx0], op, ex, chip_energy)
                op_results.append(OpResult(i, tidx0, ex.path, t_start, t_fin,
                                           ex.cycles, ex.energy, ex.roofline,
                                           1, cache_kind))
            else:
                t_fin, split_dram_b, reduce_s, link_occ, chan_occ = \
                    self._run_split(
                        i, op, pl, tile_finish, t_dep, extra_noc_s, dram_rd,
                        dram_wr, bw_share, breakdowns, chip_energy,
                        op_results, cache_kind, static, rec_of[i],
                        link, link_occ, chan_occ)
                dram_bytes_total += split_dram_b
                noc_busy_s += reduce_s

            op_finish[i] = t_fin
            op_tile[i] = tidx0
            cache_insert(tidx0, i, float(op.bytes_out))

            # PPM energy for ops fused into this head + Eq. 6 refund
            for j in fused_map.get(i, ()):
                nd = g.nodes[j]
                lane_ops = nd.elems * 2.0
                pe = lane_ops * self.calib.e_dsp_pj_per_lane_op
                breakdowns[tidx0].energy.dsp += pe
                chip_energy.dsp += pe
                refund = 2.0 * nd.bytes_out * self.calib.e_sram_pj_per_byte
                breakdowns[tidx0].energy.fuse_savings += refund
                chip_energy.fuse_savings += refund

        makespan = max(tile_finish) if any(tile_finish) else 0.0

        # --- leakage: active tiles leak fully, idle tiles are power-gated ---
        leak_rate_pj_per_s = 0.0
        for b, tmpl in zip(breakdowns, self.templates):
            area = tile_area(tmpl, self.calib)
            gated = b.ops == 0
            resid = self.calib.power_gate_residual if gated else 1.0
            leak_pj = self.calib.leak_mw_per_mm2 * area * makespan * resid * 1e9
            leak_rate_pj_per_s += self.calib.leak_mw_per_mm2 * area * resid \
                * 1e9
            b.power_gated = gated
            b.energy.leakage += leak_pj
            chip_energy.leakage += leak_pj

        area = chip_area(self.chip, self.calib)
        peak_tops = sum(t.num_macs * t.clock_mhz * 1e6 for t in self.templates) / 1e12
        achieved = total_macs / makespan / 1e12 if makespan > 0 else 0.0
        pipeline = None
        if plan.mode == "throughput":
            pipeline = self._steady_state(
                makespan, breakdowns, dram_bytes_total, noc_busy_s,
                chip_energy, leak_rate_pj_per_s, total_macs,
                chan_occ if link else None, link_occ if link else None)
        return SimResult(
            workload=g.name, arch=self.chip.name, latency_s=makespan,
            energy_pj=chip_energy.total_pj, area_mm2=area, peak_tops=peak_tops,
            achieved_tops=achieved, energy_breakdown=chip_energy,
            tiles=breakdowns, ops=op_results, total_macs=total_macs,
            arithmetic_intensity=g.arithmetic_intensity(),
            mode=plan.mode, pipeline=pipeline)

    # ---------------------------------------------- throughput steady state
    def _steady_state(self, makespan, breakdowns, dram_bytes_total,
                      noc_busy_s, chip_energy, leak_rate_pj_per_s,
                      total_macs, chan_occ=None,
                      link_occ=None) -> Dict[str, float]:
        """Throughput-mode steady state (§3.2): replay successive batches
        with a per-batch offset of II — the bottleneck-resource occupancy
        from ``costs.pipeline_bounds``, the same composition the batched
        backends evaluate in-scan.  Reports the initiation interval, the
        pipeline-fill latency (= the one-batch makespan), the per-resource
        bounds, and the steady-state per-inference energy (leakage
        re-charged over II).  The link-fidelity tier passes its per-channel
        DRAM and per-link NoC occupancy vectors through to the II max."""
        tile_busy_max = max((b.active_s for b in breakdowns), default=0.0)
        pipe = {k: float(v) for k, v in pipeline_bounds(
            np, makespan, tile_busy_max, dram_bytes_total,
            self.chip.dram_gbps, noc_busy_s, chan_bytes=chan_occ,
            dram_channels=float(self.chip.dram_channels)
            if chan_occ is not None else None,
            link_busy_s=link_occ).items()}
        ii = pipe["ii_s"]
        pipe["fill_latency_s"] = makespan
        pipe["dram_bytes_per_batch"] = dram_bytes_total
        pipe["energy_ss_pj"] = float(steady_state_energy(
            chip_energy.total_pj, chip_energy.leakage, leak_rate_pj_per_s,
            ii))
        pipe["achieved_tops_ss"] = total_macs / ii / 1e12 if ii > 0 else 0.0
        # batches in flight once the pipeline is full (the replay depth
        # after which batch k's finish times advance by exactly II)
        pipe["pipeline_depth"] = float(math.ceil(makespan / ii)) \
            if ii > 0 else 1.0
        return pipe

    # ----------------------------------------------------------- split path
    def _run_split(self, i, op, pl, tile_finish, t_dep, extra_noc_s,
                   dram_rd, dram_wr, bw_share, breakdowns, chip_energy,
                   op_results, cache_kind, static, rec0, link, link_occ,
                   chan_occ):
        """Even split along OC / B / IC with explicit reduce cost (Eq. 3).
        Returns ``(t_fin, dram_bytes, reduce_s, link_occ, chan_occ)`` —
        the finish time plus the split's aligned DRAM traffic and NoC
        reduce occupancy for the throughput-mode resource accounting
        (per-channel/per-link vectors updated on the link-fidelity tier)."""
        k = len(pl.tiles)
        finishes = []
        slice_out = op.bytes_out / k
        sub = slice_op(op, pl.axis, k)
        dram_bytes = 0.0
        for j, tidx in enumerate(pl.tiles):
            ex = self._exec_rec(static, rec0 + j, bw_share, dram_rd / k,
                                dram_wr / k)
            t_start = max(tile_finish[tidx], t_dep) + extra_noc_s
            t_fin = t_start + ex.seconds
            tile_finish[tidx] = t_fin
            finishes.append(t_fin)
            dram_bytes += ex.dram_bytes
            if link:
                chan_occ = chan_occ + self._chan_onehot[tidx] * ex.dram_bytes
            self._account(breakdowns[tidx], sub, ex, chip_energy)
            op_results.append(OpResult(i, tidx, ex.path, t_start, t_fin,
                                       ex.cycles, ex.energy, ex.roofline,
                                       k, cache_kind))
        # Eq. 3: C_reduce = max_i( ceil(B_out_i / B_NoC) + Delta_NoC )
        reduce_s = self.noc_seconds(slice_out)
        for tidx in pl.tiles[1:]:
            chip_energy.noc += self.noc_energy_pj(slice_out)
            if link:
                link_occ = link_occ + self._link_mask[tidx, pl.tiles[0]] \
                    * self.link_seconds(slice_out)
        t_fin = max(finishes) + reduce_s
        tile_finish[pl.tiles[0]] = max(tile_finish[pl.tiles[0]], t_fin)
        return t_fin, dram_bytes, reduce_s, link_occ, chan_occ

    @staticmethod
    def _account(b: TileBreakdown, op: OpNode, ex, chip_energy: EnergyBreakdown) -> None:
        b.ops += 1
        b.macs += op.macs
        b.active_s += ex.seconds
        b.energy.add(ex.energy)
        chip_energy.add(ex.energy)


def simulate(chip: ChipConfig, plan: ExecutionPlan,
             calib: CalibrationTable = DEFAULT_CALIB,
             fidelity: str = "aggregate") -> SimResult:
    return ChipSim(chip, calib, fidelity).run(plan)
