"""Backend-neutral cost formulas shared by every simulator implementation.

One set of calibrated per-module models (paper §3.3.1, Eqs. 2/4-6) written
against an array namespace ``xp`` so the same code serves three backends:

* ``xp = numpy`` — the Python reference oracle (``TileSim`` / ``ChipSim``),
  evaluating one (op, tile) pair at a time on float64 scalars;
* ``xp = jax.numpy`` under ``vmap``/``jit`` — the batched plan executor
  (``simulator.batched``) and the in-scan mapping evaluator
  (``dse.batch_eval``), evaluating (op, MAX_TILES) lanes at once.

Because all backends execute literally the same arithmetic (same formulas,
same operation order, float64 throughout), they cannot drift apart: a cost
edit lands in every simulator at once and the parity suites
(tests/test_batched_parity.py, tests/test_batch_eval.py) only have to
guard the *orchestration*, not the math.

This module also owns the activation-cache semantics (§3.3.4): a per-tile
FIFO bounded in both bytes (``CACHE_FRAC`` of SRAM) and entries
(``ACT_CACHE_SLOTS``).  ``ActivationCache`` is the Python reference;
``simulator.batched.fifo_insert`` is the array mirror, pinned bitwise by
the parity suite.

No jax import happens here — ``xp`` is always passed in, so the reference
simulator stays importable without touching XLA.
"""
from __future__ import annotations

import collections
import functools
from typing import Dict, Tuple

import numpy as np

from ..arch import Dataflow, Engine, MAX_TILES, Sparsity
from ..calibrate.asap7 import CalibrationTable
from ..ir import OpClass, OpType, PRECISION_BYTES

__all__ = [
    "CACHE_FRAC", "ACT_CACHE_SLOTS", "ACC_BYTES", "DSP_OPS_PER_ELEM",
    "DSP_OPS_TABLE", "SFU_NEED", "TILE_COST_KEYS", "OP_COST_KEYS",
    "COST_MODEL_VERSION", "FIDELITIES", "MAX_DRAM_CHANNELS", "MAX_LINKS",
    "CostModel", "cost_model", "ActivationCache",
    "noc_transfer_seconds", "noc_transfer_energy_pj", "split_op_fields",
    "grid_dims", "xy_route_link_mask", "dram_channel_one_hot",
    "pipeline_bounds", "steady_state_energy",
]

# Version tag of the cost formulas below.  The persistent DSE result store
# (``dse.store``) folds this into every content-addressed key, so bumping
# it invalidates all previously accumulated metrics at once — REQUIRED
# whenever an edit in this module (or in the mapping/orchestration
# semantics it feeds) changes any metric bit.  Format: "<pr>.<rev>".
COST_MODEL_VERSION = "9.0"

# Throughput-II fidelity tiers shared by every execution surface:
# ``aggregate`` keeps the historical one-shared-link NoC / one-channel
# DRAM bounds; ``link`` adds per-link XY-routed NoC occupancy on the tile
# grid and per-channel (address-interleaved) DRAM queues on top.
FIDELITIES = ("aggregate", "link")

# Fixed per-channel DRAM queue width of the link-fidelity tier.  Chips
# declare ``dram_channels`` in [1, MAX_DRAM_CHANNELS]; unused channel
# lanes stay zero so the vectors keep a static shape under jit.
MAX_DRAM_CHANNELS = 8

# Link-occupancy vector width: one horizontal link (to the right of each
# grid position) + one vertical link (below each grid position) on the
# largest admissible tile grid.  Positions outside a chip's actual
# ``grid_w x grid_h`` footprint never match a route and stay zero.
MAX_LINKS = 2 * MAX_TILES

# fraction of per-tile SRAM reserved for the activation cache (§3.3.4)
CACHE_FRAC = 0.25
# tag-array depth of the activation cache: at most this many outputs are
# tracked per tile, evicted FIFO (hardware: an 8-way tag RAM)
ACT_CACHE_SLOTS = 8

# Accumulator width (partial sums) per input precision index.
ACC_BYTES = (4.0, 4.0, 4.0, 4.0, 4.0)
_ACC = ACC_BYTES[0]

_BURST = 64.0  # DRAM burst alignment (bytes)

# Lane-ops each DSP-class operator spends per element (14-instruction SIMD
# ISA of §3.3.1: vadd, vmul, vexp, vreduce, vlut, ...).
DSP_OPS_PER_ELEM: Dict[int, float] = {
    int(OpType.ADD): 1.0,
    int(OpType.MUL): 1.0,
    int(OpType.SOFTMAX): 5.0,      # vmax, vsub+vexp, vreduce, vdiv
    int(OpType.LAYERNORM): 7.0,
    int(OpType.RMSNORM): 5.0,
    int(OpType.GELU): 8.0,         # tanh polynomial
    int(OpType.SILU): 5.0,
    int(OpType.RELU): 1.0,
    int(OpType.SIGMOID): 4.0,
    int(OpType.POOL): 1.0,
    int(OpType.REDUCE): 1.0,
    int(OpType.GATHER): 2.0,       # address gen + move
    int(OpType.SCATTER): 3.0,      # address gen + read-modify-write
    int(OpType.SSM_SCAN): 6.0,     # per-element recurrence work
    int(OpType.ROPE): 4.0,
}

# Dense table indexed by op_type (23 entries; default 2.0 lane-ops).
DSP_OPS_TABLE = np.array(
    [DSP_OPS_PER_ELEM.get(t, 2.0) for t in range(23)], dtype=np.float64)

# SFU-mask bit each special op needs (1 is harmless for non-special ops).
SFU_NEED = np.ones(23, dtype=np.float64)
SFU_NEED[int(OpType.FFT)] = 1.0
SFU_NEED[int(OpType.SNN_LIF)] = 2.0
SFU_NEED[int(OpType.POLY)] = 4.0

# Tile fields every CostModel entry point reads (subset of the SoA config
# stack emitted by ``simulator.batched.stack_chip_configs``).
TILE_COST_KEYS = (
    "exists", "num_macs", "rows", "cols", "engine", "prec_mask", "asym_mac",
    "sparsity", "dataflow", "sram_kb", "dsp_lanes", "dsp_count", "sfu_mask",
    "sfu_parallel", "double_buffer", "pipeline_depth", "clock_hz",
    "sram_bpc", "max_prec",
)

# Operator fields every CostModel entry point reads.
OP_COST_KEYS = (
    "op_type", "op_cls", "macs", "elems", "m", "k", "n", "precision",
    "bytes_in", "bytes_w", "bytes_out", "act_sparsity", "w_sparsity",
    "fft_n", "poly_degree", "snn_timesteps", "seq_len",
)


# =============================================================================
# NoC transfer cost (shared by mapper / orchestrator / batched / batch_eval)
# =============================================================================

def noc_transfer_seconds(xp, nbytes, noc_bpc, hops, base_cycles, ref_clock_hz):
    """Eq. 3 context: ceil(B / B_NoC) + hops * Delta_NoC cycles at the chip
    reference clock."""
    return (xp.ceil(nbytes / noc_bpc) + hops * base_cycles) / ref_clock_hz


def noc_transfer_energy_pj(xp, nbytes, e_noc_pj_per_byte_hop, hops):
    return nbytes * e_noc_pj_per_byte_hop * hops


# =============================================================================
# link-fidelity tier: XY-routed per-link NoC + per-channel DRAM queues
# =============================================================================

def grid_dims(xp, num_tiles, grid_aspect):
    """(grid_w, grid_h) of the 2D tile layout: width tracks
    ``sqrt(n) * aspect`` (clipped to [1, n]); the last row may be
    partial.  Same float64 arithmetic on both backends."""
    n = xp.maximum(xp.asarray(num_tiles, getattr(xp, "float64")), 1.0)
    w = xp.clip(xp.round(xp.sqrt(n) * grid_aspect), 1.0, n)
    return w, xp.ceil(n / w)


def xy_route_link_mask(xp, src, dst, grid_w, grid_h, torus):
    """0/1 occupancy mask over the ``MAX_LINKS`` grid links used by an
    XY route from tile ``src`` to tile ``dst``.

    Tiles are laid out row-major on a ``grid_w x grid_h`` grid.  Link
    ``i < MAX_TILES`` is the horizontal link to the *right* of grid
    position ``i``; link ``MAX_TILES + i`` is the vertical link *below*
    position ``i``.  Links are undirected shared channels — a leftward
    hop occupies the same link as the rightward one.  XY (dimension-
    ordered) routing moves horizontally along the source row first, then
    vertically along the destination column.  On a torus each dimension
    independently takes the wrap-around direction when strictly shorter
    (``2*delta > extent``; ties go the mesh way), using the wrap links at
    the grid edge.  A negative ``src``/``dst`` (no tile) yields an empty
    route.  All inputs broadcast; the link axis is appended last.
    """
    f64 = getattr(xp, "float64")
    links = xp.arange(MAX_TILES, dtype=f64)
    s = xp.asarray(src, f64)[..., None]
    d = xp.asarray(dst, f64)[..., None]
    w = xp.maximum(xp.asarray(grid_w, f64), 1.0)[..., None]
    h = xp.maximum(xp.asarray(grid_h, f64), 1.0)[..., None]
    wrap_ok = xp.asarray(torus, f64)[..., None] > 0
    sr = xp.floor_divide(s, w)
    sc = s - sr * w
    dr = xp.floor_divide(d, w)
    dc = d - dr * w
    lr = xp.floor_divide(links, w)
    lc = links - lr * w
    valid = (s >= 0) & (d >= 0)
    # horizontal segment: along the source row
    cmin = xp.minimum(sc, dc)
    cmax = xp.maximum(sc, dc)
    hwrap = wrap_ok & (2.0 * (cmax - cmin) > w)
    inside_h = (lc >= cmin) & (lc < cmax)
    outside_h = (lc >= cmax) | (lc < cmin)
    use_h = valid & (lr == sr) & xp.where(hwrap, outside_h, inside_h)
    # vertical segment: along the destination column
    rmin = xp.minimum(sr, dr)
    rmax = xp.maximum(sr, dr)
    vwrap = wrap_ok & (2.0 * (rmax - rmin) > h)
    inside_v = (lr >= rmin) & (lr < rmax)
    outside_v = (lr >= rmax) | (lr < rmin)
    use_v = valid & (lc == dc) & (lr < h) \
        & xp.where(vwrap, outside_v, inside_v)
    return xp.concatenate([xp.where(use_h, 1.0, 0.0),
                           xp.where(use_v, 1.0, 0.0)], axis=-1)


def dram_channel_one_hot(xp, tile_idx, dram_channels):
    """One-hot (..., MAX_DRAM_CHANNELS) selector of the DRAM channel that
    serves ``tile_idx``'s traffic: addresses interleave across channels
    by owner tile (``tile mod dram_channels``), the way NeuPIMs-style
    channel/rank models stripe a tensor across the memory system.  A
    negative tile index selects no channel."""
    f64 = getattr(xp, "float64")
    ch = xp.arange(MAX_DRAM_CHANNELS, dtype=f64)
    t = xp.asarray(tile_idx, f64)[..., None]
    n = xp.clip(xp.asarray(dram_channels, f64), 1.0,
                float(MAX_DRAM_CHANNELS))[..., None]
    sel = t - xp.floor_divide(t, n) * n
    return xp.where((ch == sel) & (t >= 0), 1.0, 0.0)


def split_op_fields(xp, op, axis, kf):
    """Array mirror of ``ir.slice_op``: even 1/k slice of a MAC op along
    OC (axis 0), B (1) or IC (2).  ``op`` is an ``OP_COST_KEYS`` dict;
    ``axis`` the integer ``AXIS_CODES`` value; ``kf`` the (float) split
    width.  Shared by the batched plan executor (replaying a compiled
    split) and the batched mapper (evaluating all three axes) so the
    slice arithmetic matches ``slice_op`` bitwise in every backend."""
    sub = {f: op[f] for f in OP_COST_KEYS}
    sub_m = xp.where(axis == 1, xp.maximum(xp.floor(op["m"] / kf), 1.0),
                     op["m"])
    sub_n = xp.where(axis == 0, xp.maximum(xp.floor(op["n"] / kf), 1.0),
                     op["n"])
    sub_k = xp.where(axis == 2, xp.maximum(xp.floor(op["k"] / kf), 1.0),
                     op["k"])
    sub["m"], sub["n"], sub["k"] = sub_m, sub_n, sub_k
    sub["macs"] = xp.where(op["macs"] > 0, sub_m * sub_k * sub_n, op["macs"])
    sub["bytes_in"] = xp.where(axis == 1, xp.floor(op["bytes_in"] / kf),
                               op["bytes_in"])
    sub["bytes_w"] = xp.where(axis != 1, xp.floor(op["bytes_w"] / kf),
                              op["bytes_w"])
    sub["bytes_out"] = xp.where(axis != 2, xp.floor(op["bytes_out"] / kf),
                                op["bytes_out"])
    return sub


# =============================================================================
# throughput-mode steady state (§3.2 schedule modes)
# =============================================================================

def pipeline_bounds(xp, makespan_s, tile_busy_max_s, dram_bytes, dram_gbps,
                    noc_busy_s, chan_bytes=None, dram_channels=None,
                    link_busy_s=None):
    """Steady-state initiation interval of a pipelined (throughput-mode)
    schedule: successive inference batches replay the same plan, and in
    steady state the batch rate is set by the busiest *resource*, not the
    dependence critical path.

    Three per-batch occupancy lower bounds are composed:

    * ``tile_busy_max_s`` — the bottleneck tile's summed execution time
      (every op serializes on its owner tile);
    * DRAM channel — total burst-aligned DRAM bytes of one batch at the
      full ``dram_gbps`` (steady state overlaps transfers perfectly, so
      the channel bound uses the undivided bandwidth);
    * NoC — summed cross-tile acquisition + split-reduce transfer time
      (the NoC modeled as one shared link).

    ``II = min(makespan, max(bounds))``: the serial replay (one batch per
    makespan) is always an admissible schedule, so pipelining can never be
    slower per batch — the clamp keeps the two modes consistent wherever
    the latency model's dynamic-bandwidth optimism lets overlapping tiles
    exceed a shared-resource bound.  All backends call this one function,
    so the II arithmetic cannot drift between them.

    The ``fidelity="link"`` tier passes two extra occupancy vectors and
    the chip's channel count:

    * ``chan_bytes`` — (..., MAX_DRAM_CHANNELS) per-channel DRAM bytes
      (address-interleaved by owner tile); each channel serves its queue
      at ``dram_gbps / dram_channels``, so the channel bound is the max
      channel queue at the per-channel bandwidth.  With one channel it
      reduces exactly to the aggregate DRAM bound.
    * ``link_busy_s`` — (..., MAX_LINKS) per-link XY-routed transfer
      occupancy; the link bound is the busiest single link.

    Both are *additional* lower bounds max'd into the bottleneck (the
    aggregate bounds model injection/front-end serialization and are kept)
    — so ``II(link) >= II(aggregate)`` always, and the aggregate keys keep
    their historical bits.
    """
    dram_bound = dram_bytes / (dram_gbps * 1e9)
    bottleneck = xp.maximum(xp.maximum(tile_busy_max_s, dram_bound),
                            noc_busy_s)
    out = {
        "ii_tile_bound_s": tile_busy_max_s,
        "ii_dram_bound_s": dram_bound,
        "ii_noc_bound_s": noc_busy_s,
    }
    if chan_bytes is not None:
        n_ch = xp.clip(dram_channels, 1.0, float(MAX_DRAM_CHANNELS))
        chan_bound = xp.max(chan_bytes, axis=-1) \
            / ((dram_gbps / n_ch) * 1e9)
        link_bound = xp.max(link_busy_s, axis=-1)
        bottleneck = xp.maximum(xp.maximum(bottleneck, chan_bound),
                                link_bound)
        out["ii_chan_bound_s"] = chan_bound
        out["ii_link_bound_s"] = link_bound
    out["ii_s"] = xp.minimum(makespan_s, bottleneck)
    return out


def steady_state_energy(energy_total_pj, leakage_pj, leak_rate_pj_per_s,
                        ii_s):
    """Per-inference energy in the pipelined steady state: dynamic energy
    is per batch regardless of mode, but each batch occupies only ``II``
    of wall time, so leakage is re-charged over the initiation interval
    instead of the fill makespan."""
    return energy_total_pj - leakage_pj + leak_rate_pj_per_s * ii_s


# =============================================================================
# the seven-module cost model
# =============================================================================

class CostModel:
    """Per-(op, tile) cycle/energy model bound to one (calib, xp) pair.

    ``T`` arguments are dicts of per-tile scalars or (..., MAX_TILES)
    arrays (keys: ``TILE_COST_KEYS``); ``op`` arguments are dicts of per-op
    scalars or broadcast-compatible arrays (keys: ``OP_COST_KEYS``).  All
    methods are branch-free so the same code runs on numpy scalars and
    under jit/vmap.
    """

    def __init__(self, calib: CalibrationTable, xp=np):
        self.xp = xp
        self.c = calib
        f64 = getattr(xp, "float64")
        self.e_mac = xp.asarray(calib.e_mac_pj, f64)
        self.eng_e = xp.asarray(calib.engine_e_mult, f64)
        self.dsp_ops_t = xp.asarray(DSP_OPS_TABLE, f64)
        self.sfu_need = xp.asarray(SFU_NEED, f64)
        self.bpe_t = xp.asarray(PRECISION_BYTES, f64)

    # ---------------------------------------------------------------- helpers
    def _i32(self, v):
        return self.xp.asarray(v, self.xp.int32)

    def _sel(self, conds, vals, default):
        """``xp.select`` semantics (first true condition wins) as nested
        ``where`` — an order of magnitude cheaper on the numpy scalar path
        and identical bits on both backends."""
        out = default
        for c, v in zip(reversed(conds), reversed(vals)):
            out = self.xp.where(c, v, out)
        return out

    def mac_energy_pj(self, T, prec_idx):
        """Op-precision MAC energy on this tile's datapath, including the
        clock-gating residual of the wide path (CalibrationTable.mac_energy)."""
        xp = self.xp
        dp_idx = self._i32(T["max_prec"])
        e = self.e_mac[prec_idx]
        e_wide = self.e_mac[dp_idx]
        e = xp.where(e_wide > e, e + self.c.datapath_residual * (e_wide - e), e)
        return e * self.eng_e[self._i32(T["engine"])]

    def eta(self, sparsity, act_sp, w_sp):
        """Sparsity throughput multiplier eta_T (CalibrationTable.eta)."""
        xp = self.xp
        act_sp = xp.clip(act_sp, 0.0, 0.95)
        w_sp = xp.clip(w_sp, 0.0, 0.95)
        e_act = 1.0 / (1.0 - act_sp)
        e_w = 1.0 / (1.0 - w_sp)
        e_two = 1.0 / xp.maximum((1.0 - act_sp) * (1.0 - w_sp), 1e-3)
        e_nm = xp.where(w_sp >= 0.5, 2.0, 1.0)
        e = self._sel(
            [sparsity == int(Sparsity.NONE), sparsity == int(Sparsity.ACT),
             sparsity == int(Sparsity.WEIGHT),
             sparsity == int(Sparsity.TWO_SIDED)],
            [xp.ones_like(e_act), e_act, e_w, e_two], e_nm)
        return xp.minimum(e, self.c.eta_cap)

    def supports_precision(self, T, prec):
        """Per-tile precision filter incl. asymmetric-MAC variants
        (TileTemplate.supports_precision)."""
        xp = self.xp
        native = xp.floor_divide(T["prec_mask"], 2.0 ** prec) % 2 >= 1
        int8_ok = xp.floor_divide(T["prec_mask"], 2.0) % 2 >= 1
        fp16_ok = xp.floor_divide(T["prec_mask"], 4.0) % 2 >= 1
        asym48 = ((T["asym_mac"] == 1.0) | (T["asym_mac"] == 2.0)) \
            & (prec == 0) & int8_ok
        asym416 = (T["asym_mac"] == 3.0) & (prec <= 1) & fp16_ok
        return native | asym48 | asym416

    def sfu_native(self, T, op):
        return self.xp.floor_divide(
            T["sfu_mask"], self.sfu_need[self._i32(op["op_type"])]) % 2 >= 1

    def supports(self, T, op):
        """Compatibility filter (paper §3.2; TileSim.supports)."""
        xp = self.xp
        prec_ok = self.supports_precision(T, op["precision"])
        has_dsp = T["dsp_count"] > 0
        mac_ok = ((T["num_macs"] > 0) & prec_ok) | has_dsp
        spec_ok = self.sfu_native(T, op) \
            | ((op["op_type"] == int(OpType.FFT)) & (T["num_macs"] > 0)
               & prec_ok) \
            | has_dsp
        cls_ok = self._sel(
            [op["op_cls"] == int(OpClass.MAC),
             op["op_cls"] == int(OpClass.DSP)],
            [mac_ok, has_dsp], spec_ok)
        return (T["exists"] > 0) & cls_ok

    # ---------------------------------------------------------- MAC sub-models
    def mac_tiling(self, T, m, k, n, bpe, cache_frac=CACHE_FRAC):
        """SRAM-budget tiling pass (§3.3.1): returns (m_t, k_t, n_t)."""
        xp = self.xp
        budget = T["sram_kb"] * 1024.0 * (1.0 - cache_frac)
        m_t = xp.minimum(m, T["rows"])
        n_t = xp.maximum(xp.minimum(n, T["cols"]), 1.0)
        db = xp.where(T["double_buffer"] > 0, 2.0, 1.0)
        out_b = m_t * n_t * _ACC
        k_fit = (budget - out_b) / xp.maximum((m_t + n_t) * bpe * db, 1.0)
        k_t = xp.maximum(xp.minimum(k, k_fit), xp.minimum(k, 16.0))
        return m_t, k_t, n_t

    def mac_cycles(self, T, m, k, n, eta, m_t, k_t, n_t):
        """Engine-specific compute-cycle model (Eq. 4)."""
        xp = self.xp
        D = T["pipeline_depth"]
        tn = xp.ceil(n / n_t)
        tk = xp.ceil(k / xp.maximum(k_t, 1.0))
        tm = xp.ceil(m / xp.maximum(m_t, 1.0))
        m_eff = m / xp.maximum(tm, 1.0)
        k_eff = (k / xp.maximum(tk, 1.0)) / eta
        nm = xp.maximum(T["num_macs"], 1.0)
        sys = tn * tk * (D + tm * (m_eff + k_eff + D - 2.0))
        ideal = (m * k * n / eta) / nm
        util = (m_eff / xp.maximum(m_t, 1.0)) \
            * (xp.minimum(n, n_t) / xp.maximum(n_t, 1.0))
        spatial = ideal / xp.maximum(xp.minimum(util, 1.0), 0.25) + D * tn * tk
        cim = 2.0 * ideal + D * tn * tk
        cyc = self._sel(
            [T["engine"] == int(Engine.SYSTOLIC),
             T["engine"] == int(Engine.SPATIAL),
             T["engine"] == int(Engine.DOT)],
            [sys, spatial, spatial], cim)
        return xp.where((m > 0) & (k > 0) & (n > 0), cyc, 0.0)

    def sram_traffic(self, T, m, k, n, bpe, m_t, k_t, n_t):
        """Tiling-aware SRAM traffic (bytes in, weights, out, k-tiles) from
        dataflow reuse, including the AUTO rule (§3.2)."""
        xp = self.xp
        tm = xp.ceil(m / xp.maximum(m_t, 1.0))
        tk = xp.ceil(k / xp.maximum(k_t, 1.0))
        tn = xp.ceil(n / xp.maximum(n_t, 1.0))
        auto_os = (m * n > 4.0 * k * n) & (m * n > 4.0 * m * k)
        df = xp.where(T["dataflow"] == int(Dataflow.AUTO),
                      xp.where(auto_os, float(Dataflow.OS),
                               float(Dataflow.WS)),
                      T["dataflow"])
        in_b = self._sel(
            [df == int(Dataflow.WS), df == int(Dataflow.OS)],
            [m * k * bpe * tn, m * k * bpe * tn], m * k * bpe * xp.sqrt(tn))
        w_b = self._sel(
            [df == int(Dataflow.WS), df == int(Dataflow.OS)],
            [k * n * bpe, k * n * bpe * tm], k * n * bpe * xp.sqrt(tm))
        out_b = self._sel(
            [df == int(Dataflow.WS), df == int(Dataflow.OS)],
            [m * n * _ACC * (2.0 * tk - 1.0), m * n * _ACC],
            m * n * _ACC * xp.sqrt(tk))
        return in_b, w_b, out_b, tk

    # ----------------------------------------------------------- vector paths
    def dsp_cycles_energy(self, T, op_type, elems, seq_len):
        """Vector-DSP path; the SSM scan parallelizes only per-step work."""
        xp = self.xp
        ops_pe = self.dsp_ops_t[self._i32(op_type)]
        lane_ops = elems * ops_pe
        lanes = xp.maximum(T["dsp_lanes"], 1.0)
        is_scan = (op_type == int(OpType.SSM_SCAN)) & (seq_len > 1)
        per_step = (elems / xp.maximum(seq_len, 1.0)) * ops_pe
        cyc = xp.where(is_scan,
                       seq_len * xp.ceil(per_step / lanes),
                       xp.ceil(lane_ops / lanes))
        ok = (T["dsp_count"] > 0) & (elems > 0)
        return xp.where(ok, cyc, 0.0), \
            xp.where(ok, lane_ops * self.c.e_dsp_pj_per_lane_op, 0.0)

    def sfu_cycles_energy(self, T, op_type, elems, fft_n, poly_d, snn_t):
        """Native special-function path: radix-2 FFT, LIF array, Horner."""
        xp = self.xp
        c = self.c
        par = xp.maximum(T["sfu_parallel"], 1.0)
        n = xp.maximum(fft_n, 2.0)
        transforms = xp.maximum(elems / n, 1.0)
        lg = xp.log2(n)
        c_fft = transforms * xp.ceil(n * lg / par)
        e_fft = transforms * (n / 2.0) * lg * c.e_fft_pj_per_butterfly
        t_ = xp.maximum(snn_t, 1.0)
        c_lif = xp.ceil(elems / par) * t_
        e_lif = elems * t_ * c.e_lif_pj_per_neuron_step
        d = xp.maximum(poly_d, 1.0)
        c_pol = elems * d / par
        e_pol = elems * d * c.e_poly_pj_per_fma
        cyc = self._sel([op_type == int(OpType.FFT),
                         op_type == int(OpType.SNN_LIF)], [c_fft, c_lif],
                        c_pol)
        en = self._sel([op_type == int(OpType.FFT),
                        op_type == int(OpType.SNN_LIF)], [e_fft, e_lif],
                       e_pol)
        return cyc, en

    def lowered_cycles_energy(self, T, op, prec_idx):
        """Lowered cost (paper §2.5): FFT->MAC O(N^2) when a MAC array
        exists; LIF/poly/FFT->DSP with sequential multipliers."""
        xp = self.xp
        c = self.c
        lanes = xp.maximum(T["dsp_lanes"], 1.0)
        n = xp.maximum(op["fft_n"], 2.0)
        transforms = xp.maximum(op["elems"] / n, 1.0)
        macs = 4.0 * n * n * transforms
        c_fft_mac = macs / xp.maximum(T["num_macs"], 1.0)
        e_fft_mac = macs * self.mac_energy_pj(T, prec_idx)
        tsteps = xp.maximum(op["snn_timesteps"], 1.0)
        lif_ops = op["elems"] * 4.0
        # divergence + membrane round-trips (§2.5): ~4x lane-efficiency loss
        c_lif = tsteps * (xp.ceil(lif_ops / (lanes / 4.0))
                          + xp.ceil(op["elems"] * 8.0 / T["sram_bpc"]))
        e_lif = lif_ops * tsteps * c.e_dsp_pj_per_lane_op
        d = xp.maximum(op["poly_degree"], 1.0)
        pol_ops = op["elems"] * 2.0
        c_pol = d * (xp.ceil(pol_ops / lanes)
                     + xp.ceil(op["elems"] * 2.0 / T["sram_bpc"]))
        e_pol = d * pol_ops * c.e_dsp_pj_per_lane_op
        c_fft_dsp = xp.ceil(op["elems"] * 10.0 * xp.log2(n) / lanes)
        e_fft_dsp = op["elems"] * 10.0 * xp.log2(n) * c.e_dsp_pj_per_lane_op
        is_fft = op["op_type"] == int(OpType.FFT)
        # The MAC lowering (and its DFT twiddle-weight SRAM surcharge)
        # requires the datapath to accept the op's precision; a
        # precision-mismatched MAC tile falls through to DSP butterfly
        # emulation.  (The pre-unification TileSim charged the twiddle
        # stream while costing butterfly cycles on such tiles — an
        # inconsistency this shared model resolves the batch_eval way.)
        fft_on_mac = is_fft & (T["num_macs"] > 0) \
            & self.supports_precision(T, op["precision"])
        cyc = self._sel(
            [fft_on_mac, op["op_type"] == int(OpType.SNN_LIF),
             op["op_type"] == int(OpType.POLY)],
            [c_fft_mac, c_lif, c_pol], c_fft_dsp)
        en = self._sel(
            [fft_on_mac, op["op_type"] == int(OpType.SNN_LIF),
             op["op_type"] == int(OpType.POLY)],
            [e_fft_mac, e_lif, e_pol], e_fft_dsp)
        # DFT twiddle weights streamed through SRAM on the MAC lowering
        extra_sram = xp.where(fft_on_mac, 2.0 * n * n * self.bpe_t[prec_idx]
                              * c.e_sram_pj_per_byte, 0.0)
        return cyc, en, extra_sram, fft_on_mac

    # -------------------------------------------------------------- roofline
    def roofline_cycles(self, T, op, bw_gbps):
        """Mapper's cycle estimate (Eq. 2): max of compute- and
        bandwidth-bound counts (TileSim.roofline_cycles)."""
        xp = self.xp
        total_b = op["bytes_in"] + op["bytes_w"] + op["bytes_out"]
        bpc = bw_gbps * 1e9 / T["clock_hz"]
        c_bw = total_b / xp.maximum(bpc, 1e-9)
        eta = self.eta(T["sparsity"], op["act_sparsity"], op["w_sparsity"])
        c_mac = xp.where(
            (T["num_macs"] > 0) & self.supports_precision(T, op["precision"]),
            op["macs"] / xp.maximum(T["num_macs"] * eta, 1e-9),
            xp.ceil(2.0 * op["macs"] / xp.maximum(T["dsp_lanes"], 1.0)))
        c_dsp, _ = self.dsp_cycles_energy(T, op["op_type"], op["elems"],
                                          op["seq_len"])
        c_sfu_nat, _ = self.sfu_cycles_energy(
            T, op["op_type"], op["elems"], op["fft_n"], op["poly_degree"],
            op["snn_timesteps"])
        prec_idx = self._i32(op["precision"])
        c_low, _, _, _ = self.lowered_cycles_energy(T, op, prec_idx)
        c_spec = xp.where(self.sfu_native(T, op), c_sfu_nat, c_low)
        c_cmp = self._sel(
            [op["op_cls"] == int(OpClass.MAC),
             op["op_cls"] == int(OpClass.SPECIAL)],
            [c_mac, c_spec], c_dsp)
        return xp.maximum(c_cmp, c_bw)

    # --------------------------------------------------------------- execute
    def execute_static(self, T, op, cache_frac=CACHE_FRAC):
        """The state-independent half of :meth:`execute`: every quantity
        that depends only on the (tile, op) pair — compute/memory cycle
        counts, per-module energies, execution-path routing, and the
        path-selected non-DRAM energy sum.

        Splitting this out lets the oracle orchestrator evaluate it for a
        whole plan in ONE vectorized call (one record per (op, tile)
        execution) before its sequential walk, leaving only the cheap
        bandwidth/DRAM combine (:meth:`execute_dynamic`) inside the
        per-op loop.  ``execute`` composes the two halves, so all
        backends still run literally the same arithmetic.
        """
        xp = self.xp
        c = self.c
        prec_idx = self._i32(op["precision"])
        bpe = self.bpe_t[prec_idx]

        # ---- MAC path ----------------------------------------------------
        eta = self.eta(T["sparsity"], op["act_sparsity"], op["w_sparsity"])
        m_t, k_t, n_t = self.mac_tiling(T, op["m"], op["k"], op["n"], bpe,
                                        cache_frac)
        c_mac = self.mac_cycles(T, op["m"], op["k"], op["n"], eta,
                                m_t, k_t, n_t)
        e_mac_path = (op["macs"] / eta) * self.mac_energy_pj(T, prec_idx)
        in_b, w_b, out_b, tk = self.sram_traffic(
            T, op["m"], op["k"], op["n"], bpe, m_t, k_t, n_t)
        e_sram_mac = (in_b + w_b + out_b) * c.e_sram_pj_per_byte
        irf_w = xp.ceil(in_b / 32.0) * 32.0
        irf_r = in_b * (1.0 - xp.minimum(op["act_sparsity"], 0.95))
        e_irf = (irf_w + irf_r) * c.e_irf_pj_per_byte
        orf_b = op["m"] * op["n"] * _ACC * (2.0 * tk - 1.0)
        e_orf = orf_b * c.e_orf_pj_per_byte
        c_mem_mac = xp.ceil((in_b + w_b + out_b) / T["sram_bpc"])

        # ---- DSP path ----------------------------------------------------
        c_dsp, e_dsp = self.dsp_cycles_energy(T, op["op_type"], op["elems"],
                                              op["seq_len"])
        stream_b = op["bytes_in"] + op["bytes_out"]
        e_sram_stream = stream_b * c.e_sram_pj_per_byte
        c_mem_stream = xp.ceil(stream_b / T["sram_bpc"])

        # ---- MAC op lowered onto the DSP ---------------------------------
        lanes = xp.maximum(T["dsp_lanes"], 1.0)
        c_mac_on_dsp = xp.ceil(2.0 * op["macs"] / lanes)
        e_mac_on_dsp = 2.0 * op["macs"] * c.e_dsp_pj_per_lane_op

        # ---- SPECIAL path ------------------------------------------------
        c_sfu, e_sfu = self.sfu_cycles_energy(
            T, op["op_type"], op["elems"], op["fft_n"], op["poly_degree"],
            op["snn_timesteps"])
        c_low, e_low, extra_sram_low, fft_on_mac = self.lowered_cycles_energy(
            T, op, prec_idx)
        native = self.sfu_native(T, op)
        c_spec = xp.where(native, c_sfu, c_low)
        e_spec = xp.where(native, e_sfu, e_low)
        e_spec_sram = e_sram_stream + xp.where(native, 0.0, extra_sram_low)

        is_mac_cls = op["op_cls"] == int(OpClass.MAC)
        is_spec_cls = op["op_cls"] == int(OpClass.SPECIAL)
        prec_ok = self.supports_precision(T, op["precision"])
        on_mac = is_mac_cls & (T["num_macs"] > 0) & prec_ok
        on_dsp_low = is_mac_cls & ~on_mac
        spec_lowered_mac = is_spec_cls & ~native & fft_on_mac

        c_cmp = self._sel([on_mac, on_dsp_low, is_spec_cls],
                          [c_mac, c_mac_on_dsp, c_spec], c_dsp)
        c_mem = self._sel([on_mac, on_dsp_low, is_spec_cls],
                          [c_mem_mac, c_mem_stream, c_mem_stream],
                          c_mem_stream)

        # per-module energy routing (mirrors TileSim's EnergyBreakdown fills)
        zero = xp.zeros_like(c_cmp)
        e_compute = self._sel(
            [on_mac, spec_lowered_mac], [e_mac_path, e_spec], zero)
        e_dsp_mod = self._sel(
            [on_mac, on_dsp_low, is_spec_cls],
            [zero, e_mac_on_dsp,
             xp.where(native | fft_on_mac, zero, e_spec)], e_dsp)
        e_special = xp.where(is_spec_cls & native, e_spec, 0.0)
        e_sram = self._sel(
            [on_mac, on_dsp_low, is_spec_cls],
            [e_sram_mac, e_sram_stream, e_spec_sram], e_sram_stream)
        e_irf_mod = xp.where(on_mac, e_irf, 0.0)
        e_orf_mod = xp.where(on_mac, e_orf, 0.0)

        # non-DRAM energy summed in the historical per-path order so the
        # jitted backends reproduce the pre-refactor bits exactly
        e_static = self._sel(
            [on_mac, on_dsp_low, is_spec_cls],
            [e_mac_path + e_sram_mac + e_irf + e_orf,
             e_mac_on_dsp + e_sram_stream,
             e_spec + e_spec_sram],
            e_dsp + e_sram_stream)

        path = self._sel([on_mac | spec_lowered_mac, is_spec_cls & native],
                         [xp.zeros_like(c_cmp), 2.0 + zero], 1.0 + zero)
        return {
            "c_cmp": c_cmp, "c_mem": c_mem,
            "e_compute": e_compute, "e_dsp": e_dsp_mod,
            "e_special": e_special, "e_sram": e_sram, "e_irf": e_irf_mod,
            "e_orf": e_orf_mod, "e_static": e_static, "path": path,
        }

    def execute_dynamic(self, st, T, bw_gbps, dram_rd, dram_wr):
        """The state-dependent half of :meth:`execute`: burst-aligned DRAM
        staging at the dynamically shared bandwidth, the Eq. 5 total-cycle
        combine, and the roofline code.  ``st`` is an
        :meth:`execute_static` result (or one row of a vectorized one);
        ``T`` only needs ``clock_hz`` and ``double_buffer``."""
        xp = self.xp
        c = self.c
        c_cmp, c_mem = st["c_cmp"], st["c_mem"]

        # ---- DRAM + ports + Eq. 5 combine --------------------------------
        rd_al = xp.where(dram_rd > 0, xp.ceil(dram_rd / _BURST) * _BURST, 0.0)
        wr_al = xp.where(dram_wr > 0, xp.ceil(dram_wr / _BURST) * _BURST, 0.0)
        total_dram = rd_al + wr_al
        bpc = bw_gbps * 1e9 / T["clock_hz"]
        c_dram = xp.where(total_dram > 0,
                          total_dram / xp.maximum(bpc, 1e-9)
                          + c.dram_latency_cycles, 0.0)
        e_dram = total_dram * c.e_dram_pj_per_byte
        c_lp = xp.ceil(dram_rd / 64.0)
        c_sp = xp.ceil(dram_wr / 64.0)
        c_tot = xp.where(T["double_buffer"] > 0,
                         xp.maximum(xp.maximum(c_cmp, c_mem), c_dram)
                         + c_lp + c_sp,
                         c_cmp + c_mem + c_dram + c_lp + c_sp)

        energy_total = st["e_static"] + e_dram
        roofline = xp.where(c_cmp >= xp.maximum(c_mem, c_dram), 0.0, 1.0)
        return {
            "cycles": c_tot, "seconds": c_tot / T["clock_hz"],
            "e_compute": st["e_compute"], "e_dsp": st["e_dsp"],
            "e_special": st["e_special"], "e_sram": st["e_sram"],
            "e_irf": st["e_irf"], "e_orf": st["e_orf"], "e_dram": e_dram,
            "energy_total": energy_total, "path": st["path"],
            "roofline": roofline, "dram_bytes": total_dram,
        }

    def execute(self, T, op, bw_gbps, dram_rd, dram_wr,
                cache_frac=CACHE_FRAC):
        """Full seven-module execution (Eq. 4-6; TileSim.execute).

        ``dram_rd`` / ``dram_wr`` are the effective DRAM bytes after the
        orchestrator's activation-cache adjustment (§3.3.4).  Returns a
        dict with ``cycles``, ``seconds``, per-module energies
        (``e_compute``, ``e_dsp``, ``e_special``, ``e_sram``, ``e_irf``,
        ``e_orf``, ``e_dram``), their ``energy_total``, and integer
        ``path`` (0 MAC / 1 DSP / 2 SFU) and ``roofline`` (0 compute /
        1 memory) codes.  Composition of :meth:`execute_static` and
        :meth:`execute_dynamic` — bitwise identical to the historical
        fused implementation.
        """
        return self.execute_dynamic(self.execute_static(T, op, cache_frac),
                                    T, bw_gbps, dram_rd, dram_wr)

    # ----------------------------------------------- class-specialized halves
    # ``op_cls`` is a *workload* property — identical for every candidate
    # chip in a batched evaluation.  The restrictions below are
    # :meth:`execute_static` / :meth:`roofline_cycles` / :meth:`supports`
    # with the class selects resolved at the call site: when the caller
    # already knows the class (the fused search kernel branches on the
    # op-table value with ``lax.cond``, so only the taken class runs), the
    # other classes' sub-models are never evaluated.  Each restriction is
    # term-for-term the corresponding ``_sel`` branch of the full method,
    # so the bits are identical — pinned by the batched-mapper parity
    # suite and the engine's exact-search/rescore property tests.

    def _stream_static(self, T, op):
        """Streaming (non-MAC-array) SRAM terms shared by all classes."""
        xp = self.xp
        stream_b = op["bytes_in"] + op["bytes_out"]
        e_sram_stream = stream_b * self.c.e_sram_pj_per_byte
        c_mem_stream = xp.ceil(stream_b / T["sram_bpc"])
        return e_sram_stream, c_mem_stream

    def _bw_cycles(self, T, op, bw_gbps):
        xp = self.xp
        total_b = op["bytes_in"] + op["bytes_w"] + op["bytes_out"]
        bpc = bw_gbps * 1e9 / T["clock_hz"]
        return total_b / xp.maximum(bpc, 1e-9)

    def execute_static_mac(self, T, op, cache_frac=CACHE_FRAC):
        """:meth:`execute_static` restricted to ``OpClass.MAC`` operators
        (on-array execution or DSP lowering; no SFU terms evaluated)."""
        xp = self.xp
        c = self.c
        prec_idx = self._i32(op["precision"])
        bpe = self.bpe_t[prec_idx]
        eta = self.eta(T["sparsity"], op["act_sparsity"], op["w_sparsity"])
        m_t, k_t, n_t = self.mac_tiling(T, op["m"], op["k"], op["n"], bpe,
                                        cache_frac)
        c_mac = self.mac_cycles(T, op["m"], op["k"], op["n"], eta,
                                m_t, k_t, n_t)
        e_mac_path = (op["macs"] / eta) * self.mac_energy_pj(T, prec_idx)
        in_b, w_b, out_b, tk = self.sram_traffic(
            T, op["m"], op["k"], op["n"], bpe, m_t, k_t, n_t)
        e_sram_mac = (in_b + w_b + out_b) * c.e_sram_pj_per_byte
        irf_w = xp.ceil(in_b / 32.0) * 32.0
        irf_r = in_b * (1.0 - xp.minimum(op["act_sparsity"], 0.95))
        e_irf = (irf_w + irf_r) * c.e_irf_pj_per_byte
        orf_b = op["m"] * op["n"] * _ACC * (2.0 * tk - 1.0)
        e_orf = orf_b * c.e_orf_pj_per_byte
        c_mem_mac = xp.ceil((in_b + w_b + out_b) / T["sram_bpc"])
        e_sram_stream, c_mem_stream = self._stream_static(T, op)
        lanes = xp.maximum(T["dsp_lanes"], 1.0)
        c_mac_on_dsp = xp.ceil(2.0 * op["macs"] / lanes)
        e_mac_on_dsp = 2.0 * op["macs"] * c.e_dsp_pj_per_lane_op
        on_mac = (T["num_macs"] > 0) \
            & self.supports_precision(T, op["precision"])
        zero = xp.zeros_like(c_mac)
        return {
            "c_cmp": xp.where(on_mac, c_mac, c_mac_on_dsp),
            "c_mem": xp.where(on_mac, c_mem_mac, c_mem_stream),
            "e_compute": xp.where(on_mac, e_mac_path, 0.0),
            "e_dsp": xp.where(on_mac, 0.0, e_mac_on_dsp),
            "e_special": zero,
            "e_sram": xp.where(on_mac, e_sram_mac, e_sram_stream),
            "e_irf": xp.where(on_mac, e_irf, 0.0),
            "e_orf": xp.where(on_mac, e_orf, 0.0),
            "e_static": xp.where(
                on_mac, e_mac_path + e_sram_mac + e_irf + e_orf,
                e_mac_on_dsp + e_sram_stream),
            "path": xp.where(on_mac, zero, 1.0 + zero),
        }

    def execute_static_dsp(self, T, op):
        """:meth:`execute_static` restricted to ``OpClass.DSP`` operators
        (the cheap vector path: no MAC tiling, no SFU lowering)."""
        xp = self.xp
        c_dsp, e_dsp = self.dsp_cycles_energy(T, op["op_type"], op["elems"],
                                              op["seq_len"])
        e_sram_stream, c_mem_stream = self._stream_static(T, op)
        zero = xp.zeros_like(c_dsp)
        return {
            "c_cmp": c_dsp, "c_mem": c_mem_stream + zero,
            "e_compute": zero, "e_dsp": e_dsp, "e_special": zero,
            "e_sram": e_sram_stream + zero, "e_irf": zero, "e_orf": zero,
            "e_static": e_dsp + e_sram_stream,
            "path": 1.0 + zero,
        }

    def execute_static_special(self, T, op):
        """:meth:`execute_static` restricted to ``OpClass.SPECIAL``
        operators (native SFU or §2.5 lowering; no MAC tiling pass)."""
        xp = self.xp
        prec_idx = self._i32(op["precision"])
        c_sfu, e_sfu = self.sfu_cycles_energy(
            T, op["op_type"], op["elems"], op["fft_n"], op["poly_degree"],
            op["snn_timesteps"])
        c_low, e_low, extra_sram_low, fft_on_mac = self.lowered_cycles_energy(
            T, op, prec_idx)
        native = self.sfu_native(T, op)
        e_sram_stream, c_mem_stream = self._stream_static(T, op)
        c_spec = xp.where(native, c_sfu, c_low)
        e_spec = xp.where(native, e_sfu, e_low)
        e_spec_sram = e_sram_stream + xp.where(native, 0.0, extra_sram_low)
        spec_lowered_mac = ~native & fft_on_mac
        zero = xp.zeros_like(c_spec)
        return {
            "c_cmp": c_spec, "c_mem": c_mem_stream + zero,
            "e_compute": xp.where(spec_lowered_mac, e_spec, 0.0),
            "e_dsp": xp.where(native | fft_on_mac, 0.0, e_spec),
            "e_special": xp.where(native, e_spec, 0.0),
            "e_sram": e_spec_sram, "e_irf": zero, "e_orf": zero,
            "e_static": e_spec + e_spec_sram,
            "path": xp.where(spec_lowered_mac, zero,
                             xp.where(native, 2.0 + zero, 1.0 + zero)),
        }

    def roofline_cycles_mac(self, T, op, bw_gbps):
        """:meth:`roofline_cycles` restricted to ``OpClass.MAC``."""
        xp = self.xp
        eta = self.eta(T["sparsity"], op["act_sparsity"], op["w_sparsity"])
        c_mac = xp.where(
            (T["num_macs"] > 0) & self.supports_precision(T, op["precision"]),
            op["macs"] / xp.maximum(T["num_macs"] * eta, 1e-9),
            xp.ceil(2.0 * op["macs"] / xp.maximum(T["dsp_lanes"], 1.0)))
        return xp.maximum(c_mac, self._bw_cycles(T, op, bw_gbps))

    def roofline_cycles_dsp(self, T, op, bw_gbps):
        """:meth:`roofline_cycles` restricted to ``OpClass.DSP``."""
        xp = self.xp
        c_dsp, _ = self.dsp_cycles_energy(T, op["op_type"], op["elems"],
                                          op["seq_len"])
        return xp.maximum(c_dsp, self._bw_cycles(T, op, bw_gbps))

    def roofline_cycles_special(self, T, op, bw_gbps):
        """:meth:`roofline_cycles` restricted to ``OpClass.SPECIAL``."""
        xp = self.xp
        c_sfu_nat, _ = self.sfu_cycles_energy(
            T, op["op_type"], op["elems"], op["fft_n"], op["poly_degree"],
            op["snn_timesteps"])
        prec_idx = self._i32(op["precision"])
        c_low, _, _, _ = self.lowered_cycles_energy(T, op, prec_idx)
        c_spec = xp.where(self.sfu_native(T, op), c_sfu_nat, c_low)
        return xp.maximum(c_spec, self._bw_cycles(T, op, bw_gbps))

    def supports_mac(self, T, op):
        """:meth:`supports` restricted to ``OpClass.MAC``."""
        prec_ok = self.supports_precision(T, op["precision"])
        has_dsp = T["dsp_count"] > 0
        return (T["exists"] > 0) & (((T["num_macs"] > 0) & prec_ok) | has_dsp)

    def supports_dsp(self, T, op):
        """:meth:`supports` restricted to ``OpClass.DSP``."""
        return (T["exists"] > 0) & (T["dsp_count"] > 0)

    def supports_special(self, T, op):
        """:meth:`supports` restricted to ``OpClass.SPECIAL``."""
        prec_ok = self.supports_precision(T, op["precision"])
        has_dsp = T["dsp_count"] > 0
        spec_ok = self.sfu_native(T, op) \
            | ((op["op_type"] == int(OpType.FFT)) & (T["num_macs"] > 0)
               & prec_ok) \
            | has_dsp
        return (T["exists"] > 0) & spec_ok


@functools.lru_cache(maxsize=32)
def _cached_model(calib: CalibrationTable, backend: str) -> CostModel:
    if backend == "numpy":
        return CostModel(calib, np)
    import jax.numpy as jnp  # deferred: the oracle never pays the import
    return CostModel(calib, jnp)


def cost_model(calib: CalibrationTable, xp=np) -> CostModel:
    """Cached CostModel factory; ``xp`` is ``numpy`` or ``jax.numpy``."""
    return _cached_model(calib, "numpy" if xp is np else "jax")


# =============================================================================
# activation cache (§3.3.4): byte- and slot-bounded FIFO, Python reference
# =============================================================================

class ActivationCache:
    """Per-tile FIFO activation cache.

    Holds at most ``ACT_CACHE_SLOTS`` producer outputs totalling at most
    ``cap_bytes``; inserting evicts oldest-first until the new output fits
    (outputs larger than the capacity are never inserted).  Mirrored
    bitwise by ``simulator.batched.fifo_insert`` — keep the two in sync.
    """

    def __init__(self, tile_index: int, cap_bytes: float,
                 slots: int = ACT_CACHE_SLOTS):
        self.tile_index = tile_index
        self.cap = cap_bytes
        self.slots = slots
        self.entries: collections.deque = collections.deque()  # (op, bytes)
        self.used = 0.0

    def insert(self, op_idx: int, nbytes: float,
               cached_at: Dict[int, int]) -> None:
        """Insert ``op_idx``'s output, updating the shared op->tile map."""
        if nbytes > self.cap:
            return
        while self.entries and (self.used + nbytes > self.cap
                                or len(self.entries) == self.slots):
            old_op, old_b = self.entries.popleft()
            self.used -= old_b
            cached_at.pop(old_op, None)
        self.entries.append((op_idx, nbytes))
        self.used += nbytes
        cached_at[op_idx] = self.tile_index
