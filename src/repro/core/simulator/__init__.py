"""Heterogeneity-aware analytical simulator (paper §3.3).

Module map — two execution backends around one shared cost model:

``costs``        — backend-neutral per-module cycle/energy formulas
                   (Eqs. 2/4-6) written against an array namespace ``xp``
                   (numpy or jax.numpy), plus the byte- and slot-bounded
                   FIFO activation-cache semantics (§3.3.4).  Every
                   backend below executes THIS code, so the math cannot
                   drift between them.
``modules``      — scalar/TileTemplate-typed wrappers over ``costs`` kept
                   for the historical per-module entry points.
``tile``         — ``TileSim``: routes one compiled operator through the
                   MAC / DSP / Special-Function path of one tile.
``area``         — analytical area model (Eq. 7).
``orchestrator`` — ``ChipSim``, the *reference oracle*: per-operator
                   Python walk of a compiled plan with dynamic DRAM
                   bandwidth sharing, FIFO activation caching, NoC
                   transfers, power gating, Eq. 3 splits.  Keeps the rich
                   outputs (per-op trace, per-tile breakdowns, chrome
                   trace).
``batched``      — the *fast path*: the same orchestration as jittable
                   array ops over an SoA plan op-table
                   (``ir.PlanTensor``, lowered by
                   ``compiler.pipeline.lower_plan``), ``vmap``-ed across
                   the candidate axis.  >= 5x (measured ~50x) over the
                   per-candidate oracle on a 64-genome population
                   (benchmarks/perf_micro.py).
``outputs``      — result dataclasses, per-module breakdowns, chrome
                   trace, and the ``SimResult.golden_dict`` snapshot.

Oracle-vs-batched parity is pinned three ways: frozen golden traces
(tests/golden/*.json — regenerate with ``pytest --regen-golden`` after an
*intentional* cost-model change; the comparator prints the numeric diff),
property-based random (graph x chip) agreement
(tests/test_batched_parity.py), and the full 20-workload sweep under
``-m slow``.  The DSE search heuristic (``dse.batch_eval``) shares the
same ``costs`` formulas and FIFO cache but re-derives placements in-scan.

``batched`` is intentionally NOT imported here: importing the oracle must
not pull in jax/XLA.
"""
from .outputs import OpResult, TileBreakdown, SimResult
from .area import tile_area, chip_area
from .costs import ActivationCache, CostModel, cost_model
from .tile import TileSim
from .orchestrator import ChipSim, simulate

__all__ = [
    "OpResult", "TileBreakdown", "SimResult", "tile_area", "chip_area",
    "ActivationCache", "CostModel", "cost_model", "TileSim", "ChipSim",
    "simulate",
]
