"""Heterogeneity-aware analytical simulator (paper §3.3).

``modules``      — per-module cycle/energy models (MAC engines, DRAM, SRAM,
                   IRF/ORF, DSP, SFU; Eqs. 4-5).
``tile``         — routes one compiled operator through the MAC / DSP /
                   Special-Function execution path of one tile.
``area``         — analytical area model (Eq. 7).
``orchestrator`` — chip-level schedule execution: dynamic DRAM bandwidth
                   sharing, cross-tile activation caching, NoC transfers,
                   clock/power gating, makespan + Eq. 6 energy.
``outputs``      — result dataclasses, per-module breakdowns, chrome trace.
"""
from .outputs import OpResult, TileBreakdown, SimResult
from .area import tile_area, chip_area
from .tile import TileSim
from .orchestrator import ChipSim, simulate

__all__ = [
    "OpResult", "TileBreakdown", "SimResult", "tile_area", "chip_area",
    "TileSim", "ChipSim", "simulate",
]
