"""Per-tile module pipeline (paper §3.3.1-§3.3.3).

Routes one compiled operator through one of the three execution paths
(MAC, DSP, Special-Function) of a tile, accumulating cycles and energy at
each of the seven modules, and combines them with the total-cycle model
(Eq. 5).  Operators that land on a tile lacking their natural unit are
*lowered* (paper §2.5): FFT onto the MAC array as an O(N^2) DFT matmul,
LIF and polynomial onto the DSP with their sequential multipliers, MAC ops
onto the DSP when a Special-Function tile must run a stray matmul.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from ..arch import TileTemplate, SFU_FFT, SFU_SNN, SFU_POLY
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import OpClass, OpNode, OpType, PRECISION_BYTES
from . import modules
from .outputs import EnergyBreakdown

__all__ = ["TileSim", "OpExec"]

_SFU_FOR_OP = {
    int(OpType.FFT): SFU_FFT,
    int(OpType.SNN_LIF): SFU_SNN,
    int(OpType.POLY): SFU_POLY,
}


@dataclasses.dataclass
class OpExec:
    cycles: float
    seconds: float
    energy: EnergyBreakdown
    path: str
    roofline: str
    dram_rd: float
    dram_wr: float


class TileSim:
    """Analytical model of one tile instance."""

    def __init__(self, tile: TileTemplate, calib: CalibrationTable = DEFAULT_CALIB,
                 cache_frac: float = 0.25):
        self.tile = tile
        self.calib = calib
        self.cache_frac = cache_frac
        self.clock_hz = tile.clock_mhz * 1e6
        # SRAM staging bandwidth: banks x 16-byte word per cycle
        self.sram_bpc = max(tile.sram_banks, 1) * 16.0

    # ------------------------------------------------------------------ API
    def supports(self, op: OpNode) -> bool:
        """Compatibility filter (paper §3.2): op-type and precision.

        The precision set is a property of the MAC datapath; the vector DSP
        and SFUs are FP16-native in every tile, so only ops that execute on
        the MAC array check precision."""
        t = self.tile
        cls = op.op_cls
        if cls == OpClass.MAC:
            # MAC array when the datapath matches; any DSP can lower a
            # stray mismatched-precision matmul (slowly)
            if t.num_macs > 0 and t.supports_precision(op.precision):
                return True
            return t.dsp_count > 0
        if cls == OpClass.DSP:
            return t.dsp_count > 0
        # SPECIAL: native SFU, MAC lowering (FFT), or DSP lowering
        need = _SFU_FOR_OP[int(op.op_type)]
        if t.sfu_mask & need:
            return True
        if (int(op.op_type) == int(OpType.FFT) and t.num_macs > 0
                and t.supports_precision(op.precision)):
            return True
        return t.dsp_count > 0

    def roofline_cycles(self, op: OpNode, bw_gbps: float) -> float:
        """Mapper's cycle estimate (Eq. 2): max of compute- and
        bandwidth-bound counts.  Cheap, used for placement decisions."""
        t = self.tile
        total_bytes = op.bytes_in + op.bytes_w + op.bytes_out
        bpc = bw_gbps * 1e9 / self.clock_hz
        c_bw = total_bytes / max(bpc, 1e-9)
        if op.op_cls == OpClass.MAC:
            if t.num_macs > 0 and t.supports_precision(op.precision):
                eta = self.calib.eta(int(t.sparsity), op.act_sparsity, op.w_sparsity)
                c_cmp = op.macs / (t.num_macs * eta)
            else:  # DSP lowering of a stray matmul (must match execute())
                lanes = float(max(t.dsp_count * t.dsp_simd, 1))
                c_cmp = math.ceil(2.0 * op.macs / lanes)
        elif op.op_cls == OpClass.SPECIAL:
            c_cmp, _ = self._special_cycles_energy(op)
        else:
            c_cmp, _ = modules.dsp_cycles_energy(
                t, int(op.op_type), float(op.elems), float(op.seq_len), self.calib)
        return max(c_cmp, c_bw)

    def execute(self, op: OpNode, bw_gbps: float, dram_rd: float,
                dram_wr: float) -> OpExec:
        """Full seven-module execution (Eq. 4-6).

        ``dram_rd`` / ``dram_wr`` are the effective DRAM bytes after the
        orchestrator's cross-tile activation-cache adjustment (§3.3.4).
        """
        t = self.tile
        cls = op.op_cls
        e = EnergyBreakdown()
        bpe = float(PRECISION_BYTES[op.precision])

        if cls == OpClass.MAC and t.num_macs > 0 \
                and t.supports_precision(op.precision):
            path = "MAC"
            c_cmp = self._mac_compute(op, e, bpe)
            c_mem = self._mac_sram(op, e, bpe)
        elif cls == OpClass.SPECIAL:
            path, c_cmp, c_mem = self._special(op, e, bpe)
        elif cls == OpClass.MAC:
            # stray matmul on a Special-Function tile (or a precision-
            # mismatched MAC tile): DSP lowering at 2 lane-ops per MAC
            path = "DSP"
            lanes = float(max(t.dsp_count * t.dsp_simd, 1))
            lane_ops = 2.0 * op.macs
            c_cmp = math.ceil(lane_ops / lanes)
            e.dsp += lane_ops * self.calib.e_dsp_pj_per_lane_op
            c_mem = self._stream_sram(op, e)
        else:
            path = "DSP"
            c_cmp, e_dsp = modules.dsp_cycles_energy(
                t, int(op.op_type), float(op.elems), float(op.seq_len), self.calib)
            e.dsp += e_dsp
            c_mem = self._stream_sram(op, e)

        c_dram, e_dram = modules.dram_cycles_energy(
            dram_rd, dram_wr, bw_gbps, self.clock_hz, self.calib)
        e.dram += e_dram
        # load/store port DMA: 64 B/cycle each direction
        c_lp = math.ceil(dram_rd / 64.0)
        c_sp = math.ceil(dram_wr / 64.0)

        # Eq. 5: double-buffering overlaps compute, memory staging and DRAM
        if t.double_buffer:
            c_tot = max(c_cmp, c_mem, c_dram) + c_lp + c_sp
        else:
            c_tot = c_cmp + c_mem + c_dram + c_lp + c_sp
        roofline = "compute" if c_cmp >= max(c_mem, c_dram) else "memory"
        return OpExec(cycles=c_tot, seconds=c_tot / self.clock_hz, energy=e,
                      path=path, roofline=roofline, dram_rd=dram_rd,
                      dram_wr=dram_wr)

    # ------------------------------------------------------------- MAC path
    def _mac_compute(self, op: OpNode, e: EnergyBreakdown, bpe: float) -> float:
        t = self.tile
        eta = self.calib.eta(int(t.sparsity), op.act_sparsity, op.w_sparsity)
        m_t, k_t, n_t = modules.mac_tiling(t, op.m, op.k, op.n, bpe, self.cache_frac)
        self._last_tiling = (m_t, k_t, n_t)
        c_cmp = modules.mac_cycles(t, op.m, op.k, op.n, eta, m_t, k_t, n_t)
        eff_macs = op.macs / eta  # sparsity-aware MAC count (§3.3.1)
        e.compute += eff_macs * self.calib.mac_energy(
            int(op.precision), int(t.engine), int(t.max_precision))
        return c_cmp

    def _mac_sram(self, op: OpNode, e: EnergyBreakdown, bpe: float) -> float:
        t = self.tile
        m_t, k_t, n_t = self._last_tiling
        df = modules.pick_dataflow(t, op.m, op.k, op.n)
        in_b, w_b, out_b = modules.sram_traffic(df, op.m, op.k, op.n, bpe,
                                                m_t, k_t, n_t)
        e.sram += (in_b + w_b + out_b) * self.calib.e_sram_pj_per_byte
        # IRF: writes padded to the 32 B write granularity, reads reduced by
        # activation sparsity (§3.3.1)
        irf_w = math.ceil(in_b / 32.0) * 32.0
        irf_r = in_b * (1.0 - min(op.act_sparsity, 0.95))
        e.irf += (irf_w + irf_r) * self.calib.e_irf_pj_per_byte
        # ORF: K-tile aware — first K-tile write-only, later read-modify-write
        tiles_k = math.ceil(op.k / k_t) if k_t > 0 else 1.0
        orf_b = op.m * op.n * modules.ACC_BYTES[0] * (2.0 * tiles_k - 1.0)
        e.orf += orf_b * self.calib.e_orf_pj_per_byte
        return math.ceil((in_b + w_b + out_b) / self.sram_bpc)

    # ------------------------------------------------------- DSP / SFU paths
    def _stream_sram(self, op: OpNode, e: EnergyBreakdown) -> float:
        """Streaming operators pass operands through SRAM once."""
        traffic = float(op.bytes_in + op.bytes_out)
        e.sram += traffic * self.calib.e_sram_pj_per_byte
        return math.ceil(traffic / self.sram_bpc)

    def _special_cycles_energy(self, op: OpNode):
        """Cycle/energy for a special op on THIS tile (native or lowered)."""
        t = self.tile
        need = _SFU_FOR_OP[int(op.op_type)]
        if t.sfu_mask & need:
            return modules.sfu_cycles_energy(
                t, int(op.op_type), float(op.elems), float(op.fft_n),
                float(op.poly_degree), float(op.snn_timesteps), self.calib)
        return self._lowered_cycles_energy(op)

    def _lowered_cycles_energy(self, op: OpNode):
        """Lowered cost (paper §2.5): FFT->MAC O(N^2); LIF/poly->DSP with
        sequential multipliers."""
        t = self.tile
        lanes = float(max(t.dsp_count * t.dsp_simd, 1))
        if (int(op.op_type) == int(OpType.FFT) and t.num_macs > 0
                and t.supports_precision(op.precision)):
            n = max(float(op.fft_n), 2.0)
            transforms = max(float(op.elems) / n, 1.0)
            macs = 4.0 * n * n * transforms  # complex DFT as real matmuls
            eta = 1.0
            c = macs / max(t.num_macs, 1)
            energy = macs * self.calib.mac_energy(
                int(op.precision), int(t.engine), int(t.max_precision))
            return c, energy
        if int(op.op_type) == int(OpType.SNN_LIF):
            # branchy integrate-fire-reset vectorizes poorly on a SIMD DSP
            # (divergence + membrane-state round-trips): ~4x lane-efficiency
            # loss — this is why LIF eats ~47 % of SNN-VGG9 on commercial
            # NPUs (paper Fig. 3) while a dedicated unit is a few gates
            tsteps = max(float(op.snn_timesteps), 1.0)
            lane_ops = float(op.elems) * 4.0  # mul, add, cmp, reset per step
            c = tsteps * (math.ceil(lane_ops / (lanes / 4.0))
                          + math.ceil(float(op.elems) * 8.0 / self.sram_bpc))
            return c, lane_ops * tsteps * self.calib.e_dsp_pj_per_lane_op
        if int(op.op_type) == int(OpType.POLY):
            d = max(float(op.poly_degree), 1.0)
            lane_ops = float(op.elems) * 2.0
            # a long MAC chain hopping through SRAM at every step (§2.5)
            c = d * (math.ceil(lane_ops / lanes)
                     + math.ceil(float(op.elems) * 2.0 / self.sram_bpc))
            return c, d * lane_ops * self.calib.e_dsp_pj_per_lane_op
        if int(op.op_type) == int(OpType.FFT):
            # last resort: DSP butterfly emulation
            n = max(float(op.fft_n), 2.0)
            lane_ops = float(op.elems) * 10.0 * math.log2(n)
            c = math.ceil(lane_ops / lanes)
            return c, lane_ops * self.calib.e_dsp_pj_per_lane_op
        raise ValueError(f"cannot lower op {op.op_type} on tile {t.name}")

    def _special(self, op: OpNode, e: EnergyBreakdown, bpe: float):
        t = self.tile
        need = _SFU_FOR_OP[int(op.op_type)]
        if t.sfu_mask & need:
            c_cmp, e_spec = modules.sfu_cycles_energy(
                t, int(op.op_type), float(op.elems), float(op.fft_n),
                float(op.poly_degree), float(op.snn_timesteps), self.calib)
            e.special += e_spec
            return "SFU", c_cmp, self._stream_sram(op, e)
        c_cmp, e_low = self._lowered_cycles_energy(op)
        if int(op.op_type) == int(OpType.FFT) and t.num_macs > 0:
            e.compute += e_low
            path = "MAC"
            # DFT twiddle matrix streamed as weights
            n = max(float(op.fft_n), 2.0)
            e.sram += 2.0 * n * n * bpe * self.calib.e_sram_pj_per_byte
        else:
            e.dsp += e_low
            path = "DSP"
        return path, c_cmp, self._stream_sram(op, e)
