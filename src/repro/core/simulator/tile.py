"""Per-tile module pipeline (paper §3.3.1-§3.3.3) — the reference oracle.

Routes one compiled operator through one of the three execution paths
(MAC, DSP, Special-Function) of a tile, accumulating cycles and energy at
each of the seven modules, and combines them with the total-cycle model
(Eq. 5).  Operators that land on a tile lacking their natural unit are
*lowered* (paper §2.5): FFT onto the MAC array as an O(N^2) DFT matmul,
LIF and polynomial onto the DSP with their sequential multipliers, MAC ops
onto the DSP when a Special-Function tile must run a stray matmul.

All arithmetic is delegated to the backend-neutral ``simulator.costs``
CostModel — the identical code the batched plan executor and the jitted
DSE evaluator run under vmap — so the oracle and the array backends share
one set of calibrated formulas by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..arch import TileTemplate, SFU_FFT, SFU_SNN, SFU_POLY
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import OpClass, OpNode, OpType
from .costs import cost_model
from .modules import tile_cost_dict
from .outputs import EnergyBreakdown

__all__ = ["TileSim", "OpExec", "op_cost_dict"]

_SFU_FOR_OP = {
    int(OpType.FFT): SFU_FFT,
    int(OpType.SNN_LIF): SFU_SNN,
    int(OpType.POLY): SFU_POLY,
}

_PATH_NAME = {0: "MAC", 1: "DSP", 2: "SFU"}
_ROOFLINE_NAME = {0: "compute", 1: "memory"}


def op_cost_dict(op: OpNode) -> Dict[str, float]:
    """OpNode -> the scalar field dict the shared CostModel reads."""
    return {
        "op_type": int(op.op_type),
        "op_cls": int(op.op_cls),
        "macs": float(op.macs),
        "elems": float(op.elems),
        "m": float(op.m),
        "k": float(op.k),
        "n": float(op.n),
        "precision": int(op.precision),
        "bytes_in": float(op.bytes_in),
        "bytes_w": float(op.bytes_w),
        "bytes_out": float(op.bytes_out),
        "act_sparsity": float(op.act_sparsity),
        "w_sparsity": float(op.w_sparsity),
        "fft_n": float(op.fft_n),
        "poly_degree": float(op.poly_degree),
        "snn_timesteps": float(op.snn_timesteps),
        "seq_len": float(op.seq_len),
    }


@dataclasses.dataclass
class OpExec:
    cycles: float
    seconds: float
    energy: EnergyBreakdown
    path: str
    roofline: str
    dram_rd: float
    dram_wr: float
    dram_bytes: float = 0.0  # burst-aligned rd+wr as charged (Eq. 5 stage)


class TileSim:
    """Analytical model of one tile instance (scalar CostModel frontend)."""

    def __init__(self, tile: TileTemplate, calib: CalibrationTable = DEFAULT_CALIB,
                 cache_frac: float = 0.25):
        self.tile = tile
        self.calib = calib
        self.cache_frac = cache_frac
        self.clock_hz = tile.clock_mhz * 1e6
        # SRAM staging bandwidth: banks x 16-byte word per cycle
        self.sram_bpc = max(tile.sram_banks, 1) * 16.0
        self._cm = cost_model(calib)
        self._T = tile_cost_dict(tile, cache_frac)

    # ------------------------------------------------------------------ API
    def supports(self, op: OpNode) -> bool:
        """Compatibility filter (paper §3.2): op-type and precision.

        The precision set is a property of the MAC datapath; the vector DSP
        and SFUs are FP16-native in every tile, so only ops that execute on
        the MAC array check precision."""
        return bool(self._cm.supports(self._T, op_cost_dict(op)))

    def roofline_cycles(self, op: OpNode, bw_gbps: float) -> float:
        """Mapper's cycle estimate (Eq. 2): max of compute- and
        bandwidth-bound counts.  Cheap, used for placement decisions."""
        return float(self._cm.roofline_cycles(self._T, op_cost_dict(op),
                                              float(bw_gbps)))

    def execute(self, op: OpNode, bw_gbps: float, dram_rd: float,
                dram_wr: float) -> OpExec:
        """Full seven-module execution (Eq. 4-6).

        ``dram_rd`` / ``dram_wr`` are the effective DRAM bytes after the
        orchestrator's cross-tile activation-cache adjustment (§3.3.4).
        """
        out = self._cm.execute(self._T, op_cost_dict(op), float(bw_gbps),
                               float(dram_rd), float(dram_wr),
                               cache_frac=self.cache_frac)
        e = EnergyBreakdown(
            compute=float(out["e_compute"]),
            dram=float(out["e_dram"]),
            sram=float(out["e_sram"]),
            irf=float(out["e_irf"]),
            orf=float(out["e_orf"]),
            dsp=float(out["e_dsp"]),
            special=float(out["e_special"]),
        )
        cycles = float(out["cycles"])
        return OpExec(cycles=cycles, seconds=cycles / self.clock_hz, energy=e,
                      path=_PATH_NAME[int(out["path"])],
                      roofline=_ROOFLINE_NAME[int(out["roofline"])],
                      dram_rd=dram_rd, dram_wr=dram_wr,
                      dram_bytes=float(out["dram_bytes"]))
