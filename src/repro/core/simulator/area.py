"""Analytical area model (paper Eq. 7).

A_tile = N_MAC * max_p A_MAC(p) + A_SRAM + A_DSP + A_spec + A_ports

Per-MAC area is taken over the *largest supported precision* — a
multi-precision MAC carries the wide datapath.  IRF/ORF area folds into
A_ports.  Chip area adds the NoC and omits floorplan dead space (paper §7;
the RTL gating study bounds the mismatch to ~8 %).
"""
from __future__ import annotations

from typing import Dict

from ..arch import ChipConfig, TileTemplate, SFU_FFT, SFU_SNN, SFU_POLY
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB

__all__ = ["tile_area", "chip_area", "area_breakdown", "noc_area_scale"]


def tile_area(tile: TileTemplate, calib: CalibrationTable = DEFAULT_CALIB) -> float:
    return sum(area_breakdown(tile, calib).values())


def area_breakdown(tile: TileTemplate, calib: CalibrationTable = DEFAULT_CALIB) -> Dict[str, float]:
    a_mac_unit = calib.mac_area(int(tile.max_precision), int(tile.engine))
    a_mac = tile.num_macs * a_mac_unit * calib.sparsity_a_mult[int(tile.sparsity)]
    a_sram = tile.sram_kb * calib.a_sram_mm2_per_kb
    a_dsp = tile.dsp_count * tile.dsp_simd * calib.a_dsp_mm2_per_lane
    a_spec = 0.0
    if tile.sfu_mask & SFU_FFT:
        a_spec += calib.a_fft_mm2
    if tile.sfu_mask & SFU_SNN:
        a_spec += calib.a_lif_mm2
    if tile.sfu_mask & SFU_POLY:
        a_spec += calib.a_poly_mm2
    # load/store ports + PPM + IRF/ORF + control (fitted; see calibrate/asap7)
    a_ports = calib.a_ports_base_mm2 + (tile.rows + tile.cols) * calib.a_ports_per_lane_mm2
    return {"mac": a_mac, "sram": a_sram, "dsp": a_dsp, "special": a_spec,
            "ports": a_ports}


def noc_area_scale(noc_bytes_per_cycle: float, torus: bool) -> float:
    """Interconnect area multiplier on the per-tile NoC term: router/link
    width grows with flit width (64 B/cycle is the calibrated baseline),
    and a torus carries the wrap-around links."""
    return (0.5 + 0.5 * noc_bytes_per_cycle / 64.0) * (1.25 if torus else 1.0)


def chip_area(chip: ChipConfig, calib: CalibrationTable = DEFAULT_CALIB) -> float:
    a = sum(tile_area(t, calib) * c for t, c in chip.tiles)
    a = a + chip.num_tiles * calib.a_noc_mm2_per_tile \
        * noc_area_scale(chip.noc_bytes_per_cycle, chip.torus)
    return a + (chip.dram_channels - 1) * calib.a_dram_phy_mm2
