"""Per-module cycle and energy models (paper §3.3.1) — reference wrappers.

The formulas themselves live in ``repro.core.simulator.costs`` as
backend-neutral array code shared verbatim by this reference path, the
batched plan executor (``simulator.batched``) and the jitted DSE scan
evaluator (``dse.batch_eval``) — the three backends cannot drift because
they execute the same code.  This module keeps the historical
scalar/TileTemplate-typed entry points used by ``TileSim`` and tests.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import numpy as np

from ..arch import Dataflow, Engine, Sparsity, TileTemplate
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import OpType, PRECISION_BYTES
from .costs import (ACC_BYTES, CACHE_FRAC, DSP_OPS_PER_ELEM, cost_model)

# mac_tiling / mac_cycles / sram_traffic are calibration-free — any table
# binds the same formulas; reuse one cached model.
DEFAULT_CALIB_FOR_TILING = DEFAULT_CALIB

__all__ = [
    "DSP_OPS_PER_ELEM", "ACC_BYTES", "mac_tiling", "mac_cycles",
    "sram_traffic", "dsp_cycles_energy", "sfu_cycles_energy",
    "dram_cycles_energy", "pick_dataflow", "tile_cost_dict",
]

_BURST = 64.0  # DRAM burst alignment (bytes)


def tile_cost_dict(tile: TileTemplate, cache_frac: float = CACHE_FRAC
                   ) -> Dict[str, float]:
    """TileTemplate -> the scalar field dict the shared CostModel reads."""
    return {
        "exists": 1.0,
        "num_macs": float(tile.num_macs),
        "rows": float(tile.rows),
        "cols": float(tile.cols),
        "engine": float(int(tile.engine)),
        "prec_mask": float(tile.precision_mask),
        "asym_mac": float(int(tile.asym_mac)),
        "sparsity": float(int(tile.sparsity)),
        "dataflow": float(int(tile.dataflow)),
        "sram_kb": float(tile.sram_kb),
        "dsp_lanes": float(tile.dsp_count * tile.dsp_simd),
        "dsp_count": float(tile.dsp_count),
        "sfu_mask": float(tile.sfu_mask),
        "sfu_parallel": float(tile.sfu_parallel),
        "double_buffer": float(tile.double_buffer),
        "pipeline_depth": float(tile.pipeline_depth),
        "clock_hz": tile.clock_mhz * 1e6,
        "sram_bpc": max(tile.sram_banks, 1) * 16.0,
        "max_prec": float(int(tile.max_precision)),
        "cache_cap": tile.sram_kb * 1024.0 * cache_frac,
    }


def pick_dataflow(tile: TileTemplate, m: float, k: float, n: float) -> Dataflow:
    """AUTO rule (paper §3.2): OS when M*N exceeds both K*N and M*K by 4x."""
    if tile.dataflow != Dataflow.AUTO:
        return tile.dataflow
    if m * n > 4.0 * k * n and m * n > 4.0 * m * k:
        return Dataflow.OS
    return Dataflow.WS


def mac_tiling(tile: TileTemplate, m: float, k: float, n: float,
               bpe: float, cache_frac: float = 0.25) -> Tuple[float, float, float]:
    """SRAM-budget tiling pass (paper §3.3.1): returns (m_t, k_t, n_t);
    ``cache_frac`` of SRAM is reserved for the activation cache (§3.3.4)."""
    cm = cost_model(DEFAULT_CALIB_FOR_TILING)
    T = tile_cost_dict(tile, cache_frac)
    m_t, k_t, n_t = cm.mac_tiling(T, float(m), float(k), float(n),
                                  float(bpe), cache_frac)
    return float(m_t), float(k_t), float(n_t)


def mac_cycles(tile: TileTemplate, m: float, k: float, n: float,
               eta: float, m_t: float, k_t: float, n_t: float) -> float:
    """Engine-specific compute-cycle model (Eq. 4)."""
    cm = cost_model(DEFAULT_CALIB_FOR_TILING)
    return float(cm.mac_cycles(tile_cost_dict(tile), float(m), float(k),
                               float(n), float(eta), float(m_t), float(k_t),
                               float(n_t)))


def sram_traffic(dataflow: Dataflow, m: float, k: float, n: float,
                 bpe: float, m_t: float, k_t: float, n_t: float) -> Tuple[float, float, float]:
    """Tiling-aware SRAM traffic (bytes in, weights, out) from dataflow
    reuse (WS / OS / RS; see CostModel.sram_traffic)."""
    cm = cost_model(DEFAULT_CALIB_FOR_TILING)
    T = {"dataflow": float(int(dataflow))}
    in_b, w_b, out_b, _ = cm.sram_traffic(T, float(m), float(k), float(n),
                                          float(bpe), float(m_t), float(k_t),
                                          float(n_t))
    return float(in_b), float(w_b), float(out_b)


def dsp_cycles_energy(tile: TileTemplate, op_type: int, elems: float,
                      seq_len: float, calib: CalibrationTable) -> Tuple[float, float]:
    """Vector-DSP path; the SSM scan carries a sequence-length sequential
    multiplier (paper §3.3.1)."""
    cyc, en = cost_model(calib).dsp_cycles_energy(
        tile_cost_dict(tile), int(op_type), float(elems), float(seq_len))
    return float(cyc), float(en)


def sfu_cycles_energy(tile: TileTemplate, op_type: int, elems: float,
                      fft_n: float, poly_degree: float, snn_t: float,
                      calib: CalibrationTable) -> Tuple[float, float]:
    """Special-function path (paper §3.3.1): radix-2 FFT N log2 N cycles,
    LIF ceil(N/N_par)*T cycles, Horner polynomial N*d cycles."""
    cyc, en = cost_model(calib).sfu_cycles_energy(
        tile_cost_dict(tile), int(op_type), float(elems), float(fft_n),
        float(poly_degree), float(snn_t))
    return float(cyc), float(en)


def dram_cycles_energy(bytes_rd: float, bytes_wr: float, bw_gbps: float,
                       clock_hz: float, calib: CalibrationTable) -> Tuple[float, float]:
    """Burst-aligned DRAM staging at the tile's (dynamically shared)
    bandwidth, plus the LPDDR5 access latency."""
    total = 0.0
    for b in (bytes_rd, bytes_wr):
        if b > 0:
            total += math.ceil(b / _BURST) * _BURST
    if total == 0:
        return 0.0, 0.0
    bytes_per_cycle = bw_gbps * 1e9 / clock_hz
    cycles = total / max(bytes_per_cycle, 1e-9) + calib.dram_latency_cycles
    return cycles, total * calib.e_dram_pj_per_byte
