"""Per-module cycle and energy models (paper §3.3.1).

Every function here is a pure float->float model of one hardware module,
shared by the reference tile simulator.  The jitted DSE batch evaluator
(``repro.core.dse.batch_eval``) and the Pallas kernel
(``repro.kernels.dse_eval``) mirror this math 1:1 and are pinned to it by
equivalence tests (tests/test_batch_eval.py) — treat this file as the
oracle when editing either.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

from ..arch import Dataflow, Engine, Sparsity, TileTemplate
from ..calibrate.asap7 import CalibrationTable
from ..ir import OpType, PRECISION_BYTES

__all__ = [
    "DSP_OPS_PER_ELEM", "ACC_BYTES", "mac_tiling", "mac_cycles",
    "sram_traffic", "dsp_cycles_energy", "sfu_cycles_energy",
    "dram_cycles_energy", "pick_dataflow",
]

# Lane-ops each DSP-class operator spends per element (14-instruction SIMD
# ISA of §3.3.1: vadd, vmul, vexp, vreduce, vlut, ...).
DSP_OPS_PER_ELEM: Dict[int, float] = {
    int(OpType.ADD): 1.0,
    int(OpType.MUL): 1.0,
    int(OpType.SOFTMAX): 5.0,      # vmax, vsub+vexp, vreduce, vdiv
    int(OpType.LAYERNORM): 7.0,
    int(OpType.RMSNORM): 5.0,
    int(OpType.GELU): 8.0,         # tanh polynomial
    int(OpType.SILU): 5.0,
    int(OpType.RELU): 1.0,
    int(OpType.SIGMOID): 4.0,
    int(OpType.POOL): 1.0,
    int(OpType.REDUCE): 1.0,
    int(OpType.GATHER): 2.0,       # address gen + move
    int(OpType.SCATTER): 3.0,      # address gen + read-modify-write
    int(OpType.SSM_SCAN): 6.0,     # per-element recurrence work
    int(OpType.ROPE): 4.0,
}

# Accumulator width (partial sums) per input precision index.
ACC_BYTES = (4.0, 4.0, 4.0, 4.0, 4.0)

_BURST = 64.0  # DRAM burst alignment (bytes)


def pick_dataflow(tile: TileTemplate, m: float, k: float, n: float) -> Dataflow:
    """AUTO rule (paper §3.2): OS when M*N exceeds both K*N and M*K by 4x."""
    if tile.dataflow != Dataflow.AUTO:
        return tile.dataflow
    if m * n > 4.0 * k * n and m * n > 4.0 * m * k:
        return Dataflow.OS
    return Dataflow.WS


def mac_tiling(tile: TileTemplate, m: float, k: float, n: float,
               bpe: float, cache_frac: float = 0.25) -> Tuple[float, float, float]:
    """SRAM-budget tiling pass: decompose (M,K,N) so the working set
    (weights + double-buffered activations + output tile) fits the
    working portion of the per-tile SRAM (paper §3.3.1).

    Returns (m_t, k_t, n_t).  ``cache_frac`` of SRAM is reserved for the
    cross-tile activation cache (§3.3.4).
    """
    budget = tile.sram_kb * 1024.0 * (1.0 - cache_frac)
    m_t = min(m, float(tile.rows))
    n_t = min(n, float(tile.cols))
    db = 2.0 if tile.double_buffer else 1.0
    acc = ACC_BYTES[0]
    out_bytes = m_t * n_t * acc
    denom = (m_t + n_t) * bpe * db
    k_fit = (budget - out_bytes) / max(denom, 1.0)
    k_t = max(min(k, k_fit), min(k, 16.0))
    return m_t, k_t, max(n_t, 1.0)


def mac_cycles(tile: TileTemplate, m: float, k: float, n: float,
               eta: float, m_t: float, k_t: float, n_t: float) -> float:
    """Engine-specific compute-cycle model.

    Systolic (Eq. 4):  C = sum_{n,k} [ D + sum_m (m_eff + k_eff + D - 2) ]
    with pipeline depth D; sparsity skipping shortens the streamed k_eff.
    Spatial/dot-product engines have no wavefront ramp; CIM halves the
    effective clock via the weight-write overhead (modelled as 2x cycles).
    """
    if m <= 0 or k <= 0 or n <= 0:
        return 0.0
    D = float(tile.pipeline_depth)
    n_tiles_n = math.ceil(n / n_t)
    n_tiles_k = math.ceil(k / k_t)
    n_tiles_m = math.ceil(m / m_t)
    # effective per-tile dims (average including the ragged last tile)
    m_eff = m / n_tiles_m
    k_eff = (k / n_tiles_k) / eta
    if tile.engine == Engine.SYSTOLIC:
        per_m = m_eff + k_eff + D - 2.0
        return n_tiles_n * n_tiles_k * (D + n_tiles_m * per_m)
    if tile.engine in (Engine.SPATIAL, Engine.DOT):
        ideal = (m * k * n / eta) / max(tile.num_macs, 1.0)
        # spatial arrays lose a mapping-efficiency factor on ragged tiles
        util = (m_eff / m_t) * (min(n, n_t) / n_t)
        return ideal / max(min(util, 1.0), 0.25) + D * n_tiles_n * n_tiles_k
    # CIM: mults happen in the array, but every k-tile swap rewrites the
    # bit-cells — throughput is half the digital systolic equivalent.
    ideal = (m * k * n / eta) / max(tile.num_macs, 1.0)
    return 2.0 * ideal + D * n_tiles_n * n_tiles_k


def sram_traffic(dataflow: Dataflow, m: float, k: float, n: float,
                 bpe: float, m_t: float, k_t: float, n_t: float) -> Tuple[float, float, float]:
    """Tiling-aware SRAM traffic (bytes in, weights, out) from dataflow reuse.

    WS: weights streamed once; activations re-read per n-tile; partial sums
        spill per extra k-tile (read-modify-write).
    OS: outputs resident; inputs re-read per n-tile, weights per m-tile.
    RS: row-stationary splits the re-read factors (Eyeriss-style balance).
    """
    tiles_m = math.ceil(m / m_t)
    tiles_k = math.ceil(k / k_t)
    tiles_n = math.ceil(n / n_t)
    acc = ACC_BYTES[0]
    if dataflow == Dataflow.WS:
        in_b = m * k * bpe * tiles_n
        w_b = k * n * bpe
        out_b = m * n * acc * (2.0 * tiles_k - 1.0)
    elif dataflow == Dataflow.OS:
        in_b = m * k * bpe * tiles_n
        w_b = k * n * bpe * tiles_m
        out_b = m * n * acc
    else:  # RS
        in_b = m * k * bpe * math.sqrt(tiles_n)
        w_b = k * n * bpe * math.sqrt(tiles_m)
        out_b = m * n * acc * math.sqrt(tiles_k)
    return in_b, w_b, out_b


def dsp_cycles_energy(tile: TileTemplate, op_type: int, elems: float,
                      seq_len: float, calib: CalibrationTable) -> Tuple[float, float]:
    """Vector-DSP path.  The SSM scan carries a sequence-length sequential
    multiplier (paper §3.3.1): only the per-step work parallelizes."""
    if tile.dsp_count <= 0 or elems <= 0:
        return 0.0, 0.0
    ops_pe = DSP_OPS_PER_ELEM.get(int(op_type), 2.0)
    lane_ops = elems * ops_pe
    lanes = float(tile.dsp_count * tile.dsp_simd)
    if int(op_type) == int(OpType.SSM_SCAN) and seq_len > 1:
        per_step = (elems / seq_len) * ops_pe
        cycles = seq_len * math.ceil(per_step / lanes)
    else:
        cycles = math.ceil(lane_ops / lanes)
    energy = lane_ops * calib.e_dsp_pj_per_lane_op
    return float(cycles), energy


def sfu_cycles_energy(tile: TileTemplate, op_type: int, elems: float,
                      fft_n: float, poly_degree: float, snn_t: float,
                      calib: CalibrationTable) -> Tuple[float, float]:
    """Special-function path (paper §3.3.1): radix-2 FFT N log2 N cycles,
    LIF ceil(N/N_par)*T cycles, Horner polynomial N*d cycles."""
    par = max(float(tile.sfu_parallel), 1.0)
    if op_type == int(OpType.FFT):
        n = max(fft_n, 2.0)
        transforms = max(elems / n, 1.0)
        lg = math.log2(n)
        cycles = transforms * math.ceil(n * lg / par)
        butterflies = transforms * (n / 2.0) * lg
        return cycles, butterflies * calib.e_fft_pj_per_butterfly
    if op_type == int(OpType.SNN_LIF):
        t = max(snn_t, 1.0)
        cycles = math.ceil(elems / par) * t
        return cycles, elems * t * calib.e_lif_pj_per_neuron_step
    if op_type == int(OpType.POLY):
        d = max(poly_degree, 1.0)
        cycles = elems * d / par
        return cycles, elems * d * calib.e_poly_pj_per_fma
    raise ValueError(f"not a special op: {op_type}")


def dram_cycles_energy(bytes_rd: float, bytes_wr: float, bw_gbps: float,
                       clock_hz: float, calib: CalibrationTable) -> Tuple[float, float]:
    """Burst-aligned DRAM staging at the tile's (dynamically shared)
    bandwidth, plus the LPDDR5 access latency."""
    total = 0.0
    for b in (bytes_rd, bytes_wr):
        if b > 0:
            total += math.ceil(b / _BURST) * _BURST
    if total == 0:
        return 0.0, 0.0
    bytes_per_cycle = bw_gbps * 1e9 / clock_hz
    cycles = total / max(bytes_per_cycle, 1e-9) + calib.dram_latency_cycles
    return cycles, total * calib.e_dram_pj_per_byte
