"""Simulator output records (paper §3.3.6)."""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

__all__ = ["EnergyBreakdown", "OpResult", "TileBreakdown", "SimResult"]

ENERGY_MODULES = (
    "compute", "dram", "sram", "irf", "orf", "dsp", "special", "noc", "leakage",
)


@dataclasses.dataclass
class EnergyBreakdown:
    """Per-module energy in pJ (Eq. 6 terms + NoC + leakage)."""

    compute: float = 0.0
    dram: float = 0.0
    sram: float = 0.0
    irf: float = 0.0
    orf: float = 0.0
    dsp: float = 0.0
    special: float = 0.0
    noc: float = 0.0
    leakage: float = 0.0
    fuse_savings: float = 0.0  # subtracted (E_fuse in Eq. 6)

    @property
    def total_pj(self) -> float:
        return (self.compute + self.dram + self.sram + self.irf + self.orf
                + self.dsp + self.special + self.noc + self.leakage
                - self.fuse_savings)

    def add(self, other: "EnergyBreakdown") -> None:
        for f in ENERGY_MODULES + ("fuse_savings",):
            setattr(self, f, getattr(self, f) + getattr(other, f))

    def as_dict(self) -> Dict[str, float]:
        d = {f: getattr(self, f) for f in ENERGY_MODULES}
        d["fuse_savings"] = self.fuse_savings
        d["total"] = self.total_pj
        return d


@dataclasses.dataclass
class OpResult:
    """One executed operator on one tile."""

    op_index: int
    tile_index: int
    path: str                    # "MAC" | "DSP" | "SFU"
    start_s: float
    finish_s: float
    cycles: float
    energy: EnergyBreakdown
    roofline: str = "compute"    # "compute" | "memory"
    split_tiles: int = 1         # >1 when the mapper split the op (Eq. 3)
    cache: str = "miss"          # "hit" | "noc" | "miss"

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.start_s


@dataclasses.dataclass
class TileBreakdown:
    tile_index: int
    template: str
    active_s: float = 0.0
    ops: int = 0
    macs: float = 0.0
    energy: EnergyBreakdown = dataclasses.field(default_factory=EnergyBreakdown)
    power_gated: bool = False

    def utilization(self, makespan_s: float) -> float:
        return self.active_s / makespan_s if makespan_s > 0 else 0.0


@dataclasses.dataclass
class SimResult:
    """End-to-end result for one (workload, architecture) pair (§3.3.6)."""

    workload: str
    arch: str
    latency_s: float
    energy_pj: float
    area_mm2: float
    peak_tops: float
    achieved_tops: float
    energy_breakdown: EnergyBreakdown
    tiles: List[TileBreakdown]
    ops: List[OpResult]
    total_macs: float
    arithmetic_intensity: float
    # §3.2 schedule mode this plan was emitted in.  For throughput-mode
    # runs ``pipeline`` carries the steady state: ``ii_s`` (initiation
    # interval), ``fill_latency_s`` (= one-batch makespan), the three
    # per-resource bounds (``ii_tile_bound_s`` / ``ii_dram_bound_s`` /
    # ``ii_noc_bound_s``), ``energy_ss_pj`` (per-inference energy with
    # leakage charged over II) and ``pipeline_depth``.
    mode: str = "latency"
    pipeline: Optional[Dict[str, float]] = None

    @property
    def avg_power_w(self) -> float:
        # pJ / s -> W is 1e-12
        return self.energy_pj * 1e-12 / self.latency_s if self.latency_s > 0 else 0.0

    @property
    def ii_s(self) -> float:
        """Throughput-mode initiation interval (= latency for latency-mode
        results, where every batch is a full serial replay)."""
        return self.pipeline["ii_s"] if self.pipeline else self.latency_s

    @property
    def tops_per_w(self) -> float:
        p = self.avg_power_w
        return self.achieved_tops / p if p > 0 else 0.0

    @property
    def tops_per_mm2(self) -> float:
        return self.achieved_tops / self.area_mm2 if self.area_mm2 > 0 else 0.0

    def golden_dict(self) -> Dict:
        """Full-precision snapshot for the golden-trace regression harness
        (tests/golden/): chip metrics, per-module energy, per-tile stats.
        Regenerate with ``pytest --regen-golden`` after an intentional
        cost-model change — the comparator then shows the numeric diff.
        Throughput-mode results additionally freeze the pipeline steady
        state (mode + II + bounds); latency-mode payloads are unchanged so
        pre-existing golden files stay valid."""
        d = {
            "workload": self.workload,
            "arch": self.arch,
            "latency_s": self.latency_s,
            "energy_pj": self.energy_pj,
            "area_mm2": self.area_mm2,
            "peak_tops": self.peak_tops,
            "achieved_tops": self.achieved_tops,
            "total_macs": self.total_macs,
            "arithmetic_intensity": self.arithmetic_intensity,
            "num_ops": len(self.ops),
            "energy_breakdown": self.energy_breakdown.as_dict(),
            "tiles": [
                {
                    "template": b.template,
                    "ops": b.ops,
                    "macs": b.macs,
                    "active_s": b.active_s,
                    "power_gated": bool(b.power_gated),
                    "energy_pj": b.energy.total_pj,
                }
                for b in self.tiles
            ],
        }
        if self.pipeline is not None:
            d["mode"] = self.mode
            d["pipeline"] = dict(self.pipeline)
        return d

    def summary(self) -> Dict[str, float]:
        out = {
            "workload": self.workload,
            "arch": self.arch,
            "latency_us": self.latency_s * 1e6,
            "energy_uj": self.energy_pj * 1e-6,
            "area_mm2": self.area_mm2,
            "avg_power_w": self.avg_power_w,
            "peak_tops": self.peak_tops,
            "achieved_tops": self.achieved_tops,
            "tops_per_w": self.tops_per_w,
            "tops_per_mm2": self.tops_per_mm2,
            "arithmetic_intensity": self.arithmetic_intensity,
        }
        if self.pipeline is not None:
            out["ii_us"] = self.pipeline["ii_s"] * 1e6
            out["energy_ss_uj"] = self.pipeline["energy_ss_pj"] * 1e-6
            out["pipeline_depth"] = self.pipeline["pipeline_depth"]
        return out

    # -- chrome trace (stands in for the paper's Perfetto output) ------------
    def chrome_trace(self, batches: int = 1) -> str:
        """Per-op timeline (one ``pid`` row group per batch).

        For throughput-mode results ``batches > 1`` replays the plan with
        the per-batch steady-state offset of II seconds, visualizing the
        pipelined overlap of successive inferences (the fill batch is
        ``pid 0``; batch ``b`` is shifted by ``b * II``)."""
        if batches > 1 and self.pipeline is None:
            raise ValueError(
                "multi-batch traces need a throughput-mode result "
                "(plan emitted with mode='throughput')")
        offset = self.pipeline["ii_s"] if batches > 1 else 0.0
        events = []
        for b in range(batches):
            for r in self.ops:
                events.append({
                    "name": f"op{r.op_index}:{r.path}",
                    "ph": "X",
                    "ts": (r.start_s + b * offset) * 1e6,
                    "dur": max(r.latency_s * 1e6, 1e-3),
                    "pid": b,
                    "tid": r.tile_index,
                    "args": {"cycles": r.cycles, "roofline": r.roofline,
                             "cache": r.cache, "split": r.split_tiles,
                             "batch": b},
                })
        return json.dumps({"traceEvents": events})
