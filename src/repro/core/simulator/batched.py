"""Batched plan executor: ``ChipSim.run`` re-expressed as jittable array ops.

Executes compiled plans (``PlanTensor`` op-tables: ops padded to a fixed
row count, placements as integer arrays) as one ``lax.scan`` over
operators, ``vmap``-ed across the candidate axis and jitted — so a
64-candidate GA population costs one device dispatch instead of 64 walks
of the per-operator Python loop.

Semantics are the *exact* orchestrator rules, not the search heuristic:

* dynamic DRAM bandwidth sharing (BW_total / N_active at each op start);
* the byte- and slot-bounded FIFO activation cache (§3.3.4) with local
  hit / cross-tile NoC DMA / DRAM miss accounting — ``fifo_insert`` below
  mirrors ``costs.ActivationCache`` bitwise;
* power gating of idle tiles at the 5 % residual;
* Eq. 3 split-op execution with the explicit NoC reduce cost.

Per-(op, tile) costs come from the shared ``costs.CostModel`` — literally
the same code the reference ``TileSim`` runs — so the two backends share
one set of calibrated formulas and parity reduces to the orchestration
above, pinned by golden traces (tests/golden/) and the hypothesis suite
(tests/test_batched_parity.py).  ``ChipSim`` remains the oracle: it keeps
the per-op trace, per-tile energy breakdowns, and chrome-trace output.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)  # cycle counts overflow f32 ULPs

import jax.numpy as jnp

from ..arch import MAX_TILES, ChipConfig
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import MAX_PREDS, PlanTensor
from .area import chip_area, tile_area
from .costs import (ACT_CACHE_SLOTS, CACHE_FRAC, FIDELITIES,
                    MAX_DRAM_CHANNELS, MAX_LINKS, OP_COST_KEYS, cost_model,
                    dram_channel_one_hot, grid_dims,
                    noc_transfer_energy_pj, noc_transfer_seconds,
                    pipeline_bounds, split_op_fields, steady_state_energy,
                    xy_route_link_mask)
from .orchestrator import SCHEDULE_MODES, noc_hops

__all__ = ["stack_chip_configs", "stack_plan_tables", "batch_simulate",
           "simulate_plans", "fifo_insert", "TILE_KEYS", "CHIP_KEYS"]

_F = jnp.float64

TILE_KEYS = ("exists", "num_macs", "rows", "cols", "engine", "prec_mask",
             "asym_mac", "sparsity", "dataflow", "sram_kb", "dsp_lanes",
             "dsp_count", "sfu_mask", "sfu_parallel", "double_buffer",
             "pipeline_depth", "clock_hz", "cache_cap", "sram_bpc",
             "area_mm2", "max_prec")
CHIP_KEYS = ("dram_gbps", "hops", "noc_bpc", "noc_base_cycles",
             "ref_clock_hz", "grid_w", "grid_h", "torus", "dram_channels")

_OP_TABLE_KEYS = OP_COST_KEYS + (
    "valid", "fused", "num_preds", "per_pred_bytes", "fused_lane_ops",
    "fused_refund_bytes")


# =============================================================================
# host-side stacking
# =============================================================================

def stack_chip_configs(chips: Sequence[ChipConfig],
                       calib: CalibrationTable = DEFAULT_CALIB
                       ) -> Dict[str, Dict[str, np.ndarray]]:
    """Stack chips into (B, MAX_TILES) tile / (B,) chip arrays.

    This is the single config-stacking implementation —
    ``dse.batch_eval.prepare_configs`` and the DSE engine's vectorized
    genome path both emit this exact layout.
    """
    B = len(chips)
    tile_f = {f: np.zeros((B, MAX_TILES)) for f in TILE_KEYS}
    chip_f = {f: np.zeros(B) for f in CHIP_KEYS + ("peak_tops", "chip_area")}
    for b, chip in enumerate(chips):
        inst = chip.instances()
        for i, t in enumerate(inst):
            tile_f["exists"][b, i] = 1.0
            tile_f["num_macs"][b, i] = t.num_macs
            tile_f["rows"][b, i] = t.rows
            tile_f["cols"][b, i] = t.cols
            tile_f["engine"][b, i] = int(t.engine)
            tile_f["prec_mask"][b, i] = t.precision_mask
            tile_f["asym_mac"][b, i] = int(t.asym_mac)
            tile_f["sparsity"][b, i] = int(t.sparsity)
            tile_f["dataflow"][b, i] = int(t.dataflow)
            tile_f["sram_kb"][b, i] = t.sram_kb
            tile_f["dsp_lanes"][b, i] = t.dsp_count * t.dsp_simd
            tile_f["dsp_count"][b, i] = t.dsp_count
            tile_f["sfu_mask"][b, i] = t.sfu_mask
            tile_f["sfu_parallel"][b, i] = t.sfu_parallel
            tile_f["double_buffer"][b, i] = float(t.double_buffer)
            tile_f["pipeline_depth"][b, i] = t.pipeline_depth
            tile_f["clock_hz"][b, i] = t.clock_mhz * 1e6
            tile_f["cache_cap"][b, i] = t.sram_kb * 1024.0 * CACHE_FRAC
            tile_f["sram_bpc"][b, i] = max(t.sram_banks, 1) * 16.0
            tile_f["area_mm2"][b, i] = tile_area(t, calib)
            tile_f["max_prec"][b, i] = int(t.max_precision)
        chip_f["dram_gbps"][b] = chip.dram_gbps
        chip_f["hops"][b] = noc_hops(chip.interconnect, len(inst))
        chip_f["noc_bpc"][b] = chip.noc_bytes_per_cycle
        chip_f["noc_base_cycles"][b] = chip.noc_base_cycles
        chip_f["ref_clock_hz"][b] = chip.ref_clock_mhz * 1e6
        gw, gh = grid_dims(np, float(len(inst)), chip.grid_aspect)
        chip_f["grid_w"][b] = gw
        chip_f["grid_h"][b] = gh
        chip_f["torus"][b] = float(chip.torus)
        chip_f["dram_channels"][b] = chip.dram_channels
        chip_f["peak_tops"][b] = sum(t.num_macs * t.clock_mhz * 1e6
                                     for t in inst) / 1e12
        chip_f["chip_area"][b] = chip_area(chip, calib)
    return {"tile": tile_f, "chip": chip_f}


def stack_plan_tables(tables: Sequence[PlanTensor]) -> Dict[str, np.ndarray]:
    """Stack per-candidate plan tables into (B, max_ops, ...) arrays.

    All tables must share ``max_ops`` (lower them with the same bucket);
    split masks are padded from each chip's ``num_tiles`` to MAX_TILES.
    """
    if not tables:
        raise ValueError("stack_plan_tables needs at least one plan table")
    caps = {t.max_ops for t in tables}
    if len(caps) != 1:
        raise ValueError(f"plan tables disagree on max_ops: {sorted(caps)}")
    (cap,) = caps
    modes = {t.mode for t in tables}
    if len(modes) != 1:
        raise ValueError(f"plan tables disagree on schedule mode: "
                         f"{sorted(modes)}")
    B = len(tables)
    out: Dict[str, np.ndarray] = {}
    for f in _OP_TABLE_KEYS:
        src = [t.aux[f] if f in t.aux else t.ops.arrays[f] for t in tables]
        out[f] = np.stack([np.asarray(a, np.float64) for a in src])
    out["preds"] = np.stack([t.ops.preds for t in tables]).astype(np.int32)
    out["owner"] = np.stack([t.owner for t in tables]).astype(np.int32)
    out["n_split"] = np.stack([t.n_split for t in tables]).astype(np.float64)
    out["split_axis"] = np.stack([t.split_axis
                                  for t in tables]).astype(np.int32)
    mask = np.zeros((B, cap, MAX_TILES), np.float64)
    for b, t in enumerate(tables):
        mask[b, :, :t.split_mask.shape[1]] = t.split_mask
    out["split_mask"] = mask
    out["total_macs"] = np.asarray([t.aux["total_macs"] for t in tables],
                                   np.float64)
    out["mode"] = modes.pop()
    return out


# =============================================================================
# FIFO activation cache — array mirror of costs.ActivationCache
# =============================================================================

def fifo_insert(fifo_ops, fifo_bytes, cached_at, tile, op_idx, nbytes, cap,
                enable):
    """Insert op ``op_idx``'s output (``nbytes``) into ``tile``'s FIFO row,
    evicting oldest-first until it fits in bytes (``cap``) and slots.

    ``fifo_ops`` / ``fifo_bytes`` are (MAX_TILES, ACT_CACHE_SLOTS) arrays,
    right-packed (newest at the last slot, -1 / 0.0 padding on the left);
    ``cached_at`` maps op index -> holding tile (-1 when absent).  Keep in
    bitwise sync with ``costs.ActivationCache.insert``.
    """
    S = fifo_ops.shape[1]
    row_ops = fifo_ops[tile]
    row_b = fifo_bytes[tile]
    count = jnp.sum(row_ops >= 0)
    # rem[j] = bytes kept when slots [j:] survive; monotone nonincreasing
    rem = jnp.concatenate([jnp.cumsum(row_b[::-1])[::-1],
                           jnp.zeros((1,), row_b.dtype)])
    fits = rem + nbytes <= cap
    a = jnp.maximum(jnp.argmax(fits), S - count)       # first surviving slot
    a = jnp.maximum(a, jnp.where(count == S, 1, 0))    # full row: evict >= 1
    do = enable & (nbytes <= cap)

    shifted_ops = jnp.concatenate(
        [row_ops[1:], jnp.full((1,), op_idx, row_ops.dtype)])
    shifted_b = jnp.concatenate([row_b[1:], jnp.reshape(nbytes, (1,))])
    keep_pos = jnp.arange(S) >= a - 1
    new_ops = jnp.where(keep_pos, shifted_ops, -1)
    new_b = jnp.where(keep_pos, shifted_b, 0.0)

    pos = jnp.arange(S)
    evicted = (pos >= S - count) & (pos < a) & do
    oob = cached_at.shape[0]  # scatter mode="drop" discards these
    evict_ids = jnp.where(evicted, row_ops, oob)
    cached_at = cached_at.at[evict_ids].set(-1, mode="drop")
    cached_at = cached_at.at[op_idx].set(
        jnp.where(do, tile, cached_at[op_idx]).astype(cached_at.dtype))
    fifo_ops = fifo_ops.at[tile].set(jnp.where(do, new_ops, row_ops))
    fifo_bytes = fifo_bytes.at[tile].set(jnp.where(do, new_b, row_b))
    return fifo_ops, fifo_bytes, cached_at


# =============================================================================
# the plan-execution scan (mirrors ChipSim.run op-for-op)
# =============================================================================

def _build_plan_exec(calib: CalibrationTable, max_ops: int,
                     fidelity: str = "aggregate"):
    cm = cost_model(calib, jnp)
    c = calib
    link = fidelity == "link"

    def exec_plan(tile, chip, xs, total_macs):
        T = tile

        def noc_seconds(nbytes):
            return noc_transfer_seconds(jnp, nbytes, chip["noc_bpc"],
                                        chip["hops"],
                                        chip["noc_base_cycles"],
                                        chip["ref_clock_hz"])

        def noc_energy(nbytes):
            return noc_transfer_energy_pj(jnp, nbytes,
                                          c.e_noc_pj_per_byte_hop,
                                          chip["hops"])

        def link_seconds(nbytes):
            # one grid link's store-and-forward occupancy (hops = 1)
            return noc_transfer_seconds(jnp, nbytes, chip["noc_bpc"], 1.0,
                                        chip["noc_base_cycles"],
                                        chip["ref_clock_hz"])

        # per-tile DRAM-channel one-hot of the link-fidelity tier
        # (chip-constant, hoisted out of the scan)
        tidx_f = jnp.arange(MAX_TILES, dtype=_F)
        ch_oh = dram_channel_one_hot(jnp, tidx_f, chip["dram_channels"])

        def step(carry, op):
            (tile_finish, op_finish, cached_at, fifo_ops, fifo_bytes,
             tile_ops, tile_active, tile_macs, e_mod, cache_ev,
             res_occ) = carry[:11]
            if link:
                link_occ, chan_occ = carry[11], carry[12]
            idx = jnp.asarray(op["index"], jnp.int32)
            active = (op["valid"] > 0) & (op["fused"] == 0)
            owner = jnp.asarray(op["owner"], jnp.int32)
            k = op["n_split"]
            mask = op["split_mask"] > 0
            is_split = k > 1.0
            axis = op["split_axis"]
            onehot = jnp.arange(MAX_TILES) == owner

            # ---- dependency-ready time + input acquisition --------------
            preds = jnp.asarray(op["preds"], jnp.int32)
            pred_ok = preds >= 0
            pidx = jnp.maximum(preds, 0)
            per_pred = op["per_pred_bytes"]
            t_dep = jnp.max(jnp.where(pred_ok, op_finish[pidx], 0.0))
            src = jnp.where(pred_ok, cached_at[pidx], -1)
            hit = pred_ok & (src == owner)
            via_noc = pred_ok & (src >= 0) & (src != owner)
            miss = pred_ok & (src < 0)
            dram_rd = op["bytes_w"] \
                + jnp.sum(jnp.where(miss, per_pred, 0.0)) \
                + jnp.where(op["num_preds"] == 0, op["bytes_in"], 0.0)
            extra_noc_s = jnp.sum(jnp.where(via_noc, noc_seconds(per_pred),
                                            0.0))
            e_noc_in = jnp.sum(jnp.where(via_noc, noc_energy(per_pred), 0.0))
            # write-back: outputs fitting the owner's cache partition skip
            # the DRAM round-trip; oversized outputs spill (§3.3.4)
            dram_wr = jnp.where(op["bytes_out"] > T["cache_cap"][owner],
                                op["bytes_out"], 0.0)

            # ---- dynamic DRAM bandwidth share ----------------------------
            t_start0 = jnp.maximum(tile_finish[owner], t_dep)
            n_active = jnp.maximum(jnp.sum(
                jnp.where(T["exists"] > 0, tile_finish > t_start0, False)),
                1.0)
            bw_share = chip["dram_gbps"] / n_active

            # ---- single-tile execution (on all tiles; owner selected) ----
            ex = cm.execute(T, op, bw_share, dram_rd, dram_wr)
            fin_single = t_start0 + extra_noc_s + ex["seconds"][owner]

            # ---- Eq. 3 split execution (slice_op semantics) --------------
            kf = jnp.maximum(k, 1.0)
            sub = split_op_fields(jnp, op, axis, kf)
            ex_sub = cm.execute(T, sub, bw_share, dram_rd / kf, dram_wr / kf)
            starts_sub = jnp.maximum(tile_finish, t_dep) + extra_noc_s
            fins_sub = jnp.where(mask, starts_sub + ex_sub["seconds"],
                                 -jnp.inf)
            slice_out = op["bytes_out"] / kf
            reduce_s = noc_seconds(slice_out)
            fin_split = jnp.max(fins_sub) + reduce_s
            e_noc_split = (kf - 1.0) * noc_energy(slice_out)

            fin_op = jnp.where(is_split, fin_split, fin_single)

            # ---- state updates ------------------------------------------
            tf_single = jnp.where(onehot, fin_single, tile_finish)
            tf_split = jnp.where(mask, fins_sub, tile_finish)
            tf_split = jnp.where(onehot,
                                 jnp.maximum(tf_split, fin_split), tf_split)
            new_tf = jnp.where(is_split, tf_split, tf_single)
            tile_finish = jnp.where(active, new_tf, tile_finish)

            exec_mask = jnp.where(is_split, mask, onehot)
            tile_ops = tile_ops + jnp.where(active & exec_mask, 1.0, 0.0)
            sec_each = jnp.where(is_split, ex_sub["seconds"], ex["seconds"])
            tile_active = tile_active + jnp.where(active & exec_mask,
                                                  sec_each, 0.0)
            macs_each = jnp.where(is_split, sub["macs"], op["macs"])
            tile_macs = tile_macs + jnp.where(active & exec_mask, macs_each,
                                              0.0)

            # per-module chip energy (ENERGY_MODULES order minus leakage)
            new_e = dict(e_mod)
            for mod, key in (("compute", "e_compute"), ("dram", "e_dram"),
                             ("sram", "e_sram"), ("irf", "e_irf"),
                             ("orf", "e_orf"), ("dsp", "e_dsp"),
                             ("special", "e_special")):
                # e_dram is tile-independent (op-scalar); broadcast before
                # the owner gather
                single_v = jnp.broadcast_to(ex[key], (MAX_TILES,))[owner]
                contrib = jnp.where(
                    is_split,
                    jnp.sum(jnp.where(mask, ex_sub[key], 0.0)),
                    single_v)
                new_e[mod] = e_mod[mod] + jnp.where(active, contrib, 0.0)
            e_noc_op = e_noc_in + jnp.where(is_split, e_noc_split, 0.0)
            new_e["noc"] = e_mod["noc"] + jnp.where(active, e_noc_op, 0.0)
            # PPM energy of fused children + Eq. 6 refund, credited to head
            new_e["dsp"] = new_e["dsp"] + jnp.where(
                active, op["fused_lane_ops"] * c.e_dsp_pj_per_lane_op, 0.0)
            new_e["fuse_savings"] = e_mod["fuse_savings"] + jnp.where(
                active,
                op["fused_refund_bytes"] * c.e_sram_pj_per_byte, 0.0)
            e_mod = new_e

            ev = jnp.stack([jnp.sum(hit), jnp.sum(via_noc), jnp.sum(miss)])
            cache_ev = cache_ev + jnp.where(active, ev.astype(_F),
                                            jnp.zeros(3, _F))

            # shared-resource occupancy per batch (throughput-mode II
            # inputs, mirroring the oracle walk's accumulators): aligned
            # DRAM bytes as charged, and NoC acquisition + reduce seconds
            dram_b_op = jnp.where(
                is_split,
                jnp.sum(jnp.where(mask,
                                  jnp.broadcast_to(ex_sub["dram_bytes"],
                                                   (MAX_TILES,)), 0.0)),
                jnp.broadcast_to(ex["dram_bytes"], (MAX_TILES,))[owner])
            noc_s_op = extra_noc_s + jnp.where(is_split, reduce_s, 0.0)
            occ = jnp.stack([dram_b_op, noc_s_op])
            res_occ = res_occ + jnp.where(active, occ, jnp.zeros(2, _F))

            if link:
                # --- link-fidelity occupancy (mirrors the oracle walk) ---
                # (a) XY-routed acquisition links, one route per via-NoC
                # pred; hit/miss/padded preds yield empty routes (src ==
                # owner / src < 0), so the unconditional adds stay exact.
                owner_f = jnp.asarray(owner, _F)
                acq_rt = xy_route_link_mask(
                    jnp, jnp.asarray(src, _F), owner_f, chip["grid_w"],
                    chip["grid_h"], chip["torus"])
                acq_t = link_seconds(per_pred)
                for p in range(MAX_PREDS):
                    link_occ = link_occ + jnp.where(active,
                                                    acq_rt[p] * acq_t, 0.0)
                # (b) split-reduce links: every split tile sends its output
                # slice to the owner (the owner's own route is empty)
                red_rt = xy_route_link_mask(
                    jnp, tidx_f, owner_f, chip["grid_w"], chip["grid_h"],
                    chip["torus"])
                red_t = link_seconds(slice_out)
                for t in range(MAX_TILES):
                    link_occ = link_occ + jnp.where(
                        active & is_split & mask[t], red_rt[t] * red_t, 0.0)
                # (c) per-channel DRAM bytes, interleaved by executing tile
                dram_each = jnp.where(
                    is_split,
                    jnp.where(mask,
                              jnp.broadcast_to(ex_sub["dram_bytes"],
                                               (MAX_TILES,)), 0.0),
                    jnp.where(onehot,
                              jnp.broadcast_to(ex["dram_bytes"],
                                               (MAX_TILES,)), 0.0))
                for t in range(MAX_TILES):
                    chan_occ = chan_occ + jnp.where(active,
                                                    dram_each[t] * ch_oh[t],
                                                    0.0)

            op_finish = op_finish.at[idx].set(jnp.where(active, fin_op, 0.0))
            fifo_ops, fifo_bytes, cached_at = fifo_insert(
                fifo_ops, fifo_bytes, cached_at, owner, idx,
                op["bytes_out"], T["cache_cap"][owner], active)
            out_carry = (tile_finish, op_finish, cached_at, fifo_ops,
                         fifo_bytes, tile_ops, tile_active, tile_macs,
                         e_mod, cache_ev, res_occ)
            if link:
                out_carry = out_carry + (link_occ, chan_occ)
            return out_carry, None

        e0 = {m: jnp.asarray(0.0, _F)
              for m in ("compute", "dram", "sram", "irf", "orf", "dsp",
                        "special", "noc", "fuse_savings")}
        init = (jnp.zeros(MAX_TILES, _F), jnp.zeros(max_ops, _F),
                jnp.full(max_ops, -1, jnp.int32),
                jnp.full((MAX_TILES, ACT_CACHE_SLOTS), -1, jnp.int32),
                jnp.zeros((MAX_TILES, ACT_CACHE_SLOTS), _F),
                jnp.zeros(MAX_TILES, _F), jnp.zeros(MAX_TILES, _F),
                jnp.zeros(MAX_TILES, _F), e0, jnp.zeros(3, _F),
                jnp.zeros(2, _F))
        if link:
            init = init + (jnp.zeros(MAX_LINKS, _F),
                           jnp.zeros(MAX_DRAM_CHANNELS, _F))
        final, _ = jax.lax.scan(step, init, xs["per_op"])
        (tile_finish, op_finish, cached_at, _, _, tile_ops, tile_active,
         tile_macs, e_mod, cache_ev, res_occ) = final[:11]
        link_occ, chan_occ = (final[11], final[12]) if link else (None, None)

        makespan = jnp.max(tile_finish)
        gated = tile_ops <= 0
        resid = jnp.where(gated, c.power_gate_residual, 1.0)
        leak_t = jnp.where(T["exists"] > 0,
                           c.leak_mw_per_mm2 * T["area_mm2"] * makespan
                           * resid * 1e9, 0.0)
        leakage = jnp.sum(leak_t)
        energy = (e_mod["compute"] + e_mod["dram"] + e_mod["sram"]
                  + e_mod["irf"] + e_mod["orf"] + e_mod["dsp"]
                  + e_mod["special"] + e_mod["noc"] + leakage
                  - e_mod["fuse_savings"])
        achieved = jnp.where(makespan > 0, total_macs / makespan / 1e12, 0.0)
        out = {"latency_s": makespan, "energy_pj": energy,
               "achieved_tops": achieved, "op_finish": op_finish,
               "tile_ops": tile_ops, "tile_active_s": tile_active,
               "tile_macs": tile_macs, "power_gated": gated,
               "cache_hits": cache_ev[0], "cache_noc": cache_ev[1],
               "cache_misses": cache_ev[2], "tile_leakage_pj": leak_t,
               "energy_leakage_pj": leakage}
        for m in e_mod:
            out[f"energy_{m}_pj"] = e_mod[m]

        # ---- throughput-mode steady state (§3.2): same composition as
        # ChipSim._steady_state, via the shared costs.pipeline_bounds ----
        dram_bytes, noc_busy = res_occ[0], res_occ[1]
        leak_rate = jnp.sum(jnp.where(T["exists"] > 0,
                                      c.leak_mw_per_mm2 * T["area_mm2"]
                                      * resid * 1e9, 0.0))
        out.update(pipeline_bounds(
            jnp, makespan, jnp.max(tile_active), dram_bytes,
            chip["dram_gbps"], noc_busy, chan_bytes=chan_occ,
            dram_channels=chip["dram_channels"] if link else None,
            link_busy_s=link_occ))
        ii = out["ii_s"]
        out["fill_latency_s"] = makespan
        out["dram_bytes_per_batch"] = dram_bytes
        out["energy_ss_pj"] = steady_state_energy(energy, leakage,
                                                  leak_rate, ii)
        out["achieved_tops_ss"] = jnp.where(ii > 0,
                                            total_macs / ii / 1e12, 0.0)
        out["pipeline_depth"] = jnp.where(ii > 0, jnp.ceil(makespan / ii),
                                          1.0)
        return out

    return exec_plan


_CALIB_REGISTRY: Dict[int, CalibrationTable] = {}


@functools.lru_cache(maxsize=64)
def _jitted(calib_key: int, max_ops: int, fidelity: str = "aggregate"):
    calib = _CALIB_REGISTRY[calib_key]
    fn = _build_plan_exec(calib, max_ops, fidelity)
    batched = jax.vmap(fn, in_axes=({k: 0 for k in TILE_KEYS},
                                    {k: 0 for k in CHIP_KEYS}, 0, 0))
    return jax.jit(batched)


def batch_simulate(plans: Dict[str, np.ndarray],
                   cfgs: Dict[str, Dict[str, np.ndarray]],
                   calib: CalibrationTable = DEFAULT_CALIB,
                   mode: Optional[str] = None,
                   fidelity: str = "aggregate") -> Dict[str, np.ndarray]:
    """Execute stacked plan tables against stacked chip configs.

    ``plans`` comes from ``stack_plan_tables`` (candidate b's plan must
    target candidate b's chip); ``cfgs`` from ``stack_chip_configs`` (or
    the DSE engine's vectorized genome stack).  Returns (B,) arrays:
    ``latency_s``, ``energy_pj``, ``achieved_tops``, per-module
    ``energy_*_pj``, cache event counts, and (B, MAX_TILES) per-tile op /
    active-time / gating stats — the SimResult surface minus the per-op
    trace, which stays with the oracle.

    ``mode`` defaults to the stacked tables' stamped schedule mode
    (``PlanTensor.mode``); throughput-mode results additionally carry the
    pipeline steady state — ``ii_s``, ``fill_latency_s``, the three
    per-resource ``ii_*_bound_s``, ``energy_ss_pj``,
    ``achieved_tops_ss`` and ``pipeline_depth`` — matching
    ``ChipSim._steady_state`` through the shared
    ``costs.pipeline_bounds`` composition.  A mode outside
    ``SCHEDULE_MODES`` raises instead of silently returning latency
    numbers.
    """
    mode = mode if mode is not None else plans.get("mode", "latency")
    if mode not in SCHEDULE_MODES:
        raise ValueError(
            f"batched executor cannot model schedule mode {mode!r}; "
            f"supported modes: {SCHEDULE_MODES}")
    if fidelity not in FIDELITIES:
        raise ValueError(
            f"unknown fidelity {fidelity!r}; supported: {FIDELITIES}")
    key = id(calib)
    _CALIB_REGISTRY[key] = calib
    max_ops = plans["op_type"].shape[1]
    per_op = {f: jnp.asarray(plans[f], _F) for f in _OP_TABLE_KEYS}
    per_op["preds"] = jnp.asarray(plans["preds"], jnp.int32)
    per_op["owner"] = jnp.asarray(plans["owner"], jnp.int32)
    per_op["n_split"] = jnp.asarray(plans["n_split"], _F)
    per_op["split_axis"] = jnp.asarray(plans["split_axis"], jnp.int32)
    per_op["split_mask"] = jnp.asarray(plans["split_mask"], _F)
    B = per_op["op_type"].shape[0]
    per_op["index"] = jnp.broadcast_to(jnp.arange(max_ops, dtype=jnp.int32),
                                       (B, max_ops))
    xs = {"per_op": per_op}
    tile = {k: jnp.asarray(cfgs["tile"][k], _F) for k in TILE_KEYS}
    chip = {k: jnp.asarray(cfgs["chip"][k], _F) for k in CHIP_KEYS}
    fn = _jitted(key, max_ops, fidelity)
    out = fn(tile, chip, xs, jnp.asarray(plans["total_macs"], _F))
    res = {k: np.asarray(v) for k, v in out.items()}
    res["area_mm2"] = cfgs["chip"]["chip_area"]
    res["peak_tops"] = cfgs["chip"]["peak_tops"]
    res["mode"] = mode
    return res


def simulate_plans(chips: Sequence[ChipConfig], tables: Sequence[PlanTensor],
                   calib: CalibrationTable = DEFAULT_CALIB,
                   fidelity: str = "aggregate") -> Dict[str, np.ndarray]:
    """Convenience wrapper: stack ``chips`` + their ``tables`` and execute."""
    if len(chips) != len(tables):
        raise ValueError("one plan table per chip required")
    return batch_simulate(stack_plan_tables(tables),
                          stack_chip_configs(chips, calib), calib,
                          fidelity=fidelity)
