"""Cost-aware compiler (paper §3.2): four ordered passes.

(1) mixed-precision assignment  (2) operator fusion
(3) DAG-aware mapping with op-splitting (Eqs. 1-3)  (4) schedule emission

Each pass tags operators for the simulator and DSE; no machine code is
emitted.  Pass 3 has two exact implementations: the per-candidate Python
``map_graph`` (the oracle reference) and the jitted/vmapped
``batched_mapper`` (the compile-free population path, pinned bitwise to
``map_graph``).
"""
from .precision import assign_precision
from .fusion import fuse
from .mapper import map_graph
from .schedule import emit_schedule
from .pipeline import compile_workload

__all__ = ["assign_precision", "fuse", "map_graph", "emit_schedule",
           "compile_workload", "batched_map", "map_and_simulate"]


def __getattr__(name):
    # batched_mapper is imported lazily: it pulls in jax/XLA, and
    # importing the compiler package (or the reference oracle through
    # repro.core) must stay jax-free.
    if name in ("batched_map", "map_and_simulate"):
        from . import batched_mapper
        return getattr(batched_mapper, name)
    raise AttributeError(name)
