"""Cost-aware compiler (paper §3.2): four ordered passes.

(1) mixed-precision assignment  (2) operator fusion
(3) DAG-aware mapping with op-splitting (Eqs. 1-3)  (4) schedule emission

Each pass tags operators for the simulator and DSE; no machine code is
emitted.
"""
from .precision import assign_precision
from .fusion import fuse
from .mapper import map_graph
from .schedule import emit_schedule
from .pipeline import compile_workload

__all__ = ["assign_precision", "fuse", "map_graph", "emit_schedule",
           "compile_workload"]
