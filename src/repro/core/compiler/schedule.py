"""Pass 4 — schedule emission (paper §3.2).

Converts the per-op mapping into an execution schedule.  *Latency* mode
parallelizes distinct-tile assignments (the orchestrator's per-tile finish
times realize the overlap); *throughput* mode pipelines multiple batches by
replaying the plan with a per-batch offset and reporting the steady-state
initiation interval.
"""
from __future__ import annotations

from typing import Dict

from ..ir import WorkloadGraph
from ..simulator.orchestrator import ExecutionPlan, Placement

__all__ = ["emit_schedule"]


def emit_schedule(g: WorkloadGraph, placements: Dict[int, Placement],
                  mode: str = "latency") -> ExecutionPlan:
    if mode not in ("latency", "throughput"):
        raise ValueError(f"unknown schedule mode {mode!r}")
    # topological order is preserved by construction; validate coverage
    for i, nd in enumerate(g.nodes):
        if nd.fused_into < 0 and i not in placements:
            raise ValueError(f"{g.name}: op {i} has no placement")
    return ExecutionPlan(graph=g, placements=placements, mode=mode)
