"""Pass 4 — schedule emission (paper §3.2).

Converts the per-op mapping into an execution schedule.  *Latency* mode
parallelizes distinct-tile assignments (the orchestrator's per-tile finish
times realize the overlap); *throughput* mode pipelines successive
batches through the same placements and is scored by the steady-state
initiation interval (``simulator.costs.pipeline_bounds``) instead of the
one-batch makespan.

``ExecutionPlan.mode`` dispatches downstream: ``ChipSim.run`` attaches
the pipeline steady state (II, fill latency, bottleneck bounds,
steady-state energy) to its result for throughput plans, the batched
executor carries the mode through ``PlanTensor`` / ``stack_plan_tables``,
and every backend raises ``ValueError`` on a mode it cannot model rather
than silently returning latency numbers.
"""
from __future__ import annotations

from typing import Dict

from ..ir import WorkloadGraph
from ..simulator.orchestrator import (SCHEDULE_MODES, ExecutionPlan,
                                      Placement)

__all__ = ["emit_schedule", "SCHEDULE_MODES"]


def emit_schedule(g: WorkloadGraph, placements: Dict[int, Placement],
                  mode: str = "latency") -> ExecutionPlan:
    if mode not in SCHEDULE_MODES:
        raise ValueError(f"unknown schedule mode {mode!r}; expected one of "
                         f"{SCHEDULE_MODES}")
    # topological order is preserved by construction; validate coverage
    for i, nd in enumerate(g.nodes):
        if nd.fused_into < 0 and i not in placements:
            raise ValueError(f"{g.name}: op {i} has no placement")
    return ExecutionPlan(graph=g, placements=placements, mode=mode)
