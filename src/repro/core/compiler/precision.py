"""Pass 1 — mixed-precision assignment (paper §3.2).

Default policy: Conv/MatMul/Pool -> INT8; LayerNorm/RMSNorm/Softmax/SNN/
FFT/polynomial/SSM-scan -> FP16.  A name-based override forces FP16 on
accuracy-sensitive layers (attention QKV/output projection, LM head,
classifier, embedding).  An aggressive mode demotes all convolutions to
INT4.

The policy is gated by the precision the workload *ships in* (Table 1):
post-training-quantized variants carry INT8/INT4 MAC operands; in
FP16-shipped models the compiler still demotes the "quantizable matmul
fragments" (FFN up/down projections — paper §5.3's off-loading mechanism)
to INT8 while attention and accuracy-sensitive ops stay FP16.
"""
from __future__ import annotations

import re
from typing import Optional

from ..ir import OpClass, OpType, Precision, WorkloadGraph, PRECISION_BYTES

__all__ = ["assign_precision", "ACCURACY_SENSITIVE_RE"]

# attention QKV / output projection, LM head, classifier, embedding
ACCURACY_SENSITIVE_RE = re.compile(
    r"(qkv|q_proj|k_proj|v_proj|o_proj|out_proj|attn_out|lm_head|classifier|"
    r"embed|logits)", re.IGNORECASE)

_FP16_MIN_OPS = frozenset({
    int(OpType.SOFTMAX), int(OpType.LAYERNORM), int(OpType.RMSNORM),
    int(OpType.SSM_SCAN), int(OpType.FFT), int(OpType.SNN_LIF),
    int(OpType.POLY),
})

# "quantizable matmul fragments" (paper §5.3): FFN matmuls the default
# policy demotes to INT8 even in FP16-shipped models
QUANTIZABLE_FRAGMENT_RE = re.compile(
    r"(gate_up|ffn_up|ffn_down|fc1|fc2|mlp|shared_up|shared_down|"
    r"e\d+_down|l\d+_down|_ffn|in_proj)", re.IGNORECASE)


def _rescale_bytes(node, old_p: Precision) -> None:
    """Re-derive operand byte counts after a precision change."""
    ratio = PRECISION_BYTES[node.precision] / PRECISION_BYTES[old_p]
    node.bytes_in = int(node.bytes_in * ratio)
    node.bytes_w = int(node.bytes_w * ratio)
    node.bytes_out = int(node.bytes_out * ratio)


def assign_precision(g: WorkloadGraph, aggressive_int4: bool = False) -> WorkloadGraph:
    ship = g.model_precision
    mac_target: Optional[Precision] = None
    if ship == Precision.INT8:
        mac_target = Precision.INT8
    elif ship == Precision.INT4:
        mac_target = Precision.INT4
    if aggressive_int4:
        mac_target = Precision.INT4

    for node in g.nodes:
        old = node.precision
        if node.op_cls == OpClass.MAC:
            if node.accuracy_sensitive or ACCURACY_SENSITIVE_RE.search(node.name):
                node.accuracy_sensitive = True
                node.precision = Precision.FP16
            elif mac_target is not None:
                node.precision = mac_target
            elif int(node.precision) >= int(Precision.FP16) \
                    and QUANTIZABLE_FRAGMENT_RE.search(node.name):
                node.precision = Precision.INT8
        else:
            # vector / special operators run at >= FP16 (default policy)
            if int(node.op_type) in _FP16_MIN_OPS and int(node.precision) < int(Precision.FP16):
                node.precision = Precision.FP16
        if node.precision != old:
            _rescale_bytes(node, old)
    return g
