"""Exact batched Eq. 1-3 mapper: ``map_graph`` as a jitted/vmapped scan.

The compile-free exact path.  ``map_graph`` re-derives, per candidate
chip, the same placement decision sequence the Python mapper makes —
compatibility filtering, SPECIAL->SFU routing, earliest-start times with
NoC-crossing dependency delays (Eq. 1), the roofline completion-time
argmin with the mapper's *sequential* smallest-tile tie-break (Eq. 2),
and the OC/B/IC split decision with the explicit Eq. 3 reduce/concat
cost — but as one ``lax.scan`` over the op axis with ``(MAX_TILES,)``
tile-field lanes, ``vmap``-ed across candidates and jitted.  Placements
come out as the stacked integer arrays (``owner`` / ``n_split`` /
``split_axis`` / ``split_mask``) that ``simulator.batched`` executes, in
exactly the layout ``compiler.pipeline.lower_plan`` emits, and are
pinned *bitwise* against ``map_graph`` by tests/test_batched_mapper.py.

Why exactness holds: every per-(op, tile) quantity is evaluated through
the shared ``simulator.costs.CostModel`` (literally the code the Python
mapper calls through numpy), the slice arithmetic is the shared
``split_op_fields`` mirror of ``ir.slice_op``, and the one genuinely
sequential piece of ``map_graph`` — the completion-time argmin whose
1e-15 tie band *chains* (a tie-break win updates the incumbent time) —
is replicated as an unrolled fold over the tile axis in ascending index
order rather than approximated with an epsilon-weighted ``argmin`` (the
approximation ``dse.batch_eval`` makes in-scan).

``map_and_simulate`` fuses this mapping scan with the batched plan
executor into a single device dispatch: per-workload arrays are prepared
once (``dse.engine.prepared_workload``) and shared across the candidate
axis (``vmap in_axes=None``), so the whole exact path — compile *and*
simulate — runs without any per-candidate Python work.  With a
``NamedSharding`` over the candidate axis the same dispatch spans every
available device (``launch.mesh.candidate_sharding``).

``search_and_simulate`` is the *search-loop* variant of the same exact
path: mapping and execution fused into ONE ``lax.scan`` (each op is
placed and then immediately executed in the same step), with the cost
model **class-specialized** — ``op_cls`` / ``splittable`` are workload
properties shared across the candidate axis (``vmap in_axes=None``), so
the kernel branches on them with ``lax.cond`` and only the taken class's
sub-models run: MAC operators never evaluate the SFU/lowering math,
DSP operators skip the MAC tiling pass entirely, and the Eq. 3
three-axis split probe runs only for statically splittable MAC ops.
The taken-path arithmetic is term-for-term the full model
(``costs.CostModel.execute_static_{mac,dsp,special}`` /
``roofline_cycles_*`` / ``supports_*``), so the metric surface is
**bitwise identical** to ``map_and_simulate`` — at a fraction of the
compute, and returning only the (B,) scoring surface (no per-op
placement materialization).  This is what ``EvalEngine``'s exact search
backend and the device GA loop dispatch per generation.

The Python ``map_graph`` stays the oracle reference; unmappable
candidates (some op with no compatible tile, the ``UnmappableError``
case) are reported through the ``ok`` output instead of an exception.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)  # cycle counts overflow f32 ULPs

import jax.numpy as jnp

from ..arch import MAX_TILES
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import MAX_PREDS, OpClass
from ..simulator.batched import (CHIP_KEYS, SCHEDULE_MODES, TILE_KEYS,
                                 _build_plan_exec, _OP_TABLE_KEYS,
                                 fifo_insert)
from ..simulator.costs import (ACT_CACHE_SLOTS, FIDELITIES,
                               MAX_DRAM_CHANNELS, MAX_LINKS, OP_COST_KEYS,
                               cost_model, dram_channel_one_hot,
                               noc_transfer_energy_pj, noc_transfer_seconds,
                               pipeline_bounds, split_op_fields,
                               steady_state_energy, xy_route_link_mask)

__all__ = ["batched_map", "map_and_simulate", "search_and_simulate",
           "search_population", "place_configs"]

_F = jnp.float64

# map_graph's tie band: completion times within 1e-15 s prefer the
# smaller MAC array (compiler.mapper line-for-line).
_TIE = 1e-15

# workload fields the mapper scan consumes beyond the executor's op table
_WS_KEYS = _OP_TABLE_KEYS + ("splittable",)


# =============================================================================
# the mapping scan
# =============================================================================

def _build_mapper(calib: CalibrationTable, max_ops: int,
                  enable_split: bool = True):
    cm = cost_model(calib, jnp)

    def map_one(tile, chip, xs):
        """Map ONE workload onto ONE candidate chip.  tile: dict of
        (MAX_TILES,) arrays; chip: dict of scalars; xs["per_op"]: dict of
        (max_ops, ...) arrays.  Returns the stacked placement arrays plus
        the per-candidate ``ok`` mappability flag."""
        T = tile
        num_macs = T["num_macs"]
        n_tiles = jnp.sum(T["exists"])
        # static per-tile bandwidth share for the estimate domain (§3.2)
        bw_share = chip["dram_gbps"] / n_tiles

        def noc_s(nbytes):
            return noc_transfer_seconds(jnp, nbytes, chip["noc_bpc"],
                                        chip["hops"],
                                        chip["noc_base_cycles"],
                                        chip["ref_clock_hz"])

        def step(carry, op):
            tile_finish, op_finish, op_tile, ok = carry
            idx = jnp.asarray(op["index"], jnp.int32)
            active = (op["valid"] > 0) & (op["fused"] == 0)

            # ---- compatibility + SPECIAL->SFU routing (§3.2) -------------
            compat = cm.supports(T, op)
            native = cm.sfu_native(T, op) & compat
            is_spec = op["op_cls"] == int(OpClass.SPECIAL)
            compat = jnp.where(is_spec & jnp.any(native), native, compat)
            any_compat = jnp.any(compat)

            # ---- Eq. 1 earliest start per tile ---------------------------
            preds = jnp.asarray(op["preds"], jnp.int32)
            pred_ok = preds >= 0
            pidx = jnp.maximum(preds, 0)
            per_pred = op["per_pred_bytes"]
            pf = jnp.where(pred_ok, op_finish[pidx], 0.0)
            ptile = jnp.where(pred_ok, op_tile[pidx], -1)
            # fused / absent preds (op_tile == -1) count as local, exactly
            # like map_graph's op_tile.get(p, t)
            cross = (ptile[:, None] >= 0) \
                & (ptile[:, None] != jnp.arange(MAX_TILES)[None, :])
            dep = jnp.max(jnp.where(
                pred_ok[:, None],
                pf[:, None] + jnp.where(cross, noc_s(per_pred), 0.0),
                0.0), axis=0)
            t_start = jnp.maximum(tile_finish, dep)

            # ---- single-tile candidates (Eq. 2) --------------------------
            c_hat_s = cm.roofline_cycles(T, op, bw_share) / T["clock_hz"]
            fins = t_start + c_hat_s
            # map_graph's argmin is a *sequential* fold whose 1e-15 tie
            # band chains (a tie-break win replaces the incumbent best_fin
            # too); replicate it as an unrolled fold in tile-index order.
            best_t = jnp.asarray(-1, jnp.int32)
            best_fin = jnp.asarray(jnp.inf, _F)
            best_nm = jnp.asarray(0.0, _F)
            for t in range(MAX_TILES):
                fin, nm = fins[t], num_macs[t]
                better = fin < best_fin - _TIE
                tie = (jnp.abs(fin - best_fin) <= _TIE) & (best_t >= 0) \
                    & (nm < best_nm)
                upd = compat[t] & (better | tie)
                best_t = jnp.where(upd, t, best_t).astype(jnp.int32)
                best_fin = jnp.where(upd, fin, best_fin)
                best_nm = jnp.where(upd, nm, best_nm)

            # ---- split candidates (Eq. 3) --------------------------------
            mac_mask = compat & (num_macs > 0)
            ksplit = jnp.sum(mac_mask)
            kf = jnp.maximum(ksplit.astype(_F), 1.0)
            can_split = enable_split \
                & (op["op_cls"] == int(OpClass.MAC)) \
                & (op["splittable"] > 0) & (op["macs"] > 0) & (ksplit > 1)

            def axis_fin(axis):
                sub = split_op_fields(jnp, op, axis, kf)
                ch_s = cm.roofline_cycles(T, sub, bw_share / kf) \
                    / T["clock_hz"]
                fins_s = jnp.where(mac_mask, t_start + ch_s, -jnp.inf)
                # Eq. 3 reduce/concat cost over the NoC
                return jnp.max(fins_s) + noc_s(op["bytes_out"] / kf)

            fins3 = jnp.stack([axis_fin(0), axis_fin(1), axis_fin(2)])
            # sequential strict-< axis loop == first occurrence of the min
            best_axis = jnp.argmin(fins3).astype(jnp.int32)
            do_split = can_split & (fins3[best_axis] < best_fin)

            first_mac = jnp.argmax(mac_mask).astype(jnp.int32)
            owner = jnp.where(do_split, first_mac, best_t)
            choice_fin = jnp.where(do_split, fins3[best_axis], best_fin)

            # ---- state update (map_graph's finish bookkeeping) -----------
            placed = active & any_compat
            onehot = jnp.arange(MAX_TILES) == owner
            tf_single = jnp.where(onehot, choice_fin, tile_finish)
            tf_split = jnp.where(mac_mask,
                                 jnp.maximum(tile_finish, choice_fin),
                                 tile_finish)
            tile_finish = jnp.where(placed,
                                    jnp.where(do_split, tf_split, tf_single),
                                    tile_finish)
            op_finish = op_finish.at[idx].set(
                jnp.where(placed, choice_fin, 0.0))
            op_tile = op_tile.at[idx].set(
                jnp.where(placed, owner, -1).astype(jnp.int32))
            ok = ok & (any_compat | ~active)

            ys = {
                "owner": jnp.where(placed, owner, -1).astype(jnp.int32),
                "n_split": jnp.where(
                    placed, jnp.where(do_split, ksplit, 1),
                    0).astype(jnp.int32),
                "split_axis": jnp.where(placed & do_split, best_axis,
                                        -1).astype(jnp.int32),
                "split_mask": jnp.where(
                    placed, jnp.where(do_split, mac_mask, onehot), False),
            }
            return (tile_finish, op_finish, op_tile, ok), ys

        init = (jnp.zeros(MAX_TILES, _F), jnp.zeros(max_ops, _F),
                jnp.full(max_ops, -1, jnp.int32), jnp.asarray(True))
        (_, _, _, ok), ys = jax.lax.scan(step, init, xs["per_op"])
        ys["ok"] = ok
        return ys

    return map_one


# =============================================================================
# fused mapping + plan execution (one device dispatch per workload)
# =============================================================================

def _build_map_exec(calib: CalibrationTable, max_ops: int,
                    fidelity: str = "aggregate"):
    mapper = _build_mapper(calib, max_ops)
    exec_plan = _build_plan_exec(calib, max_ops, fidelity)

    def run(tile, chip, xs, total_macs):
        placed = mapper(tile, chip, xs)
        per_op = dict(xs["per_op"])
        # unmappable rows carry owner -1; clamp for the executor's gathers
        # (their lanes are discarded through ``ok`` host-side)
        per_op["owner"] = jnp.maximum(placed["owner"], 0)
        per_op["n_split"] = placed["n_split"].astype(_F)
        per_op["split_axis"] = placed["split_axis"]
        per_op["split_mask"] = placed["split_mask"].astype(_F)
        out = exec_plan(tile, chip, {"per_op": per_op}, total_macs)
        out["ok"] = placed["ok"]
        for f in ("owner", "n_split", "split_axis", "split_mask"):
            out[f] = placed[f]
        return out

    return run


# CalibrationTable is hashable (costs._cached_model already keys an LRU
# on it), so the jit caches key on the calib directly — no id()-keyed
# registry like the older jit wrappers carry.
@functools.lru_cache(maxsize=64)
def _jitted_map(calib: CalibrationTable, max_ops: int, enable_split: bool):
    fn = _build_mapper(calib, max_ops, enable_split)
    batched = jax.vmap(fn, in_axes=({k: 0 for k in TILE_KEYS},
                                    {k: 0 for k in CHIP_KEYS}, None))
    return jax.jit(batched)


@functools.lru_cache(maxsize=64)
def _jitted_map_exec(calib: CalibrationTable, max_ops: int,
                     fidelity: str = "aggregate"):
    fn = _build_map_exec(calib, max_ops, fidelity)
    batched = jax.vmap(fn, in_axes=({k: 0 for k in TILE_KEYS},
                                    {k: 0 for k in CHIP_KEYS}, None, None))
    return jax.jit(batched)


def _device_xs(ws: Dict[str, np.ndarray]) -> Tuple[dict, int]:
    max_ops = len(ws["op_type"])
    per_op = {k: jnp.asarray(ws[k], _F) for k in _WS_KEYS}
    per_op["preds"] = jnp.asarray(ws["preds"], jnp.int32)
    per_op["index"] = jnp.arange(max_ops, dtype=jnp.int32)
    return {"per_op": per_op}, max_ops


# Device staging of prepared-workload op tables, cached by identity: the
# search loop dispatches the same handful of ``prepared_workload`` dicts
# every generation, and re-uploading ~30 (max_ops,) arrays per dispatch
# is measurable host overhead.  Holding the ws reference in the value
# pins the id, so a dead dict can never alias a cached entry; the caches
# are FIFO-bounded (dropping an entry releases the pin with it).
_XS_CACHE: Dict[int, tuple] = {}
_SEARCH_XS_CACHE: Dict[int, tuple] = {}
_XS_CACHE_MAX = 64


def _staged(cache: Dict[int, tuple], ws: Dict[str, np.ndarray],
            stage) -> tuple:
    """Identity-pinned FIFO memo shared by the two staging caches."""
    hit = cache.get(id(ws))
    if hit is not None and hit[0] is ws:
        return hit[1:]
    out = stage(ws)
    while len(cache) >= _XS_CACHE_MAX:
        cache.pop(next(iter(cache)))
    cache[id(ws)] = (ws,) + out
    return out


def _device_xs_cached(ws: Dict[str, np.ndarray]) -> Tuple[dict, int]:
    return _staged(_XS_CACHE, ws, _device_xs)


def place_configs(cfgs, sharding=None):
    """Stage a stacked config dict on device (optionally with the
    candidate-axis ``NamedSharding``) once, so callers looping over
    workloads don't re-place the same (B, MAX_TILES) arrays per
    workload.  Pass the result to ``batched_map`` / ``map_and_simulate``
    as ``placed``."""
    tile = {k: jnp.asarray(cfgs["tile"][k], _F) for k in TILE_KEYS}
    chip = {k: jnp.asarray(cfgs["chip"][k], _F) for k in CHIP_KEYS}
    if sharding is not None:
        put = lambda a: jax.device_put(a, sharding)
        tile = {k: put(v) for k, v in tile.items()}
        chip = {k: put(v) for k, v in chip.items()}
    return tile, chip


def batched_map(ws: Dict[str, np.ndarray],
                cfgs: Dict[str, Dict[str, np.ndarray]],
                calib: CalibrationTable = DEFAULT_CALIB,
                enable_split: bool = True,
                sharding=None, placed=None) -> Dict[str, np.ndarray]:
    """Exact Eq. 1-3 mapping of one workload onto B candidate chips.

    ``ws`` is a prepared-workload SoA dict (``dse.batch_eval
    .prepare_workload`` / the engine's ``prepared_workload`` cache);
    ``cfgs`` a stacked config dict (``stack_chip_configs`` or the
    engine's vectorized genome stack).  Returns ``owner`` (B, max_ops)
    int32, ``n_split`` (B, max_ops) int32, ``split_axis`` (B, max_ops)
    int32, ``split_mask`` (B, max_ops, MAX_TILES) int8 — bitwise the
    arrays ``lower_plan(emit_schedule(g, map_graph(g, chip)))`` produces
    for each candidate — and ``ok`` (B,) bool (False where ``map_graph``
    would raise ``UnmappableError``).
    """
    xs, max_ops = _device_xs_cached(ws)
    tile, chip = placed if placed is not None \
        else place_configs(cfgs, sharding)
    out = _jitted_map(calib, max_ops, enable_split)(tile, chip, xs)
    return {
        "owner": np.asarray(out["owner"], np.int32),
        "n_split": np.asarray(out["n_split"], np.int32),
        "split_axis": np.asarray(out["split_axis"], np.int32),
        "split_mask": np.asarray(out["split_mask"], np.int8),
        "ok": np.asarray(out["ok"], bool),
    }


def map_and_simulate(ws: Dict[str, np.ndarray],
                     cfgs: Dict[str, Dict[str, np.ndarray]],
                     calib: CalibrationTable = DEFAULT_CALIB,
                     sharding=None, placed=None,
                     mode: str = "latency",
                     fidelity: str = "aggregate") -> Dict[str, np.ndarray]:
    """The compile-free exact path: batched Eq. 1-3 mapping fused with the
    batched plan executor in one jitted dispatch.

    Equivalent to, per candidate, ``map_graph`` -> ``emit_schedule`` ->
    ``lower_plan`` -> ``batch_simulate`` (the PR 2 exact path), but with
    zero per-candidate Python work: the workload arrays are shared across
    the candidate axis and the mapping scan feeds the execution scan on
    device.  Returns the ``batch_simulate`` result surface plus the
    placement arrays and the ``ok`` (B,) mappability mask; rows with
    ``ok == False`` (an op with no compatible tile) carry garbage metrics
    and must be discarded by the caller.

    ``mode`` selects the §3.2 schedule mode the caller scores on.  The
    fused dispatch always evaluates both surfaces (the latency makespan
    and the pipelined steady state — ``ii_s``, ``energy_ss_pj``,
    ``achieved_tops_ss`` and the per-resource bounds — cost one shared
    scan), so mode only validates and tags the result; an unknown mode
    raises rather than silently returning latency numbers.
    """
    if mode not in SCHEDULE_MODES:
        raise ValueError(
            f"batched mapper+executor cannot model schedule mode {mode!r}; "
            f"supported modes: {SCHEDULE_MODES}")
    if fidelity not in FIDELITIES:
        raise ValueError(f"fidelity must be one of {FIDELITIES}, got {fidelity!r}")
    xs, max_ops = _device_xs_cached(ws)
    tile, chip = placed if placed is not None \
        else place_configs(cfgs, sharding)
    fn = _jitted_map_exec(calib, max_ops, fidelity)
    out = fn(tile, chip, xs, jnp.asarray(float(ws["total_macs"]), _F))
    res = {k: np.asarray(v) for k, v in out.items()}
    res["area_mm2"] = cfgs["chip"]["chip_area"]
    res["peak_tops"] = cfgs["chip"]["peak_tops"]
    res["mode"] = mode
    return res


# =============================================================================
# the search kernel: single-scan fused map+execute, class-specialized
# =============================================================================

def _build_search(calib: CalibrationTable, n_steps: int, n_state: int,
                  enable_split: bool = True, fidelity: str = "aggregate"):
    """ONE ``lax.scan`` over the op axis that maps *and* executes each op
    in the same step, with the cost model specialized per operator class.

    The class predicates (``op_cls``, ``splittable``, ``macs > 0``) come
    from the shared workload op table (``vmap in_axes=None``), so every
    ``lax.cond`` below keeps real branch semantics under vmap: only the
    taken class's sub-models are evaluated at runtime.  The taken-path
    arithmetic is the exact restriction of the full model
    (``CostModel.execute_static_*`` / ``roofline_cycles_*`` /
    ``supports_*``), so latency/energy/TOPS (both §3.2 schedule-mode
    surfaces) are bitwise equal to ``map_and_simulate`` — pinned by
    tests/test_ga_device.py and the exact-search parity property.

    The scan axis is the *compacted* op table (``_search_xs_cached``):
    fused children and padding rows — which the full executors cost and
    then gate out with ``active``, 30-90 % of the rows on real graphs —
    are dropped host-side.  ``n_steps`` is the compacted (bucketed)
    scan length; ``n_state`` the ORIGINAL op count, which still sizes
    the per-op state arrays so ``preds`` gathers use original indices
    (an inactive row never writes state, so dropping it is bitwise
    inert; compaction padding carries ``index == n_state`` and its
    state writes fall out via scatter ``mode="drop"``).
    """
    cm = cost_model(calib, jnp)
    c = calib
    link = fidelity == "link"

    def run(tile, chip, xs, total_macs):
        T = tile
        num_macs = T["num_macs"]
        n_tiles = jnp.sum(T["exists"])
        # static per-tile bandwidth share of the estimate domain (§3.2)
        bw_share_est = chip["dram_gbps"] / n_tiles
        tidx_f = jnp.arange(MAX_TILES, dtype=_F)
        ch_oh = dram_channel_one_hot(jnp, tidx_f, chip["dram_channels"])

        def noc_s(nbytes):
            return noc_transfer_seconds(jnp, nbytes, chip["noc_bpc"],
                                        chip["hops"],
                                        chip["noc_base_cycles"],
                                        chip["ref_clock_hz"])

        def noc_e(nbytes):
            return noc_transfer_energy_pj(jnp, nbytes,
                                          c.e_noc_pj_per_byte_hop,
                                          chip["hops"])

        def link_seconds(nbytes):
            return noc_transfer_seconds(jnp, nbytes, chip["noc_bpc"], 1.0,
                                        chip["noc_base_cycles"],
                                        chip["ref_clock_hz"])

        def step(carry, op):
            (m_tile_finish, m_op_finish, m_op_tile, ok,
             tile_finish, op_finish, cached_at, fifo_ops, fifo_bytes,
             tile_ops, tile_active, e_mod, res_occ) = carry[:13]
            if link:
                link_occ, chan_occ = carry[13], carry[14]
            idx = jnp.asarray(op["index"], jnp.int32)
            active = (op["valid"] > 0) & (op["fused"] == 0)

            # workload-static class predicates (shared across candidates)
            is_spec_u = op["op_cls"] == int(OpClass.SPECIAL)
            is_mac_u = op["op_cls"] == int(OpClass.MAC)
            can_split_u = jnp.asarray(enable_split) & is_mac_u \
                & (op["splittable"] > 0) & (op["macs"] > 0)

            # ---- mapping: compat + SPECIAL->SFU routing + Eq. 2 roofline
            def map_spec(o):
                compat0 = cm.supports_special(T, o)
                native = cm.sfu_native(T, o) & compat0
                compat1 = jnp.where(jnp.any(native), native, compat0)
                return compat1, cm.roofline_cycles_special(T, o, bw_share_est)

            def map_mac(o):
                return (cm.supports_mac(T, o),
                        cm.roofline_cycles_mac(T, o, bw_share_est))

            def map_dsp(o):
                return (cm.supports_dsp(T, o),
                        cm.roofline_cycles_dsp(T, o, bw_share_est))

            compat, c_hat = jax.lax.cond(
                is_spec_u, map_spec,
                lambda o: jax.lax.cond(is_mac_u, map_mac, map_dsp, o), op)
            any_compat = jnp.any(compat)

            # ---- Eq. 1 earliest start per tile ---------------------------
            preds = jnp.asarray(op["preds"], jnp.int32)
            pred_ok = preds >= 0
            pidx = jnp.maximum(preds, 0)
            per_pred = op["per_pred_bytes"]
            noc_pred_s = noc_s(per_pred)
            pf = jnp.where(pred_ok, m_op_finish[pidx], 0.0)
            ptile = jnp.where(pred_ok, m_op_tile[pidx], -1)
            cross = (ptile[:, None] >= 0) \
                & (ptile[:, None] != jnp.arange(MAX_TILES)[None, :])
            dep = jnp.max(jnp.where(
                pred_ok[:, None],
                pf[:, None] + jnp.where(cross, noc_pred_s, 0.0),
                0.0), axis=0)
            t_start = jnp.maximum(m_tile_finish, dep)
            fins = t_start + c_hat / T["clock_hz"]

            # map_graph's sequential tie-break fold (see _build_mapper)
            best_t = jnp.asarray(-1, jnp.int32)
            best_fin = jnp.asarray(jnp.inf, _F)
            best_nm = jnp.asarray(0.0, _F)
            for t in range(MAX_TILES):
                fin, nm = fins[t], num_macs[t]
                better = fin < best_fin - _TIE
                tie = (jnp.abs(fin - best_fin) <= _TIE) & (best_t >= 0) \
                    & (nm < best_nm)
                upd = compat[t] & (better | tie)
                best_t = jnp.where(upd, t, best_t).astype(jnp.int32)
                best_fin = jnp.where(upd, fin, best_fin)
                best_nm = jnp.where(upd, nm, best_nm)

            # ---- Eq. 3 split probe: statically splittable MAC ops only ---
            mac_mask = compat & (num_macs > 0)

            def probe_split(o):
                ksplit = jnp.sum(mac_mask)
                kf = jnp.maximum(ksplit.astype(_F), 1.0)

                def axis_fin(axis):
                    sub = split_op_fields(jnp, o, axis, kf)
                    ch_s = cm.roofline_cycles_mac(T, sub, bw_share_est / kf) \
                        / T["clock_hz"]
                    fins_s = jnp.where(mac_mask, t_start + ch_s, -jnp.inf)
                    return jnp.max(fins_s) + noc_s(o["bytes_out"] / kf)

                fins3 = jnp.stack([axis_fin(0), axis_fin(1), axis_fin(2)])
                best_axis = jnp.argmin(fins3).astype(jnp.int32)
                do_split = (ksplit > 1) & (fins3[best_axis] < best_fin)
                return ksplit, best_axis, do_split, fins3[best_axis]

            def no_split(o):
                return (jnp.asarray(0, jnp.sum(mac_mask).dtype),
                        jnp.asarray(-1, jnp.int32),
                        jnp.asarray(False), jnp.asarray(jnp.inf, _F))

            ksplit, best_axis, do_split, split_fin = jax.lax.cond(
                can_split_u, probe_split, no_split, op)

            first_mac = jnp.argmax(mac_mask).astype(jnp.int32)
            owner = jnp.where(do_split, first_mac, best_t)
            choice_fin = jnp.where(do_split, split_fin, best_fin)

            # ---- mapping-state update (map_graph's finish bookkeeping) ---
            placed = active & any_compat
            onehot = jnp.arange(MAX_TILES) == owner
            mtf_single = jnp.where(onehot, choice_fin, m_tile_finish)
            mtf_split = jnp.where(mac_mask,
                                  jnp.maximum(m_tile_finish, choice_fin),
                                  m_tile_finish)
            m_tile_finish = jnp.where(
                placed, jnp.where(do_split, mtf_split, mtf_single),
                m_tile_finish)
            # compaction-padding rows carry index == n_state: drop their
            # state writes instead of clipping onto a real op's slot
            m_op_finish = m_op_finish.at[idx].set(
                jnp.where(placed, choice_fin, 0.0), mode="drop")
            m_op_tile = m_op_tile.at[idx].set(
                jnp.where(placed, owner, -1).astype(jnp.int32), mode="drop")
            ok = ok & (any_compat | ~active)

            # ---- execution of this op (batched.exec_plan semantics) ------
            k_ex = jnp.where(placed & do_split, ksplit, 1).astype(_F)
            mask = jnp.where(do_split, mac_mask, onehot) & placed
            is_split = k_ex > 1.0

            t_dep_e = jnp.max(jnp.where(pred_ok, op_finish[pidx], 0.0))
            src = jnp.where(pred_ok, cached_at[pidx], -1)
            via_noc = pred_ok & (src >= 0) & (src != owner)
            miss = pred_ok & (src < 0)
            dram_rd = op["bytes_w"] \
                + jnp.sum(jnp.where(miss, per_pred, 0.0)) \
                + jnp.where(op["num_preds"] == 0, op["bytes_in"], 0.0)
            extra_noc_s = jnp.sum(jnp.where(via_noc, noc_pred_s, 0.0))
            e_noc_in = jnp.sum(jnp.where(via_noc, noc_e(per_pred), 0.0))
            dram_wr = jnp.where(op["bytes_out"] > T["cache_cap"][owner],
                                op["bytes_out"], 0.0)

            t_start0 = jnp.maximum(tile_finish[owner], t_dep_e)
            n_active = jnp.maximum(jnp.sum(
                jnp.where(T["exists"] > 0, tile_finish > t_start0, False)),
                1.0)
            bw = chip["dram_gbps"] / n_active

            def ex_spec(o):
                return cm.execute_static_special(T, o)

            def ex_mac(o):
                return cm.execute_static_mac(T, o)

            def ex_dsp(o):
                return cm.execute_static_dsp(T, o)

            st = jax.lax.cond(
                is_spec_u, ex_spec,
                lambda o: jax.lax.cond(is_mac_u, ex_mac, ex_dsp, o), op)
            ex = cm.execute_dynamic(st, T, bw, dram_rd, dram_wr)
            fin_single = t_start0 + extra_noc_s + ex["seconds"][owner]

            def exec_split(o):
                kf = jnp.maximum(k_ex, 1.0)
                sub = split_op_fields(jnp, o, best_axis, kf)
                st_s = cm.execute_static_mac(T, sub)  # splits are MAC ops
                ex_s = cm.execute_dynamic(st_s, T, bw, dram_rd / kf,
                                          dram_wr / kf)
                starts_sub = jnp.maximum(tile_finish, t_dep_e) + extra_noc_s
                fins_sub = jnp.where(mask, starts_sub + ex_s["seconds"],
                                     -jnp.inf)
                slice_out = o["bytes_out"] / kf
                reduce_s = noc_s(slice_out)
                e_split = {m: ex_s[m] for m in
                           ("e_compute", "e_dram", "e_sram", "e_irf",
                            "e_orf", "e_dsp", "e_special")}
                return (ex_s["seconds"], fins_sub,
                        jnp.max(fins_sub) + reduce_s,
                        (kf - 1.0) * noc_e(slice_out), reduce_s,
                        e_split, ex_s["dram_bytes"])

            def exec_no_split(o):
                z = jnp.zeros(MAX_TILES, _F)
                zs = jnp.asarray(0.0, _F)
                e_split = {m: z for m in ("e_compute", "e_sram", "e_irf",
                                          "e_orf", "e_dsp", "e_special")}
                e_split["e_dram"] = zs   # e_dram is op-scalar, not per-tile
                return (z, z - jnp.inf, zs, zs, zs, e_split, zs)

            (sec_sub, fins_sub, fin_split, e_noc_split, reduce_s, e_sub,
             dram_b_sub) = jax.lax.cond(can_split_u, exec_split,
                                        exec_no_split, op)

            fin_op = jnp.where(is_split, fin_split, fin_single)

            tf_single = jnp.where(onehot, fin_single, tile_finish)
            tf_split = jnp.where(mask, fins_sub, tile_finish)
            tf_split = jnp.where(onehot,
                                 jnp.maximum(tf_split, fin_split), tf_split)
            new_tf = jnp.where(is_split, tf_split, tf_single)
            tile_finish = jnp.where(placed, new_tf, tile_finish)

            exec_mask = jnp.where(is_split, mask, onehot)
            tile_ops = tile_ops + jnp.where(placed & exec_mask, 1.0, 0.0)
            sec_each = jnp.where(is_split, sec_sub, ex["seconds"])
            tile_active = tile_active + jnp.where(placed & exec_mask,
                                                  sec_each, 0.0)

            new_e = dict(e_mod)
            for mod, key in (("compute", "e_compute"), ("dram", "e_dram"),
                             ("sram", "e_sram"), ("irf", "e_irf"),
                             ("orf", "e_orf"), ("dsp", "e_dsp"),
                             ("special", "e_special")):
                single_v = jnp.broadcast_to(ex[key], (MAX_TILES,))[owner]
                contrib = jnp.where(
                    is_split,
                    jnp.sum(jnp.where(
                        mask, jnp.broadcast_to(e_sub[key], (MAX_TILES,)),
                        0.0)),
                    single_v)
                new_e[mod] = e_mod[mod] + jnp.where(placed, contrib, 0.0)
            e_noc_op = e_noc_in + jnp.where(is_split, e_noc_split, 0.0)
            new_e["noc"] = e_mod["noc"] + jnp.where(placed, e_noc_op, 0.0)
            new_e["dsp"] = new_e["dsp"] + jnp.where(
                placed, op["fused_lane_ops"] * c.e_dsp_pj_per_lane_op, 0.0)
            new_e["fuse_savings"] = e_mod["fuse_savings"] + jnp.where(
                placed,
                op["fused_refund_bytes"] * c.e_sram_pj_per_byte, 0.0)
            e_mod = new_e

            dram_b_op = jnp.where(
                is_split,
                jnp.sum(jnp.where(
                    mask, jnp.broadcast_to(dram_b_sub, (MAX_TILES,)), 0.0)),
                jnp.broadcast_to(ex["dram_bytes"], (MAX_TILES,))[owner])
            noc_s_op = extra_noc_s + jnp.where(is_split, reduce_s, 0.0)
            occ = jnp.stack([dram_b_op, noc_s_op])
            res_occ = res_occ + jnp.where(placed, occ, jnp.zeros(2, _F))

            if link:
                # per-link XY routes + per-DRAM-channel bytes, identical
                # accumulation to batched._build_plan_exec (parity holds
                # because every ok row adds the same float contributions
                # in the same op order; empty routes add exact 0.0)
                owner_f = jnp.asarray(owner, _F)
                acq_rt = xy_route_link_mask(jnp, jnp.asarray(src, _F),
                                            owner_f, chip["grid_w"],
                                            chip["grid_h"], chip["torus"])
                acq_t = link_seconds(per_pred)
                for p in range(MAX_PREDS):
                    link_occ = link_occ + jnp.where(placed,
                                                    acq_rt[p] * acq_t, 0.0)
                red_rt = xy_route_link_mask(jnp, tidx_f, owner_f,
                                            chip["grid_w"], chip["grid_h"],
                                            chip["torus"])
                red_t = link_seconds(op["bytes_out"]
                                     / jnp.maximum(k_ex, 1.0))
                for t in range(MAX_TILES):
                    link_occ = link_occ + jnp.where(
                        placed & is_split & mask[t], red_rt[t] * red_t, 0.0)
                dram_each = jnp.where(
                    is_split,
                    jnp.where(mask, jnp.broadcast_to(dram_b_sub,
                                                     (MAX_TILES,)), 0.0),
                    jnp.where(onehot, jnp.broadcast_to(ex["dram_bytes"],
                                                       (MAX_TILES,)), 0.0))
                for t in range(MAX_TILES):
                    chan_occ = chan_occ + jnp.where(placed,
                                                    dram_each[t] * ch_oh[t],
                                                    0.0)

            op_finish = op_finish.at[idx].set(
                jnp.where(placed, fin_op, 0.0), mode="drop")
            fifo_ops, fifo_bytes, cached_at = fifo_insert(
                fifo_ops, fifo_bytes, cached_at, owner, idx,
                op["bytes_out"], T["cache_cap"][owner], placed)
            out_c = (m_tile_finish, m_op_finish, m_op_tile, ok,
                     tile_finish, op_finish, cached_at, fifo_ops, fifo_bytes,
                     tile_ops, tile_active, e_mod, res_occ)
            if link:
                out_c = out_c + (link_occ, chan_occ)
            return out_c, None

        e0 = {m: jnp.asarray(0.0, _F)
              for m in ("compute", "dram", "sram", "irf", "orf", "dsp",
                        "special", "noc", "fuse_savings")}
        init = (jnp.zeros(MAX_TILES, _F), jnp.zeros(n_state, _F),
                jnp.full(n_state, -1, jnp.int32), jnp.asarray(True),
                jnp.zeros(MAX_TILES, _F), jnp.zeros(n_state, _F),
                jnp.full(n_state, -1, jnp.int32),
                jnp.full((MAX_TILES, ACT_CACHE_SLOTS), -1, jnp.int32),
                jnp.zeros((MAX_TILES, ACT_CACHE_SLOTS), _F),
                jnp.zeros(MAX_TILES, _F), jnp.zeros(MAX_TILES, _F),
                e0, jnp.zeros(2, _F))
        if link:
            init = init + (jnp.zeros(MAX_LINKS, _F),
                           jnp.zeros(MAX_DRAM_CHANNELS, _F))
        final, _ = jax.lax.scan(step, init, xs["per_op"])
        (_, _, _, ok, tile_finish, _, _, _, _, tile_ops, tile_active,
         e_mod, res_occ) = final[:13]
        link_occ, chan_occ = (final[13], final[14]) if link else (None, None)

        # final surface: batched.exec_plan's reductions, verbatim
        makespan = jnp.max(tile_finish)
        gated = tile_ops <= 0
        resid = jnp.where(gated, c.power_gate_residual, 1.0)
        leak_t = jnp.where(T["exists"] > 0,
                           c.leak_mw_per_mm2 * T["area_mm2"] * makespan
                           * resid * 1e9, 0.0)
        leakage = jnp.sum(leak_t)
        energy = (e_mod["compute"] + e_mod["dram"] + e_mod["sram"]
                  + e_mod["irf"] + e_mod["orf"] + e_mod["dsp"]
                  + e_mod["special"] + e_mod["noc"] + leakage
                  - e_mod["fuse_savings"])
        achieved = jnp.where(makespan > 0, total_macs / makespan / 1e12, 0.0)
        out = {"latency_s": makespan, "energy_pj": energy,
               "achieved_tops": achieved, "ok": ok}
        dram_bytes, noc_busy = res_occ[0], res_occ[1]
        leak_rate = jnp.sum(jnp.where(T["exists"] > 0,
                                      c.leak_mw_per_mm2 * T["area_mm2"]
                                      * resid * 1e9, 0.0))
        out.update(pipeline_bounds(
            jnp, makespan, jnp.max(tile_active), dram_bytes,
            chip["dram_gbps"], noc_busy, chan_bytes=chan_occ,
            dram_channels=chip["dram_channels"] if link else None,
            link_busy_s=link_occ))
        ii = out["ii_s"]
        out["fill_latency_s"] = makespan
        out["dram_bytes_per_batch"] = dram_bytes
        out["energy_ss_pj"] = steady_state_energy(energy, leakage,
                                                  leak_rate, ii)
        out["achieved_tops_ss"] = jnp.where(ii > 0,
                                            total_macs / ii / 1e12, 0.0)
        out["pipeline_depth"] = jnp.where(ii > 0, jnp.ceil(makespan / ii),
                                          1.0)
        return out

    return run


def _search_xs_cached(ws: Dict[str, np.ndarray]):
    """Compacted device staging for the search kernel (same identity-
    pinned cache as ``_device_xs_cached``): (xs dict of compacted
    (n_steps, ...) arrays, n_steps, n_state, total_macs)."""
    return _staged(_SEARCH_XS_CACHE, ws, _search_xs)


def _search_xs(ws: Dict[str, np.ndarray]):
    n_state = len(ws["op_type"])
    sel = np.flatnonzero((np.asarray(ws["valid"]) > 0)
                         & (np.asarray(ws["fused"]) == 0))
    # bucket the compacted scan length (multiples of 16) so near-size
    # workloads share a jit trace; padding rows are valid=0 with
    # index == n_state (their state writes are scatter-dropped)
    n_steps = max(-(-len(sel) // 16) * 16, 16)
    pad = n_steps - len(sel)
    per_op = {}
    for k in _WS_KEYS:
        a = np.asarray(ws[k], np.float64)[sel]
        per_op[k] = jnp.asarray(np.concatenate(
            [a, np.zeros(pad, np.float64)]))
    preds = np.asarray(ws["preds"], np.int32)[sel]
    per_op["preds"] = jnp.asarray(np.concatenate(
        [preds, np.full((pad,) + preds.shape[1:], -1, np.int32)]))
    per_op["index"] = jnp.asarray(np.concatenate(
        [sel.astype(np.int32), np.full(pad, n_state, np.int32)]))
    xs = {"per_op": per_op}
    tm = jnp.asarray(float(ws["total_macs"]), _F)
    return xs, n_steps, n_state, tm


@functools.lru_cache(maxsize=64)
def _jitted_search_population(calib: CalibrationTable,
                              shapes: Tuple[Tuple[int, int], ...],
                              enable_split: bool = True,
                              fidelity: str = "aggregate"):
    """One jitted dispatch evaluating a candidate batch on EVERY workload
    of a generation: the per-workload single-scan search kernels run
    back-to-back inside one executable, so a GA generation costs one
    evaluation dispatch instead of W (no per-workload host sync, no
    executable alternation between kernels)."""
    fns = [_build_search(calib, n_steps, n_state, enable_split, fidelity)
           for n_steps, n_state in shapes]

    def run_all(tile, chip, xs_list, tm_list):
        return [fn(tile, chip, xs, tm)
                for fn, xs, tm in zip(fns, xs_list, tm_list)]

    batched = jax.vmap(run_all, in_axes=({k: 0 for k in TILE_KEYS},
                                         {k: 0 for k in CHIP_KEYS},
                                         None, None))
    return jax.jit(batched)


def search_population(ws_list, cfgs, calib: CalibrationTable = DEFAULT_CALIB,
                      sharding=None, placed=None, mode: str = "latency",
                      out_keys: Optional[Tuple[str, ...]] = None,
                      fidelity: str = "aggregate"):
    """Exact search scoring of one candidate batch on a list of prepared
    workloads, as ONE device dispatch (see ``_jitted_search_population``).
    Returns one result dict per workload — the ``search_and_simulate``
    surface (restricted to ``out_keys`` + ``ok`` when given: the engine
    fetches only the mode's three metric columns).  This is what
    ``EvalEngine(backend="exact")`` dispatches per miss batch, and hence
    what the device GA loop costs per generation."""
    if mode not in SCHEDULE_MODES:
        raise ValueError(
            f"exact search kernel cannot model schedule mode {mode!r}; "
            f"supported modes: {SCHEDULE_MODES}")
    if fidelity not in FIDELITIES:
        raise ValueError(f"fidelity must be one of {FIDELITIES}, got {fidelity!r}")
    staged = [_search_xs_cached(ws) for ws in ws_list]
    shapes = tuple((s[1], s[2]) for s in staged)
    xs_list = tuple(s[0] for s in staged)
    tm_list = tuple(s[3] for s in staged)
    tile, chip = placed if placed is not None \
        else place_configs(cfgs, sharding)
    fn = _jitted_search_population(calib, shapes, True, fidelity)
    outs = fn(tile, chip, xs_list, tm_list)
    results = []
    for out in outs:
        keys = out.keys() if out_keys is None \
            else tuple(out_keys) + ("ok",)
        res = {k: np.asarray(out[k]) for k in keys}
        res["area_mm2"] = cfgs["chip"]["chip_area"]
        res["peak_tops"] = cfgs["chip"]["peak_tops"]
        res["mode"] = mode
        results.append(res)
    return results


def search_and_simulate(ws: Dict[str, np.ndarray],
                        cfgs: Dict[str, Dict[str, np.ndarray]],
                        calib: CalibrationTable = DEFAULT_CALIB,
                        sharding=None, placed=None,
                        mode: str = "latency",
                        fidelity: str = "aggregate") -> Dict[str, np.ndarray]:
    """The exact *search* dispatch: one class-specialized scan that maps
    and executes every (active) op, returning only the (B,) scoring
    surface.

    Metrics are bitwise equal to ``map_and_simulate`` (same formulas —
    only the untaken operator-class branches and the inert fused/padding
    rows are skipped), for a fraction of its wall-clock: no second scan
    pass, no per-op placement materialization, no untaken-class
    arithmetic, no dead scan steps.  Both §3.2 schedule surfaces ride in
    the one scan; ``mode`` validates and tags the result.  Rows with
    ``ok == False`` carry garbage metrics and must be discarded by the
    caller.  For scoring several workloads per batch, prefer
    ``search_population`` (one dispatch for all of them).
    """
    return search_population([ws], cfgs, calib, sharding=sharding,
                             placed=placed, mode=mode, fidelity=fidelity)[0]
