"""Exact batched Eq. 1-3 mapper: ``map_graph`` as a jitted/vmapped scan.

The compile-free exact path.  ``map_graph`` re-derives, per candidate
chip, the same placement decision sequence the Python mapper makes —
compatibility filtering, SPECIAL->SFU routing, earliest-start times with
NoC-crossing dependency delays (Eq. 1), the roofline completion-time
argmin with the mapper's *sequential* smallest-tile tie-break (Eq. 2),
and the OC/B/IC split decision with the explicit Eq. 3 reduce/concat
cost — but as one ``lax.scan`` over the op axis with ``(MAX_TILES,)``
tile-field lanes, ``vmap``-ed across candidates and jitted.  Placements
come out as the stacked integer arrays (``owner`` / ``n_split`` /
``split_axis`` / ``split_mask``) that ``simulator.batched`` executes, in
exactly the layout ``compiler.pipeline.lower_plan`` emits, and are
pinned *bitwise* against ``map_graph`` by tests/test_batched_mapper.py.

Why exactness holds: every per-(op, tile) quantity is evaluated through
the shared ``simulator.costs.CostModel`` (literally the code the Python
mapper calls through numpy), the slice arithmetic is the shared
``split_op_fields`` mirror of ``ir.slice_op``, and the one genuinely
sequential piece of ``map_graph`` — the completion-time argmin whose
1e-15 tie band *chains* (a tie-break win updates the incumbent time) —
is replicated as an unrolled fold over the tile axis in ascending index
order rather than approximated with an epsilon-weighted ``argmin`` (the
approximation ``dse.batch_eval`` makes in-scan).

``map_and_simulate`` fuses this mapping scan with the batched plan
executor into a single device dispatch: per-workload arrays are prepared
once (``dse.engine.prepared_workload``) and shared across the candidate
axis (``vmap in_axes=None``), so the whole exact path — compile *and*
simulate — runs without any per-candidate Python work.  With a
``NamedSharding`` over the candidate axis the same dispatch spans every
available device (``launch.mesh.candidate_sharding``).

The Python ``map_graph`` stays the oracle reference; unmappable
candidates (some op with no compatible tile, the ``UnmappableError``
case) are reported through the ``ok`` output instead of an exception.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)  # cycle counts overflow f32 ULPs

import jax.numpy as jnp

from ..arch import MAX_TILES
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import OpClass
from ..simulator.batched import (CHIP_KEYS, SCHEDULE_MODES, TILE_KEYS,
                                 _build_plan_exec, _OP_TABLE_KEYS)
from ..simulator.costs import (OP_COST_KEYS, cost_model,
                               noc_transfer_seconds, split_op_fields)

__all__ = ["batched_map", "map_and_simulate", "place_configs"]

_F = jnp.float64

# map_graph's tie band: completion times within 1e-15 s prefer the
# smaller MAC array (compiler.mapper line-for-line).
_TIE = 1e-15

# workload fields the mapper scan consumes beyond the executor's op table
_WS_KEYS = _OP_TABLE_KEYS + ("splittable",)


# =============================================================================
# the mapping scan
# =============================================================================

def _build_mapper(calib: CalibrationTable, max_ops: int,
                  enable_split: bool = True):
    cm = cost_model(calib, jnp)

    def map_one(tile, chip, xs):
        """Map ONE workload onto ONE candidate chip.  tile: dict of
        (MAX_TILES,) arrays; chip: dict of scalars; xs["per_op"]: dict of
        (max_ops, ...) arrays.  Returns the stacked placement arrays plus
        the per-candidate ``ok`` mappability flag."""
        T = tile
        num_macs = T["num_macs"]
        n_tiles = jnp.sum(T["exists"])
        # static per-tile bandwidth share for the estimate domain (§3.2)
        bw_share = chip["dram_gbps"] / n_tiles

        def noc_s(nbytes):
            return noc_transfer_seconds(jnp, nbytes, chip["noc_bpc"],
                                        chip["hops"],
                                        chip["noc_base_cycles"],
                                        chip["ref_clock_hz"])

        def step(carry, op):
            tile_finish, op_finish, op_tile, ok = carry
            idx = jnp.asarray(op["index"], jnp.int32)
            active = (op["valid"] > 0) & (op["fused"] == 0)

            # ---- compatibility + SPECIAL->SFU routing (§3.2) -------------
            compat = cm.supports(T, op)
            native = cm.sfu_native(T, op) & compat
            is_spec = op["op_cls"] == int(OpClass.SPECIAL)
            compat = jnp.where(is_spec & jnp.any(native), native, compat)
            any_compat = jnp.any(compat)

            # ---- Eq. 1 earliest start per tile ---------------------------
            preds = jnp.asarray(op["preds"], jnp.int32)
            pred_ok = preds >= 0
            pidx = jnp.maximum(preds, 0)
            per_pred = op["per_pred_bytes"]
            pf = jnp.where(pred_ok, op_finish[pidx], 0.0)
            ptile = jnp.where(pred_ok, op_tile[pidx], -1)
            # fused / absent preds (op_tile == -1) count as local, exactly
            # like map_graph's op_tile.get(p, t)
            cross = (ptile[:, None] >= 0) \
                & (ptile[:, None] != jnp.arange(MAX_TILES)[None, :])
            dep = jnp.max(jnp.where(
                pred_ok[:, None],
                pf[:, None] + jnp.where(cross, noc_s(per_pred), 0.0),
                0.0), axis=0)
            t_start = jnp.maximum(tile_finish, dep)

            # ---- single-tile candidates (Eq. 2) --------------------------
            c_hat_s = cm.roofline_cycles(T, op, bw_share) / T["clock_hz"]
            fins = t_start + c_hat_s
            # map_graph's argmin is a *sequential* fold whose 1e-15 tie
            # band chains (a tie-break win replaces the incumbent best_fin
            # too); replicate it as an unrolled fold in tile-index order.
            best_t = jnp.asarray(-1, jnp.int32)
            best_fin = jnp.asarray(jnp.inf, _F)
            best_nm = jnp.asarray(0.0, _F)
            for t in range(MAX_TILES):
                fin, nm = fins[t], num_macs[t]
                better = fin < best_fin - _TIE
                tie = (jnp.abs(fin - best_fin) <= _TIE) & (best_t >= 0) \
                    & (nm < best_nm)
                upd = compat[t] & (better | tie)
                best_t = jnp.where(upd, t, best_t).astype(jnp.int32)
                best_fin = jnp.where(upd, fin, best_fin)
                best_nm = jnp.where(upd, nm, best_nm)

            # ---- split candidates (Eq. 3) --------------------------------
            mac_mask = compat & (num_macs > 0)
            ksplit = jnp.sum(mac_mask)
            kf = jnp.maximum(ksplit.astype(_F), 1.0)
            can_split = enable_split \
                & (op["op_cls"] == int(OpClass.MAC)) \
                & (op["splittable"] > 0) & (op["macs"] > 0) & (ksplit > 1)

            def axis_fin(axis):
                sub = split_op_fields(jnp, op, axis, kf)
                ch_s = cm.roofline_cycles(T, sub, bw_share / kf) \
                    / T["clock_hz"]
                fins_s = jnp.where(mac_mask, t_start + ch_s, -jnp.inf)
                # Eq. 3 reduce/concat cost over the NoC
                return jnp.max(fins_s) + noc_s(op["bytes_out"] / kf)

            fins3 = jnp.stack([axis_fin(0), axis_fin(1), axis_fin(2)])
            # sequential strict-< axis loop == first occurrence of the min
            best_axis = jnp.argmin(fins3).astype(jnp.int32)
            do_split = can_split & (fins3[best_axis] < best_fin)

            first_mac = jnp.argmax(mac_mask).astype(jnp.int32)
            owner = jnp.where(do_split, first_mac, best_t)
            choice_fin = jnp.where(do_split, fins3[best_axis], best_fin)

            # ---- state update (map_graph's finish bookkeeping) -----------
            placed = active & any_compat
            onehot = jnp.arange(MAX_TILES) == owner
            tf_single = jnp.where(onehot, choice_fin, tile_finish)
            tf_split = jnp.where(mac_mask,
                                 jnp.maximum(tile_finish, choice_fin),
                                 tile_finish)
            tile_finish = jnp.where(placed,
                                    jnp.where(do_split, tf_split, tf_single),
                                    tile_finish)
            op_finish = op_finish.at[idx].set(
                jnp.where(placed, choice_fin, 0.0))
            op_tile = op_tile.at[idx].set(
                jnp.where(placed, owner, -1).astype(jnp.int32))
            ok = ok & (any_compat | ~active)

            ys = {
                "owner": jnp.where(placed, owner, -1).astype(jnp.int32),
                "n_split": jnp.where(
                    placed, jnp.where(do_split, ksplit, 1),
                    0).astype(jnp.int32),
                "split_axis": jnp.where(placed & do_split, best_axis,
                                        -1).astype(jnp.int32),
                "split_mask": jnp.where(
                    placed, jnp.where(do_split, mac_mask, onehot), False),
            }
            return (tile_finish, op_finish, op_tile, ok), ys

        init = (jnp.zeros(MAX_TILES, _F), jnp.zeros(max_ops, _F),
                jnp.full(max_ops, -1, jnp.int32), jnp.asarray(True))
        (_, _, _, ok), ys = jax.lax.scan(step, init, xs["per_op"])
        ys["ok"] = ok
        return ys

    return map_one


# =============================================================================
# fused mapping + plan execution (one device dispatch per workload)
# =============================================================================

def _build_map_exec(calib: CalibrationTable, max_ops: int):
    mapper = _build_mapper(calib, max_ops)
    exec_plan = _build_plan_exec(calib, max_ops)

    def run(tile, chip, xs, total_macs):
        placed = mapper(tile, chip, xs)
        per_op = dict(xs["per_op"])
        # unmappable rows carry owner -1; clamp for the executor's gathers
        # (their lanes are discarded through ``ok`` host-side)
        per_op["owner"] = jnp.maximum(placed["owner"], 0)
        per_op["n_split"] = placed["n_split"].astype(_F)
        per_op["split_axis"] = placed["split_axis"]
        per_op["split_mask"] = placed["split_mask"].astype(_F)
        out = exec_plan(tile, chip, {"per_op": per_op}, total_macs)
        out["ok"] = placed["ok"]
        for f in ("owner", "n_split", "split_axis", "split_mask"):
            out[f] = placed[f]
        return out

    return run


# CalibrationTable is hashable (costs._cached_model already keys an LRU
# on it), so the jit caches key on the calib directly — no id()-keyed
# registry like the older jit wrappers carry.
@functools.lru_cache(maxsize=64)
def _jitted_map(calib: CalibrationTable, max_ops: int, enable_split: bool):
    fn = _build_mapper(calib, max_ops, enable_split)
    batched = jax.vmap(fn, in_axes=({k: 0 for k in TILE_KEYS},
                                    {k: 0 for k in CHIP_KEYS}, None))
    return jax.jit(batched)


@functools.lru_cache(maxsize=64)
def _jitted_map_exec(calib: CalibrationTable, max_ops: int):
    fn = _build_map_exec(calib, max_ops)
    batched = jax.vmap(fn, in_axes=({k: 0 for k in TILE_KEYS},
                                    {k: 0 for k in CHIP_KEYS}, None, None))
    return jax.jit(batched)


def _device_xs(ws: Dict[str, np.ndarray]) -> Tuple[dict, int]:
    max_ops = len(ws["op_type"])
    per_op = {k: jnp.asarray(ws[k], _F) for k in _WS_KEYS}
    per_op["preds"] = jnp.asarray(ws["preds"], jnp.int32)
    per_op["index"] = jnp.arange(max_ops, dtype=jnp.int32)
    return {"per_op": per_op}, max_ops


def place_configs(cfgs, sharding=None):
    """Stage a stacked config dict on device (optionally with the
    candidate-axis ``NamedSharding``) once, so callers looping over
    workloads don't re-place the same (B, MAX_TILES) arrays per
    workload.  Pass the result to ``batched_map`` / ``map_and_simulate``
    as ``placed``."""
    tile = {k: jnp.asarray(cfgs["tile"][k], _F) for k in TILE_KEYS}
    chip = {k: jnp.asarray(cfgs["chip"][k], _F) for k in CHIP_KEYS}
    if sharding is not None:
        put = lambda a: jax.device_put(a, sharding)
        tile = {k: put(v) for k, v in tile.items()}
        chip = {k: put(v) for k, v in chip.items()}
    return tile, chip


def batched_map(ws: Dict[str, np.ndarray],
                cfgs: Dict[str, Dict[str, np.ndarray]],
                calib: CalibrationTable = DEFAULT_CALIB,
                enable_split: bool = True,
                sharding=None, placed=None) -> Dict[str, np.ndarray]:
    """Exact Eq. 1-3 mapping of one workload onto B candidate chips.

    ``ws`` is a prepared-workload SoA dict (``dse.batch_eval
    .prepare_workload`` / the engine's ``prepared_workload`` cache);
    ``cfgs`` a stacked config dict (``stack_chip_configs`` or the
    engine's vectorized genome stack).  Returns ``owner`` (B, max_ops)
    int32, ``n_split`` (B, max_ops) int32, ``split_axis`` (B, max_ops)
    int32, ``split_mask`` (B, max_ops, MAX_TILES) int8 — bitwise the
    arrays ``lower_plan(emit_schedule(g, map_graph(g, chip)))`` produces
    for each candidate — and ``ok`` (B,) bool (False where ``map_graph``
    would raise ``UnmappableError``).
    """
    xs, max_ops = _device_xs(ws)
    tile, chip = placed if placed is not None \
        else place_configs(cfgs, sharding)
    out = _jitted_map(calib, max_ops, enable_split)(tile, chip, xs)
    return {
        "owner": np.asarray(out["owner"], np.int32),
        "n_split": np.asarray(out["n_split"], np.int32),
        "split_axis": np.asarray(out["split_axis"], np.int32),
        "split_mask": np.asarray(out["split_mask"], np.int8),
        "ok": np.asarray(out["ok"], bool),
    }


def map_and_simulate(ws: Dict[str, np.ndarray],
                     cfgs: Dict[str, Dict[str, np.ndarray]],
                     calib: CalibrationTable = DEFAULT_CALIB,
                     sharding=None, placed=None,
                     mode: str = "latency") -> Dict[str, np.ndarray]:
    """The compile-free exact path: batched Eq. 1-3 mapping fused with the
    batched plan executor in one jitted dispatch.

    Equivalent to, per candidate, ``map_graph`` -> ``emit_schedule`` ->
    ``lower_plan`` -> ``batch_simulate`` (the PR 2 exact path), but with
    zero per-candidate Python work: the workload arrays are shared across
    the candidate axis and the mapping scan feeds the execution scan on
    device.  Returns the ``batch_simulate`` result surface plus the
    placement arrays and the ``ok`` (B,) mappability mask; rows with
    ``ok == False`` (an op with no compatible tile) carry garbage metrics
    and must be discarded by the caller.

    ``mode`` selects the §3.2 schedule mode the caller scores on.  The
    fused dispatch always evaluates both surfaces (the latency makespan
    and the pipelined steady state — ``ii_s``, ``energy_ss_pj``,
    ``achieved_tops_ss`` and the per-resource bounds — cost one shared
    scan), so mode only validates and tags the result; an unknown mode
    raises rather than silently returning latency numbers.
    """
    if mode not in SCHEDULE_MODES:
        raise ValueError(
            f"batched mapper+executor cannot model schedule mode {mode!r}; "
            f"supported modes: {SCHEDULE_MODES}")
    xs, max_ops = _device_xs(ws)
    tile, chip = placed if placed is not None \
        else place_configs(cfgs, sharding)
    fn = _jitted_map_exec(calib, max_ops)
    out = fn(tile, chip, xs, jnp.asarray(float(ws["total_macs"]), _F))
    res = {k: np.asarray(v) for k, v in out.items()}
    res["area_mm2"] = cfgs["chip"]["chip_area"]
    res["peak_tops"] = cfgs["chip"]["peak_tops"]
    res["mode"] = mode
    return res
