"""Pass 3 — DAG-aware mapping with op-splitting (paper §3.2, Eqs. 1-3).

Operators are visited in topological order.  For each operator o the
mapper filters tiles by op-type + precision compatibility, then for each
compatible tile T computes the earliest start time

    t_start(o,T) = max( tile_finish[T],
                        max_{(f_j,T_j) in preds(o)} ( f_j + 1[T_j != T] * d_NoC ) )

and the roofline cycle estimate (Eq. 2), placing o on the tile minimizing
*completion time* t_start + C_hat.  For splittable MAC-class ops with
multiple compatible MAC tiles it evaluates an even split along OC / B / IC
with the explicit reduce/concat cost of Eq. 3, accepting the split only if
its finish time beats single-tile placement.

Compatibility filters and roofline estimates are evaluated through the
shared ``simulator.costs.CostModel`` — vectorized across the tile axis in
one numpy call per (op, bandwidth) query, which is what makes the Python
compile path fast enough to feed the batched plan executor — with values
bitwise identical to the per-tile ``TileSim`` wrappers.

Under a heterogeneous architecture this rule routes each op to the
smallest compatible tile (the paper's FP16-MATMUL->Big / INT8-Conv->any /
FFT->Special-Function behaviour) and partitions bulk MAC work across
Big+Little.  FP16-only ops on chips with one FP16-capable tile serialize —
visible in the 800 mm^2 regression the paper reports.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..arch import ChipConfig
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import OpClass, WorkloadGraph, slice_op
from ..simulator.costs import TILE_COST_KEYS, cost_model
from ..simulator.modules import tile_cost_dict
from ..simulator.orchestrator import Placement, noc_hops
from ..simulator.tile import TileSim, _SFU_FOR_OP, op_cost_dict

__all__ = ["map_graph", "UnmappableError"]

SPLIT_AXES = ("OC", "B", "IC")


class UnmappableError(RuntimeError):
    """No tile on the chip can execute some operator."""


def map_graph(g: WorkloadGraph, chip: ChipConfig,
              calib: CalibrationTable = DEFAULT_CALIB,
              enable_split: bool = True) -> Dict[int, Placement]:
    templates = chip.instances()
    n = len(templates)
    cm = cost_model(calib)
    # (n,) tile-field arrays: one vectorized CostModel query scores every
    # tile at once (bitwise equal to per-tile TileSim calls)
    dicts = [tile_cost_dict(t) for t in templates]
    T = {k: np.asarray([d[k] for d in dicts], np.float64)
         for k in TILE_COST_KEYS}
    clock_hz = T["clock_hz"]
    hops = noc_hops(chip.interconnect, n)
    ref_hz = chip.ref_clock_mhz * 1e6
    # static per-tile bandwidth share for the estimate domain; the
    # orchestrator replays with the dynamic N_active share (§3.3.4)
    bw_share = chip.dram_gbps / n

    def noc_s(nbytes: float) -> float:
        cycles = math.ceil(nbytes / chip.noc_bytes_per_cycle) \
            + hops * chip.noc_base_cycles
        return cycles / ref_hz

    tile_finish = [0.0] * n
    op_finish: Dict[int, float] = {}
    op_tile: Dict[int, int] = {}
    placements: Dict[int, Placement] = {}

    for i, op in enumerate(g.nodes):
        if op.fused_into >= 0:
            continue
        opd = op_cost_dict(op)
        compat_mask = np.asarray(cm.supports(T, opd))
        compat = [t for t in range(n) if compat_mask[t]]
        if not compat:
            raise UnmappableError(
                f"{g.name}: op {i} ({op.name}, {op.op_type.name}, "
                f"prec={op.precision.name}) has no compatible tile on {chip.name}")
        # The compatibility filter routes special ops to Special-Function
        # tiles whenever the chip has one with the required SFU (paper §3.2:
        # "FFT -> Special-Function"); MAC/DSP lowering is only the fallback
        # on chips without the unit.
        if op.op_cls == OpClass.SPECIAL:
            native = [t for t in compat
                      if templates[t].sfu_mask & _SFU_FOR_OP[int(op.op_type)]]
            if native:
                compat = native

        per_pred = op.bytes_in / max(len(op.preds), 1)

        def t_start_on(t: int) -> float:
            dep = 0.0
            for p in op.preds:
                f = op_finish.get(p, 0.0)
                if op_tile.get(p, t) != t:
                    f += noc_s(per_pred)
                dep = max(dep, f)
            return max(tile_finish[t], dep)

        # --- single-tile candidates (Eq. 1 + Eq. 2) -------------------------
        c_hat_s = np.asarray(cm.roofline_cycles(T, opd, bw_share)) / clock_hz
        best_t, best_fin, best_start = -1, float("inf"), 0.0
        for t in compat:
            ts = t_start_on(t)
            fin = ts + float(c_hat_s[t])
            # tie-break toward the smallest compatible tile
            if fin < best_fin - 1e-15 or (
                    abs(fin - best_fin) <= 1e-15 and best_t >= 0
                    and templates[t].num_macs < templates[best_t].num_macs):
                best_t, best_fin, best_start = t, fin, ts
        choice = Placement([best_t])
        choice_fin = best_fin

        # --- split candidates (Eq. 3) ---------------------------------------
        if (enable_split and op.op_cls == OpClass.MAC and op.splittable
                and op.macs > 0):
            mac_tiles = [t for t in compat if templates[t].num_macs > 0]
            if len(mac_tiles) > 1:
                k = len(mac_tiles)
                for axis in SPLIT_AXES:
                    sub = slice_op(op, axis, k)
                    ch_s = np.asarray(cm.roofline_cycles(
                        T, op_cost_dict(sub), bw_share / k)) / clock_hz
                    fins = [t_start_on(t) + float(ch_s[t]) for t in mac_tiles]
                    # Eq. 3 reduce/concat cost over the NoC
                    fin = max(fins) + noc_s(op.bytes_out / k)
                    if fin < choice_fin:
                        choice = Placement(list(mac_tiles), axis)
                        choice_fin = fin

        placements[i] = choice
        owner = choice.tiles[0]
        if len(choice.tiles) == 1:
            tile_finish[owner] = choice_fin
        else:
            for t in choice.tiles:
                tile_finish[t] = max(tile_finish[t], choice_fin)
        op_finish[i] = choice_fin
        op_tile[i] = owner

    return placements
