"""Pass 2 — operator fusion (paper §3.2).

A greedy left-to-right scan matches three-op (Conv+BN+Act, Conv+Add+Act)
and two-op (Conv+Act, Conv+Add, MatMul+Act, ...) patterns.  Matched groups
fold post-processing into the tile's post-processing module (PPM),
skipping the SRAM round-trip for intermediate tensors; the refund is
E_fuse = N_fused * 2*|out| * E_SRAM/B in Eq. 6.
"""
from __future__ import annotations

from typing import Dict, List, Set

from ..ir import OpClass, OpType, WorkloadGraph

__all__ = ["fuse"]

_NORM_OPS = {int(OpType.LAYERNORM), int(OpType.RMSNORM)}
_ACT_OPS = {int(OpType.RELU), int(OpType.GELU), int(OpType.SILU),
            int(OpType.SIGMOID)}
_ELTWISE = {int(OpType.ADD), int(OpType.MUL)}
_POST_OPS = _NORM_OPS | _ACT_OPS | _ELTWISE


def _consumers(g: WorkloadGraph) -> Dict[int, List[int]]:
    out: Dict[int, List[int]] = {i: [] for i in range(len(g.nodes))}
    for i, nd in enumerate(g.nodes):
        for p in nd.preds:
            out[p].append(i)
    return out


def fuse(g: WorkloadGraph, max_group: int = 3) -> WorkloadGraph:
    cons = _consumers(g)
    for i, head in enumerate(g.nodes):
        if head.op_cls != OpClass.MAC or head.fused_into >= 0:
            continue
        tail = i
        for _ in range(max_group - 1):
            nxt = cons.get(tail, [])
            # fusable only when the intermediate has exactly one consumer
            if len(nxt) != 1:
                break
            j = nxt[0]
            cand = g.nodes[j]
            if (int(cand.op_type) not in _POST_OPS or cand.fused_into >= 0
                    or cand.op_cls != OpClass.DSP):
                break
            cand.fused_into = i
            head.fused_count += 1
            tail = j
    return g
