"""Compiler driver: runs the four ordered passes (paper §3.2) and returns
an ExecutionPlan for the simulator, plus the plan -> op-table lowering
consumed by the batched simulator backend."""
from __future__ import annotations

import copy
from typing import Optional

import numpy as np

from ..arch import ChipConfig
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import AXIS_CODES, PlanTensor, WorkloadGraph, bucket_ops
from ..simulator.orchestrator import ExecutionPlan
from .fusion import fuse
from .mapper import map_graph
from .precision import assign_precision
from .schedule import emit_schedule

__all__ = ["compile_workload", "lower_plan", "compile_to_table"]


def compile_workload(g: WorkloadGraph, chip: ChipConfig,
                     calib: CalibrationTable = DEFAULT_CALIB,
                     aggressive_int4: bool = False,
                     enable_fusion: bool = True,
                     enable_split: bool = True,
                     mode: str = "latency") -> ExecutionPlan:
    """Compile a (workload, architecture) pair into an execution plan.

    The input graph is deep-copied: passes 1-2 mutate node precision and
    fusion tags, and the same workload object is reused across thousands of
    candidate architectures during DSE.
    """
    g = copy.deepcopy(g)
    g = assign_precision(g, aggressive_int4=aggressive_int4)
    if enable_fusion:
        g = fuse(g)
    placements = map_graph(g, chip, calib, enable_split=enable_split)
    return emit_schedule(g, placements, mode=mode)


def lower_plan(plan: ExecutionPlan, num_tiles: int,
               max_ops: Optional[int] = None) -> PlanTensor:
    """Lower a compiled plan into the fixed-shape SoA op-table executed by
    ``repro.core.simulator.batched``.

    Ops are padded to ``max_ops`` rows (default: the 64-multiple bucket of
    the graph length, so similar-size workloads share jit caches).
    Placements become integer arrays: ``owner`` (= ``Placement.tiles[0]``),
    ``n_split`` / ``split_axis`` / per-slot ``split_mask`` for Eq. 3 split
    executions.  Config-independent auxiliaries (per-pred byte shares,
    fused-group PPM energy and Eq. 6 refunds, total MACs) ride along in
    ``aux`` so the executor needs no graph object.
    """
    g = plan.graph
    n = len(g.nodes)
    cap = max_ops or bucket_ops(n)
    t = g.to_tensor(max_ops=cap)

    owner = np.full(cap, -1, np.int32)
    n_split = np.zeros(cap, np.int32)
    split_axis = np.full(cap, -1, np.int32)
    split_mask = np.zeros((cap, num_tiles), np.int8)
    for i, pl in plan.placements.items():
        owner[i] = pl.tiles[0]
        n_split[i] = len(pl.tiles)
        split_axis[i] = AXIS_CODES[pl.axis] if len(pl.tiles) > 1 else -1
        split_mask[i, list(pl.tiles)] = 1

    num_preds = (t.preds >= 0).sum(axis=1).astype(np.float64)
    fused_lane_ops = np.zeros(cap)
    fused_refund_b = np.zeros(cap)
    for j, nd in enumerate(g.nodes):
        if nd.fused_into >= 0:
            fused_lane_ops[nd.fused_into] += nd.elems * 2.0
            fused_refund_b[nd.fused_into] += 2.0 * nd.bytes_out
    aux = {
        "num_preds": num_preds,
        "per_pred_bytes": t.arrays["bytes_in"] / np.maximum(num_preds, 1.0),
        "fused_lane_ops": fused_lane_ops,
        "fused_refund_bytes": fused_refund_b,
        "total_macs": np.float64(sum(nd.macs for nd in g.nodes
                                     if nd.fused_into < 0)),
    }
    table = PlanTensor(ops=t, owner=owner, n_split=n_split,
                       split_axis=split_axis, split_mask=split_mask,
                       num_tiles=num_tiles, aux=aux)
    table.validate()
    return table


def compile_to_table(g: WorkloadGraph, chip: ChipConfig,
                     calib: CalibrationTable = DEFAULT_CALIB,
                     max_ops: Optional[int] = None,
                     **compile_kwargs) -> PlanTensor:
    """``compile_workload`` + ``lower_plan`` in one step."""
    plan = compile_workload(g, chip, calib, **compile_kwargs)
    return lower_plan(plan, chip.num_tiles, max_ops=max_ops)

