"""Compiler driver: runs the four ordered passes (paper §3.2) and returns
an ExecutionPlan for the simulator."""
from __future__ import annotations

import copy
from typing import Optional

from ..arch import ChipConfig
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import WorkloadGraph
from ..simulator.orchestrator import ExecutionPlan
from .fusion import fuse
from .mapper import map_graph
from .precision import assign_precision
from .schedule import emit_schedule

__all__ = ["compile_workload"]


def compile_workload(g: WorkloadGraph, chip: ChipConfig,
                     calib: CalibrationTable = DEFAULT_CALIB,
                     aggressive_int4: bool = False,
                     enable_fusion: bool = True,
                     enable_split: bool = True,
                     mode: str = "latency") -> ExecutionPlan:
    """Compile a (workload, architecture) pair into an execution plan.

    The input graph is deep-copied: passes 1-2 mutate node precision and
    fusion tags, and the same workload object is reused across thousands of
    candidate architectures during DSE.
    """
    g = copy.deepcopy(g)
    g = assign_precision(g, aggressive_int4=aggressive_int4)
    if enable_fusion:
        g = fuse(g)
    placements = map_graph(g, chip, calib, enable_split=enable_split)
    return emit_schedule(g, placements, mode=mode)
