"""Compiler driver: runs the four ordered passes (paper §3.2) and returns
an ExecutionPlan for the simulator, plus the plan -> op-table lowering
consumed by the batched simulator backend.

Two exact compile paths exist, selected by how many candidates you have:

* **Per-candidate (this module)** — ``compile_workload`` runs the Python
  passes (deepcopy -> ``assign_precision`` -> ``fuse`` -> ``map_graph``
  -> ``emit_schedule``) for one (workload, chip) pair; ``lower_plan``
  flattens the result into the ``PlanTensor`` op-table the batched
  executor consumes.  This is the oracle-reference path: it keeps the
  graph objects, so ``ChipSim`` can replay it with per-op traces.
* **Compile-free batched (``compiler.batched_mapper``)** — the same
  Eq. 1-3 mapping decisions as a jitted scan over ``(B, MAX_TILES)``
  tile arrays, emitting the stacked placement arrays directly and (via
  ``map_and_simulate``) feeding the batched executor in the same
  dispatch.  Placements are pinned bitwise against ``map_graph``; the
  config-independent passes 1-2 + tensorization run once per workload
  (``dse.engine.prepared_workload``), not once per candidate.

``dse.engine.EvalEngine`` picks between them: ``backend="batched"`` and
``rescore()`` default to the compile-free path (``exact_mapper=
"batched"``), ``exact_mapper="python"`` forces this module's
per-candidate pipeline, and ``backend="oracle"`` walks ``ChipSim`` on
``map_graph`` placements.  ``plan_from_arrays`` below crosses between
the two worlds: it rebuilds an ``ExecutionPlan`` from one candidate's
stacked placement arrays so the oracle can replay a batched-mapper
result.
"""
from __future__ import annotations

import copy
from typing import Optional

import numpy as np

from ..arch import ChipConfig
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..ir import (AXIS_CODES, PlanTensor, WorkloadGraph, bucket_ops,
                  placement_rows)
from ..simulator.orchestrator import ExecutionPlan, Placement
from .fusion import fuse
from .mapper import map_graph
from .precision import assign_precision
from .schedule import emit_schedule

__all__ = ["compile_workload", "lower_plan", "compile_to_table",
           "plan_from_arrays"]


def compile_workload(g: WorkloadGraph, chip: ChipConfig,
                     calib: CalibrationTable = DEFAULT_CALIB,
                     aggressive_int4: bool = False,
                     enable_fusion: bool = True,
                     enable_split: bool = True,
                     mode: str = "latency") -> ExecutionPlan:
    """Compile a (workload, architecture) pair into an execution plan.

    The input graph is deep-copied: passes 1-2 mutate node precision and
    fusion tags, and the same workload object is reused across thousands of
    candidate architectures during DSE.
    """
    g = copy.deepcopy(g)
    g = assign_precision(g, aggressive_int4=aggressive_int4)
    if enable_fusion:
        g = fuse(g)
    placements = map_graph(g, chip, calib, enable_split=enable_split)
    return emit_schedule(g, placements, mode=mode)


def lower_plan(plan: ExecutionPlan, num_tiles: int,
               max_ops: Optional[int] = None) -> PlanTensor:
    """Lower a compiled plan into the fixed-shape SoA op-table executed by
    ``repro.core.simulator.batched``.

    Ops are padded to ``max_ops`` rows (default: the 64-multiple bucket of
    the graph length, so similar-size workloads share jit caches).
    Placements become integer arrays: ``owner`` (= ``Placement.tiles[0]``),
    ``n_split`` / ``split_axis`` / per-slot ``split_mask`` for Eq. 3 split
    executions.  Config-independent auxiliaries (per-pred byte shares,
    fused-group PPM energy and Eq. 6 refunds, total MACs) ride along in
    ``aux`` so the executor needs no graph object.
    """
    g = plan.graph
    n = len(g.nodes)
    cap = max_ops or bucket_ops(n)
    t = g.to_tensor(max_ops=cap)

    owner = np.full(cap, -1, np.int32)
    n_split = np.zeros(cap, np.int32)
    split_axis = np.full(cap, -1, np.int32)
    split_mask = np.zeros((cap, num_tiles), np.int8)
    for i, pl in plan.placements.items():
        owner[i] = pl.tiles[0]
        n_split[i] = len(pl.tiles)
        split_axis[i] = AXIS_CODES[pl.axis] if len(pl.tiles) > 1 else -1
        split_mask[i, list(pl.tiles)] = 1

    num_preds = (t.preds >= 0).sum(axis=1).astype(np.float64)
    fused_lane_ops = np.zeros(cap)
    fused_refund_b = np.zeros(cap)
    for j, nd in enumerate(g.nodes):
        if nd.fused_into >= 0:
            fused_lane_ops[nd.fused_into] += nd.elems * 2.0
            fused_refund_b[nd.fused_into] += 2.0 * nd.bytes_out
    aux = {
        "num_preds": num_preds,
        "per_pred_bytes": t.arrays["bytes_in"] / np.maximum(num_preds, 1.0),
        "fused_lane_ops": fused_lane_ops,
        "fused_refund_bytes": fused_refund_b,
        "total_macs": np.float64(sum(nd.macs for nd in g.nodes
                                     if nd.fused_into < 0)),
    }
    table = PlanTensor(ops=t, owner=owner, n_split=n_split,
                       split_axis=split_axis, split_mask=split_mask,
                       num_tiles=num_tiles, aux=aux, mode=plan.mode)
    table.validate()
    return table


def compile_to_table(g: WorkloadGraph, chip: ChipConfig,
                     calib: CalibrationTable = DEFAULT_CALIB,
                     max_ops: Optional[int] = None,
                     **compile_kwargs) -> PlanTensor:
    """``compile_workload`` + ``lower_plan`` in one step.

    The per-candidate exact path (full Python passes per call).  At
    population scale prefer ``compiler.batched_mapper.map_and_simulate``,
    which makes the same placement decisions bitwise without any
    per-candidate Python work.
    """
    plan = compile_workload(g, chip, calib, **compile_kwargs)
    return lower_plan(plan, chip.num_tiles, max_ops=max_ops)


def plan_from_arrays(g: WorkloadGraph, owner: np.ndarray,
                     n_split: np.ndarray, split_axis: np.ndarray,
                     split_mask: np.ndarray,
                     mode: str = "latency") -> ExecutionPlan:
    """Rebuild an ``ExecutionPlan`` from ONE candidate's stacked placement
    arrays (a ``batched_map`` row, or a ``PlanTensor``'s fields) so the
    ``ChipSim`` oracle can replay a batched-mapper result with full per-op
    traces.  ``g`` must be the prepared graph the arrays were mapped from
    (passes 1-2 already applied)."""
    placements = {
        i: Placement(list(tiles), axis)
        for i, (tiles, axis) in placement_rows(
            owner, n_split, split_axis, split_mask).items()}
    return emit_schedule(g, placements, mode=mode)

