"""MOSAIC workload IR: the 23-operator vocabulary and the DAG representation.

A *workload* is a directed acyclic graph (DAG) of operators (paper §3.1).
Each operator carries a type from a 23-entry vocabulary (5 MAC-class,
15 DSP-class, 3 special), a shape (expressed as GEMM-equivalent M/K/N
dimensions plus an element count for non-GEMM ops), a precision, and
per-operand sparsity rates.

Two representations coexist:

* ``OpNode`` / ``WorkloadGraph`` — the object graph the compiler passes
  mutate (precision assignment, fusion tags, mapping results).
* ``OpTensor`` — a structure-of-arrays (SoA) encoding of the same graph as
  fixed-width numpy arrays, consumed by the vmapped/jitted batch evaluator
  and the Pallas ``dse_eval`` kernel.  This is the TPU-native re-think of
  the paper's per-op host loop (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "OpType",
    "OpClass",
    "Precision",
    "PRECISION_BYTES",
    "OpNode",
    "WorkloadGraph",
    "OpTensor",
    "PlanTensor",
    "MAX_PREDS",
    "AXIS_CODES",
    "AXIS_NAMES",
    "bucket_ops",
    "placement_rows",
]

MAX_PREDS = 4  # fixed predecessor fan-in for the SoA encoding (padded with -1)

# Split-axis integer codes shared by slice_op, the plan lowering
# (compiler.pipeline.lower_plan), the batched mapper and the batched
# executor.
AXIS_CODES = {"": -1, "OC": 0, "B": 1, "IC": 2}
AXIS_NAMES = {v: k for k, v in AXIS_CODES.items()}


def placement_rows(owner: "np.ndarray", n_split: "np.ndarray",
                   split_axis: "np.ndarray", split_mask: "np.ndarray"
                   ) -> Dict[int, Tuple[Tuple[int, ...], str]]:
    """Decode ONE candidate's stacked placement arrays back into per-op
    placement tuples ``{op index: (tiles, axis)}`` — the row-wise inverse
    of ``compiler.pipeline.lower_plan``'s placement lowering, shared by
    the oracle-replay helper (``compiler.pipeline.plan_from_arrays``) and
    the mapper parity tests.

    ``owner`` / ``n_split`` / ``split_axis`` are (max_ops,) integer
    arrays, ``split_mask`` (max_ops, num_tile_slots); rows with
    ``n_split == 0`` (fused / padding) are omitted.  Single placements
    return ``((owner,), "")``; splits return the mask's tile indices in
    ascending order with the owner first — ``lower_plan`` and the batched
    mapper both emit the lowest-index tile as the owner, which
    ``validate()``-ed tables guarantee.
    """
    out: Dict[int, Tuple[Tuple[int, ...], str]] = {}
    for i in np.flatnonzero(np.asarray(n_split) > 0):
        i = int(i)
        k = int(n_split[i])
        if k == 1:
            out[i] = ((int(owner[i]),), "")
        else:
            tiles = tuple(int(t) for t in np.flatnonzero(split_mask[i]))
            out[i] = (tiles, AXIS_NAMES[int(split_axis[i])])
    return out


def bucket_ops(n: int) -> int:
    """Pad op counts to multiples of 64: similar-size workloads share jit
    caches without power-of-two padding on the scan length (a 25 %
    scan-step tax on an 821-op graph padded to 1024)."""
    return max(((n + 63) // 64) * 64, 64)


class OpClass(enum.IntEnum):
    MAC = 0      # executed on the MAC array
    DSP = 1      # executed on the vector DSP
    SPECIAL = 2  # executed on a special-function unit (FFT / SNN / poly)


class OpType(enum.IntEnum):
    """23-entry operator vocabulary (paper §3.1): 5 MAC, 15 DSP, 3 special."""

    # --- MAC-class (5) ---
    CONV2D = 0
    DWCONV = 1
    CONV1D = 2
    MATMUL = 3
    FC = 4
    # --- DSP-class (15) ---
    ADD = 5
    MUL = 6
    SOFTMAX = 7
    LAYERNORM = 8
    RMSNORM = 9
    GELU = 10
    SILU = 11
    RELU = 12
    SIGMOID = 13
    POOL = 14
    REDUCE = 15
    GATHER = 16
    SCATTER = 17
    SSM_SCAN = 18
    ROPE = 19
    # --- Special (3) ---
    FFT = 20
    SNN_LIF = 21
    POLY = 22


_MAC_OPS = frozenset({OpType.CONV2D, OpType.DWCONV, OpType.CONV1D, OpType.MATMUL, OpType.FC})
_SPECIAL_OPS = frozenset({OpType.FFT, OpType.SNN_LIF, OpType.POLY})


def op_class(op_type: OpType) -> OpClass:
    if op_type in _MAC_OPS:
        return OpClass.MAC
    if op_type in _SPECIAL_OPS:
        return OpClass.SPECIAL
    return OpClass.DSP


class Precision(enum.IntEnum):
    INT4 = 0
    INT8 = 1
    FP16 = 2
    BF16 = 3
    FP32 = 4


# bytes per element, indexed by Precision
PRECISION_BYTES = np.array([0.5, 1.0, 2.0, 2.0, 4.0], dtype=np.float64)


@dataclasses.dataclass
class OpNode:
    """One operator in a workload DAG.

    GEMM-equivalent dims: a MAC op computes an (M x K) @ (K x N) product
    (convolutions are im2col-lowered: M = out pixels, K = Cin*kh*kw,
    N = Cout).  DSP/special ops use ``elems`` (element count of the
    dominant operand); M/K/N stay 0.
    """

    name: str
    op_type: OpType
    # GEMM dims (MAC ops)
    m: int = 0
    k: int = 0
    n: int = 0
    # element count (DSP / special ops)
    elems: int = 0
    precision: Precision = Precision.FP16
    # operand byte counts; filled by finalize() if left at 0
    bytes_in: int = 0
    bytes_w: int = 0
    bytes_out: int = 0
    act_sparsity: float = 0.0   # fraction of zero activations
    w_sparsity: float = 0.0     # fraction of zero weights
    preds: List[int] = dataclasses.field(default_factory=list)
    # special-op parameters
    fft_n: int = 0              # FFT length (radix-2)
    poly_degree: int = 0        # Horner polynomial degree
    snn_timesteps: int = 0      # LIF integration timesteps
    seq_len: int = 0            # SSM scan sequential multiplier (paper §3.3.1)
    # splitting permission along OC / batch / IC (paper Eq. 3 context)
    splittable: bool = True
    # accuracy-sensitive layers are pinned to FP16 by compiler pass 1
    accuracy_sensitive: bool = False
    # compiler pass results
    fused_into: int = -1        # index of group head when fused away
    fused_count: int = 0        # number of ops folded into this head
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def op_cls(self) -> OpClass:
        return op_class(self.op_type)

    @property
    def macs(self) -> int:
        if self.op_cls != OpClass.MAC:
            return 0
        return self.m * self.k * self.n

    def finalize(self) -> "OpNode":
        """Fill operand byte counts from dims when not explicitly given."""
        bpe = float(PRECISION_BYTES[self.precision])
        if self.op_cls == OpClass.MAC:
            if self.bytes_in == 0:
                self.bytes_in = int(self.m * self.k * bpe)
            if self.bytes_w == 0:
                self.bytes_w = int(self.k * self.n * bpe)
            if self.bytes_out == 0:
                self.bytes_out = int(self.m * self.n * bpe)
        else:
            if self.elems == 0:
                self.elems = max(self.m * max(self.n, 1), 1)
            if self.bytes_in == 0:
                self.bytes_in = int(self.elems * bpe)
            if self.bytes_out == 0:
                self.bytes_out = int(self.elems * bpe)
        return self


@dataclasses.dataclass
class WorkloadGraph:
    """A topologically ordered operator DAG plus workload metadata."""

    name: str
    nodes: List[OpNode] = dataclasses.field(default_factory=list)
    # Default numeric precision of the published model (Table 1 column)
    model_precision: Precision = Precision.FP16
    family: str = ""

    def add(self, node: OpNode, preds: Sequence[int] = ()) -> int:
        """Append ``node`` (preds refer to already-added indices); returns index."""
        idx = len(self.nodes)
        for p in preds:
            if not (0 <= p < idx):
                raise ValueError(f"{self.name}: pred {p} of node {idx} not topological")
        node.preds = list(preds)[:MAX_PREDS]
        node.finalize()
        self.nodes.append(node)
        return idx

    # -- convenience builders used by the workload suite --------------------
    def matmul(self, name: str, m: int, k: int, n: int, preds=(), **kw) -> int:
        return self.add(OpNode(name, OpType.MATMUL, m=m, k=k, n=n, **kw), preds)

    def dsp(self, name: str, op_type: OpType, elems: int, preds=(), **kw) -> int:
        return self.add(OpNode(name, op_type, elems=elems, **kw), preds)

    def validate(self) -> None:
        for i, nd in enumerate(self.nodes):
            for p in nd.preds:
                if p >= i:
                    raise ValueError(f"{self.name}: node {i} has non-topological pred {p}")

    # -- aggregate statistics ------------------------------------------------
    @property
    def total_macs(self) -> int:
        return sum(nd.macs for nd in self.nodes)

    @property
    def total_bytes(self) -> int:
        return sum(nd.bytes_in + nd.bytes_w + nd.bytes_out for nd in self.nodes)

    def arithmetic_intensity(self) -> float:
        """MACs per byte moved (paper Fig. 8 x-axis)."""
        b = self.total_bytes
        return self.total_macs / b if b else 0.0

    def class_histogram(self) -> Dict[str, int]:
        h = {"MAC": 0, "DSP": 0, "SPECIAL": 0}
        for nd in self.nodes:
            h[nd.op_cls.name] += 1
        return h

    def to_tensor(self, max_ops: Optional[int] = None) -> "OpTensor":
        return OpTensor.from_graph(self, max_ops=max_ops)


def slice_op(op: OpNode, axis: str, k: int) -> OpNode:
    """Even 1/k slice of a MAC op along OC (N), B (M) or IC (K) for
    op-splitting (paper Eq. 3 context).  Shared by the mapper's split
    estimate and the orchestrator's split execution."""
    sub = dataclasses.replace(op, preds=list(op.preds))
    if axis == "OC":
        sub.n = max(op.n // k, 1)
    elif axis == "B":
        sub.m = max(op.m // k, 1)
    elif axis == "IC":
        sub.k = max(op.k // k, 1)
    else:
        raise ValueError(f"bad split axis {axis}")
    sub.bytes_in = int(op.bytes_in // (k if axis == "B" else 1))
    sub.bytes_w = int(op.bytes_w // (k if axis != "B" else 1))
    sub.bytes_out = int(op.bytes_out // (k if axis != "IC" else 1))
    return sub


# Field list shared between OpTensor and the Pallas dse_eval kernel layout.
_SCALAR_FIELDS: Tuple[Tuple[str, np.dtype], ...] = (
    ("op_type", np.int32),
    ("op_cls", np.int32),
    ("macs", np.float64),
    ("elems", np.float64),
    ("m", np.float64),
    ("k", np.float64),
    ("n", np.float64),
    ("precision", np.int32),
    ("bytes_in", np.float64),
    ("bytes_w", np.float64),
    ("bytes_out", np.float64),
    ("act_sparsity", np.float64),
    ("w_sparsity", np.float64),
    ("fft_n", np.float64),
    ("poly_degree", np.float64),
    ("snn_timesteps", np.float64),
    ("seq_len", np.float64),
    ("splittable", np.int32),
    ("fused", np.int32),        # 1 if folded into a predecessor (skipped)
    ("fused_count", np.int32),  # fused group size when this is a head
    ("valid", np.int32),        # 0 on padding rows
)


@dataclasses.dataclass
class OpTensor:
    """SoA encoding of a workload graph (padded to ``max_ops`` rows)."""

    name: str
    num_ops: int
    arrays: Dict[str, np.ndarray]
    preds: np.ndarray  # (max_ops, MAX_PREDS) int32, -1 padded

    def __getattr__(self, item: str) -> np.ndarray:
        try:
            return self.arrays[item]
        except KeyError as e:  # pragma: no cover - attribute protocol
            raise AttributeError(item) from e

    @property
    def max_ops(self) -> int:
        return self.preds.shape[0]

    @staticmethod
    def from_graph(g: WorkloadGraph, max_ops: Optional[int] = None) -> "OpTensor":
        g.validate()
        n = len(g.nodes)
        cap = max_ops or n
        if cap < n:
            raise ValueError(f"{g.name}: {n} ops exceed max_ops={cap}")
        arrays: Dict[str, np.ndarray] = {
            fname: np.zeros(cap, dtype=dt) for fname, dt in _SCALAR_FIELDS
        }
        preds = np.full((cap, MAX_PREDS), -1, dtype=np.int32)
        for i, nd in enumerate(g.nodes):
            arrays["op_type"][i] = int(nd.op_type)
            arrays["op_cls"][i] = int(nd.op_cls)
            arrays["macs"][i] = nd.macs
            arrays["elems"][i] = nd.elems
            arrays["m"][i] = nd.m
            arrays["k"][i] = nd.k
            arrays["n"][i] = nd.n
            arrays["precision"][i] = int(nd.precision)
            arrays["bytes_in"][i] = nd.bytes_in
            arrays["bytes_w"][i] = nd.bytes_w
            arrays["bytes_out"][i] = nd.bytes_out
            arrays["act_sparsity"][i] = nd.act_sparsity
            arrays["w_sparsity"][i] = nd.w_sparsity
            arrays["fft_n"][i] = nd.fft_n
            arrays["poly_degree"][i] = nd.poly_degree
            arrays["snn_timesteps"][i] = nd.snn_timesteps
            arrays["seq_len"][i] = nd.seq_len
            arrays["splittable"][i] = int(nd.splittable)
            arrays["fused"][i] = int(nd.fused_into >= 0)
            arrays["fused_count"][i] = nd.fused_count
            arrays["valid"][i] = 1
            for j, p in enumerate(nd.preds[:MAX_PREDS]):
                preds[i, j] = p
        return OpTensor(name=g.name, num_ops=n, arrays=arrays, preds=preds)


# Placement fields of the plan op-table (PlanTensor), alongside the
# _SCALAR_FIELDS op fields.  ``owner`` is the first placement tile
# (ChipSim's ``pl.tiles[0]``), ``n_split`` the placement width, and
# ``split_mask`` the per-instance-slot membership of a split execution.
PLAN_FIELDS: Tuple[Tuple[str, np.dtype], ...] = (
    ("owner", np.int32),
    ("n_split", np.int32),
    ("split_axis", np.int32),   # AXIS_CODES; -1 on single placements
)


@dataclasses.dataclass
class PlanTensor:
    """SoA encoding of a compiled ExecutionPlan (paper §3.2 output).

    The op-table the batched simulator executes: the workload's
    ``OpTensor`` (ops padded to a fixed row count) plus per-op placement
    integer arrays and the config-independent auxiliaries the orchestrator
    needs (per-pred byte shares, fused-group PPM/refund credits).

    Built by ``repro.core.compiler.pipeline.lower_plan``; executed by
    ``repro.core.simulator.batched``.
    """

    ops: OpTensor
    owner: np.ndarray        # (max_ops,) int32; -1 on fused/padding rows
    n_split: np.ndarray      # (max_ops,) int32; 0 on fused/padding rows
    split_axis: np.ndarray   # (max_ops,) int32; AXIS_CODES values
    split_mask: np.ndarray   # (max_ops, num_tile_slots) int8
    num_tiles: int           # instantiated tiles of the target chip
    aux: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    # §3.2 schedule mode stamped from ExecutionPlan.mode by lower_plan:
    # "latency" (one batch, makespan-scored) or "throughput" (pipelined
    # batches, scored by the steady-state initiation interval).  The
    # batched executor dispatches on it — backends refuse modes they
    # cannot model instead of silently returning latency numbers.
    mode: str = "latency"

    @property
    def name(self) -> str:
        return self.ops.name

    @property
    def max_ops(self) -> int:
        return self.ops.max_ops

    def validate(self) -> None:
        n = self.ops.num_ops
        fused = self.ops.arrays["fused"]
        for i in range(n):
            if fused[i]:
                continue
            if not (0 <= self.owner[i] < self.num_tiles):
                raise ValueError(f"{self.name}: op {i} owner {self.owner[i]} "
                                 f"outside 0..{self.num_tiles - 1}")
            k = int(self.n_split[i])
            if k < 1 or k != int(self.split_mask[i].sum()):
                raise ValueError(f"{self.name}: op {i} split width {k} "
                                 f"inconsistent with its mask")
            if k > 1 and int(self.split_axis[i]) not in (0, 1, 2):
                raise ValueError(f"{self.name}: op {i} split without axis")
