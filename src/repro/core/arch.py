"""MOSAIC architecture schema: tile templates, chip configs, and the 12-knob
DSE grid (paper §3.1, §4.5).

The same schema describes a homogeneous chip (one template), a mixed-
precision chip (two templates) or a Big+Little+Special-Function chip.
``ChipConfig.to_vector()`` flattens a chip into a fixed-width float vector so
batches of thousands of candidate chips can be evaluated inside one jitted
function (and inside the Pallas ``dse_eval`` kernel).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from .ir import Precision

__all__ = [
    "Engine", "Sparsity", "Dataflow", "Interconnect", "AsymMAC",
    "TileTemplate", "ChipConfig", "KNOB_GRID", "MAX_TILE_TYPES",
    "MAX_TILES", "TILE_VEC_FIELDS", "CHIP_VEC_FIELDS",
]

MAX_TILE_TYPES = 3   # paper §4.5: 1-3 tile types
MAX_INSTANCES = 8    # paper §4.5: 1-8 instances per type
MAX_TILES = MAX_TILE_TYPES * MAX_INSTANCES


class Engine(enum.IntEnum):
    SYSTOLIC = 0
    SPATIAL = 1
    DOT = 2
    CIM = 3          # compute-in-memory


class Sparsity(enum.IntEnum):
    NONE = 0
    ACT = 1          # activation-sided skipping
    WEIGHT = 2       # weight-sided skipping
    TWO_SIDED = 3
    NM = 4           # structured N:M


class Dataflow(enum.IntEnum):
    WS = 0
    OS = 1
    RS = 2
    AUTO = 3


class Interconnect(enum.IntEnum):
    MESH = 0
    BUS = 1
    RING = 2
    NOC = 3


class AsymMAC(enum.IntEnum):
    NONE = 0
    W4A8 = 1
    W2A8 = 2
    W4A16 = 3        # paper: W4A16+W8A16 variant


# --- SFU bit masks -----------------------------------------------------------
SFU_FFT, SFU_SNN, SFU_POLY = 1, 2, 4


def prec_mask(precisions: Sequence[Precision]) -> int:
    m = 0
    for p in precisions:
        m |= 1 << int(p)
    return m


@dataclasses.dataclass(frozen=True)
class TileTemplate:
    """One tile type; a chip instantiates ``count`` copies of each template.

    ``rows == cols == 0`` describes a Special-Function tile (no MAC array).
    The supported-precision set is a per-tile knob (paper §3.3.5), not a
    property of the Big/Little label.
    """

    name: str
    rows: int = 32
    cols: int = 32
    engine: Engine = Engine.SYSTOLIC
    precisions: FrozenSet[Precision] = frozenset({Precision.INT8, Precision.FP16})
    sparsity: Sparsity = Sparsity.NONE
    dataflow: Dataflow = Dataflow.AUTO
    sram_kb: int = 512
    sram_banks: int = 8
    irf_bytes: int = 2048
    orf_bytes: int = 2048
    dsp_count: int = 1
    dsp_simd: int = 64           # lanes
    sfu_mask: int = 0            # OR of SFU_FFT / SFU_SNN / SFU_POLY
    sfu_parallel: int = 16       # N_par for the LIF unit; butterflies/cycle for FFT
    double_buffer: bool = True
    pipeline_depth: int = 4
    clock_mhz: int = 1200        # fixed per-type clock domain (paper §3.1)
    asym_mac: AsymMAC = AsymMAC.NONE

    @property
    def is_special(self) -> bool:
        return self.rows == 0 or self.cols == 0

    @property
    def num_macs(self) -> int:
        return self.rows * self.cols

    @property
    def max_precision(self) -> Precision:
        return max(self.precisions, key=int)

    @property
    def precision_mask(self) -> int:
        return prec_mask(sorted(self.precisions))

    def supports_precision(self, p: Precision) -> bool:
        if p in self.precisions:
            return True
        # Asymmetric-precision MAC variants accept narrower weights on the
        # wider datapath (W4A8 etc.).
        if self.asym_mac in (AsymMAC.W4A8, AsymMAC.W2A8) and p == Precision.INT4:
            return Precision.INT8 in self.precisions
        if self.asym_mac == AsymMAC.W4A16 and p in (Precision.INT4, Precision.INT8):
            return Precision.FP16 in self.precisions
        return False


@dataclasses.dataclass(frozen=True)
class ChipConfig:
    """A full HPU: tile templates + counts + interconnect + DRAM."""

    name: str
    tiles: Tuple[Tuple[TileTemplate, int], ...]
    interconnect: Interconnect = Interconnect.MESH
    dram_gbps: float = 64.0
    dram_latency_cycles: int = 100   # LPDDR5 access latency (paper §3.4)
    noc_bytes_per_cycle: float = 64.0
    noc_base_cycles: int = 8         # per-hop base latency
    ref_clock_mhz: int = 1000        # chip-level cycle base for NoC/DRAM DMA
    # link-fidelity interconnect structure (ignored by the aggregate tier)
    torus: bool = False              # wrap-around links on the tile grid
    grid_aspect: float = 1.0         # grid_w ~= round(sqrt(n) * aspect)
    dram_channels: int = 1           # address-interleaved DRAM channels

    def __post_init__(self):
        if not (1 <= len(self.tiles) <= MAX_TILE_TYPES):
            raise ValueError(f"{self.name}: need 1..{MAX_TILE_TYPES} tile types")
        for t, c in self.tiles:
            if not (1 <= c <= MAX_INSTANCES):
                raise ValueError(f"{self.name}/{t.name}: count {c} out of 1..{MAX_INSTANCES}")
        if self.dram_channels < 1:
            raise ValueError(f"{self.name}: dram_channels must be >= 1")
        if self.grid_aspect <= 0:
            raise ValueError(f"{self.name}: grid_aspect must be > 0")

    def instances(self) -> List[TileTemplate]:
        out: List[TileTemplate] = []
        for t, c in self.tiles:
            out.extend([t] * c)
        return out

    @property
    def num_tiles(self) -> int:
        return sum(c for _, c in self.tiles)

    # ------------------------------------------------------------------ SoA
    def to_vector(self) -> Dict[str, np.ndarray]:
        """Flatten to fixed-width arrays over MAX_TILES instance slots."""
        inst = self.instances()
        vec = {f: np.zeros(MAX_TILES, dtype=np.float64) for f in TILE_VEC_FIELDS}
        for i, t in enumerate(inst):
            vec["exists"][i] = 1.0
            vec["rows"][i] = t.rows
            vec["cols"][i] = t.cols
            vec["engine"][i] = int(t.engine)
            vec["prec_mask"][i] = t.precision_mask
            vec["asym_mac"][i] = int(t.asym_mac)
            vec["sparsity"][i] = int(t.sparsity)
            vec["dataflow"][i] = int(t.dataflow)
            vec["sram_kb"][i] = t.sram_kb
            vec["dsp_count"][i] = t.dsp_count
            vec["dsp_simd"][i] = t.dsp_simd
            vec["sfu_mask"][i] = t.sfu_mask
            vec["sfu_parallel"][i] = t.sfu_parallel
            vec["double_buffer"][i] = float(t.double_buffer)
            vec["pipeline_depth"][i] = t.pipeline_depth
            vec["clock_mhz"][i] = t.clock_mhz
        chip = {
            "dram_gbps": np.float64(self.dram_gbps),
            "dram_latency_cycles": np.float64(self.dram_latency_cycles),
            "noc_bytes_per_cycle": np.float64(self.noc_bytes_per_cycle),
            "noc_base_cycles": np.float64(self.noc_base_cycles),
            "interconnect": np.float64(int(self.interconnect)),
            "ref_clock_mhz": np.float64(self.ref_clock_mhz),
            "torus": np.float64(self.torus),
            "grid_aspect": np.float64(self.grid_aspect),
            "dram_channels": np.float64(self.dram_channels),
        }
        return {"tile": vec, "chip": chip}


TILE_VEC_FIELDS = (
    "exists", "rows", "cols", "engine", "prec_mask", "asym_mac", "sparsity",
    "dataflow", "sram_kb", "dsp_count", "dsp_simd", "sfu_mask", "sfu_parallel",
    "double_buffer", "pipeline_depth", "clock_mhz",
)
CHIP_VEC_FIELDS = (
    "dram_gbps", "dram_latency_cycles", "noc_bytes_per_cycle",
    "noc_base_cycles", "interconnect", "ref_clock_mhz",
    "torus", "grid_aspect", "dram_channels",
)


# =============================================================================
# The 12-knob DSE grid (paper §4.5, verbatim value sets)
# =============================================================================
KNOB_GRID: Dict[str, tuple] = {
    "array_dim": (8, 16, 32, 64, 128),                       # rows and cols
    "sram_kb": (64, 128, 256, 512, 1024, 2048, 4096),
    "precision_set": (
        frozenset({Precision.INT8}),
        frozenset({Precision.INT4, Precision.INT8}),
        frozenset({Precision.INT8, Precision.FP16}),
        frozenset({Precision.INT4, Precision.INT8, Precision.FP16}),
    ),
    "dram_gbps": (16, 32, 64, 128, 256, 512),
    "count": tuple(range(1, MAX_INSTANCES + 1)),
    "sparsity": (Sparsity.NONE, Sparsity.ACT, Sparsity.TWO_SIDED),
    "engine": (Engine.SYSTOLIC, Engine.SPATIAL, Engine.DOT, Engine.CIM),
    "dataflow": (Dataflow.WS, Dataflow.OS, Dataflow.RS),
    "interconnect": (Interconnect.MESH, Interconnect.BUS, Interconnect.RING, Interconnect.NOC),
    # link-fidelity interconnect knobs (searched as genome genes; the
    # aggregate tier only reads noc_bpc)
    "noc_topology": (False, True),                           # mesh, torus
    "grid_aspect": (0.5, 1.0, 2.0),
    "noc_bpc": (32, 64, 128, 256),
    "dram_channels": (1, 2, 4, 8),
    "double_buffer": (False, True),
    "asym_mac": (AsymMAC.NONE, AsymMAC.W4A8, AsymMAC.W2A8, AsymMAC.W4A16),
    "pipeline_depth": (1, 4, 8, 16),
    # tile-type composition is the 12th knob: how many types and which kinds
    "sfu_mask": (0, SFU_FFT, SFU_SNN, SFU_POLY, SFU_FFT | SFU_SNN | SFU_POLY),
}


def knob_space_size() -> float:
    """Rough cardinality of the joint space; the paper quotes > 1e14."""
    per_tile = (
        len(KNOB_GRID["array_dim"]) ** 2
        * len(KNOB_GRID["sram_kb"])
        * len(KNOB_GRID["precision_set"])
        * len(KNOB_GRID["count"])
        * len(KNOB_GRID["sparsity"])
        * len(KNOB_GRID["engine"])
        * len(KNOB_GRID["dataflow"])
        * len(KNOB_GRID["double_buffer"])
        * len(KNOB_GRID["asym_mac"])
        * len(KNOB_GRID["pipeline_depth"])
        * len(KNOB_GRID["sfu_mask"])
    )
    chip = (
        len(KNOB_GRID["dram_gbps"]) * len(KNOB_GRID["interconnect"])
        * len(KNOB_GRID["noc_topology"]) * len(KNOB_GRID["grid_aspect"])
        * len(KNOB_GRID["noc_bpc"]) * len(KNOB_GRID["dram_channels"])
    )
    return float(per_tile) ** MAX_TILE_TYPES * chip


# =============================================================================
# Canonical tile templates / baselines used throughout the paper's results
# =============================================================================

def big_tile(rows: int = 64, cols: int = 64, sram_kb: int = 2048,
             precisions: FrozenSet[Precision] = frozenset({Precision.INT8, Precision.FP16}),
             **kw) -> TileTemplate:
    """Paper §3.3.5 Big tile: large array, ample SRAM, two-sided sparsity, dual DSP."""
    kw.setdefault("sparsity", Sparsity.TWO_SIDED)
    kw.setdefault("dsp_count", 2)
    kw.setdefault("clock_mhz", 1200)
    return TileTemplate(name="big", rows=rows, cols=cols, sram_kb=sram_kb,
                        precisions=precisions, **kw)


def little_tile(rows: int = 16, cols: int = 16, sram_kb: int = 256,
                precisions: FrozenSet[Precision] = frozenset({Precision.INT4, Precision.INT8}),
                **kw) -> TileTemplate:
    """Paper §3.3.5 Little tile: small array, modest SRAM, single DSP, 500 MHz."""
    kw.setdefault("sparsity", Sparsity.ACT)
    kw.setdefault("dsp_count", 1)
    kw.setdefault("clock_mhz", 500)
    return TileTemplate(name="little", rows=rows, cols=cols, sram_kb=sram_kb,
                        precisions=precisions, **kw)


def special_tile(sfu_mask: int = SFU_FFT | SFU_SNN | SFU_POLY, sram_kb: int = 256,
                 **kw) -> TileTemplate:
    """Paper §3.3.5 Special-Function tile: no MAC array, SFUs + one DSP."""
    kw.setdefault("dsp_count", 1)
    kw.setdefault("clock_mhz", 800)
    return TileTemplate(name="special", rows=0, cols=0, sram_kb=sram_kb,
                        precisions=frozenset({Precision.FP16, Precision.INT8}),
                        sfu_mask=sfu_mask, **kw)


def homogeneous_baseline(n_tiles: int = 6, rows: int = 32, cols: int = 32,
                         sram_kb: int = 2048, dram_gbps: float = 64.0) -> ChipConfig:
    """Intel LNL-class homogeneous NPU (paper §3.1): identical FP16+INT8 MAC
    tiles with matched SRAM and DSPs, mesh interconnect, one DRAM channel."""
    t = TileTemplate(
        name="homog", rows=rows, cols=cols, sram_kb=sram_kb,
        precisions=frozenset({Precision.INT8, Precision.FP16}),
        sparsity=Sparsity.NONE, dsp_count=2, clock_mhz=1200,
    )
    return ChipConfig(name=f"homo-{n_tiles}x{rows}x{cols}",
                      tiles=((t, n_tiles),), dram_gbps=dram_gbps)


def hetero_bl(n_big: int = 2, n_little: int = 4, dram_gbps: float = 64.0) -> ChipConfig:
    return ChipConfig(name=f"heteroBL-{n_big}B{n_little}L",
                      tiles=((big_tile(), n_big), (little_tile(), n_little)),
                      dram_gbps=dram_gbps)


def hetero_bls(n_big: int = 2, n_little: int = 4, n_special: int = 1,
               dram_gbps: float = 64.0) -> ChipConfig:
    return ChipConfig(
        name=f"heteroBLS-{n_big}B{n_little}L{n_special}S",
        tiles=((big_tile(), n_big), (little_tile(), n_little),
               (special_tile(), n_special)),
        dram_gbps=dram_gbps)
