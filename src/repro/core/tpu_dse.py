"""Beyond-paper: MOSAIC's DSE methodology re-targeted at the TPU mesh.

The paper searches NPU tile compositions with analytical roofline cost
models; this module applies the identical methodology to the *training
framework itself*: knobs = (data-parallel width, tensor-parallel width,
microbatches, remat policy), cost model = the same three roofline terms
EXPERIMENTS.md §Roofline reports, calibrated against the dry-run's
compiled cost_analysis.  The search returns the predicted-fastest sharding
for a (ModelConfig, batch, seq) training cell — the paper's contribution
as a first-class feature of the runtime (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

from ..models.config import ModelConfig

__all__ = ["MeshKnobs", "MeshCost", "predict_cost", "search_mesh"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclasses.dataclass(frozen=True)
class MeshKnobs:
    dp: int
    tp: int
    microbatches: int = 1
    remat: bool = True


@dataclasses.dataclass
class MeshCost:
    knobs: MeshKnobs
    compute_s: float
    memory_s: float
    collective_s: float
    hbm_gib: float
    fits: bool

    @property
    def step_s(self) -> float:
        # double-buffered overlap of compute against the slower of
        # memory/collective traffic (Eq. 5's max-combine, applied to chips)
        return max(self.compute_s, self.memory_s, self.collective_s)


def predict_cost(cfg: ModelConfig, knobs: MeshKnobs, global_batch: int,
                 seq_len: int, hbm_gib: float = 16.0) -> MeshCost:
    """Analytical three-term roofline for one training step."""
    chips = knobs.dp * knobs.tp
    n = cfg.param_count()
    if cfg.n_experts:
        f = cfg.moe_d_ff or cfg.d_ff
        moe_layers = sum(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
        n_active = n - moe_layers * (cfg.n_experts - cfg.top_k) * 3 * cfg.d_model * f
    else:
        n_active = n
    tokens = global_batch * seq_len
    flops = 6.0 * n_active * tokens * (4.0 / 3.0 if knobs.remat else 1.0)
    t_c = flops / (chips * PEAK_FLOPS)

    # HBM traffic: params + grads + optimizer read/write per step, plus one
    # activation sweep per microbatch
    state_bytes = n * (2 + 2 + 8)  # bf16 p + bf16 g + fp32 m,v
    act_bytes = tokens * cfg.d_model * 2 * cfg.n_layers * (2 if knobs.remat else 6)
    t_m = (state_bytes + act_bytes) / (chips * HBM_BW)

    # collectives: TP all-gathers/reduce-scatters per layer + DP grad
    # all-reduce (ring: 2(p-1)/p of the shard)
    act_per_layer = (global_batch / knobs.dp) * seq_len * cfg.d_model * 2
    tp_bytes = 4.0 * cfg.n_layers * act_per_layer * (knobs.tp - 1) / max(knobs.tp, 1)
    dp_bytes = 2.0 * (n * 2 / knobs.tp) * (knobs.dp - 1) / max(knobs.dp, 1)
    t_l = (tp_bytes + dp_bytes) / (chips * LINK_BW)

    # memory check
    per_chip = state_bytes / chips \
        + act_bytes / chips / knobs.microbatches
    fits = per_chip <= hbm_gib * 2**30
    return MeshCost(knobs, t_c, t_m, t_l, per_chip / 2**30, fits)


def search_mesh(cfg: ModelConfig, chips: int, global_batch: int,
                seq_len: int, hbm_gib: float = 16.0) -> List[MeshCost]:
    """Enumerate (dp, tp, microbatch, remat) over ``chips`` and rank by the
    predicted step time — MOSAIC's sweep stage on mesh knobs."""
    out = []
    tps = [t for t in (1, 2, 4, 8, 16, 32) if chips % t == 0]
    for tp, mb, remat in itertools.product(tps, (1, 2, 4, 8), (False, True)):
        dp = chips // tp
        if global_batch % (dp * mb):
            continue
        out.append(predict_cost(cfg, MeshKnobs(dp, tp, mb, remat),
                                global_batch, seq_len, hbm_gib))
    out.sort(key=lambda c: (not c.fits, c.step_s, c.collective_s))
    return out
