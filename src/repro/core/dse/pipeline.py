"""The fused §4 multi-seed pipeline: sweep seeding → per-seed GA
refinement → Pareto merge, with device-resident memo state between
stages.

MOSAIC's Stage-1+2 study (paper §4.5) is per seed: a stratified random
sweep seeds one GA refinement per area bracket, and the per-bracket
winners across seeds merge into one energy/area/latency Pareto front.
``run_pipeline`` runs that whole study with the host involved only at
stage boundaries:

* Stage 1 per seed is ``sweep.run_sweep`` on a shared exact engine —
  scored batches land in the engine's host store, so repeated genomes
  across seeds (and across pipeline runs sharing a persistent store)
  are free.
* At each seed boundary the store's in-memory tier is loaded into a
  device-resident memo table (``device_memo.memo_from_store``) ONCE;
  every Stage-2 refinement of that seed then runs as one fused
  dispatch per bracket (``ga_device.run_ga_fused`` with
  ``store_sync=False``), threading the memo table bracket-to-bracket
  so later brackets hit earlier brackets' evaluations without a host
  round trip.  After the last bracket the memo drains back to the
  store (``device_memo.drain_to_store``) — the device→host half of
  the boundary sync.
* The Pareto merge is the device kernel ``pareto.pareto_mask_device``
  over (mean energy, area, mean latency) of every valid refined
  candidate — the same objective columns the evaluation service
  streams — keeping genomes aligned with surviving points.

Scale-out: with ``islands=None`` each refinement becomes an
island-model GA over the local device mesh (one island per device,
ring migration via collective permute — ``launch.mesh
.island_sharding``); on a single device it falls back to one panmictic
island, whose seeded genome stream is bitwise that of
``run_ga(loop="device")`` (pinned by tests/test_pipeline.py).

``on_stage`` streams progress: called after every completed stage with
an event dict carrying the stage name, seed/bracket, wall seconds, and
the *cumulative* Pareto front so far — the evaluation service's
pipeline endpoint forwards these to clients as they happen.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from .checkpoint import PipelineCheckpoint, run_digest
from .device_memo import (clear_fresh, drain_to_store, fresh_entries,
                          memo_from_store)
from .encoding import GENOME_LEN
from .api import EngineConfig
from .engine import EvalEngine, canonical_genomes
from .ga import GAConfig, GAResult
from .ga_device import run_ga_fused
from .objective import AREA_BRACKETS
from .pareto import pareto_mask_device
from .sweep import SweepResult, run_sweep

__all__ = ["PipelineResult", "run_pipeline"]


@dataclasses.dataclass
class PipelineResult:
    """Everything the §4 study produces, merged across seeds."""

    workloads: List[str]
    seeds: List[int]
    brackets: List[float]
    sweeps: Dict[int, SweepResult]
    # {seed: {bracket: GAResult}} — brackets without a homogeneous
    # baseline in that seed's sweep are absent (run_ga contract)
    results: Dict[int, Dict[float, GAResult]]
    front_points: np.ndarray     # (F, 3) mean energy pJ, area mm^2, mean lat s
    front_genomes: np.ndarray    # (F, GENOME_LEN) aligned with front_points
    evaluated: int               # genome evaluations across all GA stages
    stage_seconds: Dict[str, float]   # {"sweep": ..., "refine": ..., "merge": ...}

    def best(self, bracket: float) -> Optional[GAResult]:
        """Across seeds, the highest-fitness refinement at one bracket."""
        cands = [r[bracket] for r in self.results.values() if bracket in r]
        if not cands:
            return None
        return max(cands, key=lambda r: r.best_fitness)


def _sweep_arrays(swp: SweepResult) -> Dict[str, np.ndarray]:
    return {"genomes": swp.genomes, "family": swp.family,
            "bracket": swp.bracket, "area": swp.area,
            "latency": swp.latency, "energy": swp.energy,
            "tops_w": swp.tops_w}


def _sweep_from_record(seed: int, workloads: Sequence[str],
                       rec: Dict[str, np.ndarray]) -> SweepResult:
    return SweepResult(seed=seed, workloads=list(workloads),
                       genomes=rec["genomes"], family=rec["family"],
                       bracket=rec["bracket"], area=rec["area"],
                       latency=rec["latency"], energy=rec["energy"],
                       tops_w=rec["tops_w"])


def _import_sweep(engine: EvalEngine, swp: SweepResult) -> None:
    """Replay a resumed sweep's metric rows into the engine store —
    bitwise the rows ``run_sweep`` stored when it computed them — so
    the remaining stages' memo preloads and store probes hit exactly as
    the uninterrupted run's would."""
    rows = np.stack([swp.latency, swp.energy, swp.tops_w], axis=1)
    engine.import_memo(canonical_genomes(swp.genomes), rows)


def _refine_arrays(fused, front_pts: np.ndarray, front_genomes: np.ndarray,
                   dcanon: np.ndarray, drows: np.ndarray
                   ) -> Dict[str, np.ndarray]:
    r = fused.result
    return {"best_genome": r.best_genome,
            "best_fitness": np.float64(r.best_fitness),
            "best_savings": r.best_savings_per_wl,
            "best_lat": r.best_metrics["latency"],
            "best_en": r.best_metrics["energy"],
            "best_tw": r.best_metrics["tops_w"],
            "best_area": np.float64(r.best_metrics["area"]),
            "history": np.asarray(r.history, np.float64),
            "evaluated": np.int64(r.evaluated),
            "generations": np.int64(fused.generations_run),
            "front_points": front_pts, "front_genomes": front_genomes,
            "delta_canon": dcanon, "delta_rows": drows}


def _result_from_record(bracket: float, rec: Dict[str, np.ndarray]
                        ) -> GAResult:
    return GAResult(
        bracket=float(bracket), best_genome=rec["best_genome"],
        best_fitness=float(rec["best_fitness"]),
        best_savings_per_wl=rec["best_savings"],
        best_metrics={"latency": rec["best_lat"],
                      "energy": rec["best_en"],
                      "tops_w": rec["best_tw"],
                      "area": np.float64(rec["best_area"])},
        history=[float(x) for x in rec["history"]],
        evaluated=int(rec["evaluated"]))


def _valid_rows(metrics: Dict[str, np.ndarray]) -> np.ndarray:
    lat, en = metrics["latency"], metrics["energy"]
    ok = np.isfinite(lat).all(axis=1) & (lat > 0).all(axis=1)
    return ok & np.isfinite(en).all(axis=1)


def _merge_front(front_pts: np.ndarray, front_genomes: np.ndarray,
                 pop: np.ndarray, metrics: Dict[str, np.ndarray]
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Fold one refined population into the cumulative front (device
    dominance kernel; keep-first dedupe favours the incumbent front)."""
    valid = _valid_rows(metrics)
    if not valid.any():
        return front_pts, front_genomes
    pts = np.stack([metrics["energy"][valid].mean(axis=1),
                    metrics["area"][valid],
                    metrics["latency"][valid].mean(axis=1)], axis=1)
    front_pts = np.concatenate([front_pts, pts])
    front_genomes = np.concatenate(
        [front_genomes, np.asarray(pop, np.int64)[valid]])
    mask = np.asarray(pareto_mask_device(front_pts))
    return front_pts[mask], front_genomes[mask]


def run_pipeline(workloads: Sequence[str], seeds: Sequence[int] = (0, 1, 2),
                 brackets: Sequence[float] = AREA_BRACKETS,
                 samples_per_stratum: int = 64,
                 cfg: Optional[GAConfig] = None,
                 calib: CalibrationTable = DEFAULT_CALIB,
                 engine: Optional[EvalEngine] = None,
                 islands: Optional[int] = None, migrate_every: int = 5,
                 migrate_k: int = 2, memo_capacity: int = 1 << 15,
                 verbose: bool = False,
                 on_stage: Optional[Callable[[Dict[str, Any]], None]] = None,
                 checkpoint: Optional[str] = None,
                 cluster=None
                 ) -> PipelineResult:
    """Run the full multi-seed pipeline (see module docstring).

    ``engine`` must be a local exact engine when given (the fused
    refinement stages the search scan itself); by default one is built
    and shared across every stage, so its store accumulates the whole
    study.  ``cfg`` applies to every refinement; ``islands=None``
    scales each refinement over the local device mesh when the
    population divides evenly (single panmictic island otherwise).

    ``checkpoint=<dir>`` makes every completed stage durable
    (``dse.checkpoint.PipelineCheckpoint``: atomic per-stage records +
    a run digest) and resumes from it: rerunning after a crash replays
    completed stages from their records — emitting their events with
    ``"resumed": True`` and re-importing their store rows so the
    remaining stages hit a warm store — and the finished study is
    **bitwise equal** to an uninterrupted run (pinned by
    tests/test_checkpoint.py).  When no ``engine`` is passed, the
    default engine's store persists in the same directory
    (``results.sqlite``).  In checkpointed runs the memo drains to the
    host store after every *bracket* (the recorded delta) rather than
    once per seed, so a kill mid-seed loses at most one refinement.

    ``on_stage(event)`` fires after each stage with

    * ``{"stage": "sweep", "seed": s, "configs": n, "seconds": dt}``
    * ``{"stage": "refine", "seed": s, "bracket": b, "seconds": dt,
      "best_fitness": f, "generations": g, "front": {"points": (F, 3)
      array, "genomes": (F, GENOME_LEN) array}}`` — the cumulative
      front after merging this stage (ordered by mean energy)
    * ``{"stage": "seed_done", "seed": s, "drained": n}`` after the
      seed's memo drains back to the store (in checkpointed runs ``n``
      counts the seed's per-bracket deltas, resumed ones included)

    and must not mutate its arguments.  A checkpointed stage's record
    is durable *before* its event fires, so an ``on_stage`` callback
    that raises (or a kill while it runs) never loses the stage.

    ``cluster=<serve.cluster.DSECluster>`` scores the Stage-1 sweeps
    through a worker cluster instead of the local engine: shard losses
    fail over to surviving workers inside the cluster, and the sweep's
    metric rows are then replayed into the local engine's store
    (``_import_sweep`` — float64 round-trips bitwise over the wire), so
    the fused refinements proceed from exactly the warm store an
    all-local run would have.  The study result is bitwise equal with
    or without a cluster, so ``checkpoint=`` composes freely: a
    coordinator crash resumes from the checkpoint, a worker crash never
    loses a stage (the cluster absorbs it), and a checkpoint written
    by a clustered run resumes on a local one (the run digest is
    identical).  The cluster must serve the same engine context as
    ``engine`` (enforced via ``context_key``).
    """
    cfg = cfg or GAConfig()
    ck = PipelineCheckpoint(checkpoint) if checkpoint is not None else None
    if engine is None:
        engine = EvalEngine(workloads, calib, config=EngineConfig(
            backend="exact", nonfinite="skip",
            store=ck.open_store() if ck is not None else None))
    else:
        engine.check_workloads(workloads, calib)
    if not isinstance(engine, EvalEngine):
        raise ValueError("run_pipeline needs a local EvalEngine — the fused "
                         "refinement cannot run over a remote client")
    if engine.backend != "exact":
        raise ValueError("run_pipeline requires backend='exact'; got "
                         f"{engine.backend!r}")
    if cluster is not None:
        cluster.check_workloads(workloads, calib)
        if cluster.context_key() != engine.context_key():
            raise ValueError(
                "cluster workers serve a different engine context than the "
                "local pipeline engine — sweep rows would not replay "
                "bitwise into its store")
    if ck is not None:
        ck.open(run_digest(engine, seeds, brackets, samples_per_stratum,
                           cfg, islands, migrate_every, migrate_k))

    front_pts = np.zeros((0, 3))
    front_genomes = np.zeros((0, GENOME_LEN), np.int64)
    sweeps: Dict[int, SweepResult] = {}
    results: Dict[int, Dict[float, GAResult]] = {}
    evaluated = 0
    secs = {"sweep": 0.0, "refine": 0.0, "merge": 0.0}

    def emit(ev: Dict[str, Any]) -> None:
        if on_stage is not None:
            on_stage(ev)

    for s in seeds:
        skey = f"sweep:{s}"
        if ck is not None and ck.has(skey):
            rec = ck.load(skey)
            swp = _sweep_from_record(s, workloads, rec)
            dt = float(rec["seconds"])
            secs["sweep"] += dt
            sweeps[s] = swp
            _import_sweep(engine, swp)
            emit({"stage": "sweep", "seed": s, "configs": len(swp.genomes),
                  "seconds": dt, "resumed": True})
        else:
            t0 = time.perf_counter()
            swp = run_sweep(workloads, samples_per_stratum, seed=s,
                            calib=calib, brackets=brackets, verbose=verbose,
                            engine=engine if cluster is None else cluster)
            if cluster is not None:
                # the workers computed the rows; replay them into the
                # local engine's store so the fused refinements hit the
                # same warm store an all-local sweep would have left
                _import_sweep(engine, swp)
            dt = time.perf_counter() - t0
            secs["sweep"] += dt
            sweeps[s] = swp
            if ck is not None:
                ck.record(skey, seconds=np.float64(dt), **_sweep_arrays(swp))
            emit({"stage": "sweep", "seed": s, "configs": len(swp.genomes),
                  "seconds": dt})

        # seed boundary, host -> device: ONE memo load per seed, created
        # lazily before the first refinement that actually *runs* — so
        # on a resume every replayed stage has re-imported its rows into
        # the store by the time the preload walks it.  The per-bracket
        # refinements thread the table forward with store_sync=False: no
        # host sync between brackets (checkpointed runs additionally
        # drain each bracket's delta into the host store when recording).
        memo = None
        results[s] = {}
        drained = 0
        for b in brackets:
            rkey = f"refine:{s}:{float(b):g}"
            if ck is not None and ck.has(rkey):
                rec = ck.load(rkey)
                dt = float(rec["seconds"])
                secs["refine"] += dt
                if "skipped" in rec:
                    emit({"stage": "refine", "seed": s, "bracket": b,
                          "seconds": dt,
                          "skipped": "no homogeneous baseline",
                          "resumed": True})
                    continue
                res = _result_from_record(b, rec)
                results[s][b] = res
                evaluated += res.evaluated
                front_pts = rec["front_points"]
                front_genomes = rec["front_genomes"]
                engine.import_memo(rec["delta_canon"], rec["delta_rows"])
                drained += len(rec["delta_canon"])
                emit({"stage": "refine", "seed": s, "bracket": b,
                      "seconds": dt, "best_fitness": res.best_fitness,
                      "generations": int(rec["generations"]),
                      "front": {"points": front_pts.copy(),
                                "genomes": front_genomes.copy()},
                      "resumed": True})
                continue

            if memo is None:
                memo = memo_from_store(engine, memo_capacity)
            t0 = time.perf_counter()
            fused = run_ga_fused(swp, b, cfg, seed=s, calib=calib,
                                 verbose=verbose, engine=engine,
                                 islands=islands,
                                 migrate_every=migrate_every,
                                 migrate_k=migrate_k, memo=memo,
                                 store_sync=False)
            dt = time.perf_counter() - t0
            secs["refine"] += dt
            if fused is None:
                if ck is not None:
                    ck.record(rkey, skipped=np.int64(1),
                              seconds=np.float64(dt))
                emit({"stage": "refine", "seed": s, "bracket": b,
                      "seconds": dt, "skipped": "no homogeneous baseline"})
                continue
            memo = fused.memo
            results[s][b] = fused.result
            evaluated += fused.result.evaluated

            t0 = time.perf_counter()
            front_pts, front_genomes = _merge_front(
                front_pts, front_genomes, fused.population,
                fused.pop_metrics)
            order = np.argsort(front_pts[:, 0])
            front_pts = front_pts[order]
            front_genomes = front_genomes[order]
            secs["merge"] += time.perf_counter() - t0
            if ck is not None:
                # drain this bracket's delta now (instead of once per
                # seed): the recorded stage then carries its own rows —
                # the unit a resume re-imports
                dcanon, drows = fresh_entries(memo)
                engine.import_memo(dcanon, drows)
                memo = clear_fresh(memo)
                drained += len(dcanon)
                ck.record(rkey, seconds=np.float64(dt),
                          **_refine_arrays(fused, front_pts, front_genomes,
                                           dcanon, drows))
            emit({"stage": "refine", "seed": s, "bracket": b, "seconds": dt,
                  "best_fitness": fused.result.best_fitness,
                  "generations": fused.generations_run,
                  "front": {"points": front_pts.copy(),
                            "genomes": front_genomes.copy()}})
            if verbose:
                print(f"[pipeline seed {s}] bracket {b:.0f}mm2: "
                      f"best={fused.result.best_fitness:+.4f}, "
                      f"front size {len(front_pts)}")

        # seed boundary, device -> host: drain the memo once (already
        # drained per bracket in checkpointed runs — only leftovers,
        # normally zero, export here)
        dkey = f"seed_done:{s}"
        if ck is not None and ck.has(dkey):
            rec = ck.load(dkey)
            emit({"stage": "seed_done", "seed": s,
                  "drained": int(rec["drained"]), "resumed": True})
        else:
            if ck is None:
                drained = drain_to_store(memo, engine) \
                    if memo is not None else 0
            elif memo is not None:
                dcanon, drows = fresh_entries(memo)
                engine.import_memo(dcanon, drows)
                drained += len(dcanon)
            if ck is not None:
                ck.record(dkey, drained=np.int64(drained))
            emit({"stage": "seed_done", "seed": s, "drained": drained})

    return PipelineResult(
        workloads=list(workloads), seeds=list(seeds),
        brackets=[float(b) for b in brackets], sweeps=sweeps,
        results=results, front_points=front_pts,
        front_genomes=front_genomes, evaluated=evaluated,
        stage_seconds=secs)
