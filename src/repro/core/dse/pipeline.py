"""The fused §4 multi-seed pipeline: sweep seeding → per-seed GA
refinement → Pareto merge, with device-resident memo state between
stages.

MOSAIC's Stage-1+2 study (paper §4.5) is per seed: a stratified random
sweep seeds one GA refinement per area bracket, and the per-bracket
winners across seeds merge into one energy/area/latency Pareto front.
``run_pipeline`` runs that whole study with the host involved only at
stage boundaries:

* Stage 1 per seed is ``sweep.run_sweep`` on a shared exact engine —
  scored batches land in the engine's host store, so repeated genomes
  across seeds (and across pipeline runs sharing a persistent store)
  are free.
* At each seed boundary the store's in-memory tier is loaded into a
  device-resident memo table (``device_memo.memo_from_store``) ONCE;
  every Stage-2 refinement of that seed then runs as one fused
  dispatch per bracket (``ga_device.run_ga_fused`` with
  ``store_sync=False``), threading the memo table bracket-to-bracket
  so later brackets hit earlier brackets' evaluations without a host
  round trip.  After the last bracket the memo drains back to the
  store (``device_memo.drain_to_store``) — the device→host half of
  the boundary sync.
* The Pareto merge is the device kernel ``pareto.pareto_mask_device``
  over (mean energy, area, mean latency) of every valid refined
  candidate — the same objective columns the evaluation service
  streams — keeping genomes aligned with surviving points.

Scale-out: with ``islands=None`` each refinement becomes an
island-model GA over the local device mesh (one island per device,
ring migration via collective permute — ``launch.mesh
.island_sharding``); on a single device it falls back to one panmictic
island, whose seeded genome stream is bitwise that of
``run_ga(loop="device")`` (pinned by tests/test_pipeline.py).

``on_stage`` streams progress: called after every completed stage with
an event dict carrying the stage name, seed/bracket, wall seconds, and
the *cumulative* Pareto front so far — the evaluation service's
pipeline endpoint forwards these to clients as they happen.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from .device_memo import drain_to_store, memo_from_store
from .encoding import GENOME_LEN
from .engine import EvalEngine
from .ga import GAConfig, GAResult
from .ga_device import run_ga_fused
from .objective import AREA_BRACKETS
from .pareto import pareto_mask_device
from .sweep import SweepResult, run_sweep

__all__ = ["PipelineResult", "run_pipeline"]


@dataclasses.dataclass
class PipelineResult:
    """Everything the §4 study produces, merged across seeds."""

    workloads: List[str]
    seeds: List[int]
    brackets: List[float]
    sweeps: Dict[int, SweepResult]
    # {seed: {bracket: GAResult}} — brackets without a homogeneous
    # baseline in that seed's sweep are absent (run_ga contract)
    results: Dict[int, Dict[float, GAResult]]
    front_points: np.ndarray     # (F, 3) mean energy pJ, area mm^2, mean lat s
    front_genomes: np.ndarray    # (F, GENOME_LEN) aligned with front_points
    evaluated: int               # genome evaluations across all GA stages
    stage_seconds: Dict[str, float]   # {"sweep": ..., "refine": ..., "merge": ...}

    def best(self, bracket: float) -> Optional[GAResult]:
        """Across seeds, the highest-fitness refinement at one bracket."""
        cands = [r[bracket] for r in self.results.values() if bracket in r]
        if not cands:
            return None
        return max(cands, key=lambda r: r.best_fitness)


def _valid_rows(metrics: Dict[str, np.ndarray]) -> np.ndarray:
    lat, en = metrics["latency"], metrics["energy"]
    ok = np.isfinite(lat).all(axis=1) & (lat > 0).all(axis=1)
    return ok & np.isfinite(en).all(axis=1)


def _merge_front(front_pts: np.ndarray, front_genomes: np.ndarray,
                 pop: np.ndarray, metrics: Dict[str, np.ndarray]
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Fold one refined population into the cumulative front (device
    dominance kernel; keep-first dedupe favours the incumbent front)."""
    valid = _valid_rows(metrics)
    if not valid.any():
        return front_pts, front_genomes
    pts = np.stack([metrics["energy"][valid].mean(axis=1),
                    metrics["area"][valid],
                    metrics["latency"][valid].mean(axis=1)], axis=1)
    front_pts = np.concatenate([front_pts, pts])
    front_genomes = np.concatenate(
        [front_genomes, np.asarray(pop, np.int64)[valid]])
    mask = np.asarray(pareto_mask_device(front_pts))
    return front_pts[mask], front_genomes[mask]


def run_pipeline(workloads: Sequence[str], seeds: Sequence[int] = (0, 1, 2),
                 brackets: Sequence[float] = AREA_BRACKETS,
                 samples_per_stratum: int = 64,
                 cfg: Optional[GAConfig] = None,
                 calib: CalibrationTable = DEFAULT_CALIB,
                 engine: Optional[EvalEngine] = None,
                 islands: Optional[int] = None, migrate_every: int = 5,
                 migrate_k: int = 2, memo_capacity: int = 1 << 15,
                 verbose: bool = False,
                 on_stage: Optional[Callable[[Dict[str, Any]], None]] = None
                 ) -> PipelineResult:
    """Run the full multi-seed pipeline (see module docstring).

    ``engine`` must be a local exact engine when given (the fused
    refinement stages the search scan itself); by default one is built
    and shared across every stage, so its store accumulates the whole
    study.  ``cfg`` applies to every refinement; ``islands=None``
    scales each refinement over the local device mesh when the
    population divides evenly (single panmictic island otherwise).

    ``on_stage(event)`` fires after each stage with

    * ``{"stage": "sweep", "seed": s, "configs": n, "seconds": dt}``
    * ``{"stage": "refine", "seed": s, "bracket": b, "seconds": dt,
      "best_fitness": f, "generations": g, "front": {"points": (F, 3)
      array, "genomes": (F, GENOME_LEN) array}}`` — the cumulative
      front after merging this stage (ordered by mean energy)
    * ``{"stage": "seed_done", "seed": s, "drained": n}`` after the
      seed's memo drains back to the store

    and must not mutate its arguments.
    """
    cfg = cfg or GAConfig()
    engine = (engine.check_workloads(workloads, calib)
              if engine is not None
              else EvalEngine(workloads, calib, backend="exact"))
    if not isinstance(engine, EvalEngine):
        raise ValueError("run_pipeline needs a local EvalEngine — the fused "
                         "refinement cannot run over a remote client")
    if engine.backend != "exact":
        raise ValueError("run_pipeline requires backend='exact'; got "
                         f"{engine.backend!r}")

    front_pts = np.zeros((0, 3))
    front_genomes = np.zeros((0, GENOME_LEN), np.int64)
    sweeps: Dict[int, SweepResult] = {}
    results: Dict[int, Dict[float, GAResult]] = {}
    evaluated = 0
    secs = {"sweep": 0.0, "refine": 0.0, "merge": 0.0}

    def emit(ev: Dict[str, Any]) -> None:
        if on_stage is not None:
            on_stage(ev)

    for s in seeds:
        t0 = time.perf_counter()
        swp = run_sweep(workloads, samples_per_stratum, seed=s, calib=calib,
                        brackets=brackets, verbose=verbose, engine=engine)
        dt = time.perf_counter() - t0
        secs["sweep"] += dt
        sweeps[s] = swp
        emit({"stage": "sweep", "seed": s, "configs": len(swp.genomes),
              "seconds": dt})

        # seed boundary, host -> device: ONE memo load per seed; the
        # per-bracket refinements below thread the table forward with
        # store_sync=False so no host sync happens between brackets
        memo = memo_from_store(engine, memo_capacity)
        results[s] = {}
        for b in brackets:
            t0 = time.perf_counter()
            fused = run_ga_fused(swp, b, cfg, seed=s, calib=calib,
                                 verbose=verbose, engine=engine,
                                 islands=islands,
                                 migrate_every=migrate_every,
                                 migrate_k=migrate_k, memo=memo,
                                 store_sync=False)
            dt = time.perf_counter() - t0
            secs["refine"] += dt
            if fused is None:
                emit({"stage": "refine", "seed": s, "bracket": b,
                      "seconds": dt, "skipped": "no homogeneous baseline"})
                continue
            memo = fused.memo
            results[s][b] = fused.result
            evaluated += fused.result.evaluated

            t0 = time.perf_counter()
            front_pts, front_genomes = _merge_front(
                front_pts, front_genomes, fused.population,
                fused.pop_metrics)
            order = np.argsort(front_pts[:, 0])
            front_pts = front_pts[order]
            front_genomes = front_genomes[order]
            secs["merge"] += time.perf_counter() - t0
            emit({"stage": "refine", "seed": s, "bracket": b, "seconds": dt,
                  "best_fitness": fused.result.best_fitness,
                  "generations": fused.generations_run,
                  "front": {"points": front_pts.copy(),
                            "genomes": front_genomes.copy()}})
            if verbose:
                print(f"[pipeline seed {s}] bracket {b:.0f}mm2: "
                      f"best={fused.result.best_fitness:+.4f}, "
                      f"front size {len(front_pts)}")

        # seed boundary, device -> host: drain the memo once
        drained = drain_to_store(memo, engine)
        emit({"stage": "seed_done", "seed": s, "drained": drained})

    return PipelineResult(
        workloads=list(workloads), seeds=list(seeds),
        brackets=[float(b) for b in brackets], sweeps=sweeps,
        results=results, front_points=front_pts,
        front_genomes=front_genomes, evaluated=evaluated,
        stage_seconds=secs)
