"""Stage 1 — multi-seed stratified random sweep (paper §3.5, §4.5).

Strata = area bracket x architecture family ({Homo, Hetero-BL,
Hetero-BLS}).  Per seed, a genome pool is sampled per family, assigned to
area brackets, and every in-bracket config is scored on every workload
with the jitted batch evaluator.  Per-workload savings are computed
against the *best homogeneous design at the same bracket* found in the
same sweep (the iso-area baseline of Eq. 8).

Paper scale is 3 seeds x ~980 K samples; ``samples_per_family`` keeps CPU
runs tractable and ``--paper-scale`` in the benchmarks restores the full
counts (DESIGN.md §2 "assumptions changed").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..workloads import build
from .batch_eval import batch_evaluate, prepare_configs, prepare_workload
from .encoding import FAMILIES, decode, random_genomes
from .api import EngineConfig
from .engine import EvalEngine
from .objective import ALPHA, AREA_BRACKETS, area_bracket

__all__ = ["SweepResult", "run_sweep", "run_sweeps", "evaluate_genomes",
           "evaluate_genomes_reference"]


@dataclasses.dataclass
class SweepResult:
    """All sampled configs of one seed, plus per-workload metrics."""

    seed: int
    workloads: List[str]
    genomes: np.ndarray          # (N, GENOME_LEN)
    family: np.ndarray           # (N,) index into FAMILIES
    bracket: np.ndarray          # (N,) mm^2 bracket value
    area: np.ndarray             # (N,)
    latency: np.ndarray          # (N, W) seconds
    energy: np.ndarray           # (N, W) pJ
    tops_w: np.ndarray           # (N, W)

    def valid_mask(self) -> np.ndarray:
        ok = np.isfinite(self.latency).all(axis=1) & (self.latency > 0).all(axis=1)
        return ok & np.isfinite(self.energy).all(axis=1)

    def homo_baseline(self) -> Dict[float, np.ndarray]:
        """Per bracket: per-workload minimum energy over valid Homo configs
        with area <= bracket.  Cumulative over brackets because the largest
        single-type homo chip tops out near ~220 mm^2 on the paper's knob
        grid — at 400/800 mm^2 the baseline is "the biggest homo chip"."""
        out: Dict[float, np.ndarray] = {}
        valid = self.valid_mask()
        best: Optional[np.ndarray] = None
        for b in AREA_BRACKETS:
            sel = valid & (self.family == 0) & (self.bracket == b)
            if sel.any():
                cur = self.energy[sel].min(axis=0)
                best = cur if best is None else np.minimum(best, cur)
            if best is not None:
                out[b] = best
        return out

    def savings(self) -> np.ndarray:
        """(N, W) iso-area fractional savings vs the homo baseline; NaN when
        the bracket has no homogeneous baseline."""
        base = self.homo_baseline()
        sav = np.full_like(self.energy, np.nan)
        for b, e_h in base.items():
            sel = self.bracket == b
            sav[sel] = (e_h[None, :] - self.energy[sel]) / np.maximum(e_h, 1e-30)
        sav[~self.valid_mask()] = np.nan
        return sav

    def fitness(self, alpha: float = ALPHA) -> np.ndarray:
        """(N,) Eq. 8 fitness (NaN-safe; invalid configs get -inf)."""
        sav = self.savings()
        mean_sav = np.nanmean(sav, axis=1)
        peak_tw = np.nanmax(np.where(np.isfinite(self.tops_w), self.tops_w, np.nan),
                            axis=1)
        max_tw = np.nanmax(peak_tw) if np.isfinite(peak_tw).any() else 1.0
        fit = mean_sav + alpha * peak_tw / max(max_tw, 1e-30)
        fit[~np.isfinite(fit)] = -np.inf
        return fit


def evaluate_genomes(genomes: np.ndarray, workloads: Sequence[str],
                     calib: CalibrationTable = DEFAULT_CALIB,
                     batch: int = 1024) -> Dict[str, np.ndarray]:
    """Score genomes on every workload (one-shot ``EvalEngine``).

    Search loops should hold their own engine so the genome memo and
    workload-prep cache persist across calls; this wrapper exists for
    single-batch scoring and backwards compatibility."""
    return EvalEngine(workloads, calib,
                      config=EngineConfig(batch=batch)).evaluate(genomes)


def evaluate_genomes_reference(genomes: np.ndarray, workloads: Sequence[str],
                               calib: CalibrationTable = DEFAULT_CALIB,
                               batch: int = 1024) -> Dict[str, np.ndarray]:
    """Pre-engine host loop, kept verbatim as the parity/benchmark
    baseline: re-prepares every workload per batch and decodes every
    genome into Python ChipConfig objects."""
    chips = [decode(g, f"g{i}") for i, g in enumerate(genomes)]
    n, w = len(chips), len(workloads)
    lat = np.zeros((n, w))
    en = np.zeros((n, w))
    tw = np.zeros((n, w))
    area = np.zeros(n)
    for s in range(0, n, batch):
        cfgs = prepare_configs(chips[s:s + batch], calib)
        area[s:s + batch] = cfgs["chip"]["chip_area"]
        for j, wname in enumerate(workloads):
            ws = prepare_workload(build(wname))
            res = batch_evaluate(ws, cfgs, calib)
            lat[s:s + batch, j] = res["latency_s"]
            en[s:s + batch, j] = res["energy_pj"]
            power = res["energy_pj"] * 1e-12 / np.maximum(res["latency_s"], 1e-30)
            tw[s:s + batch, j] = res["achieved_tops"] / np.maximum(power, 1e-30)
    return {"latency": lat, "energy": en, "tops_w": tw, "area": area}


def run_sweep(workloads: Sequence[str], samples_per_stratum: int = 64,
              seed: int = 0, calib: CalibrationTable = DEFAULT_CALIB,
              brackets: Sequence[float] = AREA_BRACKETS,
              verbose: bool = False,
              engine: Optional[EvalEngine] = None,
              exact: bool = False) -> SweepResult:
    """One seed of the stratified sweep (strata = bracket x family).

    Pass a shared ``engine`` to reuse its caches across seeds and into
    the downstream GA refinement (repeated genomes are free).  The
    engine's §3.2 schedule mode flows through unchanged: with
    ``EvalEngine(..., mode="throughput")`` the latency/energy matrices
    hold the pipelined steady state (II, energy per inference), so the
    same sweep ranks serving-deployment designs — see
    ``objective.serving_fitness`` and ``examples/serve_lm.py --dse``.

    ``exact=True`` (only meaningful without a shared ``engine``) scores
    the sweep through the exact search backend
    (``EvalEngine(backend="exact")``): every metric matrix — and hence
    the homogeneous baselines the GA's Eq. 8 fitness is measured
    against — holds exact fused-mapper numbers instead of the in-scan
    approximate mapping's."""
    from .encoding import sample_in_bracket

    engine = (engine.check_workloads(workloads, calib)
              if engine is not None
              else EvalEngine(workloads, calib, config=EngineConfig(
                  backend="exact" if exact else "scan")))
    rng = np.random.default_rng(seed)

    def area_fn(genome):
        return float(engine.areas(genome[None, :])[0])

    genomes_all, fam_all = [], []
    for fi, fam in enumerate(FAMILIES):
        for b in brackets:
            g = sample_in_bracket(rng, samples_per_stratum, fam, b, area_fn)
            genomes_all.append(g)
            fam_all.append(np.full(len(g), fi))
    genomes = np.concatenate(genomes_all)
    family = np.concatenate(fam_all)

    t0 = time.time()
    m = engine.evaluate(genomes)
    bracket = np.array([area_bracket(a) for a in m["area"]])
    if verbose:
        print(f"[sweep seed {seed}] {len(genomes)} configs x "
              f"{len(workloads)} workloads in {time.time() - t0:.1f}s "
              f"(cache hit rate {engine.stats.hit_rate():.0%})")
    return SweepResult(seed=seed, workloads=list(workloads), genomes=genomes,
                       family=family, bracket=bracket, area=m["area"],
                       latency=m["latency"], energy=m["energy"],
                       tops_w=m["tops_w"])


def run_sweeps(workloads: Sequence[str], seeds: Sequence[int] = (0, 1, 2),
               samples_per_stratum: int = 64,
               calib: CalibrationTable = DEFAULT_CALIB,
               brackets: Sequence[float] = AREA_BRACKETS,
               verbose: bool = False,
               engine: Optional[EvalEngine] = None,
               exact: bool = False) -> Dict[int, SweepResult]:
    """The paper's multi-seed Stage 1: one stratified sweep per seed,
    sharing one engine (and hence one memo/store — repeated genomes
    across seeds are free).  Returns ``{seed: SweepResult}`` in seed
    order; ``dse.pipeline.run_pipeline`` is the fused Stage-1+2+merge
    frontend over this."""
    engine = (engine.check_workloads(workloads, calib)
              if engine is not None
              else EvalEngine(workloads, calib, config=EngineConfig(
                  backend="exact" if exact else "scan")))
    return {s: run_sweep(workloads, samples_per_stratum, seed=s, calib=calib,
                         brackets=brackets, verbose=verbose, engine=engine)
            for s in seeds}
