"""Bayesian-optimization search backend (paper §3.5): a sample-efficient
alternative to the stratified sweep when the simulation budget is
constrained.

Surrogate: RBF-kernel ridge regression over one-hot-ish normalized genomes
(pure numpy — no sklearn offline).  Acquisition: expected improvement,
maximized over a random candidate pool each round.

Scoring runs the engine's *exact* search backend by default (the fused
class-specialized mapping+execution scan): the surrogate is fit on, and
the reported optimum scored with, exact fused-mapper metrics — the BO
loop no longer takes the approximate scan numbers at face value.  When
a caller shares an approximate (``scan``) engine, the returned best is
exact-rescored post hoc (``best_metrics_exact`` / ``best_score_exact``)
so the reported numbers are exact either way.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from .encoding import GENOME_LEN, genome_bounds, random_genomes
from .api import EngineConfig
from .engine import EvalEngine
from .objective import area_bracket

__all__ = ["BayesConfig", "run_bayes"]


@dataclasses.dataclass
class BayesConfig:
    init_samples: int = 64
    rounds: int = 8
    batch_per_round: int = 16
    candidate_pool: int = 2048
    length_scale: float = 1.2
    ridge: float = 1e-4
    explore: float = 0.01  # EI jitter


def _featurize(genomes: np.ndarray) -> np.ndarray:
    return genomes.astype(np.float64) / genome_bounds()[None, :]


def _rbf(a: np.ndarray, b: np.ndarray, ls: float) -> np.ndarray:
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    return np.exp(-d2 / (2.0 * ls * ls))


class _Surrogate:
    def __init__(self, ls: float, ridge: float):
        self.ls, self.ridge = ls, ridge
        self.x: Optional[np.ndarray] = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> None:
        self.x = x
        k = _rbf(x, x, self.ls) + self.ridge * np.eye(len(x))
        self.k_inv = np.linalg.inv(k)
        self.alpha = self.k_inv @ y
        self.y_mean = float(y.mean())

    def predict(self, x: np.ndarray):
        ks = _rbf(x, self.x, self.ls)
        mu = ks @ self.alpha
        var = np.maximum(1.0 - np.einsum("ij,jk,ik->i", ks, self.k_inv, ks), 1e-9)
        return mu, np.sqrt(var)


def _expected_improvement(mu, sigma, best, xi):
    z = (mu - best - xi) / sigma
    # standard normal pdf / cdf without scipy
    pdf = np.exp(-0.5 * z * z) / np.sqrt(2 * np.pi)
    cdf = 0.5 * (1 + np.vectorize(_erf)(z / np.sqrt(2)))
    return (mu - best - xi) * cdf + sigma * pdf


def _erf(x: float) -> float:
    import math
    return math.erf(x)


def run_bayes(workloads: Sequence[str], objective_fn,
              cfg: BayesConfig = BayesConfig(), seed: int = 0,
              calib: CalibrationTable = DEFAULT_CALIB,
              verbose: bool = False,
              engine: Optional[EvalEngine] = None) -> Dict[str, object]:
    """Maximize ``objective_fn(metrics) -> (N,) score`` over the genome
    space.  Returns best genome/score plus the evaluation history.
    Scoring goes through a (optionally shared) ``EvalEngine``, so a
    candidate the acquisition re-picks in a later round is a cache hit.
    The default engine runs ``backend="exact"`` (search-time metrics ==
    ``rescore()`` bitwise); with a shared non-exact engine the best
    genome is exact-rescored after the rounds, and the result carries
    ``best_metrics_exact`` / ``best_score_exact`` alongside the
    search-time numbers."""
    engine = (engine.check_workloads(workloads, calib)
              if engine is not None
              else EvalEngine(workloads, calib,
                              config=EngineConfig(backend="exact",
                                                  nonfinite="skip")))
    rng = np.random.default_rng(seed)
    genomes = random_genomes(rng, cfg.init_samples)
    metrics = engine.evaluate(genomes)
    metrics.pop("meta", None)  # concatenated per-genome arrays only
    scores = objective_fn(metrics)
    history = [float(np.nanmax(scores))]
    surr = _Surrogate(cfg.length_scale, cfg.ridge)

    for rnd in range(cfg.rounds):
        ok = np.isfinite(scores)
        surr.fit(_featurize(genomes[ok]), scores[ok])
        best = float(scores[ok].max())
        pool = random_genomes(rng, cfg.candidate_pool)
        mu, sigma = surr.predict(_featurize(pool))
        ei = _expected_improvement(mu, sigma, best, cfg.explore)
        pick = pool[np.argsort(-ei)[:cfg.batch_per_round]]
        m2 = engine.evaluate(pick)
        s2 = objective_fn(m2)
        genomes = np.concatenate([genomes, pick])
        scores = np.concatenate([scores, s2])
        for k in metrics:
            metrics[k] = np.concatenate([metrics[k], m2[k]])
        history.append(float(np.nanmax(scores)))
        if verbose:
            print(f"[bayes] round {rnd}: best={history[-1]:+.4f}")

    bi = int(np.nanargmax(scores))
    # exact numbers for the reported optimum: free when the search itself
    # ran the exact backend; one fused rescore dispatch otherwise
    if engine.backend in ("exact", "batched"):
        m_exact = {k: metrics[k][bi:bi + 1] for k in
                   ("latency", "energy", "tops_w", "area")}
    else:
        m_exact = engine.rescore(genomes[bi][None, :])
        m_exact.pop("meta", None)
    score_exact = float(np.asarray(objective_fn(m_exact)).reshape(-1)[0])
    return {"best_genome": genomes[bi], "best_score": float(scores[bi]),
            "best_metrics_exact": m_exact, "best_score_exact": score_exact,
            "history": history, "genomes": genomes, "scores": scores,
            "metrics": metrics}
