"""Deterministic, seedable fault injection for the DSE stack.

Chaos testing a bitwise-deterministic system needs bitwise-deterministic
faults: a failure schedule that depends on wall clock or a shared global
RNG makes every red run unreproducible.  ``FaultInjector`` therefore
derives each fire/no-fire decision purely from ``(seed, site, n)`` where
``n`` is that site's own call counter — replaying the same operations in
the same order replays the same faults, regardless of what other sites
did in between.

Sites (one counter each):

* ``store_get`` / ``store_put`` — the persistent back tier erroring on
  read/write (exercises ``TieredStore``'s LRU-only degradation);
* ``sqlite_lock`` — ``sqlite3.OperationalError: database is locked``
  (exercises ``SqliteStore``'s bounded-backoff retry);
* ``tcp_drop`` — the service aborts a client connection mid-protocol
  (exercises ``DSEClient``'s reconnect/backoff/idempotent-retry path);
* ``engine_exc`` — ``EvalEngine._simulate`` raises (exercises the
  service failing one batch without killing the batcher loop);
* ``nan_metrics`` — ``_simulate`` returns a NaN row (exercises the
  engine's non-finite guard);
* ``worker_kill`` — a ``DSECluster`` shard dispatch kills its target
  worker outright (the service stops, no drain) before the call lands
  (exercises ejection + shard failover onto survivors);
* ``heartbeat_drop`` — a cluster ``heartbeat()`` probe fails
  (exercises consecutive-failure ejection and backoff-gated rejoin);
* ``shard_timeout`` — a cluster shard dispatch is declared lost on its
  first attempt (exercises the retry-on-surviving-workers path without
  waiting out a real timeout).

The three cluster sites are consulted only from single-threaded call
sites (the coordinator's shard-assignment loop and the heartbeat
prober), so their counters advance deterministically even though shard
execution itself is concurrent.

Faults can be scheduled two ways, combinable per site:

* ``rates={"store_put": 0.2}`` — fire pseudorandomly at that marginal
  rate (sha256 of (seed, site, n) mapped to [0, 1));
* ``at={"tcp_drop": (0, 5)}`` — fire exactly at those call indices.

Injected faults raise ``InjectedFault`` subclasses carrying
``retryable = True`` so the resilience layers under test can make the
same retry decision they would for the real error.  Chaos tests use
fault classes that never corrupt values (fail-then-retry, never
wrong-data), which is why tenant results under faults are asserted
*bitwise equal* to clean runs (tests/test_faults.py).
"""
from __future__ import annotations

import hashlib
import os
import sqlite3
import threading
from typing import Dict, Iterable, Optional

import numpy as np

from .store import ResultStore, Row

__all__ = ["FAULT_SITES", "InjectedFault", "InjectedStoreError",
           "InjectedEngineError", "FaultInjector", "FaultyStore",
           "inject_engine_faults", "fault_seed_from_env"]

FAULT_SITES = ("store_get", "store_put", "sqlite_lock", "tcp_drop",
               "engine_exc", "nan_metrics", "worker_kill",
               "heartbeat_drop", "shard_timeout")


class InjectedFault(RuntimeError):
    """Base class for injector-raised errors.  ``retryable`` mirrors the
    contract real transient errors carry through the service wire."""

    retryable = True


class InjectedStoreError(InjectedFault):
    pass


class InjectedEngineError(InjectedFault):
    pass


def fault_seed_from_env(default: int = 0) -> int:
    """The chaos suite's seed: ``FAULT_SEED`` env var (CI matrixes over
    it) or ``default``."""
    return int(os.environ.get("FAULT_SEED", default))


def _u01(seed: int, site: str, n: int) -> float:
    h = hashlib.sha256(f"{seed}:{site}:{n}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0 ** 64


class FaultInjector:
    """Deterministic per-site fault schedule (see module docstring).

    Thread-safe: counters advance under a lock, and the decision for
    call ``n`` of a site depends only on ``(seed, site, n)``.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 at: Optional[Dict[str, Iterable[int]]] = None):
        for site in list(rates or ()) + list(at or ()):
            if site not in FAULT_SITES:
                raise ValueError(f"unknown fault site {site!r}; "
                                 f"known: {FAULT_SITES}")
        self.seed = int(seed)
        self.rates = {k: float(v) for k, v in (rates or {}).items()}
        self.at = {k: frozenset(int(i) for i in v)
                   for k, v in (at or {}).items()}
        self._lock = threading.Lock()
        self._calls: Dict[str, int] = {s: 0 for s in FAULT_SITES}
        self._fired: Dict[str, int] = {s: 0 for s in FAULT_SITES}

    def should_fire(self, site: str) -> bool:
        """Advance ``site``'s counter and decide (deterministically)
        whether this call faults."""
        with self._lock:
            n = self._calls[site]
            self._calls[site] = n + 1
            fire = n in self.at.get(site, ())
            rate = self.rates.get(site, 0.0)
            if not fire and rate > 0.0:
                fire = _u01(self.seed, site, n) < rate
            if fire:
                self._fired[site] += 1
            return fire

    def calls(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._calls)

    def fired(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._fired)


class FaultyStore(ResultStore):
    """Delegating ``ResultStore`` wrapper that raises per the injector.

    ``store_get``/``store_put`` raise ``InjectedStoreError``;
    ``sqlite_lock`` raises the real ``sqlite3.OperationalError`` text the
    retry/degradation paths match on.  Used as a ``TieredStore`` back
    tier to exercise LRU-only degradation without a real disk failure.
    """

    def __init__(self, inner: ResultStore, injector: FaultInjector):
        self.inner = inner
        self.injector = injector

    # stats/locking live in the wrapped store
    @property
    def stats(self):
        return self.inner.stats

    def bind(self, context: bytes) -> "ResultStore":
        self.inner.bind(context)
        return self

    def _maybe_lock(self) -> None:
        if self.injector.should_fire("sqlite_lock"):
            raise sqlite3.OperationalError("database is locked")

    def get(self, key: bytes) -> Optional[Row]:
        self._maybe_lock()
        if self.injector.should_fire("store_get"):
            raise InjectedStoreError("injected store read failure")
        return self.inner.get(key)

    def put(self, key: bytes, row: Row) -> None:
        self._maybe_lock()
        if self.injector.should_fire("store_put"):
            raise InjectedStoreError("injected store write failure")
        self.inner.put(key, row)

    def peek(self, key: bytes) -> bool:
        return self.inner.peek(key)

    def __len__(self) -> int:
        return len(self.inner)

    def lru_dict(self):
        return self.inner.lru_dict()

    def close(self) -> None:
        self.inner.close()


def inject_engine_faults(engine, injector: FaultInjector):
    """Wrap ``engine._simulate`` so ``engine_exc`` raises an
    ``InjectedEngineError`` and ``nan_metrics`` poisons one latency cell
    with NaN (which the engine's non-finite guard must catch before the
    row reaches any memo/store).  Returns the engine; the wrapper only
    shadows the bound method on this instance."""
    inner = engine._simulate

    def _simulate(cfgs, n, genomes=None, mode=None):
        if injector.should_fire("engine_exc"):
            raise InjectedEngineError("injected engine failure")
        lat, en, tw = inner(cfgs, n, genomes=genomes, mode=mode)
        if injector.should_fire("nan_metrics"):
            lat = np.array(lat, np.float64, copy=True)
            lat[0, 0] = np.nan
        return lat, en, tw

    engine._simulate = _simulate
    return engine
