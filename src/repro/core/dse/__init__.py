"""Design-space exploration engine (paper §3.5, §4.5).

Two-stage multi-seed pipeline over the 12-knob joint space:

* ``sweep``     — stratified random sampling (strata = area budget x
                  architecture family), scored by the jitted batch
                  evaluator, finalists re-scored by the reference
                  simulator.
* ``ga``        — per-area-budget genetic refinement seeded from the sweep
                  bests (population 200, tournament 5, 80 % crossover,
                  20 % mutation, 10 % elitism at paper scale).
* ``bayes``     — sample-efficient Bayesian-optimization backend (RBF
                  surrogate + expected improvement).
* ``objective`` — Eq. 8 fitness: workload-equal-weighted mean iso-area
                  energy savings + alpha * normalized TOPS/W.
* ``batch_eval``— the JAX-native evaluator: the whole compile+simulate
                  cost model as one lax.scan, vmapped over thousands of
                  candidate chips (DESIGN.md §2).
"""
from .encoding import Genome, decode, random_genomes, GENOME_LEN
from .batch_eval import batch_evaluate, prepare_workload, prepare_configs
from .pareto import pareto_front
from .objective import iso_area_savings, fitness

__all__ = [
    "Genome", "decode", "random_genomes", "GENOME_LEN",
    "batch_evaluate", "prepare_workload", "prepare_configs",
    "pareto_front", "iso_area_savings", "fitness",
]
