"""Design-space exploration engine (paper §3.5, §4.5).

Two-stage multi-seed pipeline over the 12-knob joint space, with every
search frontend scoring candidates through one cache-aware evaluation
engine:

* ``engine``    — the unified ``EvalEngine``: per-workload preparation
                  cache, genome-level memoization (elites / duplicate
                  children / cross-seed repeats are never re-simulated),
                  vectorized genome→SoA config stacking (no per-genome
                  Python objects in the hot loop; bitwise-parity with the
                  reference ``decode`` path), and optional multi-device
                  sharding of the candidate batch axis.
* ``sweep``     — stratified random sampling (strata = area budget x
                  architecture family), finalists re-scored by the
                  reference simulator.
* ``ga``        — per-area-budget genetic refinement seeded from the sweep
                  bests (population 200, tournament 5, 80 % crossover,
                  20 % mutation, 10 % elitism at paper scale).
* ``ga_device`` — the GA generation loop as jitted device dispatches
                  (``run_ga``'s default): tournament/crossover/mutation/
                  elitism + memo-key canonicalization in one
                  ``jax.random``-keyed kernel per generation, scoring
                  *exact* fused-mapper metrics through the engine's
                  ``backend="exact"`` (search fitness == ``rescore()``
                  bitwise; seeded runs bitwise-deterministic).
* ``bayes``     — sample-efficient Bayesian-optimization backend (RBF
                  surrogate + expected improvement), scoring exact by
                  default.
* ``objective`` — Eq. 8 fitness: workload-equal-weighted mean iso-area
                  energy savings + alpha * normalized TOPS/W.
* ``batch_eval``— the JAX-native evaluator: the whole compile+simulate
                  cost model as one lax.scan, vmapped over thousands of
                  candidate chips (DESIGN.md §2).  Carries the two
                  documented simplifications the engine inherits: the
                  FIFO-eviction-free activation-cache model, and Eq. 3
                  split execution without the per-slice ragged remainder.
* ``device_memo``— the device-resident genome memo: a fixed-size
                  open-addressing hash of canonical-genome keys in device
                  memory, probed and filled *inside* the jitted
                  generation step; host store sync only at seed
                  boundaries.
* ``pipeline``  — ``run_pipeline``, the fused §4 study: per-seed
                  stratified sweep → fused island-GA refinement per
                  bracket (one dispatch each, threading the device memo)
                  → device Pareto merge over (energy, area, latency).
"""
from .encoding import Genome, decode, random_genomes, GENOME_LEN
from .batch_eval import batch_evaluate, prepare_workload, prepare_configs
from .engine import EvalEngine, EngineStats, genomes_to_configs, genome_areas
from .pareto import pareto_front
from .objective import iso_area_savings, fitness
from .pipeline import PipelineResult, run_pipeline

__all__ = [
    "Genome", "decode", "random_genomes", "GENOME_LEN",
    "batch_evaluate", "prepare_workload", "prepare_configs",
    "EvalEngine", "EngineStats", "genomes_to_configs", "genome_areas",
    "pareto_front", "iso_area_savings", "fitness",
    "PipelineResult", "run_pipeline",
]
