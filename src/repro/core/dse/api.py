"""The unified engine/evaluator API surface.

PR 9 consolidates the knob sprawl that had accumulated on
``EvalEngine.__init__`` (backend, schedule mode, exact-mapper choice,
sharding, store, memo sizing, non-finite policy — and now the NoC/DRAM
fidelity tier) into one frozen ``EngineConfig`` value object.  The
config is the *single source of truth* for the engine's content
context: ``context_digest`` derives the store/checkpoint binding key
from it, so every knob that changes metrics provably lands in the
digest (adding a knob here without threading it through the digest is a
one-line diff review, not an archaeology project).

``Evaluator`` is the protocol every scoring surface satisfies — the
in-process ``EvalEngine``, the in-process ``DSEClient``, and the TCP
``DSEClient`` — pinned by the shared conformance suite in
tests/test_api.py.  Search frontends type against it; "engine-shaped"
stops being folklore.

**Fidelity tiers** (the PR-9 axis).  ``fidelity`` selects how the
steady-state initiation interval composes interconnect contention:

* ``"aggregate"`` — the historical single-resource model: one NoC busy
  term, one DRAM bandwidth term.  Bitwise-identical to every pre-PR-9
  result.
* ``"link"`` — per-link 2D mesh/torus XY-routed NoC occupancy and
  per-channel DRAM queues; the II is additionally bounded by the
  hottest link and the hottest channel (so ``II(link) >=
  II(aggregate)`` by construction).  Same mapping, same energy, same
  latency-mode metrics — only the throughput-mode II composition
  changes.

Both tiers run through every backend (oracle / batched / exact / scan)
with the same bitwise-parity guarantees the aggregate tier always had.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import (Any, Dict, Optional, Protocol, Sequence,
                    runtime_checkable)

import numpy as np

from ..calibrate.asap7 import CalibrationTable
from ..simulator.costs import COST_MODEL_VERSION, FIDELITIES
from ..simulator.orchestrator import SCHEDULE_MODES

__all__ = ["EngineConfig", "Evaluator", "context_digest", "BACKENDS",
           "EXACT_MAPPERS", "NONFINITE_POLICIES", "META_VERSION"]

BACKENDS = ("scan", "exact", "batched", "oracle")
EXACT_MAPPERS = ("batched", "python")
NONFINITE_POLICIES = ("raise", "skip")

# Version stamp of the result["meta"] schema every Evaluator returns
# (see README "Result meta schema").  Bump when meta keys change
# meaning; consumers can gate on it instead of sniffing keys.
META_VERSION = 1


def context_digest(workloads: Sequence[str], calib: CalibrationTable,
                   aggressive_int4: bool, enable_fusion: bool,
                   backend: str, fidelity: str) -> bytes:
    """Digest of everything a memoized metric row depends on besides the
    (canonical genome, mode) pair the short store key carries: the
    workload list *and order* (metric columns follow it), the
    calibration table, the precision/fusion compile flags, the backend's
    mapping-fidelity class (the ``scan`` backend's approximate in-scan
    mapping produces different numbers than the exact family, which is
    bitwise-shared by exact/batched/oracle), the NoC/DRAM fidelity tier,
    and the cost-model version.  Persistent stores and checkpoints fold
    this into their content address, so results accumulated by one
    engine are served to another exactly when every one of these
    matches.  The service handshake recomputes this digest client-side
    (``DSEClient._connect``) — keep the two in lockstep by keeping them
    the same function."""
    mapping = "approx" if backend == "scan" else "exact"
    text = repr((tuple(workloads), repr(calib), bool(aggressive_int4),
                 bool(enable_fusion), mapping, str(fidelity),
                 COST_MODEL_VERSION))
    return hashlib.sha256(text.encode()).digest()


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Every ``EvalEngine`` knob in one frozen, comparable value object.

    ``EvalEngine(workloads, config=EngineConfig(...))`` is the
    canonical construction; the legacy per-knob kwargs still work but
    warn ``DeprecationWarning``.  ``store`` is excluded from equality /
    repr — it is runtime wiring (an open sqlite handle), not identity.
    """

    backend: str = "scan"
    mode: str = "latency"
    fidelity: str = "aggregate"
    exact_mapper: str = "batched"
    shard: bool = False
    memoize: bool = True
    vectorized: bool = True
    aggressive_int4: bool = False
    enable_fusion: bool = True
    batch: int = 1024
    memo_max: Optional[int] = None
    nonfinite: str = "raise"
    store: Optional[Any] = dataclasses.field(
        default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        if self.mode not in SCHEDULE_MODES:
            raise ValueError(f"mode {self.mode!r} not in {SCHEDULE_MODES}")
        if self.fidelity not in FIDELITIES:
            raise ValueError(
                f"fidelity {self.fidelity!r} not in {FIDELITIES}")
        if self.exact_mapper not in EXACT_MAPPERS:
            raise ValueError(f"exact_mapper {self.exact_mapper!r} not in "
                             f"{EXACT_MAPPERS}")
        if self.nonfinite not in NONFINITE_POLICIES:
            raise ValueError(f"nonfinite {self.nonfinite!r} not in "
                             f"{NONFINITE_POLICIES}")
        if self.backend == "exact" and self.exact_mapper != "batched":
            raise ValueError("backend='exact' is the fused search kernel; "
                             "it cannot run exact_mapper='python'")
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")

    def context_digest(self, workloads: Sequence[str],
                       calib: CalibrationTable) -> bytes:
        """The content-context digest this config induces for a given
        (workloads, calib) pair — see module-level ``context_digest``."""
        return context_digest(workloads, calib, self.aggressive_int4,
                              self.enable_fusion, self.backend,
                              self.fidelity)


@runtime_checkable
class Evaluator(Protocol):
    """What a scoring surface must provide for the search frontends
    (sweep / GA / Bayes / hillclimb) and the serving layer to drive it.
    Satisfied by ``EvalEngine`` and ``DSEClient`` (both bindings);
    pinned by the conformance suite in tests/test_api.py.

    Metric contract: ``evaluate``/``rescore``/``score_batch`` return a
    dict of ``latency`` (N, W), ``energy`` (N, W), ``tops_w`` (N, W),
    ``area`` (N,); ``evaluate`` and ``rescore`` additionally carry a
    ``"meta"`` dict stamped with ``meta_version`` (see ``META_VERSION``
    and the README meta-schema table).
    """

    workloads: Sequence[str]
    stats: Any

    def evaluate(self, genomes: np.ndarray, keep=None,
                 mode: Optional[str] = None,
                 canonical: Optional[np.ndarray] = None
                 ) -> Dict[str, Any]: ...

    def rescore(self, genomes: np.ndarray, oracle: bool = False,
                mode: Optional[str] = None) -> Dict[str, Any]: ...

    def score_batch(self, genomes: np.ndarray,
                    mode: Optional[str] = None) -> Dict[str, Any]: ...

    def context_key(self) -> bytes: ...
