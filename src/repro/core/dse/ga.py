"""Stage 2 — per-area-budget genetic-algorithm refinement (paper §4.5).

Paper settings: population 200, 100 generations, tournament selection of
size 5, 80 % crossover, 20 % mutation, 10 % elitism, seeded from the top
50 sweep individuals at each budget, ten-generation no-improvement early
stop.  Fitness is Eq. 8 against the sweep's best-homogeneous baseline at
the same bracket.

``run_ga`` delegates to the jitted device generation loop
(``ga_device.run_ga_device``) by default — genetics + canonicalization
as one device dispatch per generation, scoring exact fused-mapper
metrics (``EvalEngine(backend="exact")``) so the selected-on fitness
equals a post-hoc exact ``rescore()`` bitwise.  The numpy loop below
(``loop="host"``) is retained as the PR-4 reference/benchmark baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from .encoding import GENOME_LEN, genome_bounds, random_genomes
from .api import EngineConfig
from .engine import EvalEngine
from .objective import ALPHA, AREA_BRACKETS, area_bracket
from .sweep import SweepResult

__all__ = ["GAConfig", "GAResult", "run_ga"]


@dataclasses.dataclass
class GAConfig:
    population: int = 200
    generations: int = 100
    tournament: int = 5
    crossover_rate: float = 0.8
    mutation_rate: float = 0.2
    elitism: float = 0.1
    seed_top_k: int = 50
    early_stop: int = 10  # generations without improvement
    alpha: float = ALPHA


@dataclasses.dataclass
class GAResult:
    bracket: float
    best_genome: np.ndarray
    best_fitness: float
    best_savings_per_wl: np.ndarray
    best_metrics: Dict[str, np.ndarray]
    history: List[float]
    evaluated: int


def _fitness(en: np.ndarray, tw: np.ndarray, lat: np.ndarray,
             area: np.ndarray, bracket: float, e_homo: np.ndarray,
             alpha: float) -> np.ndarray:
    sav = (e_homo[None, :] - en) / np.maximum(e_homo[None, :], 1e-30)
    fit = sav.mean(axis=1)
    peak_tw = tw.max(axis=1)
    bad = ~np.isfinite(lat).all(axis=1) | ~(lat > 0).all(axis=1)
    # out-of-bracket designs are not iso-area comparable
    bad |= np.array([area_bracket(a) != bracket for a in area])
    # normalize TOPS/W over comparable designs only: a -inf-fitness
    # out-of-bracket child must not rescale the alpha term of the valid
    # population (it also lets the engine skip simulating such children)
    ok = ~bad
    max_tw = peak_tw[ok].max() if ok.any() else 1.0
    fit = fit + alpha * peak_tw / max(max_tw, 1e-30)
    fit[bad] = -np.inf
    return fit


def run_ga(sweep: SweepResult, bracket: float,
           cfg: GAConfig = GAConfig(), seed: int = 0,
           calib: CalibrationTable = DEFAULT_CALIB,
           verbose: bool = False, engine: Optional[EvalEngine] = None,
           prefilter: bool = True, loop: str = "device",
           on_generation: Optional[Callable] = None) -> Optional[GAResult]:
    """GA refinement at one area budget, seeded from the sweep.

    ``loop="device"`` (default) delegates to the jitted generation loop
    (``ga_device.run_ga_device``): tournament selection, uniform
    crossover, Poisson-k mutation, elitism and canonicalization run as
    one ``jax.random``-keyed device dispatch per generation, and —
    absent an explicit ``engine`` — scoring runs the *exact* search
    backend (``EvalEngine(backend="exact")``), so the fitness the GA
    selects on equals a post-hoc ``rescore()`` bitwise.  Seeded runs
    are deterministic; same numpy API and ``GAResult`` contract.
    ``loop="host"`` keeps the historical numpy generation loop (the
    PR-4 benchmark baseline; a different random stream, so the two
    loops explore different — equally valid — trajectories).

    Scoring goes through a (optionally shared) ``EvalEngine``: the 10 %
    elites re-entering every generation and duplicate children are cache
    hits, and with ``prefilter`` (default) out-of-bracket children — whose
    Eq. 8 fitness is -inf regardless of their metrics — skip simulation
    entirely.  Both are fitness-preserving: ``best_fitness`` is bitwise
    identical to the uncached, unfiltered evaluation.

    A shared engine in ``mode="throughput"`` refines on the pipelined
    steady state instead (energy column = per-inference energy at II):
    the Eq. 8 savings term then optimizes serving energy, and an II
    target can be enforced on finalists via
    ``objective.serving_fitness``.

    ``on_generation(gen, pop, fit, metrics)``, when given, is called
    after every scored population — ``gen`` 0 for the seed population,
    then 1..N — with the raw genomes, their Eq. 8 fitness, and the
    metric arrays.  The evaluation service streams Pareto-front updates
    from it; it must not mutate its arguments.

    ``loop="fused"`` runs the whole refinement as ONE jitted dispatch
    against the device-resident memo (``ga_device.run_ga_fused``,
    single island): seeded runs are genome-for-genome equal to
    ``loop="device"`` (pinned by tests/test_pipeline.py) without the
    per-generation host round trip; the engine store syncs only at the
    call boundary.  Requires a local exact engine; ``on_generation``
    can't fire from inside one dispatch, so it is rejected — use
    ``loop="device"`` for per-generation streaming, or the §4 pipeline's
    per-stage hook."""
    if loop not in ("device", "host", "fused"):
        raise ValueError(f"loop {loop!r} not in ('device', 'host', 'fused')")
    if loop == "device":
        from .ga_device import run_ga_device
        return run_ga_device(sweep, bracket, cfg, seed=seed, calib=calib,
                             verbose=verbose, engine=engine,
                             prefilter=prefilter,
                             on_generation=on_generation)
    if loop == "fused":
        if on_generation is not None:
            raise ValueError(
                "loop='fused' runs the whole refinement as one dispatch — "
                "per-generation hooks can't fire; use loop='device' or "
                "run_pipeline(on_stage=...)")
        from .ga_device import run_ga_fused
        fused = run_ga_fused(sweep, bracket, cfg, seed=seed, calib=calib,
                             verbose=verbose, engine=engine, islands=1)
        return None if fused is None else fused.result
    engine = (engine.check_workloads(sweep.workloads, calib)
              if engine is not None
              else EvalEngine(sweep.workloads, calib,
                              config=EngineConfig()))
    rng = np.random.default_rng(seed + int(bracket))
    base = sweep.homo_baseline()
    if bracket not in base:
        return None
    e_homo = base[bracket]
    bounds = genome_bounds()

    # ---- seed population: top-k sweep individuals in this bracket ----------
    fit_sweep = sweep.fitness(cfg.alpha)
    in_b = np.nonzero((sweep.bracket == bracket) & np.isfinite(fit_sweep))[0]
    # seed_top_k may exceed the population: keep the fittest `population`
    # (the fill loop below never truncated an already-oversized seed set,
    # so generation 0 silently ran over-populated on the host loop and
    # broke the fused kernel's fixed shapes)
    order = in_b[np.argsort(-fit_sweep[in_b])][:cfg.seed_top_k]
    pop = sweep.genomes[order].copy()[:cfg.population]
    while len(pop) < cfg.population:
        fill = random_genomes(rng, cfg.population - len(pop),
                              family="hetero_bls" if rng.random() < 0.5 else None)
        pop = np.concatenate([pop, fill])[:cfg.population]

    def keep(areas: np.ndarray) -> np.ndarray:
        return np.fromiter((area_bracket(a) == bracket for a in areas),
                           bool, len(areas))

    def evaluate(genomes: np.ndarray):
        m = engine.evaluate(genomes, keep=keep if prefilter else None)
        m.pop("meta", None)  # best_metrics holds per-genome arrays only
        fit = _fitness(m["energy"], m["tops_w"], m["latency"], m["area"],
                       bracket, e_homo, cfg.alpha)
        return fit, m

    fit, metrics = evaluate(pop)
    if on_generation is not None:
        on_generation(0, pop, fit, metrics)
    best_i = int(np.argmax(fit))
    best = (fit[best_i], pop[best_i].copy(),
            {k: v[best_i] for k, v in metrics.items()})
    history = [float(best[0])]
    evaluated = len(pop)
    stall = 0

    n_elite = max(int(cfg.elitism * cfg.population), 1)
    for gen in range(cfg.generations):
        # tournament selection
        def pick() -> np.ndarray:
            idx = rng.integers(0, len(pop), cfg.tournament)
            return pop[idx[np.argmax(fit[idx])]]

        children = []
        elite_idx = np.argsort(-fit)[:n_elite]
        children.extend(pop[elite_idx].copy())
        while len(children) < cfg.population:
            a, b = pick().copy(), pick().copy()
            if rng.random() < cfg.crossover_rate:   # uniform crossover
                mask = rng.random(GENOME_LEN) < 0.5
                a[mask], b[mask] = b[mask], a[mask]
            for child in (a, b):
                if rng.random() < cfg.mutation_rate:
                    k = max(1, rng.poisson(2))
                    genes = rng.integers(0, GENOME_LEN, k)
                    child[genes] = (rng.random(k) * bounds[genes]).astype(np.int32)
                children.append(child)
        pop = np.asarray(children[:cfg.population])
        fit, metrics = evaluate(pop)
        if on_generation is not None:
            on_generation(gen + 1, pop, fit, metrics)
        evaluated += len(pop)
        gi = int(np.argmax(fit))
        if fit[gi] > best[0]:
            best = (fit[gi], pop[gi].copy(),
                    {k: v[gi] for k, v in metrics.items()})
            stall = 0
        else:
            stall += 1
        history.append(float(best[0]))
        if verbose:
            print(f"[ga {bracket:.0f}mm2] gen {gen}: best={best[0]:+.4f} "
                  f"(stall {stall})")
        if stall >= cfg.early_stop:
            break

    sav = (e_homo - best[2]["energy"]) / np.maximum(e_homo, 1e-30)
    return GAResult(bracket=bracket, best_genome=best[1],
                    best_fitness=float(best[0]), best_savings_per_wl=sav,
                    best_metrics=best[2], history=history, evaluated=evaluated)
