"""Device-side GA generation loop (paper §4.5) over the exact search path.

The Stage-2 refinement loop, with the genetics moved off the host: one
jitted ``jax.random``-keyed dispatch per generation runs tournament
selection (size ``cfg.tournament``), uniform crossover, Poisson-k gene
mutation and elitism over the whole ``(P, GENOME_LEN)`` population —
replacing the ~P Python tournament draws, per-child numpy crossover
/mutation, and per-generation host round trips of the historical loop
(``ga.run_ga(loop="host")``).  The same dispatch canonicalizes the
children (``canonical_genomes``, ported to jnp bit-for-bit), so the
engine's mode-keyed memo lookup costs no extra host pass: elites and
duplicate children are cache hits that skip the simulation scan
entirely.

Scoring goes through an ``EvalEngine`` — by default one constructed
with ``backend="exact"``, the class-specialized fused mapping+execution
scan (``compiler.batched_mapper.search_and_simulate``), so the Eq. 8
fitness the tournament selects on is computed from *exact*
(fused-mapper) metrics: search-time fitness equals a post-hoc
``rescore()`` bitwise, retiring the approximate-search-then-rescore
fidelity gap for GA refinement.  The Eq. 8 fitness itself (iso-area
savings vs the bracket's homogeneous baseline + the alpha TOPS/W
tie-break, with the area-bracket validity mask) is a jitted device
kernel over the (P, W) metric matrices.

Seeded runs are bitwise-deterministic: the genome stream is a
``jax.random`` fold of (seed, bracket), engine metrics are
batch-composition-independent (pinned by tests/test_engine.py), and two
same-seed runs produce identical ``best_genome``/``history``
(tests/test_ga_device.py).  With a sharded engine and a population
divisible by the mesh, the population axis of the genetics dispatch is
placed with the same ``NamedSharding`` as the evaluation batches
(``launch.mesh.population_sharding``).

The one *documented* departure from the host loop's numpy genetics: the
Poisson-k mutation draw is truncated at ``MUT_GENES_MAX`` (= 8) genes
per child (P[k > 8 | k ~ Poisson(2)] < 3e-4); the host loop keeps the
unbounded draw.  Both are the paper's operator — the two loops walk
different (equally valid) random streams either way.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Dict, Optional, Tuple

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from ..arch import MAX_TILE_TYPES, MAX_TILES
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..simulator.batched import CHIP_KEYS, TILE_KEYS
from ..simulator.costs import grid_dims
from ..simulator.orchestrator import CACHE_FRAC
from .api import EngineConfig
from .device_memo import (DeviceMemo, drain_to_store, memo_from_store,
                          memo_init, memo_insert, memo_lookup)
from .encoding import (FIELDS_PER_TILE, GENOME_LEN, IDX_ASPECT, IDX_DRAM,
                       IDX_DRAM_CH, IDX_ICONN, IDX_NOC_BPC, IDX_TOPO,
                       genome_bounds, random_genomes)
from .engine import (_ARRAY_DIM, _ASPECT, _ASYM, _ASYM_CANON, _ASYM_COL,
                     _COUNT, _DATAFLOW, _DB, _DRAM, _DRAM_CH, _ENGINE,
                     _FIELD_COL, _HOPS_TABLE, _MODE_KEYS, _NOC_BPC, _PIPE,
                     _PREC_COL, _PREC_MASK, _PREC_MAX, _SFU, _SFU_COL,
                     _SPARSITY, _SPECIAL_INERT_COLS, _SRAM_KB, _TOPO,
                     EvalEngine)
from .objective import ALPHA, AREA_BRACKETS, area_bracket

__all__ = ["run_ga_device", "run_ga_fused", "FusedRefinement",
           "MUT_GENES_MAX", "canonical_genomes_device", "fitness_device",
           "bracket_bounds"]

# Poisson-k mutation truncation of the device loop (see module docstring)
MUT_GENES_MAX = 8

_SFU_DEV = jnp.asarray(_SFU)
_ASYM_CANON_DEV = jnp.asarray(_ASYM_CANON, jnp.int32)


# =============================================================================
# device canonicalization (bitwise port of engine.canonical_genomes)
# =============================================================================

def _canonical_device(g):
    """jnp mirror of ``engine.canonical_genomes`` on a (P, GENOME_LEN)
    int array — same zeroing order, same tables, bit-for-bit (pinned by
    tests/test_ga_device.py)."""
    n_types = g[:, 0] + 1
    for t in range(MAX_TILE_TYPES):
        base = 1 + t * FIELDS_PER_TILE
        inactive = t >= n_types
        block = g[:, base:base + FIELDS_PER_TILE]
        g = g.at[:, base:base + FIELDS_PER_TILE].set(
            jnp.where(inactive[:, None], 0, block))
        special = (_SFU_DEV[g[:, base + _SFU_COL] % len(_SFU)] > 0) \
            & ~inactive
        for col in _SPECIAL_INERT_COLS:
            g = g.at[:, base + col].set(
                jnp.where(special, 0, g[:, base + col]))
        g = g.at[:, base + _ASYM_COL].set(
            _ASYM_CANON_DEV[g[:, base + _PREC_COL] % 4,
                            g[:, base + _ASYM_COL] % 4].astype(g.dtype))
    return g


@jax.jit
def _canonical_device_jit(g):
    return _canonical_device(g)


def canonical_genomes_device(genomes: np.ndarray) -> np.ndarray:
    """Host-callable wrapper over the jitted device canonicalizer."""
    g = np.asarray(genomes, np.int64).reshape(-1, GENOME_LEN)
    return np.asarray(_canonical_device_jit(jnp.asarray(g)))


# =============================================================================
# Eq. 8 fitness kernel
# =============================================================================

def bracket_bounds(bracket: float):
    """(lo, hi] area band equivalent to ``area_bracket(a) == bracket``
    (the last bracket is open above: oversized chips land in it)."""
    if bracket not in AREA_BRACKETS:
        return math.nan, math.nan      # no design can match (host parity)
    bi = AREA_BRACKETS.index(bracket)
    lo = AREA_BRACKETS[bi - 1] if bi > 0 else -math.inf
    hi = bracket if bi < len(AREA_BRACKETS) - 1 else math.inf
    return lo, hi


@jax.jit
def _fitness_kernel(en, tw, lat, area, e_homo, lo, hi, alpha):
    """Eq. 8 on the (P, W) metric matrices, on device: mean iso-area
    savings vs the bracket's homogeneous baseline + alpha * TOPS/W
    normalized over comparable (in-bracket, valid) designs only;
    invalid/out-of-bracket rows score -inf (``ga._fitness`` semantics)."""
    sav = (e_homo[None, :] - en) / jnp.maximum(e_homo[None, :], 1e-30)
    fit = sav.mean(axis=1)
    peak_tw = tw.max(axis=1)
    bad = ~jnp.isfinite(lat).all(axis=1) | ~(lat > 0).all(axis=1)
    bad = bad | ~((area > lo) & (area <= hi))
    ok = ~bad
    max_tw = jnp.max(jnp.where(ok, peak_tw, -jnp.inf))
    max_tw = jnp.where(jnp.any(ok), max_tw, 1.0)
    fit = fit + alpha * peak_tw / jnp.maximum(max_tw, 1e-30)
    return jnp.where(bad, -jnp.inf, fit)


def fitness_device(metrics: Dict[str, np.ndarray], e_homo: np.ndarray,
                   bracket: float, alpha: float = ALPHA) -> np.ndarray:
    """Eq. 8 fitness of an engine ``evaluate()``/``rescore()`` result
    through the device kernel — the scoring the device GA loop selects
    on (and what the exact-search/rescore parity property compares)."""
    lo, hi = bracket_bounds(bracket)
    return np.asarray(_fitness_kernel(
        jnp.asarray(metrics["energy"]), jnp.asarray(metrics["tops_w"]),
        jnp.asarray(metrics["latency"]), jnp.asarray(metrics["area"]),
        jnp.asarray(e_homo, jnp.float64), jnp.asarray(lo, jnp.float64),
        jnp.asarray(hi, jnp.float64), jnp.asarray(alpha, jnp.float64)))


# =============================================================================
# the jitted generation kernel
# =============================================================================

@functools.lru_cache(maxsize=32)
def _genetics_kernel(population: int, tournament: int, n_elite: int,
                     crossover_rate: float, mutation_rate: float):
    """One GA generation as a single jitted dispatch:
    ``(pop, fit, key) -> (children, canonical(children))``.

    Mirrors the host loop's operator semantics — elites pass through
    unchanged, each non-elite slot pair comes from two size-K
    tournaments, uniform crossover swaps genes with p=0.5 at
    ``crossover_rate``, and mutated children redraw Poisson-k genes
    uniformly under ``genome_bounds`` (k truncated at MUT_GENES_MAX on
    device) — over a different (jax.random) stream.
    """
    bounds = jnp.asarray(genome_bounds(), jnp.int32)
    L = GENOME_LEN
    n_pairs = max(-(-(population - n_elite) // 2), 0)
    n_children = n_pairs * 2

    def gen(pop, fit, key):
        pop = pop.astype(jnp.int32)
        k_t, k_cx, k_cxm, k_mut, k_mk, k_mg, k_mv = jax.random.split(key, 7)
        # ---- elitism -----------------------------------------------------
        elite_idx = jnp.argsort(-fit)[:n_elite]
        elites = pop[elite_idx]
        # ---- tournament selection (all draws in one dispatch) ------------
        idx = jax.random.randint(k_t, (n_children, tournament), 0, population)
        winners = idx[jnp.arange(n_children), jnp.argmax(fit[idx], axis=1)]
        pa = pop[winners[0::2]]
        pb = pop[winners[1::2]]
        # ---- uniform crossover ------------------------------------------
        do_cx = jax.random.uniform(k_cx, (n_pairs,)) < crossover_rate
        swap = do_cx[:, None] & (jax.random.uniform(k_cxm, (n_pairs, L)) < 0.5)
        ca = jnp.where(swap, pb, pa)
        cb = jnp.where(swap, pa, pb)
        children = jnp.stack([ca, cb], axis=1).reshape(n_children, L)
        # ---- Poisson-k gene mutation ------------------------------------
        do_mut = jax.random.uniform(k_mut, (n_children,)) < mutation_rate
        k_genes = jnp.clip(jax.random.poisson(k_mk, 2.0, (n_children,)),
                           1, MUT_GENES_MAX)
        genes = jax.random.randint(k_mg, (n_children, MUT_GENES_MAX), 0, L)
        vals = jnp.floor(jax.random.uniform(k_mv, (n_children, MUT_GENES_MAX))
                         * bounds[genes]).astype(jnp.int32)

        def mutate(child, do, kk, gg, vv):
            # sequential application: later draws overwrite earlier ones
            # on duplicate gene indices, like the host fancy assignment
            def body(j, ch):
                return jnp.where(do & (j < kk), ch.at[gg[j]].set(vv[j]), ch)
            return jax.lax.fori_loop(0, MUT_GENES_MAX, body, child)

        children = jax.vmap(mutate)(children, do_mut, k_genes, genes, vals)
        new_pop = jnp.concatenate([elites, children])[:population]
        return new_pop, _canonical_device(new_pop)

    return jax.jit(gen)


# =============================================================================
# the generation loop
# =============================================================================

def run_ga_device(sweep, bracket: float, cfg=None, seed: int = 0,
                  calib: CalibrationTable = DEFAULT_CALIB,
                  verbose: bool = False, engine: Optional[EvalEngine] = None,
                  prefilter: bool = True,
                  on_generation: Optional[Callable] = None):
    """GA refinement at one area budget on the device generation loop.

    Same contract as ``ga.run_ga`` (which delegates here by default):
    seeded from the sweep's top-k at the bracket, returns a ``GAResult``
    or None when the bracket has no homogeneous baseline.  Without an
    explicit ``engine``, scoring runs the exact search backend — one
    class-specialized fused map+execute dispatch per workload per
    generation, memo hits (elites, duplicate children) and
    bracket-prefiltered genomes skipping the scan.  ``engine`` may be
    any object with the engine scoring surface — e.g. the evaluation
    service's ``DSEClient``, which coalesces this loop's populations
    with other tenants' candidates.  ``on_generation(gen, pop, fit,
    metrics)`` is invoked after every scored population (gen 0 = the
    seed population) — the hook the service streams Pareto-front
    updates from.
    """
    from .ga import GAConfig, GAResult
    cfg = cfg or GAConfig()
    engine = (engine.check_workloads(sweep.workloads, calib)
              if engine is not None
              else EvalEngine(sweep.workloads, calib,
                              config=EngineConfig(backend="exact",
                                                  nonfinite="skip")))
    rng = np.random.default_rng(seed + int(bracket))
    base = sweep.homo_baseline()
    if bracket not in base:
        return None
    e_homo = np.asarray(base[bracket], np.float64)
    lo, hi = bracket_bounds(bracket)

    # ---- seed population: identical to the host loop -----------------------
    fit_sweep = sweep.fitness(cfg.alpha)
    in_b = np.nonzero((sweep.bracket == bracket) & np.isfinite(fit_sweep))[0]
    order = in_b[np.argsort(-fit_sweep[in_b])][:cfg.seed_top_k]
    pop = sweep.genomes[order].copy()[:cfg.population]
    while len(pop) < cfg.population:
        fill = random_genomes(rng, cfg.population - len(pop),
                              family="hetero_bls" if rng.random() < 0.5
                              else None)
        pop = np.concatenate([pop, fill])[:cfg.population]
    pop = np.ascontiguousarray(pop, np.int32)

    def keep(areas: np.ndarray) -> np.ndarray:
        # vectorized `area_bracket(a) == bracket` (bracket_bounds parity
        # is pinned by tests/test_ga_device.py)
        return (areas > lo) & (areas <= hi)

    def evaluate(genomes: np.ndarray, canonical=None):
        m = engine.evaluate(genomes, keep=keep if prefilter else None,
                            canonical=canonical)
        m.pop("meta", None)  # best_metrics holds per-genome arrays only
        fit = fitness_device(m, e_homo, bracket, cfg.alpha)
        return fit, m

    # per-generation miss counts sweep the whole bucket range: register
    # the shapes up front so every dispatch is minimally padded
    engine.reserve_shapes(cfg.population)
    fit, metrics = evaluate(pop)
    if on_generation is not None:
        on_generation(0, pop, fit, metrics)
    best_i = int(np.argmax(fit))
    best = (fit[best_i], pop[best_i].copy(),
            {k: v[best_i] for k, v in metrics.items()})
    history = [float(best[0])]
    evaluated = len(pop)
    stall = 0

    n_elite = max(int(cfg.elitism * cfg.population), 1)
    gen_fn = _genetics_kernel(cfg.population, cfg.tournament, n_elite,
                              cfg.crossover_rate, cfg.mutation_rate)
    key = jax.random.PRNGKey(seed + int(bracket))
    sharding = None
    if engine._sharding is not None \
            and cfg.population % engine._sharding.mesh.size == 0:
        from ...launch.mesh import population_sharding
        sharding = population_sharding()
    pop_dev = jnp.asarray(pop, jnp.int32)
    if sharding is not None:
        pop_dev = jax.device_put(pop_dev, sharding)

    for gen in range(cfg.generations):
        key, sub = jax.random.split(key)
        pop_dev, canon_dev = gen_fn(pop_dev, jnp.asarray(fit), sub)
        # ONE host transfer per generation: the (P, GENOME_LEN) children
        # + their canonical forms (the engine's memo keys)
        pop = np.asarray(pop_dev)
        canon = np.asarray(canon_dev)
        fit, metrics = evaluate(pop, canonical=canon)
        if on_generation is not None:
            on_generation(gen + 1, pop, fit, metrics)
        evaluated += len(pop)
        gi = int(np.argmax(fit))
        if fit[gi] > best[0]:
            best = (fit[gi], pop[gi].copy(),
                    {k: v[gi] for k, v in metrics.items()})
            stall = 0
        else:
            stall += 1
        history.append(float(best[0]))
        if verbose:
            print(f"[ga-dev {bracket:.0f}mm2] gen {gen}: best={best[0]:+.4f} "
                  f"(stall {stall})")
        if stall >= cfg.early_stop:
            break

    sav = (e_homo - best[2]["energy"]) / np.maximum(e_homo, 1e-30)
    return GAResult(bracket=bracket, best_genome=best[1],
                    best_fitness=float(best[0]), best_savings_per_wl=sav,
                    best_metrics=best[2], history=history, evaluated=evaluated)


# =============================================================================
# device genome -> config stacking (bitwise port of genomes_to_configs)
# =============================================================================

_ARRAY_DIM_DEV = jnp.asarray(_ARRAY_DIM)
_SRAM_KB_DEV = jnp.asarray(_SRAM_KB)
_COUNT_DEV = jnp.asarray(_COUNT)
_ENGINE_DEV = jnp.asarray(_ENGINE)
_SPARSITY_DEV = jnp.asarray(_SPARSITY)
_DATAFLOW_DEV = jnp.asarray(_DATAFLOW)
_PIPE_DEV = jnp.asarray(_PIPE)
_DB_DEV = jnp.asarray(_DB)
_ASYM_DEV = jnp.asarray(_ASYM)
_PREC_MASK_DEV = jnp.asarray(_PREC_MASK)
_PREC_MAX_DEV = jnp.asarray(_PREC_MAX)
_DRAM_DEV = jnp.asarray(_DRAM)
_HOPS_TABLE_DEV = jnp.asarray(_HOPS_TABLE)
_TOPO_DEV = jnp.asarray(_TOPO)
_ASPECT_DEV = jnp.asarray(_ASPECT)
_NOC_BPC_DEV = jnp.asarray(_NOC_BPC)
_DRAM_CH_DEV = jnp.asarray(_DRAM_CH)


def _area_tables(calib: CalibrationTable):
    """Device views of the cached host tables.  Converted per call so the
    constants belong to whichever trace consumes them — caching the
    ``jnp`` arrays themselves would capture trace-local tracers whenever
    the first call happens inside a jit trace, poisoning every later
    retrace (a second kernel shape in the same process) with an
    UnexpectedTracerError."""
    return tuple(jnp.asarray(t) for t in _area_tables_host(calib))


@functools.lru_cache(maxsize=4)
def _area_tables_host(calib: CalibrationTable):
    """Host-precomputed Eq. 7 area tables over the full (discrete) knob
    grid: per-type tile area, tile area x count, and NoC area by tile
    count.  XLA:CPU contracts mul+add chains into FMAs under jit — no
    flag or ``optimization_barrier`` prevents it — which skips the host
    stack's per-product rounding and breaks this port's bitwise-parity
    contract.  So the device does NO area arithmetic: every area value
    is a gather from these tables, each entry computed by the exact
    numpy expressions ``engine._per_type_values`` runs (identical
    rounding by construction).  Grid: prec(4) x engine(4) x sparsity(3)
    x rows(5) x cols(5) x sfu(len _SFU) x sram(7) = 42 K entries."""
    S = len(_SFU)
    p_, e_, s_, r_, c_, f_, k_ = np.meshgrid(
        np.arange(4), np.arange(4), np.arange(3), np.arange(5),
        np.arange(5), np.arange(S), np.arange(7), indexing="ij")
    sfu = _SFU[f_]
    special = sfu > 0
    rows = np.where(special, 0.0, _ARRAY_DIM[r_])
    cols = np.where(special, 0.0, _ARRAY_DIM[c_])
    num_macs = rows * cols
    big = num_macs >= 1024.0
    dsp_count = np.where(special, 1.0, np.where(big, 2.0, 1.0))
    dsp_simd = np.full_like(dsp_count, 64.0)
    max_prec = _PREC_MAX[p_]
    eng_idx = np.asarray(_ENGINE[e_], np.int64)
    sp_idx = np.asarray(_SPARSITY[s_], np.int64)
    sram_kb = _SRAM_KB[k_]

    a_mac_mm2 = np.asarray(calib.a_mac_mm2, np.float64)
    eng_a = np.asarray(calib.engine_a_mult, np.float64)
    sp_a = np.asarray(calib.sparsity_a_mult, np.float64)
    a_mac_unit = a_mac_mm2[max_prec] * eng_a[eng_idx]
    a_mac = num_macs * a_mac_unit * sp_a[sp_idx]
    a_sram = sram_kb * calib.a_sram_mm2_per_kb
    a_dsp = dsp_count * dsp_simd * calib.a_dsp_mm2_per_lane
    sfu_i = np.asarray(sfu, np.int64)
    a_spec = np.where(sfu_i & 1, calib.a_fft_mm2, 0.0)
    a_spec = a_spec + np.where(sfu_i & 2, calib.a_lif_mm2, 0.0)
    a_spec = a_spec + np.where(sfu_i & 4, calib.a_poly_mm2, 0.0)
    a_ports = calib.a_ports_base_mm2 \
        + (rows + cols) * calib.a_ports_per_lane_mm2
    area = a_mac + a_sram + a_dsp + a_spec + a_ports

    count_terms = area[..., None] * _COUNT        # x count, pre-rounded
    max_tiles = MAX_TILE_TYPES * int(np.max(_COUNT))
    # NoC term by (tile count, noc_bpc knob, torus knob): the host stack
    # computes ``(num_tiles * a_noc) * noc_scale`` left-associatively —
    # precompute every product here so the device gathers a finished
    # float64 (the same FMA-contraction hazard as the tile terms)
    n_tiles = np.arange(max_tiles + 1, dtype=np.float64)
    noc_scale = (0.5 + 0.5 * _NOC_BPC / 64.0)[:, None] \
        * np.where(_TOPO[None, :] > 0, 1.25, 1.0)
    noc = (n_tiles * calib.a_noc_mm2_per_tile)[:, None, None] \
        * noc_scale[None, :, :]
    # per-channel DRAM PHY term by the dram_channels knob
    dram_phy = (_DRAM_CH - 1.0) * calib.a_dram_phy_mm2
    return (np.ascontiguousarray(area.reshape(-1)),
            np.ascontiguousarray(count_terms.reshape(-1, len(_COUNT))),
            np.ascontiguousarray(noc),
            np.ascontiguousarray(dram_phy))


def _chip_area_device(g, calib: CalibrationTable):
    """(P,) chip areas only — what the Eq. 8 fitness band consumes —
    through the same ``_area_tables`` gathers ``_configs_device`` runs
    (bitwise identical by construction).  Split out so the fused loop's
    all-hit generations (every child memoized) pay a handful of gathers
    instead of full config building.  Traceable inside jit."""
    g = g.astype(jnp.int64)
    B = g.shape[0]
    T = MAX_TILE_TYPES

    def tcol(t, f):
        return g[:, 1 + t * FIELDS_PER_TILE + _FIELD_COL[f]]

    area_tab, count_tab, noc_tab, dram_tab = _area_tables(calib)
    sfu_idx = jnp.stack([tcol(t, "sfu") % len(_SFU) for t in range(T)],
                        axis=1)
    prec_idx = jnp.stack([tcol(t, "prec") % 4 for t in range(T)], axis=1)
    eng_k = jnp.stack([tcol(t, "engine") % 4 for t in range(T)], axis=1)
    sp_k = jnp.stack([tcol(t, "sparsity") % 3 for t in range(T)], axis=1)
    rows_k = jnp.stack([tcol(t, "rows") % 5 for t in range(T)], axis=1)
    cols_k = jnp.stack([tcol(t, "cols") % 5 for t in range(T)], axis=1)
    sram_k = jnp.stack([tcol(t, "sram") % 7 for t in range(T)], axis=1)
    flat = (((prec_idx * 4 + eng_k) * 3 + sp_k) * 5 + rows_k) * 5 + cols_k
    flat = (flat * len(_SFU) + sfu_idx) * 7 + sram_k

    counts = jnp.stack([_COUNT_DEV[tcol(t, "count") % 8] for t in range(T)],
                       axis=1)
    n_types = (g[:, 0] + 1)[:, None]
    active = jnp.arange(T)[None, :] < n_types
    counts = jnp.where(active, counts, 0)
    num_tiles = counts.sum(axis=1)

    cnt_k = jnp.stack([tcol(t, "count") % len(_COUNT) for t in range(T)],
                      axis=1)
    terms = jnp.where(active, count_tab[flat, cnt_k], 0.0)
    area = jnp.zeros(B)
    for t in range(T):
        area = area + terms[:, t]
    area = area + noc_tab[num_tiles.astype(jnp.int64),
                          g[:, IDX_NOC_BPC] % 4, g[:, IDX_TOPO] % 2]
    return area + dram_tab[g[:, IDX_DRAM_CH] % 4]


def _configs_device(g, calib: CalibrationTable):
    """jnp mirror of ``engine.genomes_to_configs`` on a (P, GENOME_LEN)
    int array: same knob tables, same modulo wrapping, same Eq. 7 term
    order, same *sequential* peak-TOPS/chip-area accumulation — so the
    (tile, chip) stacks and areas are bit-for-bit the host stack that
    ``place_configs`` would ship (pinned by tests/test_pipeline.py).
    Returns ``(tile, chip, chip_area)``: the search kernel's two config
    dicts (f64, exactly TILE_KEYS/CHIP_KEYS) plus the (P,) areas the
    fitness band needs.  Traceable inside jit."""
    g = g.astype(jnp.int64)
    B = g.shape[0]
    T = MAX_TILE_TYPES

    def tcol(t, f):
        return g[:, 1 + t * FIELDS_PER_TILE + _FIELD_COL[f]]

    v: Dict[str, jnp.ndarray] = {}
    sfu_idx = jnp.stack([tcol(t, "sfu") % len(_SFU) for t in range(T)],
                        axis=1)
    sfu = _SFU_DEV[sfu_idx]
    special = sfu > 0
    rows = jnp.stack([_ARRAY_DIM_DEV[tcol(t, "rows") % 5] for t in range(T)],
                     axis=1)
    cols = jnp.stack([_ARRAY_DIM_DEV[tcol(t, "cols") % 5] for t in range(T)],
                     axis=1)
    rows = jnp.where(special, 0.0, rows)
    cols = jnp.where(special, 0.0, cols)
    big = rows * cols >= 1024.0
    v["rows"], v["cols"] = rows, cols
    v["num_macs"] = rows * cols
    clock_mhz = jnp.where(special, 800.0, jnp.where(big, 1200.0, 500.0))
    v["dsp_count"] = jnp.where(special, 1.0, jnp.where(big, 2.0, 1.0))
    dsp_simd = jnp.full((B, T), 64.0)
    v["sfu_mask"] = sfu
    v["sfu_parallel"] = jnp.full((B, T), 16.0)
    v["sram_bpc"] = jnp.full((B, T), 8 * 16.0)   # default sram_banks=8

    v["engine"] = jnp.stack([_ENGINE_DEV[tcol(t, "engine") % 4]
                             for t in range(T)], axis=1)
    prec_idx = jnp.stack([tcol(t, "prec") % 4 for t in range(T)], axis=1)
    v["prec_mask"] = _PREC_MASK_DEV[prec_idx]
    max_prec = _PREC_MAX_DEV[prec_idx]
    v["max_prec"] = max_prec.astype(jnp.float64)
    v["sparsity"] = jnp.stack([_SPARSITY_DEV[tcol(t, "sparsity") % 3]
                               for t in range(T)], axis=1)
    v["dataflow"] = jnp.stack([_DATAFLOW_DEV[tcol(t, "dataflow") % 3]
                               for t in range(T)], axis=1)
    v["sram_kb"] = jnp.stack([_SRAM_KB_DEV[tcol(t, "sram") % 7]
                              for t in range(T)], axis=1)
    v["double_buffer"] = jnp.stack([_DB_DEV[tcol(t, "db") % 2]
                                    for t in range(T)], axis=1)
    v["pipeline_depth"] = jnp.stack([_PIPE_DEV[tcol(t, "pipe") % 4]
                                     for t in range(T)], axis=1)
    v["asym_mac"] = jnp.stack([_ASYM_DEV[tcol(t, "asym") % 4]
                               for t in range(T)], axis=1)
    v["cache_cap"] = v["sram_kb"] * 1024.0 * CACHE_FRAC
    v["dsp_lanes"] = v["dsp_count"] * dsp_simd
    v["clock_hz"] = clock_mhz * 1e6

    # tile_area (Eq. 7) as a pure gather from the host-precomputed knob
    # grid (see _area_tables for why no area arithmetic may run on device)
    area_tab, count_tab, noc_tab, dram_tab = _area_tables(calib)
    eng_k = jnp.stack([tcol(t, "engine") % 4 for t in range(T)], axis=1)
    sp_k = jnp.stack([tcol(t, "sparsity") % 3 for t in range(T)], axis=1)
    rows_k = jnp.stack([tcol(t, "rows") % 5 for t in range(T)], axis=1)
    cols_k = jnp.stack([tcol(t, "cols") % 5 for t in range(T)], axis=1)
    sram_k = jnp.stack([tcol(t, "sram") % 7 for t in range(T)], axis=1)
    flat = (((prec_idx * 4 + eng_k) * 3 + sp_k) * 5 + rows_k) * 5 + cols_k
    flat = (flat * len(_SFU) + sfu_idx) * 7 + sram_k
    v["area_mm2"] = area_tab[flat]

    counts = jnp.stack([_COUNT_DEV[tcol(t, "count") % 8] for t in range(T)],
                       axis=1)
    n_types = (g[:, 0] + 1)[:, None]
    counts = jnp.where(jnp.arange(T)[None, :] < n_types, counts, 0)

    starts = jnp.concatenate(
        [jnp.zeros((B, 1), counts.dtype),
         jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)
    ends = starts + counts
    slots = jnp.arange(MAX_TILES)
    member = (slots[None, None, :] >= starts[:, :, None]) \
        & (slots[None, None, :] < ends[:, :, None])

    tile = {}
    for f in ("num_macs", "rows", "cols", "engine", "prec_mask", "asym_mac",
              "sparsity", "dataflow", "sram_kb", "dsp_lanes", "dsp_count",
              "sfu_mask", "sfu_parallel", "double_buffer", "pipeline_depth",
              "clock_hz", "cache_cap", "sram_bpc", "area_mm2", "max_prec"):
        tile[f] = jnp.sum(jnp.where(member, v[f][:, :, None], 0.0), axis=1)
    tile["exists"] = member.any(axis=1).astype(jnp.float64)

    num_tiles = counts.sum(axis=1)
    gw, gh = grid_dims(jnp, num_tiles.astype(jnp.float64),
                       _ASPECT_DEV[g[:, IDX_ASPECT] % 3])
    chip = {
        "dram_gbps": _DRAM_DEV[g[:, IDX_DRAM] % 6],
        "hops": _HOPS_TABLE_DEV[g[:, IDX_ICONN] % 4, num_tiles],
        "noc_bpc": _NOC_BPC_DEV[g[:, IDX_NOC_BPC] % 4],
        "noc_base_cycles": jnp.full(B, 8.0),
        "ref_clock_hz": jnp.full(B, 1000 * 1e6),
        "torus": _TOPO_DEV[g[:, IDX_TOPO] % 2],
        "dram_channels": _DRAM_CH_DEV[g[:, IDX_DRAM_CH] % 4],
        "grid_w": gw,
        "grid_h": gh,
    }
    assert set(tile) == set(TILE_KEYS) and set(chip) == set(CHIP_KEYS)

    # chip_area: per-type sequential sum in type order + NoC (host order),
    # every term a gather from the pre-rounded area x count / NoC / DRAM
    # PHY tables
    cnt_k = jnp.stack([tcol(t, "count") % len(_COUNT) for t in range(T)],
                      axis=1)
    active = jnp.arange(T)[None, :] < n_types
    terms = jnp.where(active, count_tab[flat, cnt_k], 0.0)
    area = jnp.zeros(B)
    for t in range(T):
        area = area + terms[:, t]
    area = area + noc_tab[num_tiles.astype(jnp.int64),
                          g[:, IDX_NOC_BPC] % 4, g[:, IDX_TOPO] % 2]
    area = area + dram_tab[g[:, IDX_DRAM_CH] % 4]
    return tile, chip, area


# =============================================================================
# the fused refinement: whole GA run (island model) as ONE dispatch
# =============================================================================

@dataclasses.dataclass
class FusedRefinement:
    """``run_ga_fused`` output: the ``GAResult`` plus what the pipeline's
    cross-seed Pareto merge and seed-boundary store sync consume — the
    device memo state and the final scored population (which always
    contains the best-ever genome: elitism carries it forward)."""

    result: "GAResult"               # noqa: F821 — ga.GAResult
    memo: DeviceMemo
    population: np.ndarray           # (P, GENOME_LEN) final genomes
    pop_metrics: Dict[str, np.ndarray]   # latency/energy/tops_w (P, W), area (P,)
    generations_run: int


@functools.lru_cache(maxsize=16)
def _refine_kernel(calib: CalibrationTable,
                   shapes: Tuple[Tuple[int, int], ...], mode: str,
                   population: int, islands: int, generations: int,
                   tournament: int, n_elite: int, crossover_rate: float,
                   mutation_rate: float, early_stop: int,
                   migrate_every: int, migrate_k: int,
                   fidelity: str = "aggregate"):
    """The whole Stage-2 refinement as ONE jitted dispatch: a
    ``lax.while_loop`` over generations whose body runs ring migration
    (islands > 1), the genetics kernel, canonicalization, the
    device-memo probe, the fused exact search scan (skipped entirely via
    ``lax.cond`` when every row hits), the memo insert, and the Eq. 8
    fitness + best/stall tracking — no host round trip anywhere inside.

    With ``islands == 1`` the generation body is exactly the host-memo
    device loop's: same ``_genetics_kernel`` instance, same key-split
    sequence, memo hits bitwise inert — which is what makes a seeded
    single-island run genome-for-genome equal to ``run_ga_device``
    (pinned by tests/test_pipeline.py).  With ``islands > 1`` the
    population is logically (islands, P/islands) — per-island
    tournaments/elites over per-island key streams, and every
    ``migrate_every`` generations each island's top ``migrate_k`` rows
    replace the next island's worst via ``jnp.roll`` over the island
    axis (a collective permute when that axis is sharded — see
    ``launch.mesh.island_sharding``).  Migrant fitness rows travel with
    the genomes, so migration costs no rescoring.
    """
    from ..compiler.batched_mapper import _jitted_search_population

    P, I = population, islands
    Pi = P // I
    L = GENOME_LEN
    lkey, ekey, akey = _MODE_KEYS[mode]
    gen_fn = _genetics_kernel(Pi, tournament, n_elite, crossover_rate,
                              mutation_rate)
    search_fn = _jitted_search_population(calib, shapes, True, fidelity)

    def score(pop, canon, memo, e_homo, lo, hi, alpha, xs_list, tm_list):
        # areas only (cheap gathers, bitwise _configs_device's) — full
        # config building happens inside the miss branch, so an all-hit
        # generation skips it along with the scan
        area = _chip_area_device(pop, calib)
        hit, mv = memo_lookup(memo, canon)

        def cached(_):
            return mv[:, 0], mv[:, 1], mv[:, 2]

        def fresh(_):
            tile, chip, _ = _configs_device(pop, calib)
            outs = search_fn(tile, chip, xs_list, tm_list)
            l = jnp.stack([o[lkey] for o in outs], axis=1)     # (P, W)
            e = jnp.stack([o[ekey] for o in outs], axis=1)
            a = jnp.stack([o[akey] for o in outs], axis=1)
            ok = jnp.stack([o["ok"] for o in outs], axis=1)
            power = e * 1e-12 / jnp.maximum(l, 1e-30)
            t = a / jnp.maximum(power, 1e-30)
            # unmappable rows: inf latency/energy, zero TOPS/W (the
            # engine's exact-path masking, elementwise identical).  A
            # NaN cell (cost-model corruption) is masked the same way —
            # the device memo must never cache a non-finite row, and the
            # host engine would have scored it skip/-inf too.  No NaN
            # ever arises from a healthy cost model, so the extra mask
            # is bitwise inert on clean runs.
            okk = ok & ~(jnp.isnan(l) | jnp.isnan(e)
                         | jnp.isnan(t) | jnp.isinf(t))
            lat = jnp.where(okk, l, jnp.inf)
            en = jnp.where(okk, e, jnp.inf)
            tw = jnp.where(okk, t, 0.0)
            # hit rows take their memo values — numerically a no-op
            # (metrics are bitwise reproducible) but keeps the two cond
            # branches the same function of the memo state
            return (jnp.where(hit[:, None], mv[:, 0], lat),
                    jnp.where(hit[:, None], mv[:, 1], en),
                    jnp.where(hit[:, None], mv[:, 2], tw))

        # warm replay: a generation whose every child is memoized skips
        # the search scan wholesale
        lat, en, tw = jax.lax.cond(jnp.all(hit), cached, fresh, None)
        memo = memo_insert(memo, canon, jnp.stack([lat, en, tw], axis=1),
                           update=~hit)
        fit = _fitness_kernel(en, tw, lat, area, e_homo, lo, hi, alpha)
        return fit, lat, en, tw, area, memo

    def migrate(popI, fitI):
        order = jnp.argsort(-fitI, axis=1)             # best first
        top = order[:, :migrate_k]
        worst = order[:, Pi - migrate_k:]
        mig_g = jnp.take_along_axis(popI, top[:, :, None], axis=1)
        mig_f = jnp.take_along_axis(fitI, top, axis=1)
        mig_g = jnp.roll(mig_g, 1, axis=0)             # ring: i <- i-1
        mig_f = jnp.roll(mig_f, 1, axis=0)
        ii = jnp.arange(I)[:, None]
        return (popI.at[ii, worst].set(mig_g),
                fitI.at[ii, worst].set(mig_f))

    def refine(pop0, key, memo, e_homo, lo, hi, alpha, xs_list, tm_list):
        pop0 = pop0.astype(jnp.int32)
        canon0 = _canonical_device(pop0)
        fit, lat, en, tw, area, memo = score(
            pop0, canon0, memo, e_homo, lo, hi, alpha, xs_list, tm_list)
        gi = jnp.argmax(fit)
        best = (fit[gi], pop0[gi], lat[gi], en[gi], tw[gi], area[gi])
        hist = jnp.full(generations + 1, -jnp.inf).at[0].set(fit[gi])
        carry = (jnp.asarray(0), jnp.asarray(0), key, pop0, fit,
                 lat, en, tw, area, memo, best, hist)

        def cond(c):
            gen, stall = c[0], c[1]
            return (gen < generations) & (stall < early_stop)

        def body(c):
            (gen, stall, key, pop, fit, lat, en, tw, area, memo, best,
             hist) = c
            if I > 1:
                popI = pop.reshape(I, Pi, L)
                fitI = fit.reshape(I, Pi)
                popI, fitI = jax.lax.cond(
                    (gen > 0) & (gen % migrate_every == 0),
                    lambda a: migrate(*a), lambda a: a, (popI, fitI))
                pop = popI.reshape(P, L)
                fit = fitI.reshape(P)
            key, sub = jax.random.split(key)
            if I == 1:
                pop, canon = gen_fn(pop, fit, sub)
            else:
                subs = jax.random.split(sub, I)
                popI, canonI = jax.vmap(gen_fn)(
                    pop.reshape(I, Pi, L), fit.reshape(I, Pi), subs)
                pop = popI.reshape(P, L)
                canon = canonI.reshape(P, L)
            fit, lat, en, tw, area, memo = score(
                pop, canon, memo, e_homo, lo, hi, alpha, xs_list, tm_list)
            gi = jnp.argmax(fit)
            imp = fit[gi] > best[0]

            def pick(new, old):
                return jnp.where(imp, new, old)

            best = (pick(fit[gi], best[0]), pick(pop[gi], best[1]),
                    pick(lat[gi], best[2]), pick(en[gi], best[3]),
                    pick(tw[gi], best[4]), pick(area[gi], best[5]))
            stall = jnp.where(imp, 0, stall + 1)
            hist = hist.at[gen + 1].set(best[0])
            return (gen + 1, stall, key, pop, fit, lat, en, tw, area,
                    memo, best, hist)

        (gen, _, _, pop, fit, lat, en, tw, area, memo, best,
         hist) = jax.lax.while_loop(cond, body, carry)
        return {"gen": gen, "pop": pop, "fit": fit, "lat": lat, "en": en,
                "tw": tw, "area": area, "memo": memo, "hist": hist,
                "best_fit": best[0], "best_genome": best[1],
                "best_lat": best[2], "best_en": best[3],
                "best_tw": best[4], "best_area": best[5]}

    return jax.jit(refine)


def run_ga_fused(sweep, bracket: float, cfg=None, seed: int = 0,
                 calib: CalibrationTable = DEFAULT_CALIB,
                 verbose: bool = False,
                 engine: Optional[EvalEngine] = None,
                 islands: Optional[int] = None, migrate_every: int = 5,
                 migrate_k: int = 2, memo: Optional[DeviceMemo] = None,
                 memo_capacity: int = 1 << 15,
                 store_sync: bool = True) -> Optional[FusedRefinement]:
    """GA refinement at one area budget with the WHOLE run fused into one
    jitted dispatch, scored against the device-resident memo
    (``dse.device_memo``) instead of per-generation host memo round
    trips.

    Same seeding and contract as ``run_ga_device`` (None when the
    bracket has no homogeneous baseline); requires a *local*
    ``EvalEngine(backend="exact")`` — the loop builds configs and runs
    the search scan itself on device, so a remote ``DSEClient`` can't
    serve it.  ``islands=None`` picks one island per local device when
    the population splits evenly (``launch.mesh.default_islands``), else
    a single panmictic island, which walks the exact genome stream of
    ``run_ga_device`` (the PR's bitwise invariant).  ``store_sync=True``
    treats this call as one seed boundary: the memo preloads from the
    engine store's LRU tier and drains back after the run (the §4
    pipeline passes ``memo=`` and manages boundaries itself).

    The engine's ``stats``/store see nothing per generation — that is
    the point; hits/misses live in the device table until drained.
    """
    from .ga import GAConfig, GAResult
    from ..compiler.batched_mapper import _search_xs_cached
    cfg = cfg or GAConfig()
    if engine is None:
        engine = EvalEngine(sweep.workloads, calib,
                            config=EngineConfig(backend="exact",
                                                nonfinite="skip"))
    elif not isinstance(engine, EvalEngine):
        raise ValueError("run_ga_fused needs a local EvalEngine — the "
                         "fused loop stages configs and the search scan "
                         "itself, which a remote client cannot serve")
    else:
        engine.check_workloads(sweep.workloads, calib)
    if engine.backend != "exact":
        raise ValueError("run_ga_fused requires backend='exact' (the fused "
                         f"search kernel); got {engine.backend!r}")
    rng = np.random.default_rng(seed + int(bracket))
    base = sweep.homo_baseline()
    if bracket not in base:
        return None
    e_homo = np.asarray(base[bracket], np.float64)
    lo, hi = bracket_bounds(bracket)
    W = len(engine.workloads)

    # ---- seed population: identical to run_ga_device ----------------------
    fit_sweep = sweep.fitness(cfg.alpha)
    in_b = np.nonzero((sweep.bracket == bracket) & np.isfinite(fit_sweep))[0]
    order = in_b[np.argsort(-fit_sweep[in_b])][:cfg.seed_top_k]
    pop = sweep.genomes[order].copy()[:cfg.population]
    while len(pop) < cfg.population:
        fill = random_genomes(rng, cfg.population - len(pop),
                              family="hetero_bls" if rng.random() < 0.5
                              else None)
        pop = np.concatenate([pop, fill])[:cfg.population]
    pop = np.ascontiguousarray(pop, np.int32)

    P = cfg.population
    if islands is None:
        from ...launch.mesh import default_islands
        islands = default_islands(P)
    islands = max(int(islands), 1)
    if P % islands:
        raise ValueError(f"population {P} not divisible into "
                         f"{islands} islands")
    Pi = P // islands
    n_elite = max(int(cfg.elitism * Pi), 1)
    if n_elite >= Pi:
        raise ValueError(f"per-island population {Pi} leaves no room for "
                         f"{n_elite} elites — fewer islands or more genomes")
    mk = max(min(int(migrate_k), Pi // 2), 1) if islands > 1 else 0

    if memo is None:
        memo = memo_from_store(engine, memo_capacity) if store_sync \
            else memo_init(memo_capacity, W)
    elif memo.vals.shape[-1] != W:
        raise ValueError(f"memo carries {memo.vals.shape[-1]}-workload "
                         f"rows; engine scores {W}")

    staged = [_search_xs_cached(engine._prepared(w))
              for w in engine.workloads]
    shapes = tuple((s[1], s[2]) for s in staged)
    xs_list = tuple(s[0] for s in staged)
    tm_list = tuple(s[3] for s in staged)

    kernel = _refine_kernel(calib, shapes, engine.mode, P, islands,
                            cfg.generations, cfg.tournament, n_elite,
                            cfg.crossover_rate, cfg.mutation_rate,
                            cfg.early_stop, int(migrate_every), mk,
                            engine.fidelity)

    pop_dev = jnp.asarray(pop, jnp.int32)
    sharding = None
    if islands > 1:
        from ...launch.mesh import island_sharding
        sharding = island_sharding(islands)
    elif engine._sharding is not None \
            and P % engine._sharding.mesh.size == 0:
        from ...launch.mesh import population_sharding
        sharding = population_sharding()
    if sharding is not None:
        pop_dev = jax.device_put(pop_dev, sharding)

    key = jax.random.PRNGKey(seed + int(bracket))
    out = kernel(pop_dev, key, memo,
                 jnp.asarray(e_homo), jnp.asarray(lo, jnp.float64),
                 jnp.asarray(hi, jnp.float64),
                 jnp.asarray(cfg.alpha, jnp.float64), xs_list, tm_list)

    n_gens = int(out["gen"])
    history = [float(x) for x in np.asarray(out["hist"][:n_gens + 1])]
    best_metrics = {"latency": np.asarray(out["best_lat"]),
                    "energy": np.asarray(out["best_en"]),
                    "tops_w": np.asarray(out["best_tw"]),
                    "area": np.float64(out["best_area"])}
    sav = (e_homo - best_metrics["energy"]) / np.maximum(e_homo, 1e-30)
    result = GAResult(
        bracket=bracket, best_genome=np.asarray(out["best_genome"]),
        best_fitness=float(out["best_fit"]), best_savings_per_wl=sav,
        best_metrics=best_metrics, history=history,
        evaluated=P * (n_gens + 1))
    memo = out["memo"]
    if store_sync:
        drain_to_store(memo, engine)
    if verbose:
        print(f"[ga-fused {bracket:.0f}mm2] {n_gens} gens x {P} genomes "
              f"({islands} island(s)): best={result.best_fitness:+.4f}")
    return FusedRefinement(
        result=result, memo=memo,
        population=np.asarray(out["pop"]),
        pop_metrics={"latency": np.asarray(out["lat"]),
                     "energy": np.asarray(out["en"]),
                     "tops_w": np.asarray(out["tw"]),
                     "area": np.asarray(out["area"])},
        generations_run=n_gens)
