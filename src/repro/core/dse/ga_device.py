"""Device-side GA generation loop (paper §4.5) over the exact search path.

The Stage-2 refinement loop, with the genetics moved off the host: one
jitted ``jax.random``-keyed dispatch per generation runs tournament
selection (size ``cfg.tournament``), uniform crossover, Poisson-k gene
mutation and elitism over the whole ``(P, GENOME_LEN)`` population —
replacing the ~P Python tournament draws, per-child numpy crossover
/mutation, and per-generation host round trips of the historical loop
(``ga.run_ga(loop="host")``).  The same dispatch canonicalizes the
children (``canonical_genomes``, ported to jnp bit-for-bit), so the
engine's mode-keyed memo lookup costs no extra host pass: elites and
duplicate children are cache hits that skip the simulation scan
entirely.

Scoring goes through an ``EvalEngine`` — by default one constructed
with ``backend="exact"``, the class-specialized fused mapping+execution
scan (``compiler.batched_mapper.search_and_simulate``), so the Eq. 8
fitness the tournament selects on is computed from *exact*
(fused-mapper) metrics: search-time fitness equals a post-hoc
``rescore()`` bitwise, retiring the approximate-search-then-rescore
fidelity gap for GA refinement.  The Eq. 8 fitness itself (iso-area
savings vs the bracket's homogeneous baseline + the alpha TOPS/W
tie-break, with the area-bracket validity mask) is a jitted device
kernel over the (P, W) metric matrices.

Seeded runs are bitwise-deterministic: the genome stream is a
``jax.random`` fold of (seed, bracket), engine metrics are
batch-composition-independent (pinned by tests/test_engine.py), and two
same-seed runs produce identical ``best_genome``/``history``
(tests/test_ga_device.py).  With a sharded engine and a population
divisible by the mesh, the population axis of the genetics dispatch is
placed with the same ``NamedSharding`` as the evaluation batches
(``launch.mesh.population_sharding``).

The one *documented* departure from the host loop's numpy genetics: the
Poisson-k mutation draw is truncated at ``MUT_GENES_MAX`` (= 8) genes
per child (P[k > 8 | k ~ Poisson(2)] < 3e-4); the host loop keeps the
unbounded draw.  Both are the paper's operator — the two loops walk
different (equally valid) random streams either way.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict, Optional

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from ..arch import MAX_TILE_TYPES
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from .encoding import FIELDS_PER_TILE, GENOME_LEN, genome_bounds, random_genomes
from .engine import (_ASYM_CANON, _ASYM_COL, _FIELD_COL, _PREC_COL, _SFU,
                     _SFU_COL, _SPECIAL_INERT_COLS, EvalEngine)
from .objective import ALPHA, AREA_BRACKETS, area_bracket

__all__ = ["run_ga_device", "MUT_GENES_MAX", "canonical_genomes_device",
           "fitness_device", "bracket_bounds"]

# Poisson-k mutation truncation of the device loop (see module docstring)
MUT_GENES_MAX = 8

_SFU_DEV = jnp.asarray(_SFU)
_ASYM_CANON_DEV = jnp.asarray(_ASYM_CANON, jnp.int32)


# =============================================================================
# device canonicalization (bitwise port of engine.canonical_genomes)
# =============================================================================

def _canonical_device(g):
    """jnp mirror of ``engine.canonical_genomes`` on a (P, GENOME_LEN)
    int array — same zeroing order, same tables, bit-for-bit (pinned by
    tests/test_ga_device.py)."""
    n_types = g[:, 0] + 1
    for t in range(MAX_TILE_TYPES):
        base = 1 + t * FIELDS_PER_TILE
        inactive = t >= n_types
        block = g[:, base:base + FIELDS_PER_TILE]
        g = g.at[:, base:base + FIELDS_PER_TILE].set(
            jnp.where(inactive[:, None], 0, block))
        special = (_SFU_DEV[g[:, base + _SFU_COL] % len(_SFU)] > 0) \
            & ~inactive
        for col in _SPECIAL_INERT_COLS:
            g = g.at[:, base + col].set(
                jnp.where(special, 0, g[:, base + col]))
        g = g.at[:, base + _ASYM_COL].set(
            _ASYM_CANON_DEV[g[:, base + _PREC_COL] % 4,
                            g[:, base + _ASYM_COL] % 4].astype(g.dtype))
    return g


@jax.jit
def _canonical_device_jit(g):
    return _canonical_device(g)


def canonical_genomes_device(genomes: np.ndarray) -> np.ndarray:
    """Host-callable wrapper over the jitted device canonicalizer."""
    g = np.asarray(genomes, np.int64).reshape(-1, GENOME_LEN)
    return np.asarray(_canonical_device_jit(jnp.asarray(g)))


# =============================================================================
# Eq. 8 fitness kernel
# =============================================================================

def bracket_bounds(bracket: float):
    """(lo, hi] area band equivalent to ``area_bracket(a) == bracket``
    (the last bracket is open above: oversized chips land in it)."""
    if bracket not in AREA_BRACKETS:
        return math.nan, math.nan      # no design can match (host parity)
    bi = AREA_BRACKETS.index(bracket)
    lo = AREA_BRACKETS[bi - 1] if bi > 0 else -math.inf
    hi = bracket if bi < len(AREA_BRACKETS) - 1 else math.inf
    return lo, hi


@jax.jit
def _fitness_kernel(en, tw, lat, area, e_homo, lo, hi, alpha):
    """Eq. 8 on the (P, W) metric matrices, on device: mean iso-area
    savings vs the bracket's homogeneous baseline + alpha * TOPS/W
    normalized over comparable (in-bracket, valid) designs only;
    invalid/out-of-bracket rows score -inf (``ga._fitness`` semantics)."""
    sav = (e_homo[None, :] - en) / jnp.maximum(e_homo[None, :], 1e-30)
    fit = sav.mean(axis=1)
    peak_tw = tw.max(axis=1)
    bad = ~jnp.isfinite(lat).all(axis=1) | ~(lat > 0).all(axis=1)
    bad = bad | ~((area > lo) & (area <= hi))
    ok = ~bad
    max_tw = jnp.max(jnp.where(ok, peak_tw, -jnp.inf))
    max_tw = jnp.where(jnp.any(ok), max_tw, 1.0)
    fit = fit + alpha * peak_tw / jnp.maximum(max_tw, 1e-30)
    return jnp.where(bad, -jnp.inf, fit)


def fitness_device(metrics: Dict[str, np.ndarray], e_homo: np.ndarray,
                   bracket: float, alpha: float = ALPHA) -> np.ndarray:
    """Eq. 8 fitness of an engine ``evaluate()``/``rescore()`` result
    through the device kernel — the scoring the device GA loop selects
    on (and what the exact-search/rescore parity property compares)."""
    lo, hi = bracket_bounds(bracket)
    return np.asarray(_fitness_kernel(
        jnp.asarray(metrics["energy"]), jnp.asarray(metrics["tops_w"]),
        jnp.asarray(metrics["latency"]), jnp.asarray(metrics["area"]),
        jnp.asarray(e_homo, jnp.float64), jnp.asarray(lo, jnp.float64),
        jnp.asarray(hi, jnp.float64), jnp.asarray(alpha, jnp.float64)))


# =============================================================================
# the jitted generation kernel
# =============================================================================

@functools.lru_cache(maxsize=32)
def _genetics_kernel(population: int, tournament: int, n_elite: int,
                     crossover_rate: float, mutation_rate: float):
    """One GA generation as a single jitted dispatch:
    ``(pop, fit, key) -> (children, canonical(children))``.

    Mirrors the host loop's operator semantics — elites pass through
    unchanged, each non-elite slot pair comes from two size-K
    tournaments, uniform crossover swaps genes with p=0.5 at
    ``crossover_rate``, and mutated children redraw Poisson-k genes
    uniformly under ``genome_bounds`` (k truncated at MUT_GENES_MAX on
    device) — over a different (jax.random) stream.
    """
    bounds = jnp.asarray(genome_bounds(), jnp.int32)
    L = GENOME_LEN
    n_pairs = max(-(-(population - n_elite) // 2), 0)
    n_children = n_pairs * 2

    def gen(pop, fit, key):
        pop = pop.astype(jnp.int32)
        k_t, k_cx, k_cxm, k_mut, k_mk, k_mg, k_mv = jax.random.split(key, 7)
        # ---- elitism -----------------------------------------------------
        elite_idx = jnp.argsort(-fit)[:n_elite]
        elites = pop[elite_idx]
        # ---- tournament selection (all draws in one dispatch) ------------
        idx = jax.random.randint(k_t, (n_children, tournament), 0, population)
        winners = idx[jnp.arange(n_children), jnp.argmax(fit[idx], axis=1)]
        pa = pop[winners[0::2]]
        pb = pop[winners[1::2]]
        # ---- uniform crossover ------------------------------------------
        do_cx = jax.random.uniform(k_cx, (n_pairs,)) < crossover_rate
        swap = do_cx[:, None] & (jax.random.uniform(k_cxm, (n_pairs, L)) < 0.5)
        ca = jnp.where(swap, pb, pa)
        cb = jnp.where(swap, pa, pb)
        children = jnp.stack([ca, cb], axis=1).reshape(n_children, L)
        # ---- Poisson-k gene mutation ------------------------------------
        do_mut = jax.random.uniform(k_mut, (n_children,)) < mutation_rate
        k_genes = jnp.clip(jax.random.poisson(k_mk, 2.0, (n_children,)),
                           1, MUT_GENES_MAX)
        genes = jax.random.randint(k_mg, (n_children, MUT_GENES_MAX), 0, L)
        vals = jnp.floor(jax.random.uniform(k_mv, (n_children, MUT_GENES_MAX))
                         * bounds[genes]).astype(jnp.int32)

        def mutate(child, do, kk, gg, vv):
            # sequential application: later draws overwrite earlier ones
            # on duplicate gene indices, like the host fancy assignment
            def body(j, ch):
                return jnp.where(do & (j < kk), ch.at[gg[j]].set(vv[j]), ch)
            return jax.lax.fori_loop(0, MUT_GENES_MAX, body, child)

        children = jax.vmap(mutate)(children, do_mut, k_genes, genes, vals)
        new_pop = jnp.concatenate([elites, children])[:population]
        return new_pop, _canonical_device(new_pop)

    return jax.jit(gen)


# =============================================================================
# the generation loop
# =============================================================================

def run_ga_device(sweep, bracket: float, cfg=None, seed: int = 0,
                  calib: CalibrationTable = DEFAULT_CALIB,
                  verbose: bool = False, engine: Optional[EvalEngine] = None,
                  prefilter: bool = True,
                  on_generation: Optional[Callable] = None):
    """GA refinement at one area budget on the device generation loop.

    Same contract as ``ga.run_ga`` (which delegates here by default):
    seeded from the sweep's top-k at the bracket, returns a ``GAResult``
    or None when the bracket has no homogeneous baseline.  Without an
    explicit ``engine``, scoring runs the exact search backend — one
    class-specialized fused map+execute dispatch per workload per
    generation, memo hits (elites, duplicate children) and
    bracket-prefiltered genomes skipping the scan.  ``engine`` may be
    any object with the engine scoring surface — e.g. the evaluation
    service's ``DSEClient``, which coalesces this loop's populations
    with other tenants' candidates.  ``on_generation(gen, pop, fit,
    metrics)`` is invoked after every scored population (gen 0 = the
    seed population) — the hook the service streams Pareto-front
    updates from.
    """
    from .ga import GAConfig, GAResult
    cfg = cfg or GAConfig()
    engine = (engine.check_workloads(sweep.workloads, calib)
              if engine is not None
              else EvalEngine(sweep.workloads, calib, backend="exact"))
    rng = np.random.default_rng(seed + int(bracket))
    base = sweep.homo_baseline()
    if bracket not in base:
        return None
    e_homo = np.asarray(base[bracket], np.float64)
    lo, hi = bracket_bounds(bracket)

    # ---- seed population: identical to the host loop -----------------------
    fit_sweep = sweep.fitness(cfg.alpha)
    in_b = np.nonzero((sweep.bracket == bracket) & np.isfinite(fit_sweep))[0]
    order = in_b[np.argsort(-fit_sweep[in_b])][:cfg.seed_top_k]
    pop = sweep.genomes[order].copy()
    while len(pop) < cfg.population:
        fill = random_genomes(rng, cfg.population - len(pop),
                              family="hetero_bls" if rng.random() < 0.5
                              else None)
        pop = np.concatenate([pop, fill])[:cfg.population]
    pop = np.ascontiguousarray(pop, np.int32)

    def keep(areas: np.ndarray) -> np.ndarray:
        # vectorized `area_bracket(a) == bracket` (bracket_bounds parity
        # is pinned by tests/test_ga_device.py)
        return (areas > lo) & (areas <= hi)

    def evaluate(genomes: np.ndarray, canonical=None):
        m = engine.evaluate(genomes, keep=keep if prefilter else None,
                            canonical=canonical)
        m.pop("meta", None)  # best_metrics holds per-genome arrays only
        fit = fitness_device(m, e_homo, bracket, cfg.alpha)
        return fit, m

    # per-generation miss counts sweep the whole bucket range: register
    # the shapes up front so every dispatch is minimally padded
    engine.reserve_shapes(cfg.population)
    fit, metrics = evaluate(pop)
    if on_generation is not None:
        on_generation(0, pop, fit, metrics)
    best_i = int(np.argmax(fit))
    best = (fit[best_i], pop[best_i].copy(),
            {k: v[best_i] for k, v in metrics.items()})
    history = [float(best[0])]
    evaluated = len(pop)
    stall = 0

    n_elite = max(int(cfg.elitism * cfg.population), 1)
    gen_fn = _genetics_kernel(cfg.population, cfg.tournament, n_elite,
                              cfg.crossover_rate, cfg.mutation_rate)
    key = jax.random.PRNGKey(seed + int(bracket))
    sharding = None
    if engine._sharding is not None \
            and cfg.population % engine._sharding.mesh.size == 0:
        from ...launch.mesh import population_sharding
        sharding = population_sharding()
    pop_dev = jnp.asarray(pop, jnp.int32)
    if sharding is not None:
        pop_dev = jax.device_put(pop_dev, sharding)

    for gen in range(cfg.generations):
        key, sub = jax.random.split(key)
        pop_dev, canon_dev = gen_fn(pop_dev, jnp.asarray(fit), sub)
        # ONE host transfer per generation: the (P, GENOME_LEN) children
        # + their canonical forms (the engine's memo keys)
        pop = np.asarray(pop_dev)
        canon = np.asarray(canon_dev)
        fit, metrics = evaluate(pop, canonical=canon)
        if on_generation is not None:
            on_generation(gen + 1, pop, fit, metrics)
        evaluated += len(pop)
        gi = int(np.argmax(fit))
        if fit[gi] > best[0]:
            best = (fit[gi], pop[gi].copy(),
                    {k: v[gi] for k, v in metrics.items()})
            stall = 0
        else:
            stall += 1
        history.append(float(best[0]))
        if verbose:
            print(f"[ga-dev {bracket:.0f}mm2] gen {gen}: best={best[0]:+.4f} "
                  f"(stall {stall})")
        if stall >= cfg.early_stop:
            break

    sav = (e_homo - best[2]["energy"]) / np.maximum(e_homo, 1e-30)
    return GAResult(bracket=bracket, best_genome=best[1],
                    best_fitness=float(best[0]), best_savings_per_wl=sav,
                    best_metrics=best[2], history=history, evaluated=evaluated)
