"""Genome encoding for the 12-knob design space (paper §4.5).

A genome is a fixed-length integer vector indexing the knob grids of
``repro.core.arch.KNOB_GRID``:

  [ n_tile_types,
    (count, rows, cols, sram, prec, sparsity, engine, dataflow,
     sfu, asym, pipe, db)  x MAX_TILE_TYPES,
    dram_bw, interconnect ]

A tile type with sfu > 0 decodes to a Special-Function tile (rows=cols=0,
SFUs + one DSP) — SFUs live in Special-Function tiles, matching the
paper's tile taxonomy (§3.3.5).  Clock domains follow the paper's fixed
assignment: >= 32x32 MAC tiles at 1200 MHz (Big), smaller at 500 MHz
(Little), Special-Function at 800 MHz.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..arch import (KNOB_GRID, MAX_TILE_TYPES, AsymMAC, ChipConfig, Dataflow,
                    Engine, Interconnect, Sparsity, TileTemplate)
from ..ir import Precision

_HOMO_PREC_IDX = KNOB_GRID["precision_set"].index(
    frozenset({Precision.INT8, Precision.FP16}))

__all__ = ["Genome", "GENOME_LEN", "FIELDS_PER_TILE", "decode",
           "random_genomes", "genome_bounds", "FAMILIES",
           "IDX_DRAM", "IDX_ICONN", "IDX_TOPO", "IDX_ASPECT",
           "IDX_NOC_BPC", "IDX_DRAM_CH", "INTERCONNECT_GENE_DEFAULTS"]

_TILE_FIELDS = ("count", "rows", "cols", "sram", "prec", "sparsity",
                "engine", "dataflow", "sfu", "asym", "pipe", "db")
FIELDS_PER_TILE = len(_TILE_FIELDS)
# chip-level genes trail the tile blocks: dram bw, interconnect enum,
# then the PR-9 interconnect-structure genes (mesh/torus, grid aspect,
# NoC bytes/cycle, DRAM channel count)
GENOME_LEN = 1 + MAX_TILE_TYPES * FIELDS_PER_TILE + 6
IDX_DRAM = 1 + MAX_TILE_TYPES * FIELDS_PER_TILE          # 37
IDX_ICONN = IDX_DRAM + 1                                 # 38
IDX_TOPO = IDX_DRAM + 2                                  # 39
IDX_ASPECT = IDX_DRAM + 3                                # 40
IDX_NOC_BPC = IDX_DRAM + 4                               # 41
IDX_DRAM_CH = IDX_DRAM + 5                               # 42

_ASPECT_DEFAULT_IDX = KNOB_GRID["grid_aspect"].index(1.0)
_NOC_BPC_DEFAULT_IDX = KNOB_GRID["noc_bpc"].index(64)
# gene values that reproduce the pre-topology chip (mesh, square grid,
# 64 B/cycle NoC, one DRAM channel) — the canonical interconnect
INTERCONNECT_GENE_DEFAULTS = {
    IDX_TOPO: 0,
    IDX_ASPECT: _ASPECT_DEFAULT_IDX,
    IDX_NOC_BPC: _NOC_BPC_DEFAULT_IDX,
    IDX_DRAM_CH: 0,
}

_GRID_FOR_FIELD = {
    "count": KNOB_GRID["count"],
    "rows": KNOB_GRID["array_dim"],
    "cols": KNOB_GRID["array_dim"],
    "sram": KNOB_GRID["sram_kb"],
    "prec": KNOB_GRID["precision_set"],
    "sparsity": KNOB_GRID["sparsity"],
    "engine": KNOB_GRID["engine"],
    "dataflow": KNOB_GRID["dataflow"],
    "sfu": KNOB_GRID["sfu_mask"],
    "asym": KNOB_GRID["asym_mac"],
    "pipe": KNOB_GRID["pipeline_depth"],
    "db": KNOB_GRID["double_buffer"],
}

FAMILIES = ("homo", "hetero_bl", "hetero_bls")

Genome = np.ndarray  # (GENOME_LEN,) int32


def genome_bounds() -> np.ndarray:
    """Exclusive upper bound per gene (for sampling / mutation clipping)."""
    b: List[int] = [MAX_TILE_TYPES]  # n_tile_types - 1 in [0, 2]
    for _ in range(MAX_TILE_TYPES):
        b.extend(len(_GRID_FOR_FIELD[f]) for f in _TILE_FIELDS)
    b.append(len(KNOB_GRID["dram_gbps"]))
    b.append(len(KNOB_GRID["interconnect"]))
    b.append(len(KNOB_GRID["noc_topology"]))
    b.append(len(KNOB_GRID["grid_aspect"]))
    b.append(len(KNOB_GRID["noc_bpc"]))
    b.append(len(KNOB_GRID["dram_channels"]))
    return np.asarray(b, dtype=np.int32)


def _tile_slice(t: int) -> slice:
    start = 1 + t * FIELDS_PER_TILE
    return slice(start, start + FIELDS_PER_TILE)


def decode(genome: Genome, name: str = "dse") -> ChipConfig:
    """Decode a genome into a ChipConfig."""
    genome = np.asarray(genome, dtype=np.int64)
    n_types = int(genome[0]) + 1
    tiles: List[Tuple[TileTemplate, int]] = []
    for t in range(n_types):
        vals = dict(zip(_TILE_FIELDS, genome[_tile_slice(t)]))
        sfu = KNOB_GRID["sfu_mask"][vals["sfu"] % len(KNOB_GRID["sfu_mask"])]
        rows = KNOB_GRID["array_dim"][vals["rows"] % 5]
        cols = KNOB_GRID["array_dim"][vals["cols"] % 5]
        if sfu:
            rows = cols = 0
            clock = 800
            dsp_count, dsp_simd = 1, 64
        else:
            clock = 1200 if rows * cols >= 1024 else 500
            dsp_count = 2 if rows * cols >= 1024 else 1
            dsp_simd = 64
        tmpl = TileTemplate(
            name=f"t{t}" + ("s" if sfu else ""),
            rows=rows, cols=cols,
            engine=KNOB_GRID["engine"][vals["engine"] % 4],
            precisions=KNOB_GRID["precision_set"][vals["prec"] % 4],
            sparsity=KNOB_GRID["sparsity"][vals["sparsity"] % 3],
            dataflow=KNOB_GRID["dataflow"][vals["dataflow"] % 3],
            sram_kb=KNOB_GRID["sram_kb"][vals["sram"] % 7],
            dsp_count=dsp_count, dsp_simd=dsp_simd,
            sfu_mask=sfu,
            double_buffer=bool(KNOB_GRID["double_buffer"][vals["db"] % 2]),
            pipeline_depth=KNOB_GRID["pipeline_depth"][vals["pipe"] % 4],
            clock_mhz=clock,
            asym_mac=KNOB_GRID["asym_mac"][vals["asym"] % 4],
        )
        tiles.append((tmpl, int(KNOB_GRID["count"][vals["count"] % 8])))
    return ChipConfig(
        name=name, tiles=tuple(tiles),
        interconnect=KNOB_GRID["interconnect"][int(genome[IDX_ICONN]) % 4],
        dram_gbps=float(KNOB_GRID["dram_gbps"][int(genome[IDX_DRAM]) % 6]),
        torus=bool(KNOB_GRID["noc_topology"][int(genome[IDX_TOPO]) % 2]),
        grid_aspect=float(KNOB_GRID["grid_aspect"][int(genome[IDX_ASPECT]) % 3]),
        noc_bytes_per_cycle=float(KNOB_GRID["noc_bpc"][int(genome[IDX_NOC_BPC]) % 4]),
        dram_channels=int(KNOB_GRID["dram_channels"][int(genome[IDX_DRAM_CH]) % 4]),
    )


def _family_fixup(genomes: np.ndarray, family: str) -> np.ndarray:
    """Constrain genomes to an architecture-family stratum (§4.5)."""
    g = genomes
    if family == "homo":
        # iso-knob homogeneous baseline (§4.3): N identical FP16+INT8 MAC
        # tiles — the commercial-NPU template the savings are measured
        # against, on the stock mesh/1-channel interconnect
        g[:, 0] = 0
        for idx, v in INTERCONNECT_GENE_DEFAULTS.items():
            g[:, idx] = v
        sl = _tile_slice(0)
        g[:, sl.start + _TILE_FIELDS.index("sfu")] = 0
        g[:, sl.start + _TILE_FIELDS.index("prec")] = _HOMO_PREC_IDX
        # LNL-class baseline (§3.1): no sparsity skipping, no asym MACs
        g[:, sl.start + _TILE_FIELDS.index("sparsity")] = 0
        g[:, sl.start + _TILE_FIELDS.index("asym")] = 0
    elif family == "hetero_bl":
        g[:, 0] = 1
        for t in range(2):
            g[:, _tile_slice(t)][:, _TILE_FIELDS.index("sfu")] = 0
    else:  # hetero_bls: 3 types, third is Special-Function
        g[:, 0] = 2
        for t in range(2):
            g[:, _tile_slice(t)][:, _TILE_FIELDS.index("sfu")] = 0
        sfu_col = 1 + 2 * FIELDS_PER_TILE + _TILE_FIELDS.index("sfu")
        # force a non-empty SFU set on the third type
        g[:, sfu_col] = np.where(g[:, sfu_col] == 0,
                                 len(KNOB_GRID["sfu_mask"]) - 1, g[:, sfu_col])
    return g


def random_genomes(rng: np.random.Generator, n: int,
                   family: Optional[str] = None) -> np.ndarray:
    """Uniform random genomes, optionally constrained to a family stratum."""
    bounds = genome_bounds()
    g = (rng.random((n, GENOME_LEN)) * bounds).astype(np.int32)
    if family is not None:
        g = _family_fixup(g, family)
    return g


_GROWABLE = tuple(_TILE_FIELDS.index(f) for f in ("count", "rows", "cols", "sram"))


_BOUNDS_CACHE = genome_bounds()


def sample_in_bracket(rng: np.random.Generator, n: int, family: str,
                      bracket: float, area_fn, max_repair: int = 24,
                      max_attempts_per_sample: int = 12) -> np.ndarray:
    """Stratified sampling (paper §4.5): draw genomes and repair them into
    the (bracket/2, bracket] area band by growing/shrinking the structural
    genes (tile count, array dims, SRAM).  ``area_fn(genome) -> mm^2``.

    Some strata are unreachable (a single-type Homo chip tops out near
    ~220 mm^2 on the paper's knob grid): after the attempt budget, the
    largest-area genome seen is accepted with area <= bracket, so the
    800 mm^2 homogeneous baseline is simply "the biggest homo chip" —
    consistent with the paper's iso-area comparison semantics.
    """
    lo, hi = bracket / 2.0, bracket
    bounds = _BOUNDS_CACHE
    out = []
    while len(out) < n:
        best_fallback, best_area = None, -1.0
        accepted = False
        for _ in range(max_attempts_per_sample):
            g = random_genomes(rng, 1, family=family)[0]
            n_types = int(g[0]) + 1
            for _ in range(max_repair):
                a = area_fn(g)
                if lo < a <= hi:
                    out.append(g)
                    accepted = True
                    break
                if a <= hi and a > best_area:
                    best_fallback, best_area = g.copy(), a
                t = int(rng.integers(0, n_types))
                gene = 1 + t * FIELDS_PER_TILE + _GROWABLE[int(rng.integers(0, 4))]
                if a > hi and g[gene] > 0:
                    g[gene] -= 1
                elif a <= lo and g[gene] < bounds[gene] - 1:
                    g[gene] += 1
                else:
                    cg = 1 + t * FIELDS_PER_TILE
                    if a > hi and g[cg] > 0:
                        g[cg] -= 1
                    elif a <= lo and g[cg] < bounds[cg] - 1:
                        g[cg] += 1
            if accepted:
                break
        if not accepted:
            if best_fallback is None:
                best_fallback = random_genomes(rng, 1, family=family)[0]
            out.append(best_fallback)
    return np.asarray(out[:n])
