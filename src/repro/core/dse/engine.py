"""Cache-aware DSE evaluation engine — the single hot path of the search.

Every search frontend (stratified sweep, GA refinement, Bayesian
optimization, genome hillclimb) funnels its candidate scoring through one
``EvalEngine``.  The engine owns three caches and one scaling axis:

1. **Workload preparation cache** — ``prepare_workload(build(name))``
   (compiler passes 1-2 + SoA tensorization) runs once per
   ``(workload, precision/fusion setting)`` per process, not once per
   batch per generation.  Shared module-wide via an LRU.
2. **Genome memoization** — results are keyed on the genome's integer
   content.  The GA's elites, duplicate children, and genomes repeated
   across seeds / brackets / rounds are never re-simulated.  Safe because
   the jitted batch evaluator is vmapped element-wise: a config's result
   is bitwise identical regardless of the batch it rides in (pinned by
   tests/test_engine.py).
3. **Vectorized genome→SoA decoding** — ``genomes_to_configs`` stacks the
   ``prepare_configs`` arrays directly from the integer genomes with pure
   numpy, without materializing per-genome Python ``ChipConfig`` /
   ``TileTemplate`` objects in the hot loop.  Bitwise parity with
   ``prepare_configs([decode(g)])`` is pinned by tests/test_engine.py;
   the reference ``decode()`` stays the finalist re-scoring path.
4. **Candidate-axis sharding** — with ``shard=True`` and more than one
   JAX device, the (B, MAX_TILES) config arrays are placed with a
   ``NamedSharding`` over the batch axis
   (``repro.launch.mesh.candidate_sharding``), so the sweep scales
   across whatever devices exist; on one device it is a no-op.  The
   sharding covers every evaluation path — the ``batch_eval`` scan AND
   the compile-free batched mapper+executor; ``_pad_size`` rounds batch
   shapes up to a mesh-size multiple (after bucket rounding) so uneven
   populations never fall back to per-device replication.

**Evaluation backends.**  Cache misses are simulated by one of four
backends sharing one set of cost formulas (``simulator.costs``):

* ``"scan"`` (default) — ``batch_eval``'s fused compile+simulate scan:
  exact orchestrator semantics but an in-scan greedy re-derivation of
  the Eq. 1-3 mapping (epsilon tie-breaks, ragged-remainder-free
  splits).  Retained as the approximate-search baseline;
* ``"exact"`` (the *search* grade of the exact path) — the
  class-specialized single-scan kernel
  (``compiler.batched_mapper.search_and_simulate``): exact Eq. 1-3
  mapping fused with exact plan execution in ONE scan, with only the
  op's class sub-models evaluated per step.  Metrics are bitwise equal
  to ``rescore()``, so a search running this backend never needs a
  post-hoc exact re-score — searching on an approximate objective and
  re-ranking finalists (the fidelity gap HARP-style taxonomies warn
  about) is retired for GA refinement;
* ``"batched"`` — the two-scan exact path:
  ``compiler.batched_mapper.map_and_simulate`` fuses the exact batched
  Eq. 1-3 mapping scan (placements pinned *bitwise* to ``map_graph``)
  with the vmapped/jitted ``simulator.batched`` plan executor.
  ``exact_mapper="python"`` falls back to the per-candidate
  ``map_graph`` -> ``lower_plan`` pipeline (the oracle-reference
  compile path, bitwise-identical results);
* ``"oracle"`` — ``map_graph`` + the per-candidate Python ``ChipSim``
  walk, kept as the ground truth the other two are pinned against.

Search uses the engine; finalists of approximate (``scan``) searches
are re-scored through ``rescore()`` (exact), and ``exact``-backend
searches are already exact at search time.  Every ``evaluate()`` result
carries a ``"meta"`` entry reporting the backend, the schedule mode,
and the call's cache hit/miss/skip counts.

**Schedule modes** (§3.2, the serving-vs-latency scenario axis).
``mode="latency"`` (default) scores the one-batch makespan;
``mode="throughput"`` scores the pipelined steady state — the
``latency`` column becomes the initiation interval (II), ``energy`` the
per-inference steady-state energy (leakage charged over II), and
``tops_w`` the TOPS/W at the pipelined rate.  All three backends model
both modes through the shared ``costs.pipeline_bounds`` composition
(oracle/batched at 0 rel err; the scan backend on its in-scan greedy
placements); memo entries are keyed on (mode, genome).

An optional ``keep`` predicate lets a frontend skip simulation for
genomes it will discard anyway (e.g. the GA's out-of-bracket children,
whose fitness is -inf regardless of their metrics): skipped genomes get
``inf`` latency/energy and are *not* memoized, so a later unfiltered
request still simulates them.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..arch import (KNOB_GRID, MAX_TILE_TYPES, MAX_TILES, prec_mask)
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..simulator.costs import COST_MODEL_VERSION, FIDELITIES, grid_dims
from ..simulator.orchestrator import CACHE_FRAC, SCHEDULE_MODES, noc_hops
from ..workloads import build
from .api import (BACKENDS, EngineConfig, META_VERSION, context_digest)
from .batch_eval import (_CHIP_KEYS, _TILE_KEYS, batch_evaluate,
                         prepare_configs, prepare_workload)
from .encoding import (FIELDS_PER_TILE, GENOME_LEN, IDX_ASPECT, IDX_DRAM,
                       IDX_DRAM_CH, IDX_ICONN, IDX_NOC_BPC, IDX_TOPO,
                       _TILE_FIELDS, decode)
from .store import MemoryLRUStore, ResultStore, TieredStore

__all__ = ["EvalEngine", "EngineStats", "EngineConfig",
           "NonFiniteMetricsError", "genomes_to_configs", "genome_areas",
           "canonical_genomes", "prepared_workload", "BACKENDS",
           "SCHEDULE_MODES"]


class NonFiniteMetricsError(RuntimeError):
    """A freshly simulated metric row contained NaN (or a non-finite
    TOPS/W) — raised *before* the row can enter any memo, store, or
    Pareto front, naming the offending canonical genome.  ``retryable``
    because the poisoned batch was never memoized: a retry re-simulates
    it cleanly when the corruption was transient (the chaos suite's
    injected-NaN case)."""

    retryable = True

    def __init__(self, canon: np.ndarray, mode: str,
                 row: Tuple[np.ndarray, np.ndarray, np.ndarray]):
        self.canon = np.asarray(canon, np.int64).copy()
        self.mode = str(mode)
        self.row = tuple(np.asarray(a, np.float64).copy() for a in row)
        super().__init__(
            f"non-finite metrics for canonical genome "
            f"{self.canon.tolist()} (mode={self.mode}): lat="
            f"{self.row[0].tolist()} en={self.row[1].tolist()} "
            f"tops_w={self.row[2].tolist()}; pass nonfinite='skip' to "
            f"score such rows -inf instead")

# metric keys each §3.2 schedule mode scores on: latency-critical
# deployment uses the one-batch makespan; serving (throughput) uses the
# pipelined steady state — initiation interval, per-inference energy with
# leakage charged over II, and steady-state achieved TOPS
_MODE_KEYS = {
    "latency": ("latency_s", "energy_pj", "achieved_tops"),
    "throughput": ("ii_s", "energy_ss_pj", "achieved_tops_ss"),
}


@functools.lru_cache(maxsize=128)
def _prepared_graph(name: str, aggressive_int4: bool = False,
                    enable_fusion: bool = True):
    """Config-independent compiler passes 1-2 on one workload, cached so
    the exact backends re-run only the per-chip mapping.  Callers must
    treat the returned graph as read-only (map_graph does)."""
    import copy as _copy
    from ..compiler.fusion import fuse
    from ..compiler.precision import assign_precision
    g = _copy.deepcopy(build(name))
    g = assign_precision(g, aggressive_int4=aggressive_int4)
    if enable_fusion:
        g = fuse(g)
    return g


# =============================================================================
# workload preparation cache (cache 1)
# =============================================================================

@functools.lru_cache(maxsize=128)
def prepared_workload(name: str, aggressive_int4: bool = False,
                      enable_fusion: bool = True) -> Dict[str, np.ndarray]:
    """Config-independent compile of one workload, cached process-wide.
    Callers must treat the returned arrays as read-only."""
    return prepare_workload(build(name), aggressive_int4=aggressive_int4,
                            enable_fusion=enable_fusion)


# =============================================================================
# vectorized genome -> SoA config stacking (cache 3's fast path)
# =============================================================================
# Grid lookup tables.  Index order matches KNOB_GRID, and the modulo used
# per field matches decode() exactly.

_ARRAY_DIM = np.asarray(KNOB_GRID["array_dim"], np.float64)
_SRAM_KB = np.asarray(KNOB_GRID["sram_kb"], np.float64)
_COUNT = np.asarray(KNOB_GRID["count"], np.int64)
_SFU = np.asarray(KNOB_GRID["sfu_mask"], np.float64)
_ENGINE = np.asarray([int(e) for e in KNOB_GRID["engine"]], np.float64)
_SPARSITY = np.asarray([int(s) for s in KNOB_GRID["sparsity"]], np.float64)
_DATAFLOW = np.asarray([int(d) for d in KNOB_GRID["dataflow"]], np.float64)
_PIPE = np.asarray(KNOB_GRID["pipeline_depth"], np.float64)
_DB = np.asarray([float(b) for b in KNOB_GRID["double_buffer"]], np.float64)
_ASYM = np.asarray([int(a) for a in KNOB_GRID["asym_mac"]], np.float64)
_PREC_MASK = np.asarray([prec_mask(sorted(s))
                         for s in KNOB_GRID["precision_set"]], np.float64)
_PREC_MAX = np.asarray([int(max(s, key=int))
                        for s in KNOB_GRID["precision_set"]], np.int64)
_DRAM = np.asarray(KNOB_GRID["dram_gbps"], np.float64)
_ICONN = [ic for ic in KNOB_GRID["interconnect"]]
# interconnect-structure gene grids (PR 9 topology genes)
_TOPO = np.asarray([float(b) for b in KNOB_GRID["noc_topology"]], np.float64)
_ASPECT = np.asarray(KNOB_GRID["grid_aspect"], np.float64)
_NOC_BPC = np.asarray(KNOB_GRID["noc_bpc"], np.float64)
_DRAM_CH = np.asarray(KNOB_GRID["dram_channels"], np.float64)
# hop counts tabulated over (interconnect, num_tiles): 4 x (MAX_TILES+1)
_HOPS_TABLE = np.asarray(
    [[float(noc_hops(ic, max(n, 1))) for n in range(MAX_TILES + 1)]
     for ic in _ICONN], np.float64)

_FIELD_COL = {f: i for i, f in enumerate(_TILE_FIELDS)}


def _tile_cols(genomes: np.ndarray, t: int, field: str) -> np.ndarray:
    return genomes[:, 1 + t * FIELDS_PER_TILE + _FIELD_COL[field]]


def _per_type_values(genomes: np.ndarray, calib: CalibrationTable):
    """(B, MAX_TILE_TYPES) arrays of per-tile-type template values,
    replicating decode()'s knob lookups (including its modulo wrapping) and
    tile_area()'s arithmetic term-for-term so parity is bitwise."""
    B = len(genomes)
    T = MAX_TILE_TYPES
    v: Dict[str, np.ndarray] = {}
    f64 = lambda a: np.asarray(a, np.float64)

    sfu_idx = np.stack([_tile_cols(genomes, t, "sfu") % len(_SFU)
                        for t in range(T)], axis=1)
    sfu = _SFU[sfu_idx]
    special = sfu > 0

    rows = np.stack([_ARRAY_DIM[_tile_cols(genomes, t, "rows") % 5]
                     for t in range(T)], axis=1)
    cols = np.stack([_ARRAY_DIM[_tile_cols(genomes, t, "cols") % 5]
                     for t in range(T)], axis=1)
    rows = np.where(special, 0.0, rows)
    cols = np.where(special, 0.0, cols)
    big = rows * cols >= 1024.0
    v["rows"], v["cols"] = rows, cols
    v["num_macs"] = rows * cols
    v["clock_mhz"] = np.where(special, 800.0, np.where(big, 1200.0, 500.0))
    v["dsp_count"] = np.where(special, 1.0, np.where(big, 2.0, 1.0))
    v["dsp_simd"] = np.full((B, T), 64.0)
    v["sfu_mask"] = sfu
    v["sfu_parallel"] = np.full((B, T), 16.0)
    v["sram_bpc"] = np.full((B, T), 8 * 16.0)   # default sram_banks=8

    v["engine"] = np.stack([_ENGINE[_tile_cols(genomes, t, "engine") % 4]
                            for t in range(T)], axis=1)
    prec_idx = np.stack([_tile_cols(genomes, t, "prec") % 4
                         for t in range(T)], axis=1)
    v["prec_mask"] = _PREC_MASK[prec_idx]
    max_prec = _PREC_MAX[prec_idx]
    v["max_prec"] = f64(max_prec)
    v["sparsity"] = np.stack([_SPARSITY[_tile_cols(genomes, t, "sparsity") % 3]
                              for t in range(T)], axis=1)
    v["dataflow"] = np.stack([_DATAFLOW[_tile_cols(genomes, t, "dataflow") % 3]
                              for t in range(T)], axis=1)
    v["sram_kb"] = np.stack([_SRAM_KB[_tile_cols(genomes, t, "sram") % 7]
                             for t in range(T)], axis=1)
    v["double_buffer"] = np.stack([_DB[_tile_cols(genomes, t, "db") % 2]
                                   for t in range(T)], axis=1)
    v["pipeline_depth"] = np.stack([_PIPE[_tile_cols(genomes, t, "pipe") % 4]
                                    for t in range(T)], axis=1)
    v["asym_mac"] = np.stack([_ASYM[_tile_cols(genomes, t, "asym") % 4]
                              for t in range(T)], axis=1)
    v["cache_cap"] = v["sram_kb"] * 1024.0 * CACHE_FRAC
    v["dsp_lanes"] = v["dsp_count"] * v["dsp_simd"]
    v["clock_hz"] = v["clock_mhz"] * 1e6

    # tile_area (Eq. 7), same term order as simulator.area.area_breakdown
    a_mac_mm2 = np.asarray(calib.a_mac_mm2, np.float64)
    eng_a = np.asarray(calib.engine_a_mult, np.float64)
    sp_a = np.asarray(calib.sparsity_a_mult, np.float64)
    eng_idx = np.asarray(v["engine"], np.int64)
    sp_idx = np.asarray(v["sparsity"], np.int64)
    a_mac_unit = a_mac_mm2[max_prec] * eng_a[eng_idx]
    a_mac = v["num_macs"] * a_mac_unit * sp_a[sp_idx]
    a_sram = v["sram_kb"] * calib.a_sram_mm2_per_kb
    a_dsp = v["dsp_count"] * v["dsp_simd"] * calib.a_dsp_mm2_per_lane
    sfu_i = np.asarray(sfu, np.int64)
    a_spec = np.where(sfu_i & 1, calib.a_fft_mm2, 0.0)
    a_spec = a_spec + np.where(sfu_i & 2, calib.a_lif_mm2, 0.0)
    a_spec = a_spec + np.where(sfu_i & 4, calib.a_poly_mm2, 0.0)
    a_ports = calib.a_ports_base_mm2 \
        + (rows + cols) * calib.a_ports_per_lane_mm2
    v["area_mm2"] = a_mac + a_sram + a_dsp + a_spec + a_ports

    counts = np.stack([_COUNT[_tile_cols(genomes, t, "count") % 8]
                       for t in range(T)], axis=1)
    n_types = (genomes[:, 0] + 1)[:, None]  # decode: genome[0] + 1
    counts = np.where(np.arange(T)[None, :] < n_types, counts, 0)
    v["counts"] = counts
    return v


def genomes_to_configs(genomes: np.ndarray,
                       calib: CalibrationTable = DEFAULT_CALIB
                       ) -> Dict[str, Dict[str, np.ndarray]]:
    """Vectorized equivalent of ``prepare_configs([decode(g) for g in
    genomes], calib)`` — bitwise identical output, no per-genome Python
    object materialization."""
    genomes = np.asarray(genomes, dtype=np.int64).reshape(-1, GENOME_LEN)
    B = len(genomes)
    v = _per_type_values(genomes, calib)
    counts = v["counts"]                        # (B, T) ints
    starts = np.zeros_like(counts)
    starts[:, 1:] = np.cumsum(counts, axis=1)[:, :-1]
    ends = starts + counts

    slots = np.arange(MAX_TILES)                # (S,)
    # (B, T, S) membership of each instance slot in each tile type
    member = (slots[None, None, :] >= starts[:, :, None]) \
        & (slots[None, None, :] < ends[:, :, None])

    tile_f = {}
    for f in ("num_macs", "rows", "cols", "engine", "prec_mask", "asym_mac",
              "sparsity", "dataflow", "sram_kb", "dsp_lanes", "dsp_count",
              "sfu_mask", "sfu_parallel", "double_buffer", "pipeline_depth",
              "clock_hz", "cache_cap", "sram_bpc", "area_mm2", "max_prec"):
        # exactly one membership per occupied slot -> the masked sum is the
        # per-type value itself, bit-for-bit
        tile_f[f] = np.sum(np.where(member, v[f][:, :, None], 0.0), axis=1)
    tile_f["exists"] = member.any(axis=1).astype(np.float64)

    num_tiles = counts.sum(axis=1)              # (B,) ints
    chip_f = {f: np.zeros(B) for f in _CHIP_KEYS}
    chip_f["dram_gbps"] = _DRAM[genomes[:, IDX_DRAM] % 6].copy()
    iconn_idx = np.asarray(genomes[:, IDX_ICONN] % 4)
    chip_f["hops"] = _HOPS_TABLE[iconn_idx, num_tiles]
    chip_f["noc_bpc"] = _NOC_BPC[genomes[:, IDX_NOC_BPC] % 4].copy()
    chip_f["noc_base_cycles"] = np.full(B, 8.0)  # ChipConfig defaults
    chip_f["ref_clock_hz"] = np.full(B, 1000 * 1e6)
    # interconnect-structure genes (decode()'s knob lookups, vectorized)
    chip_f["torus"] = _TOPO[genomes[:, IDX_TOPO] % 2].copy()
    chip_f["dram_channels"] = _DRAM_CH[genomes[:, IDX_DRAM_CH] % 4].copy()
    aspect = _ASPECT[genomes[:, IDX_ASPECT] % 3]
    gw, gh = grid_dims(np, np.asarray(num_tiles, np.float64), aspect)
    chip_f["grid_w"], chip_f["grid_h"] = gw, gh

    # peak_tops: sequential per-instance sum, matching prepare_configs
    term = tile_f["num_macs"] * tile_f["clock_hz"]
    acc = np.zeros(B)
    for s in range(MAX_TILES):
        acc = acc + term[:, s]
    chip_f["peak_tops"] = acc / 1e12

    # chip_area: per-type tile_area * count summed in type order + NoC
    # (router/link width + torus scale) + extra DRAM-channel PHYs —
    # term-for-term simulator.area.chip_area
    area = np.zeros(B)
    for t in range(MAX_TILE_TYPES):
        area = area + v["area_mm2"][:, t] * counts[:, t]
    noc_scale = (0.5 + 0.5 * chip_f["noc_bpc"] / 64.0) \
        * np.where(chip_f["torus"] > 0, 1.25, 1.0)
    area = area + num_tiles * calib.a_noc_mm2_per_tile * noc_scale
    chip_f["chip_area"] = area \
        + (chip_f["dram_channels"] - 1) * calib.a_dram_phy_mm2
    return {"tile": tile_f, "chip": chip_f}


def genome_areas(genomes: np.ndarray,
                 calib: CalibrationTable = DEFAULT_CALIB) -> np.ndarray:
    """(N,) chip areas straight from genomes (== chip_area(decode(g)))."""
    return genomes_to_configs(genomes, calib)["chip"]["chip_area"]


_SFU_COL = _FIELD_COL["sfu"]
# genes decode() ignores on a Special-Function tile (rows/cols are forced
# to 0) plus the MAC-path knobs whose values only feed terms that a
# zero-MAC tile multiplies or gates away (engine/precision/sparsity/
# dataflow/asym/pipeline) — bitwise inertness is pinned by
# tests/test_engine.py::test_special_tile_inert_genes
_SPECIAL_INERT_COLS = tuple(
    _FIELD_COL[f] for f in ("rows", "cols", "engine", "prec", "sparsity",
                            "dataflow", "asym", "pipe"))
_PREC_COL = _FIELD_COL["prec"]
_ASYM_COL = _FIELD_COL["asym"]
# asym_mac acts only through supports_precision, so per precision-set the
# four variants collapse into equivalence classes (row = prec gene, col =
# asym gene): {INT8} gains INT4 from W4A8/W2A8 and nothing from W4A16;
# {INT4,INT8} and the full set gain nothing; {INT8,FP16} gains INT4 from
# any variant.  Pinned bitwise by tests/test_engine.py.
_ASYM_CANON = np.asarray([[0, 1, 1, 0],
                          [0, 0, 0, 0],
                          [0, 1, 1, 1],
                          [0, 0, 0, 0]], np.int64)


def canonical_genomes(genomes: np.ndarray) -> np.ndarray:
    """Zero every don't-care gene so genomes that decode() maps to the
    same chip (or to chips with bitwise-identical metrics) share one memo
    entry: the tile-type blocks beyond ``n_tile_types``, and the inert
    genes of Special-Function tiles.  Crossover and mutation constantly
    touch these genes — without canonicalization every such child looks
    novel and gets re-simulated."""
    g = np.asarray(genomes, dtype=np.int64).reshape(-1, GENOME_LEN).copy()
    n_types = g[:, 0] + 1
    for t in range(MAX_TILE_TYPES):
        base = 1 + t * FIELDS_PER_TILE
        inactive = t >= n_types
        block = g[:, base:base + FIELDS_PER_TILE]
        g[:, base:base + FIELDS_PER_TILE] = \
            np.where(inactive[:, None], 0, block)
        special = (_SFU[g[:, base + _SFU_COL] % len(_SFU)] > 0) & ~inactive
        for col in _SPECIAL_INERT_COLS:
            g[:, base + col] = np.where(special, 0, g[:, base + col])
        g[:, base + _ASYM_COL] = _ASYM_CANON[g[:, base + _PREC_COL] % 4,
                                             g[:, base + _ASYM_COL] % 4]
    return g


# =============================================================================
# the engine
# =============================================================================

@dataclasses.dataclass
class EngineStats:
    """Counters over the engine's lifetime.  ``requests`` counts genome
    scoring requests (one per genome per evaluate() call); a request is a
    hit (memoized), a skip (rejected by the ``keep`` predicate), or a
    miss (simulated now, on every workload)."""

    requests: int = 0
    hits: int = 0
    skips: int = 0
    misses: int = 0
    eval_seconds: float = 0.0
    workloads: int = 0
    # fused miss-batch dispatches: one per simulated micro-batch (the unit
    # the serving layer's cross-request coalescing reduces)
    dispatches: int = 0

    def hit_rate(self) -> float:
        return self.hits / max(self.requests, 1)

    def throughput(self) -> float:
        """Scored (config x workload) pairs per second of evaluate() time,
        counting cache hits as scored work (that is the point)."""
        pairs = (self.hits + self.misses) * self.workloads
        return pairs / max(self.eval_seconds, 1e-12)


# sentinel distinguishing "caller passed this legacy kwarg" (deprecation
# shim fires) from "default" on EvalEngine.__init__
_UNSET = object()


def _bucket(n: int, step: int = 4, floor: int = 16) -> int:
    """Pad batch sizes to multiples of ``step`` (>= ``floor``): CPU
    vectorization of the vmapped scan saturates around B=16, so cost is
    ~linear in B beyond that and coarse power-of-two padding would waste
    up to 2x the work.  The bounded shape set keeps jit retraces finite
    (see ``warmup``)."""
    return max(((n + step - 1) // step) * step, floor)


class EvalEngine:
    """Unified cached scorer: genomes x fixed workload list -> metrics.

    ``evaluate`` has the same output contract as the legacy
    ``sweep.evaluate_genomes``: dict of ``latency`` (N, W), ``energy``
    (N, W), ``tops_w`` (N, W), ``area`` (N,).

    ``memoize=False`` / ``vectorized=False`` disable cache 2 / cache 3
    (the decode()-based reference path) — used by parity tests and the
    perf benchmark as the pre-refactor baseline.
    """

    def __init__(self, workloads: Sequence[str],
                 calib: CalibrationTable = DEFAULT_CALIB,
                 batch=_UNSET, memoize=_UNSET,
                 vectorized=_UNSET, shard=_UNSET,
                 aggressive_int4=_UNSET, enable_fusion=_UNSET,
                 memo_max=_UNSET, backend=_UNSET,
                 exact_mapper=_UNSET, mode=_UNSET,
                 memo_limit=_UNSET,
                 store=_UNSET,
                 nonfinite=_UNSET, fidelity=_UNSET,
                 config: Optional[EngineConfig] = None):
        # ``config=EngineConfig(...)`` is the canonical construction; the
        # per-knob kwargs are the pre-PR-9 surface, kept working behind a
        # deprecation shim (they warn, then assemble the same config).
        if memo_limit is not _UNSET:
            warnings.warn(
                "EvalEngine(memo_limit=...) is deprecated; pass "
                "config=EngineConfig(memo_max=...) (memo_limit is the "
                "pre-PR-5 alias of memo_max)", DeprecationWarning,
                stacklevel=2)
            if memo_max is not _UNSET:
                raise ValueError("pass memo_max or its legacy alias "
                                 "memo_limit, not both")
            memo_max = memo_limit
        legacy = {k: v for k, v in [
            ("batch", batch), ("memoize", memoize),
            ("vectorized", vectorized), ("shard", shard),
            ("aggressive_int4", aggressive_int4),
            ("enable_fusion", enable_fusion), ("memo_max", memo_max),
            ("backend", backend), ("exact_mapper", exact_mapper),
            ("mode", mode), ("store", store), ("nonfinite", nonfinite),
            ("fidelity", fidelity)] if v is not _UNSET}
        if config is not None:
            if legacy:
                raise ValueError(
                    f"pass config=EngineConfig(...) or the legacy per-knob "
                    f"kwargs, not both (got both config= and "
                    f"{sorted(legacy)})")
        else:
            if legacy:
                warnings.warn(
                    f"EvalEngine per-knob kwargs ({sorted(legacy)}) are "
                    f"deprecated; pass config=EngineConfig(...) instead",
                    DeprecationWarning, stacklevel=2)
            config = EngineConfig(**legacy)
        self.config = config
        self.exact_mapper = config.exact_mapper
        self.mode = config.mode
        self.fidelity = config.fidelity
        self.workloads = list(workloads)
        self.calib = calib
        self.batch = config.batch
        self.memoize = config.memoize
        self.vectorized = config.vectorized
        self.shard = config.shard
        self.aggressive_int4 = config.aggressive_int4
        self.enable_fusion = config.enable_fusion
        self.backend = config.backend
        self.nonfinite = config.nonfinite
        # rebind the locals the rest of the ctor reads off the config
        batch, shard = config.batch, config.shard
        memo_max = config.memo_max
        store = config.store
        self.stats = EngineStats(workloads=len(self.workloads))
        # genome key -> (lat (W,), en (W,), tw (W,)); areas are always
        # recomputed from the (cheap, bitwise-reproducible) config stack.
        # Bounded LRU (hits refresh recency): a paper-scale multi-seed
        # random sweep sees millions of unique genomes with near-zero
        # reuse, and an unbounded memo would hold them all for nothing.
        # The default cap holds ~6 full paper-scale GA refinements
        # (population 200 x 101 generations of novel canonical genomes
        # per (bracket, seed)) before recency eviction kicks in, so long
        # multi-seed multi-bracket runs stay bounded without evicting the
        # live refinement's working set.  >= batch so entries stored in
        # one call can't evict each other.
        explicit_cap = memo_max is not None
        self.memo_max = max(memo_max if explicit_cap else 131_072, batch)
        # Caching policy lives behind the pluggable ResultStore interface
        # (dse.store): the default is the historical in-process LRU; pass
        # a TieredStore(MemoryLRUStore(), SqliteStore(path)) to accumulate
        # exact metrics across processes/CI runs/users.  The store is
        # bound to this engine's content context (workloads x calib x
        # flags x backend fidelity x cost-model version), so persistent
        # entries can never be served across incompatible engines.
        #
        # An *explicit* memo_max combined with a caller-supplied store is
        # applied to the store's in-memory LRU tier (re-capped eagerly);
        # a store with no LRU tier to cap makes the combination an error
        # rather than a silent no-op.
        if store is None:
            self.store: ResultStore = MemoryLRUStore(self.memo_max)
        else:
            self.store = store
            if explicit_cap:
                lru = store if isinstance(store, MemoryLRUStore) else (
                    store.front if isinstance(store, TieredStore)
                    and isinstance(store.front, MemoryLRUStore) else None)
                if lru is None:
                    raise ValueError(
                        "memo_max cannot cap a store without an in-memory "
                        "LRU tier — size the store yourself and drop "
                        "memo_max, or wrap it in a TieredStore with a "
                        "MemoryLRUStore front")
                lru.resize(self.memo_max)
        self.store.bind(self.context_key())
        self._sharding = None
        if shard:
            self._sharding = self._make_sharding()
        self._shapes: set = set()   # batch sizes this engine has emitted
        self._shape_lock = threading.Lock()
        # export_memo bulk views keyed on the LRU tier's mutation stamp
        # (see _memo_stamp): a seed-boundary preload over an unchanged
        # store costs O(1) host work instead of a full dict walk
        self._export_cache: Dict[str, Tuple[tuple, Tuple[np.ndarray,
                                                         np.ndarray]]] = {}

    def context_key(self) -> bytes:
        """Digest of everything a memoized metric row depends on besides
        the (canonical genome, mode) pair the short store key carries —
        ``api.context_digest`` over this engine's config (workloads,
        calibration, compile flags, backend mapping class, NoC/DRAM
        fidelity tier, cost-model version).  Persistent stores fold this
        into their content address, so results accumulated by one engine
        are served to another exactly when every one of these
        matches."""
        return context_digest(self.workloads, self.calib,
                              self.aggressive_int4, self.enable_fusion,
                              self.backend, self.fidelity)

    @property
    def _memo(self) -> Dict[bytes, Tuple[np.ndarray, np.ndarray,
                                         np.ndarray]]:
        """Legacy view of the in-memory cache tier (PR 1-5 name): the
        LRU-ordered dict of the store's front tier, or an unshared empty
        dict when the configured store has no in-memory tier."""
        d = self.store.lru_dict()
        return d if d is not None else {}

    def _pad_size(self, n: int) -> int:
        """Batch padding: the jit bucket, rounded up — AFTER bucket
        rounding — so a sharded batch axis divides evenly across devices
        (an indivisible batch makes XLA fall back to whole-batch
        per-device replication).  Unwarmed engines reuse the smallest
        previously-emitted shape within 1.5x instead of minting a new
        one — miss counts vary every GA generation, and without this an
        unwarmed search loop would trigger a fresh XLA compile per new
        count (the shape set converges after a few generations; warmup()
        pre-populates it so padding is then always minimal).  Reused
        shapes are filtered to mesh-size multiples too, so a shape minted
        before sharding context changed can never leak back in.  The
        shape set is lock-guarded: reentrant ``score_batch`` callers
        (the evaluation service's dispatch thread racing a local caller)
        must not corrupt it."""
        pad = _bucket(n)
        ndev = self._sharding.mesh.size if self._sharding is not None else 1
        pad = ((pad + ndev - 1) // ndev) * ndev
        with self._shape_lock:
            reusable = [s for s in self._shapes
                        if pad <= s <= pad * 3 // 2 and s % ndev == 0]
            if reusable:
                return min(reusable)
            self._shapes.add(pad)
            return pad

    # ------------------------------------------------------------- sharding
    @staticmethod
    def _make_sharding():
        """NamedSharding over the candidate batch axis; None on one device."""
        from ...launch.mesh import candidate_sharding
        return candidate_sharding()

    def _shard_cfgs(self, cfgs):
        if self._sharding is None:
            return cfgs
        import jax
        put = lambda a: jax.device_put(a, self._sharding)
        return {"tile": {k: put(cfgs["tile"][k]) for k in _TILE_KEYS},
                "chip": {k: (put(cfgs["chip"][k]) if k in _CHIP_KEYS
                             else cfgs["chip"][k])
                         for k in cfgs["chip"]}}

    # ------------------------------------------------------------- plumbing
    def check_workloads(self, workloads: Sequence[str],
                        calib: Optional[CalibrationTable] = None
                        ) -> "EvalEngine":
        """Guard for shared-engine frontends: metric columns follow
        *this* engine's workload order and calibration, so a caller
        holding a different list (or passing a different calib) would get
        silently mislabeled or miscalibrated numbers."""
        if list(workloads) != self.workloads:
            raise ValueError(
                f"engine workloads {self.workloads} != caller workloads "
                f"{list(workloads)}")
        if calib is not None and calib != self.calib:
            raise ValueError("caller calib differs from the shared "
                             "engine's calib — results would not match")
        return self

    def _prepared(self, wname: str) -> Dict[str, np.ndarray]:
        return prepared_workload(wname, self.aggressive_int4,
                                 self.enable_fusion)

    def _configs(self, genomes: np.ndarray):
        if self.vectorized:
            return genomes_to_configs(genomes, self.calib)
        chips = [decode(g, f"g{i}") for i, g in enumerate(genomes)]
        return prepare_configs(chips, self.calib)

    @staticmethod
    def _key(genome: np.ndarray) -> bytes:
        return np.ascontiguousarray(genome, dtype=np.int64).tobytes()

    @staticmethod
    def _take(cfgs, idx):
        return {"tile": {k: v[idx] for k, v in cfgs["tile"].items()},
                "chip": {k: v[idx] for k, v in cfgs["chip"].items()}}

    def _simulate(self, cfgs, n: int, genomes: Optional[np.ndarray] = None,
                  mode: Optional[str] = None):
        """(n, W) lat/en/tw for the first n rows of a (possibly padded)
        config stack, through this engine's backend.  In throughput mode
        the three metrics are the steady-state surface: II (s),
        per-inference energy (pJ), and TOPS/W at the steady-state rate."""
        mode = self.mode if mode is None else mode
        self.stats.dispatches += 1
        if self.backend != "scan":
            return self._simulate_exact(genomes[:n],
                                        oracle=self.backend == "oracle",
                                        pad_to=len(cfgs["chip"]["chip_area"]),
                                        cfgs=cfgs, mode=mode)
        lkey, ekey, akey = _MODE_KEYS[mode]
        W = len(self.workloads)
        pad_n = len(cfgs["chip"]["chip_area"])
        lat = np.zeros((pad_n, W))
        en = np.zeros((pad_n, W))
        tw = np.zeros((pad_n, W))
        cfgs = self._shard_cfgs(cfgs)
        for j, wname in enumerate(self.workloads):
            res = batch_evaluate(self._prepared(wname), cfgs, self.calib,
                                 fidelity=self.fidelity)
            lat[:, j] = res[lkey]
            en[:, j] = res[ekey]
            power = res[ekey] * 1e-12 / np.maximum(res[lkey], 1e-30)
            tw[:, j] = res[akey] / np.maximum(power, 1e-30)
        return lat[:n], en[:n], tw[:n]

    def _simulate_exact(self, genomes: np.ndarray, oracle: bool = False,
                        pad_to: Optional[int] = None, cfgs=None,
                        mode: Optional[str] = None):
        """Exact scoring.  Default (``exact_mapper="batched"``): the
        compile-free path — one fused batched-mapping + plan-execution
        dispatch per workload, placements bitwise equal to ``map_graph``.
        ``exact_mapper="python"`` compiles per candidate with the real
        Python mapper instead; ``oracle=True`` additionally walks the
        per-candidate ``ChipSim``.  Unmappable (genome, workload) pairs
        score inf latency/energy on every path.  ``cfgs``, when given,
        is the caller's already-built (``pad_to``-row) config stack for
        these genomes, so ``evaluate()`` misses don't stack twice.
        ``mode`` selects the §3.2 schedule mode (plans are emitted with
        it, so every exact path scores the same steady state)."""
        from ..compiler.mapper import UnmappableError, map_graph
        from ..compiler.pipeline import lower_plan
        from ..compiler.schedule import emit_schedule
        from ..simulator.batched import simulate_plans
        from ..simulator.orchestrator import simulate as oracle_simulate

        mode = self.mode if mode is None else mode
        genomes = np.asarray(genomes, np.int64).reshape(-1, GENOME_LEN)
        n, W = len(genomes), len(self.workloads)
        if not oracle and self.exact_mapper == "batched":
            return self._simulate_exact_fused(genomes, pad_to, cfgs, mode,
                                              lean=self.backend == "exact")
        lkey, ekey, akey = _MODE_KEYS[mode]
        chips = [decode(g, f"x{i}") for i, g in enumerate(genomes)]
        lat = np.full((n, W), np.inf)
        en = np.full((n, W), np.inf)
        tw = np.zeros((n, W))
        for j, wname in enumerate(self.workloads):
            g = _prepared_graph(wname, self.aggressive_int4,
                                self.enable_fusion)
            plans, rows = [], []
            for i, chip in enumerate(chips):
                try:
                    placements = map_graph(g, chip, self.calib)
                except UnmappableError:
                    continue
                plans.append(emit_schedule(g, placements, mode=mode))
                rows.append(i)
            if not rows:
                continue
            if oracle:
                for i, plan in zip(rows, plans):
                    r = oracle_simulate(chips[i], plan, self.calib,
                                        fidelity=self.fidelity)
                    if mode == "throughput":
                        lat[i, j] = r.pipeline["ii_s"]
                        en[i, j] = r.pipeline["energy_ss_pj"]
                        a = r.pipeline["achieved_tops_ss"]
                    else:
                        lat[i, j], en[i, j] = r.latency_s, r.energy_pj
                        a = r.achieved_tops
                    power = en[i, j] * 1e-12 / max(lat[i, j], 1e-30)
                    tw[i, j] = a / max(power, 1e-30)
                continue
            sel = list(rows)
            tables = [lower_plan(p, chips[i].num_tiles)
                      for i, p in zip(rows, plans)]
            if pad_to is not None and len(sel) < pad_to:
                # repeat row 0 so the jitted executor keeps a stable batch
                # shape across calls (compile once per (bucket, max_ops))
                reps = pad_to - len(sel)
                sel = sel + [rows[0]] * reps
                tables = tables + [tables[0]] * reps
            res = simulate_plans([chips[i] for i in sel], tables, self.calib,
                                 fidelity=self.fidelity)
            for r, i in enumerate(rows):
                lat[i, j] = res[lkey][r]
                en[i, j] = res[ekey][r]
                power = res[ekey][r] * 1e-12 / max(res[lkey][r], 1e-30)
                tw[i, j] = res[akey][r] / max(power, 1e-30)
        return lat, en, tw

    def _simulate_exact_fused(self, genomes: np.ndarray,
                              pad_to: Optional[int] = None, cfgs=None,
                              mode: Optional[str] = None,
                              lean: bool = False):
        """The compile-free exact path: per workload, ONE fused
        batched-mapper + plan-executor dispatch over all candidates,
        sharded over the candidate axis when the engine shards.
        ``lean=True`` (the ``"exact"`` search backend) dispatches the
        class-specialized single-scan search kernel
        (``compiler.batched_mapper.search_and_simulate``); ``lean=False``
        (the ``"batched"`` backend and ``rescore()``) keeps the two-scan
        ``map_and_simulate`` dispatch — metrics are bitwise identical
        either way (and to the per-candidate compile path).  The
        per-workload compiler passes 1-2 + tensorization come from the
        process-wide ``prepared_workload`` cache (``self._prepared``) —
        nothing runs per (workload, candidate) on the host."""
        from ..compiler.batched_mapper import (map_and_simulate,
                                               place_configs,
                                               search_population)

        mode = self.mode if mode is None else mode
        lkey, ekey, akey = _MODE_KEYS[mode]
        n, W = len(genomes), len(self.workloads)
        lat = np.full((n, W), np.inf)
        en = np.full((n, W), np.inf)
        tw = np.zeros((n, W))
        # pad to the jit bucket (a mesh-size multiple under sharding) by
        # repeating row 0, so shapes stay stable and shards stay even
        pad = pad_to if pad_to is not None else self._pad_size(n)
        if cfgs is None:
            cfgs = self._configs(genomes)
            if pad > n:
                sel = np.concatenate([np.arange(n),
                                      np.zeros(pad - n, np.int64)])
                cfgs = self._take(cfgs, sel)
        # device placement (and sharding) once, not once per workload
        placed = place_configs(cfgs, self._sharding)
        if lean:
            # the search grade: ONE class-specialized dispatch scores the
            # batch on every workload (no per-workload host round trips),
            # fetching only the mode's metric columns
            results = search_population(
                [self._prepared(w) for w in self.workloads], cfgs,
                self.calib, placed=placed, mode=mode,
                out_keys=(lkey, ekey, akey), fidelity=self.fidelity)
        else:
            results = [map_and_simulate(self._prepared(w), cfgs, self.calib,
                                        placed=placed, mode=mode,
                                        fidelity=self.fidelity)
                       for w in self.workloads]
        for j, res in enumerate(results):
            ok = res["ok"][:n]
            l, e = res[lkey][:n], res[ekey][:n]
            lat[ok, j] = l[ok]
            en[ok, j] = e[ok]
            power = e[ok] * 1e-12 / np.maximum(l[ok], 1e-30)
            tw[ok, j] = res[akey][:n][ok] / np.maximum(power, 1e-30)
        return lat, en, tw

    # ----------------------------------------------------------- score_batch
    def score_batch(self, genomes: np.ndarray,
                    mode: Optional[str] = None) -> Dict[str, np.ndarray]:
        """The reentrant engine core: canonical (or raw) genomes in,
        exact-per-backend metrics out, one fused dispatch per padded
        micro-batch — no cache interaction, no keep predicate, no
        request/hit/miss accounting.  This is what the coalescing
        evaluation service (``repro.serve.dse_service``) drives and what
        ``evaluate()`` composes with the caching policy.

        Pure up to process-global compile caches, the engine's emitted
        shape set (lock-guarded), and the monotonic ``stats.dispatches``
        telemetry counter; concurrent callers get independent, bitwise
        batch-composition-independent results (pinned by
        tests/test_engine.py / tests/test_service.py).

        Returns ``latency``/``energy``/``tops_w`` (N, W) and ``area``
        (N,) arrays (no ``meta``: nothing request-scoped happens here).
        """
        mode = self.mode if mode is None else mode
        if mode not in SCHEDULE_MODES:
            raise ValueError(f"mode {mode!r} not in {SCHEDULE_MODES}")
        genomes = np.asarray(genomes, dtype=np.int64).reshape(-1, GENOME_LEN)
        n = len(genomes)
        cfgs = self._configs(genomes)
        area = np.asarray(cfgs["chip"]["chip_area"], np.float64).copy()
        lat = np.zeros((n, len(self.workloads)))
        en = np.zeros_like(lat)
        tw = np.zeros_like(lat)
        for s in range(0, n, self.batch):
            chunk = np.arange(s, min(s + self.batch, n))
            pad = self._pad_size(len(chunk))
            sel = np.concatenate(
                [chunk, np.full(pad - len(chunk), chunk[0], np.int64)])
            l, e, t = self._simulate(self._take(cfgs, sel), len(chunk),
                                     genomes[sel], mode=mode)
            lat[chunk], en[chunk], tw[chunk] = l, e, t
        return {"latency": lat, "energy": en, "tops_w": tw, "area": area}

    # ------------------------------------------------------------- evaluate
    def evaluate(self, genomes: np.ndarray,
                 keep: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                 mode: Optional[str] = None,
                 canonical: Optional[np.ndarray] = None
                 ) -> Dict[str, np.ndarray]:
        """Score every genome on every workload.

        ``keep(areas) -> (N,) bool`` optionally pre-filters by chip area:
        genomes with ``keep == False`` (and no memoized result) are not
        simulated and come back with inf latency/energy and zero TOPS/W.

        ``mode`` overrides the engine's §3.2 schedule mode for this call
        (``"latency"`` or ``"throughput"``).  In throughput mode the
        ``latency`` column holds the steady-state initiation interval,
        ``energy`` the per-inference steady-state energy, and ``tops_w``
        the TOPS/W at the pipelined rate; memo entries are keyed on
        (mode, genome), so the two modes never cross-contaminate.

        ``canonical`` optionally supplies the rows'
        ``canonical_genomes`` forms when the caller already computed
        them (the device GA loop canonicalizes children on device in the
        same dispatch as the genetics, so memo keys cost it no extra
        host pass).  Must be bitwise equal to
        ``canonical_genomes(genomes)`` — pinned for the device
        canonicalizer by tests/test_ga_device.py.
        """
        mode = self.mode if mode is None else mode
        if mode not in SCHEDULE_MODES:
            raise ValueError(f"mode {mode!r} not in {SCHEDULE_MODES}")
        t0 = time.perf_counter()
        pre = dataclasses.replace(self.stats)
        genomes = np.asarray(genomes, dtype=np.int64).reshape(-1, GENOME_LEN)
        n, W = len(genomes), len(self.workloads)
        lat = np.zeros((n, W))
        en = np.zeros((n, W))
        tw = np.zeros((n, W))
        cfgs = self._configs(genomes)
        area = np.asarray(cfgs["chip"]["chip_area"], np.float64).copy()
        self.stats.requests += n

        tag = mode.encode() + b":"
        canon = canonical_genomes(genomes) if canonical is None else \
            np.asarray(canonical, np.int64).reshape(-1, GENOME_LEN)
        keys = [tag + self._key(g) for g in canon]
        keep_mask = np.ones(n, bool) if keep is None else \
            np.asarray(keep(area), bool)

        miss_idx: List[int] = []
        dup_idx: List[int] = []
        seen_this_call: Dict[bytes, int] = {}
        for i, k in enumerate(keys):
            row = self.store.get(k) if self.memoize else None
            if row is not None:
                lat[i], en[i], tw[i] = row
                self.stats.hits += 1
            elif not keep_mask[i]:
                lat[i] = np.inf
                en[i] = np.inf
                self.stats.skips += 1
            elif self.memoize and k in seen_this_call:
                dup_idx.append(i)       # resolved from the first copy's row
                self.stats.hits += 1
            else:
                seen_this_call[k] = i
                miss_idx.append(i)
                self.stats.misses += 1

        # simulate misses in _bucket-padded batches (bounded jit shapes)
        nonfinite = 0
        for s in range(0, len(miss_idx), self.batch):
            chunk = miss_idx[s:s + self.batch]
            pad = self._pad_size(len(chunk))
            sel = chunk + [chunk[0]] * (pad - len(chunk))
            l, e, t = self._simulate(self._take(cfgs, np.asarray(sel)),
                                     len(chunk), genomes[np.asarray(sel)],
                                     mode=mode)
            for r, i in enumerate(chunk):
                # Guard fresh rows before they can reach the memo/store/
                # Pareto front.  Unmappable candidates legitimately score
                # (inf, inf, 0); NaN anywhere — or a non-finite TOPS/W —
                # is cost-model corruption and must not be cached.
                if (np.isnan(l[r]).any() or np.isnan(e[r]).any()
                        or np.isnan(t[r]).any() or np.isinf(t[r]).any()):
                    nonfinite += 1
                    if self.nonfinite == "raise":
                        raise NonFiniteMetricsError(
                            canon[i], mode, (l[r], e[r], t[r]))
                    # skip: score like an area-filtered candidate (-inf
                    # fitness downstream), never memoize the bad row
                    lat[i], en[i] = np.inf, np.inf
                    tw[i] = 0.0
                    continue
                lat[i], en[i], tw[i] = l[r], e[r], t[r]
                if self.memoize:
                    self.store.put(
                        keys[i], (l[r].copy(), e[r].copy(), t[r].copy()))
        # duplicates copy their first occurrence's output row directly —
        # never via the store, whose LRU bound may already have evicted the
        # entry within a single paper-scale call
        for i in dup_idx:
            j = seen_this_call[keys[i]]
            lat[i], en[i], tw[i] = lat[j], en[j], tw[j]
        self.stats.eval_seconds += time.perf_counter() - t0
        meta = {"meta_version": META_VERSION, "backend": self.backend,
                "mode": mode, "fidelity": self.fidelity, "requests": n,
                "hits": self.stats.hits - pre.hits,
                "misses": self.stats.misses - pre.misses,
                "skips": self.stats.skips - pre.skips,
                "nonfinite": nonfinite,
                "dispatches": self.stats.dispatches - pre.dispatches}
        meta["hit_rate"] = meta["hits"] / max(n, 1)
        return {"latency": lat, "energy": en, "tops_w": tw, "area": area,
                "meta": meta}

    def rescore(self, genomes: np.ndarray, oracle: bool = False,
                mode: Optional[str] = None) -> Dict[str, np.ndarray]:
        """Exact re-scoring of finalists through the engine's exact
        mapper — by default the compile-free batched Eq. 1-3 pass fused
        with the batched plan executor (bitwise ``map_graph`` placements,
        no per-candidate compile); ``exact_mapper="python"`` compiles
        per candidate instead, and ``oracle=True`` walks the Python
        ChipSim.  Bypasses the memo — results are exact regardless of
        this engine's search backend.  ``mode`` overrides the engine's
        schedule mode (throughput: II / steady-state energy / steady-state
        TOPS/W in the latency/energy/tops_w columns)."""
        mode = self.mode if mode is None else mode
        if mode not in SCHEDULE_MODES:
            raise ValueError(f"mode {mode!r} not in {SCHEDULE_MODES}")
        genomes = np.asarray(genomes, dtype=np.int64).reshape(-1, GENOME_LEN)
        lat, en, tw = self._simulate_exact(genomes, oracle=oracle, mode=mode)
        mapper = "python" if oracle else self.exact_mapper
        return {"latency": lat, "energy": en, "tops_w": tw,
                "area": self.areas(genomes),
                "meta": {"meta_version": META_VERSION,
                         "backend": "oracle" if oracle else "batched",
                         "mapper": mapper, "mode": mode,
                         "fidelity": self.fidelity,
                         "requests": len(genomes), "hits": 0,
                         "misses": len(genomes), "skips": 0,
                         "hit_rate": 0.0}}

    # --------------------------------------------------- device-memo sync
    def export_memo(self, mode: Optional[str] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Bulk view of the store's in-memory tier for one schedule mode:
        ``(canon (N, GENOME_LEN) int64, rows (N, 3, W) float64)`` in LRU
        order — what ``dse.device_memo.memo_from_store`` preloads into
        the device-resident table at a seed boundary.

        Only the enumerable LRU tier exports (a persistent sqlite back
        tier is content-addressed — its keys are digests, not genomes —
        so its entries surface here only after promotion into the
        front); an engine whose store has no in-memory tier exports
        empty.  No stats or recency side effects.

        Bulk views are cached per mode against the tier's mutation
        stamp (accepted puts + evictions), so back-to-back exports over
        an unchanged store — a pipeline replaying against a warm
        persistent store — skip the dict walk.  Callers must treat the
        returned arrays as read-only.
        """
        mode = self.mode if mode is None else mode
        if mode not in SCHEDULE_MODES:
            raise ValueError(f"mode {mode!r} not in {SCHEDULE_MODES}")
        stamp = self._memo_stamp()
        cached = self._export_cache.get(mode)
        if stamp is not None and cached is not None and cached[0] == stamp:
            return cached[1]
        tag = mode.encode() + b":"
        W = len(self.workloads)
        d = self.store.lru_dict()
        genomes: List[np.ndarray] = []
        rows: List[np.ndarray] = []
        for k, row in (list(d.items()) if d else ()):
            if not k.startswith(tag):
                continue
            genomes.append(np.frombuffer(k[len(tag):], np.int64))
            rows.append(np.stack([np.asarray(a, np.float64) for a in row]))
        if not genomes:
            out = (np.zeros((0, GENOME_LEN), np.int64),
                   np.zeros((0, 3, W), np.float64))
        else:
            out = (np.asarray(genomes, np.int64),
                   np.asarray(rows, np.float64))
        if stamp is not None:
            self._export_cache[mode] = (stamp, out)
        return out

    def _memo_stamp(self) -> Optional[tuple]:
        """Mutation stamp of the store's enumerable LRU tier: changes
        exactly when the tier's *membership* changes (accepted puts and
        evictions; recency reorders don't count — export order is not
        load-bearing, every consumer is order-independent).  None when
        the tier keeps no stats, which disables the export cache."""
        front = getattr(self.store, "front", self.store)
        stats = getattr(front, "stats", None)
        if stats is None:
            return None
        return (id(front), stats.puts, stats.evictions)

    def import_memo(self, canon: np.ndarray, rows: np.ndarray,
                    mode: Optional[str] = None) -> int:
        """Offer drained device-memo entries to the host store
        (put-if-absent; a persistent tier makes them durable).  ``canon``:
        (N, GENOME_LEN) canonical genomes; ``rows``: (N, 3, W) metric
        rows, bitwise the values ``evaluate`` would have stored.  Returns
        the number of rows offered."""
        mode = self.mode if mode is None else mode
        if mode not in SCHEDULE_MODES:
            raise ValueError(f"mode {mode!r} not in {SCHEDULE_MODES}")
        tag = mode.encode() + b":"
        canon = np.asarray(canon, np.int64).reshape(-1, GENOME_LEN)
        rows = np.asarray(rows, np.float64)
        if rows.shape[:1] != (len(canon),) or rows.ndim != 3 \
                or rows.shape[1] != 3:
            raise ValueError(f"rows shape {rows.shape} does not match "
                             f"{len(canon)} genomes x (3, W)")
        for g, r in zip(canon, rows):
            self.store.put(tag + self._key(g),
                           (r[0].copy(), r[1].copy(), r[2].copy()))
        return len(canon)

    def reserve_shapes(self, max_batch: int = 64) -> None:
        """Pre-register the search-loop batch buckets in the emitted-shape
        set WITHOUT compiling, so ``_pad_size`` always pads minimally
        instead of reusing a previously-minted larger shape (up to 1.5x
        wasted rows per dispatch).  Each shape still jit-compiles lazily
        on first use — the device GA loop calls this because its jits are
        process-global and its per-generation miss counts sweep the whole
        bucket range; ``warmup()`` remains the compile-ahead variant."""
        for b in range(16, _bucket(max_batch) + 4, 4):
            self._pad_size(b)

    def warmup(self, buckets: Sequence[int] = tuple(range(16, 68, 4))
               ) -> None:
        """Pre-compile the jitted evaluator for the search-loop batch
        shapes (miss batches up to a GA-population-sized 64), so loop
        latency is steady-state from the first generation and padding is
        always minimal.  One-off larger batches (e.g. a whole sweep)
        compile once on first use, exactly as the pre-refactor path did."""
        g = np.zeros((1, GENOME_LEN), np.int64)
        cfgs = self._configs(g)
        for b in sorted({self._pad_size(b) for b in buckets}):
            self._simulate(self._take(cfgs, np.zeros(b, np.int64)), 1,
                           np.repeat(g, b, axis=0))

    def areas(self, genomes: np.ndarray) -> np.ndarray:
        """Chip areas only — no simulation, no cache interaction.  The
        scalar decode path wins below ~batch 16 (numpy dispatch overhead),
        and both paths are bitwise identical, so pick by batch size."""
        genomes = np.asarray(genomes, dtype=np.int64).reshape(-1, GENOME_LEN)
        if self.vectorized and len(genomes) >= 16:
            return genome_areas(genomes, self.calib)
        from ..simulator.area import chip_area
        return np.asarray([chip_area(decode(g), self.calib)
                           for g in genomes])
