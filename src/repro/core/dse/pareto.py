"""Pareto-front utilities for the (energy, area, latency) PEA triple
(paper §3.5, §4.2 — lower is better on every axis)."""
from __future__ import annotations

from typing import Sequence

import numpy as np

__all__ = ["pareto_front", "pareto_mask"]


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows.  ``points``: (N, D), lower is
    better on every column.  O(N^2) but N is the finalist set, not the
    sweep."""
    pts = np.asarray(points, dtype=np.float64)
    n = len(pts)
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        if not mask[i]:
            continue
        dominates = np.all(pts <= pts[i], axis=1) & np.any(pts < pts[i], axis=1)
        if np.any(dominates & mask):
            mask[i] = False
    return mask


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Indices of the Pareto-optimal rows, sorted by the first column."""
    idx = np.nonzero(pareto_mask(points))[0]
    return idx[np.argsort(np.asarray(points)[idx, 0])]
