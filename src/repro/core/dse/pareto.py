"""Pareto-front utilities for the (energy, area, latency) PEA triple
(paper §3.5, §4.2 — lower is better on every axis)."""
from __future__ import annotations

from typing import Sequence

import numpy as np

__all__ = ["pareto_front", "pareto_mask", "pareto_mask_device"]


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows.  ``points``: (N, D), lower is
    better on every column.  O(N^2) but N is the finalist set, not the
    sweep.

    Bitwise-identical rows are mutually non-dominating, so without a
    dedupe every copy would survive — and cumulative fronts (the
    service's streamed Pareto updates, the pipeline's cross-seed merge)
    would grow with each repeated candidate.  Only the first copy of a
    duplicate row is kept.
    """
    pts = np.asarray(points, dtype=np.float64)
    n = len(pts)
    mask = np.ones(n, dtype=bool)
    if n == 0:
        return mask
    # keep-first dedupe before the dominance loop
    _, first = np.unique(pts, axis=0, return_index=True)
    keep_first = np.zeros(n, dtype=bool)
    keep_first[first] = True
    mask &= keep_first
    for i in range(n):
        if not mask[i]:
            continue
        dominates = np.all(pts <= pts[i], axis=1) & np.any(pts < pts[i], axis=1)
        if np.any(dominates & mask):
            mask[i] = False
    return mask


def pareto_mask_device(points) -> "jnp.ndarray":
    """``pareto_mask`` as a vectorized jnp kernel — the pipeline's
    device-side front merge.  Same semantics: keep-first dedupe of
    bitwise-identical rows, then dominance (a row is dropped iff some
    row is <= on every column and < on at least one).  O(N^2) memory,
    fine for finalist-set sizes; traceable under jit."""
    import jax.numpy as jnp   # deferred: keep the numpy path jax-free

    pts = jnp.asarray(points, jnp.float64)
    n = pts.shape[0]
    if n == 0:
        return jnp.ones((0,), bool)
    eq = jnp.all(pts[:, None, :] == pts[None, :, :], axis=2)      # [i, j]
    earlier = jnp.arange(n)[None, :] < jnp.arange(n)[:, None]     # j < i
    dup = jnp.any(eq & earlier, axis=1)
    le = jnp.all(pts[None, :, :] <= pts[:, None, :], axis=2)      # j <= i
    lt = jnp.any(pts[None, :, :] < pts[:, None, :], axis=2)       # j < i somewhere
    dominated = jnp.any(le & lt, axis=1)
    return ~dup & ~dominated


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Indices of the Pareto-optimal rows, sorted by the first column."""
    idx = np.nonzero(pareto_mask(points))[0]
    return idx[np.argsort(np.asarray(points)[idx, 0])]
