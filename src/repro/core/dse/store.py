"""Pluggable result stores for the DSE evaluation engine.

The engine's caching policy (PR 1's in-process LRU memo) is factored out
behind one small interface so the same ``EvalEngine.evaluate()`` loop can
run against

* ``MemoryLRUStore`` — the historical in-process bounded LRU (the
  default; hits refresh recency, inserts evict the oldest entry);
* ``SqliteStore`` — a *persistent content-addressed* store: one sqlite
  file (WAL mode, safe under concurrent writers from many processes)
  keyed by canonical genome x engine context x schedule mode x cost-model
  version, so exact metrics accumulate across processes, CI runs, and
  users.  A ``COST_MODEL_VERSION`` bump changes every key and thereby
  invalidates stale entries automatically (``purge_stale()`` reclaims
  the dead rows);
* ``TieredStore`` — an LRU front over a persistent back: gets probe the
  front first and promote back-tier hits, puts write through to both.

Keys and values
---------------
The engine hands stores *short* keys — ``b"<mode>:" + canonical genome
bytes`` — plus, once at construction, a binding **context**: a digest of
everything else the metrics depend on (workload list and order, the
calibration table, precision/fusion flags, backend fidelity class, and
``simulator.costs.COST_MODEL_VERSION``).  In-process stores may ignore
the context (the engine instance itself scopes them); persistent stores
MUST fold it into the stored key, which is what makes the addressing
content-based: two engines with identical context share entries, any
difference (or a cost-model version bump) keeps them apart.

Values are the engine's memo rows: a ``(lat, en, tw)`` triple of
float64 ``(W,)`` arrays.  Persistence round-trips them through raw
little-endian bytes, so a store-served result is *bitwise* identical to
the freshly computed one (pinned by tests/test_store.py).

``put`` is put-if-absent everywhere: metrics for one key are immutable
(bitwise reproducible), so first-write-wins makes concurrent writers
trivially safe — two processes racing on one key insert the same bytes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import sqlite3
import threading
import time
import warnings
from typing import Dict, Optional, Tuple

import numpy as np

from ..simulator.costs import COST_MODEL_VERSION

__all__ = ["StoreStats", "ResultStore", "MemoryLRUStore", "SqliteStore",
           "TieredStore", "COST_MODEL_VERSION"]

Row = Tuple[np.ndarray, np.ndarray, np.ndarray]


@dataclasses.dataclass
class StoreStats:
    """Lifetime counters of one store instance (not of the backing file:
    a shared sqlite file is fed by many instances across processes)."""

    gets: int = 0
    hits: int = 0
    puts: int = 0
    evictions: int = 0
    errors: int = 0     # tier/backing failures survived (degraded ops)

    def hit_rate(self) -> float:
        return self.hits / max(self.gets, 1)

    def snapshot(self) -> Dict[str, float]:
        return {"gets": self.gets, "hits": self.hits, "puts": self.puts,
                "evictions": self.evictions, "errors": self.errors,
                "hit_rate": self.hit_rate()}


class ResultStore:
    """Interface the engine's caching policy is written against."""

    def bind(self, context: bytes) -> "ResultStore":
        """Attach the engine-context digest (see module docstring).
        Returns self.  Persistent stores fold it into every key;
        in-process stores may ignore it.  Rebinding with a different
        context raises — one store instance serves one engine context
        (share the *file*, not the instance)."""
        raise NotImplementedError

    def get(self, key: bytes) -> Optional[Row]:
        raise NotImplementedError

    def put(self, key: bytes, row: Row) -> None:
        """Put-if-absent; values for one key are immutable."""
        raise NotImplementedError

    def peek(self, key: bytes) -> bool:
        """Presence probe with no stats or recency side effects (the
        service uses it for per-request store-hit attribution)."""
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    def lru_dict(self) -> Optional[Dict[bytes, Row]]:
        """The in-memory LRU mapping when this store (or its front tier)
        has one — the engine's legacy ``_memo`` view — else None."""
        return None

    def close(self) -> None:
        pass


class _Bindable(ResultStore):
    def __init__(self) -> None:
        self._context: Optional[bytes] = None
        self.stats = StoreStats()

    def bind(self, context: bytes) -> "ResultStore":
        if self._context is not None and self._context != context:
            raise ValueError(
                "store instance already bound to a different engine "
                "context — construct one instance per engine (a "
                "persistent store may still share the same file path)")
        self._context = context
        return self


class MemoryLRUStore(_Bindable):
    """The historical engine memo as a store: bounded dict-ordered LRU.
    ``get`` refreshes recency; ``put`` evicts the least recently touched
    entry once ``max_entries`` is reached.  Not persistent; the binding
    context is ignored (the owning engine scopes the instance)."""

    def __init__(self, max_entries: int = 131_072):
        super().__init__()
        self.max_entries = max(int(max_entries), 1)
        self.data: Dict[bytes, Row] = {}
        self._lock = threading.Lock()

    def get(self, key: bytes) -> Optional[Row]:
        with self._lock:
            self.stats.gets += 1
            row = self.data.get(key)
            if row is None:
                return None
            self.data[key] = self.data.pop(key)   # refresh recency
            self.stats.hits += 1
            return row

    def put(self, key: bytes, row: Row) -> None:
        with self._lock:
            if key in self.data:
                return
            while len(self.data) >= self.max_entries:
                self.data.pop(next(iter(self.data)))
                self.stats.evictions += 1
            self.data[key] = row
            self.stats.puts += 1

    def peek(self, key: bytes) -> bool:
        return key in self.data

    def resize(self, max_entries: int) -> None:
        """Re-cap the LRU, eagerly evicting least-recently-touched
        entries when the new cap is smaller.  The engine applies an
        explicit ``memo_max`` to a caller-supplied store through this
        (previously ``memo_max`` was silently ignored with ``store=``)."""
        with self._lock:
            self.max_entries = max(int(max_entries), 1)
            while len(self.data) > self.max_entries:
                self.data.pop(next(iter(self.data)))
                self.stats.evictions += 1

    def __len__(self) -> int:
        return len(self.data)

    def lru_dict(self) -> Optional[Dict[bytes, Row]]:
        return self.data


class SqliteStore(_Bindable):
    """Persistent content-addressed result store over one sqlite file.

    Stored key = sha256(version digest + engine context + short key):
    canonical genome x chip/engine context x mode x cost-model version,
    fixed 32 bytes.  The file is opened in WAL mode with a busy timeout,
    and every write is a single ``INSERT OR IGNORE`` transaction —
    concurrent writers (threads or processes) serialize on sqlite's file
    lock and first-write-wins keeps the table consistent without any
    application-level locking (values per key are immutable).

    ``version`` defaults to ``simulator.costs.COST_MODEL_VERSION``; a
    bump re-addresses every key, so stale metrics can never be served.
    The superseded rows stay on disk (still tagged with the version that
    wrote them) until ``purge_stale()`` deletes them.

    Busy/locked errors (another writer holding the file lock past the
    30 s sqlite busy timeout, NFS hiccups, an injected fault under the
    chaos suite) are retried with bounded exponential backoff
    (``lock_retries`` attempts) instead of raising straight through
    ``EvalEngine.evaluate()``; only after the retry budget is exhausted
    does the error propagate.  ``close()`` runs a WAL checkpoint first
    so short-lived processes don't leave ``-wal``/``-shm`` files behind.
    """

    LOCK_BACKOFF_S = 0.02   # first retry sleep; doubles, capped at 0.5 s

    def __init__(self, path: str, version: str = COST_MODEL_VERSION,
                 lock_retries: int = 6, fault_injector=None):
        super().__init__()
        self.path = str(path)
        self.version = str(version)
        self.lock_retries = max(int(lock_retries), 1)
        self._faults = None   # armed after setup so schedules count ops only
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        self._lock = threading.Lock()   # sqlite conns are not thread-safe
        self._closed = False
        self._conn = sqlite3.connect(self.path, timeout=30.0,
                                     check_same_thread=False)
        with self._lock:
            self._execute("PRAGMA journal_mode=WAL")
            self._execute("PRAGMA synchronous=NORMAL")
            self._execute(
                "CREATE TABLE IF NOT EXISTS results ("
                " k BLOB PRIMARY KEY,"
                " w INTEGER NOT NULL,"
                " data BLOB NOT NULL,"
                " version TEXT NOT NULL,"
                " created REAL NOT NULL)")
            self._commit()
        self._faults = fault_injector

    # --------------------------------------------------------- lock retries
    @staticmethod
    def _is_lock_error(exc: sqlite3.OperationalError) -> bool:
        msg = str(exc).lower()
        return "locked" in msg or "busy" in msg

    def _retry(self, fn):
        """Run ``fn`` under the bounded-backoff locked/busy retry loop.
        Call with ``self._lock`` held."""
        delay = self.LOCK_BACKOFF_S
        for attempt in range(self.lock_retries):
            if self._faults is not None \
                    and self._faults.should_fire("sqlite_lock"):
                err: sqlite3.OperationalError = sqlite3.OperationalError(
                    "database is locked")
            else:
                try:
                    return fn()
                except sqlite3.OperationalError as exc:
                    if not self._is_lock_error(exc):
                        raise
                    err = exc
            if attempt == self.lock_retries - 1:
                raise err
            time.sleep(delay)
            delay = min(delay * 2, 0.5)
        raise AssertionError("unreachable")

    def _execute(self, sql: str, params: Tuple = ()):
        return self._retry(lambda: self._conn.execute(sql, params))

    def _commit(self) -> None:
        self._retry(self._conn.commit)

    # ------------------------------------------------------------ keys/values
    def _addr(self, key: bytes) -> bytes:
        h = hashlib.sha256()
        h.update(self.version.encode())
        h.update(b"\x00")
        h.update(self._context or b"")
        h.update(b"\x00")
        h.update(key)
        return h.digest()

    @staticmethod
    def _encode(row: Row) -> Tuple[int, bytes]:
        lat, en, tw = (np.ascontiguousarray(a, np.float64) for a in row)
        return len(lat), lat.tobytes() + en.tobytes() + tw.tobytes()

    @staticmethod
    def _decode(w: int, blob: bytes) -> Row:
        flat = np.frombuffer(blob, np.float64)
        return (flat[:w].copy(), flat[w:2 * w].copy(), flat[2 * w:].copy())

    # ------------------------------------------------------------- interface
    def get(self, key: bytes) -> Optional[Row]:
        self.stats.gets += 1
        with self._lock:
            cur = self._execute(
                "SELECT w, data FROM results WHERE k = ?", (self._addr(key),))
            hit = cur.fetchone()
        if hit is None:
            return None
        self.stats.hits += 1
        return self._decode(int(hit[0]), hit[1])

    def put(self, key: bytes, row: Row) -> None:
        w, blob = self._encode(row)
        with self._lock:
            self._execute(
                "INSERT OR IGNORE INTO results (k, w, data, version, created)"
                " VALUES (?, ?, ?, ?, ?)",
                (self._addr(key), w, blob, self.version, time.time()))
            self._commit()
        self.stats.puts += 1

    def peek(self, key: bytes) -> bool:
        with self._lock:
            cur = self._execute(
                "SELECT 1 FROM results WHERE k = ?", (self._addr(key),))
            return cur.fetchone() is not None

    def __len__(self) -> int:
        with self._lock:
            return int(self._execute(
                "SELECT COUNT(*) FROM results").fetchone()[0])

    def version_counts(self) -> Dict[str, int]:
        """Rows per cost-model version in the backing file (stale rows
        are the ones not matching ``self.version``)."""
        with self._lock:
            cur = self._execute(
                "SELECT version, COUNT(*) FROM results GROUP BY version")
            return {v: int(n) for v, n in cur.fetchall()}

    def purge_stale(self) -> int:
        """Delete rows written under any other cost-model version;
        returns the number reclaimed."""
        with self._lock:
            cur = self._execute(
                "DELETE FROM results WHERE version != ?", (self.version,))
            self._commit()
            return cur.rowcount

    def close(self) -> None:
        """Idempotent; checkpoints + truncates the WAL first so a
        short-lived process leaves just the .sqlite file behind."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            try:
                self._conn.execute("PRAGMA wal_checkpoint(TRUNCATE)")
            except sqlite3.Error:
                pass   # best effort — another writer may hold the lock
            self._conn.close()


class TieredStore(_Bindable):
    """LRU front tier over a (typically persistent) back tier.

    ``get``: front first; on a front miss the back is probed and a hit
    is promoted into the front (so a warm persistent file refills the
    hot in-process working set at memory speed).  ``put``: write-through
    to both tiers.  Stats: this instance counts the merged view; the
    tiers keep their own counters for attribution.

    Degradation: the back tier is *optional for correctness* (it only
    adds persistence), so a back-tier error — disk full, a locked sqlite
    file past its retry budget, an injected chaos fault — never fails
    the evaluation: the op completes against the LRU front alone, the
    failure is counted in ``stats.errors``, and a ``RuntimeWarning`` is
    emitted once per instance.  Reads degrade to front-only hits, writes
    to front-only inserts; the run loses persistence for those rows,
    not results."""

    def __init__(self, front: ResultStore, back: ResultStore):
        super().__init__()
        self.front = front
        self.back = back
        self._warned_back = False

    def bind(self, context: bytes) -> "ResultStore":
        super().bind(context)
        self.front.bind(context)
        self.back.bind(context)
        return self

    def _back_error(self, op: str, exc: Exception) -> None:
        self.stats.errors += 1
        if not self._warned_back:
            self._warned_back = True
            warnings.warn(
                f"TieredStore back tier failed on {op} ({exc!r}); "
                "continuing LRU-only (counted in stats.errors)",
                RuntimeWarning, stacklevel=3)

    def get(self, key: bytes) -> Optional[Row]:
        self.stats.gets += 1
        row = self.front.get(key)
        if row is None:
            try:
                row = self.back.get(key)
            except Exception as exc:      # degrade: serve front-only
                self._back_error("get", exc)
                row = None
            if row is not None:
                self.front.put(key, row)   # promote
        if row is not None:
            self.stats.hits += 1
        return row

    def put(self, key: bytes, row: Row) -> None:
        self.front.put(key, row)
        try:
            self.back.put(key, row)
        except Exception as exc:          # degrade: lose persistence only
            self._back_error("put", exc)
        self.stats.puts += 1

    def peek(self, key: bytes) -> bool:
        if self.front.peek(key):
            return True
        try:
            return self.back.peek(key)
        except Exception as exc:
            self._back_error("peek", exc)
            return False

    def __len__(self) -> int:
        try:
            n_back = len(self.back)
        except Exception as exc:
            self._back_error("len", exc)
            n_back = 0
        return max(len(self.front), n_back)

    def lru_dict(self) -> Optional[Dict[bytes, Row]]:
        return self.front.lru_dict()

    def close(self) -> None:
        self.front.close()
        try:
            self.back.close()
        except Exception as exc:
            self._back_error("close", exc)
