"""Durable checkpoint/resume for the §4 multi-seed pipeline.

A paper-scale ``run_pipeline`` study is hours of work whose stages are
already deterministic and content-addressed: sweeps are keyed by seed,
GA genome streams by ``PRNGKey(seed + bracket)``, and every metric row
is bitwise reproducible and memo-hit inert.  That means resume needs no
mid-kernel state capture at all — it only has to make each *stage
boundary* durable:

* after every sweep: the ``SweepResult`` arrays (which double as store
  rows — resume re-imports them, so refinements of a resumed run hit
  the store exactly like the uninterrupted run's warm store);
* after every refinement: the ``GAResult``, the final population and
  its metrics, the cumulative Pareto front *after* merging the stage,
  and the device-memo **delta** (the ``fresh_entries`` computed by this
  bracket) so later brackets' memo preloads stay warm across a resume;
* after every seed: a ``seed_done`` watermark.

Each stage is one ``.npz`` record written atomically (tmp file +
``os.replace`` + directory fsync): a SIGKILL at any instant leaves
either no record or a complete one, never a torn file.  Presence of the
record *is* the watermark — there is no manifest to double-write.

``meta.json`` pins a **run digest** — engine ``context_key()`` (which
already folds workloads, calibration, compile flags, backend fidelity,
and the cost-model version) plus every pipeline parameter that shapes
the outputs (seeds, brackets, samples per stratum, the full
``GAConfig``, island topology).  Resuming against a directory whose
digest differs raises ``CheckpointMismatch`` instead of silently mixing
two studies.

Bitwise-equality argument (pinned by tests/test_checkpoint.py): a
resumed run replays completed stages from records (bitwise, via npz)
and recomputes the rest from the same keyed RNG streams against a store
whose *values* are bitwise identical — and since memo/store hits are
bitwise inert everywhere in the engine and the fused loop, the merged
front, per-seed results, and ``best()`` match an uninterrupted run
bit for bit.

The checkpoint directory can also host the study's persistent result
store (``open_store()`` → ``TieredStore`` over ``results.sqlite``), so
one directory is the whole resumable study.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

from .store import MemoryLRUStore, SqliteStore, TieredStore

__all__ = ["CheckpointMismatch", "PipelineCheckpoint", "run_digest"]

_FORMAT = 1


class CheckpointMismatch(ValueError):
    """The checkpoint directory was written by a different study
    (engine context or pipeline parameters differ)."""


def run_digest(engine, seeds: Iterable[int], brackets: Iterable[float],
               samples_per_stratum: int, cfg, islands: Optional[int],
               migrate_every: int, migrate_k: int) -> str:
    """Digest of everything that determines the study's outputs."""
    text = repr((engine.context_key().hex(), engine.mode,
                 tuple(int(s) for s in seeds),
                 tuple(float(b) for b in brackets),
                 int(samples_per_stratum), dataclasses.astuple(cfg),
                 islands if islands is None else int(islands),
                 int(migrate_every), int(migrate_k), _FORMAT))
    return hashlib.sha256(text.encode()).hexdigest()


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class PipelineCheckpoint:
    """One directory of atomic per-stage records (see module docstring).

    Stage keys are ``sweep:<seed>``, ``refine:<seed>:<bracket:g>`` and
    ``seed_done:<seed>``; ``record()`` makes a key durable, ``has()``
    answers whether a prior run completed it, ``load()`` returns its
    arrays.  ``open()`` must run first: it writes the run digest on a
    fresh directory and verifies it on an existing one.
    """

    def __init__(self, path: str):
        self.path = str(path)
        os.makedirs(self.path, exist_ok=True)
        self._digest: Optional[str] = None
        self._done: Dict[str, str] = {}   # stage key -> filename

    # --------------------------------------------------------------- lifecycle
    def open(self, digest: str) -> "PipelineCheckpoint":
        meta_path = os.path.join(self.path, "meta.json")
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                meta = json.load(f)
            if meta.get("format") != _FORMAT:
                raise CheckpointMismatch(
                    f"checkpoint format {meta.get('format')!r} != {_FORMAT}")
            if meta.get("digest") != digest:
                raise CheckpointMismatch(
                    "checkpoint directory belongs to a different study "
                    f"(digest {meta.get('digest')!r:.20} != {digest!r:.20}); "
                    "use a fresh directory or rerun with the original "
                    "workloads/seeds/brackets/GA config")
        else:
            self._write_atomic(meta_path, json.dumps(
                {"format": _FORMAT, "digest": digest}).encode())
        self._digest = digest
        self._scan()
        return self

    def _write_atomic(self, path: str, data: bytes) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        _fsync_dir(self.path)

    def _scan(self) -> None:
        """Index complete records.  Only ``*.npz`` final names count —
        interrupted writes only ever leave ``*.tmp`` files behind."""
        self._done.clear()
        for fname in sorted(os.listdir(self.path)):
            if not fname.endswith(".npz"):
                continue
            full = os.path.join(self.path, fname)
            try:
                with np.load(full) as f:
                    key = str(f["stage"])
            except Exception:
                continue   # unreadable/foreign file — treat as absent
            self._done[key] = fname

    # ----------------------------------------------------------------- stages
    @staticmethod
    def _fname(key: str) -> str:
        return key.replace(":", "_").replace(".", "-") + ".npz"

    def completed(self) -> List[str]:
        return sorted(self._done)

    def has(self, key: str) -> bool:
        return key in self._done

    def record(self, key: str, **arrays: Any) -> None:
        """Make one completed stage durable (atomic; idempotent —
        last-write-wins, but stage outputs are deterministic so every
        write holds the same bytes)."""
        if self._digest is None:
            raise RuntimeError("PipelineCheckpoint.open() must run first")
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, stage=np.asarray(key), **arrays)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, os.path.join(self.path, self._fname(key)))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        _fsync_dir(self.path)
        self._done[key] = self._fname(key)

    def load(self, key: str) -> Dict[str, np.ndarray]:
        with np.load(os.path.join(self.path, self._done[key])) as f:
            return {k: f[k].copy() for k in f.files if k != "stage"}

    # ------------------------------------------------------------------ store
    def store_path(self) -> str:
        return os.path.join(self.path, "results.sqlite")

    def open_store(self, lru_entries: int = 131_072) -> TieredStore:
        """The study's persistent result store, living in the checkpoint
        directory: LRU front over ``results.sqlite``."""
        return TieredStore(MemoryLRUStore(lru_entries),
                           SqliteStore(self.store_path()))
