"""JAX-native batch evaluator: the MOSAIC compile+simulate cost model as a
single ``lax.scan`` over operators, ``vmap``-ed over thousands of candidate
chips and jitted (DESIGN.md §2 — the TPU-native re-think of the paper's
per-config host loop; the Pallas ``dse_eval`` kernel accelerates the
per-(config x op) pre-filter).

Per-(op, tile) costs come from the shared ``simulator.costs.CostModel``
(the identical code the reference ``TileSim`` executes), and the
activation cache is the same byte- and slot-bounded FIFO the orchestrator
runs (mirrored via ``simulator.batched.fifo_insert``).  What remains
approximate is the *in-scan greedy mapping*: Eq. 1-3 placement decisions
are re-derived inside the scan (with an epsilon tie-break instead of the
mapper's sequential one) and Eq. 3 splits ignore the rare per-slice
ragged remainder — so this evaluator fuses compile+simulate into one
dispatch, where ``simulator.batched`` executes an exact pre-compiled
plan.

Equivalence is pinned by tests/test_batch_eval.py: median relative error
vs the reference simulator and a tolerance band over random config
batches.

**Status (PR 5).**  Exact search is no longer more expensive than this
approximate scan: ``compiler.batched_mapper.search_and_simulate`` fuses
the *exact* Eq. 1-3 mapping with plan execution in one
class-specialized scan, and ``EvalEngine(backend="exact")`` routes
search through it — the device GA loop and the BO backend score on
exact metrics directly (search-time fitness == ``rescore()`` bitwise),
with no finalist re-ranking step.  This scan remains the engine's
default ``"scan"`` backend for bulk sweeps and as the approximate-search
baseline the perf trajectory is measured against
(``benchmarks/perf_micro.py``); searches that rank on it must still
re-score finalists through an exact backend.
"""
from __future__ import annotations

import copy
import functools
from typing import Dict, List, Sequence

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)  # cycle counts overflow f32 ULPs

import jax.numpy as jnp

from ..arch import (MAX_TILES, ChipConfig, Dataflow, Engine, Interconnect,
                    Sparsity)
from ..calibrate.asap7 import CalibrationTable, DEFAULT_CALIB
from ..compiler.fusion import fuse
from ..compiler.precision import assign_precision
from ..ir import (MAX_PREDS, OpClass, OpType, PRECISION_BYTES, WorkloadGraph,
                  bucket_ops)
from ..simulator.batched import (CHIP_KEYS, TILE_KEYS, fifo_insert,
                                 stack_chip_configs)
from ..simulator.costs import (ACC_BYTES, ACT_CACHE_SLOTS, CACHE_FRAC,
                               DSP_OPS_PER_ELEM, DSP_OPS_TABLE, FIDELITIES,
                               MAX_DRAM_CHANNELS, MAX_LINKS, SFU_NEED,
                               cost_model, dram_channel_one_hot,
                               noc_transfer_seconds, pipeline_bounds,
                               steady_state_energy, xy_route_link_mask)
from ..simulator.orchestrator import noc_hops

__all__ = ["prepare_workload", "prepare_configs", "batch_evaluate"]

_ACC = ACC_BYTES[0]
_F = jnp.float64

# Backwards-compatible aliases (tables now live in simulator.costs).
_DSP_OPS_TABLE = DSP_OPS_TABLE
_SFU_NEED = SFU_NEED
_bucket = bucket_ops


# =============================================================================
# host-side preparation
# =============================================================================


def prepare_workload(g: WorkloadGraph, aggressive_int4: bool = False,
                     enable_fusion: bool = True) -> Dict[str, np.ndarray]:
    """Run the config-independent compiler passes 1-2 and emit SoA arrays."""
    g = copy.deepcopy(g)
    g = assign_precision(g, aggressive_int4=aggressive_int4)
    if enable_fusion:
        g = fuse(g)
    t = g.to_tensor(max_ops=_bucket(len(g.nodes)))
    a = dict(t.arrays)
    a["preds"] = t.preds
    num_preds = (t.preds >= 0).sum(axis=1).astype(np.float64)
    a["num_preds"] = num_preds
    a["per_pred_bytes"] = a["bytes_in"] / np.maximum(num_preds, 1.0)
    # PPM energy + Eq. 6 refund for fused children, credited to the head
    fused_lane_ops = np.zeros(t.max_ops)
    fused_refund_b = np.zeros(t.max_ops)
    for j, nd in enumerate(g.nodes):
        if nd.fused_into >= 0:
            fused_lane_ops[nd.fused_into] += nd.elems * 2.0
            fused_refund_b[nd.fused_into] += 2.0 * nd.bytes_out
    a["fused_lane_ops"] = fused_lane_ops
    a["fused_refund_bytes"] = fused_refund_b
    a["total_macs"] = np.float64(g.total_macs)
    return a


def prepare_configs(chips: Sequence[ChipConfig],
                    calib: CalibrationTable = DEFAULT_CALIB) -> Dict[str, np.ndarray]:
    """Stack a list of chips into (B, MAX_TILES) / (B,) arrays (the single
    implementation lives in ``simulator.batched.stack_chip_configs``)."""
    return stack_chip_configs(chips, calib)


# =============================================================================
# vectorized per-tile models — now just the shared CostModel
# =============================================================================

def _make_eval(calib: CalibrationTable, max_ops: int):
    """Bind the shared simulator cost formulas for this calibration.

    Everything below delegates to ``simulator.costs.CostModel`` — the same
    code ``TileSim`` and the batched plan executor run — so a calibration
    edit cannot drift between the search evaluator and the oracle."""
    cm = cost_model(calib, jnp)

    def execute(T, op, bw_gbps, dram_rd, dram_wr):
        out = cm.execute(T, op, bw_gbps, dram_rd, dram_wr)
        return (out["seconds"], out["energy_total"], out["cycles"],
                out["dram_bytes"])

    return {
        "supports": cm.supports, "roofline_cycles": cm.roofline_cycles,
        "execute": execute, "sfu_native": cm.sfu_native, "eta": cm.eta,
    }


# =============================================================================
# the scan: greedy Eq. 1-3 mapping + orchestrator replay, one op per step
# =============================================================================

def _build_eval_fn(calib: CalibrationTable, max_ops: int,
                   fidelity: str = "aggregate"):
    fns = _make_eval(calib, max_ops)
    c = calib
    eps_tie = 1e-18
    link = fidelity == "link"

    def eval_one(tile, chip, ops_xs, total_macs):
        """Evaluate ONE config against one workload.  tile: dict of
        (MAX_TILES,) arrays; chip: dict of scalars; ops_xs: dict of
        (max_ops, ...) arrays."""
        T = tile
        n_tiles_f = jnp.sum(T["exists"])
        tidx_f = jnp.arange(MAX_TILES, dtype=_F)
        ch_oh = dram_channel_one_hot(jnp, tidx_f, chip["dram_channels"])

        def noc_seconds(nbytes):
            cyc = jnp.ceil(nbytes / chip["noc_bpc"]) \
                + chip["hops"] * chip["noc_base_cycles"]
            return cyc / chip["ref_clock_hz"]

        def link_seconds(nbytes):
            return noc_transfer_seconds(jnp, nbytes, chip["noc_bpc"], 1.0,
                                        chip["noc_base_cycles"],
                                        chip["ref_clock_hz"])

        def noc_energy(nbytes):
            return nbytes * c.e_noc_pj_per_byte_hop * chip["hops"]

        bw_static = chip["dram_gbps"] / n_tiles_f

        def step(carry, op):
            (fin_est, fin_act, opf_est, opf_act, op_tile, tile_ops, energy,
             cached_at, fifo_ops, fifo_bytes, tile_busy, res_occ) = carry[:12]
            if link:
                link_occ, chan_occ = carry[12], carry[13]
            idx = jnp.asarray(op["index"], jnp.int32)
            active = (op["valid"] > 0) & (op["fused"] == 0)

            compat = fns["supports"](T, op)
            # special ops route to native-SFU tiles when one exists (§3.2)
            native = fns["sfu_native"](T, op) & compat
            has_native = jnp.any(native)
            is_spec = op["op_cls"] == int(OpClass.SPECIAL)
            compat = jnp.where(is_spec & has_native, native, compat)

            preds = jnp.asarray(op["preds"], jnp.int32)
            pred_ok = preds >= 0
            pidx = jnp.maximum(preds, 0)
            per_pred = op["per_pred_bytes"]

            # ---------- estimate domain (mapper, Eq. 1-2) ----------
            pf_est = jnp.where(pred_ok, opf_est[pidx], 0.0)
            ptile = jnp.where(pred_ok, op_tile[pidx], -1)
            # (P, T): pred finish + NoC hop if cross-tile (fused/absent
            # preds, op_tile == -1, count as local — mirrors the reference)
            cross = (ptile[:, None] != jnp.arange(MAX_TILES)[None, :]) \
                & (ptile[:, None] >= 0)
            dep_est = jnp.max(jnp.where(
                pred_ok[:, None],
                pf_est[:, None] + jnp.where(cross, noc_seconds(per_pred), 0.0),
                0.0), axis=0)
            t_start_est = jnp.maximum(fin_est, dep_est)
            c_hat = fns["roofline_cycles"](T, op, bw_static) / T["clock_hz"]
            completion = t_start_est + c_hat + T["num_macs"] * eps_tie
            completion = jnp.where(compat, completion, jnp.inf)
            best_single = jnp.argmin(completion)
            best_single_fin = completion[best_single] - T["num_macs"][best_single] * eps_tie

            # ---------- split candidates (Eq. 3) ----------
            mac_mask = compat & (T["num_macs"] > 0)
            ksplit = jnp.sum(mac_mask)
            can_split = (op["op_cls"] == int(OpClass.MAC)) \
                & (op["splittable"] > 0) & (op["macs"] > 0) & (ksplit >= 2)
            kf = jnp.maximum(ksplit, 1.0)

            def split_fin(axis):
                sm = jnp.where(axis == 1, jnp.maximum(jnp.floor(op["m"] / kf), 1.0), op["m"])
                sn = jnp.where(axis == 0, jnp.maximum(jnp.floor(op["n"] / kf), 1.0), op["n"])
                sk = jnp.where(axis == 2, jnp.maximum(jnp.floor(op["k"] / kf), 1.0), op["k"])
                sub = dict(op)
                sub["m"], sub["n"], sub["k"] = sm, sn, sk
                sub["macs"] = sm * sn * sk
                sub["bytes_in"] = jnp.floor(op["bytes_in"] / jnp.where(axis == 1, kf, 1.0))
                sub["bytes_w"] = jnp.floor(op["bytes_w"] / jnp.where(axis != 1, kf, 1.0))
                sub["bytes_out"] = jnp.floor(op["bytes_out"] / jnp.where(axis != 2, kf, 1.0))
                ch = fns["roofline_cycles"](T, sub, bw_static / kf) / T["clock_hz"]
                fins = jnp.where(mac_mask, t_start_est + ch, -jnp.inf)
                return jnp.max(fins) + noc_seconds(op["bytes_out"] / kf), sub

            fin_oc, sub_oc = split_fin(0)
            fin_b, sub_b = split_fin(1)
            fin_ic, sub_ic = split_fin(2)
            fins3 = jnp.stack([fin_oc, fin_b, fin_ic])
            best_axis = jnp.argmin(fins3)
            best_split_fin = fins3[best_axis]
            do_split = can_split & (best_split_fin < best_single_fin)

            sub = {k2: jnp.select([best_axis == 0, best_axis == 1],
                                  [sub_oc[k2], sub_b[k2]], sub_ic[k2])
                   for k2 in ("m", "n", "k", "macs", "bytes_in", "bytes_w",
                              "bytes_out")}
            for k2 in ("op_type", "op_cls", "precision", "elems",
                       "act_sparsity", "w_sparsity", "fft_n", "poly_degree",
                       "snn_timesteps", "seq_len"):
                sub[k2] = op[k2]

            owner = jnp.where(do_split,
                              jnp.argmax(mac_mask), best_single).astype(jnp.int32)
            choice_fin_est = jnp.where(do_split, best_split_fin, best_single_fin)

            # ---------- actual domain (orchestrator §3.3.4) ----------
            pf_act = jnp.where(pred_ok, opf_act[pidx], 0.0)
            t_dep_act = jnp.max(jnp.where(pred_ok, pf_act, 0.0))
            # FIFO activation cache, identical to the orchestrator's:
            # cached_at carries the op -> holding-tile map maintained by
            # fifo_insert below
            src = jnp.where(pred_ok, cached_at[pidx], -1)
            via_noc = pred_ok & (src >= 0) & (src != owner)
            miss = pred_ok & (src < 0)
            dram_rd = op["bytes_w"] + jnp.sum(jnp.where(miss, per_pred, 0.0)) \
                + jnp.where(jnp.sum(pred_ok) == 0, op["bytes_in"], 0.0)
            extra_noc_s = jnp.sum(jnp.where(via_noc, noc_seconds(per_pred), 0.0))
            e_noc = jnp.sum(jnp.where(via_noc, noc_energy(per_pred), 0.0))
            # write-back: outputs fitting the owner's cache skip DRAM
            dram_wr = jnp.where(op["bytes_out"] > T["cache_cap"][owner],
                                op["bytes_out"], 0.0)

            t_start0 = jnp.maximum(fin_act[owner], t_dep_act)
            n_active = jnp.maximum(jnp.sum(
                jnp.where(T["exists"] > 0, fin_act > t_start0, False)), 1.0)
            bw_share = chip["dram_gbps"] / n_active

            # single-tile execution on ALL tiles, select owner
            sec_all, en_all, _, db_single = fns["execute"](T, op, bw_share,
                                                           dram_rd, dram_wr)
            t_start_1 = t_start0 + extra_noc_s
            fin_single = t_start_1 + sec_all[owner]

            # split execution (mirrors orchestrator._run_split)
            sec_sub, en_sub, _, db_sub = fns["execute"](T, sub, bw_share,
                                                        dram_rd / kf,
                                                        dram_wr / kf)
            starts_sub = jnp.maximum(fin_act, t_dep_act) + extra_noc_s
            fins_sub = jnp.where(mac_mask, starts_sub + sec_sub, -jnp.inf)
            reduce_s = noc_seconds(op["bytes_out"] / kf)
            fin_split = jnp.max(fins_sub) + reduce_s
            e_split = jnp.sum(jnp.where(mac_mask, en_sub, 0.0)) \
                + (kf - 1.0) * noc_energy(op["bytes_out"] / kf)

            # unmappable op (reference raises UnmappableError) -> inf latency
            any_compat = jnp.any(compat)
            fin_op = jnp.where(do_split, fin_split, fin_single)
            fin_op = jnp.where(any_compat, fin_op, jnp.inf)
            e_op = jnp.where(do_split, e_split, en_all[owner]) + e_noc
            # PPM energy of fused children + Eq. 6 refund
            e_op = e_op + op["fused_lane_ops"] * c.e_dsp_pj_per_lane_op \
                - op["fused_refund_bytes"] * c.e_sram_pj_per_byte

            # ---------- state update ----------
            onehot = jax.nn.one_hot(owner, MAX_TILES, dtype=_F)
            new_fin_act = jnp.where(
                do_split & mac_mask, fins_sub,
                jnp.where(onehot > 0, fin_single, fin_act))
            new_fin_act = jnp.where(
                do_split & (onehot > 0), jnp.maximum(new_fin_act, fin_split),
                new_fin_act)
            new_fin_est = jnp.where(
                do_split & mac_mask, jnp.maximum(fin_est, choice_fin_est),
                jnp.where(onehot > 0, choice_fin_est, fin_est))
            new_ops = tile_ops + jnp.where(do_split, mac_mask.astype(_F), onehot)

            fin_est = jnp.where(active, new_fin_est, fin_est)
            fin_act = jnp.where(active, new_fin_act, fin_act)
            opf_est = opf_est.at[idx].set(jnp.where(active, choice_fin_est, 0.0))
            opf_act = opf_act.at[idx].set(jnp.where(active, fin_op, 0.0))
            op_tile = op_tile.at[idx].set(jnp.where(active, owner, -1))
            tile_ops = jnp.where(active, new_ops, tile_ops)
            energy = energy + jnp.where(active, e_op, 0.0)

            # throughput-mode II state: per-tile busy time plus shared
            # DRAM-byte / NoC-second occupancy (the batched executor's
            # res_occ twin, on this scan's greedy placements)
            busy_op = jnp.where(do_split, jnp.where(mac_mask, sec_sub, 0.0),
                                onehot * sec_all[owner])
            tile_busy = tile_busy + jnp.where(active, busy_op, 0.0)
            dram_b_op = jnp.where(
                do_split,
                jnp.sum(jnp.where(mac_mask,
                                  jnp.broadcast_to(db_sub, (MAX_TILES,)),
                                  0.0)),
                db_single)
            noc_s_op = extra_noc_s + jnp.where(do_split, reduce_s, 0.0)
            res_occ = res_occ + jnp.where(
                active, jnp.stack([dram_b_op, noc_s_op]), jnp.zeros(2, _F))

            if link:
                # per-link XY-route and per-DRAM-channel occupancy on this
                # scan's greedy placements (same composition the exact
                # backends accumulate; tightens the II bound only)
                owner_f = jnp.asarray(owner, _F)
                acq_rt = xy_route_link_mask(jnp, jnp.asarray(src, _F),
                                            owner_f, chip["grid_w"],
                                            chip["grid_h"], chip["torus"])
                acq_t = link_seconds(per_pred)
                for p in range(MAX_PREDS):
                    link_occ = link_occ + jnp.where(active,
                                                    acq_rt[p] * acq_t, 0.0)
                red_rt = xy_route_link_mask(jnp, tidx_f, owner_f,
                                            chip["grid_w"], chip["grid_h"],
                                            chip["torus"])
                red_t = link_seconds(op["bytes_out"] / kf)
                for t in range(MAX_TILES):
                    link_occ = link_occ + jnp.where(
                        active & do_split & mac_mask[t], red_rt[t] * red_t,
                        0.0)
                dram_each = jnp.where(
                    do_split, jnp.where(mac_mask, db_sub, 0.0),
                    jnp.where(onehot > 0, db_single, 0.0))
                for t in range(MAX_TILES):
                    chan_occ = chan_occ + jnp.where(active,
                                                    dram_each[t] * ch_oh[t],
                                                    0.0)

            fifo_ops, fifo_bytes, cached_at = fifo_insert(
                fifo_ops, fifo_bytes, cached_at, owner, idx,
                op["bytes_out"], T["cache_cap"][owner], active)
            out = (fin_est, fin_act, opf_est, opf_act, op_tile, tile_ops,
                   energy, cached_at, fifo_ops, fifo_bytes, tile_busy,
                   res_occ)
            if link:
                out = out + (link_occ, chan_occ)
            return out, None

        init = (jnp.zeros(MAX_TILES, _F), jnp.zeros(MAX_TILES, _F),
                jnp.zeros(max_ops, _F), jnp.zeros(max_ops, _F),
                jnp.full(max_ops, -1, jnp.int32), jnp.zeros(MAX_TILES, _F),
                jnp.asarray(0.0, _F), jnp.full(max_ops, -1, jnp.int32),
                jnp.full((MAX_TILES, ACT_CACHE_SLOTS), -1, jnp.int32),
                jnp.zeros((MAX_TILES, ACT_CACHE_SLOTS), _F),
                jnp.zeros(MAX_TILES, _F), jnp.zeros(2, _F))
        if link:
            init = init + (jnp.zeros(MAX_LINKS, _F),
                           jnp.zeros(MAX_DRAM_CHANNELS, _F))
        final, _ = jax.lax.scan(step, init, ops_xs["per_op"])
        (fin_est, fin_act, opf_est, opf_act, op_tile, tile_ops, energy,
         _, _, _, tile_busy, res_occ) = final[:12]
        link_occ, chan_occ = (final[12], final[13]) if link else (None, None)

        makespan = jnp.max(fin_act)
        gated = tile_ops <= 0
        resid = jnp.where(gated, c.power_gate_residual, 1.0)
        leak = jnp.sum(jnp.where(T["exists"] > 0,
                                 c.leak_mw_per_mm2 * T["area_mm2"]
                                 * makespan * resid * 1e9, 0.0))
        energy = energy + leak
        achieved_tops = jnp.where(makespan > 0, total_macs / makespan / 1e12, 0.0)

        # throughput-mode steady state (same pipeline_bounds composition
        # as the exact backends, over this scan's greedy placements);
        # unmappable candidates keep inf on the II surface too
        leak_rate = jnp.sum(jnp.where(T["exists"] > 0,
                                      c.leak_mw_per_mm2 * T["area_mm2"]
                                      * resid * 1e9, 0.0))
        bounds = pipeline_bounds(
            jnp, makespan, jnp.max(tile_busy), res_occ[0],
            chip["dram_gbps"], res_occ[1], chan_bytes=chan_occ,
            dram_channels=chip["dram_channels"] if link else None,
            link_busy_s=link_occ)
        ii = jnp.where(jnp.isfinite(makespan), bounds["ii_s"], jnp.inf)
        energy_ss = jnp.where(
            jnp.isfinite(makespan),
            steady_state_energy(energy, leak, leak_rate, ii), jnp.inf)
        tops_ss = jnp.where(jnp.isfinite(ii) & (ii > 0),
                            total_macs / ii / 1e12, 0.0)
        return {"latency_s": makespan, "energy_pj": energy,
                "achieved_tops": achieved_tops, "ii_s": ii,
                "energy_ss_pj": energy_ss, "achieved_tops_ss": tops_ss,
                "fill_latency_s": makespan}

    return eval_one


@functools.lru_cache(maxsize=64)
def _jitted(calib_key, max_ops: int, fidelity: str = "aggregate"):
    # maxsize must exceed the distinct (calib, max_ops) pairs of a full
    # workload-suite sweep: the multiple-of-64 op buckets give the 20
    # stock workloads ~10 distinct max_ops, and an engine loops over all
    # of them every evaluate() — an undersized LRU would recompile the
    # evaluator on every call
    calib = _CALIB_REGISTRY[calib_key]
    eval_one = _build_eval_fn(calib, max_ops, fidelity)
    batched = jax.vmap(eval_one, in_axes=({k: 0 for k in _TILE_KEYS},
                                          {k: 0 for k in _CHIP_KEYS},
                                          None, None))
    return jax.jit(batched)


# the single field list lives with the config stacker in simulator.batched
_TILE_KEYS = TILE_KEYS
_CHIP_KEYS = CHIP_KEYS
_CALIB_REGISTRY: Dict[int, CalibrationTable] = {}

_PER_OP_KEYS = ("op_type", "op_cls", "macs", "elems", "m", "k", "n",
                "precision", "bytes_in", "bytes_w", "bytes_out",
                "act_sparsity", "w_sparsity", "fft_n", "poly_degree",
                "snn_timesteps", "seq_len", "splittable", "fused", "valid",
                "num_preds", "per_pred_bytes", "fused_lane_ops",
                "fused_refund_bytes")


def batch_evaluate(ws: Dict[str, np.ndarray], cfgs: Dict[str, Dict[str, np.ndarray]],
                   calib: CalibrationTable = DEFAULT_CALIB,
                   fidelity: str = "aggregate") -> Dict[str, np.ndarray]:
    """Evaluate every config in ``cfgs`` against workload ``ws``.

    Returns dict with (B,) arrays: latency_s, energy_pj, achieved_tops,
    plus pass-through area/peak_tops from prepare_configs.
    """
    if fidelity not in FIDELITIES:
        raise ValueError(f"fidelity must be one of {FIDELITIES}, got {fidelity!r}")
    key = id(calib)
    _CALIB_REGISTRY[key] = calib
    max_ops = len(ws["op_type"])
    per_op = {k: jnp.asarray(ws[k], _F) for k in _PER_OP_KEYS}
    per_op["index"] = jnp.arange(max_ops, dtype=jnp.int32)
    per_op["preds"] = jnp.asarray(ws["preds"], jnp.int32)
    ops_xs = {"per_op": per_op}
    tile = {k: jnp.asarray(cfgs["tile"][k], _F) for k in _TILE_KEYS}
    chip = {k: jnp.asarray(cfgs["chip"][k], _F) for k in _CHIP_KEYS}
    fn = _jitted(key, max_ops, fidelity)
    out = fn(tile, chip, ops_xs, jnp.asarray(float(ws["total_macs"]), _F))
    res = {k: np.asarray(v) for k, v in out.items()}
    res["area_mm2"] = cfgs["chip"]["chip_area"]
    res["peak_tops"] = cfgs["chip"]["peak_tops"]
    return res
